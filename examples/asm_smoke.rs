//! CI smoke for the assembler front end: assemble the shipped example
//! listing, check it against its generator twin, and validate the
//! `cimone asm --analyze --json` output through `Json::parse`.
//! Optionally validates an externally produced JSON file (e.g. piped
//! from `cimone asm ... --analyze --json`) passed as the first argument.
//!
//! ```text
//! cargo run --example asm_smoke [-- asm.json]
//! ```

use cimone::isa::assembler;
use cimone::util::json::Json;

fn main() -> cimone::Result<()> {
    let path = "examples/kernels/dgemm_rvv1_8x8.S";
    let text = std::fs::read_to_string(path)?;
    let prog = assembler::assemble_named(&text, path).map_err(anyhow::Error::msg)?;
    let (v, m, s) = prog.mix();
    assert_eq!(prog.insts.len(), v + m + s, "mix does not partition the program");
    assert!(v > 0, "{path}: no vector instructions?");

    // validate an externally produced `--analyze --json` file when given one
    if let Some(json_path) = std::env::args().nth(1) {
        let external = std::fs::read_to_string(&json_path)?;
        let parsed = Json::parse(&external).map_err(anyhow::Error::msg)?;
        let dialect = parsed
            .get("dialect")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("{json_path}: missing `dialect`"))?;
        assert_eq!(dialect, "rvv10", "{json_path}: unexpected dialect");
        let insts = parsed.get("insts").and_then(Json::as_usize).unwrap_or(0);
        assert!(insts > 0, "{json_path}: zero instructions");
        let flops = parsed.get("flops").and_then(Json::as_usize).unwrap_or(0);
        let cycles = parsed.get("cycles").and_then(Json::as_f64).unwrap_or(0.0);
        assert!(flops > 0, "{json_path}: zero flops");
        assert!(cycles > 0.0, "{json_path}: zero cycles");
        println!("{json_path}: valid analysis JSON ({insts} insts, {flops} flops)");
    }

    let n = v + m + s;
    println!("asm smoke OK: {path} assembles to {n} insts ({v} vector, {m} mem, {s} other)");
    Ok(())
}
