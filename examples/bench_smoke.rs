//! CI smoke for `cimone bench`: validate a bench JSON file (as written
//! by `cimone bench --quick --out FILE`) through `Json::parse`, check
//! every recorded metric is present and positive, and print the
//! determinism fingerprint so the CI job can compare two fresh runs.
//!
//! ```text
//! cargo run --example bench_smoke -- BENCH_A.json
//! ```
//!
//! Without an argument it runs the quick suite in-process instead and
//! validates its JSON the same way.

use cimone::util::json::Json;

const REQUIRED_KEYS: [&str; 12] = [
    "vec_machine_insts_per_s",
    "program_gen_per_s",
    "analyze_cold_per_s",
    "analyze_warm_per_s",
    "trace_sim_interval_accesses_per_s",
    "trace_sim_per_access_accesses_per_s",
    "trace_sim_speedup",
    "trace_memo_lookups_per_s",
    "scenarios_per_s_cold",
    "scenarios_per_s_warm",
    "warm_speedup",
    "full_codesign_scenarios_per_s",
];

/// The memo caches whose counters the bench surfaces under `caches`.
const CACHES: [&str; 4] = ["programs", "analyses", "estimates", "traces"];

fn main() -> cimone::Result<()> {
    let (text, source) = match std::env::args().nth(1) {
        Some(path) => (std::fs::read_to_string(&path)?, path),
        None => (cimone::perfsuite::run(true)?.json.render(), "in-process".to_string()),
    };
    let parsed = Json::parse(&text).map_err(anyhow::Error::msg)?;
    for key in REQUIRED_KEYS {
        let v = parsed.get(key).and_then(Json::as_f64).unwrap_or(-1.0);
        anyhow::ensure!(v > 0.0, "{source}: `{key}` missing or non-positive ({v})");
    }
    let caches = parsed
        .get("caches")
        .ok_or_else(|| anyhow::anyhow!("{source}: missing `caches` stats object"))?;
    for name in CACHES {
        let c = caches
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("{source}: missing `caches.{name}`"))?;
        for counter in ["hits", "misses", "entries", "hit_rate"] {
            let v = c.get(counter).and_then(Json::as_f64).unwrap_or(-1.0);
            anyhow::ensure!(v >= 0.0, "{source}: `caches.{name}.{counter}` missing ({v})");
        }
    }
    let fp = parsed
        .get("determinism_fingerprint")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("{source}: missing `determinism_fingerprint`"))?;
    anyhow::ensure!(
        fp.len() == 32 && fp.chars().all(|c| c.is_ascii_hexdigit()),
        "{source}: fingerprint `{fp}` is not a 128-bit hex digest"
    );
    let warm = parsed.get("warm_speedup").and_then(Json::as_f64).unwrap_or(0.0);
    eprintln!("bench smoke OK ({source}): warm/cold sweep speedup {warm:.1}x");
    // stdout carries ONLY the fingerprint, for `FP=$(... bench_smoke ...)`
    println!("{fp}");
    Ok(())
}
