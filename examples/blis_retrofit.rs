//! BLIS retrofit scenario — the paper's Section 3.3 as a live demo:
//!
//! 1. print BLIS's shipped RVV 1.0 micro-kernel (Fig 2a schedule);
//! 2. retrofit it to RVV 0.7.1 / theadvector (Section 3.3.1) and show the
//!    rewritten assembly;
//! 3. execute both on the functional vector machine — bitwise-equal C;
//! 4. apply the LMUL=4 rewrite (Section 3.3.2, Fig 2b) and show the
//!    instruction-count and modelled-cycle deltas that become Fig 7's +49%.
//!
//! ```bash
//! cargo run --release --example blis_retrofit
//! ```

use cimone::arch::presets;
use cimone::isa::asm::render_program;
use cimone::isa::exec::VecMachine;
use cimone::isa::timing::CycleModel;
use cimone::isa::translate::rvv10_to_thead;
use cimone::ukernel::{KernelRegistry, PanelLayout};
use cimone::util::Matrix;

fn main() {
    let kc = 2;
    let layout = PanelLayout::new(8, 4, kc);
    let reg = KernelRegistry::builtin();

    // 1. the shipped kernel
    let lmul1 = reg.get("blis-lmul1").unwrap();
    let prog10 = lmul1.program(layout);
    println!("--- BLIS rv64iv micro-kernel (RVV 1.0), kc={kc} ---");
    println!("{}\n", render_program(&prog10));

    // 2. the retrofit
    let prog07 = rvv10_to_thead(&prog10).expect("retrofit");
    println!("--- retrofitted to theadvector / RVV 0.7.1 (Section 3.3.1) ---");
    println!("{}\n", render_program(&prog07));

    // 3. numerical equivalence on the vector machine
    let a = Matrix::random_hpl(8, kc, 1);
    let b = Matrix::random_hpl(kc, 4, 2);
    let c = Matrix::random_hpl(8, 4, 3);
    let mem = layout.pack(&a, &b, &c);
    let mut m10 = VecMachine::new(128, layout.mem_words()).unwrap();
    let mut m07 = VecMachine::new(128, layout.mem_words()).unwrap();
    m10.mem = mem.clone();
    m07.mem = mem;
    m10.run(&prog10).unwrap();
    m07.run(&prog07).unwrap();
    assert_eq!(m10.mem, m07.mem);
    println!("retrofit check: RVV 1.0 and 0.7.1 programs produce bitwise-equal C\n");

    // 4. the optimization
    let lmul4 = reg.get("blis-lmul4").unwrap();
    let deep = PanelLayout::new(8, 4, 128);
    let p1 = lmul1.program(deep);
    let p4 = lmul4.program(deep);
    let core = presets::c920();
    let cm = CycleModel::new(&core);
    let t1 = cm.analyze(&p1);
    let t4 = cm.analyze(&p4);
    println!("--- LMUL=1 -> LMUL=4 rewrite (Section 3.3.2), kc=128 ---");
    println!("                      LMUL=1      LMUL=4");
    println!("instructions        {:>8}    {:>8}", t1.insts, t4.insts);
    println!("modelled cycles     {:>8.0}    {:>8.0}", t1.cycles, t4.cycles);
    println!("flops/cycle         {:>8.2}    {:>8.2}", t1.flops_per_cycle(), t4.flops_per_cycle());
    println!(
        "kernel speedup: {:.2}x  (propagates to the paper's +49% HPL gain at 128 cores)",
        t1.cycles / t4.cycles
    );

    // and the numerics still agree, of course
    let a = Matrix::random_hpl(8, 128, 4);
    let b = Matrix::random_hpl(128, 4, 5);
    let c = Matrix::random_hpl(8, 4, 6);
    let o1 = lmul1.run(&a, &b, &c).unwrap();
    let o4 = lmul4.run(&a, &b, &c).unwrap();
    assert!(o1.allclose(&o4, 0.0, 0.0));
    println!("numerics check: both schedules produce bitwise-identical results");
}
