//! END-TO-END DRIVER — the full system composed on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_cluster
//! ```
//!
//! What happens, in order:
//! 1. the Monte Cimone v2 fleet is instantiated (12 nodes, 2 partitions);
//! 2. a REAL HPL system (N=256) is generated, factored with its trailing
//!    updates executed through the PJRT artifacts — i.e. the Pallas
//!    micro-kernel lowered through JAX to HLO, compiled and run by the
//!    Rust runtime — solved, and validated with HPL's residual criterion;
//! 3. the real STREAM kernels run through their artifacts and validate;
//! 4. the paper's full benchmark campaign is submitted to the SLURM-like
//!    scheduler with modelled runtimes, metrics land in the ExaMon-like
//!    monitor;
//! 5. every figure of the paper is regenerated and printed.
//!
//! The run is recorded in EXPERIMENTS.md section End-to-end.

use std::time::Instant;

use anyhow::Context;
use cimone::cluster::monte_cimone_v2;
use cimone::coordinator::driver::run_campaign_on;
use cimone::coordinator::report;
use cimone::error::CimoneError;
use cimone::hpl::lu::{lu_blocked, lu_solve};
use cimone::hpl::validate::{hpl_residual, HPL_THRESHOLD};
use cimone::runtime::{entries, Runtime};
use cimone::util::stats::hpl_flops;
use cimone::util::{Matrix, Rng};

fn main() -> cimone::Result<()> {
    let t0 = Instant::now();
    println!("==================================================================");
    println!(" Monte Cimone v2 reproduction — end-to-end driver");
    println!("==================================================================\n");

    // --- 1. fleet ---
    let inv = monte_cimone_v2();
    println!(
        "[1/5] fleet: {} nodes ({} MCv1 + {} MCv2), {:.0} Gflop/s peak, fabric: {}",
        inv.nodes.len(),
        8,
        4,
        inv.peak_gflops(),
        inv.fabric.label
    );

    // --- 2. real HPL through the PJRT artifacts (all three layers) ---
    let mut rt = Runtime::new().context("run `make artifacts`")?;
    println!("[2/5] PJRT runtime up on `{}`; running HPL N=256 via artifacts...", rt.platform());
    let n = rt.manifest.n_gemm;
    let nb = rt.manifest.nb;
    let a = Matrix::random_hpl(n, n, 2026);
    let mut rng = Rng::new(710);
    let b: Vec<f64> = (0..n).map(|_| rng.hpl_entry()).collect();
    let t = Instant::now();
    let mut update = |c: &mut Matrix, l: &Matrix, u: &Matrix| {
        entries::trailing_update(&mut rt, c, l, u).map_err(CimoneError::from)
    };
    let f = lu_blocked(&a, nb, &mut update)?;
    let x = lu_solve(&f, &b);
    let secs = t.elapsed().as_secs_f64();
    let res = hpl_residual(&a, &x, &b);
    println!(
        "      HPL N={n} nb={nb}: {:.2}s ({:.2} Gflop/s host), residual {:.2e} -> {}",
        secs,
        hpl_flops(n) / secs / 1e9,
        res,
        if res < HPL_THRESHOLD { "PASSED" } else { "FAILED" }
    );
    if res >= HPL_THRESHOLD {
        anyhow::bail!("PJRT-backed HPL failed validation");
    }
    println!("      dgemm fraction of trace: {:.1}%", 100.0 * f.trace.dgemm_fraction());

    // --- 3. STREAM through the artifacts ---
    let ns = rt.manifest.n_stream;
    let sa: Vec<f64> = (0..ns).map(|i| ((i % 911) as f64) * 0.01).collect();
    let sb: Vec<f64> = (0..ns).map(|i| ((i % 677) as f64) * 0.02).collect();
    let triad = entries::stream(&mut rt, "triad", &sa, Some(&sb))?;
    let mut want = vec![0.0; ns];
    cimone::stream::kernels::triad(&mut want, &sa, &sb);
    let ok = triad
        .iter()
        .zip(&want)
        .all(|(g, w)| (g - w).abs() < 1e-12);
    println!("[3/5] STREAM artifacts: triad over {ns} elems -> {}", if ok { "validated" } else { "MISMATCH" });
    if !ok {
        anyhow::bail!("stream artifact mismatch");
    }

    // --- 4. the campaign on the scheduler ---
    println!("[4/5] submitting the paper's campaign to the SLURM-like scheduler...");
    let campaign = run_campaign_on(&inv, 128)?;
    println!(
        "      {} jobs completed, simulated makespan {:.1} h, {} metrics recorded",
        campaign.jobs.len(),
        campaign.makespan_s / 3600.0,
        campaign.monitor.metric_count()
    );
    for j in &campaign.jobs {
        println!(
            "        {:<18} -> {:>8.1}  ({:.0} W/node, {:.0} kJ)",
            j.name,
            j.headline,
            j.avg_node_w,
            j.energy_j / 1e3
        );
    }

    // --- 5. every figure ---
    println!("\n[5/5] regenerating all paper figures...\n");
    println!("{}", report::render_all(0.5));

    println!(
        "\nend-to-end driver done in {:.1}s (wall). All layers composed: Pallas kernel ->\nJAX graph -> HLO text -> PJRT CPU -> Rust coordinator -> scheduler/monitor -> figures.",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
