//! HPL cluster scenario: Figs 4, 5 and 7 regenerated, with the network
//! ablation (what if Monte Cimone had 10 GbE?) and the N-sensitivity of
//! multi-node scaling — the two questions the paper's Fig 5 raises.
//!
//! ```bash
//! cargo run --release --example hpl_cluster
//! ```

use cimone::arch::platform::{mcv1_u740, mcv2_pioneer};
use cimone::coordinator::report;
use cimone::hpl::model::{project, ClusterConfig};
use cimone::net::Fabric;
use cimone::util::table::Table;

fn main() {
    println!("{}\n", report::render_fig4());
    println!("{}\n", report::render_fig5());
    println!("{}\n", report::render_fig7());

    // N-sensitivity of the 2-node MCv2 configuration
    let mut t = Table::new(vec!["N", "2-node Gflop/s", "scaling vs 1 node", "comm share"]);
    let one_node = project(&ClusterConfig::hpl_default(mcv2_pioneer(), 1, 64)).gflops;
    for n in [20_000usize, 40_000, 57_600, 80_000, 115_200] {
        let mut cfg = ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64);
        cfg.n = n;
        cfg.nb = 192;
        let p = project(&cfg);
        t.row(vec![
            n.to_string(),
            format!("{:.1}", p.gflops),
            format!("{:.2}x", p.gflops / one_node),
            format!("{:.0}%", 100.0 * p.t_comm / (p.t_comp + p.t_comm)),
        ]);
    }
    println!("2-node scaling vs problem size (1 GbE):\n{}", t.render());

    // network ablation
    let mut t = Table::new(vec!["fabric", "2-node Gflop/s", "scaling", "MCv1 8-node Gflop/s"]);
    for fabric in [Fabric::gbe_flat(), Fabric::ten_gbe_flat()] {
        let mut cfg = ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64);
        cfg.fabric = fabric.clone();
        let p = project(&cfg);
        // mcv1-u740's platform default is already OpenBLAS-generic
        let mut v1 = ClusterConfig::hpl_default(mcv1_u740(), 8, 4);
        v1.fabric = fabric.clone();
        t.row(vec![
            fabric.label.clone(),
            format!("{:.1}", p.gflops),
            format!("{:.2}x", p.gflops / one_node),
            format!("{:.1}", project(&v1).gflops),
        ]);
    }
    println!("fabric ablation:\n{}", t.render());
    println!(
        "conclusion: the 1 GbE that served MCv1 ({:.0}% efficiency) caps MCv2 scaling;\n\
         a 10 GbE fabric would restore near-linear 2-node scaling.",
        100.0 * project(&ClusterConfig::hpl_default(mcv1_u740(), 8, 4)).efficiency_vs_one_node
    );
}
