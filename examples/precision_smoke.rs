//! CI smoke for the mixed-precision sweep: dry-run the built-in
//! `precision` matrix, validate its `--json` output through
//! `Json::parse`, and check the HPL-MxP punchline — SEW=32 strictly
//! above FP64 HPL on every vector generation, but under the 2x
//! lane-packing bound. Then load the `examples/sweep_precision.toml`
//! spec (hpl + hpl-mxp + stream + spmv) end to end and hold the SpMV
//! rows to the triad bandwidth roof. Optionally validates an externally
//! produced JSON file (e.g. piped from
//! `cimone sweep --matrix precision --dry-run --json`) passed as the
//! first argument.
//!
//! ```text
//! cargo run --example precision_smoke [-- precision.json]
//! ```

use cimone::coordinator::scenario::{dry_run_matrix, ScenarioMatrix};
use cimone::mem::stream_model::SPMV_STREAM_FACTOR;
use cimone::util::json::Json;

fn main() -> cimone::Result<()> {
    let matrix = ScenarioMatrix::precision();
    let report = dry_run_matrix(&matrix)?;

    // the JSON export must round-trip through our own parser
    let text = report.to_json().render();
    let parsed = Json::parse(&text).map_err(anyhow::Error::msg)?;
    let rows = parsed
        .get("scenarios")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing `scenarios` array"))?;
    assert_eq!(rows.len(), 4, "expected one scenario per vector generation");

    for row in rows {
        let name = row.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
        let jobs = row
            .get("jobs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("{name}: missing `jobs` array"))?;
        let headline = |job: &str| -> f64 {
            jobs.iter()
                .find(|j| j.get("name").and_then(Json::as_str) == Some(job))
                .and_then(|j| j.get("headline").and_then(Json::as_f64))
                .unwrap_or(0.0)
        };
        let (hpl, mxp) = (headline("hpl"), headline("hpl-mxp"));
        assert!(hpl > 0.0, "{name}: no FP64 HPL row");
        assert!(mxp > hpl, "{name}: MxP {mxp:.1} GF/s !> HPL {hpl:.1} GF/s");
        assert!(mxp < 2.5 * hpl, "{name}: MxP {mxp:.1} GF/s breaks the lane-packing bound");
        println!("{name}: HPL {hpl:.1} GF/s -> MxP {mxp:.1} GF/s ({:.2}x)", mxp / hpl);
    }

    // the spec-file path: hpl + hpl-mxp + spmv end to end, with every
    // SpMV projection at or under the platform's triad bandwidth roof
    let spec = ScenarioMatrix::load("examples/sweep_precision.toml")?;
    let spec_report = dry_run_matrix(&spec)?;
    assert_eq!(spec_report.scenarios.len(), 4);
    for o in &spec_report.scenarios {
        let spmv = o
            .jobs
            .iter()
            .find(|j| j.name == "spmv")
            .ok_or_else(|| anyhow::anyhow!("{}: missing spmv job", o.name))?;
        let roof = o.stream_gbs * SPMV_STREAM_FACTOR / 6.0;
        assert!(
            spmv.headline > 0.0 && spmv.headline <= roof,
            "{}: SpMV {:.2} GF/s outside (0, {roof:.2}] triad roof",
            o.name,
            spmv.headline
        );
    }

    // validate an externally produced JSON file when given one
    if let Some(path) = std::env::args().nth(1) {
        let external = std::fs::read_to_string(&path)?;
        let parsed = Json::parse(&external).map_err(anyhow::Error::msg)?;
        let n = parsed.get("scenarios").and_then(Json::as_arr).map(|a| a.len()).unwrap_or(0);
        assert!(n > 0, "{path}: no scenarios in the sweep JSON");
        println!("{path}: valid sweep JSON with {n} scenarios");
    }

    println!("precision smoke OK: MxP above HPL on all 4 vector generations, SpMV under the roof");
    Ok(())
}
