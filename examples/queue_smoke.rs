//! CI smoke for the production queue campaign: run the 10,000-job
//! multi-user queue spec end to end, validate the `--json` export
//! through `Json::parse`, and check every stream drained with a
//! bit-identical rerun. Optionally validates an externally produced
//! campaign JSON file (e.g. piped from `cimone campaign --spec
//! examples/queue_production.toml --json`) passed as the first argument.
//!
//! ```text
//! cargo run --example queue_smoke [-- queue.json]
//! ```

use cimone::coordinator::{driver, CampaignSpec};
use cimone::util::json::Json;

fn main() -> cimone::Result<()> {
    let spec = CampaignSpec::load("examples/queue_production.toml")?;
    let inv = spec.build_inventory()?;
    let report = driver::run_campaign_spec(&inv, &spec)?;

    // the JSON export must round-trip through our own parser
    let text = report.to_json().render();
    let parsed = Json::parse(&text).map_err(anyhow::Error::msg)?;
    let queues = parsed
        .get("queues")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing `queues` array"))?;
    assert_eq!(queues.len(), 4, "expected one row per user stream");
    let total: f64 = queues
        .iter()
        .map(|q| q.get("jobs").and_then(Json::as_f64).unwrap_or(0.0))
        .sum();
    assert_eq!(total as usize, 10_000, "every queued job must drain");

    // the event-driven drain is deterministic: a rerun is bit-identical
    let rerun = driver::run_campaign_spec(&inv, &spec)?;
    assert_eq!(rerun.makespan_s, report.makespan_s, "makespan must not drift");
    assert_eq!(rerun.queues, report.queues, "queue outcomes must not drift");

    // validate an externally produced JSON file when given one
    if let Some(path) = std::env::args().nth(1) {
        let external = std::fs::read_to_string(&path)?;
        let parsed = Json::parse(&external).map_err(anyhow::Error::msg)?;
        let n = parsed.get("queues").and_then(Json::as_arr).map(|a| a.len()).unwrap_or(0);
        assert!(n > 0, "{path}: no queues in the campaign JSON");
        println!("{path}: valid campaign JSON with {n} queue rows");
    }

    println!(
        "queue smoke OK: {} jobs drained across {} streams, makespan {:.0}s",
        total as usize,
        queues.len(),
        report.makespan_s
    );
    Ok(())
}
