//! Quickstart: the five-minute tour of the cimone stack.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks through: (1) the fleet, (2) one real HPL solve with validation,
//! (3) one PJRT-backed matrix multiply (Pallas -> JAX -> HLO -> Rust),
//! (4) the paper's headline numbers.

use cimone::cluster::monte_cimone_v2;
use cimone::coordinator::report;
use cimone::hpl::driver::{run, Backend, HplConfig};
use cimone::util::Matrix;

fn main() -> cimone::Result<()> {
    // 1. the machine
    let inv = monte_cimone_v2();
    println!("Monte Cimone v2: {} nodes, {:.0} Gflop/s peak", inv.nodes.len(), inv.peak_gflops());
    for n in &inv.nodes {
        println!(
            "  {:<9} {:<26} {:>3} cores {:>7.1} GF/s peak  {}",
            n.hostname,
            n.platform.label,
            n.cores(),
            n.peak_gflops(),
            n.os()
        );
    }

    // 2. a real HPL solve (factor, solve, residual-check)
    let r = run(&HplConfig { n: 256, nb: 32, seed: 42, backend: Backend::Native })?;
    println!(
        "\nHPL N=256: {:.2} host Gflop/s, residual {:.2e} -> {}",
        r.host_gflops,
        r.residual,
        if r.passed { "PASSED" } else { "FAILED" }
    );

    // 3. the three-layer path: Pallas-authored GEMM through PJRT
    match cimone::runtime::Runtime::new() {
        Ok(mut rt) => {
            let n = rt.manifest.n_gemm;
            let a = Matrix::random_hpl(n, n, 1);
            let b = Matrix::random_hpl(n, n, 2);
            let c = cimone::runtime::entries::gemm(&mut rt, &a, &b)?;
            let mut want = Matrix::zeros(n, n);
            Matrix::gemm_acc(&mut want, &a, &b);
            println!(
                "PJRT {}x{} GEMM on {}: {}",
                n,
                n,
                rt.platform(),
                if c.allclose(&want, 1e-9, 1e-9) { "matches native numerics" } else { "MISMATCH" }
            );
        }
        Err(e) => println!("PJRT step skipped ({e}); run `make artifacts`"),
    }

    // 4. headline
    println!("\n{}", report::render_headline());
    Ok(())
}
