//! STREAM scenario: full Fig-3 regeneration with a thread sweep on every
//! node type and the oversubscription / pinning ablations the paper
//! mentions in prose.
//!
//! ```bash
//! cargo run --release --example stream_sweep
//! ```

use cimone::arch::presets;
use cimone::mem::stream_model::predict_node_bandwidth;
use cimone::stream::harness::{run_sweep, StreamConfig};
use cimone::util::table::Table;

fn main() {
    // the figure itself
    println!("{}", cimone::coordinator::report::render_fig3());

    // thread sweep per node type (projection)
    let mut t = Table::new(vec!["threads", "MCv1 GB/s", "MCv2 1S GB/s", "MCv2 2S GB/s"]);
    for threads in [1usize, 2, 4, 8, 16, 32, 48, 64, 96, 128] {
        t.row(vec![
            threads.to_string(),
            format!("{:.2}", predict_node_bandwidth(&presets::u740(), threads, true) / 1e9),
            format!("{:.1}", predict_node_bandwidth(&presets::sg2042(), threads, true) / 1e9),
            format!("{:.1}", predict_node_bandwidth(&presets::sg2042_dual(), threads, true) / 1e9),
        ]);
    }
    println!("bandwidth vs threads (symmetric pinning):\n{}", t.render());

    // the paper's two prose observations
    let d = presets::sg2042_dual();
    println!(
        "pinning ablation @64 threads on the dual-socket node: symmetric {:.1} GB/s vs packed {:.1} GB/s",
        predict_node_bandwidth(&d, 64, true) / 1e9,
        predict_node_bandwidth(&d, 64, false) / 1e9,
    );
    let s1 = presets::sg2042();
    println!(
        "oversubscription on the single socket: 64 thr {:.1} GB/s -> 128 thr {:.1} GB/s",
        predict_node_bandwidth(&s1, 64, true) / 1e9,
        predict_node_bandwidth(&s1, 128, true) / 1e9,
    );

    // run the real kernels once (host) to validate the methodology
    let rep = run_sweep(
        &StreamConfig { n: 1 << 21, reps: 2, thread_counts: vec![64] },
        &presets::sg2042(),
    );
    println!("\nSTREAM kernel validation: {}", if rep.validated { "ok" } else { "FAILED" });
    for k in rep.results {
        println!("  host {:<6} {:.2} GB/s", k.kernel, k.host_bytes_per_sec / 1e9);
    }
}
