//! CI smoke for the scenario sweep: load the generation-matrix spec
//! file, dry-run it, validate the `--json` output through `Json::parse`,
//! and check the paper's headline ratios survive. Optionally validates
//! an externally produced JSON file (e.g. piped from
//! `cimone sweep --dry-run --json`) passed as the first argument.
//!
//! ```text
//! cargo run --example sweep_smoke [-- sweep.json]
//! ```

use cimone::coordinator::scenario::{dry_run_matrix, ScenarioMatrix};
use cimone::util::json::Json;

fn main() -> cimone::Result<()> {
    let matrix = ScenarioMatrix::load("examples/sweep_generations.toml")?;
    let report = dry_run_matrix(&matrix)?;

    // the JSON export must round-trip through our own parser
    let text = report.to_json().render();
    let parsed = Json::parse(&text).map_err(anyhow::Error::msg)?;
    let rows = parsed
        .get("scenarios")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing `scenarios` array"))?;
    assert_eq!(rows.len(), 5, "expected one scenario per generation");

    let dual = rows
        .iter()
        .find(|r| r.get("name").and_then(Json::as_str) == Some("mcv2-dual"))
        .ok_or_else(|| anyhow::anyhow!("missing mcv2-dual scenario"))?;
    let hpl_x = dual.get("hpl_speedup").and_then(Json::as_f64).unwrap_or(0.0);
    let stream_x = dual.get("stream_speedup").and_then(Json::as_f64).unwrap_or(0.0);
    assert!((100.0..160.0).contains(&hpl_x), "HPL uplift {hpl_x:.0}x (paper 127x)");
    assert!((55.0..85.0).contains(&stream_x), "STREAM uplift {stream_x:.0}x (paper 69x)");

    // validate an externally produced JSON file when given one
    if let Some(path) = std::env::args().nth(1) {
        let external = std::fs::read_to_string(&path)?;
        let parsed = Json::parse(&external).map_err(anyhow::Error::msg)?;
        let n = parsed.get("scenarios").and_then(Json::as_arr).map(|a| a.len()).unwrap_or(0);
        assert!(n > 0, "{path}: no scenarios in the sweep JSON");
        println!("{path}: valid sweep JSON with {n} scenarios");
    }

    println!("sweep smoke OK: mcv2-dual at {hpl_x:.0}x HPL / {stream_x:.0}x STREAM vs MCv1");
    println!("{}", report.render());
    Ok(())
}
