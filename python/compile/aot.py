"""AOT export: lower every Layer-2 entry point to HLO *text* artifacts.

HLO text — NOT ``lowered.compile()`` output or a serialized
``HloModuleProto`` — is the interchange format: jax >= 0.5 emits protos
with 64-bit instruction ids which the Rust side's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the HLO text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out ../artifacts

Writes one ``<name>.hlo.txt`` per manifest entry plus ``manifest.json``
describing shapes/dtypes, which rust/src/runtime/artifacts.rs consumes.
"""

import argparse
import hashlib
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402  (after x64 flag)
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels import microkernel as mk  # noqa: E402

F64 = "f64"

# Fixed export shapes. HPL's trailing update shrinks every iteration; the
# Rust driver zero-pads to the next exported geometry (padding rows/cols of
# A and B contribute exact zeros to C, so numerics are unaffected).
NB = 32          # HPL block size used by the Rust driver
N_GEMM = 256     # square GEMM artifact edge
N_STREAM = 1 << 20  # STREAM vector length (8 MiB/operand, beats any LLC)


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


def manifest_entries():
    """(name, fn, arg_specs) for every artifact."""
    return [
        ("gemm_256", model.gemm, [_spec(N_GEMM, N_GEMM), _spec(N_GEMM, N_GEMM)]),
        # L2 perf ablation: the same contraction as one XLA dot (no Pallas
        # grid) — quantifies what the interpret-mode lowering costs on CPU
        # (EXPERIMENTS.md section Perf).
        (
            "gemm_xla_256",
            lambda a, b: jnp.dot(a, b, preferred_element_type=jnp.float64),
            [_spec(N_GEMM, N_GEMM), _spec(N_GEMM, N_GEMM)],
        ),
        ("gemm_lmul1_64", model.gemm_lmul1, [_spec(64, 64), _spec(64, 64)]),
        (
            "trailing_update_256",
            model.trailing_update,
            [_spec(N_GEMM, N_GEMM), _spec(N_GEMM, NB), _spec(NB, N_GEMM)],
        ),
        (
            "panel_solve_32",
            model.panel_solve,
            [_spec(NB, NB), _spec(NB, N_GEMM)],
        ),
        (
            "residual_256",
            model.residual_inf,
            [_spec(N_GEMM, N_GEMM), _spec(N_GEMM), _spec(N_GEMM)],
        ),
        ("stream_copy", model.stream_copy, [_spec(N_STREAM)]),
        ("stream_scale", model.stream_scale, [_spec(N_STREAM)]),
        ("stream_add", model.stream_add, [_spec(N_STREAM), _spec(N_STREAM)]),
        ("stream_triad", model.stream_triad, [_spec(N_STREAM), _spec(N_STREAM)]),
        (
            "ukernel_lmul1",
            mk.ukernel_lmul1,
            [_spec(8, 64), _spec(64, 8), _spec(8, 8)],
        ),
        (
            "ukernel_lmul4",
            mk.ukernel_lmul4,
            [_spec(8, 64), _spec(64, 8), _spec(8, 8)],
        ),
    ]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_one(name, fn, specs, out_dir):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_specs = jax.eval_shape(fn, *specs)
    if not isinstance(out_specs, (tuple, list)):
        out_specs = (out_specs,)
    return {
        "name": name,
        "file": f"{name}.hlo.txt",
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "inputs": [{"shape": list(s.shape), "dtype": F64} for s in specs],
        "outputs": [{"shape": list(s.shape), "dtype": F64} for s in out_specs],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated subset of entry names"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    entries = []
    for name, fn, specs in manifest_entries():
        if only and name not in only:
            continue
        entries.append(export_one(name, fn, specs, args.out))
        print(f"  lowered {name}: {entries[-1]['file']}")

    manifest = {
        "format": 1,
        "dtype_note": "all artifacts are float64 (HPL is a DP benchmark)",
        "nb": NB,
        "n_gemm": N_GEMM,
        "n_stream": N_STREAM,
        "entries": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
