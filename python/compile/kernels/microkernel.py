"""Layer-1 Pallas GEMM micro-kernels — the paper's Fig 2 schedules on TPU terms.

The paper's contribution (Section 3.3.2) rewrites the BLIS RVV micro-kernel
from per-vector-register rank-1 updates (Fig 2a, LMUL=1: four `vle64` +
four `vfmacc.vf` per 8-element AB column) into register-grouped updates
(Fig 2b, LMUL=4: one load + one `vfmacc.vf` per column).

HARDWARE ADAPTATION (DESIGN.md section 2): on TPU the analogous resource is
VMEM-resident tiles feeding the MXU, not 128-bit vector registers. We
express the same two schedules as Pallas kernels:

- ``ukernel_lmul1`` — the k-loop performs MR/2 *independent* 2-row FMA
  updates per step, mirroring the four disjoint vector registers of
  Fig 2a. Structurally more ops per k-step, identical math.
- ``ukernel_lmul4`` — the k-loop performs ONE full-column rank-1 update
  per step (a single fused multiply-accumulate over the whole MR-row
  group), mirroring the LMUL=4 register group of Fig 2b.

Both are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness is asserted against ``ref.ref_microkernel`` and
the *structural* cost difference (ops per k-step, VMEM footprint) is what
the Rust ISA-level model measures for real (rust/src/ukernel/).

Blocking geometry: MR = NR = 8. Eight FP64 rows = 4 C920 vregs x 2 lanes —
exactly the paper's "eight-element column of AB"; on the MXU side an 8x8
FP64 tile is one systolic-array pass worth of work.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MR = 8  # micro-tile rows: 4 vector registers x 2 FP64 lanes (VLEN=128)
NR = 8  # micro-tile cols
LANES = 2  # FP64 lanes per 128-bit vector register
VREGS_PER_COLUMN = MR // LANES  # 4: what LMUL=4 grouping collapses to 1


def _lmul1_step(a_col, b_row, c):
    """One Fig-2a k-step: MR/LANES independent 2-lane rank-1 updates.

    Each slice ``a_col[2g:2g+2]`` models one 128-bit vector register; the
    update of the matching C rows is an independent `vfmacc.vf`. jnp
    concatenation keeps the register groups disjoint, as in the paper.
    """
    groups = []
    for g in range(VREGS_PER_COLUMN):
        seg = jax.lax.dynamic_slice_in_dim(a_col, g * LANES, LANES)  # one vreg
        c_rows = jax.lax.dynamic_slice_in_dim(c, g * LANES, LANES)
        groups.append(c_rows + seg[:, None] * b_row[None, :])
    return jnp.concatenate(groups, axis=0)


def _lmul4_step(a_col, b_row, c):
    """One Fig-2b k-step: a single whole-column (LMUL=4 group) FMA."""
    return c + a_col[:, None] * b_row[None, :]


def _microkernel_body(step_fn, a_ref, b_ref, cin_ref, o_ref):
    """Shared k-loop: KC rank-1 updates of the (MR, NR) accumulator."""
    kc = a_ref.shape[1]

    def body(k, c):
        return step_fn(a_ref[:, k], b_ref[k, :], c)

    o_ref[...] = jax.lax.fori_loop(0, kc, body, cin_ref[...])


def _make_microkernel(step_fn):
    def ukernel(a, b, c):
        """C + A@B on an (MR,KC)x(KC,NR) micro-panel pair."""
        mr, kc = a.shape
        _, nr = b.shape
        assert c.shape == (mr, nr), (a.shape, b.shape, c.shape)
        return pl.pallas_call(
            functools.partial(_microkernel_body, step_fn),
            out_shape=jax.ShapeDtypeStruct((mr, nr), c.dtype),
            interpret=True,
        )(a, b, c)

    return ukernel


#: Fig 2a schedule — BLIS's shipped rv64iv micro-kernel structure.
ukernel_lmul1 = _make_microkernel(_lmul1_step)

#: Fig 2b schedule — the paper's optimized LMUL=4 register-grouped kernel.
ukernel_lmul4 = _make_microkernel(_lmul4_step)


def gemm_tiled(a, b, *, variant="lmul4", mr=MR, nr=NR):
    """Blocked GEMM: grid of (M/mr, N/nr) micro-kernel invocations.

    This is the macro-kernel wrapping of BLIS (Section 3.3 of the paper):
    BlockSpec pulls an (mr, K) sliver of A and a (K, nr) sliver of B into
    VMEM per grid point — the HBM<->VMEM schedule that BLIS expresses with
    its packing buffers and the paper's CUDA-era analogues express with
    threadblocks.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % mr == 0 and n % nr == 0, (a.shape, b.shape)
    step_fn = _lmul4_step if variant == "lmul4" else _lmul1_step

    def kernel(a_ref, b_ref, o_ref):
        def body(kk, c):
            return step_fn(a_ref[:, kk], b_ref[kk, :], c)

        o_ref[...] = jax.lax.fori_loop(
            0, k, body, jnp.zeros((mr, nr), a_ref.dtype)
        )

    return pl.pallas_call(
        kernel,
        grid=(m // mr, n // nr),
        in_specs=[
            pl.BlockSpec((mr, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, nr), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((mr, nr), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def vmem_footprint_bytes(mr, nr, kc, itemsize=8):
    """Estimated VMEM residency of one micro-kernel invocation.

    A-sliver + B-sliver + C-tile; used by DESIGN.md section 6 and asserted
    < 16 MiB by the test suite for every exported shape.
    """
    return (mr * kc + kc * nr + mr * nr) * itemsize
