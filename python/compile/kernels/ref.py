"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: pytest asserts `allclose` between
each Pallas kernel (interpret mode) and the corresponding function here,
and the Rust side re-validates the AOT'd HLO against matrices generated
with the same seeds.

The micro-kernel semantics mirror the BLIS GEMM micro-kernel of the paper
(Fig 2): an (MR x KC) panel of A times a (KC x NR) panel of B accumulated
into an (MR x NR) tile of C via KC rank-1 updates.
"""

import jax.numpy as jnp

# C920 geometry: VLEN = 128 bits = 2 FP64 lanes; the paper's micro-kernel
# updates an 8-element column of AB, i.e. MR = 4 vregs x 2 lanes.
MR = 8
NR = 8


def ref_microkernel(a, b, c):
    """C_tile = c + a @ b for a:(MR,KC) b:(KC,NR) c:(MR,NR)."""
    return c + jnp.dot(a, b, preferred_element_type=c.dtype)


def ref_gemm(a, b):
    """Plain full-precision GEMM oracle."""
    return jnp.dot(a, b, preferred_element_type=a.dtype)


def ref_trailing_update(c, a, b):
    """HPL trailing-submatrix update: C <- C - A @ B (right-looking LU)."""
    return c - jnp.dot(a, b, preferred_element_type=c.dtype)


def ref_stream_copy(a):
    return a


def ref_stream_scale(a, scalar):
    return scalar * a


def ref_stream_add(a, b):
    return a + b


def ref_stream_triad(a, b, scalar):
    return a + scalar * b


def ref_residual_inf(a, x, b):
    """HPL-style residual numerator: max_i |A x - b|_i."""
    return jnp.max(jnp.abs(jnp.dot(a, x, preferred_element_type=a.dtype) - b))
