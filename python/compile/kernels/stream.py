"""Layer-1 Pallas STREAM kernels (McCalpin's four: copy/scale/add/triad).

STREAM is the paper's memory-bandwidth probe (Fig 3). The kernels are
trivially bandwidth-bound; what matters for the TPU mapping is the
HBM<->VMEM blocking, which BlockSpec expresses: each grid point streams a
BLOCK-element chunk through VMEM exactly once (no reuse — STREAM by
construction defeats caches).

All four are exported AOT so the Rust coordinator runs the *same* kernels
it times with the DDR model, and the numerics are asserted against ref.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Elements per grid step. 4096 f64 = 32 KiB per operand — comfortably
#: VMEM-resident with double-buffering headroom.
BLOCK = 4096


def _blocked_1d(kernel_fn, n_out_dtype, arrays, scalars=()):
    n = arrays[0].shape[0]
    assert n % BLOCK == 0, n
    grid = (n // BLOCK,)
    in_specs = [pl.BlockSpec((BLOCK,), lambda i: (i,)) for _ in arrays]
    return pl.pallas_call(
        kernel_fn,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), n_out_dtype),
        interpret=True,
    )(*arrays)


def stream_copy(a):
    """c[i] = a[i]"""

    def kernel(a_ref, o_ref):
        o_ref[...] = a_ref[...]

    return _blocked_1d(kernel, a.dtype, (a,))


def stream_scale(a, scalar):
    """b[i] = q * a[i] — scalar is closed over (STREAM uses a constant q)."""

    def kernel(a_ref, o_ref):
        o_ref[...] = scalar * a_ref[...]

    return _blocked_1d(kernel, a.dtype, (a,))


def stream_add(a, b):
    """c[i] = a[i] + b[i]"""

    def kernel(a_ref, b_ref, o_ref):
        o_ref[...] = a_ref[...] + b_ref[...]

    return _blocked_1d(kernel, a.dtype, (a, b))


def stream_triad(a, b, scalar):
    """a[i] = b[i] + q * c[i] (canonical STREAM triad, renamed operands)."""

    def kernel(a_ref, b_ref, o_ref):
        o_ref[...] = a_ref[...] + scalar * b_ref[...]

    return _blocked_1d(kernel, a.dtype, (a, b))


#: Bytes moved per element for each kernel, used to convert kernel time to
#: GB/s exactly as stream.c does (copy/scale: 16 B, add/triad: 24 B).
BYTES_PER_ELEM = {
    "copy": 16,
    "scale": 16,
    "add": 24,
    "triad": 24,
}
