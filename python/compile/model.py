"""Layer-2 JAX compute graphs — the HPL/STREAM numerical payloads.

Everything here is pure JAX calling the Layer-1 Pallas kernels, so a
single `jax.jit(...).lower()` produces one fused HLO module per entry
point. `aot.py` exports each entry at the fixed shapes listed in its
manifest; the Rust runtime (rust/src/runtime/) loads and executes them on
the request path — Python never runs after `make artifacts`.

Entry points:

- ``gemm``            C = A @ B           (micro-kernel-tiled, Fig 2b schedule)
- ``gemm_lmul1``      C = A @ B           (Fig 2a schedule — ablation twin)
- ``trailing_update`` C <- C - A @ B      (the DGEMM inside each HPL iteration)
- ``panel_solve``     U row-block solve   (unit-lower TRSM, HPL's DTRSM)
- ``residual_inf``    max|Ax - b|         (HPL validation numerator)
- ``stream_*``        the four STREAM kernels
"""

import jax
import jax.numpy as jnp

from compile.kernels import microkernel as mk
from compile.kernels import stream as sk


def gemm(a, b):
    """C = A @ B with the paper's optimized (LMUL=4 / Fig 2b) schedule."""
    return mk.gemm_tiled(a, b, variant="lmul4")


def gemm_lmul1(a, b):
    """C = A @ B with the baseline (LMUL=1 / Fig 2a) schedule.

    Numerically identical to :func:`gemm`; exists so both schedules are
    exercised end-to-end through AOT and the Rust runtime (ablation twin).
    """
    return mk.gemm_tiled(a, b, variant="lmul1")


def trailing_update(c, a, b):
    """HPL right-looking trailing update: C <- C - A @ B.

    A is the (rows x nb) panel column below the diagonal block, B the
    (nb x cols) row slab right of it. This single call is >90% of HPL's
    FLOPs, which is why the paper's whole Section 4 reduces to DGEMM
    micro-kernel quality.
    """
    return c - mk.gemm_tiled(a, b, variant="lmul4")


def panel_solve(l_block, u_rows):
    """Solve L * X = U_rows for X where L is unit lower triangular (nb x nb).

    This is HPL's DTRSM on the row slab right of the diagonal block.
    Forward substitution expressed as a scan over rows so XLA emits one
    compact loop instead of nb unrolled updates.
    """
    l_block = jnp.asarray(l_block)
    u_rows = jnp.asarray(u_rows)
    nb = l_block.shape[0]

    def body(carry, i):
        x = carry
        # x[i, :] -= L[i, :i] @ x[:i, :]  (masked full-row form, scan-safe)
        mask = (jnp.arange(nb) < i).astype(l_block.dtype)
        contrib = (l_block[i, :] * mask) @ x
        x = x.at[i, :].add(-contrib)
        return x, ()

    x, _ = jax.lax.scan(body, u_rows, jnp.arange(nb))
    return x


def residual_inf(a, x, b):
    """HPL validation numerator max_i |A x - b|_i (scalar f64)."""
    r = a @ x - b
    return jnp.max(jnp.abs(r))


def stream_copy(a):
    return sk.stream_copy(a)


def stream_scale(a):
    return sk.stream_scale(a, 3.0)  # STREAM's constant q = 3.0


def stream_add(a, b):
    return sk.stream_add(a, b)


def stream_triad(a, b):
    return sk.stream_triad(a, b, 3.0)
