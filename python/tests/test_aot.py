"""AOT path: every manifest entry lowers to parseable HLO text.

These tests exercise exactly the code `make artifacts` runs, on the two
cheapest entries (full export is exercised by the Makefile itself).
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model


class TestManifest:
    def test_entry_names_unique(self):
        names = [n for n, _, _ in aot.manifest_entries()]
        assert len(names) == len(set(names))

    def test_covers_required_entries(self):
        names = {n for n, _, _ in aot.manifest_entries()}
        required = {
            "gemm_256",
            "trailing_update_256",
            "panel_solve_32",
            "residual_256",
            "stream_copy",
            "stream_scale",
            "stream_add",
            "stream_triad",
            "ukernel_lmul1",
            "ukernel_lmul4",
        }
        assert required <= names

    def test_all_f64(self):
        for _, _, specs in aot.manifest_entries():
            for s in specs:
                assert s.dtype == np.float64


class TestLowering:
    def lower_text(self, name):
        for n, fn, specs in aot.manifest_entries():
            if n == name:
                return aot.to_hlo_text(jax.jit(fn).lower(*specs))
        raise KeyError(name)

    def test_ukernel_lowers_to_hlo_text(self):
        text = self.lower_text("ukernel_lmul4")
        assert "HloModule" in text
        assert "f64" in text

    def test_panel_solve_lowers(self):
        text = self.lower_text("panel_solve_32")
        assert "HloModule" in text
        # scan should lower to a while loop, not 32 unrolled bodies
        assert "while" in text

    def test_export_one_writes_file_and_metadata(self, tmp_path):
        name, fn, specs = next(
            e for e in aot.manifest_entries() if e[0] == "ukernel_lmul4"
        )
        meta = aot.export_one(name, fn, specs, str(tmp_path))
        assert (tmp_path / meta["file"]).exists()
        assert meta["inputs"][0]["shape"] == [8, 64]
        assert meta["outputs"][0]["shape"] == [8, 8]
        assert len(meta["sha256"]) == 64


class TestArtifactsDirIfBuilt:
    """Validate the real artifacts/ directory when it exists (post-make)."""

    MANIFEST = os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json"
    )

    @pytest.fixture
    def manifest(self):
        if not os.path.exists(self.MANIFEST):
            pytest.skip("artifacts not built yet (run `make artifacts`)")
        with open(self.MANIFEST) as f:
            return json.load(f)

    def test_files_exist_and_nonempty(self, manifest):
        base = os.path.dirname(self.MANIFEST)
        for e in manifest["entries"]:
            p = os.path.join(base, e["file"])
            assert os.path.getsize(p) > 100, e["name"]

    def test_manifest_geometry(self, manifest):
        assert manifest["nb"] == 32
        assert manifest["n_gemm"] == 256
        by_name = {e["name"]: e for e in manifest["entries"]}
        assert by_name["trailing_update_256"]["inputs"][1]["shape"] == [256, 32]
        assert by_name["stream_triad"]["inputs"][0]["shape"] == [manifest["n_stream"]]
