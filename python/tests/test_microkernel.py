"""Layer-1 correctness: Pallas micro-kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes and value ranges; the two schedules
(Fig 2a LMUL=1, Fig 2b LMUL=4) must agree with ref_microkernel AND with
each other bit-for-bit-close — the paper's optimization changes the
instruction schedule, never the math.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import microkernel as mk
from compile.kernels import ref


def rng_mats(seed, mr, kc, nr, dtype=np.float64, scale=1.0):
    r = np.random.default_rng(seed)
    a = (r.standard_normal((mr, kc)) * scale).astype(dtype)
    b = (r.standard_normal((kc, nr)) * scale).astype(dtype)
    c = (r.standard_normal((mr, nr)) * scale).astype(dtype)
    return a, b, c


class TestMicrokernelFixed:
    def test_lmul4_matches_ref_8x8x64(self):
        a, b, c = rng_mats(0, 8, 64, 8)
        out = mk.ukernel_lmul4(a, b, c)
        np.testing.assert_allclose(out, ref.ref_microkernel(a, b, c), rtol=1e-12)

    def test_lmul1_matches_ref_8x8x64(self):
        a, b, c = rng_mats(1, 8, 64, 8)
        out = mk.ukernel_lmul1(a, b, c)
        np.testing.assert_allclose(out, ref.ref_microkernel(a, b, c), rtol=1e-12)

    def test_schedules_agree(self):
        """Fig 2a and Fig 2b compute the same rank-1 sum in the same order."""
        a, b, c = rng_mats(2, 8, 32, 8)
        np.testing.assert_array_equal(
            np.asarray(mk.ukernel_lmul1(a, b, c)),
            np.asarray(mk.ukernel_lmul4(a, b, c)),
        )

    def test_zero_accumulator(self):
        a, b, _ = rng_mats(3, 8, 16, 8)
        c = np.zeros((8, 8))
        out = mk.ukernel_lmul4(a, b, c)
        np.testing.assert_allclose(out, a @ b, rtol=1e-12)

    def test_identity_panel(self):
        """A = I picks B's first MR rows through the rank-1 chain."""
        kc = 8
        a = np.eye(8, kc)
        b = np.random.default_rng(4).standard_normal((kc, 8))
        c = np.zeros((8, 8))
        np.testing.assert_allclose(mk.ukernel_lmul4(a, b, c), b[:8], rtol=1e-12)

    def test_kc_one_single_rank1(self):
        a, b, c = rng_mats(5, 8, 1, 8)
        out = mk.ukernel_lmul1(a, b, c)
        np.testing.assert_allclose(out, c + np.outer(a[:, 0], b[0]), rtol=1e-12)

    def test_accumulation_is_additive(self):
        """ukernel(a,b,ukernel(a,b,c)) == c + 2*a@b (accumulator semantics)."""
        a, b, c = rng_mats(6, 8, 16, 8)
        once = np.asarray(mk.ukernel_lmul4(a, b, c))
        twice = np.asarray(mk.ukernel_lmul4(a, b, once))
        np.testing.assert_allclose(twice, c + 2 * (a @ b), rtol=1e-11)

    def test_float32_supported(self):
        a, b, c = rng_mats(7, 8, 32, 8, dtype=np.float32)
        out = mk.ukernel_lmul4(a, b, c)
        assert np.asarray(out).dtype == np.float32
        np.testing.assert_allclose(out, c + a @ b, rtol=2e-4, atol=1e-5)


class TestMicrokernelHypothesis:
    @settings(max_examples=25, deadline=None)
    @given(
        kc=st.integers(min_value=1, max_value=96),
        seed=st.integers(min_value=0, max_value=2**31),
        scale=st.sampled_from([1e-3, 1.0, 1e3]),
    )
    def test_lmul4_sweep(self, kc, seed, scale):
        a, b, c = rng_mats(seed, 8, kc, 8, scale=scale)
        out = mk.ukernel_lmul4(a, b, c)
        np.testing.assert_allclose(
            out, ref.ref_microkernel(a, b, c), rtol=1e-10, atol=1e-10 * scale**2
        )

    @settings(max_examples=15, deadline=None)
    @given(
        kc=st.integers(min_value=1, max_value=64),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_lmul1_equals_lmul4(self, kc, seed):
        a, b, c = rng_mats(seed, 8, kc, 8)
        np.testing.assert_array_equal(
            np.asarray(mk.ukernel_lmul1(a, b, c)),
            np.asarray(mk.ukernel_lmul4(a, b, c)),
        )

    @settings(max_examples=10, deadline=None)
    @given(
        dtype=st.sampled_from([np.float32, np.float64]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_dtypes(self, dtype, seed):
        a, b, c = rng_mats(seed, 8, 24, 8, dtype=dtype)
        out = np.asarray(mk.ukernel_lmul4(a, b, c))
        assert out.dtype == dtype
        rtol = 2e-4 if dtype == np.float32 else 1e-11
        np.testing.assert_allclose(out, c + a @ b, rtol=rtol, atol=1e-5)


class TestGemmTiled:
    @pytest.mark.parametrize("variant", ["lmul1", "lmul4"])
    @pytest.mark.parametrize("m,n,k", [(8, 8, 8), (16, 24, 32), (64, 64, 64)])
    def test_matches_ref(self, variant, m, n, k):
        r = np.random.default_rng(m * n + k)
        a = r.standard_normal((m, k))
        b = r.standard_normal((k, n))
        out = mk.gemm_tiled(a, b, variant=variant)
        np.testing.assert_allclose(out, a @ b, rtol=1e-11)

    def test_variants_bitwise_equal(self):
        r = np.random.default_rng(99)
        a = r.standard_normal((32, 48))
        b = r.standard_normal((48, 16))
        np.testing.assert_array_equal(
            np.asarray(mk.gemm_tiled(a, b, variant="lmul1")),
            np.asarray(mk.gemm_tiled(a, b, variant="lmul4")),
        )

    def test_rejects_unaligned(self):
        a = np.zeros((9, 8))
        b = np.zeros((8, 8))
        with pytest.raises(AssertionError):
            mk.gemm_tiled(a, b)


class TestVmemFootprint:
    def test_exported_shapes_fit_vmem(self):
        """Every AOT'd micro-kernel geometry must fit TPU VMEM (16 MiB)."""
        assert mk.vmem_footprint_bytes(8, 8, 64) < 16 * 2**20
        assert mk.vmem_footprint_bytes(8, 8, 256) < 16 * 2**20

    def test_footprint_formula(self):
        assert mk.vmem_footprint_bytes(8, 8, 64) == (8 * 64 + 64 * 8 + 64) * 8
