"""Layer-2 correctness: model graphs vs numpy, incl. the HPL building blocks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, stream as sk


def rng(seed):
    return np.random.default_rng(seed)


class TestGemm:
    def test_gemm_256_matches_numpy(self):
        r = rng(0)
        a = r.standard_normal((64, 64))
        b = r.standard_normal((64, 64))
        np.testing.assert_allclose(model.gemm(a, b), a @ b, rtol=1e-11)

    def test_gemm_variants_equal(self):
        r = rng(1)
        a = r.standard_normal((32, 32))
        b = r.standard_normal((32, 32))
        np.testing.assert_array_equal(
            np.asarray(model.gemm(a, b)), np.asarray(model.gemm_lmul1(a, b))
        )


class TestGemmXlaParity:
    def test_pallas_grid_equals_fused_dot(self):
        """The L2 perf-ablation artifact (plain jnp.dot) must agree with
        the Pallas-tiled gemm to fp64 precision — same contraction, two
        lowerings (EXPERIMENTS.md section Perf quantifies their speed gap)."""
        r = rng(77)
        a = r.standard_normal((64, 64))
        b = r.standard_normal((64, 64))
        pallas = np.asarray(model.gemm(a, b))
        fused = np.asarray(jnp.dot(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_allclose(pallas, fused, rtol=1e-12, atol=1e-12)


class TestTrailingUpdate:
    def test_matches_ref(self):
        r = rng(2)
        c = r.standard_normal((64, 64))
        a = r.standard_normal((64, 32))
        b = r.standard_normal((32, 64))
        np.testing.assert_allclose(
            model.trailing_update(c, a, b),
            ref.ref_trailing_update(c, a, b),
            rtol=1e-11,
        )

    def test_zero_padding_invariance(self):
        """Zero-padded A/B rows+cols must not change the live region of C.

        This is the property the Rust HPL driver relies on to reuse one
        fixed-shape artifact for every (shrinking) trailing submatrix.
        """
        r = rng(3)
        live = 40
        c = r.standard_normal((64, 64))
        a = np.zeros((64, 32))
        b = np.zeros((32, 64))
        a[:live, :] = r.standard_normal((live, 32))
        b[:, :live] = r.standard_normal((32, live))
        out = np.asarray(model.trailing_update(c, a, b))
        expected_live = c[:live, :live] - a[:live] @ b[:, :live]
        np.testing.assert_allclose(out[:live, :live], expected_live, rtol=1e-11)
        # dead region: C untouched where A rows or B cols are zero
        np.testing.assert_allclose(out[live:, :], c[live:, :], rtol=1e-12)
        np.testing.assert_allclose(out[:, live:], c[:, live:], rtol=1e-12)


class TestPanelSolve:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31))
    def test_unit_lower_trsm(self, seed):
        r = rng(seed)
        nb, n = 16, 24
        l = np.tril(r.standard_normal((nb, nb)), k=-1) + np.eye(nb)
        u = r.standard_normal((nb, n))
        x = np.asarray(model.panel_solve(l, u))
        np.testing.assert_allclose(l @ x, u, rtol=1e-10, atol=1e-10)

    def test_identity_l_is_noop(self):
        u = rng(7).standard_normal((16, 8))
        x = np.asarray(model.panel_solve(np.eye(16), u))
        np.testing.assert_allclose(x, u, rtol=1e-12)


class TestResidual:
    def test_exact_solution_zero_residual(self):
        r = rng(8)
        a = r.standard_normal((32, 32)) + 32 * np.eye(32)
        x = r.standard_normal(32)
        b = a @ x
        res = float(model.residual_inf(a, x, b))
        assert res < 1e-9

    def test_perturbed_solution_nonzero(self):
        r = rng(9)
        a = r.standard_normal((32, 32)) + 32 * np.eye(32)
        x = r.standard_normal(32)
        b = a @ x
        res = float(model.residual_inf(a, x + 1e-3, b))
        assert res > 1e-4


class TestStream:
    N = 8192

    def arr(self, seed, n=None):
        return rng(seed).standard_normal(n or self.N)

    def test_copy(self):
        a = self.arr(0)
        np.testing.assert_array_equal(np.asarray(model.stream_copy(a)), a)

    def test_scale(self):
        a = self.arr(1)
        np.testing.assert_allclose(model.stream_scale(a), 3.0 * a, rtol=1e-14)

    def test_add(self):
        a, b = self.arr(2), self.arr(3)
        np.testing.assert_allclose(model.stream_add(a, b), a + b, rtol=1e-14)

    def test_triad(self):
        a, b = self.arr(4), self.arr(5)
        np.testing.assert_allclose(
            model.stream_triad(a, b), a + 3.0 * b, rtol=1e-14, atol=1e-14
        )

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=2**31),
        scalar=st.floats(min_value=-8.0, max_value=8.0, allow_nan=False),
    )
    def test_triad_kernel_sweep(self, seed, scalar):
        a, b = self.arr(seed), self.arr(seed + 1)
        np.testing.assert_allclose(
            sk.stream_triad(a, b, scalar),
            ref.ref_stream_triad(a, b, scalar),
            rtol=1e-13,
            atol=1e-13,
        )

    def test_bytes_per_elem_table(self):
        assert sk.BYTES_PER_ELEM == {"copy": 16, "scale": 16, "add": 24, "triad": 24}
