//! Bench: regenerate Fig 3 — STREAM bandwidth on MCv1 / MCv2 1S / MCv2 2S.
//!
//! Measures the real kernels on this host (timed with the in-house
//! harness) and prints the projected RISC-V-target series next to the
//! paper's numbers.

use cimone::arch::presets;
use cimone::coordinator::report;
use cimone::stream::harness::{run_sweep, StreamConfig};
use cimone::util::bench::Bench;
use cimone::util::units::fmt_gbs;

fn main() {
    println!("=== Fig 3: STREAM benchmark ===\n");
    println!("{}", report::render_fig3());

    // host-side kernel measurement (methodology check: our kernels move
    // the bytes STREAM says they move)
    let cfg = StreamConfig { n: 1 << 22, reps: 3, thread_counts: vec![1, 4, 16, 32, 64, 128] };
    let rep = run_sweep(&cfg, &presets::sg2042());
    assert!(rep.validated, "STREAM validation failed");
    println!("host kernel rates (this machine, single thread):");
    for k in &rep.results {
        println!("  {:<6} {}", k.kernel, fmt_gbs(k.host_bytes_per_sec));
    }

    println!("\nprojected MCv2 single-socket bandwidth vs threads (copy):");
    for (t, bw) in &rep.results[0].projected {
        println!("  {t:>4} threads: {}", fmt_gbs(*bw));
    }

    // timing of the projection itself (it sits on monitoring hot paths)
    let b = Bench::default();
    let m = b.run("predict_node_bandwidth(sg2042_dual, 64)", || {
        std::hint::black_box(cimone::mem::stream_model::predict_node_bandwidth(
            &presets::sg2042_dual(),
            64,
            true,
        ));
    });
    println!("\n{}", m.report());
}
