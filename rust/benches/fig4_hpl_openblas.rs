//! Bench: regenerate Fig 4 — HPL vs core count, OpenBLAS generic vs
//! optimized, on the MCv2 single-socket node.
//!
//! Also times the full model pipeline (ISA cycle analysis -> node
//! projection), since `cimone report-all` runs it interactively.

use cimone::arch::presets;
use cimone::blas::perf::PerfModel;
use cimone::coordinator::report;
use cimone::ukernel::{analysis, KernelRegistry};
use cimone::util::bench::Bench;

fn main() {
    println!("=== Fig 4: HPL with OpenBLAS (generic vs optimized target) ===\n");
    println!("{}", report::render_fig4());

    // the kernel-model numbers underneath the figure
    let core = presets::c920();
    let reg = KernelRegistry::builtin();
    for id in ["openblas-generic", "openblas-c920"] {
        let p = analysis::analyze(&reg.get(id).unwrap(), &core);
        println!(
            "{:<28} {:>6.2} insts/k-step {:>7.2} cyc/k-step {:>6.2} raw GF/s {:>6.2} eff GF/s",
            id,
            p.insts_per_kstep,
            p.cycles_per_kstep,
            p.raw_gflops,
            p.effective_gflops
        );
    }

    let b = Bench::default();
    let d = cimone::arch::platform::mcv2_pioneer();
    let ob = reg.get("openblas-c920").unwrap();
    let m1 = b.run("PerfModel::new (cycle analysis)", || {
        std::hint::black_box(PerfModel::new(&d, std::sync::Arc::clone(&ob)));
    });
    let pm = PerfModel::new(&d, ob);
    let m2 = b.run("node_gflops(64)", || {
        std::hint::black_box(pm.node_gflops(64));
    });
    println!("\n{}\n{}", m1.report(), m2.report());
}
