//! Bench: regenerate Fig 5 — HPL across node configurations (MCv1 full
//! machine, MCv2 1S, 2x1S over 1 GbE, 1x2S), plus a REAL small HPL run
//! end to end (native backend) to anchor the projection in executed
//! numerics.

use cimone::coordinator::report;
use cimone::hpl::driver::{run, Backend, HplConfig};
use cimone::hpl::model::{project, ClusterConfig};
use cimone::net::Fabric;
use cimone::util::bench::Bench;

fn main() {
    println!("=== Fig 5: HPL on different node configurations ===\n");
    println!("{}", report::render_fig5());

    // communication breakdown for the 2-node case (the paper's point)
    let cfg = ClusterConfig::hpl_default(cimone::arch::platform::mcv2_pioneer(), 2, 64);
    let p = project(&cfg);
    println!(
        "2-node breakdown: comp {:.0}s, comm {:.0}s ({:.0}% overhead) at N={}",
        p.t_comp,
        p.t_comm,
        100.0 * p.t_comm / p.t_comp,
        cfg.n
    );
    // ablation: the same cluster on 10 GbE
    let mut ten = cfg.clone();
    ten.fabric = Fabric::ten_gbe_flat();
    let p10 = project(&ten);
    println!(
        "ablation (10 GbE): {:.1} Gflop/s, efficiency {:.2} (1 GbE: {:.2})",
        p10.gflops, p10.efficiency_vs_one_node, p.efficiency_vs_one_node
    );

    // real numerics anchor: factor + solve + validate, timed
    let b = Bench::quick();
    let m = b.run("real HPL N=256 native (factor+solve+validate)", || {
        let r = run(&HplConfig { n: 256, nb: 32, seed: 1, backend: Backend::Native }).unwrap();
        assert!(r.passed);
        std::hint::black_box(r.host_gflops);
    });
    println!("\n{}", m.report());
    let r = run(&HplConfig { n: 256, nb: 32, seed: 1, backend: Backend::Native }).unwrap();
    println!(
        "host HPL N=256: {:.2} Gflop/s, residual {:.3e} (threshold 16)",
        r.host_gflops, r.residual
    );
}
