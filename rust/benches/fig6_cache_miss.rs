//! Bench: regenerate Fig 6 — L1/L3 miss rates of the HPL DGEMM under
//! optimized OpenBLAS vs vanilla BLIS blocking, trace-driven, plus the
//! simulator's own throughput (it's a perf-pass hot path).

use cimone::arch::presets;
use cimone::blas::blocking::Blocking;
use cimone::cache::{simulate_gemm, GemmTraceConfig};
use cimone::coordinator::report;
use std::time::Instant;

fn main() {
    println!("=== Fig 6: cache miss rates, OpenBLAS vs BLIS ===\n");
    println!("{}", report::render_fig6(1.0));

    // simulator throughput measurement
    let socket = presets::sg2042().sockets[0].clone();
    let cfg = GemmTraceConfig {
        m: 256,
        n: 256,
        k: 768,
        blocking: Blocking::blis_for(&socket, 8, 4),
        cores: 4,
    };
    let t = Instant::now();
    let st = simulate_gemm(&cfg, &socket);
    let secs = t.elapsed().as_secs_f64();
    println!(
        "simulator throughput: {:.1} M element-accesses/s ({} accesses in {:.2}s)",
        st.l1_accesses as f64 / secs / 1e6,
        st.l1_accesses,
        secs
    );
}
