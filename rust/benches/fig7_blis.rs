//! Bench: regenerate Fig 7 — HPL+OpenBLAS vs HPL+BLIS pre/post
//! optimization, plus the micro-kernel-level measurements (instruction
//! counts, modelled cycles, functional-machine execution time) that
//! substantiate the +49%.

use cimone::arch::presets;
use cimone::coordinator::report;
use cimone::ukernel::{analysis, KernelRegistry};
use cimone::util::bench::Bench;
use cimone::util::Matrix;

fn main() {
    println!("=== Fig 7: HPL by BLAS library (pre/post BLIS optimization) ===\n");
    println!("{}", report::render_fig7());

    // the micro-kernel story backing the figure
    let core = presets::c920();
    println!("micro-kernel analysis (C920 cycle model, KC=128):");
    let reg = KernelRegistry::builtin();
    for id in ["blis-lmul1", "blis-lmul4", "openblas-c920"] {
        let p = analysis::analyze(&reg.get(id).unwrap(), &core);
        println!(
            "  {:<26} {:>5.1} insts/k {:>6.1} cyc/k {:>5.2} flops/cyc {:>5.2} GF/s eff",
            id,
            p.insts_per_kstep,
            p.cycles_per_kstep,
            p.flops_per_cycle,
            p.effective_gflops
        );
    }
    println!(
        "kernel-level LMUL=4 speedup: {:.2}x (end-to-end Fig 7 improvement: paper +49%)",
        analysis::lmul_speedup(&core)
    );

    // functional-machine execution timing (host): both schedules do the
    // same math; the simulated instruction count difference shows up as
    // host wall-clock too
    let b = Bench::default();
    let a = Matrix::random_hpl(8, 256, 1);
    let bm = Matrix::random_hpl(256, 4, 2);
    let c = Matrix::random_hpl(8, 4, 3);
    for id in ["blis-lmul1", "blis-lmul4"] {
        let k = reg.get(id).unwrap();
        let m = b.run(&format!("VecMachine exec {id} (kc=256)"), || {
            std::hint::black_box(k.run(&a, &bm, &c).unwrap());
        });
        println!("{}", m.report());
    }
}
