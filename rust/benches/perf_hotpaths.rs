//! Perf-pass harness: times every hot path in the stack and prints a
//! before/after-friendly report (EXPERIMENTS.md section Perf records the
//! iteration log against these numbers).
//!
//! Hot paths:
//!   L3-sim  : isa::exec dispatch loop (the functional vector machine)
//!   L3-sim  : cache trace simulation (element-weighted line accesses)
//!   L3-num  : blocked LU factorization (native trailing updates)
//!   L3-pjrt : PJRT gemm_256 end-to-end latency (when artifacts exist)
//!   L3-model: full report-all projection pipeline
//!   suite   : the `cimone bench` estimation-stack suite (cold vs warm
//!             cache scenarios/s + determinism fingerprint — the same
//!             numbers BENCH_6.json records)

use cimone::arch::presets;
use cimone::blas::blocking::Blocking;
use cimone::cache::{simulate_gemm, GemmTraceConfig};
use cimone::hpl::lu::{lu_blocked, native_update};
use cimone::ukernel::KernelRegistry;
use cimone::util::bench::Bench;
use cimone::util::stats::hpl_flops;
use cimone::util::Matrix;
use std::time::Instant;

fn main() {
    let b = Bench::default();
    println!("=== perf hot paths ===");

    // --- ISA functional machine throughput ---
    let k = KernelRegistry::builtin().get("blis-lmul4").unwrap();
    let a = Matrix::random_hpl(8, 256, 1);
    let bm = Matrix::random_hpl(256, 4, 2);
    let c = Matrix::random_hpl(8, 4, 3);
    let m = b.run("isa exec: lmul4 ukernel kc=256", || {
        std::hint::black_box(k.run(&a, &bm, &c).unwrap());
    });
    // 256 k-steps x 12 insts + 9 fixed
    let insts = 256.0 * 12.0 + 9.0;
    println!("{}   ({:.1} M simulated insts/s)", m.report(), insts / m.secs_per_iter / 1e6);

    // --- cache trace simulator throughput ---
    let socket = presets::sg2042().sockets[0].clone();
    let cfg = GemmTraceConfig {
        m: 192,
        n: 192,
        k: 768,
        blocking: Blocking::blis_for(&socket, 8, 4),
        cores: 2,
    };
    let t = Instant::now();
    let st = simulate_gemm(&cfg, &socket);
    let secs = t.elapsed().as_secs_f64();
    println!(
        "cache sim: {:>10.1} M element-accesses/s ({} accesses, {:.3}s)",
        st.l1_accesses as f64 / secs / 1e6,
        st.l1_accesses,
        secs
    );

    // --- native blocked LU (the real-numerics anchor) ---
    let n = 384;
    let a = Matrix::random_hpl(n, n, 7);
    let m = b.run("lu_blocked n=384 nb=32 (native)", || {
        std::hint::black_box(lu_blocked(&a, 32, &mut native_update).unwrap());
    });
    println!(
        "{}   ({:.2} host Gflop/s)",
        m.report(),
        hpl_flops(n) / m.secs_per_iter / 1e9
    );

    // --- PJRT end-to-end latency (if artifacts are built) ---
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use cimone::runtime::{entries, Runtime};
        let mut rt = Runtime::with_dir("artifacts").expect("runtime");
        let n = rt.manifest.n_gemm;
        let ga = Matrix::random_hpl(n, n, 11);
        let gb = Matrix::random_hpl(n, n, 12);
        // warm the compile cache first
        entries::gemm(&mut rt, &ga, &gb).unwrap();
        let m = Bench::quick().run("PJRT gemm_256 end-to-end", || {
            std::hint::black_box(entries::gemm(&mut rt, &ga, &gb).unwrap());
        });
        let flops = 2.0 * (n as f64).powi(3);
        println!("{}   ({:.2} Gflop/s through PJRT)", m.report(), flops / m.secs_per_iter / 1e9);
        // L2 ablation: same contraction as one fused XLA dot (no Pallas grid)
        if rt.manifest.entry("gemm_xla_256").is_some() {
            let ra = ga.to_row_major();
            let rb = gb.to_row_major();
            rt.call("gemm_xla_256", &[&ra, &rb]).unwrap();
            let m = Bench::quick().run("PJRT gemm_xla_256 (fused dot)", || {
                std::hint::black_box(rt.call("gemm_xla_256", &[&ra, &rb]).unwrap());
            });
            println!(
                "{}   ({:.2} Gflop/s through PJRT)",
                m.report(),
                flops / m.secs_per_iter / 1e9
            );
        }
    } else {
        println!("PJRT gemm: skipped (artifacts not built)");
    }

    // --- whole projection pipeline ---
    let m = b.run("report pipeline (figs 3/4/5/7 + headline)", || {
        std::hint::black_box(cimone::coordinator::report::render_fig3());
        std::hint::black_box(cimone::coordinator::report::render_fig4());
        std::hint::black_box(cimone::coordinator::report::render_fig5());
        std::hint::black_box(cimone::coordinator::report::render_fig7());
        std::hint::black_box(cimone::coordinator::report::render_headline());
    });
    println!("{}", m.report());

    // --- the estimation-stack suite (what `cimone bench` runs) ---
    println!();
    match cimone::perfsuite::run(false) {
        Ok(suite) => println!("{}", suite.render()),
        Err(e) => println!("perf suite failed: {e}"),
    }
}
