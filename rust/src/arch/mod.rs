//! Hardware architecture descriptors — the open platform API.
//!
//! The layer has three levels:
//!
//! - [`soc`] — raw geometry types ([`CoreModel`], [`CacheGeom`],
//!   [`MemorySystem`], [`Socket`], [`SocDescriptor`]) that parameterize
//!   every model downstream: the ISA timing model reads pipeline widths,
//!   the cache simulator reads hierarchy geometry, the DDR model reads
//!   channel counts, and the HPL projection reads peak FLOP rates.
//! - [`presets`] — concrete descriptors for each SoC generation: U740
//!   (MCv1), SG2042 single/dual socket (MCv2), and the SG2044 / MCv3
//!   successors from arXiv 2508.13840 and 2605.22831.
//! - [`platform`] — the data-driven registry. A [`Platform`] bundles a
//!   descriptor with its [`platform::PowerModel`], perf calibration
//!   ([`platform::PerfCalib`]), partition/hostname/OS identity and
//!   default BLAS library; a [`PlatformRegistry`] resolves them by
//!   string id or alias. Everything above (power, perf calibration,
//!   workloads, inventories, campaign specs) goes through the registry,
//!   so adding a SoC generation is a `register()` call — or a
//!   `[[platform]]` section in a campaign spec file — instead of a
//!   cross-cutting enum match.

pub mod platform;
pub mod presets;
pub mod soc;

pub use platform::{PerfCalib, Platform, PlatformRegistry, PowerModel};
pub use presets::{sg2042, sg2042_dual, sg2044_dual, u740};
pub use soc::{CacheGeom, CoreModel, MemorySystem, Socket, SocDescriptor};
