//! Hardware architecture descriptors for the Monte Cimone fleet.
//!
//! The paper's testbed spans two SoC generations:
//! - MCv1: SiFive Freedom U740 (E4 RV007 blades) — no vector unit.
//! - MCv2: Sophgo Sophon SG2042 (Milk-V Pioneer / SR1-2208A0) — 64 × T-Head
//!   C920 cores with RVV 0.7.1.
//!
//! These descriptors parameterize every model downstream: the ISA timing
//! model reads pipeline widths, the cache simulator reads the hierarchy
//! geometry, the DDR model reads channel counts, and the HPL projection
//! reads peak FLOP rates.

pub mod presets;
pub mod soc;

pub use presets::{sg2042, sg2042_dual, u740};
pub use soc::{CacheGeom, CoreModel, MemorySystem, NodeKind, Socket, SocDescriptor};
