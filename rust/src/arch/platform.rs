//! The open platform API: a [`Platform`] bundles everything the stack
//! needs to know about one SoC generation — hardware geometry
//! ([`SocDescriptor`]), a [`PowerModel`], perf-calibration constants
//! ([`PerfCalib`]), scheduling identity (partition, hostname prefix, OS
//! image) and the default BLAS library — registered by string id in a
//! [`PlatformRegistry`].
//!
//! This replaces the closed `NodeKind` enum the seed matched on in five
//! modules: adding a SoC generation is now a [`PlatformRegistry::register`]
//! call (or a `[[platform]]` section in a campaign spec file), not a
//! cross-cutting code change. The built-in fleet covers the paper plus
//! its successors:
//!
//! | id             | node                                    | source            |
//! |----------------|-----------------------------------------|-------------------|
//! | `mcv1-u740`    | E4 RV007 blade, SiFive U740             | the paper (MCv1)  |
//! | `mcv2-pioneer` | Milk-V Pioneer, 1x SG2042               | the paper (MCv2)  |
//! | `mcv2-dual`    | Sophgo SR1-2208A0, 2x SG2042            | the paper (MCv2)  |
//! | `sg2044`       | Pioneer II class, 1x SG2044 (C920v2)    | arXiv 2508.13840  |
//! | `mcv3`         | projected MCv3 node, 2x SG2044          | arXiv 2605.22831  |
//! | `c930-eval`    | projected C930-class node (VLEN=256)    | what-if (PR 5 note)|
//!
//! Platforms validate their own invariants on registration (non-zero
//! frequency, coherent socket/core counts, sane power and calibration
//! constants) and report violations as typed
//! [`CimoneError::InvalidPlatform`] values.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::presets;
use super::soc::SocDescriptor;
use crate::error::CimoneError;
use crate::util::config::Section;
use crate::util::hash::ContentHasher;

/// Node power as idle + per-active-core dynamic draw (Monte Cimone has
/// carried fine-grained power monitoring since MCv1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerModel {
    pub idle_w: f64,
    pub per_core_active_w: f64,
}

impl PowerModel {
    /// Whole-node draw with `active_cores` busy.
    pub fn node_power(&self, active_cores: usize) -> f64 {
        self.idle_w + self.per_core_active_w * active_cores as f64
    }
}

/// Calibration constants of the node-level DGEMM/HPL performance model
/// ([`crate::blas::perf::PerfModel`]); see DESIGN.md section 5.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerfCalib {
    /// Effective DGEMM DRAM traffic per FLOP (bytes). SG2042-class caches
    /// hold ~0.25 B/flop at HPL block sizes; the U740's tiny L2 and
    /// absent L3 force ~0.6 B/flop (EXPERIMENTS.md 'Calibration').
    pub traffic_bytes_per_flop: f64,
    /// SoC-wide SMP scaling friction per additional core.
    pub smp_alpha: f64,
    /// Steepness of the bandwidth-contention penalty.
    pub bw_gamma: f64,
}

impl PerfCalib {
    /// SG2042/SG2044-class calibration (large shared L3).
    pub fn sg2042_class() -> PerfCalib {
        PerfCalib { traffic_bytes_per_flop: 0.25, smp_alpha: 0.002, bw_gamma: 1.375 }
    }

    /// U740-class calibration (no L3, 2 MB L2).
    pub fn u740_class() -> PerfCalib {
        PerfCalib { traffic_bytes_per_flop: 0.60, smp_alpha: 0.002, bw_gamma: 1.375 }
    }
}

/// One registrable node platform: hardware + power + calibration +
/// fleet identity.
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    /// Registry key and config-file spelling (e.g. `mcv2-pioneer`).
    pub id: String,
    /// Human label used in reports (e.g. `MCv2 1-socket (SG2042)`).
    pub label: String,
    /// Alternate spec-file spellings (`sg2042`, `pioneer`, ...).
    pub aliases: Vec<String>,
    /// SLURM partition nodes of this platform join.
    pub partition: String,
    /// Hostname prefix in [`crate::cluster::Inventory::from_fleet`];
    /// platforms sharing a prefix share one hostname counter (the paper
    /// numbers Pioneer boxes and the SR1 in one `mcv2-NN` sequence).
    pub host_prefix: String,
    /// OS image, as the fleet records it.
    pub os: String,
    /// BLAS kernel registry id (or alias) HPL defaults to on this
    /// platform — resolved against the
    /// [`crate::ukernel::KernelRegistry`] (MCv1 runs the scalar
    /// OpenBLAS, MCv2 the C920 asm, and the SG2044/MCv3 successors the
    /// native RVV 1.0 BLIS tuning points).
    pub default_lib: String,
    /// Interconnect fabric id (or alias) clusters of this platform hang
    /// off by default — resolved against the
    /// [`crate::net::FabricRegistry`] (MCv1/MCv2 ship on `gbe-flat`, the
    /// MCv3 projection on `ten-gbe-flat`).
    pub default_fabric: String,
    pub desc: SocDescriptor,
    pub power: PowerModel,
    pub calib: PerfCalib,
}

impl Platform {
    /// Does `name` refer to this platform (id or alias)?
    pub fn matches(&self, name: &str) -> bool {
        self.id == name || self.aliases.iter().any(|a| a == name)
    }

    /// Peak FP64 GFLOP/s of one node.
    pub fn peak_gflops(&self) -> f64 {
        self.desc.peak_flops() / 1e9
    }

    /// Canonical content feed for the estimation cache: identity plus
    /// every field the workload estimators read (geometry, power,
    /// calibration, defaults). Cosmetic fields (label, aliases,
    /// partition, hostname, OS image) are deliberately excluded — they
    /// never reach an estimate.
    pub fn feed_content(&self, h: &mut ContentHasher) {
        h.write_str(&self.id);
        h.write_str(&self.default_lib);
        h.write_str(&self.default_fabric);
        self.desc.feed_content(h);
        h.write_f64(self.power.idle_w).write_f64(self.power.per_core_active_w);
        h.write_f64(self.calib.traffic_bytes_per_flop)
            .write_f64(self.calib.smp_alpha)
            .write_f64(self.calib.bw_gamma);
    }

    /// The 128-bit content digest of [`Platform::feed_content`].
    pub fn content_hash(&self) -> u128 {
        let mut h = ContentHasher::new();
        self.feed_content(&mut h);
        h.finish()
    }

    fn err(&self, reason: impl Into<String>) -> CimoneError {
        CimoneError::InvalidPlatform { id: self.id.clone(), reason: reason.into() }
    }

    /// Check the platform's own invariants; every registration path runs
    /// this, so malformed platforms never reach the models.
    pub fn validate(&self) -> Result<(), CimoneError> {
        if self.id.is_empty() || self.id.contains(char::is_whitespace) {
            return Err(self.err("id must be non-empty and free of whitespace"));
        }
        if self.partition.is_empty() {
            return Err(self.err("partition must be non-empty"));
        }
        if self.default_fabric.is_empty() || self.default_fabric.contains(char::is_whitespace) {
            return Err(self.err("default_fabric must be non-empty and free of whitespace"));
        }
        if self.default_lib.is_empty() || self.default_lib.contains(char::is_whitespace) {
            return Err(self.err("default_lib must be non-empty and free of whitespace"));
        }
        if self.desc.sockets.is_empty() {
            return Err(self.err("descriptor has no sockets"));
        }
        let cores0 = self.desc.sockets[0].cores;
        for (i, s) in self.desc.sockets.iter().enumerate() {
            if s.cores == 0 {
                return Err(self.err(format!("socket {i} has zero cores")));
            }
            if s.cores != cores0 {
                return Err(self.err(format!(
                    "incoherent socket core counts ({} vs {} on socket {i})",
                    cores0, s.cores
                )));
            }
            let c = &s.core;
            if !(c.freq_hz.is_finite() && c.freq_hz > 0.0) {
                return Err(self.err(format!("socket {i}: core frequency must be finite and > 0")));
            }
            if c.vlen_bits > 0 && c.vfma_lanes_per_cycle == 0 {
                return Err(self.err(format!("socket {i}: vector unit with zero FMA lanes")));
            }
            if c.vlen_bits == 0 && c.scalar_fma_per_cycle <= 0.0 {
                return Err(self.err(format!("socket {i}: no vector unit and no scalar FMA path")));
            }
            let m = &s.mem;
            if m.channels == 0 || !(m.channel_bw_bytes.is_finite() && m.channel_bw_bytes > 0.0) {
                return Err(self.err(format!("socket {i}: memory channels/bandwidth must be > 0")));
            }
            if !(m.efficiency > 0.0 && m.efficiency <= 1.0) {
                return Err(self.err(format!("socket {i}: memory efficiency must be in (0, 1]")));
            }
            if !(m.per_core_bw_bytes.is_finite() && m.per_core_bw_bytes > 0.0) {
                return Err(self.err(format!("socket {i}: per-core bandwidth must be > 0")));
            }
            if m.capacity_bytes == 0 {
                return Err(self.err(format!("socket {i}: zero memory capacity")));
            }
        }
        if !(self.desc.numa_penalty > 0.0 && self.desc.numa_penalty <= 1.0) {
            return Err(self.err("numa_penalty must be in (0, 1]"));
        }
        if self.desc.peak_flops() <= 0.0 {
            return Err(self.err("zero peak FLOP/s"));
        }
        let p = &self.power;
        if !(p.idle_w.is_finite() && p.idle_w >= 0.0)
            || !(p.per_core_active_w.is_finite() && p.per_core_active_w >= 0.0)
        {
            return Err(self.err("power parameters must be finite and >= 0"));
        }
        let c = &self.calib;
        if !(c.traffic_bytes_per_flop.is_finite() && c.traffic_bytes_per_flop > 0.0) {
            return Err(self.err("traffic_bytes_per_flop must be finite and > 0"));
        }
        if !(c.smp_alpha.is_finite() && c.smp_alpha >= 0.0)
            || !(c.bw_gamma.is_finite() && c.bw_gamma >= 0.0)
        {
            return Err(self.err("smp_alpha / bw_gamma must be finite and >= 0"));
        }
        Ok(())
    }
}

/// MCv1 E4 RV007 blade (SiFive Freedom U740), as the paper fields it.
pub fn mcv1_u740() -> Platform {
    Platform {
        id: "mcv1-u740".into(),
        label: "MCv1 (U740)".into(),
        aliases: vec!["mcv1".into(), "u740".into()],
        partition: "mcv1".into(),
        host_prefix: "mc".into(),
        os: "Ubuntu 21.04".into(),
        default_lib: "openblas-generic".into(),
        default_fabric: "gbe-flat".into(),
        desc: presets::u740(),
        // U740 SoC ~5 W + board overhead
        power: PowerModel { idle_w: 25.0, per_core_active_w: 1.2 },
        calib: PerfCalib::u740_class(),
    }
}

/// MCv2 Milk-V Pioneer Box (1x SG2042, 128 GB).
pub fn mcv2_pioneer() -> Platform {
    Platform {
        id: "mcv2-pioneer".into(),
        label: "MCv2 1-socket (SG2042)".into(),
        aliases: vec!["mcv2".into(), "sg2042".into(), "pioneer".into(), "mcv2-1s".into()],
        partition: "mcv2".into(),
        host_prefix: "mcv2".into(),
        os: "Fedora 38".into(),
        default_lib: "openblas-c920".into(),
        default_fabric: "gbe-flat".into(),
        desc: presets::sg2042(),
        // SG2042 TDP ~120 W/socket; Pioneer box idles ~60 W
        power: PowerModel { idle_w: 60.0, per_core_active_w: 1.4 },
        calib: PerfCalib::sg2042_class(),
    }
}

/// MCv2 dual-socket Sophgo SR1-2208A0 (2x SG2042, 256 GB).
pub fn mcv2_dual() -> Platform {
    Platform {
        id: "mcv2-dual".into(),
        label: "MCv2 2-socket (SG2042x2)".into(),
        aliases: vec!["sg2042-dual".into(), "dual".into(), "mcv2-2s".into(), "sr1-2208a0".into()],
        partition: "mcv2".into(),
        host_prefix: "mcv2".into(),
        os: "Fedora 38".into(),
        default_lib: "openblas-c920".into(),
        default_fabric: "gbe-flat".into(),
        desc: presets::sg2042_dual(),
        power: PowerModel { idle_w: 110.0, per_core_active_w: 1.4 },
        calib: PerfCalib::sg2042_class(),
    }
}

/// Sophon SG2044 evaluation node (Pioneer II class, 1 socket, DDR5) —
/// the SG2042 successor Brown et al. evaluate in arXiv 2508.13840.
pub fn sg2044() -> Platform {
    Platform {
        id: "sg2044".into(),
        label: "SG2044 1-socket (C920v2)".into(),
        aliases: vec!["sg2044-1s".into(), "pioneer-ii".into()],
        partition: "sg2044".into(),
        host_prefix: "sg2044".into(),
        os: "Fedora 41".into(),
        // arXiv 2508.13840: the C920v2 speaks ratified RVV 1.0 natively;
        // the LMUL=2 deep-unroll BLIS tuning point is its best kernel
        default_lib: "blis-rvv1-lmul2".into(),
        default_fabric: "gbe-flat".into(),
        desc: presets::sg2044(),
        // lower idle than the Pioneer (DDR5 PHY efficiency), hotter cores
        // at 2.6 GHz
        power: PowerModel { idle_w: 55.0, per_core_active_w: 1.7 },
        calib: PerfCalib::sg2042_class(),
    }
}

/// Projected Monte Cimone v3 node: dual-socket SG2044, 256 GB DDR5
/// (arXiv 2605.22831 direction).
pub fn mcv3() -> Platform {
    Platform {
        id: "mcv3".into(),
        label: "MCv3 2-socket (SG2044x2)".into(),
        aliases: vec!["mcv3-dual".into(), "sg2044-dual".into()],
        partition: "mcv3".into(),
        host_prefix: "mcv3".into(),
        os: "Fedora 41".into(),
        // native RVV 1.0, LMUL=4: the dual-socket node's contended
        // front end still rewards Fig 2b's minimal fetch bandwidth
        default_lib: "blis-rvv1-lmul4".into(),
        // arXiv 2605.22831: MCv3 moves to 10 GbE precisely because the
        // 1 GbE fabric could no longer feed SG2042-class nodes
        default_fabric: "ten-gbe-flat".into(),
        desc: presets::sg2044_dual(),
        power: PowerModel { idle_w: 100.0, per_core_active_w: 1.7 },
        calib: PerfCalib::sg2042_class(),
    }
}

/// Projected C930-class evaluation node: one 64-core VLEN-256 socket,
/// DDR5 — the wider-VLEN what-if platform the PR 5 notes left open.
/// Defaults to the matching VLEN-256 BLIS tuning point, which is the
/// pairing the co-design sweeps exist to interrogate.
pub fn c930_eval() -> Platform {
    Platform {
        id: "c930-eval".into(),
        label: "C930-class eval (VLEN=256)".into(),
        aliases: vec!["c930".into()],
        partition: "c930".into(),
        host_prefix: "c930".into(),
        os: "Fedora 41".into(),
        default_lib: "blis-rvv1-vl256".into(),
        default_fabric: "ten-gbe-flat".into(),
        desc: presets::c930_node(),
        // 4-lane vector units draw harder than the C920v2's two
        power: PowerModel { idle_w: 60.0, per_core_active_w: 2.0 },
        calib: PerfCalib::sg2042_class(),
    }
}

/// Platforms keyed by id, resolvable by id or alias.
#[derive(Debug, Clone, Default)]
pub struct PlatformRegistry {
    by_id: BTreeMap<String, Arc<Platform>>,
}

impl PlatformRegistry {
    /// An empty registry.
    pub fn new() -> PlatformRegistry {
        PlatformRegistry::default()
    }

    /// The built-in fleet: MCv1, both MCv2 node types, the SG2044 /
    /// MCv3 successors, and the C930-class what-if node.
    pub fn builtin() -> PlatformRegistry {
        let mut reg = PlatformRegistry::new();
        for p in [mcv1_u740(), mcv2_pioneer(), mcv2_dual(), sg2044(), mcv3(), c930_eval()] {
            reg.register(p).expect("built-in platforms are valid and unique");
        }
        reg
    }

    /// Validate and add a platform. Ids and aliases share one namespace;
    /// any clash with an already-registered name is rejected.
    pub fn register(&mut self, platform: Platform) -> Result<Arc<Platform>, CimoneError> {
        platform.validate()?;
        for name in std::iter::once(&platform.id).chain(platform.aliases.iter()) {
            if self.resolve(name).is_some() {
                return Err(CimoneError::DuplicatePlatform(name.clone()));
            }
        }
        let arc = Arc::new(platform);
        self.by_id.insert(arc.id.clone(), Arc::clone(&arc));
        Ok(arc)
    }

    fn resolve(&self, name: &str) -> Option<&Arc<Platform>> {
        self.by_id.get(name).or_else(|| self.by_id.values().find(|p| p.matches(name)))
    }

    /// Look a platform up by id or alias.
    pub fn get(&self, name: &str) -> Result<Arc<Platform>, CimoneError> {
        self.resolve(name).cloned().ok_or_else(|| CimoneError::UnknownPlatform {
            id: name.to_string(),
            known: self.ids().join(", "),
        })
    }

    pub fn contains(&self, name: &str) -> bool {
        self.resolve(name).is_some()
    }

    /// Registered ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        self.by_id.keys().cloned().collect()
    }

    /// All registered platforms, in id order.
    pub fn platforms(&self) -> impl Iterator<Item = &Arc<Platform>> {
        self.by_id.values()
    }

    /// Register a platform described by a `[[platform]]` campaign-spec
    /// section: a required `base` platform (id or alias) plus overrides.
    ///
    /// ```text
    /// [[platform]]
    /// id = "sg2044-oc"
    /// base = "sg2044"
    /// freq_ghz = 3.0          # core clock
    /// idle_w = 70.0           # power model
    /// # other overrides: label, partition, os, host_prefix, default_lib,
    /// # sockets, cores, mem_gb, channels, channel_bw_gb, mem_efficiency,
    /// # per_core_bw_gb, numa_penalty, per_core_w,
    /// # traffic_bytes_per_flop, smp_alpha, bw_gamma
    /// ```
    pub fn register_section(&mut self, sec: &Section) -> Result<Arc<Platform>, CimoneError> {
        const KNOWN_KEYS: &[&str] = &[
            "id",
            "base",
            "label",
            "partition",
            "os",
            "host_prefix",
            "default_lib",
            "default_fabric",
            "sockets",
            "cores",
            "freq_ghz",
            "mem_gb",
            "channels",
            "channel_bw_gb",
            "mem_efficiency",
            "per_core_bw_gb",
            "numa_penalty",
            "idle_w",
            "per_core_w",
            "traffic_bytes_per_flop",
            "smp_alpha",
            "bw_gamma",
        ];
        let id = sec
            .get("id")
            .and_then(|v| v.as_str())
            .ok_or_else(|| CimoneError::Spec("[[platform]]: missing string key `id`".into()))?
            .to_string();
        let spec_err =
            |msg: String| -> CimoneError { CimoneError::Spec(format!("platform `{id}`: {msg}")) };
        // a misspelled override must be a load-time error, not a platform
        // silently identical to its base
        if let Some(unknown) = sec.keys().find(|k| !KNOWN_KEYS.contains(&k.as_str())) {
            return Err(spec_err(format!(
                "unknown key `{unknown}` (known: {})",
                KNOWN_KEYS.join(", ")
            )));
        }
        let base = sec
            .get("base")
            .and_then(|v| v.as_str())
            .ok_or_else(|| spec_err("missing string key `base`".into()))?;
        let mut p: Platform = (*self.get(base)?).clone();
        let base_label = p.label.clone();
        p.id = id.clone();
        p.aliases = Vec::new();
        p.label = format!("{id} (custom, from {base_label})");
        p.host_prefix = id.clone();

        for (key, target) in [
            ("label", &mut p.label),
            ("partition", &mut p.partition),
            ("os", &mut p.os),
            ("host_prefix", &mut p.host_prefix),
            // resolution against the fabric/kernel registries happens at
            // campaign load time, where custom [[fabric]] / [[kernel]]
            // sections are in scope
            ("default_fabric", &mut p.default_fabric),
            ("default_lib", &mut p.default_lib),
        ] {
            if let Some(v) = sec.get(key) {
                *target = v
                    .as_str()
                    .ok_or_else(|| spec_err(format!("`{key}` must be a string")))?
                    .to_string();
            }
        }

        let get_f64 = |key: &str| -> Result<Option<f64>, CimoneError> {
            match sec.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_float()
                    .filter(|f| f.is_finite())
                    .map(Some)
                    .ok_or_else(|| spec_err(format!("`{key}` must be a finite number"))),
            }
        };
        let get_usize = |key: &str| -> Result<Option<usize>, CimoneError> {
            match sec.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_int()
                    .filter(|i| *i > 0)
                    .map(|i| Some(i as usize))
                    .ok_or_else(|| spec_err(format!("`{key}` must be a positive int"))),
            }
        };

        if let Some(n) = get_usize("sockets")? {
            let proto = p.desc.sockets[0].clone();
            p.desc.sockets = vec![proto; n];
        }
        for s in &mut p.desc.sockets {
            if let Some(c) = get_usize("cores")? {
                s.cores = c;
            }
            if let Some(f) = get_f64("freq_ghz")? {
                s.core.freq_hz = f * 1e9;
            }
            if let Some(g) = get_f64("mem_gb")? {
                s.mem.capacity_bytes = (g * (1u64 << 30) as f64) as u64;
            }
            if let Some(c) = get_usize("channels")? {
                s.mem.channels = c;
            }
            if let Some(b) = get_f64("channel_bw_gb")? {
                s.mem.channel_bw_bytes = b * 1e9;
            }
            if let Some(e) = get_f64("mem_efficiency")? {
                s.mem.efficiency = e;
            }
            if let Some(b) = get_f64("per_core_bw_gb")? {
                s.mem.per_core_bw_bytes = b * 1e9;
            }
        }
        if let Some(v) = get_f64("numa_penalty")? {
            p.desc.numa_penalty = v;
        }
        if let Some(v) = get_f64("idle_w")? {
            p.power.idle_w = v;
        }
        if let Some(v) = get_f64("per_core_w")? {
            p.power.per_core_active_w = v;
        }
        if let Some(v) = get_f64("traffic_bytes_per_flop")? {
            p.calib.traffic_bytes_per_flop = v;
        }
        if let Some(v) = get_f64("smp_alpha")? {
            p.calib.smp_alpha = v;
        }
        if let Some(v) = get_f64("bw_gamma")? {
            p.calib.bw_gamma = v;
        }
        self.register(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_fleet_registers_and_resolves_aliases() {
        let reg = PlatformRegistry::builtin();
        assert_eq!(
            reg.ids(),
            ["c930-eval", "mcv1-u740", "mcv2-dual", "mcv2-pioneer", "mcv3", "sg2044"]
        );
        assert_eq!(reg.get("mcv1").unwrap().id, "mcv1-u740");
        assert_eq!(reg.get("sg2042").unwrap().id, "mcv2-pioneer");
        assert_eq!(reg.get("sr1-2208a0").unwrap().id, "mcv2-dual");
        assert_eq!(reg.get("pioneer-ii").unwrap().id, "sg2044");
        assert_eq!(reg.get("sg2044-dual").unwrap().id, "mcv3");
        assert_eq!(reg.get("c930").unwrap().id, "c930-eval");
    }

    #[test]
    fn unknown_platform_is_typed_and_lists_known_ids() {
        let reg = PlatformRegistry::builtin();
        match reg.get("epyc") {
            Err(CimoneError::UnknownPlatform { id, known }) => {
                assert_eq!(id, "epyc");
                assert!(known.contains("mcv2-pioneer"), "{known}");
            }
            other => panic!("expected UnknownPlatform, got {other:?}"),
        }
    }

    #[test]
    fn duplicate_id_and_alias_rejected() {
        let mut reg = PlatformRegistry::builtin();
        assert!(matches!(reg.register(sg2044()), Err(CimoneError::DuplicatePlatform(_))));
        // an alias clashing with an existing alias is also a duplicate
        let mut p = sg2044();
        p.id = "sg2044-b".into();
        p.aliases = vec!["pioneer-ii".into()];
        assert!(matches!(reg.register(p), Err(CimoneError::DuplicatePlatform(_))));
    }

    #[test]
    fn validation_catches_broken_invariants() {
        let mut p = mcv2_pioneer();
        p.desc.sockets[0].core.freq_hz = 0.0;
        assert!(matches!(p.validate(), Err(CimoneError::InvalidPlatform { .. })));

        let mut p = mcv2_dual();
        p.desc.sockets[1].cores = 32; // incoherent with socket 0
        assert!(matches!(p.validate(), Err(CimoneError::InvalidPlatform { .. })));

        let mut p = sg2044();
        p.calib.traffic_bytes_per_flop = 0.0;
        assert!(matches!(p.validate(), Err(CimoneError::InvalidPlatform { .. })));

        let mut p = mcv3();
        p.desc.numa_penalty = 1.5;
        assert!(matches!(p.validate(), Err(CimoneError::InvalidPlatform { .. })));
    }

    #[test]
    fn sg2044_peak_exceeds_sg2042() {
        assert!(sg2044().peak_gflops() > mcv2_pioneer().peak_gflops());
        assert!(mcv3().peak_gflops() > mcv2_dual().peak_gflops());
    }

    #[test]
    fn custom_platform_from_section_inherits_and_overrides() {
        use crate::util::config::Config;
        let cfg = Config::parse(
            "[[platform]]\nid = \"sg2044-oc\"\nbase = \"sg2044\"\nfreq_ghz = 3.0\nidle_w = 70.0\n",
        )
        .unwrap();
        let mut reg = PlatformRegistry::builtin();
        let p = reg.register_section(&cfg.table_arrays["platform"][0]).unwrap();
        assert_eq!(p.id, "sg2044-oc");
        assert!((p.desc.sockets[0].core.freq_hz - 3.0e9).abs() < 1.0);
        assert!((p.power.idle_w - 70.0).abs() < 1e-9);
        // inherited geometry
        assert_eq!(p.desc.sockets[0].cores, 64);
        assert_eq!(reg.get("sg2044-oc").unwrap().id, "sg2044-oc");
    }

    #[test]
    fn custom_platform_unknown_key_is_rejected() {
        use crate::util::config::Config;
        // `freq_gz` (misspelled) must not silently produce a stock clone
        let cfg = Config::parse(
            "[[platform]]\nid = \"typo\"\nbase = \"sg2044\"\nfreq_gz = 3.0\n",
        )
        .unwrap();
        let mut reg = PlatformRegistry::builtin();
        match reg.register_section(&cfg.table_arrays["platform"][0]) {
            Err(CimoneError::Spec(m)) => assert!(m.contains("unknown key `freq_gz`"), "{m}"),
            other => panic!("expected Spec error, got {other:?}"),
        }
    }

    #[test]
    fn custom_platform_bad_override_is_rejected() {
        use crate::util::config::Config;
        let cfg = Config::parse(
            "[[platform]]\nid = \"dud\"\nbase = \"sg2044\"\nmem_efficiency = 2.0\n",
        )
        .unwrap();
        let mut reg = PlatformRegistry::builtin();
        assert!(matches!(
            reg.register_section(&cfg.table_arrays["platform"][0]),
            Err(CimoneError::InvalidPlatform { .. })
        ));
    }
}
