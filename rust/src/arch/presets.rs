//! Concrete hardware descriptors for the Monte Cimone fleet and its
//! successors: the paper and the SG2042 TRM (paper refs [9], [10]) for
//! MCv1/MCv2, arXiv 2508.13840 (Brown) for the SG2044, and arXiv
//! 2605.22831 for the Monte Cimone v3 direction.
//!
//! These are raw [`SocDescriptor`] building blocks. The platform layer
//! ([`crate::arch::platform`]) bundles them with power models and perf
//! calibration into registrable [`crate::arch::platform::Platform`]s.

use super::soc::{CacheGeom, CoreModel, MemorySystem, Socket, SocDescriptor};

const GB: u64 = 1 << 30;

/// T-Head C920 core as integrated in the SG2042.
///
/// - 2.0 GHz, dual-issue in-order front end.
/// - RVV 0.7.1, VLEN = 128 (2 FP64 lanes), fused multiply-add.
/// - `vinst_dispatch_cycles` = 2.0: calibrated so the BLIS LMUL=1 -> 4
///   rewrite yields the paper's ~1.9x micro-kernel / +49% HPL gain
///   (EXPERIMENTS.md section Fig7 shows the calibration fit).
pub fn c920() -> CoreModel {
    CoreModel {
        freq_hz: 2.0e9,
        issue_width: 2,
        vlen_bits: 128,
        native_rvv10: false,
        vfma_lanes_per_cycle: 2,
        vinst_dispatch_cycles: 2.0,
        scalar_fma_per_cycle: 1.0,
        lsu_per_cycle: 1.0,
    }
}

/// T-Head C920v2 core as integrated in the SG2044 (arXiv 2508.13840).
///
/// Same VLEN-128 FP64 datapath as the C920 but clocked at 2.6 GHz,
/// speaking ratified RVV 1.0 natively, and with a reworked front end:
/// `vinst_dispatch_cycles` = 1.0 models the halved vector-dispatch
/// serialization Brown et al. observe relative to the SG2042.
pub fn c920v2() -> CoreModel {
    CoreModel {
        freq_hz: 2.6e9,
        issue_width: 2,
        vlen_bits: 128,
        native_rvv10: true,
        vfma_lanes_per_cycle: 2,
        vinst_dispatch_cycles: 1.0,
        scalar_fma_per_cycle: 1.0,
        lsu_per_cycle: 1.0,
    }
}

/// T-Head C930-class core: the announced VLEN-256 successor of the
/// C920v2 (what-if projection for the next Monte Cimone generation).
///
/// - 2.5 GHz, dual-issue, ratified RVV 1.0.
/// - VLEN = 256 (4 FP64 lanes), same 1-cycle vector dispatch as the
///   C920v2 front end — so a full-width vfmacc retires 4 FMA lanes per
///   cycle instead of 2, and LMUL=4 kernels keep the datapath busy.
pub fn c930() -> CoreModel {
    CoreModel {
        freq_hz: 2.5e9,
        issue_width: 2,
        vlen_bits: 256,
        native_rvv10: true,
        vfma_lanes_per_cycle: 4,
        vinst_dispatch_cycles: 1.0,
        scalar_fma_per_cycle: 1.0,
        lsu_per_cycle: 1.0,
    }
}

/// SiFive U74 core (U740 SoC): no RVV, single FP pipe.
///
/// MCv1 peak is 4.0 GF/s/node over 4 application cores = 1.0 GF/s/core
/// = 0.5 GHz-equivalent FMA issue at 1.0 GHz... in reality the U74 runs
/// 1.2 GHz with one FMA every ~2.4 cycles; we encode the paper's peak
/// directly: freq 1.0 GHz x 2 flops x 0.5 FMA/cycle = 1.0 GF/s.
pub fn u74() -> CoreModel {
    CoreModel {
        freq_hz: 1.0e9,
        issue_width: 2,
        vlen_bits: 0,
        native_rvv10: false,
        vfma_lanes_per_cycle: 0,
        vinst_dispatch_cycles: 0.0,
        scalar_fma_per_cycle: 0.5,
        lsu_per_cycle: 1.0,
    }
}

fn sg2042_socket() -> Socket {
    Socket {
        cores: 64,
        core: c920(),
        // 64 KB L1D per core, 8-way, 64 B lines
        l1d: CacheGeom { size_bytes: 64 * 1024, line_bytes: 64, ways: 8, shared_by: 1 },
        // 1 MB L2 per 4-core cluster, 16-way
        l2: CacheGeom { size_bytes: 1 << 20, line_bytes: 64, ways: 16, shared_by: 4 },
        // 64 MB system L3, 16-way
        l3: Some(CacheGeom { size_bytes: 64 << 20, line_bytes: 64, ways: 16, shared_by: 64 }),
        mem: MemorySystem {
            channels: 4,
            channel_bw_bytes: 25.6e9, // DDR4-3200
            // paper Fig 3: 41.9 GB/s attained of 102.4 GB/s theoretical
            efficiency: 0.409,
            // ramp slope: an in-order C920 keeps ~1.35 GB/s in flight, so a
            // socket saturates near 32 threads — which is why the paper's
            // dual-socket node hits 82.9 GB/s with only 64 threads pinned
            // symmetrically (32 per socket)
            per_core_bw_bytes: 1.35e9,
            capacity_bytes: 128 * GB,
        },
    }
}

fn sg2044_socket() -> Socket {
    Socket {
        cores: 64,
        core: c920v2(),
        l1d: CacheGeom { size_bytes: 64 * 1024, line_bytes: 64, ways: 8, shared_by: 1 },
        // 2 MB L2 per 4-core cluster
        l2: CacheGeom { size_bytes: 2 << 20, line_bytes: 64, ways: 16, shared_by: 4 },
        l3: Some(CacheGeom { size_bytes: 64 << 20, line_bytes: 64, ways: 16, shared_by: 64 }),
        mem: MemorySystem {
            channels: 4,
            channel_bw_bytes: 44.8e9, // DDR5-5600
            // Brown et al.: roughly half the theoretical 179.2 GB/s is
            // attainable from the cores — a big step over the SG2042's
            // 41% but still short of x86 controllers
            efficiency: 0.50,
            per_core_bw_bytes: 3.0e9,
            capacity_bytes: 128 * GB,
        },
    }
}

fn c930_socket() -> Socket {
    Socket {
        cores: 64,
        core: c930(),
        l1d: CacheGeom { size_bytes: 64 * 1024, line_bytes: 64, ways: 8, shared_by: 1 },
        // 4 MB L2 per 4-core cluster: twice the SG2044's, sized so the
        // wider vector unit's streaming B panels stay resident
        l2: CacheGeom { size_bytes: 4 << 20, line_bytes: 64, ways: 16, shared_by: 4 },
        l3: Some(CacheGeom { size_bytes: 128 << 20, line_bytes: 64, ways: 16, shared_by: 64 }),
        mem: MemorySystem {
            channels: 4,
            channel_bw_bytes: 51.2e9, // DDR5-6400
            // projected controller efficiency just past the SG2044's 50%
            efficiency: 0.55,
            per_core_bw_bytes: 3.5e9,
            capacity_bytes: 128 * GB,
        },
    }
}

/// MCv2 Milk-V Pioneer Box: single SG2042, 128 GB DDR4.
pub fn sg2042() -> SocDescriptor {
    SocDescriptor {
        name: "milkv-pioneer".into(),
        sockets: vec![sg2042_socket()],
        numa_penalty: 1.0,
    }
}

/// MCv2 dual-socket Sophgo SR1-2208A0: 2x SG2042, 256 GB.
///
/// `numa_penalty` = 0.88 calibrated to the paper's 1.76x dual/single
/// HPL ratio (2 x 0.88 = 1.76).
pub fn sg2042_dual() -> SocDescriptor {
    SocDescriptor {
        name: "sophgo-sr1-2208a0".into(),
        sockets: vec![sg2042_socket(), sg2042_socket()],
        numa_penalty: 0.88,
    }
}

/// SG2044 evaluation system (Milk-V Pioneer II class): single SG2044,
/// 128 GB DDR5 (arXiv 2508.13840).
pub fn sg2044() -> SocDescriptor {
    SocDescriptor {
        name: "milkv-pioneer-ii".into(),
        sockets: vec![sg2044_socket()],
        numa_penalty: 1.0,
    }
}

/// Projected MCv3-class dual-socket SG2044 node, 256 GB DDR5
/// (arXiv 2605.22831 direction). Slightly milder NUMA penalty than the
/// SR1-2208A0: DDR5 leaves more headroom for cross-socket traffic.
pub fn sg2044_dual() -> SocDescriptor {
    SocDescriptor {
        name: "mcv3-sg2044x2".into(),
        sockets: vec![sg2044_socket(), sg2044_socket()],
        numa_penalty: 0.90,
    }
}

/// Projected C930-class evaluation node: single 64-core VLEN-256
/// socket, 128 GB DDR5. The wider-VLEN what-if platform left open by
/// the PR 5 notes.
pub fn c930_node() -> SocDescriptor {
    SocDescriptor {
        name: "c930-eval".into(),
        sockets: vec![c930_socket()],
        numa_penalty: 1.0,
    }
}

/// MCv1 E4 RV007 blade: SiFive HiFive Unmatched (Freedom U740), 16 GB.
pub fn u740() -> SocDescriptor {
    SocDescriptor {
        name: "e4-rv007-u740".into(),
        sockets: vec![Socket {
            cores: 4,
            core: u74(),
            l1d: CacheGeom { size_bytes: 32 * 1024, line_bytes: 64, ways: 8, shared_by: 1 },
            l2: CacheGeom { size_bytes: 2 << 20, line_bytes: 64, ways: 16, shared_by: 4 },
            l3: None,
            mem: MemorySystem {
                channels: 1,
                channel_bw_bytes: 8.5e9, // DDR4-2133 single channel (FU740)
                // paper: 1.1 GB/s attained — the FU740 memory controller is
                // notoriously inefficient
                efficiency: 0.129,
                per_core_bw_bytes: 0.32e9,
                capacity_bytes: 16 * GB,
            },
        }],
        numa_penalty: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sg2042_memory_geometry_matches_trm() {
        let s = sg2042();
        let sk = &s.sockets[0];
        assert_eq!(sk.l1d.size_bytes, 64 * 1024);
        assert_eq!(sk.l2.size_bytes, 1 << 20);
        assert_eq!(sk.l2.shared_by, 4);
        assert_eq!(sk.l3.unwrap().size_bytes, 64 << 20);
        assert!((sk.mem.peak_bw() - 102.4e9).abs() < 1e7);
    }

    #[test]
    fn sg2042_attained_bw_matches_fig3() {
        let s = sg2042();
        let bw = s.sockets[0].mem.attainable_bw();
        assert!((bw - 41.9e9).abs() < 0.2e9, "{bw}");
    }

    #[test]
    fn u740_attained_bw_matches_fig3() {
        let s = u740();
        let bw = s.sockets[0].mem.attainable_bw();
        assert!((bw - 1.1e9).abs() < 0.05e9, "{bw}");
    }

    #[test]
    fn numa_penalty_yields_176x() {
        let d = sg2042_dual();
        assert!((2.0 * d.numa_penalty - 1.76).abs() < 1e-9);
    }

    #[test]
    fn sg2044_outclasses_sg2042() {
        // higher clock => higher peak, DDR5 => more attainable bandwidth
        let old = sg2042();
        let new = sg2044();
        assert!(new.peak_flops() > old.peak_flops());
        assert!(
            new.sockets[0].mem.attainable_bw() > 1.5 * old.sockets[0].mem.attainable_bw()
        );
    }

    #[test]
    fn c930_widens_the_vector_datapath() {
        let core = c930();
        assert_eq!(core.vlen_bits, 256);
        assert!(core.native_rvv10);
        assert_eq!(core.vfma_lanes_per_cycle, 4);
        // per-core FP64 peak: 2.5 GHz x 4 lanes x 2 flops = 20 GF/s,
        // vs the C920v2's 2.6 x 2 x 2 = 10.4
        let node = c930_node();
        assert!(node.peak_flops() > 1.8 * sg2044().peak_flops());
        assert!(node.sockets[0].mem.attainable_bw() > sg2044().sockets[0].mem.attainable_bw());
    }

    #[test]
    fn sg2044_dual_doubles_sg2044() {
        let one = sg2044();
        let two = sg2044_dual();
        assert_eq!(two.total_cores(), 2 * one.total_cores());
        assert_eq!(two.total_memory(), 2 * one.total_memory());
    }
}
