//! SoC / node descriptor types.

use crate::util::hash::ContentHasher;

/// Geometry of one cache level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheGeom {
    pub size_bytes: usize,
    pub line_bytes: usize,
    pub ways: usize,
    /// Cores sharing one instance of this cache (1 = private,
    /// 4 = per-cluster like the SG2042 L2, usize::MAX = chip-wide).
    pub shared_by: usize,
}

impl CacheGeom {
    pub fn sets(&self) -> usize {
        self.size_bytes / (self.line_bytes * self.ways)
    }

    /// Canonical content feed for the estimation cache.
    pub fn feed_content(&self, h: &mut ContentHasher) {
        h.write_usize(self.size_bytes)
            .write_usize(self.line_bytes)
            .write_usize(self.ways)
            .write_usize(self.shared_by);
    }
}

/// Core microarchitecture parameters consumed by `isa::timing`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreModel {
    pub freq_hz: f64,
    /// Scalar instructions issued per cycle (C920: dual-issue in-order).
    pub issue_width: usize,
    /// Vector register length in bits (0 = no vector unit).
    pub vlen_bits: usize,
    /// Does the core speak ratified RVV 1.0 natively (C920v2 and later)?
    /// `false` = the theadvector/0.7.1 era; meaningless when
    /// `vlen_bits == 0`. Kernels tuned for the other dialect pay a port
    /// tax in [`crate::ukernel::analysis`] (the paper's Section 3.3.1
    /// retrofit, or the reverse port of hand-scheduled 0.7.1 asm).
    pub native_rvv10: bool,
    /// FP64 lanes the vector FMA datapath retires per cycle.
    pub vfma_lanes_per_cycle: usize,
    /// Fixed dispatch/sequencing overhead, in cycles, charged per vector
    /// instruction regardless of LMUL. This models the C920's in-order
    /// fetch/decode bottleneck — the quantity the paper's LMUL=4 rewrite
    /// amortizes over 4x more work.
    pub vinst_dispatch_cycles: f64,
    /// Scalar FP64 FMA throughput (instructions/cycle).
    pub scalar_fma_per_cycle: f64,
    /// Scalar load/store units.
    pub lsu_per_cycle: f64,
}

impl CoreModel {
    /// FP64 lanes per vector register (VLEN / 64).
    pub fn f64_lanes(&self) -> usize {
        self.vlen_bits / 64
    }

    /// Peak FP64 FLOP/s of one core (vector FMA path if present).
    pub fn peak_flops(&self) -> f64 {
        if self.vlen_bits > 0 {
            // FMA = 2 flops per lane per cycle
            2.0 * self.vfma_lanes_per_cycle as f64 * self.freq_hz
        } else {
            2.0 * self.scalar_fma_per_cycle * self.freq_hz
        }
    }

    /// Canonical content feed for the estimation cache: every field the
    /// cycle model reads, bit-exact.
    pub fn feed_content(&self, h: &mut ContentHasher) {
        h.write_f64(self.freq_hz)
            .write_usize(self.issue_width)
            .write_usize(self.vlen_bits)
            .write_bool(self.native_rvv10)
            .write_usize(self.vfma_lanes_per_cycle)
            .write_f64(self.vinst_dispatch_cycles)
            .write_f64(self.scalar_fma_per_cycle)
            .write_f64(self.lsu_per_cycle);
    }
}

/// Memory system of one socket.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemorySystem {
    pub channels: usize,
    /// Per-channel peak (e.g. DDR4-3200: 25.6 GB/s).
    pub channel_bw_bytes: f64,
    /// Fraction of theoretical bandwidth attainable by cores (controller
    /// efficiency x coherence traffic); calibrated to the paper's STREAM.
    pub efficiency: f64,
    /// Single-core attainable load/store bandwidth (bytes/s) — the ramp
    /// slope of the STREAM-vs-threads curve.
    pub per_core_bw_bytes: f64,
    pub capacity_bytes: u64,
}

impl MemorySystem {
    pub fn peak_bw(&self) -> f64 {
        self.channels as f64 * self.channel_bw_bytes
    }
    pub fn attainable_bw(&self) -> f64 {
        self.peak_bw() * self.efficiency
    }

    /// Canonical content feed for the estimation cache.
    pub fn feed_content(&self, h: &mut ContentHasher) {
        h.write_usize(self.channels)
            .write_f64(self.channel_bw_bytes)
            .write_f64(self.efficiency)
            .write_f64(self.per_core_bw_bytes)
            .write_u64(self.capacity_bytes);
    }
}

/// One socket: cores + caches + memory.
#[derive(Debug, Clone, PartialEq)]
pub struct Socket {
    pub cores: usize,
    pub core: CoreModel,
    pub l1d: CacheGeom,
    pub l2: CacheGeom,
    pub l3: Option<CacheGeom>,
    pub mem: MemorySystem,
}

impl Socket {
    pub fn peak_flops(&self) -> f64 {
        self.cores as f64 * self.core.peak_flops()
    }

    /// Canonical content feed for the estimation cache.
    pub fn feed_content(&self, h: &mut ContentHasher) {
        h.write_usize(self.cores);
        self.core.feed_content(h);
        self.l1d.feed_content(h);
        self.l2.feed_content(h);
        h.write_bool(self.l3.is_some());
        if let Some(l3) = &self.l3 {
            l3.feed_content(h);
        }
        self.mem.feed_content(h);
    }
}

/// A full node descriptor (possibly multi-socket). Pure hardware
/// geometry — identity, power and calibration live one level up in
/// [`crate::arch::platform::Platform`].
#[derive(Debug, Clone, PartialEq)]
pub struct SocDescriptor {
    pub name: String,
    pub sockets: Vec<Socket>,
    /// Attained-bandwidth penalty when threads span sockets without
    /// symmetric pinning (NUMA effect the paper observes on the
    /// dual-socket node).
    pub numa_penalty: f64,
}

impl SocDescriptor {
    pub fn total_cores(&self) -> usize {
        self.sockets.iter().map(|s| s.cores).sum()
    }

    pub fn peak_flops(&self) -> f64 {
        self.sockets.iter().map(|s| s.peak_flops()).sum()
    }

    pub fn total_memory(&self) -> u64 {
        self.sockets.iter().map(|s| s.mem.capacity_bytes).sum()
    }

    /// Largest HPL problem fitting in (fraction of) memory:
    /// N = sqrt(frac * bytes / 8).
    pub fn hpl_max_n(&self, mem_fraction: f64) -> usize {
        let bytes = self.total_memory() as f64 * mem_fraction;
        (bytes / 8.0).sqrt() as usize
    }

    /// Canonical content feed for the estimation cache.
    pub fn feed_content(&self, h: &mut ContentHasher) {
        h.write_str(&self.name);
        h.write_usize(self.sockets.len());
        for s in &self.sockets {
            s.feed_content(h);
        }
        h.write_f64(self.numa_penalty);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn cache_sets_geometry() {
        let g = CacheGeom { size_bytes: 64 * 1024, line_bytes: 64, ways: 4, shared_by: 1 };
        assert_eq!(g.sets(), 256);
    }

    #[test]
    fn sg2042_peak_matches_paper_math() {
        // 64 cores x 2 GHz x 2 lanes x 2 flops = 512 GF/s per socket
        let s = presets::sg2042();
        assert_eq!(s.total_cores(), 64);
        assert!((s.peak_flops() - 512e9).abs() < 1e6, "{}", s.peak_flops());
    }

    #[test]
    fn u740_peak_matches_mcv1_spec() {
        // paper: 4.0 Gflop/s theoretical peak per MCv1 node
        let s = presets::u740();
        assert!((s.peak_flops() - 4.0e9).abs() < 1e6, "{}", s.peak_flops());
    }

    #[test]
    fn dual_socket_doubles_resources() {
        let one = presets::sg2042();
        let two = presets::sg2042_dual();
        assert_eq!(two.total_cores(), 2 * one.total_cores());
        assert_eq!(two.total_memory(), 2 * one.total_memory());
    }

    #[test]
    fn hpl_max_n_scales_with_memory() {
        let one = presets::sg2042();
        let n = one.hpl_max_n(0.8);
        // 128 GB * 0.8 / 8 = 12.8e9 doubles -> N ~ 113k
        assert!(n > 100_000 && n < 120_000, "{n}");
    }

    #[test]
    fn vector_core_lanes() {
        let s = presets::sg2042();
        assert_eq!(s.sockets[0].core.f64_lanes(), 2);
    }
}
