//! BLIS-style cache blocking (MC/KC/NC/MR/NR) derived from cache geometry.
//!
//! BLIS's analytical model (Low et al., "Analytical Modeling Is Enough for
//! High-Performance BLIS"): the micro-panel of B (KC x NR) lives in L1,
//! the packed A block (MC x KC) in L2, the packed B panel (KC x NC) in L3.
//! OpenBLAS uses fixed, x86-tuned parameters — the difference Fig 6
//! measures as cache-miss-rate gaps.

use crate::arch::soc::Socket;

/// The five blocking parameters of a level-3 BLAS implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Blocking {
    pub mr: usize,
    pub nr: usize,
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

impl Blocking {
    /// Derive BLIS-style blocking from a socket's cache geometry.
    pub fn blis_for(socket: &Socket, mr: usize, nr: usize) -> Blocking {
        let elem = 8; // f64
        // KC: the B micro-panel (KC x NR) plus an A micro-panel (MR x KC)
        // should fill ~half of L1D (leave room for C and streams).
        let l1_budget = socket.l1d.size_bytes / 2;
        let kc_raw = l1_budget / (elem * (mr + nr));
        let kc = round_down_pow2ish(kc_raw.clamp(64, 512));
        // MC: packed A block (MC x KC) fills ~half of the L2 share per core.
        let l2_per_core = socket.l2.size_bytes / socket.l2.shared_by;
        let mc_raw = (l2_per_core / 2) / (elem * kc);
        let mc = (mc_raw / mr).max(1) * mr;
        // NC: packed B panel (KC x NC) fills ~half of the per-core L3 share.
        let nc = match socket.l3 {
            Some(l3) => {
                let l3_per_core = l3.size_bytes / l3.shared_by;
                let nc_raw = (l3_per_core / 2) / (elem * kc);
                (nc_raw / nr).max(1) * nr
            }
            None => 4096,
        };
        Blocking { mr, nr, mc, kc, nc }
    }

    /// OpenBLAS's fixed parameter set (x86-cache-ratio tuned; what its
    /// `param.h` ships for generic 64-bit targets, sized for 512 KB+
    /// private L2s and 32 MB LLCs). On the SG2042 this is doubly wrong:
    /// the A micro-panel stream (MRxKC = 48 KB) plus the B micro-panel
    /// (KCxNR = 24 KB) overflow the 64 KB L1D, evicting B between reuses,
    /// and the packed A block (MCxKC = 4.7 MB) dwarfs the 256 KB
    /// per-core L2 share — the locality gap Fig 6 measures.
    pub fn openblas_fixed(mr: usize, nr: usize) -> Blocking {
        Blocking { mr, nr, mc: 768, kc: 768, nc: 8192 }
    }

    /// Working-set bytes per cache level: (L1 set, L2 set, L3 set).
    pub fn working_sets(&self) -> (usize, usize, usize) {
        let e = 8;
        (
            self.kc * self.nr * e + self.mr * self.kc * e,
            self.mc * self.kc * e,
            self.kc * self.nc * e,
        )
    }

    /// Effective DGEMM DRAM traffic in bytes per FLOP for this blocking —
    /// the demand number the contention model feeds on. Classic result:
    /// each element of A/B/C moves ~(1/NC + 1/MC + 2/KC) x 8 bytes per
    /// 2 flops, plus packing traffic.
    pub fn dram_bytes_per_flop(&self) -> f64 {
        let e = 8.0;
        let reuse = 1.0 / self.nc as f64 + 1.0 / self.mc as f64 + 2.0 / self.kc as f64;
        // packing reads+writes A and B once per block pass
        let packing = 2.0 / self.kc.min(self.nc) as f64;
        // 1.5x: empirical scale from ideal-reuse traffic to attained traffic
        // (TLB refills, write-allocate on C, prefetcher overshoot)
        e * (reuse + packing) / 2.0 * 1.5
    }
}

fn round_down_pow2ish(x: usize) -> usize {
    // round down to a multiple of 32 (vector-friendly KC)
    (x / 32).max(1) * 32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn blis_blocking_fits_sg2042_caches() {
        let s = &presets::sg2042().sockets[0];
        let b = Blocking::blis_for(s, 8, 4);
        let (l1, l2, l3) = b.working_sets();
        assert!(l1 <= s.l1d.size_bytes, "L1 set {l1}");
        assert!(l2 <= s.l2.size_bytes / s.l2.shared_by, "L2 set {l2}");
        assert!(l3 <= s.l3.unwrap().size_bytes / 64, "L3 set {l3}");
        assert_eq!(b.mc % b.mr, 0);
        assert_eq!(b.nc % b.nr, 0);
    }

    #[test]
    fn openblas_fixed_overflows_sg2042_l2_share() {
        // the premise of Fig 6: OpenBLAS's blocking doesn't fit the SG2042's
        // small per-cluster L2, BLIS's derived blocking does
        let s = &presets::sg2042().sockets[0];
        let ob = Blocking::openblas_fixed(8, 4);
        let (_, l2, _) = ob.working_sets();
        assert!(l2 > s.l2.size_bytes / s.l2.shared_by);
    }

    #[test]
    fn kc_in_sane_range() {
        let s = &presets::sg2042().sockets[0];
        let b = Blocking::blis_for(s, 8, 4);
        assert!((64..=512).contains(&b.kc), "kc={}", b.kc);
    }

    #[test]
    fn u740_gets_smaller_blocks() {
        let v1 = &presets::u740().sockets[0];
        let v2 = &presets::sg2042().sockets[0];
        let b1 = Blocking::blis_for(v1, 4, 4);
        let b2 = Blocking::blis_for(v2, 8, 4);
        assert!(b1.kc <= b2.kc);
    }

    #[test]
    fn traffic_decreases_with_bigger_blocks() {
        let small = Blocking { mr: 8, nr: 4, mc: 64, kc: 64, nc: 512 };
        let big = Blocking { mr: 8, nr: 4, mc: 256, kc: 256, nc: 4096 };
        assert!(big.dram_bytes_per_flop() < small.dram_bytes_per_flop());
    }

    #[test]
    fn sg2042_traffic_near_calibration() {
        // EXPERIMENTS.md 'Calibration': ~0.25 B/flop effective DGEMM traffic
        let s = &presets::sg2042().sockets[0];
        let b = Blocking::blis_for(s, 8, 4);
        let t = b.dram_bytes_per_flop();
        assert!((0.1..0.5).contains(&t), "traffic {t}");
    }
}
