//! Blocked GEMM driver: the BLIS macro-kernel loop nest running real
//! micro-kernel programs on the functional vector machine.
//!
//! Loop structure (BLIS's five loops around the micro-kernel):
//! ```text
//! for jc in 0..n step NC        (B panel -> L3)
//!   for pc in 0..k step KC      (A block -> L2, B packed)
//!     for ic in 0..m step MC
//!       for jr in 0..nc step NR (micro-panel of B -> L1)
//!         for ir in 0..mc step MR
//!           ukernel(A[ir, pc], B[pc, jr], C[ir, jr])
//! ```
//!
//! Edge tiles (m % MR, n % NR, k % KC) are zero-padded into full panels —
//! numerically exact, matching how our AOT'd trailing-update artifact
//! handles shrinking HPL submatrices.

use super::library::BlasLibrary;
use crate::error::CimoneError;
use crate::util::Matrix;

/// C += A * B through the library's micro-kernel.
pub fn gemm_acc(
    lib: &BlasLibrary,
    c: &mut Matrix,
    a: &Matrix,
    b: &Matrix,
) -> Result<(), CimoneError> {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    if k != k2 || c.rows() != m || c.cols() != n {
        return Err(CimoneError::GemmShape {
            cm: c.rows(),
            cn: c.cols(),
            am: m,
            ak: k,
            bk: k2,
            bn: n,
        });
    }
    let bl = lib.blocking;
    for jc in (0..n).step_by(bl.nc) {
        let ncb = bl.nc.min(n - jc);
        for pc in (0..k).step_by(bl.kc) {
            let kcb = bl.kc.min(k - pc);
            for ic in (0..m).step_by(bl.mc) {
                let mcb = bl.mc.min(m - ic);
                for jr in (0..ncb).step_by(bl.nr) {
                    let nrb = bl.nr.min(ncb - jr);
                    for ir in (0..mcb).step_by(bl.mr) {
                        let mrb = bl.mr.min(mcb - ir);
                        // pack (zero-padded) panels
                        let mut ap = Matrix::zeros(bl.mr, kcb);
                        ap.set_block(0, 0, &a.block(ic + ir, pc, mrb, kcb));
                        let mut bp = Matrix::zeros(kcb, bl.nr);
                        bp.set_block(0, 0, &b.block(pc, jc + jr, kcb, nrb));
                        let mut cp = Matrix::zeros(bl.mr, bl.nr);
                        cp.set_block(0, 0, &c.block(ic + ir, jc + jr, mrb, nrb));
                        let out = lib.kernel.run(&ap, &bp, &cp)?;
                        c.set_block(ic + ir, jc + jr, &out.block(0, 0, mrb, nrb));
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::ukernel::KernelRegistry;
    use crate::util::prop;
    use crate::util::Rng;

    fn lib(id: &str) -> BlasLibrary {
        let kernel = KernelRegistry::builtin().get(id).unwrap();
        BlasLibrary::for_socket(kernel, &presets::sg2042().sockets[0])
    }

    fn check_against_naive(id: &str, m: usize, n: usize, k: usize, seed: u64) {
        let l = lib(id);
        let a = Matrix::random_hpl(m, k, seed);
        let b = Matrix::random_hpl(k, n, seed + 1);
        let mut c = Matrix::random_hpl(m, n, seed + 2);
        let mut want = c.clone();
        gemm_acc(&l, &mut c, &a, &b).unwrap();
        Matrix::gemm_acc(&mut want, &a, &b);
        assert!(c.allclose(&want, 1e-11, 1e-11), "{id} {m}x{n}x{k}");
    }

    #[test]
    fn all_libraries_aligned_sizes() {
        for id in KernelRegistry::builtin().ids() {
            check_against_naive(&id, 16, 16, 16, 100);
        }
    }

    #[test]
    fn ragged_edges_all_libraries() {
        for id in KernelRegistry::builtin().ids() {
            check_against_naive(&id, 13, 7, 9, 200);
        }
    }

    #[test]
    fn tall_skinny_and_wide() {
        check_against_naive("blis-lmul4", 40, 3, 5, 300);
        check_against_naive("openblas-c920", 3, 40, 5, 301);
        check_against_naive("openblas-generic", 5, 3, 40, 302);
    }

    #[test]
    fn shape_mismatch_is_error() {
        let l = lib("blis-lmul4");
        let a = Matrix::zeros(4, 4);
        let b = Matrix::zeros(5, 4);
        let mut c = Matrix::zeros(4, 4);
        assert!(gemm_acc(&l, &mut c, &a, &b).is_err());
    }

    #[test]
    fn property_random_shapes_blis_lmul4() {
        prop::check(
            "blocked gemm == naive gemm",
            0xB11,
            12,
            |rng: &mut Rng, size: usize| {
                let s = size.max(1).min(20);
                (
                    rng.range_usize(1, 3 * s + 2),
                    rng.range_usize(1, 3 * s + 2),
                    rng.range_usize(1, 3 * s + 2),
                    rng.next_u64(),
                )
            },
            |&(m, n, k, seed)| {
                let l = lib("blis-lmul4");
                let a = Matrix::random_hpl(m, k, seed);
                let b = Matrix::random_hpl(k, n, seed ^ 1);
                let mut c = Matrix::random_hpl(m, n, seed ^ 2);
                let mut want = c.clone();
                gemm_acc(&l, &mut c, &a, &b).map_err(|e| e.to_string())?;
                Matrix::gemm_acc(&mut want, &a, &b);
                if c.allclose(&want, 1e-10, 1e-10) {
                    Ok(())
                } else {
                    Err(format!("mismatch at {m}x{n}x{k}"))
                }
            },
        );
    }
}
