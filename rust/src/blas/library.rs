//! A BLAS library instance: one registered micro-kernel descriptor
//! paired with the blocking its policy derives for a concrete socket.

use std::sync::Arc;

use super::blocking::Blocking;
use crate::arch::soc::Socket;
use crate::ukernel::{BlockingPolicy, KernelDescriptor};

/// A BLAS library = micro-kernel descriptor + derived blocking.
pub struct BlasLibrary {
    pub kernel: Arc<KernelDescriptor>,
    pub blocking: Blocking,
}

impl BlasLibrary {
    /// Instantiate a library for a given socket; the blocking follows
    /// the descriptor's policy (BLIS derives analytically from the
    /// cache hierarchy, OpenBLAS ships fixed x86-tuned parameters).
    pub fn for_socket(kernel: Arc<KernelDescriptor>, socket: &Socket) -> BlasLibrary {
        let (mr, nr) = kernel.tile();
        let blocking = match kernel.blocking {
            BlockingPolicy::CacheDerived => Blocking::blis_for(socket, mr, nr),
            BlockingPolicy::Fixed => Blocking::openblas_fixed(mr, nr),
        };
        BlasLibrary { kernel, blocking }
    }

    pub fn label(&self) -> &str {
        &self.kernel.label
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::ukernel::KernelRegistry;

    #[test]
    fn blis_and_openblas_blockings_differ() {
        let reg = KernelRegistry::builtin();
        let s = &presets::sg2042().sockets[0];
        let blis = BlasLibrary::for_socket(reg.get("blis-lmul4").unwrap(), s);
        let ob = BlasLibrary::for_socket(reg.get("openblas-c920").unwrap(), s);
        assert_ne!(blis.blocking, ob.blocking);
        // the Fig-6 premise: BLIS's working set fits the per-cluster L2
        let l2_share = s.l2.size_bytes / s.l2.shared_by;
        assert!(blis.blocking.working_sets().1 <= l2_share);
        assert!(ob.blocking.working_sets().1 > l2_share);
    }

    #[test]
    fn tiles_match_kernels() {
        let reg = KernelRegistry::builtin();
        let s = &presets::sg2042().sockets[0];
        for k in reg.kernels() {
            let lib = BlasLibrary::for_socket(Arc::clone(k), s);
            let (mr, nr) = lib.kernel.tile();
            assert_eq!((lib.blocking.mr, lib.blocking.nr), (mr, nr));
        }
    }
}
