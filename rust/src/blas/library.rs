//! The four BLAS libraries of the paper's evaluation, as (micro-kernel,
//! blocking) pairs with a uniform interface.

use super::blocking::Blocking;
use crate::arch::soc::Socket;
use crate::ukernel::{MicroKernel, UkernelId};

/// A BLAS library = micro-kernel + blocking policy + metadata.
pub struct BlasLibrary {
    pub id: UkernelId,
    pub kernel: Box<dyn MicroKernel>,
    pub blocking: Blocking,
}

impl BlasLibrary {
    /// Instantiate a library for a given socket (blocking derives from the
    /// cache geometry for BLIS, is fixed for OpenBLAS).
    pub fn for_socket(id: UkernelId, socket: &Socket) -> BlasLibrary {
        let kernel = id.build();
        let (mr, nr) = kernel.tile();
        let blocking = match id {
            // BLIS derives blocking analytically from the cache hierarchy
            UkernelId::BlisLmul1 | UkernelId::BlisLmul4 => Blocking::blis_for(socket, mr, nr),
            // OpenBLAS ships fixed parameters tuned elsewhere
            UkernelId::OpenblasGeneric | UkernelId::OpenblasC920 => {
                Blocking::openblas_fixed(mr, nr)
            }
        };
        BlasLibrary { id, kernel, blocking }
    }

    pub fn label(&self) -> &'static str {
        self.id.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn blis_and_openblas_blockings_differ() {
        let s = &presets::sg2042().sockets[0];
        let blis = BlasLibrary::for_socket(UkernelId::BlisLmul4, s);
        let ob = BlasLibrary::for_socket(UkernelId::OpenblasC920, s);
        assert_ne!(blis.blocking, ob.blocking);
        // the Fig-6 premise: BLIS's working set fits the per-cluster L2
        let l2_share = s.l2.size_bytes / s.l2.shared_by;
        assert!(blis.blocking.working_sets().1 <= l2_share);
        assert!(ob.blocking.working_sets().1 > l2_share);
    }

    #[test]
    fn tiles_match_kernels() {
        let s = &presets::sg2042().sockets[0];
        for id in UkernelId::all() {
            let lib = BlasLibrary::for_socket(id, s);
            let (mr, nr) = lib.kernel.tile();
            assert_eq!((lib.blocking.mr, lib.blocking.nr), (mr, nr));
        }
    }
}
