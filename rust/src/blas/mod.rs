//! BLAS-library substrate: blocked GEMM over the micro-kernels, BLIS-style
//! cache-blocking derivation, the calibrated machine-performance model,
//! and the BLAS call-trace recorder the cache simulator consumes.

pub mod blocking;
pub mod gemm;
pub mod library;
pub mod perf;
pub mod trace;

pub use blocking::Blocking;
pub use library::BlasLibrary;
pub use perf::PerfModel;
