//! Calibrated node-level DGEMM/HPL performance model.
//!
//! Composition (DESIGN.md section 5 'Calibration constants'):
//!
//! 1. **Per-core rate** — the ISA cycle model's effective GFLOP/s for the
//!    library's micro-kernel ([`crate::ukernel::analysis`]).
//! 2. **SMP friction** — SoC-wide scaling loss (mesh/L3/controller
//!    serialization): `1 / (1 + alpha*(n-1))`, library-independent. At 64
//!    cores the SG2042 calibration gives 0.888 — the "both of them
//!    experience a degradation" observation under Fig 4.
//! 3. **Bandwidth contention** — when the library's aggregate DRAM demand
//!    (rate x traffic-per-flop x cores) exceeds the socket's attainable
//!    STREAM bandwidth, a hyperbolic penalty kicks in:
//!    `1 / (1 + gamma * excess_ratio)`. Fast vector kernels (OpenBLAS-opt,
//!    BLIS-opt) cross this knee near 48 cores; slow ones never do — which
//!    is exactly why the generic/optimized efficiency ratio *rises* from
//!    0.68 to 0.89 across Fig 4.
//! 4. **NUMA penalty** — multiplied once when a job spans two sockets
//!    (0.88, giving the paper's 1.76x dual/single ratio).
//!
//! All three constants live in the platform's [`PerfCalib`] — the model
//! itself is platform- AND kernel-agnostic: any registered
//! [`KernelDescriptor`] models on any registered platform.

use std::sync::Arc;

use crate::arch::platform::{PerfCalib, Platform};
use crate::arch::soc::SocDescriptor;
use crate::error::CimoneError;
use crate::ukernel::analysis;
use crate::ukernel::{KernelDescriptor, KernelRegistry};

/// Node-level performance model for one library on one platform.
pub struct PerfModel<'a> {
    pub desc: &'a SocDescriptor,
    pub calib: PerfCalib,
    pub lib: Arc<KernelDescriptor>,
    /// Per-core effective DGEMM GFLOP/s at 1 core (cycle model output).
    pub per_core_gflops: f64,
}

impl<'a> PerfModel<'a> {
    pub fn new(platform: &'a Platform, lib: Arc<KernelDescriptor>) -> Self {
        let core = &platform.desc.sockets[0].core;
        let per_core_gflops = analysis::analyze(&lib, core).effective_gflops;
        PerfModel { desc: &platform.desc, calib: platform.calib, lib, per_core_gflops }
    }

    /// [`PerfModel::new`] with the kernel resolved from the *built-in*
    /// registry by id or alias (typed [`CimoneError::UnknownKernel`]
    /// otherwise). Campaign paths resolve against their own registry —
    /// custom `[[kernel]]` sections included — and use `new` directly.
    pub fn by_id(platform: &'a Platform, lib: &str) -> Result<Self, CimoneError> {
        Ok(PerfModel::new(platform, KernelRegistry::builtin().get(lib)?))
    }

    /// Combined scaling factor at `n` active cores on one socket.
    pub fn sigma(&self, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let base = 1.0 / (1.0 + self.calib.smp_alpha * (n as f64 - 1.0));
        let socket = &self.desc.sockets[0];
        let bw = socket.mem.attainable_bw();
        let demand = self.per_core_gflops * 1e9 * self.calib.traffic_bytes_per_flop * n as f64;
        let excess = ((demand - bw) / bw).max(0.0);
        base / (1.0 + self.calib.bw_gamma * excess)
    }

    /// HPL GFLOP/s of this node with `cores` active, pinned symmetrically
    /// across sockets (the paper's configuration).
    pub fn node_gflops(&self, cores: usize) -> f64 {
        let total = self.desc.total_cores();
        let cores = cores.min(total);
        if cores == 0 {
            return 0.0;
        }
        let per_socket_cap = self.desc.sockets[0].cores;
        let sockets_used = if cores <= per_socket_cap { 1 } else { self.desc.sockets.len() };
        let n_s = cores / sockets_used;
        let rem = cores % sockets_used;
        let mut gf = 0.0;
        for s in 0..sockets_used {
            let n = n_s + if s < rem { 1 } else { 0 };
            gf += n as f64 * self.per_core_gflops * self.sigma(n);
        }
        if sockets_used > 1 {
            gf *= self.desc.numa_penalty;
        }
        gf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::platform::{mcv1_u740, mcv2_dual, mcv2_pioneer, mcv3, sg2044};

    #[test]
    fn fig4_one_core_rates() {
        let d = mcv2_pioneer();
        let opt = PerfModel::by_id(&d, "openblas-c920").unwrap().node_gflops(1);
        let gen = PerfModel::by_id(&d, "openblas-generic").unwrap().node_gflops(1);
        assert!((2.9..3.5).contains(&opt), "opt 1-core {opt:.2}");
        let ratio = gen / opt;
        assert!((0.60..0.76).contains(&ratio), "generic/opt @1 core {ratio:.3}");
    }

    #[test]
    fn fig4_sixty_four_core_node() {
        // paper: MCv2 single-socket HPL ~ 244.9/1.76 ~ 139 Gflop/s
        let d = mcv2_pioneer();
        let opt = PerfModel::by_id(&d, "openblas-c920").unwrap().node_gflops(64);
        assert!((125.0..155.0).contains(&opt), "64-core optimized {opt:.1}");
        // "which increases to 89% of the optimized one"
        let gen = PerfModel::by_id(&d, "openblas-generic").unwrap().node_gflops(64);
        let ratio = gen / opt;
        assert!((0.82..0.95).contains(&ratio), "generic/opt @64 {ratio:.3}");
    }

    #[test]
    fn fig4_relative_degradation_at_full_cores() {
        // both libraries lose per-core efficiency at 64 cores
        for id in ["openblas-c920", "openblas-generic"] {
            let d = mcv2_pioneer();
            let m = PerfModel::by_id(&d, id).unwrap();
            let eff64 = m.node_gflops(64) / 64.0;
            let eff1 = m.node_gflops(1);
            assert!(eff64 < 0.92 * eff1, "{id}: {eff64:.2} vs {eff1:.2}");
        }
    }

    #[test]
    fn fig7_128_core_numbers() {
        // paper: OpenBLAS-opt 244.9, BLIS-vanilla 165.0, BLIS-opt 245.8
        let d = mcv2_dual();
        let ob = PerfModel::by_id(&d, "openblas-c920").unwrap().node_gflops(128);
        let bv = PerfModel::by_id(&d, "blis-lmul1").unwrap().node_gflops(128);
        let bo = PerfModel::by_id(&d, "blis-lmul4").unwrap().node_gflops(128);
        assert!((225.0..265.0).contains(&ob), "openblas-opt {ob:.1}");
        assert!((150.0..180.0).contains(&bv), "blis-vanilla {bv:.1}");
        assert!((225.0..265.0).contains(&bo), "blis-opt {bo:.1}");
        // the headline: +49% over baseline BLIS
        let improvement = bo / bv - 1.0;
        assert!((0.35..0.60).contains(&improvement), "improvement {improvement:.2}");
        // and parity-or-better vs OpenBLAS
        assert!(bo > 0.97 * ob, "bo={bo:.1} ob={ob:.1}");
    }

    #[test]
    fn fig5_dual_socket_ratio() {
        // paper: dual-socket node = 1.76x single-socket node
        let d1 = mcv2_pioneer();
        let d2 = mcv2_dual();
        let s = PerfModel::by_id(&d1, "openblas-c920").unwrap().node_gflops(64);
        let d = PerfModel::by_id(&d2, "openblas-c920").unwrap().node_gflops(128);
        let ratio = d / s;
        assert!((1.70..1.82).contains(&ratio), "dual/single {ratio:.3}");
    }

    #[test]
    fn headline_127x_over_mcv1() {
        // paper abstract: "127x on HPL DP FLOP/s" node-vs-node
        let v1 = mcv1_u740();
        let v2 = mcv2_dual();
        let old = PerfModel::by_id(&v1, "openblas-generic").unwrap().node_gflops(4);
        let new = PerfModel::by_id(&v2, "openblas-c920").unwrap().node_gflops(128);
        let ratio = new / old;
        assert!((100.0..160.0).contains(&ratio), "HPL uplift {ratio:.0}x (old={old:.2})");
    }

    #[test]
    fn mcv1_node_matches_cluster_math() {
        // 8 MCv1 nodes reached ~13 Gflop/s => ~1.6 per node
        let v1 = mcv1_u740();
        let node = PerfModel::by_id(&v1, "openblas-generic").unwrap().node_gflops(4);
        assert!((1.3..2.0).contains(&node), "MCv1 node {node:.2}");
    }

    #[test]
    fn sg2044_node_beats_sg2042_node() {
        // arXiv 2508.13840: the C920v2 at 2.6 GHz with DDR5 clears the
        // SG2042 on HPL at every core count
        let old = mcv2_pioneer();
        let new = sg2044();
        for cores in [1usize, 16, 64] {
            let o = PerfModel::by_id(&old, "openblas-c920").unwrap().node_gflops(cores);
            let n = PerfModel::by_id(&new, "openblas-c920").unwrap().node_gflops(cores);
            assert!(n.is_finite() && n > o, "at {cores} cores: sg2044 {n:.1} vs sg2042 {o:.1}");
        }
        // and the MCv3 dual-socket projection clears the SR1
        let d_old = PerfModel::by_id(&mcv2_dual(), "openblas-c920").unwrap().node_gflops(128);
        let d_new = PerfModel::by_id(&mcv3(), "openblas-c920").unwrap().node_gflops(128);
        assert!(d_new > d_old, "mcv3 {d_new:.1} vs mcv2-dual {d_old:.1}");
    }

    #[test]
    fn native_kernel_is_the_sg2044_node_winner() {
        // the blas-tuning premise at node level: the native RVV 1.0
        // tuning point clears every 0.7.1-era kernel on the C920v2
        let p = sg2044();
        let native = PerfModel::by_id(&p, "blis-rvv1-lmul2").unwrap().node_gflops(64);
        for other in ["openblas-c920", "blis-lmul1", "blis-lmul4", "openblas-generic"] {
            let o = PerfModel::by_id(&p, other).unwrap().node_gflops(64);
            assert!(native > o, "{other}: {o:.1} !< native {native:.1}");
        }
        // while the SG2042's LMUL=4 > LMUL=1 ordering stays the paper's
        let old = mcv2_pioneer();
        let v1 = PerfModel::by_id(&old, "blis-lmul1").unwrap().node_gflops(64);
        let v4 = PerfModel::by_id(&old, "blis-lmul4").unwrap().node_gflops(64);
        assert!(v4 > 1.3 * v1, "{v4:.1} vs {v1:.1}");
    }

    #[test]
    fn unknown_kernel_id_is_typed() {
        let d = mcv2_pioneer();
        assert!(matches!(
            PerfModel::by_id(&d, "mkl"),
            Err(CimoneError::UnknownKernel { ref name, .. }) if name == "mkl"
        ));
    }

    #[test]
    fn sigma_monotone_nonincreasing() {
        let d = mcv2_pioneer();
        let m = PerfModel::by_id(&d, "openblas-c920").unwrap();
        let mut last = f64::INFINITY;
        for n in [1, 2, 4, 8, 16, 32, 48, 64] {
            let s = m.sigma(n);
            assert!(s <= last + 1e-12, "sigma not monotone at {n}");
            assert!(s > 0.0 && s <= 1.0);
            last = s;
        }
    }

    #[test]
    fn zero_cores_zero_gflops() {
        let d = mcv2_pioneer();
        let m = PerfModel::by_id(&d, "blis-lmul4").unwrap();
        assert_eq!(m.node_gflops(0), 0.0);
        assert_eq!(m.sigma(0), 0.0);
    }

    #[test]
    fn cores_clamped_to_node() {
        let d = mcv2_pioneer();
        let m = PerfModel::by_id(&d, "blis-lmul4").unwrap();
        assert_eq!(m.node_gflops(64), m.node_gflops(9999));
    }
}
