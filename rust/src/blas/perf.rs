//! Calibrated node-level DGEMM/HPL performance model.
//!
//! Composition (DESIGN.md section 5 'Calibration constants'):
//!
//! 1. **Per-core rate** — the ISA cycle model's effective GFLOP/s for the
//!    library's micro-kernel ([`crate::ukernel::analysis`]).
//! 2. **SMP friction** — SoC-wide scaling loss (mesh/L3/controller
//!    serialization): `1 / (1 + ALPHA*(n-1))`, library-independent. At 64
//!    cores this is 0.888 — the "both of them experience a degradation"
//!    observation under Fig 4.
//! 3. **Bandwidth contention** — when the library's aggregate DRAM demand
//!    (rate x traffic-per-flop x cores) exceeds the socket's attainable
//!    STREAM bandwidth, a hyperbolic penalty kicks in:
//!    `1 / (1 + GAMMA * excess_ratio)`. Fast vector kernels (OpenBLAS-opt,
//!    BLIS-opt) cross this knee near 48 cores; slow ones never do — which
//!    is exactly why the generic/optimized efficiency ratio *rises* from
//!    0.68 to 0.89 across Fig 4.
//! 4. **NUMA penalty** — multiplied once when a job spans two sockets
//!    (0.88, giving the paper's 1.76x dual/single ratio).

use crate::arch::soc::{NodeKind, SocDescriptor};
use crate::ukernel::analysis;
use crate::ukernel::UkernelId;

/// SoC-wide SMP scaling friction (per additional core).
pub const SMP_ALPHA: f64 = 0.002;
/// Steepness of the bandwidth-contention penalty.
pub const BW_GAMMA: f64 = 1.375;

/// Effective DGEMM DRAM traffic per FLOP (bytes), per node family.
/// Calibrated: the SG2042 at HPL block sizes moves ~0.25 B/flop; the U740's
/// tiny L2 and absent L3 force ~0.6 B/flop (see EXPERIMENTS.md
/// 'Calibration').
pub fn traffic_bytes_per_flop(kind: NodeKind) -> f64 {
    match kind {
        NodeKind::Mcv1U740 => 0.60,
        NodeKind::Mcv2Pioneer | NodeKind::Mcv2DualSocket => 0.25,
    }
}

/// Node-level performance model for one library on one node type.
pub struct PerfModel<'a> {
    pub desc: &'a SocDescriptor,
    pub lib: UkernelId,
    /// Per-core effective DGEMM GFLOP/s at 1 core (cycle model output).
    pub per_core_gflops: f64,
}

impl<'a> PerfModel<'a> {
    pub fn new(desc: &'a SocDescriptor, lib: UkernelId) -> Self {
        let core = &desc.sockets[0].core;
        let per_core_gflops = analysis::analyze(lib, core).effective_gflops;
        PerfModel { desc, lib, per_core_gflops }
    }

    /// Combined scaling factor at `n` active cores on one socket.
    pub fn sigma(&self, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let base = 1.0 / (1.0 + SMP_ALPHA * (n as f64 - 1.0));
        let socket = &self.desc.sockets[0];
        let bw = socket.mem.attainable_bw();
        let demand =
            self.per_core_gflops * 1e9 * traffic_bytes_per_flop(self.desc.kind) * n as f64;
        let excess = ((demand - bw) / bw).max(0.0);
        base / (1.0 + BW_GAMMA * excess)
    }

    /// HPL GFLOP/s of this node with `cores` active, pinned symmetrically
    /// across sockets (the paper's configuration).
    pub fn node_gflops(&self, cores: usize) -> f64 {
        let total = self.desc.total_cores();
        let cores = cores.min(total);
        if cores == 0 {
            return 0.0;
        }
        let per_socket_cap = self.desc.sockets[0].cores;
        let sockets_used = if cores <= per_socket_cap { 1 } else { self.desc.sockets.len() };
        let n_s = cores / sockets_used;
        let rem = cores % sockets_used;
        let mut gf = 0.0;
        for s in 0..sockets_used {
            let n = n_s + if s < rem { 1 } else { 0 };
            gf += n as f64 * self.per_core_gflops * self.sigma(n);
        }
        if sockets_used > 1 {
            gf *= self.desc.numa_penalty;
        }
        gf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{sg2042, sg2042_dual, u740};

    #[test]
    fn fig4_one_core_rates() {
        let d = sg2042();
        let opt = PerfModel::new(&d, UkernelId::OpenblasC920).node_gflops(1);
        let gen = PerfModel::new(&d, UkernelId::OpenblasGeneric).node_gflops(1);
        assert!((2.9..3.5).contains(&opt), "opt 1-core {opt:.2}");
        let ratio = gen / opt;
        assert!((0.60..0.76).contains(&ratio), "generic/opt @1 core {ratio:.3}");
    }

    #[test]
    fn fig4_sixty_four_core_node() {
        // paper: MCv2 single-socket HPL ~ 244.9/1.76 ~ 139 Gflop/s
        let d = sg2042();
        let opt = PerfModel::new(&d, UkernelId::OpenblasC920).node_gflops(64);
        assert!((125.0..155.0).contains(&opt), "64-core optimized {opt:.1}");
        // "which increases to 89% of the optimized one"
        let gen = PerfModel::new(&d, UkernelId::OpenblasGeneric).node_gflops(64);
        let ratio = gen / opt;
        assert!((0.82..0.95).contains(&ratio), "generic/opt @64 {ratio:.3}");
    }

    #[test]
    fn fig4_relative_degradation_at_full_cores() {
        // both libraries lose per-core efficiency at 64 cores
        for id in [UkernelId::OpenblasC920, UkernelId::OpenblasGeneric] {
            let d = sg2042();
            let m = PerfModel::new(&d, id);
            let eff64 = m.node_gflops(64) / 64.0;
            let eff1 = m.node_gflops(1);
            assert!(eff64 < 0.92 * eff1, "{id:?}: {eff64:.2} vs {eff1:.2}");
        }
    }

    #[test]
    fn fig7_128_core_numbers() {
        // paper: OpenBLAS-opt 244.9, BLIS-vanilla 165.0, BLIS-opt 245.8
        let d = sg2042_dual();
        let ob = PerfModel::new(&d, UkernelId::OpenblasC920).node_gflops(128);
        let bv = PerfModel::new(&d, UkernelId::BlisLmul1).node_gflops(128);
        let bo = PerfModel::new(&d, UkernelId::BlisLmul4).node_gflops(128);
        assert!((225.0..265.0).contains(&ob), "openblas-opt {ob:.1}");
        assert!((150.0..180.0).contains(&bv), "blis-vanilla {bv:.1}");
        assert!((225.0..265.0).contains(&bo), "blis-opt {bo:.1}");
        // the headline: +49% over baseline BLIS
        let improvement = bo / bv - 1.0;
        assert!((0.35..0.60).contains(&improvement), "improvement {improvement:.2}");
        // and parity-or-better vs OpenBLAS
        assert!(bo > 0.97 * ob, "bo={bo:.1} ob={ob:.1}");
    }

    #[test]
    fn fig5_dual_socket_ratio() {
        // paper: dual-socket node = 1.76x single-socket node
        let d1 = sg2042();
        let d2 = sg2042_dual();
        let s = PerfModel::new(&d1, UkernelId::OpenblasC920).node_gflops(64);
        let d = PerfModel::new(&d2, UkernelId::OpenblasC920).node_gflops(128);
        let ratio = d / s;
        assert!((1.70..1.82).contains(&ratio), "dual/single {ratio:.3}");
    }

    #[test]
    fn headline_127x_over_mcv1() {
        // paper abstract: "127x on HPL DP FLOP/s" node-vs-node
        let v1 = u740();
        let v2 = sg2042_dual();
        let old = PerfModel::new(&v1, UkernelId::OpenblasGeneric).node_gflops(4);
        let new = PerfModel::new(&v2, UkernelId::OpenblasC920).node_gflops(128);
        let ratio = new / old;
        assert!((100.0..160.0).contains(&ratio), "HPL uplift {ratio:.0}x (old={old:.2})");
    }

    #[test]
    fn mcv1_node_matches_cluster_math() {
        // 8 MCv1 nodes reached ~13 Gflop/s => ~1.6 per node
        let v1 = u740();
        let node = PerfModel::new(&v1, UkernelId::OpenblasGeneric).node_gflops(4);
        assert!((1.3..2.0).contains(&node), "MCv1 node {node:.2}");
    }

    #[test]
    fn sigma_monotone_nonincreasing() {
        let d = sg2042();
        let m = PerfModel::new(&d, UkernelId::OpenblasC920);
        let mut last = f64::INFINITY;
        for n in [1, 2, 4, 8, 16, 32, 48, 64] {
            let s = m.sigma(n);
            assert!(s <= last + 1e-12, "sigma not monotone at {n}");
            assert!(s > 0.0 && s <= 1.0);
            last = s;
        }
    }

    #[test]
    fn zero_cores_zero_gflops() {
        let d = sg2042();
        let m = PerfModel::new(&d, UkernelId::BlisLmul4);
        assert_eq!(m.node_gflops(0), 0.0);
        assert_eq!(m.sigma(0), 0.0);
    }

    #[test]
    fn cores_clamped_to_node() {
        let d = sg2042();
        let m = PerfModel::new(&d, UkernelId::BlisLmul4);
        assert_eq!(m.node_gflops(64), m.node_gflops(9999));
    }
}
