//! BLAS call-trace recorder: captures the sequence of (level-3) BLAS calls
//! an HPL factorization issues, so the cache simulator can replay the
//! *actual* loop nests with the *actual* shapes.

/// One recorded BLAS call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BlasCall {
    /// dgemm: C(m x n) -= A(m x k) * B(k x n)
    Dgemm { m: usize, n: usize, k: usize },
    /// dtrsm: solve L(nb x nb) X = B(nb x n)
    Dtrsm { nb: usize, n: usize },
    /// dger-ish panel rank-1 update inside the panel factorization
    PanelUpdate { rows: usize, cols: usize },
}

impl BlasCall {
    pub fn flops(&self) -> f64 {
        match *self {
            BlasCall::Dgemm { m, n, k } => 2.0 * m as f64 * n as f64 * k as f64,
            BlasCall::Dtrsm { nb, n } => nb as f64 * nb as f64 * n as f64,
            BlasCall::PanelUpdate { rows, cols } => 2.0 * rows as f64 * cols as f64,
        }
    }
}

/// Accumulates calls; exposes mix statistics.
#[derive(Debug, Default, Clone)]
pub struct CallTrace {
    pub calls: Vec<BlasCall>,
}

impl CallTrace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, call: BlasCall) {
        self.calls.push(call);
    }

    pub fn total_flops(&self) -> f64 {
        self.calls.iter().map(|c| c.flops()).sum()
    }

    /// Fraction of FLOPs spent in DGEMM — HPL is >90% DGEMM at sane block
    /// sizes, the premise of the paper's whole methodology.
    pub fn dgemm_fraction(&self) -> f64 {
        let total = self.total_flops();
        if total == 0.0 {
            return 0.0;
        }
        let dgemm: f64 = self
            .calls
            .iter()
            .filter(|c| matches!(c, BlasCall::Dgemm { .. }))
            .map(|c| c.flops())
            .sum();
        dgemm / total
    }

    /// Largest DGEMM in the trace (the representative shape for cache sim).
    pub fn largest_dgemm(&self) -> Option<BlasCall> {
        self.calls
            .iter()
            .filter(|c| matches!(c, BlasCall::Dgemm { .. }))
            .copied()
            .max_by(|a, b| a.flops().total_cmp(&b.flops()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_formulas() {
        assert_eq!(BlasCall::Dgemm { m: 10, n: 10, k: 10 }.flops(), 2000.0);
        assert_eq!(BlasCall::Dtrsm { nb: 4, n: 10 }.flops(), 160.0);
        assert_eq!(BlasCall::PanelUpdate { rows: 8, cols: 4 }.flops(), 64.0);
    }

    #[test]
    fn dgemm_fraction_of_mixed_trace() {
        let mut t = CallTrace::new();
        t.record(BlasCall::Dgemm { m: 100, n: 100, k: 100 }); // 2e6
        t.record(BlasCall::Dtrsm { nb: 10, n: 100 }); // 1e4
        let f = t.dgemm_fraction();
        assert!(f > 0.99, "{f}");
    }

    #[test]
    fn empty_trace_fraction_zero() {
        assert_eq!(CallTrace::new().dgemm_fraction(), 0.0);
    }

    #[test]
    fn largest_dgemm_found() {
        let mut t = CallTrace::new();
        t.record(BlasCall::Dgemm { m: 10, n: 10, k: 10 });
        t.record(BlasCall::Dgemm { m: 50, n: 50, k: 10 });
        t.record(BlasCall::Dtrsm { nb: 99, n: 999 });
        match t.largest_dgemm().unwrap() {
            BlasCall::Dgemm { m: 50, .. } => {}
            other => panic!("{other:?}"),
        }
    }
}
