//! Multi-core L1/L2/L3 composition matching the SG2042 topology:
//! private L1D per core, L2 shared per 4-core cluster, chip-wide L3.

use super::set_assoc::SetAssocCache;
use super::stats::LevelStats;
use crate::arch::soc::Socket;

/// The cache hierarchy for `cores` active cores of one socket.
pub struct MultiCoreHierarchy {
    l1: Vec<SetAssocCache>,
    l2: Vec<SetAssocCache>,
    l3: Option<SetAssocCache>,
    l2_shared_by: usize,
    /// Range touches go through the interval engine
    /// ([`SetAssocCache::access_line_run`]) instead of the per-access
    /// reference loop. Both paths are counter-identical by construction
    /// (property-tested below); the reference stays for verification.
    interval: bool,
    /// Arena-reused L1 miss buffer for the interval path — range touches
    /// never allocate per access.
    miss_scratch: Vec<u64>,
}

impl MultiCoreHierarchy {
    pub fn new(socket: &Socket, cores: usize) -> Self {
        Self::with_engine(socket, cores, true)
    }

    /// Build with the range engine chosen explicitly: `interval = false`
    /// replays ranges through the retained per-access reference path.
    pub fn with_engine(socket: &Socket, cores: usize, interval: bool) -> Self {
        assert!(cores >= 1 && cores <= socket.cores);
        let n_l2 = cores.div_ceil(socket.l2.shared_by);
        MultiCoreHierarchy {
            l1: (0..cores).map(|_| SetAssocCache::new(socket.l1d)).collect(),
            l2: (0..n_l2).map(|_| SetAssocCache::new(socket.l2)).collect(),
            l3: socket.l3.map(SetAssocCache::new),
            l2_shared_by: socket.l2.shared_by,
            interval,
            miss_scratch: Vec::new(),
        }
    }

    pub fn cores(&self) -> usize {
        self.l1.len()
    }

    /// One memory access by `core` at byte address `addr`. Misses propagate
    /// down the hierarchy.
    pub fn access(&mut self, core: usize, addr: u64) {
        self.access_block(core, addr, 1);
    }

    /// `elem_count` element accesses coalesced into the line at `addr`.
    pub fn access_block(&mut self, core: usize, addr: u64, elem_count: u64) {
        if self.l1[core].access_block(addr, elem_count) {
            return;
        }
        let l2_idx = core / self.l2_shared_by;
        if self.l2[l2_idx].access(addr) {
            return;
        }
        if let Some(l3) = &mut self.l3 {
            l3.access(addr);
        }
    }

    /// A contiguous element range [lo, hi) in bytes: touch each line once
    /// with the element count it covers. Dispatches to the interval
    /// engine (the default) or the per-access reference loop.
    pub fn access_range(&mut self, core: usize, lo: u64, hi: u64) {
        if self.interval {
            self.access_range_interval(core, lo, hi);
        } else {
            self.access_range_per_access(core, lo, hi);
        }
    }

    /// The retained per-access reference path: one `access_block` per
    /// line. The interval engine is property-tested bit-identical to
    /// this loop; it is also what `cimone bench` times the engine
    /// against.
    pub fn access_range_per_access(&mut self, core: usize, lo: u64, hi: u64) {
        const LINE: u64 = 64;
        const ELEM: u64 = 8;
        let mut a = lo & !(LINE - 1);
        while a < hi {
            let seg_lo = a.max(lo);
            let seg_hi = (a + LINE).min(hi);
            let elems = (seg_hi - seg_lo).div_ceil(ELEM).max(1);
            self.access_block(core, a, elems);
            a += LINE;
        }
    }

    /// The interval path: resolve the whole line run against the core's
    /// L1 in one `access_line_run` call, weight the retired-load counter
    /// once for the range (the edge lines cover fewer elements than the
    /// interior's eight), then replay the missed lines — sorted back
    /// into reference order — down L2/L3 exactly as the per-line loop
    /// would have.
    fn access_range_interval(&mut self, core: usize, lo: u64, hi: u64) {
        const LINE: u64 = 64;
        const ELEM: u64 = 8;
        if hi <= lo {
            return;
        }
        let lo_line = lo / LINE;
        let hi_line = (hi - 1) / LINE + 1;
        let run_len = hi_line - lo_line;
        // retired loads per line: interior lines cover LINE/ELEM
        // elements, the edges only their covered fraction
        let total_elems = if run_len == 1 {
            (hi - lo).div_ceil(ELEM).max(1)
        } else {
            let first = ((lo_line + 1) * LINE - lo).div_ceil(ELEM).max(1);
            let last = (hi - (hi_line - 1) * LINE).div_ceil(ELEM).max(1);
            first + last + (run_len - 2) * (LINE / ELEM)
        };
        let mut misses = std::mem::take(&mut self.miss_scratch);
        misses.clear();
        let l1 = &mut self.l1[core];
        l1.access_line_run(lo_line, hi_line, &mut misses);
        l1.accesses += total_elems - run_len;
        // per-set resolution emits misses out of order; the next level
        // must see them in ascending (reference) order
        misses.sort_unstable();
        let l2 = &mut self.l2[core / self.l2_shared_by];
        for &line in &misses {
            if !l2.access(line * LINE) {
                if let Some(l3) = &mut self.l3 {
                    l3.access(line * LINE);
                }
            }
        }
        self.miss_scratch = misses;
    }

    /// Aggregate stats per level.
    pub fn stats(&self) -> LevelStats {
        let sum = |cs: &[SetAssocCache]| {
            let a: u64 = cs.iter().map(|c| c.accesses).sum();
            let m: u64 = cs.iter().map(|c| c.misses).sum();
            (a, m)
        };
        let (l1a, l1m) = sum(&self.l1);
        let (l2a, l2m) = sum(&self.l2);
        let (l3a, l3m) = self.l3.as_ref().map(|c| (c.accesses, c.misses)).unwrap_or((0, 0));
        LevelStats { l1_accesses: l1a, l1_misses: l1m, l2_accesses: l2a, l2_misses: l2m, l3_accesses: l3a, l3_misses: l3m }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::util::prop;
    use crate::util::Rng;

    #[test]
    fn property_inclusion_counting_invariants() {
        // for any access stream: L2 accesses == L1 misses, L3 accesses ==
        // L2 misses, and per-level misses <= accesses
        prop::check(
            "hierarchy counting invariants",
            0xCAFE,
            30,
            |rng: &mut Rng, size: usize| {
                let n = 50 + size * 40;
                let cores = 1 + rng.below(8) as usize;
                let seed = rng.next_u64();
                (n, cores, seed)
            },
            |&(n, cores, seed)| {
                let s = &presets::sg2042().sockets[0];
                let mut h = MultiCoreHierarchy::new(s, cores);
                let mut rng = Rng::new(seed);
                for _ in 0..n {
                    let core = rng.below(cores as u64) as usize;
                    // mixed working set: hot region + cold streaming
                    let addr = if rng.below(2) == 0 {
                        rng.below(4096) * 8
                    } else {
                        rng.below(1 << 24) * 8
                    };
                    h.access(core, addr);
                }
                let st = h.stats();
                if st.l2_accesses != st.l1_misses {
                    return Err(format!("L2 acc {} != L1 miss {}", st.l2_accesses, st.l1_misses));
                }
                if st.l3_accesses != st.l2_misses {
                    return Err(format!("L3 acc {} != L2 miss {}", st.l3_accesses, st.l2_misses));
                }
                for (m, a) in [
                    (st.l1_misses, st.l1_accesses),
                    (st.l2_misses, st.l2_accesses),
                    (st.l3_misses, st.l3_accesses),
                ] {
                    if m > a {
                        return Err(format!("misses {m} > accesses {a}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_weighted_access_only_inflates_hits() {
        // access_block(addr, k) must change accesses by k but misses by
        // at most 1, for any k
        let s = &presets::sg2042().sockets[0];
        let mut h = MultiCoreHierarchy::new(s, 1);
        h.access_block(0, 0, 8);
        let st = h.stats();
        assert_eq!(st.l1_accesses, 8);
        assert_eq!(st.l1_misses, 1);
        h.access_block(0, 0, 100);
        let st = h.stats();
        assert_eq!(st.l1_accesses, 108);
        assert_eq!(st.l1_misses, 1);
    }

    #[test]
    fn topology_matches_sg2042() {
        let s = &presets::sg2042().sockets[0];
        let h = MultiCoreHierarchy::new(s, 8);
        assert_eq!(h.l1.len(), 8);
        assert_eq!(h.l2.len(), 2); // 8 cores / 4 per cluster
        assert!(h.l3.is_some());
    }

    #[test]
    fn private_l1_isolated_between_cores() {
        let s = &presets::sg2042().sockets[0];
        let mut h = MultiCoreHierarchy::new(s, 2);
        h.access(0, 0);
        h.access(0, 0); // hit in core 0's L1
        h.access(1, 0); // core 1 misses L1, hits L2 (same cluster)
        let st = h.stats();
        assert_eq!(st.l1_accesses, 3);
        assert_eq!(st.l1_misses, 2);
        assert_eq!(st.l2_accesses, 2);
        assert_eq!(st.l2_misses, 1);
    }

    #[test]
    fn cross_cluster_sharing_happens_in_l3() {
        let s = &presets::sg2042().sockets[0];
        let mut h = MultiCoreHierarchy::new(s, 8);
        h.access(0, 4096); // cluster 0: L1 miss, L2 miss, L3 miss
        h.access(7, 4096); // cluster 1: L1 miss, L2 miss, L3 HIT
        let st = h.stats();
        assert_eq!(st.l3_accesses, 2);
        assert_eq!(st.l3_misses, 1);
    }

    #[test]
    fn u740_has_no_l3() {
        let s = &presets::u740().sockets[0];
        let mut h = MultiCoreHierarchy::new(s, 4);
        h.access(0, 0);
        assert_eq!(h.stats().l3_accesses, 0);
    }

    #[test]
    fn property_interval_engine_is_bit_identical_to_per_access() {
        // seeded random [lo, hi) byte ranges over mixed hot/cold regions
        // and cores, replayed through the interval engine and the
        // retained per-access reference: LevelStats must be bit-equal
        // after every single range (not just at the end), on sockets
        // with and without an L3
        prop::check(
            "interval engine bit-identity",
            0xB10C,
            25,
            |rng: &mut Rng, size: usize| {
                let n = 30 + size * 25;
                let cores = 1 + rng.below(8) as usize;
                let seed = rng.next_u64();
                let with_l3 = rng.below(2) == 0;
                (n, cores, seed, with_l3)
            },
            |&(n, cores, seed, with_l3)| {
                let soc = if with_l3 { presets::sg2042() } else { presets::u740() };
                let s = &soc.sockets[0];
                let cores = cores.min(s.cores);
                let mut fast = MultiCoreHierarchy::with_engine(s, cores, true);
                let mut refr = MultiCoreHierarchy::with_engine(s, cores, false);
                let mut rng = Rng::new(seed);
                for i in 0..n {
                    let core = rng.below(cores as u64) as usize;
                    // hot reused region, cold streaming region, and the
                    // occasional giant run that sweeps every set
                    let lo = match rng.below(3) {
                        0 => rng.below(1 << 14),
                        1 => rng.below(1 << 26),
                        _ => rng.below(1 << 14) + (1 << 20),
                    };
                    let len = 1 + rng.below(64 * 400);
                    fast.access_range(core, lo, lo + len);
                    refr.access_range(core, lo, lo + len);
                    let (a, b) = (fast.stats(), refr.stats());
                    if a != b {
                        return Err(format!("range {i} [{lo}, {}): {a:?} != {b:?}", lo + len));
                    }
                }
                Ok(())
            },
        );
    }
}
