//! Multi-core L1/L2/L3 composition matching the SG2042 topology:
//! private L1D per core, L2 shared per 4-core cluster, chip-wide L3.

use super::set_assoc::SetAssocCache;
use super::stats::LevelStats;
use crate::arch::soc::Socket;

/// The cache hierarchy for `cores` active cores of one socket.
pub struct MultiCoreHierarchy {
    l1: Vec<SetAssocCache>,
    l2: Vec<SetAssocCache>,
    l3: Option<SetAssocCache>,
    l2_shared_by: usize,
}

impl MultiCoreHierarchy {
    pub fn new(socket: &Socket, cores: usize) -> Self {
        assert!(cores >= 1 && cores <= socket.cores);
        let n_l2 = cores.div_ceil(socket.l2.shared_by);
        MultiCoreHierarchy {
            l1: (0..cores).map(|_| SetAssocCache::new(socket.l1d)).collect(),
            l2: (0..n_l2).map(|_| SetAssocCache::new(socket.l2)).collect(),
            l3: socket.l3.map(SetAssocCache::new),
            l2_shared_by: socket.l2.shared_by,
        }
    }

    pub fn cores(&self) -> usize {
        self.l1.len()
    }

    /// One memory access by `core` at byte address `addr`. Misses propagate
    /// down the hierarchy.
    pub fn access(&mut self, core: usize, addr: u64) {
        self.access_block(core, addr, 1);
    }

    /// `elem_count` element accesses coalesced into the line at `addr`.
    pub fn access_block(&mut self, core: usize, addr: u64, elem_count: u64) {
        if self.l1[core].access_block(addr, elem_count) {
            return;
        }
        let l2_idx = core / self.l2_shared_by;
        if self.l2[l2_idx].access(addr) {
            return;
        }
        if let Some(l3) = &mut self.l3 {
            l3.access(addr);
        }
    }

    /// A contiguous element range [lo, hi) in bytes: touch each line once
    /// with the element count it covers.
    pub fn access_range(&mut self, core: usize, lo: u64, hi: u64) {
        const LINE: u64 = 64;
        const ELEM: u64 = 8;
        let mut a = lo & !(LINE - 1);
        while a < hi {
            let seg_lo = a.max(lo);
            let seg_hi = (a + LINE).min(hi);
            let elems = (seg_hi - seg_lo).div_ceil(ELEM).max(1);
            self.access_block(core, a, elems);
            a += LINE;
        }
    }

    /// Aggregate stats per level.
    pub fn stats(&self) -> LevelStats {
        let sum = |cs: &[SetAssocCache]| {
            let a: u64 = cs.iter().map(|c| c.accesses).sum();
            let m: u64 = cs.iter().map(|c| c.misses).sum();
            (a, m)
        };
        let (l1a, l1m) = sum(&self.l1);
        let (l2a, l2m) = sum(&self.l2);
        let (l3a, l3m) = self.l3.as_ref().map(|c| (c.accesses, c.misses)).unwrap_or((0, 0));
        LevelStats { l1_accesses: l1a, l1_misses: l1m, l2_accesses: l2a, l2_misses: l2m, l3_accesses: l3a, l3_misses: l3m }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::util::prop;
    use crate::util::Rng;

    #[test]
    fn property_inclusion_counting_invariants() {
        // for any access stream: L2 accesses == L1 misses, L3 accesses ==
        // L2 misses, and per-level misses <= accesses
        prop::check(
            "hierarchy counting invariants",
            0xCAFE,
            30,
            |rng: &mut Rng, size: usize| {
                let n = 50 + size * 40;
                let cores = 1 + rng.below(8) as usize;
                let seed = rng.next_u64();
                (n, cores, seed)
            },
            |&(n, cores, seed)| {
                let s = &presets::sg2042().sockets[0];
                let mut h = MultiCoreHierarchy::new(s, cores);
                let mut rng = Rng::new(seed);
                for _ in 0..n {
                    let core = rng.below(cores as u64) as usize;
                    // mixed working set: hot region + cold streaming
                    let addr = if rng.below(2) == 0 {
                        rng.below(4096) * 8
                    } else {
                        rng.below(1 << 24) * 8
                    };
                    h.access(core, addr);
                }
                let st = h.stats();
                if st.l2_accesses != st.l1_misses {
                    return Err(format!("L2 acc {} != L1 miss {}", st.l2_accesses, st.l1_misses));
                }
                if st.l3_accesses != st.l2_misses {
                    return Err(format!("L3 acc {} != L2 miss {}", st.l3_accesses, st.l2_misses));
                }
                for (m, a) in [
                    (st.l1_misses, st.l1_accesses),
                    (st.l2_misses, st.l2_accesses),
                    (st.l3_misses, st.l3_accesses),
                ] {
                    if m > a {
                        return Err(format!("misses {m} > accesses {a}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_weighted_access_only_inflates_hits() {
        // access_block(addr, k) must change accesses by k but misses by
        // at most 1, for any k
        let s = &presets::sg2042().sockets[0];
        let mut h = MultiCoreHierarchy::new(s, 1);
        h.access_block(0, 0, 8);
        let st = h.stats();
        assert_eq!(st.l1_accesses, 8);
        assert_eq!(st.l1_misses, 1);
        h.access_block(0, 0, 100);
        let st = h.stats();
        assert_eq!(st.l1_accesses, 108);
        assert_eq!(st.l1_misses, 1);
    }

    #[test]
    fn topology_matches_sg2042() {
        let s = &presets::sg2042().sockets[0];
        let h = MultiCoreHierarchy::new(s, 8);
        assert_eq!(h.l1.len(), 8);
        assert_eq!(h.l2.len(), 2); // 8 cores / 4 per cluster
        assert!(h.l3.is_some());
    }

    #[test]
    fn private_l1_isolated_between_cores() {
        let s = &presets::sg2042().sockets[0];
        let mut h = MultiCoreHierarchy::new(s, 2);
        h.access(0, 0);
        h.access(0, 0); // hit in core 0's L1
        h.access(1, 0); // core 1 misses L1, hits L2 (same cluster)
        let st = h.stats();
        assert_eq!(st.l1_accesses, 3);
        assert_eq!(st.l1_misses, 2);
        assert_eq!(st.l2_accesses, 2);
        assert_eq!(st.l2_misses, 1);
    }

    #[test]
    fn cross_cluster_sharing_happens_in_l3() {
        let s = &presets::sg2042().sockets[0];
        let mut h = MultiCoreHierarchy::new(s, 8);
        h.access(0, 4096); // cluster 0: L1 miss, L2 miss, L3 miss
        h.access(7, 4096); // cluster 1: L1 miss, L2 miss, L3 HIT
        let st = h.stats();
        assert_eq!(st.l3_accesses, 2);
        assert_eq!(st.l3_misses, 1);
    }

    #[test]
    fn u740_has_no_l3() {
        let s = &presets::u740().sockets[0];
        let mut h = MultiCoreHierarchy::new(s, 4);
        h.access(0, 0);
        assert_eq!(h.stats().l3_accesses, 0);
    }
}
