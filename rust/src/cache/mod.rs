//! Trace-driven cache-hierarchy simulator.
//!
//! Fig 6 of the paper compares L1/L3 miss rates of HPL+OpenBLAS vs
//! HPL+BLIS (measured there with Linux `perf`; here with a set-associative
//! LRU model fed by the *actual* blocked-GEMM loop nest of each library's
//! blocking parameters). The paper's conclusion — vanilla BLIS already has
//! better cache behaviour than optimized OpenBLAS, so BLIS's bottleneck
//! must be the micro-kernel — is a locality property of the loop nests,
//! which this module reproduces mechanically.

pub mod hierarchy;
pub mod set_assoc;
pub mod stats;
pub mod trace;

pub use hierarchy::MultiCoreHierarchy;
pub use set_assoc::SetAssocCache;
pub use stats::LevelStats;
pub use trace::{
    reset_trace_cache, simulate_gemm, simulate_gemm_with, trace_cache_stats, GemmTraceConfig,
    TraceEngine,
};
