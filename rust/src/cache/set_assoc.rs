//! Set-associative cache with true-LRU replacement.
//!
//! Line-granular: callers pass byte addresses; the cache tracks tags only
//! (contents are irrelevant for miss-rate studies). Write-allocate,
//! write-back — the policy of the C920's caches.

use crate::arch::soc::CacheGeom;

/// One set-associative cache instance.
#[derive(Debug, Clone)]
pub struct SetAssocCache {
    geom: CacheGeom,
    sets: usize,
    line_shift: u32,
    /// tags[set * ways + way]; u64::MAX = invalid.
    tags: Vec<u64>,
    /// LRU stamp per way (bigger = more recent).
    stamps: Vec<u64>,
    clock: u64,
    pub accesses: u64,
    pub misses: u64,
}

impl SetAssocCache {
    pub fn new(geom: CacheGeom) -> Self {
        let sets = geom.sets();
        assert!(sets.is_power_of_two(), "sets must be a power of two: {sets}");
        assert!(geom.line_bytes.is_power_of_two());
        SetAssocCache {
            geom,
            sets,
            line_shift: geom.line_bytes.trailing_zeros(),
            tags: vec![u64::MAX; sets * geom.ways],
            stamps: vec![0; sets * geom.ways],
            clock: 0,
            accesses: 0,
            misses: 0,
        }
    }

    pub fn geom(&self) -> &CacheGeom {
        &self.geom
    }

    /// Access a byte address; returns true on hit. On miss the line is
    /// filled (evicting LRU).
    pub fn access(&mut self, addr: u64) -> bool {
        self.accesses += 1;
        self.clock += 1;
        let line = addr >> self.line_shift;
        let set = (line as usize) & (self.sets - 1);
        let base = set * self.geom.ways;
        let ways = &mut self.tags[base..base + self.geom.ways];
        // hit?
        for (w, tag) in ways.iter().enumerate() {
            if *tag == line {
                self.stamps[base + w] = self.clock;
                return true;
            }
        }
        // miss: evict LRU way
        self.misses += 1;
        let mut victim = 0;
        let mut oldest = u64::MAX;
        for w in 0..self.geom.ways {
            let s = self.stamps[base + w];
            if self.tags[base + w] == u64::MAX {
                victim = w;
                break;
            }
            if s < oldest {
                oldest = s;
                victim = w;
            }
        }
        self.tags[base + victim] = line;
        self.stamps[base + victim] = self.clock;
        false
    }

    /// Access one line on behalf of `elem_count` element loads/stores:
    /// the tag is checked once (hardware coalesces within a line), the
    /// access counter advances by `elem_count`, at most one miss results.
    /// This is how `perf` counts: events per retired load, not per line.
    pub fn access_block(&mut self, addr: u64, elem_count: u64) -> bool {
        let hit = self.access(addr);
        self.accesses += elem_count.saturating_sub(1);
        hit
    }

    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }

    pub fn reset_stats(&mut self) {
        self.accesses = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SetAssocCache {
        // 4 sets x 2 ways x 64B lines = 512 B
        SetAssocCache::new(CacheGeom { size_bytes: 512, line_bytes: 64, ways: 2, shared_by: 1 })
    }

    #[test]
    fn first_access_misses_second_hits() {
        let mut c = small();
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(63)); // same line
        assert!(!c.access(64)); // next line
        assert_eq!(c.misses, 2);
        assert_eq!(c.accesses, 4);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = small();
        // set 0 holds lines with (line % 4 == 0): lines 0, 4, 8 (addr = line*64)
        c.access(0); // line 0 -> set 0
        c.access(4 * 64); // line 4 -> set 0
        c.access(0); // touch line 0 (now MRU)
        c.access(8 * 64); // line 8 -> set 0, evicts line 4 (LRU)
        assert!(c.access(0), "line 0 must survive");
        assert!(!c.access(4 * 64), "line 4 must have been evicted");
    }

    #[test]
    fn distinct_sets_dont_conflict() {
        let mut c = small();
        for line in 0..4u64 {
            c.access(line * 64);
        }
        for line in 0..4u64 {
            assert!(c.access(line * 64), "line {line}");
        }
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = small();
        // 16 lines > 8-line capacity, streamed twice round-robin: all miss
        for _ in 0..2 {
            for line in 0..16u64 {
                c.access(line * 64);
            }
        }
        assert_eq!(c.miss_rate(), 1.0);
    }

    #[test]
    fn working_set_fitting_cache_hits_on_reuse() {
        let mut c = small();
        for rep in 0..4 {
            for line in 0..8u64 {
                let hit = c.access(line * 64);
                assert_eq!(hit, rep > 0, "rep {rep} line {line}");
            }
        }
        assert!((c.miss_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sg2042_l1_geometry_constructs() {
        let g = CacheGeom { size_bytes: 64 * 1024, line_bytes: 64, ways: 8, shared_by: 1 };
        let c = SetAssocCache::new(g);
        assert_eq!(c.sets, 128);
    }
}
