//! Set-associative cache with true-LRU replacement.
//!
//! Line-granular: callers pass byte addresses; the cache tracks tags only
//! (contents are irrelevant for miss-rate studies). Write-allocate,
//! write-back — the policy of the C920's caches.

use crate::arch::soc::CacheGeom;

/// One set-associative cache instance.
#[derive(Debug, Clone)]
pub struct SetAssocCache {
    geom: CacheGeom,
    sets: usize,
    line_shift: u32,
    /// tags[set * ways + way]; u64::MAX = invalid.
    tags: Vec<u64>,
    /// LRU stamp per way (bigger = more recent).
    stamps: Vec<u64>,
    clock: u64,
    pub accesses: u64,
    pub misses: u64,
}

impl SetAssocCache {
    pub fn new(geom: CacheGeom) -> Self {
        let sets = geom.sets();
        assert!(sets.is_power_of_two(), "sets must be a power of two: {sets}");
        assert!(geom.line_bytes.is_power_of_two());
        SetAssocCache {
            geom,
            sets,
            line_shift: geom.line_bytes.trailing_zeros(),
            tags: vec![u64::MAX; sets * geom.ways],
            stamps: vec![0; sets * geom.ways],
            clock: 0,
            accesses: 0,
            misses: 0,
        }
    }

    pub fn geom(&self) -> &CacheGeom {
        &self.geom
    }

    /// Access a byte address; returns true on hit. On miss the line is
    /// filled (evicting LRU).
    pub fn access(&mut self, addr: u64) -> bool {
        self.accesses += 1;
        self.clock += 1;
        let line = addr >> self.line_shift;
        let hit = self.probe(line, self.clock);
        if !hit {
            self.misses += 1;
        }
        hit
    }

    /// One tag probe with an externally supplied LRU stamp: hit check,
    /// stamp refresh, LRU fill on miss. Counters are the caller's job —
    /// this is the shared core of [`Self::access`] and the run engine.
    fn probe(&mut self, line: u64, stamp: u64) -> bool {
        let set = (line as usize) & (self.sets - 1);
        let base = set * self.geom.ways;
        let ways = &self.tags[base..base + self.geom.ways];
        // hit?
        for (w, tag) in ways.iter().enumerate() {
            if *tag == line {
                self.stamps[base + w] = stamp;
                return true;
            }
        }
        // miss: evict LRU way
        let mut victim = 0;
        let mut oldest = u64::MAX;
        for w in 0..self.geom.ways {
            let s = self.stamps[base + w];
            if self.tags[base + w] == u64::MAX {
                victim = w;
                break;
            }
            if s < oldest {
                oldest = s;
                victim = w;
            }
        }
        self.tags[base + victim] = line;
        self.stamps[base + victim] = stamp;
        false
    }

    /// The interval engine: touch every line of the ascending run
    /// `[lo_line, hi_line)` exactly once, as if [`Self::access`] had been
    /// called per line in ascending order. Counter- and state-equivalent
    /// to that reference loop (the LRU generation stamp each line would
    /// have received is derived from the run's base generation instead of
    /// ticking the clock per access), but runs whose footprint covers the
    /// whole index space are resolved *per set*: a set whose resident run
    /// lines are all present hits in O(ways), a set holding none of the
    /// run ("clean") bulk-misses in O(ways), and only conflict sets —
    /// partial residency — fall back to the exact per-line LRU walk.
    ///
    /// Missed lines are appended to `out_misses` in per-set order, which
    /// is NOT globally ascending on the per-set path: callers must sort
    /// before replaying the misses into the next level.
    pub fn access_line_run(&mut self, lo_line: u64, hi_line: u64, out_misses: &mut Vec<u64>) {
        if hi_line <= lo_line {
            return;
        }
        let run_len = hi_line - lo_line;
        let clock_base = self.clock;
        self.clock += run_len;
        self.accesses += run_len;
        let sets = self.sets as u64;
        if run_len < sets {
            // short run: every line lands in its own set; the per-line
            // probe is already O(ways) with nothing to amortize
            let mut misses = 0u64;
            for j in 0..run_len {
                let line = lo_line + j;
                if !self.probe(line, clock_base + j + 1) {
                    misses += 1;
                    out_misses.push(line);
                }
            }
            self.misses += misses;
            return;
        }
        // full sweep: every set is touched; resolve set by set
        let ways = self.geom.ways;
        let lo_set = (lo_line % sets) as usize;
        let mut misses = 0u64;
        for set in 0..self.sets {
            let base = set * ways;
            // first run line mapping to this set, and how many follow
            let off = (set + self.sets - lo_set) % self.sets;
            let first = lo_line + off as u64;
            let k = (hi_line - first).div_ceil(sets);
            debug_assert!(k >= 1);
            // how many of this set's run lines are already resident
            let mut resident = 0u64;
            for w in 0..ways {
                let t = self.tags[base + w];
                if t >= lo_line && t < hi_line {
                    resident += 1;
                }
            }
            if resident == k {
                // analytic hit path: every run line is resident; refresh
                // each stamp to the generation it would have been touched
                for w in 0..ways {
                    let t = self.tags[base + w];
                    if t >= lo_line && t < hi_line {
                        self.stamps[base + w] = clock_base + (t - lo_line) + 1;
                    }
                }
            } else if resident == 0 {
                // analytic miss path ("clean" set): all k lines miss
                misses += k;
                let mut line = first;
                while line < hi_line {
                    out_misses.push(line);
                    line += sets;
                }
                if k >= ways as u64 {
                    // evictions consume every pre-run way, then the
                    // run's own oldest fills; the set ends holding the
                    // last `ways` run lines with their touch stamps
                    let mut line = first + (k - ways as u64) * sets;
                    for w in 0..ways {
                        self.tags[base + w] = line;
                        self.stamps[base + w] = clock_base + (line - lo_line) + 1;
                        line += sets;
                    }
                } else {
                    // fewer fills than ways: evict in reference order
                    // (first invalid way, else oldest stamp)
                    for j in 0..k {
                        let line = first + j * sets;
                        let mut victim = 0;
                        let mut oldest = u64::MAX;
                        for w in 0..ways {
                            let s = self.stamps[base + w];
                            if self.tags[base + w] == u64::MAX {
                                victim = w;
                                break;
                            }
                            if s < oldest {
                                oldest = s;
                                victim = w;
                            }
                        }
                        self.tags[base + victim] = line;
                        self.stamps[base + victim] = clock_base + (line - lo_line) + 1;
                    }
                }
            } else {
                // conflict set: partial residency — exact LRU walk
                let mut line = first;
                while line < hi_line {
                    if !self.probe(line, clock_base + (line - lo_line) + 1) {
                        misses += 1;
                        out_misses.push(line);
                    }
                    line += sets;
                }
            }
        }
        self.misses += misses;
    }

    /// Access one line on behalf of `elem_count` element loads/stores:
    /// the tag is checked once (hardware coalesces within a line), the
    /// access counter advances by `elem_count`, at most one miss results.
    /// This is how `perf` counts: events per retired load, not per line.
    pub fn access_block(&mut self, addr: u64, elem_count: u64) -> bool {
        let hit = self.access(addr);
        self.accesses += elem_count.saturating_sub(1);
        hit
    }

    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }

    pub fn reset_stats(&mut self) {
        self.accesses = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SetAssocCache {
        // 4 sets x 2 ways x 64B lines = 512 B
        SetAssocCache::new(CacheGeom { size_bytes: 512, line_bytes: 64, ways: 2, shared_by: 1 })
    }

    #[test]
    fn first_access_misses_second_hits() {
        let mut c = small();
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(63)); // same line
        assert!(!c.access(64)); // next line
        assert_eq!(c.misses, 2);
        assert_eq!(c.accesses, 4);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = small();
        // set 0 holds lines with (line % 4 == 0): lines 0, 4, 8 (addr = line*64)
        c.access(0); // line 0 -> set 0
        c.access(4 * 64); // line 4 -> set 0
        c.access(0); // touch line 0 (now MRU)
        c.access(8 * 64); // line 8 -> set 0, evicts line 4 (LRU)
        assert!(c.access(0), "line 0 must survive");
        assert!(!c.access(4 * 64), "line 4 must have been evicted");
    }

    #[test]
    fn distinct_sets_dont_conflict() {
        let mut c = small();
        for line in 0..4u64 {
            c.access(line * 64);
        }
        for line in 0..4u64 {
            assert!(c.access(line * 64), "line {line}");
        }
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = small();
        // 16 lines > 8-line capacity, streamed twice round-robin: all miss
        for _ in 0..2 {
            for line in 0..16u64 {
                c.access(line * 64);
            }
        }
        assert_eq!(c.miss_rate(), 1.0);
    }

    #[test]
    fn working_set_fitting_cache_hits_on_reuse() {
        let mut c = small();
        for rep in 0..4 {
            for line in 0..8u64 {
                let hit = c.access(line * 64);
                assert_eq!(hit, rep > 0, "rep {rep} line {line}");
            }
        }
        assert!((c.miss_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sg2042_l1_geometry_constructs() {
        let g = CacheGeom { size_bytes: 64 * 1024, line_bytes: 64, ways: 8, shared_by: 1 };
        let c = SetAssocCache::new(g);
        assert_eq!(c.sets, 128);
    }

    #[test]
    fn access_block_touches_the_line_once_whatever_the_element_count() {
        // all elements share one cache line: one tag probe, one miss,
        // `elem_count` retired accesses — never a per-element loop
        let mut c = small();
        assert!(!c.access_block(0, 8));
        assert_eq!((c.accesses, c.misses), (8, 1));
        assert!(c.access_block(32, 8)); // same 64B line, different offset
        assert_eq!((c.accesses, c.misses), (16, 1));
        assert!(!c.access_block(64, 100)); // next line, heavy weight
        assert_eq!((c.accesses, c.misses), (116, 2));
    }

    /// Reference loop for the run engine: per-line `access` calls.
    fn access_run_ref(c: &mut SetAssocCache, lo: u64, hi: u64, out: &mut Vec<u64>) {
        for line in lo..hi {
            if !c.access(line * 64) {
                out.push(line);
            }
        }
    }

    #[test]
    fn line_run_matches_per_line_reference() {
        // a mix of short runs, full sweeps, re-sweeps (all-hit), partial
        // overlaps (conflict sets) and thrashing runs, replayed through
        // both paths: counters and the sorted miss lists must agree
        let runs: &[(u64, u64)] = &[
            (0, 2),     // short run
            (0, 8),     // full sweep of the 4-set cache
            (0, 8),     // re-sweep: all resident
            (4, 10),    // partial overlap: conflict sets
            (0, 32),    // thrash: 8 lines/set vs 2 ways
            (0, 32),    // thrash again: still all miss
            (30, 33),   // tail reuse
            (100, 101), // cold singleton
        ];
        let mut a = small();
        let mut b = small();
        for &(lo, hi) in runs {
            let mut ma = Vec::new();
            let mut mb = Vec::new();
            a.access_line_run(lo, hi, &mut ma);
            access_run_ref(&mut b, lo, hi, &mut mb);
            ma.sort_unstable();
            assert_eq!(ma, mb, "miss lines for run [{lo}, {hi})");
            assert_eq!((a.accesses, a.misses), (b.accesses, b.misses), "run [{lo}, {hi})");
        }
    }

    #[test]
    fn line_run_seeded_streams_match_reference() {
        // randomized run streams over a few geometries; LevelStats-level
        // bit-identity is re-asserted hierarchy-wide in hierarchy.rs
        let mut state = 0x9E37_79B9_7F4A_7C15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for ways in [1usize, 2, 8] {
            let geom = CacheGeom { size_bytes: 64 * 64 * ways, line_bytes: 64, ways, shared_by: 1 };
            let mut a = SetAssocCache::new(geom);
            let mut b = SetAssocCache::new(geom);
            for _ in 0..200 {
                let lo = next() % 512;
                let len = next() % 300;
                let (mut ma, mut mb) = (Vec::new(), Vec::new());
                a.access_line_run(lo, lo + len, &mut ma);
                access_run_ref(&mut b, lo, lo + len, &mut mb);
                ma.sort_unstable();
                assert_eq!(ma, mb, "ways {ways} run [{lo}, {})", lo + len);
                assert_eq!((a.accesses, a.misses), (b.accesses, b.misses), "ways {ways}");
            }
        }
    }
}
