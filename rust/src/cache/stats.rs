//! Per-level cache statistics (the quantities Fig 6 plots).

/// Aggregate access/miss counts for a hierarchy run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LevelStats {
    pub l1_accesses: u64,
    pub l1_misses: u64,
    pub l2_accesses: u64,
    pub l2_misses: u64,
    pub l3_accesses: u64,
    pub l3_misses: u64,
}

impl LevelStats {
    pub fn l1_miss_rate(&self) -> f64 {
        rate(self.l1_misses, self.l1_accesses)
    }

    pub fn l2_miss_rate(&self) -> f64 {
        rate(self.l2_misses, self.l2_accesses)
    }

    /// L3 miss rate as `perf` reports it: misses over L3 *accesses*
    /// (i.e. over L2 misses), not over all loads.
    pub fn l3_miss_rate(&self) -> f64 {
        rate(self.l3_misses, self.l3_accesses)
    }

    /// L3 misses normalized to retired loads (the Fig 6 metric we report;
    /// see EXPERIMENTS.md — the raw misses/L3-accesses ratio rewards
    /// libraries that spill L2 constantly, because their denominator
    /// balloons with L3 *hits*; per-load normalization compares actual
    /// DRAM-bound traffic apples-to-apples).
    pub fn l3_misses_per_load(&self) -> f64 {
        rate(self.l3_misses, self.l1_accesses)
    }

    /// DRAM lines touched (L3 misses, or L2 misses when no L3 exists).
    pub fn dram_lines(&self) -> u64 {
        if self.l3_accesses > 0 {
            self.l3_misses
        } else {
            self.l2_misses
        }
    }
}

fn rate(m: u64, a: u64) -> f64 {
    if a == 0 {
        0.0
    } else {
        m as f64 / a as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_and_dram_lines() {
        let s = LevelStats {
            l1_accesses: 100,
            l1_misses: 10,
            l2_accesses: 10,
            l2_misses: 5,
            l3_accesses: 5,
            l3_misses: 2,
        };
        assert!((s.l1_miss_rate() - 0.1).abs() < 1e-12);
        assert!((s.l2_miss_rate() - 0.5).abs() < 1e-12);
        assert!((s.l3_miss_rate() - 0.4).abs() < 1e-12);
        assert_eq!(s.dram_lines(), 2);
    }

    #[test]
    fn no_l3_falls_back_to_l2_misses() {
        let s = LevelStats { l2_misses: 7, ..Default::default() };
        assert_eq!(s.dram_lines(), 7);
        assert_eq!(s.l3_miss_rate(), 0.0);
    }

    #[test]
    fn zero_accesses_zero_rate() {
        assert_eq!(LevelStats::default().l1_miss_rate(), 0.0);
    }
}
