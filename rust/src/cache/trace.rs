//! GEMM loop-nest address-trace generation.
//!
//! Replays the exact memory-access pattern of a library's blocked DGEMM
//! (packing loops + the five BLIS loops + micro-kernel streaming) through
//! a [`MultiCoreHierarchy`], with element-weighted line accesses (miss
//! rates are per retired load, the way `perf` counts them in Fig 6).
//!
//! Cores parallelize the jc (N-dimension) loop like threaded BLIS /
//! OpenBLAS. Core interleaving happens at (pc, ic)-block granularity: each
//! core replays one block of its share, round-robin — coarse enough to be
//! cheap, fine enough that per-core packing buffers genuinely compete for
//! the shared L3.
//!
//! Address map: A, B, C column-major back to back; per-core packing
//! buffers (packed-A block, packed-B panel) above them.

use super::hierarchy::MultiCoreHierarchy;
use super::stats::LevelStats;
use crate::arch::soc::Socket;
use crate::blas::blocking::Blocking;
use crate::util::hash::ContentHasher;
use crate::util::memo::{CacheStats, MemoCache};

const ELEM: u64 = 8;

/// Which range engine replays the trace: the interval engine (default;
/// run-based `[lo, hi)` touches resolved per set) or the retained
/// per-access reference loop. Both produce bit-identical [`LevelStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceEngine {
    Interval,
    PerAccess,
}

/// Memoized trace results: one [`LevelStats`] per resolved
/// `(GemmTraceConfig, Socket)` content digest.
static TRACE_CACHE: MemoCache<LevelStats> = MemoCache::new();

/// Snapshot of the trace-sim cache counters (for `cimone bench`).
pub fn trace_cache_stats() -> CacheStats {
    TRACE_CACHE.stats()
}

/// Drop the trace-sim cache — the perf harness's cold start.
pub fn reset_trace_cache() {
    TRACE_CACHE.reset();
}

/// One simulated DGEMM: C(m x n) += A(m x k) B(k x n).
#[derive(Debug, Clone, Copy)]
pub struct GemmTraceConfig {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub blocking: Blocking,
    pub cores: usize,
}

#[derive(Debug, Clone, Copy)]
struct AddrMap {
    a_base: u64,
    b_base: u64,
    c_base: u64,
    pack_base: u64,
    pack_stride: u64,
    m: u64,
    k: u64,
    /// offset of packed B within a core's packing region (after packed A)
    packed_b_off: u64,
}

impl AddrMap {
    fn new(cfg: &GemmTraceConfig) -> AddrMap {
        let (m, n, k) = (cfg.m as u64, cfg.n as u64, cfg.k as u64);
        let bl = cfg.blocking;
        let packed_a_bytes = (bl.mc * bl.kc) as u64 * ELEM;
        let packed_b_bytes = (bl.kc * bl.nc) as u64 * ELEM;
        AddrMap {
            a_base: 0,
            b_base: m * k * ELEM,
            c_base: (m * k + k * n) * ELEM,
            pack_base: (m * k + k * n + m * n) * ELEM,
            pack_stride: packed_a_bytes + packed_b_bytes + 4096,
            m,
            k,
            packed_b_off: packed_a_bytes + 2048,
        }
    }

    fn a_col(&self, i: u64, j: u64) -> u64 {
        self.a_base + (i + j * self.m) * ELEM
    }

    fn b_col(&self, i: u64, j: u64) -> u64 {
        self.b_base + (i + j * self.k) * ELEM
    }

    fn c_col(&self, i: u64, j: u64) -> u64 {
        self.c_base + (i + j * self.m) * ELEM
    }

    fn packed_a(&self, core: u64, elem_off: u64) -> u64 {
        self.pack_base + core * self.pack_stride + elem_off * ELEM
    }

    fn packed_b(&self, core: u64, elem_off: u64) -> u64 {
        self.pack_base + core * self.pack_stride + self.packed_b_off + elem_off * ELEM
    }
}

/// One (jc, pc, ic) block of one core's work-list.
#[derive(Debug, Clone, Copy)]
struct BlockTask {
    core: usize,
    jc: usize,
    ncb: usize,
    pc: usize,
    kcb: usize,
    ic: usize,
    mcb: usize,
    /// pack B in this block? (only on the first ic of each (jc, pc))
    pack_b: bool,
}

/// Replay one block's access stream into the hierarchy.
fn replay_block(h: &mut MultiCoreHierarchy, map: &AddrMap, bl: &Blocking, t: &BlockTask) {
    let core = t.core as u64;
    let cid = t.core;
    // --- pack B panel (kc x nc): read B columns, write packed ---
    if t.pack_b {
        for j in 0..t.ncb as u64 {
            let col = map.b_col(t.pc as u64, t.jc as u64 + j);
            h.access_range(cid, col, col + t.kcb as u64 * ELEM);
        }
        h.access_range(cid, map.packed_b(core, 0), map.packed_b(core, (t.kcb * t.ncb) as u64));
    }
    // --- pack A block (mc x kc): read A columns, write packed ---
    for kk in 0..t.kcb as u64 {
        let col = map.a_col(t.ic as u64, t.pc as u64 + kk);
        h.access_range(cid, col, col + t.mcb as u64 * ELEM);
    }
    h.access_range(cid, map.packed_a(core, 0), map.packed_a(core, (t.mcb * t.kcb) as u64));
    // --- macro-kernel: micro-tiles stream the packed panels ---
    for jr in (0..t.ncb).step_by(bl.nr) {
        let nrb = bl.nr.min(t.ncb - jr);
        for ir in (0..t.mcb).step_by(bl.mr) {
            let mrb = bl.mr.min(t.mcb - ir);
            // C tile load + store
            for j in 0..nrb as u64 {
                let col = map.c_col((t.ic + ir) as u64, (t.jc + jr) as u64 + j);
                h.access_range(cid, col, col + mrb as u64 * ELEM);
                h.access_range(cid, col, col + mrb as u64 * ELEM);
            }
            // k-loop streams: packed A micro-panel (mr x kc), packed B
            // micro-panel (kc x nr)
            let a_off = (ir * t.kcb) as u64;
            h.access_range(
                cid,
                map.packed_a(core, a_off),
                map.packed_a(core, a_off + (mrb * t.kcb) as u64),
            );
            let b_off = (jr * t.kcb) as u64;
            h.access_range(
                cid,
                map.packed_b(core, b_off),
                map.packed_b(core, b_off + (t.kcb * nrb) as u64),
            );
        }
    }
}

impl GemmTraceConfig {
    fn feed_content(&self, h: &mut ContentHasher) {
        h.write_str("cache-trace/v1");
        h.write_usize(self.m).write_usize(self.n).write_usize(self.k);
        let bl = self.blocking;
        h.write_usize(bl.mr)
            .write_usize(bl.nr)
            .write_usize(bl.mc)
            .write_usize(bl.kc)
            .write_usize(bl.nc);
        h.write_usize(self.cores);
    }
}

/// Run the trace through a hierarchy built for `socket`, memoized on the
/// `(GemmTraceConfig, Socket)` content digest: repeated sweeps over the
/// same trace coordinates (e.g. every scenario sharing one kernel's
/// blocking) replay once and hit the cache thereafter.
pub fn simulate_gemm(cfg: &GemmTraceConfig, socket: &Socket) -> LevelStats {
    let mut h = ContentHasher::new();
    cfg.feed_content(&mut h);
    socket.feed_content(&mut h);
    let (cfg, socket) = (*cfg, socket.clone());
    TRACE_CACHE.get_or_insert_with(h.finish(), move || {
        simulate_gemm_with(&cfg, &socket, TraceEngine::Interval)
    })
}

/// Run the trace through a hierarchy built for `socket` with an explicit
/// range engine, uncached. Returns stats.
pub fn simulate_gemm_with(
    cfg: &GemmTraceConfig,
    socket: &Socket,
    engine: TraceEngine,
) -> LevelStats {
    assert!(cfg.cores >= 1);
    let mut h =
        MultiCoreHierarchy::with_engine(socket, cfg.cores, engine == TraceEngine::Interval);
    let map = AddrMap::new(cfg);
    let bl = cfg.blocking;

    // build the per-core block lists (jc loop split over cores) as one
    // flat arena: tasks are appended per core, with `spans[core]`
    // delimiting each core's slice — no per-core Vec growth in the replay
    let mut tasks: Vec<BlockTask> = Vec::new();
    let mut spans: Vec<(usize, usize)> = Vec::with_capacity(cfg.cores);
    for core in 0..cfg.cores {
        let start = tasks.len();
        let n0 = (core * cfg.n) / cfg.cores;
        let n1 = ((core + 1) * cfg.n) / cfg.cores;
        for jc in (n0..n1).step_by(bl.nc) {
            let ncb = bl.nc.min(n1 - jc);
            for pc in (0..cfg.k).step_by(bl.kc) {
                let kcb = bl.kc.min(cfg.k - pc);
                let mut first = true;
                for ic in (0..cfg.m).step_by(bl.mc) {
                    let mcb = bl.mc.min(cfg.m - ic);
                    tasks.push(BlockTask {
                        core,
                        jc,
                        ncb,
                        pc,
                        kcb,
                        ic,
                        mcb,
                        pack_b: first,
                    });
                    first = false;
                }
            }
        }
        spans.push((start, tasks.len()));
    }

    // round-robin the block lists so cores advance together
    let mut idx: Vec<usize> = spans.iter().map(|&(start, _)| start).collect();
    let mut live = true;
    while live {
        live = false;
        for core in 0..cfg.cores {
            if idx[core] < spans[core].1 {
                replay_block(&mut h, &map, &bl, &tasks[idx[core]]);
                idx[core] += 1;
                live = true;
            }
        }
    }
    h.stats()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    fn sg_socket() -> crate::arch::soc::Socket {
        presets::sg2042().sockets[0].clone()
    }

    fn blis_cfg(n: usize, cores: usize) -> GemmTraceConfig {
        let s = sg_socket();
        GemmTraceConfig { m: n, n, k: n, blocking: Blocking::blis_for(&s, 8, 4), cores }
    }

    fn openblas_cfg(n: usize, cores: usize) -> GemmTraceConfig {
        GemmTraceConfig { m: n, n, k: n, blocking: Blocking::openblas_fixed(8, 4), cores }
    }

    #[test]
    fn produces_plausible_miss_rates() {
        let st = simulate_gemm(&blis_cfg(256, 1), &sg_socket());
        assert!(st.l1_accesses > 100_000);
        // L1 miss rate for blocked DGEMM must be low single digits
        let r = st.l1_miss_rate();
        assert!(r > 0.0005 && r < 0.10, "L1 miss rate {r:.4}");
    }

    /// Deep-K config: KC only unfolds fully when k >= OpenBLAS's 768.
    fn deep_cfg(blocking: Blocking, cores: usize) -> GemmTraceConfig {
        GemmTraceConfig { m: 256, n: 256, k: 768, blocking, cores }
    }

    #[test]
    fn blis_beats_openblas_on_l1_misses() {
        // the Fig 6 premise: OpenBLAS's x86-sized KC makes the A stream +
        // B micro-panel (48+24 KB) overflow the 64 KB L1D, so B re-reads
        // miss; BLIS's derived KC keeps both resident
        let s = sg_socket();
        let blis = simulate_gemm(&deep_cfg(Blocking::blis_for(&s, 8, 4), 1), &s);
        let ob = simulate_gemm(&deep_cfg(Blocking::openblas_fixed(8, 4), 1), &s);
        assert!(
            blis.l1_miss_rate() < 0.85 * ob.l1_miss_rate(),
            "blis {:.4} vs openblas {:.4}",
            blis.l1_miss_rate(),
            ob.l1_miss_rate()
        );
    }

    #[test]
    fn blis_beats_openblas_on_l2_traffic() {
        let s = sg_socket();
        let blis = simulate_gemm(&deep_cfg(Blocking::blis_for(&s, 8, 4), 1), &s);
        let ob = simulate_gemm(&deep_cfg(Blocking::openblas_fixed(8, 4), 1), &s);
        // OpenBLAS's 4.7 MiB packed-A block cannot live in the 256 KiB L2
        // share; BLIS's derived block can
        assert!(
            blis.l2_miss_rate() < ob.l2_miss_rate(),
            "blis {:.4} vs openblas {:.4}",
            blis.l2_miss_rate(),
            ob.l2_miss_rate()
        );
    }

    #[test]
    fn blis_beats_openblas_on_l3_under_multicore_pressure() {
        // L3 story (tested on a scaled-down L3 so the unit test stays
        // fast; the bench regenerates it at full geometry): OpenBLAS's
        // giant per-core packing regions thrash the shared L3, BLIS's
        // NC-blocking keeps its panel L3-resident
        let mut s = sg_socket();
        s.l3 = Some(crate::arch::soc::CacheGeom {
            size_bytes: 2 << 20,
            line_bytes: 64,
            ways: 16,
            shared_by: 64,
        });
        let blis_bl = Blocking::blis_for(&s, 8, 4);
        let blis = simulate_gemm(&deep_cfg(blis_bl, 4), &s);
        let ob = simulate_gemm(&deep_cfg(Blocking::openblas_fixed(8, 4), 4), &s);
        assert!(
            blis.l3_misses_per_load() < ob.l3_misses_per_load(),
            "blis {:.5} vs openblas {:.5}",
            blis.l3_misses_per_load(),
            ob.l3_misses_per_load()
        );
    }

    #[test]
    fn access_count_scales_with_problem_size() {
        let s = sg_socket();
        let small = simulate_gemm(&blis_cfg(64, 1), &s);
        let big = simulate_gemm(&blis_cfg(256, 1), &s);
        assert!(big.l1_accesses > 8 * small.l1_accesses);
    }

    #[test]
    fn interval_engine_is_bit_identical_on_gemm_traces() {
        // the whole default trace set, both engines: LevelStats must be
        // bit-equal — the GEMM half of the interval-engine property
        let s = sg_socket();
        let cfgs = [
            blis_cfg(96, 1),
            blis_cfg(128, 2),
            openblas_cfg(96, 1),
            deep_cfg(Blocking::blis_for(&s, 8, 4), 2),
        ];
        for cfg in &cfgs {
            let fast = simulate_gemm_with(cfg, &s, TraceEngine::Interval);
            let refr = simulate_gemm_with(cfg, &s, TraceEngine::PerAccess);
            assert_eq!(fast, refr, "m={} n={} k={} cores={}", cfg.m, cfg.n, cfg.k, cfg.cores);
        }
    }

    #[test]
    fn memoized_trace_is_bit_identical_and_counts_hits() {
        let s = sg_socket();
        let cfg = blis_cfg(64, 1);
        let cold_stats = trace_cache_stats();
        let cold = simulate_gemm(&cfg, &s);
        let warm = simulate_gemm(&cfg, &s);
        assert_eq!(cold, warm);
        assert_eq!(cold, simulate_gemm_with(&cfg, &s, TraceEngine::Interval));
        let warm_stats = trace_cache_stats();
        assert!(warm_stats.hits > cold_stats.hits, "{warm_stats:?} vs {cold_stats:?}");
    }
}
