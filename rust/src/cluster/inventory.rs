//! The Monte Cimone v2 fleet, as Section 3.1 describes it:
//! 8 MCv1 blades (4 E4 RV007 servers x 2 boards) + 3 Milk-V Pioneer boxes
//! + 1 dual-socket Sophgo SR1-2208A0, on one 1 Gb/s network, exposed as
//! two SLURM partitions.

use super::node::Node;
use crate::arch::presets;
use crate::net::Link;
use crate::sched::{Partition, Scheduler};

/// The full machine: nodes + fabric.
#[derive(Debug, Clone)]
pub struct Inventory {
    pub nodes: Vec<Node>,
    pub fabric: Link,
}

impl Inventory {
    /// Node by *id* (not vector position — the two coincide in the
    /// standard fleet but diverge in pruned/reordered inventories).
    pub fn node(&self, id: usize) -> &Node {
        self.nodes
            .iter()
            .find(|n| n.id == id)
            .unwrap_or_else(|| panic!("no node with id {id} in the inventory"))
    }

    pub fn ids_of_kind(&self, kind: crate::arch::soc::NodeKind) -> Vec<usize> {
        self.nodes.iter().filter(|n| n.desc.kind == kind).map(|n| n.id).collect()
    }

    /// Build the SLURM-like scheduler with the paper's two partitions.
    pub fn scheduler(&self) -> Scheduler {
        use crate::arch::soc::NodeKind::*;
        let mcv1 = self.ids_of_kind(Mcv1U740);
        let mut mcv2 = self.ids_of_kind(Mcv2Pioneer);
        mcv2.extend(self.ids_of_kind(Mcv2DualSocket));
        Scheduler::new(vec![Partition::new("mcv1", mcv1), Partition::new("mcv2", mcv2)])
    }

    /// Total peak FP64 of the machine.
    pub fn peak_gflops(&self) -> f64 {
        self.nodes.iter().map(|n| n.peak_gflops()).sum()
    }
}

/// The MCv2 machine of the paper.
pub fn monte_cimone_v2() -> Inventory {
    let mut nodes = Vec::new();
    // 8 MCv1 U740 boards
    for i in 0..8 {
        nodes.push(Node::new(i, format!("mc-{:02}", i + 1), presets::u740()));
    }
    // 3 Milk-V Pioneer boxes
    for i in 0..3 {
        nodes.push(Node::new(8 + i, format!("mcv2-{:02}", i + 1), presets::sg2042()));
    }
    // 1 dual-socket SR1-2208A0
    nodes.push(Node::new(11, "mcv2-04", presets::sg2042_dual()));
    Inventory { nodes, fabric: Link::gbe() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::soc::NodeKind;

    #[test]
    fn fleet_matches_paper() {
        let inv = monte_cimone_v2();
        assert_eq!(inv.nodes.len(), 12);
        assert_eq!(inv.ids_of_kind(NodeKind::Mcv1U740).len(), 8);
        assert_eq!(inv.ids_of_kind(NodeKind::Mcv2Pioneer).len(), 3);
        assert_eq!(inv.ids_of_kind(NodeKind::Mcv2DualSocket).len(), 1);
    }

    #[test]
    fn partitions_cover_fleet() {
        let inv = monte_cimone_v2();
        let s = inv.scheduler();
        assert_eq!(s.partitions["mcv1"].size(), 8);
        assert_eq!(s.partitions["mcv2"].size(), 4);
    }

    #[test]
    fn dual_socket_node_has_128_cores() {
        let inv = monte_cimone_v2();
        assert_eq!(inv.node(11).cores(), 128);
    }

    #[test]
    fn machine_peak_dominated_by_mcv2() {
        let inv = monte_cimone_v2();
        // 8*4 + 3*512 + 1024 = 32 + 2560 = ~2592
        assert!((inv.peak_gflops() - 2592.0).abs() < 5.0, "{}", inv.peak_gflops());
    }
}
