//! Fleet inventories built from `(platform_id, count)` fleet specs.
//!
//! The paper's machine (Section 3.1) — 8 MCv1 blades + 3 Milk-V Pioneer
//! boxes + 1 dual-socket Sophgo SR1-2208A0 on one 1 Gb/s network — is
//! just [`PAPER_FLEET`] run through [`Inventory::from_fleet`]; any other
//! fleet (SG2044 testbeds, MCv3 projections, custom platforms) is a
//! different spec, not different code. SLURM-like partitions are derived
//! from each platform's `partition` field, in fleet order.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::node::Node;
use crate::arch::platform::PlatformRegistry;
use crate::error::CimoneError;
use crate::net::{Fabric, FabricRegistry};
use crate::sched::{Partition, Scheduler};
use crate::ukernel::KernelRegistry;

/// The paper's fleet as a spec: `(platform id, node count)`.
pub const PAPER_FLEET: &[(&str, usize)] =
    &[("mcv1-u740", 8), ("mcv2-pioneer", 3), ("mcv2-dual", 1)];

/// The full machine: nodes + the interconnect they hang off, plus the
/// fabric registry workload-level `fabric =` overrides resolve against.
#[derive(Debug, Clone)]
pub struct Inventory {
    pub nodes: Vec<Node>,
    /// The machine's resolved interconnect.
    pub fabric: Arc<Fabric>,
    /// Registry the machine fabric came from (built-ins plus any
    /// `[[fabric]]` definitions of the campaign spec that built this
    /// inventory); per-workload overrides resolve here.
    pub fabrics: FabricRegistry,
    /// Micro-kernel registry workload `lib =` keys and platform
    /// `default_lib`s resolve against (built-ins plus any `[[kernel]]`
    /// definitions of the campaign spec that built this inventory).
    pub kernels: KernelRegistry,
}

impl Inventory {
    /// Build a fleet from `(platform_id, count)` pairs resolved against a
    /// registry. Node ids are sequential in spec order; hostnames are
    /// `<host_prefix>-NN` with one counter per prefix (which reproduces
    /// the paper's `mc-01..08` / `mcv2-01..04` naming exactly). The
    /// fabric defaults to the first platform's `default_fabric` resolved
    /// against the built-in [`FabricRegistry`].
    pub fn from_fleet<S: AsRef<str>>(
        registry: &PlatformRegistry,
        fleet: &[(S, usize)],
    ) -> Result<Inventory, CimoneError> {
        Inventory::from_fleet_on(
            registry,
            &FabricRegistry::builtin(),
            &KernelRegistry::builtin(),
            fleet,
            None,
        )
    }

    /// [`Inventory::from_fleet`] with explicit fabric and kernel
    /// registries (the campaign layer passes its own, custom
    /// `[[fabric]]`/`[[kernel]]` sections included) and an optional
    /// machine-fabric id (falling back to the first platform's
    /// `default_fabric`, then to the paper's `gbe-flat`). Checks the
    /// switch has a port per node ([`CimoneError::FabricTooSmall`]) so
    /// the flow model never sees an out-of-range rank.
    pub fn from_fleet_on<S: AsRef<str>>(
        registry: &PlatformRegistry,
        fabrics: &FabricRegistry,
        kernels: &KernelRegistry,
        fleet: &[(S, usize)],
        fabric: Option<&str>,
    ) -> Result<Inventory, CimoneError> {
        let mut nodes = Vec::new();
        let mut counters: BTreeMap<String, usize> = BTreeMap::new();
        for (platform_id, count) in fleet {
            let platform = registry.get(platform_id.as_ref())?;
            for _ in 0..*count {
                let n = counters.entry(platform.host_prefix.clone()).or_insert(0);
                *n += 1;
                let hostname = format!("{}-{:02}", platform.host_prefix, *n);
                let id = nodes.len();
                nodes.push(Node::new(id, hostname, platform.clone()));
            }
        }
        let fabric_id: String = match fabric {
            Some(id) => id.to_string(),
            None => match nodes.first() {
                Some(n) => n.platform.default_fabric.clone(),
                None => "gbe-flat".to_string(),
            },
        };
        let fabric = fabrics.get(&fabric_id)?;
        fabric.validate_cluster(nodes.len())?;
        // every node platform's default kernel must resolve — the same
        // load-time guarantee the fabric gets, so estimation never hits
        // an UnknownKernel the spec could have caught
        for n in &nodes {
            kernels.get(&n.platform.default_lib)?;
        }
        Ok(Inventory { nodes, fabric, fabrics: fabrics.clone(), kernels: kernels.clone() })
    }

    /// Node by *id* (not vector position — the two coincide in the
    /// standard fleet but diverge in pruned/reordered inventories).
    pub fn node(&self, id: usize) -> &Node {
        self.nodes
            .iter()
            .find(|n| n.id == id)
            .unwrap_or_else(|| panic!("no node with id {id} in the inventory"))
    }

    /// Ids of every node whose platform matches `name` (id or alias).
    pub fn ids_of_platform(&self, name: &str) -> Vec<usize> {
        self.nodes.iter().filter(|n| n.platform.matches(name)).map(|n| n.id).collect()
    }

    /// Build the SLURM-like scheduler: one partition per distinct
    /// platform `partition` name, in node order.
    pub fn scheduler(&self) -> Scheduler {
        let mut order: Vec<String> = Vec::new();
        let mut groups: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for n in &self.nodes {
            let part = n.platform.partition.clone();
            if !groups.contains_key(&part) {
                order.push(part.clone());
            }
            groups.entry(part).or_default().push(n.id);
        }
        Scheduler::new(
            order
                .into_iter()
                .map(|p| {
                    let ids = groups.remove(&p).unwrap_or_default();
                    Partition::new(p, ids)
                })
                .collect(),
        )
    }

    /// Total peak FP64 of the machine.
    pub fn peak_gflops(&self) -> f64 {
        self.nodes.iter().map(|n| n.peak_gflops()).sum()
    }
}

/// The MCv2 machine of the paper.
pub fn monte_cimone_v2() -> Inventory {
    Inventory::from_fleet(&PlatformRegistry::builtin(), PAPER_FLEET)
        .expect("the paper fleet names built-in platforms")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_matches_paper() {
        let inv = monte_cimone_v2();
        assert_eq!(inv.nodes.len(), 12);
        assert_eq!(inv.ids_of_platform("mcv1-u740").len(), 8);
        assert_eq!(inv.ids_of_platform("mcv2-pioneer").len(), 3);
        assert_eq!(inv.ids_of_platform("mcv2-dual").len(), 1);
        // aliases resolve too
        assert_eq!(inv.ids_of_platform("sg2042").len(), 3);
    }

    #[test]
    fn hostnames_match_paper_naming() {
        let inv = monte_cimone_v2();
        assert_eq!(inv.node(0).hostname, "mc-01");
        assert_eq!(inv.node(7).hostname, "mc-08");
        assert_eq!(inv.node(8).hostname, "mcv2-01");
        // the SR1 continues the mcv2 hostname sequence
        assert_eq!(inv.node(11).hostname, "mcv2-04");
    }

    #[test]
    fn partitions_cover_fleet() {
        let inv = monte_cimone_v2();
        let s = inv.scheduler();
        assert_eq!(s.partitions["mcv1"].size(), 8);
        assert_eq!(s.partitions["mcv2"].size(), 4);
    }

    #[test]
    fn dual_socket_node_has_128_cores() {
        let inv = monte_cimone_v2();
        assert_eq!(inv.node(11).cores(), 128);
    }

    #[test]
    fn machine_peak_dominated_by_mcv2() {
        let inv = monte_cimone_v2();
        // 8*4 + 3*512 + 1024 = 32 + 2560 = ~2592
        assert!((inv.peak_gflops() - 2592.0).abs() < 5.0, "{}", inv.peak_gflops());
    }

    #[test]
    fn next_gen_fleet_is_a_spec_not_a_refactor() {
        // the whole point of the registry: an SG2044 + MCv3 testbed is data
        let reg = PlatformRegistry::builtin();
        let inv = Inventory::from_fleet(&reg, &[("sg2044", 4), ("mcv3", 2)]).unwrap();
        assert_eq!(inv.nodes.len(), 6);
        assert_eq!(inv.node(0).hostname, "sg2044-01");
        assert_eq!(inv.node(4).hostname, "mcv3-01");
        let s = inv.scheduler();
        assert_eq!(s.partitions["sg2044"].size(), 4);
        assert_eq!(s.partitions["mcv3"].size(), 2);
    }

    #[test]
    fn unknown_fleet_platform_is_typed() {
        let reg = PlatformRegistry::builtin();
        assert!(matches!(
            Inventory::from_fleet(&reg, &[("epyc", 2)]),
            Err(CimoneError::UnknownPlatform { .. })
        ));
    }

    #[test]
    fn inventory_carries_the_builtin_kernel_registry() {
        let inv = monte_cimone_v2();
        assert!(inv.kernels.contains("blis-lmul4"));
        assert!(inv.kernels.contains("blis-opt")); // aliases resolve too
        assert!(!inv.kernels.contains("mkl"));
    }

    #[test]
    fn fleet_fabric_defaults_to_the_leading_platforms_interconnect() {
        // the paper fleet rides the 1 GbE ToR; an MCv3 fleet its 10 GbE
        assert_eq!(monte_cimone_v2().fabric.id, "gbe-flat");
        let reg = PlatformRegistry::builtin();
        let inv = Inventory::from_fleet(&reg, &[("mcv3", 2)]).unwrap();
        assert_eq!(inv.fabric.id, "ten-gbe-flat");
    }

    #[test]
    fn explicit_fleet_fabric_overrides_the_platform_default() {
        let reg = PlatformRegistry::builtin();
        let inv = Inventory::from_fleet_on(
            &reg,
            &FabricRegistry::builtin(),
            &KernelRegistry::builtin(),
            &[("mcv2-pioneer", 4)],
            Some("10gbe"), // alias resolves too
        )
        .unwrap();
        assert_eq!(inv.fabric.id, "ten-gbe-flat");
    }

    #[test]
    fn fleet_wider_than_the_switch_is_typed_at_build_time() {
        let reg = PlatformRegistry::builtin();
        assert!(matches!(
            Inventory::from_fleet(&reg, &[("mcv2-pioneer", 17)]),
            Err(CimoneError::FabricTooSmall { ports: 16, nodes: 17, .. })
        ));
        // unknown fabric ids are typed too
        assert!(matches!(
            Inventory::from_fleet_on(
                &reg,
                &FabricRegistry::builtin(),
                &KernelRegistry::builtin(),
                &[("mcv2-pioneer", 2)],
                Some("infiniband"),
            ),
            Err(CimoneError::UnknownFabric { .. })
        ));
    }
}
