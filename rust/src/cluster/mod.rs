//! Cluster inventory, topology and monitoring — the Monte Cimone machine
//! itself as a simulated object: node fleet (MCv1 blades + MCv2 Pioneers +
//! the dual-socket SR1), the 1 GbE fabric, and an ExaMon-like metric sink.

pub mod inventory;
pub mod monitor;
pub mod node;
pub mod power;

pub use inventory::{monte_cimone_v2, Inventory};
pub use monitor::Monitor;
pub use node::Node;
