//! Cluster inventory, topology and monitoring — the Monte Cimone machine
//! itself as a simulated object: a node fleet built from `(platform_id,
//! count)` specs against the [`crate::arch::PlatformRegistry`] (the
//! paper's MCv1 blades + MCv2 Pioneers + dual-socket SR1 is
//! [`inventory::PAPER_FLEET`]), the interconnect it hangs off (a
//! resolved [`crate::net::Fabric`] — the paper's 1 GbE by default), and
//! an ExaMon-like metric sink.

pub mod inventory;
pub mod monitor;
pub mod node;
pub mod power;

pub use inventory::{monte_cimone_v2, Inventory};
pub use monitor::Monitor;
pub use node::Node;
