//! ExaMon-like monitoring sink: named time-series of metrics, queried by
//! the coordinator's reports (the paper integrates MCv2 into ExaMon for
//! exactly this role).

use std::collections::BTreeMap;

/// One sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub t: f64,
    pub value: f64,
}

/// The metric store.
#[derive(Debug, Default, Clone)]
pub struct Monitor {
    series: BTreeMap<String, Vec<Sample>>,
}

impl Monitor {
    pub fn new() -> Monitor {
        Monitor::default()
    }

    /// Record `metric` = `value` at time `t`. Metric names follow ExaMon's
    /// dotted convention, e.g. `node08.hpl.gflops`.
    pub fn record(&mut self, metric: &str, t: f64, value: f64) {
        self.series.entry(metric.to_string()).or_default().push(Sample { t, value });
    }

    pub fn series(&self, metric: &str) -> Option<&[Sample]> {
        self.series.get(metric).map(|v| v.as_slice())
    }

    pub fn latest(&self, metric: &str) -> Option<f64> {
        self.series.get(metric).and_then(|v| v.last()).map(|s| s.value)
    }

    pub fn mean(&self, metric: &str) -> Option<f64> {
        let s = self.series.get(metric)?;
        if s.is_empty() {
            return None;
        }
        Some(s.iter().map(|x| x.value).sum::<f64>() / s.len() as f64)
    }

    /// Largest recorded value of a series (NaN-safe total order).
    pub fn max(&self, metric: &str) -> Option<f64> {
        self.series.get(metric)?.iter().map(|x| x.value).max_by(f64::total_cmp)
    }

    /// All metrics matching a prefix (dotted-hierarchy query).
    pub fn query_prefix(&self, prefix: &str) -> Vec<(&str, f64)> {
        self.series
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .filter_map(|(k, v)| v.last().map(|s| (k.as_str(), s.value)))
            .collect()
    }

    pub fn metric_count(&self) -> usize {
        self.series.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut m = Monitor::new();
        m.record("node08.hpl.gflops", 0.0, 100.0);
        m.record("node08.hpl.gflops", 1.0, 139.4);
        assert_eq!(m.latest("node08.hpl.gflops"), Some(139.4));
        assert_eq!(m.mean("node08.hpl.gflops"), Some(119.7));
        assert_eq!(m.series("node08.hpl.gflops").unwrap().len(), 2);
    }

    #[test]
    fn prefix_query() {
        let mut m = Monitor::new();
        m.record("node08.power.w", 0.0, 120.0);
        m.record("node08.hpl.gflops", 0.0, 139.0);
        m.record("node09.power.w", 0.0, 118.0);
        let node8 = m.query_prefix("node08.");
        assert_eq!(node8.len(), 2);
    }

    #[test]
    fn missing_metric_is_none() {
        let m = Monitor::new();
        assert_eq!(m.latest("nope"), None);
        assert_eq!(m.mean("nope"), None);
        assert_eq!(m.max("nope"), None);
    }

    #[test]
    fn max_tracks_the_series_peak() {
        let mut m = Monitor::new();
        m.record("w", 0.0, 120.0);
        m.record("w", 1.0, 150.0);
        m.record("w", 2.0, 90.0);
        assert_eq!(m.max("w"), Some(150.0));
    }
}
