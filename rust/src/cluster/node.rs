//! One cluster node: hardware descriptor + identity + runtime state.

use crate::arch::soc::{NodeKind, SocDescriptor};

/// A named node in the fleet.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub hostname: String,
    pub desc: SocDescriptor,
    /// OS image, as the paper records it (Ubuntu 21.04 on MCv1, Fedora 38
    /// on MCv2).
    pub os: &'static str,
    pub up: bool,
}

impl Node {
    pub fn new(id: usize, hostname: impl Into<String>, desc: SocDescriptor) -> Node {
        let os = match desc.kind {
            NodeKind::Mcv1U740 => "Ubuntu 21.04",
            NodeKind::Mcv2Pioneer | NodeKind::Mcv2DualSocket => "Fedora 38",
        };
        Node { id, hostname: hostname.into(), desc, os, up: true }
    }

    pub fn cores(&self) -> usize {
        self.desc.total_cores()
    }

    pub fn peak_gflops(&self) -> f64 {
        self.desc.peak_flops() / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn os_follows_generation() {
        let v1 = Node::new(0, "mc-01", presets::u740());
        let v2 = Node::new(8, "mcv2-01", presets::sg2042());
        assert_eq!(v1.os, "Ubuntu 21.04");
        assert_eq!(v2.os, "Fedora 38");
    }

    #[test]
    fn peak_gflops_sane() {
        let v2 = Node::new(0, "x", presets::sg2042());
        assert!((v2.peak_gflops() - 512.0).abs() < 1.0);
    }
}
