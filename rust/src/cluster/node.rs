//! One cluster node: a platform instance with identity + runtime state.

use std::sync::Arc;

use crate::arch::platform::Platform;
use crate::arch::soc::SocDescriptor;

/// A named node in the fleet. Hardware, OS image and power model all
/// come from the shared [`Platform`] the node instantiates.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub hostname: String,
    pub platform: Arc<Platform>,
    pub up: bool,
}

impl Node {
    pub fn new(id: usize, hostname: impl Into<String>, platform: Arc<Platform>) -> Node {
        Node { id, hostname: hostname.into(), platform, up: true }
    }

    /// Hardware descriptor of this node's platform.
    pub fn desc(&self) -> &SocDescriptor {
        &self.platform.desc
    }

    /// OS image, as the fleet records it (Ubuntu 21.04 on MCv1, Fedora
    /// on MCv2 and later).
    pub fn os(&self) -> &str {
        &self.platform.os
    }

    pub fn cores(&self) -> usize {
        self.platform.desc.total_cores()
    }

    pub fn peak_gflops(&self) -> f64 {
        self.platform.desc.peak_flops() / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::platform;

    #[test]
    fn os_follows_generation() {
        let v1 = Node::new(0, "mc-01", Arc::new(platform::mcv1_u740()));
        let v2 = Node::new(8, "mcv2-01", Arc::new(platform::mcv2_pioneer()));
        assert_eq!(v1.os(), "Ubuntu 21.04");
        assert_eq!(v2.os(), "Fedora 38");
    }

    #[test]
    fn peak_gflops_sane() {
        let v2 = Node::new(0, "x", Arc::new(platform::mcv2_pioneer()));
        assert!((v2.peak_gflops() - 512.0).abs() < 1.0);
    }
}
