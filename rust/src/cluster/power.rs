//! Node power model (Monte Cimone has carried fine-grained power
//! monitoring since MCv1; we model socket power as idle + per-active-core
//! dynamic draw so efficiency tables can be produced).

use crate::arch::soc::{NodeKind, SocDescriptor};

/// Power parameters per node kind (published SG2042/U740 figures).
#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    pub idle_w: f64,
    pub per_core_active_w: f64,
}

impl PowerModel {
    pub fn for_kind(kind: NodeKind) -> PowerModel {
        match kind {
            // U740 SoC ~5 W + board overhead
            NodeKind::Mcv1U740 => PowerModel { idle_w: 25.0, per_core_active_w: 1.2 },
            // SG2042 TDP ~120 W/socket; Pioneer box idles ~60 W
            NodeKind::Mcv2Pioneer => PowerModel { idle_w: 60.0, per_core_active_w: 1.4 },
            NodeKind::Mcv2DualSocket => PowerModel { idle_w: 110.0, per_core_active_w: 1.4 },
        }
    }

    pub fn node_power(&self, active_cores: usize) -> f64 {
        self.idle_w + self.per_core_active_w * active_cores as f64
    }
}

/// GFLOP/s per watt for a given HPL rate.
pub fn efficiency_gflops_per_w(desc: &SocDescriptor, active_cores: usize, gflops: f64) -> f64 {
    let p = PowerModel::for_kind(desc.kind).node_power(active_cores);
    gflops / p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn power_scales_with_cores() {
        let pm = PowerModel::for_kind(NodeKind::Mcv2Pioneer);
        assert!(pm.node_power(64) > pm.node_power(1));
        assert!((pm.node_power(64) - (60.0 + 1.4 * 64.0)).abs() < 1e-9);
    }

    #[test]
    fn mcv2_more_efficient_than_mcv1() {
        // ~139 GF at ~150 W vs ~1.6 GF at ~30 W
        let v2 = efficiency_gflops_per_w(&presets::sg2042(), 64, 139.0);
        let v1 = efficiency_gflops_per_w(&presets::u740(), 4, 1.63);
        assert!(v2 > 10.0 * v1, "v2={v2:.3} v1={v1:.3}");
    }
}
