//! Node power accounting (Monte Cimone has carried fine-grained power
//! monitoring since MCv1). The [`PowerModel`] itself lives on the
//! [`Platform`] — idle + per-active-core dynamic draw, data-driven per
//! registered platform instead of matched on a closed enum — and this
//! module keeps the fleet-level efficiency helpers.

pub use crate::arch::platform::PowerModel;
use crate::arch::platform::Platform;

/// GFLOP/s per watt of one node of `platform` running `active_cores`
/// cores at `gflops`.
pub fn efficiency_gflops_per_w(platform: &Platform, active_cores: usize, gflops: f64) -> f64 {
    gflops / platform.power.node_power(active_cores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::platform;

    #[test]
    fn power_scales_with_cores() {
        let pm = platform::mcv2_pioneer().power;
        assert!(pm.node_power(64) > pm.node_power(1));
        assert!((pm.node_power(64) - (60.0 + 1.4 * 64.0)).abs() < 1e-9);
    }

    #[test]
    fn mcv2_more_efficient_than_mcv1() {
        // ~139 GF at ~150 W vs ~1.6 GF at ~30 W
        let v2 = efficiency_gflops_per_w(&platform::mcv2_pioneer(), 64, 139.0);
        let v1 = efficiency_gflops_per_w(&platform::mcv1_u740(), 4, 1.63);
        assert!(v2 > 10.0 * v1, "v2={v2:.3} v1={v1:.3}");
    }

    #[test]
    fn sg2044_generation_power_is_registered_data() {
        // new generations carry their own power model — no enum to extend
        let p = platform::sg2044();
        assert!(p.power.node_power(64) > p.power.idle_w);
        let e_new = efficiency_gflops_per_w(&p, 64, 250.0);
        let e_old = efficiency_gflops_per_w(&platform::mcv2_pioneer(), 64, 139.0);
        assert!(e_new > e_old, "new {e_new:.2} vs old {e_old:.2}");
    }
}
