//! Node power accounting (Monte Cimone has carried fine-grained power
//! monitoring since MCv1). The [`PowerModel`] itself lives on the
//! [`Platform`] — idle + per-active-core dynamic draw, data-driven per
//! registered platform instead of matched on a closed enum — and this
//! module keeps the fleet-level efficiency helpers.

pub use crate::arch::platform::PowerModel;
use crate::arch::platform::Platform;

/// GFLOP/s per watt of one node of `platform` running `active_cores`
/// cores at `gflops`.
pub fn efficiency_gflops_per_w(platform: &Platform, active_cores: usize, gflops: f64) -> f64 {
    gflops / platform.power.node_power(active_cores)
}

/// The most cores a node can run under a per-node power cap, given its
/// affine power model — the inverse of [`PowerModel::node_power`].
/// Returns `None` when even one active core exceeds the cap (the node
/// cannot host work at all at that operating point).
pub fn max_cores_under_cap(power: &PowerModel, cap_w: f64, total_cores: usize) -> Option<usize> {
    if power.node_power(1) > cap_w {
        return None;
    }
    if power.per_core_active_w <= 0.0 {
        return Some(total_cores);
    }
    // first guess by inverting the affine model, then settle on the
    // exact boundary against node_power itself: the division can land
    // one off when the cap sits exactly on a representable power level
    let guess = ((cap_w - power.idle_w) / power.per_core_active_w).floor();
    let mut fit =
        if guess.is_finite() && guess >= 1.0 { guess as usize } else { 1 }.min(total_cores);
    while power.node_power(fit) > cap_w {
        fit -= 1; // terminates: node_power(1) <= cap_w was checked above
    }
    while fit < total_cores && power.node_power(fit + 1) <= cap_w {
        fit += 1;
    }
    Some(fit)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::platform;

    #[test]
    fn power_scales_with_cores() {
        let pm = platform::mcv2_pioneer().power;
        assert!(pm.node_power(64) > pm.node_power(1));
        assert!((pm.node_power(64) - (60.0 + 1.4 * 64.0)).abs() < 1e-9);
    }

    #[test]
    fn mcv2_more_efficient_than_mcv1() {
        // ~139 GF at ~150 W vs ~1.6 GF at ~30 W
        let v2 = efficiency_gflops_per_w(&platform::mcv2_pioneer(), 64, 139.0);
        let v1 = efficiency_gflops_per_w(&platform::mcv1_u740(), 4, 1.63);
        assert!(v2 > 10.0 * v1, "v2={v2:.3} v1={v1:.3}");
    }

    #[test]
    fn power_cap_inverts_the_affine_model() {
        // mcv2-pioneer: 60 + 1.4c W; a 120 W cap fits floor(60/1.4) = 42
        let pm = platform::mcv2_pioneer().power;
        assert_eq!(max_cores_under_cap(&pm, 120.0, 64), Some(42));
        assert!(pm.node_power(42) <= 120.0);
        assert!(pm.node_power(43) > 120.0);
        // a generous cap clamps to the physical core count
        assert_eq!(max_cores_under_cap(&pm, 1e6, 64), Some(64));
        // the exact boundary is inclusive: 60 + 1.4 = 61.4 W at one core
        assert_eq!(max_cores_under_cap(&pm, 61.4, 64), Some(1));
        // ...and below it the node cannot host work at all
        assert_eq!(max_cores_under_cap(&pm, 61.0, 64), None);
    }

    #[test]
    fn sg2044_generation_power_is_registered_data() {
        // new generations carry their own power model — no enum to extend
        let p = platform::sg2044();
        assert!(p.power.node_power(64) > p.power.idle_w);
        let e_new = efficiency_gflops_per_w(&p, 64, 250.0);
        let e_old = efficiency_gflops_per_w(&platform::mcv2_pioneer(), 64, 139.0);
        assert!(e_new > e_old, "new {e_new:.2} vs old {e_old:.2}");
    }
}
