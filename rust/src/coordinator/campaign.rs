//! Declarative campaign descriptions: the benchmark campaign as *data*.
//!
//! A [`CampaignSpec`] is an ordered list of [`WorkloadSpec`] descriptors
//! plus the fleet it runs on and the real-numerics validation problem
//! size. It can be built in code ([`CampaignSpec::paper_default`]
//! reproduces the paper's 9-job campaign exactly) or parsed from a
//! `util::config` TOML-subset file ([`CampaignSpec::load`] /
//! [`CampaignSpec::from_config`]). Workloads and fleet entries name
//! platforms by [`PlatformRegistry`] id (or alias), so new scenarios —
//! SG2044 testbeds, Monte Cimone v3 projections, user-defined platform
//! variants — are config changes, not code changes.
//!
//! Spec file format (`cimone campaign --spec file.toml`):
//!
//! ```text
//! [campaign]
//! validate_n = 96          # real-numerics HPL validation size
//! # fabric = "ten-gbe-flat"  # optional machine interconnect
//!
//! [[platform]]             # optional: derive a custom platform
//! id = "sg2044-oc"
//! base = "sg2044"          # any registered id or alias
//! freq_ghz = 3.0           # see arch::platform for all override keys
//!
//! [[fabric]]               # optional: derive a custom interconnect
//! id = "gbe-8to1"
//! base = "gbe-flat"        # any registered fabric id or alias
//! backplane_factor = 0.125 # see net::fabric for all override keys
//!
//! [[kernel]]               # optional: derive a custom BLAS micro-kernel
//! id = "blis-rvv1-u8"
//! base = "blis-rvv1-lmul2" # any registered kernel id or alias
//! k_unroll = 8             # see ukernel::registry for all override keys
//! # family = "asm-source"  # hand-written kernels: add a listing via
//! # path = "dgemm.S"       #   a file next to the spec, or inline with
//! # source = '''           #   a multi-line literal
//! #     ...
//! # '''
//!
//! [[fleet]]                # optional: the machine to simulate;
//! platform = "sg2044"      # omitted => the paper's 12-node fleet
//! count = 4
//! # fabric = "gbe-8to1"    # machine interconnect (same as [campaign])
//!
//! [[workload]]
//! kind = "stream"          # stream | hpl | hpl-mxp | spmv | blis-ablation
//! name = "stream-sg2044"
//! platform = "sg2044"      # registry id or alias (`node` also accepted)
//! partition = "sg2044"
//! nodes = 1
//! threads = 64
//!
//! [[workload]]
//! kind = "hpl"
//! name = "hpl-sg2044-2n"
//! platform = "sg2044"
//! partition = "sg2044"
//! nodes = 2
//! cores_per_node = 64
//! # cluster_nodes = 2      # defaults to `nodes`
//! # lib = "openblas-c920"  # defaults to the platform's library
//! # fabric = "ten-gbe-flat" # defaults to the machine's fabric
//!
//! [[workload]]
//! kind = "hpl-mxp"         # mixed-precision HPL: the kernel rebuilt at
//! name = "mxp-sg2044"      #   SEW=32 (same optional keys as kind = "hpl")
//! platform = "sg2044"
//! partition = "sg2044"
//! cores_per_node = 64
//!
//! [[workload]]
//! kind = "spmv"            # HPCG-style sparse matrix-vector product
//! name = "spmv-sg2044"
//! platform = "sg2044"
//! partition = "sg2044"
//! threads = 64
//! # rows = 1048576         # HPCG reference problem: 2^20 rows...
//! # nnz_per_row = 27       # ...of a 27-point stencil...
//! # index_bytes = 4        # ...stored as int32 CSR
//!
//! [[workload]]
//! kind = "blis-ablation"
//! name = "hpl-blis-opt"
//! partition = "mcv2"
//! lib = "blis-opt"
//! cores = 128
//! # platform = "mcv2-dual" # default
//! # runtime_s = 3600
//!
//! [[queue]]                # optional: a production-shaped job stream
//! user = "alice"           # per-user accounting in the report
//! workload = "hpl-sg2044-2n" # template: any [[workload]] name
//! count = 100              # jobs in the stream
//! start_s = 0.0            # arrival of the first job
//! interval_s = 60.0        # spacing between arrivals (0 = all at once)
//! priority = 1             # higher runs first; default 0
//!
//! [[outage]]               # optional: node-availability ablation
//! node = 3                 # global node id (inventory order)
//! down_s = 100.0           # leaves service here (busy nodes drain)
//! up_s = 400.0             # returns here; omit to stay down
//! # repeat = 5             # link flap: this many windows...
//! # every = 1000.0         # ...spaced this far apart
//! ```

use std::path::Path;
use std::sync::Arc;

use crate::arch::platform::{Platform, PlatformRegistry};
use crate::cluster::inventory::{Inventory, PAPER_FLEET};
use crate::error::CimoneError;
use crate::mem::stream_model::SparseShape;
use crate::net::{Fabric, FabricRegistry};
use crate::ukernel::{KernelDescriptor, KernelFamily, KernelRegistry};
use crate::util::config::{Config, Section, Value};

use super::workload::{
    BlisAblationWorkload, HplMxpWorkload, HplWorkload, SparseSpmvWorkload, StreamWorkload,
    Workload,
};

/// One workload descriptor — plain data, buildable from code or config.
/// Platforms are named by registry id or alias.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    Stream { name: String, partition: String, nodes: usize, platform: String, threads: usize },
    Hpl {
        name: String,
        partition: String,
        nodes: usize,
        platform: String,
        cluster_nodes: usize,
        cores_per_node: usize,
        /// Kernel override (registry id); `None` uses the platform's
        /// `default_lib`.
        lib: Option<String>,
        /// Fabric override (registry id); `None` rides the machine fabric.
        fabric: Option<String>,
    },
    /// Mixed-precision HPL (HPL-MxP): same projection machinery as `Hpl`,
    /// with the job's kernel rebuilt at SEW=32 before projection.
    HplMxp {
        name: String,
        partition: String,
        nodes: usize,
        platform: String,
        cluster_nodes: usize,
        cores_per_node: usize,
        /// Kernel override (registry id); `None` uses the platform's
        /// `default_lib`.
        lib: Option<String>,
        /// Fabric override (registry id); `None` rides the machine fabric.
        fabric: Option<String>,
    },
    /// HPCG-style sparse matrix-vector product, bandwidth-bound through
    /// the platform's DDR stream model.
    Spmv {
        name: String,
        partition: String,
        nodes: usize,
        platform: String,
        threads: usize,
        /// CSR rows (HPCG reference: 2^20).
        rows: usize,
        /// Nonzeros per row (HPCG reference: the 27-point stencil).
        nnz_per_row: usize,
        /// CSR index width in bytes (4 = int32).
        index_bytes: usize,
    },
    BlisAblation {
        name: String,
        partition: String,
        platform: String,
        /// Kernel registry id of the ablated micro-kernel.
        lib: String,
        cores: usize,
        runtime_s: f64,
    },
}

impl WorkloadSpec {
    /// Job name of the described workload.
    pub fn name(&self) -> &str {
        match self {
            WorkloadSpec::Stream { name, .. }
            | WorkloadSpec::Hpl { name, .. }
            | WorkloadSpec::HplMxp { name, .. }
            | WorkloadSpec::Spmv { name, .. }
            | WorkloadSpec::BlisAblation { name, .. } => name,
        }
    }

    /// Spec-file kind keyword (`stream` | `hpl` | `hpl-mxp` | `spmv` |
    /// `blis-ablation`).
    pub fn kind(&self) -> &'static str {
        match self {
            WorkloadSpec::Stream { .. } => "stream",
            WorkloadSpec::Hpl { .. } => "hpl",
            WorkloadSpec::HplMxp { .. } => "hpl-mxp",
            WorkloadSpec::Spmv { .. } => "spmv",
            WorkloadSpec::BlisAblation { .. } => "blis-ablation",
        }
    }

    /// Nodes the described job allocates from its partition.
    pub fn nodes(&self) -> usize {
        match self {
            WorkloadSpec::Stream { nodes, .. }
            | WorkloadSpec::Hpl { nodes, .. }
            | WorkloadSpec::HplMxp { nodes, .. }
            | WorkloadSpec::Spmv { nodes, .. } => *nodes,
            WorkloadSpec::BlisAblation { .. } => 1,
        }
    }

    /// SLURM partition the described job is submitted to.
    pub fn partition(&self) -> &str {
        match self {
            WorkloadSpec::Stream { partition, .. }
            | WorkloadSpec::Hpl { partition, .. }
            | WorkloadSpec::HplMxp { partition, .. }
            | WorkloadSpec::Spmv { partition, .. }
            | WorkloadSpec::BlisAblation { partition, .. } => partition,
        }
    }

    /// Platform id (or alias) the workload targets.
    pub fn platform(&self) -> &str {
        match self {
            WorkloadSpec::Stream { platform, .. }
            | WorkloadSpec::Hpl { platform, .. }
            | WorkloadSpec::HplMxp { platform, .. }
            | WorkloadSpec::Spmv { platform, .. }
            | WorkloadSpec::BlisAblation { platform, .. } => platform,
        }
    }

    /// Instantiate the runnable workload this descriptor names.
    pub fn build(&self) -> Box<dyn Workload> {
        match self.clone() {
            WorkloadSpec::Stream { name, partition, nodes, platform, threads } => {
                Box::new(StreamWorkload { name, partition, nodes, platform, threads })
            }
            WorkloadSpec::Hpl {
                name,
                partition,
                nodes,
                platform,
                cluster_nodes,
                cores_per_node,
                lib,
                fabric,
            } => Box::new(HplWorkload {
                name,
                partition,
                nodes,
                platform,
                cluster_nodes,
                cores_per_node,
                lib,
                fabric,
            }),
            WorkloadSpec::HplMxp {
                name,
                partition,
                nodes,
                platform,
                cluster_nodes,
                cores_per_node,
                lib,
                fabric,
            } => Box::new(HplMxpWorkload {
                name,
                partition,
                nodes,
                platform,
                cluster_nodes,
                cores_per_node,
                lib,
                fabric,
            }),
            WorkloadSpec::Spmv {
                name,
                partition,
                nodes,
                platform,
                threads,
                rows,
                nnz_per_row,
                index_bytes,
            } => Box::new(SparseSpmvWorkload {
                name,
                partition,
                nodes,
                platform,
                threads,
                shape: SparseShape { rows, nnz_per_row, index_bytes },
            }),
            WorkloadSpec::BlisAblation { name, partition, platform, lib, cores, runtime_s } => {
                Box::new(BlisAblationWorkload { name, partition, platform, lib, cores, runtime_s })
            }
        }
    }

    /// Parse one `[[workload]]` section.
    pub fn from_section(sec: &Section) -> Result<WorkloadSpec, CimoneError> {
        let name = req_str(sec, "name", "?")?.to_string();
        let partition = req_str(sec, "partition", &name)?.to_string();
        let kind = req_str(sec, "kind", &name)?;
        // a misspelled key (or one the kind does not accept, like
        // `fabric` on a stream job) must be a load-time error, not a
        // silently ignored no-op
        let known: &[&str] = match kind {
            "stream" => &["kind", "name", "partition", "platform", "node", "nodes", "threads"],
            "hpl" | "hpl-mxp" => &[
                "kind",
                "name",
                "partition",
                "platform",
                "node",
                "nodes",
                "cluster_nodes",
                "cores_per_node",
                "lib",
                "fabric",
            ],
            "spmv" => &[
                "kind",
                "name",
                "partition",
                "platform",
                "node",
                "nodes",
                "threads",
                "rows",
                "nnz_per_row",
                "index_bytes",
            ],
            "blis-ablation" => {
                &["kind", "name", "partition", "platform", "node", "lib", "cores", "runtime_s"]
            }
            _ => &[], // unknown kinds are rejected below with their own error
        };
        if !known.is_empty() {
            if let Some(unknown) = sec.keys().find(|k| !known.contains(&k.as_str())) {
                return Err(CimoneError::Spec(format!(
                    "workload `{name}`: unknown key `{unknown}` for kind `{kind}` (known: {})",
                    known.join(", ")
                )));
            }
        }
        match kind {
            "stream" => Ok(WorkloadSpec::Stream {
                nodes: opt_usize(sec, "nodes", &name)?.unwrap_or(1),
                platform: req_platform(sec, &name)?,
                threads: opt_usize(sec, "threads", &name)?.ok_or_else(|| {
                    CimoneError::Spec(format!("workload `{name}`: missing `threads`"))
                })?,
                name,
                partition,
            }),
            "hpl" | "hpl-mxp" => {
                let nodes = opt_usize(sec, "nodes", &name)?.unwrap_or(1);
                let platform = req_platform(sec, &name)?;
                let cluster_nodes = opt_usize(sec, "cluster_nodes", &name)?.unwrap_or(nodes);
                let cores_per_node = opt_usize(sec, "cores_per_node", &name)?.ok_or_else(
                    || CimoneError::Spec(format!("workload `{name}`: missing `cores_per_node`")),
                )?;
                let lib = opt_lib(sec, &name)?;
                let fabric = opt_str(sec, "fabric", &name)?;
                if kind == "hpl" {
                    Ok(WorkloadSpec::Hpl {
                        platform,
                        cluster_nodes,
                        cores_per_node,
                        lib,
                        fabric,
                        nodes,
                        name,
                        partition,
                    })
                } else {
                    Ok(WorkloadSpec::HplMxp {
                        platform,
                        cluster_nodes,
                        cores_per_node,
                        lib,
                        fabric,
                        nodes,
                        name,
                        partition,
                    })
                }
            }
            "spmv" => Ok(WorkloadSpec::Spmv {
                nodes: opt_usize(sec, "nodes", &name)?.unwrap_or(1),
                platform: req_platform(sec, &name)?,
                threads: opt_usize(sec, "threads", &name)?.ok_or_else(|| {
                    CimoneError::Spec(format!("workload `{name}`: missing `threads`"))
                })?,
                rows: opt_usize(sec, "rows", &name)?.unwrap_or(1 << 20),
                nnz_per_row: opt_usize(sec, "nnz_per_row", &name)?.unwrap_or(27),
                index_bytes: opt_usize(sec, "index_bytes", &name)?.unwrap_or(4),
                name,
                partition,
            }),
            "blis-ablation" => Ok(WorkloadSpec::BlisAblation {
                platform: opt_platform(sec, &name)?.unwrap_or_else(|| "mcv2-dual".to_string()),
                lib: opt_lib(sec, &name)?.ok_or_else(|| {
                    CimoneError::Spec(format!("workload `{name}`: missing `lib`"))
                })?,
                cores: opt_usize(sec, "cores", &name)?.unwrap_or(128),
                runtime_s: sec
                    .get("runtime_s")
                    .map(|v| {
                        v.as_float().filter(|f| f.is_finite() && *f > 0.0).ok_or_else(|| {
                            CimoneError::Spec(format!(
                                "workload `{name}`: `runtime_s` must be a positive number"
                            ))
                        })
                    })
                    .transpose()?
                    .unwrap_or(3600.0),
                name,
                partition,
            }),
            other => Err(CimoneError::Spec(format!(
                "workload `{name}`: unknown kind `{other}` \
                 (stream | hpl | hpl-mxp | spmv | blis-ablation)"
            ))),
        }
    }

    /// Render back to a `[[workload]]` section;
    /// [`WorkloadSpec::from_section`] on the result reconstructs an equal
    /// value (every defaultable key is written out explicitly).
    pub fn render(&self) -> String {
        match self {
            WorkloadSpec::Stream { name, partition, nodes, platform, threads } => format!(
                "[[workload]]\nkind = \"stream\"\nname = \"{name}\"\nplatform = \"{platform}\"\n\
                 partition = \"{partition}\"\nnodes = {nodes}\nthreads = {threads}\n"
            ),
            WorkloadSpec::Hpl {
                name,
                partition,
                nodes,
                platform,
                cluster_nodes,
                cores_per_node,
                lib,
                fabric,
            } => {
                let mut s = format!(
                    "[[workload]]\nkind = \"hpl\"\nname = \"{name}\"\nplatform = \"{platform}\"\n\
                     partition = \"{partition}\"\nnodes = {nodes}\ncluster_nodes = {cluster_nodes}\n\
                     cores_per_node = {cores_per_node}\n"
                );
                if let Some(lib) = lib {
                    s.push_str(&format!("lib = \"{lib}\"\n"));
                }
                if let Some(fabric) = fabric {
                    s.push_str(&format!("fabric = \"{fabric}\"\n"));
                }
                s
            }
            WorkloadSpec::HplMxp {
                name,
                partition,
                nodes,
                platform,
                cluster_nodes,
                cores_per_node,
                lib,
                fabric,
            } => {
                let mut s = format!(
                    "[[workload]]\nkind = \"hpl-mxp\"\nname = \"{name}\"\nplatform = \"{platform}\"\n\
                     partition = \"{partition}\"\nnodes = {nodes}\ncluster_nodes = {cluster_nodes}\n\
                     cores_per_node = {cores_per_node}\n"
                );
                if let Some(lib) = lib {
                    s.push_str(&format!("lib = \"{lib}\"\n"));
                }
                if let Some(fabric) = fabric {
                    s.push_str(&format!("fabric = \"{fabric}\"\n"));
                }
                s
            }
            WorkloadSpec::Spmv {
                name,
                partition,
                nodes,
                platform,
                threads,
                rows,
                nnz_per_row,
                index_bytes,
            } => format!(
                "[[workload]]\nkind = \"spmv\"\nname = \"{name}\"\nplatform = \"{platform}\"\n\
                 partition = \"{partition}\"\nnodes = {nodes}\nthreads = {threads}\n\
                 rows = {rows}\nnnz_per_row = {nnz_per_row}\nindex_bytes = {index_bytes}\n"
            ),
            WorkloadSpec::BlisAblation { name, partition, platform, lib, cores, runtime_s } => {
                format!(
                    "[[workload]]\nkind = \"blis-ablation\"\nname = \"{name}\"\n\
                     platform = \"{platform}\"\npartition = \"{partition}\"\nlib = \"{lib}\"\n\
                     cores = {cores}\nruntime_s = {}\n",
                    fmt_float(*runtime_s)
                )
            }
        }
    }
}

/// Format a float so `util::config` re-parses it as a float (never an
/// int): integral values keep one decimal place.
pub(crate) fn fmt_float(v: f64) -> String {
    if v == v.trunc() && v.is_finite() {
        format!("{v:.1}")
    } else {
        format!("{v}")
    }
}

/// The spec value to write for a key whose parse runs back through a
/// unit conversion (e.g. `latency_us * 1e-6`): `forward` is the naive
/// inverse, but one rounding each way can land 1 ulp off `target`,
/// breaking the `parse(render()) == spec` guarantee. Nudge by ulps until
/// `back` reproduces `target` exactly — guaranteed to terminate on specs
/// that came through a section parser, where `target = back(v)` for some
/// writable `v` within a few ulps of `forward`.
fn exact_preimage(forward: f64, target: f64, back: impl Fn(f64) -> f64) -> f64 {
    if back(forward) == target {
        return forward;
    }
    let bits = forward.to_bits() as i64;
    for delta in 1..=4i64 {
        for cand in [f64::from_bits((bits - delta) as u64), f64::from_bits((bits + delta) as u64)]
        {
            if back(cand) == target {
                return cand;
            }
        }
    }
    forward
}

fn req_str<'a>(sec: &'a Section, key: &str, who: &str) -> Result<&'a str, CimoneError> {
    sec.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| CimoneError::Spec(format!("workload `{who}`: missing string key `{key}`")))
}

fn opt_str(sec: &Section, key: &str, who: &str) -> Result<Option<String>, CimoneError> {
    match sec.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| CimoneError::Spec(format!("workload `{who}`: `{key}` must be a string"))),
    }
}

/// Positive-integer key: 0 would flow into the models as a divisor and
/// produce infinite simulated runtimes.
fn opt_usize(sec: &Section, key: &str, who: &str) -> Result<Option<usize>, CimoneError> {
    match sec.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_int()
            .filter(|i| *i > 0)
            .map(|i| Some(i as usize))
            .ok_or_else(|| {
                CimoneError::Spec(format!("workload `{who}`: `{key}` must be a positive int"))
            }),
    }
}

/// The platform key: `platform = "..."` preferred, `node = "..."` kept as
/// the legacy spelling.
fn opt_platform(sec: &Section, who: &str) -> Result<Option<String>, CimoneError> {
    for key in ["platform", "node"] {
        if let Some(v) = sec.get(key) {
            return v
                .as_str()
                .map(|s| Some(s.to_string()))
                .ok_or_else(|| {
                    CimoneError::Spec(format!("workload `{who}`: `{key}` must be a string"))
                });
        }
    }
    Ok(None)
}

fn req_platform(sec: &Section, who: &str) -> Result<String, CimoneError> {
    opt_platform(sec, who)?.ok_or_else(|| {
        CimoneError::Spec(format!("workload `{who}`: missing string key `platform`"))
    })
}

/// The raw `lib =` key; canonicalization against the spec's kernel
/// registry (aliases -> id, unknown -> typed `UnknownKernel`) happens in
/// `CampaignSpec::from_config`, where custom `[[kernel]]`s are in scope.
fn opt_lib(sec: &Section, who: &str) -> Result<Option<String>, CimoneError> {
    match sec.get("lib") {
        None => Ok(None),
        Some(v) => v.as_str().map(|s| Some(s.to_string())).ok_or_else(|| {
            CimoneError::Spec(format!("workload `{who}`: `lib` must be a string"))
        }),
    }
}

/// One `[[queue]]` section: a production-shaped stream of jobs cloned
/// from a template `[[workload]]`, arriving on a fixed cadence under one
/// user's account. The scheduler drains these with FIFO + EASY-backfill
/// semantics, so queue specs turn the paper campaign into a multi-user
/// production scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct QueueSpec {
    /// Owning user (multi-tenant accounting in the report).
    pub user: String,
    /// Name of the `[[workload]]` used as the job template.
    pub workload: String,
    /// Number of jobs in the stream.
    pub count: usize,
    /// Arrival time of the first job (simulated seconds).
    pub start_s: f64,
    /// Spacing between consecutive arrivals (0 = all at once).
    pub interval_s: f64,
    /// Scheduler priority of every job in the stream (higher first).
    pub priority: i64,
}

impl QueueSpec {
    /// Parse one `[[queue]]` section.
    pub fn from_section(sec: &Section) -> Result<QueueSpec, CimoneError> {
        const KNOWN: &[&str] = &["user", "workload", "count", "start_s", "interval_s", "priority"];
        let err = |m: String| CimoneError::Spec(format!("[[queue]]: {m}"));
        if let Some(unknown) = sec.keys().find(|k| !KNOWN.contains(&k.as_str())) {
            return Err(err(format!("unknown key `{unknown}` (known: {})", KNOWN.join(", "))));
        }
        let str_key = |key: &str| -> Result<String, CimoneError> {
            sec.get(key)
                .and_then(Value::as_str)
                .map(str::to_string)
                .ok_or_else(|| err(format!("missing string key `{key}`")))
        };
        let time_key = |key: &str| -> Result<f64, CimoneError> {
            match sec.get(key) {
                None => Ok(0.0),
                Some(v) => v
                    .as_float()
                    .filter(|f| f.is_finite() && *f >= 0.0)
                    .ok_or_else(|| err(format!("`{key}` must be a non-negative number"))),
            }
        };
        let user = str_key("user")?;
        if user.is_empty() {
            return Err(err("`user` must be non-empty".into()));
        }
        let count = match sec.get("count") {
            None => 1,
            Some(v) => v
                .as_int()
                .filter(|i| *i > 0)
                .ok_or_else(|| err("`count` must be a positive int".into()))?
                as usize,
        };
        let priority = match sec.get("priority") {
            None => 0,
            Some(v) => v.as_int().ok_or_else(|| err("`priority` must be an int".into()))?,
        };
        Ok(QueueSpec {
            user,
            workload: str_key("workload")?,
            count,
            start_s: time_key("start_s")?,
            interval_s: time_key("interval_s")?,
            priority,
        })
    }

    /// Job name of the `i`-th job in the stream.
    pub fn job_name(&self, i: usize) -> String {
        format!("{}/{}.{i}", self.user, self.workload)
    }

    /// Arrival time of the `i`-th job in the stream.
    pub fn arrival_s(&self, i: usize) -> f64 {
        self.start_s + i as f64 * self.interval_s
    }

    /// Render back to a `[[queue]]` section; [`QueueSpec::from_section`]
    /// on the result reconstructs an equal value.
    pub fn render(&self) -> String {
        format!(
            "[[queue]]\nuser = \"{}\"\nworkload = \"{}\"\ncount = {}\nstart_s = {}\n\
             interval_s = {}\npriority = {}\n",
            self.user,
            self.workload,
            self.count,
            fmt_float(self.start_s),
            fmt_float(self.interval_s),
            self.priority
        )
    }
}

/// One `[[outage]]` section: a node-availability window (maintenance,
/// failure injection) or — with `repeat`/`every` — a flapping link that
/// takes the node out on a fixed cadence. Busy nodes drain gracefully:
/// the running job finishes before the node leaves service.
#[derive(Debug, Clone, PartialEq)]
pub struct OutageSpec {
    /// Global node id (inventory order).
    pub node: usize,
    /// When the node leaves service.
    pub down_s: f64,
    /// When it returns; `None` keeps it down for the whole campaign.
    pub up_s: Option<f64>,
    /// Number of down/up windows (link flap); 1 = a single outage.
    pub repeat: usize,
    /// Spacing between consecutive windows (required when `repeat` > 1).
    pub every: f64,
}

impl OutageSpec {
    /// Parse one `[[outage]]` section.
    pub fn from_section(sec: &Section) -> Result<OutageSpec, CimoneError> {
        const KNOWN: &[&str] = &["node", "down_s", "up_s", "repeat", "every"];
        let err = |m: String| CimoneError::Spec(format!("[[outage]]: {m}"));
        if let Some(unknown) = sec.keys().find(|k| !KNOWN.contains(&k.as_str())) {
            return Err(err(format!("unknown key `{unknown}` (known: {})", KNOWN.join(", "))));
        }
        let node = sec
            .get("node")
            .and_then(Value::as_int)
            .filter(|i| *i >= 0)
            .ok_or_else(|| err("missing or invalid `node` (non-negative int)".into()))?
            as usize;
        let down_s = sec
            .get("down_s")
            .map(|v| {
                v.as_float()
                    .filter(|f| f.is_finite() && *f >= 0.0)
                    .ok_or_else(|| err("`down_s` must be a non-negative number".into()))
            })
            .transpose()?
            .unwrap_or(0.0);
        let up_s = sec
            .get("up_s")
            .map(|v| {
                v.as_float().filter(|f| f.is_finite() && *f > down_s).ok_or_else(|| {
                    err(format!("`up_s` must be a finite number > down_s ({down_s})"))
                })
            })
            .transpose()?;
        let repeat = match sec.get("repeat") {
            None => 1,
            Some(v) => v
                .as_int()
                .filter(|i| *i >= 1)
                .ok_or_else(|| err("`repeat` must be a positive int".into()))?
                as usize,
        };
        let every = match sec.get("every") {
            None => 0.0,
            Some(v) => v
                .as_float()
                .filter(|f| f.is_finite() && *f >= 0.0)
                .ok_or_else(|| err("`every` must be a non-negative number".into()))?,
        };
        if repeat > 1 {
            let up = up_s
                .ok_or_else(|| err("`repeat` > 1 needs `up_s` (flap windows must close)".into()))?;
            if every <= 0.0 {
                return Err(err("`repeat` > 1 needs `every` > 0 (window spacing)".into()));
            }
            if every < up - down_s {
                return Err(err(format!(
                    "`every` ({every}) must cover the window (up_s - down_s = {})",
                    up - down_s
                )));
            }
        }
        Ok(OutageSpec { node, down_s, up_s, repeat, every })
    }

    /// The expanded `(down, up)` windows this outage describes, in time
    /// order (window `k` is shifted by `k * every`).
    pub fn windows(&self) -> Vec<(f64, Option<f64>)> {
        (0..self.repeat)
            .map(|k| {
                let shift = k as f64 * self.every;
                (self.down_s + shift, self.up_s.map(|u| u + shift))
            })
            .collect()
    }

    /// Render back to an `[[outage]]` section; [`OutageSpec::from_section`]
    /// on the result reconstructs an equal value.
    pub fn render(&self) -> String {
        let mut s =
            format!("[[outage]]\nnode = {}\ndown_s = {}\n", self.node, fmt_float(self.down_s));
        if let Some(up) = self.up_s {
            s.push_str(&format!("up_s = {}\n", fmt_float(up)));
        }
        if self.repeat != 1 {
            s.push_str(&format!("repeat = {}\n", self.repeat));
        }
        if self.every != 0.0 {
            s.push_str(&format!("every = {}\n", fmt_float(self.every)));
        }
        s
    }
}

/// One `[[platform]]` definition: the derived [`Platform`] plus the base
/// it was derived from, kept so the spec can render itself back to
/// config text as `base` + overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformDef {
    /// Registry id (or alias) the platform derives from.
    pub base: String,
    pub platform: Platform,
}

/// One `[[fabric]]` definition: the derived [`Fabric`] plus the base it
/// was derived from, kept so the spec can render itself back to config
/// text as `base` + overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricDef {
    /// Registry id (or alias) the fabric derives from.
    pub base: String,
    pub fabric: Fabric,
}

/// One `[[kernel]]` definition: the derived [`KernelDescriptor`] plus
/// the base it was derived from, kept so the spec can render itself
/// back to config text as `base` + overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelDef {
    /// Registry id (or alias) the kernel derives from.
    pub base: String,
    pub kernel: KernelDescriptor,
}

/// A full campaign: ordered workloads, the fleet they run on, and the
/// validation problem size.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignSpec {
    pub workloads: Vec<WorkloadSpec>,
    /// Problem size for the real-numerics HPL validation run that anchors
    /// the campaign's modelled numbers in executed arithmetic.
    pub validate_n: usize,
    /// `(platform_id, count)` fleet to simulate; empty means the paper's
    /// 12-node machine ([`PAPER_FLEET`]).
    pub fleet: Vec<(String, usize)>,
    /// Platforms defined by `[[platform]]` sections, registered on top of
    /// the built-ins when the spec builds its registry/inventory.
    pub custom_platforms: Vec<PlatformDef>,
    /// Machine interconnect (fabric registry id); `None` falls back to
    /// the leading fleet platform's `default_fabric`.
    pub fabric: Option<String>,
    /// Fabrics defined by `[[fabric]]` sections, registered on top of
    /// the built-ins when the spec builds its fabric registry.
    pub custom_fabrics: Vec<FabricDef>,
    /// Micro-kernels defined by `[[kernel]]` sections, registered on
    /// top of the built-ins when the spec builds its kernel registry.
    pub custom_kernels: Vec<KernelDef>,
    /// Production-shaped job streams (`[[queue]]` sections), expanded by
    /// the campaign driver into per-user arrival sequences.
    pub queues: Vec<QueueSpec>,
    /// Node-availability windows (`[[outage]]` sections), applied to the
    /// scheduler before the campaign's jobs are submitted.
    pub outages: Vec<OutageSpec>,
}

impl Default for CampaignSpec {
    fn default() -> Self {
        CampaignSpec {
            workloads: Vec::new(),
            validate_n: 96,
            fleet: Vec::new(),
            custom_platforms: Vec::new(),
            fabric: None,
            custom_fabrics: Vec::new(),
            custom_kernels: Vec::new(),
            queues: Vec::new(),
            outages: Vec::new(),
        }
    }
}

impl CampaignSpec {
    /// Empty campaign (drains to a zero makespan).
    pub fn new() -> CampaignSpec {
        CampaignSpec::default()
    }

    pub fn push(&mut self, w: WorkloadSpec) {
        self.workloads.push(w);
    }

    pub fn len(&self) -> usize {
        self.workloads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workloads.is_empty()
    }

    /// The paper's own campaign: STREAM on each node kind (Fig 3), HPL on
    /// the four node configurations (Fig 5), and the BLIS micro-kernel
    /// ablation at 128 cores (Fig 7) — 9 jobs, in figure order.
    pub fn paper_default() -> CampaignSpec {
        let mut spec = CampaignSpec::new();
        for (name, platform, partition, threads) in [
            ("stream-mcv1", "mcv1-u740", "mcv1", 4usize),
            ("stream-mcv2-1s", "mcv2-pioneer", "mcv2", 64),
            ("stream-mcv2-2s", "mcv2-dual", "mcv2", 64),
        ] {
            spec.push(WorkloadSpec::Stream {
                name: name.into(),
                partition: partition.into(),
                nodes: 1,
                platform: platform.into(),
                threads,
            });
        }
        for (name, partition, nodes, platform, cores_per_node, lib) in [
            ("hpl-mcv1-full", "mcv1", 8usize, "mcv1-u740", 4usize, Some("openblas-generic")),
            ("hpl-mcv2-1s", "mcv2", 1, "mcv2-pioneer", 64, None),
            ("hpl-mcv2-2n", "mcv2", 2, "mcv2-pioneer", 64, None),
            ("hpl-mcv2-2s", "mcv2", 1, "mcv2-dual", 128, None),
        ] {
            spec.push(WorkloadSpec::Hpl {
                name: name.into(),
                partition: partition.into(),
                nodes,
                platform: platform.into(),
                cluster_nodes: nodes,
                cores_per_node,
                lib: lib.map(str::to_string),
                fabric: None,
            });
        }
        for (name, lib) in [("hpl-blis-vanilla", "blis-lmul1"), ("hpl-blis-opt", "blis-lmul4")] {
            spec.push(WorkloadSpec::BlisAblation {
                name: name.into(),
                partition: "mcv2".into(),
                platform: "mcv2-dual".into(),
                lib: lib.into(),
                cores: 128,
                runtime_s: 3600.0,
            });
        }
        spec
    }

    /// Build a campaign from a parsed config: `[campaign]` scalars,
    /// optional `[[platform]]` definitions and `[[fleet]]` entries, plus
    /// one `[[workload]]` table per job. Platform names (fleet and
    /// workloads) are checked against the spec's own registry here, so a
    /// typo is a typed error at load time, not at estimation time.
    pub fn from_config(cfg: &Config) -> Result<CampaignSpec, CimoneError> {
        let mut spec = CampaignSpec::new();
        // a misspelled [campaign] key (e.g. `fabrik`) must not silently
        // run the campaign on the wrong interconnect or validation size
        if let Some(sec) = cfg.sections.get("campaign") {
            if let Some(unknown) =
                sec.keys().find(|k| !["validate_n", "fabric"].contains(&k.as_str()))
            {
                return Err(CimoneError::Spec(format!(
                    "[campaign]: unknown key `{unknown}` (known: validate_n, fabric)"
                )));
            }
        }
        if let Some(v) = cfg.get("campaign.validate_n") {
            spec.validate_n = v
                .as_int()
                .filter(|i| *i > 0)
                .ok_or_else(|| {
                    CimoneError::Spec("campaign.validate_n must be a positive int".into())
                })? as usize;
        }
        // fabrics first: platforms and fleet entries may reference them
        let mut freg = FabricRegistry::builtin();
        for sec in cfg.table_arrays.get("fabric").map(Vec::as_slice).unwrap_or(&[]) {
            let base = sec.get("base").and_then(Value::as_str).unwrap_or_default().to_string();
            let f = freg.register_section(sec)?;
            spec.custom_fabrics.push(FabricDef { base, fabric: (*f).clone() });
        }
        if let Some(v) = cfg.get("campaign.fabric") {
            let s = v
                .as_str()
                .ok_or_else(|| CimoneError::Spec("campaign.fabric must be a string".into()))?;
            // canonicalize aliases to the registry id at load time
            spec.fabric = Some(freg.get(s)?.id.clone());
        }
        // kernels next: platforms and workloads may reference them; an
        // asm-source kernel's `path =` listing resolves relative to the
        // spec file itself (when the config knows where it came from)
        let spec_dir = cfg.origin.as_deref().and_then(|p| Path::new(p).parent());
        let mut kreg = KernelRegistry::builtin();
        for sec in cfg.table_arrays.get("kernel").map(Vec::as_slice).unwrap_or(&[]) {
            let base = sec.get("base").and_then(Value::as_str).unwrap_or_default().to_string();
            let k = kreg.register_section_with_dir(sec, spec_dir)?;
            spec.custom_kernels.push(KernelDef { base, kernel: (*k).clone() });
        }
        let mut reg = PlatformRegistry::builtin();
        for sec in cfg.table_arrays.get("platform").map(Vec::as_slice).unwrap_or(&[]) {
            // `base` is re-read here (register_section already validates
            // its presence) so the def can render itself back to text
            let base = sec.get("base").and_then(Value::as_str).unwrap_or_default().to_string();
            let p = reg.register_section(sec)?;
            // a custom platform's default_fabric and default_lib must
            // resolve, here at load time, against the spec's own
            // registries
            freg.get(&p.default_fabric)?;
            kreg.get(&p.default_lib)?;
            spec.custom_platforms.push(PlatformDef { base, platform: (*p).clone() });
        }
        for sec in cfg.table_arrays.get("fleet").map(Vec::as_slice).unwrap_or(&[]) {
            // a misspelled key (e.g. `cout`) must not silently default
            if let Some(unknown) = sec
                .keys()
                .find(|k| !["platform", "count", "fabric"].contains(&k.as_str()))
            {
                return Err(CimoneError::Spec(format!(
                    "[[fleet]]: unknown key `{unknown}` (known: platform, count, fabric)"
                )));
            }
            let platform = req_str(sec, "platform", "[[fleet]]")?.to_string();
            let count = opt_usize(sec, "count", "[[fleet]]")?.unwrap_or(1);
            // resolve now so a bad fleet entry fails at load time
            reg.get(&platform)?;
            if let Some(f) = opt_str(sec, "fabric", "[[fleet]]")? {
                let id = freg.get(&f)?.id.clone();
                // one machine, one wire: conflicting fabric keys are a typo
                if let Some(prev) = &spec.fabric {
                    if *prev != id {
                        return Err(CimoneError::Spec(format!(
                            "conflicting machine fabrics `{prev}` and `{id}` \
                             (the fleet shares one interconnect)"
                        )));
                    }
                }
                spec.fabric = Some(id);
            }
            spec.fleet.push((platform, count));
        }
        for sec in cfg.table_arrays.get("workload").map(Vec::as_slice).unwrap_or(&[]) {
            let mut w = WorkloadSpec::from_section(sec)?;
            reg.get(w.platform())?;
            // canonicalize the per-job fabric override (typed if unknown)
            match &mut w {
                WorkloadSpec::Hpl { fabric: Some(f), .. }
                | WorkloadSpec::HplMxp { fabric: Some(f), .. } => {
                    *f = freg.get(f)?.id.clone();
                }
                _ => {}
            }
            // ...and the kernel names (aliases -> registry ids, unknown
            // kernels typed at load time, custom [[kernel]]s in scope)
            match &mut w {
                WorkloadSpec::Hpl { lib: Some(l), .. }
                | WorkloadSpec::HplMxp { lib: Some(l), .. }
                | WorkloadSpec::BlisAblation { lib: l, .. } => {
                    *l = kreg.get(l)?.id.clone();
                }
                _ => {}
            }
            spec.push(w);
        }
        for sec in cfg.table_arrays.get("queue").map(Vec::as_slice).unwrap_or(&[]) {
            spec.queues.push(QueueSpec::from_section(sec)?);
        }
        for sec in cfg.table_arrays.get("outage").map(Vec::as_slice).unwrap_or(&[]) {
            spec.outages.push(OutageSpec::from_section(sec)?);
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Cross-workload invariants: unique job names, resolvable fabrics
    /// and kernels, and a switch port per node (machine-wide and per HPL
    /// job). Called by the config loaders and again by the engine, so
    /// code-built specs are held to the same rules.
    pub fn validate(&self) -> Result<(), CimoneError> {
        let mut seen = std::collections::BTreeSet::new();
        for w in &self.workloads {
            if !seen.insert(w.name()) {
                return Err(CimoneError::Spec(format!("duplicate workload name `{}`", w.name())));
            }
        }
        // kernel fit: every named library must resolve (typed
        // UnknownKernel at load time, not mid-estimation)
        let kreg = self.kernel_registry()?;
        for w in &self.workloads {
            match w {
                WorkloadSpec::Hpl { lib: Some(l), .. }
                | WorkloadSpec::HplMxp { lib: Some(l), .. }
                | WorkloadSpec::BlisAblation { lib: l, .. } => {
                    kreg.get(l)?;
                }
                _ => {}
            }
        }
        // fabric fit: the whole fleet must hang off the machine switch,
        // and every per-job override must carry that job's HPL cluster —
        // typed errors here, at load time, instead of a port-array panic
        // inside `Switch::flows_time` mid-sweep
        let freg = self.fabric_registry()?;
        let machine = self.resolve_fabric(&freg)?;
        let fleet_nodes: usize = if self.fleet.is_empty() {
            PAPER_FLEET.iter().map(|(_, c)| *c).sum()
        } else {
            self.fleet.iter().map(|(_, c)| *c).sum()
        };
        machine.validate_cluster(fleet_nodes)?;
        for w in &self.workloads {
            match w {
                WorkloadSpec::Hpl { fabric, cluster_nodes, .. }
                | WorkloadSpec::HplMxp { fabric, cluster_nodes, .. } => {
                    let f = match fabric {
                        Some(id) => freg.get(id)?,
                        None => Arc::clone(&machine),
                    };
                    f.validate_cluster(*cluster_nodes)?;
                }
                _ => {}
            }
        }
        // queue templates must name a workload in this spec, and a
        // (user, template) pair must be unique — its jobs are named
        // `user/template.i`, which must not collide between streams
        let mut queue_ids = std::collections::BTreeSet::new();
        for q in &self.queues {
            if !self.workloads.iter().any(|w| w.name() == q.workload) {
                return Err(CimoneError::Spec(format!(
                    "queue for user `{}`: no workload named `{}` to use as a template",
                    q.user, q.workload
                )));
            }
            if !queue_ids.insert((q.user.as_str(), q.workload.as_str())) {
                return Err(CimoneError::Spec(format!(
                    "duplicate queue `{}/{}` (merge the streams or rename the user)",
                    q.user, q.workload
                )));
            }
        }
        // outages must name a node the fleet actually has
        for o in &self.outages {
            if o.node >= fleet_nodes {
                return Err(CimoneError::Spec(format!(
                    "outage references node {} but the fleet has {fleet_nodes} nodes",
                    o.node
                )));
            }
        }
        Ok(())
    }

    /// The platform registry this spec runs against: the built-in fleet
    /// plus any `[[platform]]` definitions.
    pub fn registry(&self) -> Result<PlatformRegistry, CimoneError> {
        let mut reg = PlatformRegistry::builtin();
        for def in &self.custom_platforms {
            reg.register(def.platform.clone())?;
        }
        Ok(reg)
    }

    /// The fabric registry this spec runs against: the built-in fabrics
    /// plus any `[[fabric]]` definitions.
    pub fn fabric_registry(&self) -> Result<FabricRegistry, CimoneError> {
        let mut reg = FabricRegistry::builtin();
        for def in &self.custom_fabrics {
            reg.register(def.fabric.clone())?;
        }
        Ok(reg)
    }

    /// The micro-kernel registry this spec runs against: the built-in
    /// kernels plus any `[[kernel]]` definitions.
    pub fn kernel_registry(&self) -> Result<KernelRegistry, CimoneError> {
        let mut reg = KernelRegistry::builtin();
        for def in &self.custom_kernels {
            reg.register(def.kernel.clone())?;
        }
        Ok(reg)
    }

    /// The machine interconnect: the spec's explicit `fabric` key, or the
    /// leading fleet platform's `default_fabric`, or the paper's 1 GbE.
    fn resolve_fabric(&self, freg: &FabricRegistry) -> Result<Arc<Fabric>, CimoneError> {
        match &self.fabric {
            Some(id) => freg.get(id),
            None => {
                let first = self.fleet.first().map(|(p, _)| p.as_str());
                match first {
                    Some(pid) => freg.get(&self.registry()?.get(pid)?.default_fabric),
                    // the paper fleet leads with MCv1 -> gbe-flat
                    None => freg.get("gbe-flat"),
                }
            }
        }
    }

    /// Build the inventory this spec describes: its `[[fleet]]` entries
    /// resolved against [`Self::registry`], or the paper's machine when
    /// no fleet is given, hanging off the spec's resolved fabric.
    pub fn build_inventory(&self) -> Result<Inventory, CimoneError> {
        let reg = self.registry()?;
        let freg = self.fabric_registry()?;
        // workload `lib =` keys and platform defaults resolve against
        // the spec's own kernels ([[kernel]] sections included)
        let kreg = self.kernel_registry()?;
        if self.fleet.is_empty() {
            Inventory::from_fleet_on(&reg, &freg, &kreg, PAPER_FLEET, self.fabric.as_deref())
        } else {
            Inventory::from_fleet_on(&reg, &freg, &kreg, &self.fleet, self.fabric.as_deref())
        }
    }

    /// Parse a spec from config text.
    pub fn parse(text: &str) -> Result<CampaignSpec, CimoneError> {
        let cfg = Config::parse(text).map_err(CimoneError::Spec)?;
        CampaignSpec::from_config(&cfg)
    }

    /// Load a spec file from disk.
    pub fn load(path: &str) -> Result<CampaignSpec, CimoneError> {
        let cfg = Config::load(path).map_err(CimoneError::Spec)?;
        CampaignSpec::from_config(&cfg)
    }

    /// Render the spec back to spec-file text. `CampaignSpec::parse` on
    /// the result reconstructs an equal spec: workloads and fleet render
    /// every key explicitly, `[[platform]]` definitions render as their
    /// base plus only the overridden keys (so inherited fields stay
    /// bit-identical through the round-trip).
    pub fn render(&self) -> String {
        let mut out = format!("[campaign]\nvalidate_n = {}\n", self.validate_n);
        if let Some(fabric) = &self.fabric {
            out.push_str(&format!("fabric = \"{fabric}\"\n"));
        }
        let mut freg = FabricRegistry::builtin();
        for def in &self.custom_fabrics {
            out.push('\n');
            out.push_str(&render_fabric_def(&mut freg, def));
        }
        let mut kreg = KernelRegistry::builtin();
        for def in &self.custom_kernels {
            out.push('\n');
            out.push_str(&render_kernel_def(&mut kreg, def));
        }
        let mut reg = PlatformRegistry::builtin();
        for def in &self.custom_platforms {
            out.push('\n');
            out.push_str(&render_platform_def(&mut reg, def));
        }
        for (platform, count) in &self.fleet {
            out.push_str(&format!("\n[[fleet]]\nplatform = \"{platform}\"\ncount = {count}\n"));
        }
        for w in &self.workloads {
            out.push('\n');
            out.push_str(&w.render());
        }
        for q in &self.queues {
            out.push('\n');
            out.push_str(&q.render());
        }
        for o in &self.outages {
            out.push('\n');
            out.push_str(&o.render());
        }
        out
    }
}

/// Render one `[[platform]]` definition as `base` + the overrides that
/// differ from what `PlatformRegistry::register_section` would derive
/// with no overrides at all. The actual platform is then registered into
/// `reg` so later definitions can use it as their own base.
///
/// Precondition (guaranteed for specs built by `from_config`, which
/// derives every def through `register_section`): `def.base` resolves in
/// `reg`. A hand-built def with a bogus base renders as id + base only —
/// such text cannot re-parse anyway, since `register_section` would
/// reject the unknown base with a typed error.
fn render_platform_def(reg: &mut PlatformRegistry, def: &PlatformDef) -> String {
    let p = &def.platform;
    let mut s = format!("[[platform]]\nid = \"{}\"\nbase = \"{}\"\n", p.id, def.base);
    if let Ok(base) = reg.get(&def.base) {
        // the no-override derivation, mirroring register_section
        let mut d = (*base).clone();
        let base_label = d.label.clone();
        d.id = p.id.clone();
        d.aliases = Vec::new();
        d.label = format!("{} (custom, from {base_label})", p.id);
        d.host_prefix = p.id.clone();

        for (key, actual, default) in [
            ("label", &p.label, &d.label),
            ("partition", &p.partition, &d.partition),
            ("os", &p.os, &d.os),
            ("host_prefix", &p.host_prefix, &d.host_prefix),
            ("default_fabric", &p.default_fabric, &d.default_fabric),
        ] {
            if actual != default {
                s.push_str(&format!("{key} = \"{actual}\"\n"));
            }
        }
        if p.default_lib != d.default_lib {
            s.push_str(&format!("default_lib = \"{}\"\n", p.default_lib));
        }
        if p.desc.sockets.len() != d.desc.sockets.len() {
            s.push_str(&format!("sockets = {}\n", p.desc.sockets.len()));
        }
        let (a, b) = (&p.desc.sockets[0], &d.desc.sockets[0]);
        if a.cores != b.cores {
            s.push_str(&format!("cores = {}\n", a.cores));
        }
        if a.core.freq_hz != b.core.freq_hz {
            s.push_str(&format!("freq_ghz = {}\n", fmt_float(a.core.freq_hz / 1e9)));
        }
        if a.mem.capacity_bytes != b.mem.capacity_bytes {
            let gb = a.mem.capacity_bytes as f64 / (1u64 << 30) as f64;
            s.push_str(&format!("mem_gb = {}\n", fmt_float(gb)));
        }
        if a.mem.channels != b.mem.channels {
            s.push_str(&format!("channels = {}\n", a.mem.channels));
        }
        if a.mem.channel_bw_bytes != b.mem.channel_bw_bytes {
            s.push_str(&format!("channel_bw_gb = {}\n", fmt_float(a.mem.channel_bw_bytes / 1e9)));
        }
        if a.mem.efficiency != b.mem.efficiency {
            s.push_str(&format!("mem_efficiency = {}\n", fmt_float(a.mem.efficiency)));
        }
        if a.mem.per_core_bw_bytes != b.mem.per_core_bw_bytes {
            s.push_str(&format!("per_core_bw_gb = {}\n", fmt_float(a.mem.per_core_bw_bytes / 1e9)));
        }
        for (key, actual, default) in [
            ("numa_penalty", p.desc.numa_penalty, d.desc.numa_penalty),
            ("idle_w", p.power.idle_w, d.power.idle_w),
            ("per_core_w", p.power.per_core_active_w, d.power.per_core_active_w),
            (
                "traffic_bytes_per_flop",
                p.calib.traffic_bytes_per_flop,
                d.calib.traffic_bytes_per_flop,
            ),
            ("smp_alpha", p.calib.smp_alpha, d.calib.smp_alpha),
            ("bw_gamma", p.calib.bw_gamma, d.calib.bw_gamma),
        ] {
            if actual != default {
                s.push_str(&format!("{key} = {}\n", fmt_float(actual)));
            }
        }
    }
    // later [[platform]] sections may derive from this one
    let _ = reg.register(p.clone());
    s
}

/// Render one `[[fabric]]` definition as `base` + the overrides that
/// differ from what `FabricRegistry::register_section` would derive with
/// no overrides at all — the fabric analogue of [`render_platform_def`],
/// with the same precondition on `def.base`.
fn render_fabric_def(reg: &mut FabricRegistry, def: &FabricDef) -> String {
    let f = &def.fabric;
    let mut s = format!("[[fabric]]\nid = \"{}\"\nbase = \"{}\"\n", f.id, def.base);
    if let Ok(base) = reg.get(&def.base) {
        // the no-override derivation, mirroring register_section
        let mut d = (*base).clone();
        let base_label = d.label.clone();
        d.id = f.id.clone();
        d.aliases = Vec::new();
        d.label = format!("{} (custom, from {base_label})", f.id);

        if f.label != d.label {
            s.push_str(&format!("label = \"{}\"\n", f.label));
        }
        // unit-converted keys go through exact_preimage: the naive
        // inverse of the parse-side conversion can be 1 ulp off, which
        // would break the parse(render()) == spec equality
        if f.link.raw_bps != d.link.raw_bps {
            let gbps = exact_preimage(f.link.raw_bps / 1e9, f.link.raw_bps, |g| g * 1e9);
            s.push_str(&format!("raw_gbps = {}\n", fmt_float(gbps)));
        }
        if f.link.latency_s != d.link.latency_s {
            let us = exact_preimage(f.link.latency_s * 1e6, f.link.latency_s, |us| us * 1e-6);
            s.push_str(&format!("latency_us = {}\n", fmt_float(us)));
        }
        for (key, actual, default) in [
            ("efficiency", f.link.efficiency, d.link.efficiency),
            ("backplane_factor", f.backplane_factor, d.backplane_factor),
        ] {
            if actual != default {
                s.push_str(&format!("{key} = {}\n", fmt_float(actual)));
            }
        }
        if f.ports != d.ports {
            s.push_str(&format!("ports = {}\n", f.ports));
        }
    }
    // later [[fabric]] sections may derive from this one
    let _ = reg.register(f.clone());
    s
}

/// Render one `[[kernel]]` definition as `base` + the overrides that
/// differ from what `KernelRegistry::register_section` would derive with
/// no overrides at all — the kernel analogue of [`render_platform_def`],
/// with the same precondition on `def.base`.
fn render_kernel_def(reg: &mut KernelRegistry, def: &KernelDef) -> String {
    let k = &def.kernel;
    let mut s = format!("[[kernel]]\nid = \"{}\"\nbase = \"{}\"\n", k.id, def.base);
    if let Ok(base) = reg.get(&def.base) {
        // the no-override derivation, mirroring register_section
        let mut d = (*base).clone();
        let base_label = d.label.clone();
        d.id = k.id.clone();
        d.aliases = Vec::new();
        d.label = format!("{} (custom, from {base_label})", k.id);
        // mirror register_section: a non-asm family never inherits a
        // listing from its base
        if d.family != KernelFamily::AsmSource {
            d.asm = None;
        }

        if k.label != d.label {
            s.push_str(&format!("label = \"{}\"\n", k.label));
        }
        if k.family != d.family {
            s.push_str(&format!("family = \"{}\"\n", k.family.spec_name()));
        }
        if k.vlen_bits != d.vlen_bits {
            s.push_str(&format!("vlen = {}\n", k.vlen_bits));
        }
        if k.lmul != d.lmul {
            s.push_str(&format!("lmul = {}\n", k.lmul.multiplier()));
        }
        if k.sew != d.sew {
            s.push_str(&format!("sew = {}\n", k.sew.bits()));
        }
        if k.mr != d.mr {
            s.push_str(&format!("mr = {}\n", k.mr));
        }
        if k.nr != d.nr {
            s.push_str(&format!("nr = {}\n", k.nr));
        }
        if k.k_unroll != d.k_unroll {
            s.push_str(&format!("k_unroll = {}\n", k.k_unroll));
        }
        if k.blocking != d.blocking {
            s.push_str(&format!("blocking = \"{}\"\n", k.blocking.spec_name()));
        }
        if k.host_overhead != d.host_overhead {
            s.push_str(&format!("host_overhead = {}\n", fmt_float(k.host_overhead)));
        }
        if k.native_rvv10 != d.native_rvv10 {
            s.push_str(&format!("native_rvv10 = {}\n", k.native_rvv10));
        }
        if k.asm != d.asm {
            if let Some(a) = &k.asm {
                // inline the listing so the rendered spec is
                // self-contained (no `path =` file dependence)
                s.push_str(&format!("source = '''\n{}\n'''\n", a.text.trim_end_matches('\n')));
            }
        }
    }
    // later [[kernel]] sections may derive from this one
    let _ = reg.register(k.clone());
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_is_the_nine_jobs_in_figure_order() {
        let spec = CampaignSpec::paper_default();
        let names: Vec<&str> = spec.workloads.iter().map(|w| w.name()).collect();
        assert_eq!(
            names,
            [
                "stream-mcv1",
                "stream-mcv2-1s",
                "stream-mcv2-2s",
                "hpl-mcv1-full",
                "hpl-mcv2-1s",
                "hpl-mcv2-2n",
                "hpl-mcv2-2s",
                "hpl-blis-vanilla",
                "hpl-blis-opt",
            ]
        );
        assert_eq!(spec.validate_n, 96);
        assert!(spec.fleet.is_empty(), "paper campaign runs the paper fleet");
    }

    const SAMPLE: &str = r#"
[campaign]
validate_n = 64

[[workload]]
kind = "stream"
name = "stream-one"
platform = "mcv2"
partition = "mcv2"
threads = 64

[[workload]]
kind = "hpl"
name = "hpl-two-node"
node = "mcv2"
partition = "mcv2"
nodes = 2
cores_per_node = 64

[[workload]]
kind = "blis-ablation"
name = "ablate-opt"
partition = "mcv2"
lib = "blis-opt"
"#;

    #[test]
    fn parses_all_three_kinds_from_config() {
        let spec = CampaignSpec::parse(SAMPLE).unwrap();
        assert_eq!(spec.validate_n, 64);
        assert_eq!(spec.len(), 3);
        assert_eq!(
            spec.workloads[0],
            WorkloadSpec::Stream {
                name: "stream-one".into(),
                partition: "mcv2".into(),
                nodes: 1,
                platform: "mcv2".into(),
                threads: 64,
            }
        );
        match &spec.workloads[1] {
            WorkloadSpec::Hpl { nodes, cluster_nodes, cores_per_node, lib, platform, .. } => {
                assert_eq!((*nodes, *cluster_nodes, *cores_per_node), (2, 2, 64));
                assert!(lib.is_none());
                // legacy `node =` spelling still parses
                assert_eq!(platform, "mcv2");
            }
            other => panic!("expected Hpl, got {other:?}"),
        }
        match &spec.workloads[2] {
            WorkloadSpec::BlisAblation { lib, cores, runtime_s, platform, .. } => {
                // the `blis-opt` alias canonicalized to the registry id
                assert_eq!(lib, "blis-lmul4");
                assert_eq!(*cores, 128);
                assert_eq!(*runtime_s, 3600.0);
                assert_eq!(platform, "mcv2-dual");
            }
            other => panic!("expected BlisAblation, got {other:?}"),
        }
    }

    const MIXED: &str = r#"
[[workload]]
kind = "hpl-mxp"
name = "mxp-one"
platform = "mcv2"
partition = "mcv2"
cores_per_node = 128
lib = "blis-opt"
fabric = "10gbe"

[[workload]]
kind = "spmv"
name = "spmv-one"
platform = "sg2044"
partition = "sg2044"
threads = 64
"#;

    #[test]
    fn parses_spmv_and_hpl_mxp_kinds_from_config() {
        let spec = CampaignSpec::parse(MIXED).unwrap();
        assert_eq!(spec.len(), 2);
        match &spec.workloads[0] {
            WorkloadSpec::HplMxp { nodes, cluster_nodes, cores_per_node, lib, fabric, .. } => {
                assert_eq!((*nodes, *cluster_nodes, *cores_per_node), (1, 1, 128));
                // aliases canonicalize to registry ids at load time,
                // exactly as they do for kind = "hpl"
                assert_eq!(lib.as_deref(), Some("blis-lmul4"));
                assert_eq!(fabric.as_deref(), Some("ten-gbe-flat"));
            }
            other => panic!("expected HplMxp, got {other:?}"),
        }
        assert_eq!(
            spec.workloads[1],
            WorkloadSpec::Spmv {
                name: "spmv-one".into(),
                partition: "sg2044".into(),
                nodes: 1,
                platform: "sg2044".into(),
                threads: 64,
                // the HPCG reference problem fills in the shape
                rows: 1 << 20,
                nnz_per_row: 27,
                index_bytes: 4,
            }
        );
        // the descriptors build matching runnable workloads
        for w in &spec.workloads {
            let built = w.build();
            assert_eq!(built.name(), w.name());
            assert_eq!(built.nodes(), w.nodes());
        }
    }

    #[test]
    fn spmv_and_mxp_render_and_reparse_to_an_equal_spec() {
        let spec = CampaignSpec::parse(MIXED).unwrap();
        let back = CampaignSpec::parse(&spec.render()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn spmv_shape_keys_are_rejected_on_other_kinds() {
        // `rows` belongs to the sparse shape, not to dense HPL
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"hpl\"\nname = \"h\"\nplatform = \"mcv2\"\npartition = \"mcv2\"\n\
             cores_per_node = 64\nrows = 100\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("unknown key `rows`")));
        // ...and a zero-row spmv job is a load-time error, not a NaN
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"spmv\"\nname = \"s\"\nplatform = \"sg2044\"\npartition = \"sg2044\"\n\
             threads = 64\nrows = 0\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("positive int")));
    }

    #[test]
    fn mxp_fabric_override_is_held_to_the_port_check() {
        // 17 nodes cannot hang off the 16-port ToR switch, MxP or not
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"hpl-mxp\"\nname = \"m\"\nplatform = \"mcv2\"\npartition = \"mcv2\"\n\
             nodes = 2\ncluster_nodes = 17\ncores_per_node = 64\nfabric = \"gbe-flat\"\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::FabricTooSmall { nodes: 17, .. }));
    }

    #[test]
    fn unknown_kind_is_a_spec_error() {
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"dgemm\"\nname = \"x\"\npartition = \"mcv2\"\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("unknown kind `dgemm`")));
    }

    #[test]
    fn misspelled_campaign_keys_are_rejected() {
        let err = CampaignSpec::parse("[campaign]\nfabrik = \"ten-gbe-flat\"\n").unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("unknown key `fabrik`")));
    }

    #[test]
    fn unknown_or_misplaced_workload_keys_are_rejected() {
        // a misspelled `fabric` must not silently run on the wrong wire
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"hpl\"\nname = \"h\"\nplatform = \"mcv2\"\npartition = \"mcv2\"\n\
             cores_per_node = 64\nfabrik = \"ten-gbe-flat\"\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("unknown key `fabrik`")));
        // ...and `fabric` on a stream job (which has no network model)
        // is equally a load-time error, not an ignored key
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"stream\"\nname = \"s\"\nplatform = \"mcv2\"\npartition = \"mcv2\"\n\
             threads = 64\nfabric = \"ten-gbe-flat\"\n",
        )
        .unwrap_err();
        assert!(
            matches!(err, CimoneError::Spec(ref m) if m.contains("unknown key `fabric`") && m.contains("kind `stream`"))
        );
    }

    #[test]
    fn unknown_platform_in_workload_is_typed_at_load_time() {
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"stream\"\nname = \"s\"\nplatform = \"epyc\"\npartition = \"mcv2\"\nthreads = 4\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::UnknownPlatform { ref id, .. } if id == "epyc"));
    }

    #[test]
    fn missing_required_key_is_a_spec_error() {
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"stream\"\nname = \"s\"\npartition = \"mcv2\"\nnode = \"mcv2\"\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("threads")));
    }

    #[test]
    fn zero_or_negative_numerics_rejected() {
        // threads = 0 would project zero bandwidth -> infinite runtime
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"stream\"\nname = \"s\"\npartition = \"mcv2\"\nnode = \"mcv2\"\nthreads = 0\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("positive int")));
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"blis-ablation\"\nname = \"b\"\npartition = \"mcv2\"\nlib = \"blis\"\nruntime_s = -5.0\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("positive number")));
    }

    #[test]
    fn duplicate_names_rejected() {
        let text = "[[workload]]\nkind = \"stream\"\nname = \"a\"\npartition = \"p\"\nnode = \"mcv1\"\nthreads = 4\n\
                    \n[[workload]]\nkind = \"stream\"\nname = \"a\"\npartition = \"p\"\nnode = \"mcv1\"\nthreads = 4\n";
        assert!(matches!(
            CampaignSpec::parse(text),
            Err(CimoneError::Spec(ref m)) if m.contains("duplicate")
        ));
    }

    #[test]
    fn empty_text_is_an_empty_campaign() {
        let spec = CampaignSpec::parse("").unwrap();
        assert!(spec.is_empty());
        assert_eq!(spec.validate_n, 96);
        // default inventory is the paper machine
        assert_eq!(spec.build_inventory().unwrap().nodes.len(), 12);
    }

    #[test]
    fn fleet_sections_build_the_described_inventory() {
        let spec = CampaignSpec::parse(
            "[[fleet]]\nplatform = \"sg2044\"\ncount = 4\n\n[[fleet]]\nplatform = \"mcv3\"\n",
        )
        .unwrap();
        assert_eq!(spec.fleet, vec![("sg2044".to_string(), 4), ("mcv3".to_string(), 1)]);
        let inv = spec.build_inventory().unwrap();
        assert_eq!(inv.nodes.len(), 5);
        assert_eq!(inv.ids_of_platform("sg2044").len(), 4);
        assert_eq!(inv.ids_of_platform("mcv3").len(), 1);
    }

    #[test]
    fn unknown_fleet_platform_rejected_at_load_time() {
        let err = CampaignSpec::parse("[[fleet]]\nplatform = \"epyc\"\n").unwrap_err();
        assert!(matches!(err, CimoneError::UnknownPlatform { ref id, .. } if id == "epyc"));
    }

    #[test]
    fn misspelled_fleet_key_rejected_at_load_time() {
        let err =
            CampaignSpec::parse("[[fleet]]\nplatform = \"sg2044\"\ncout = 4\n").unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("unknown key `cout`")));
    }

    #[test]
    fn custom_platform_section_feeds_fleet_and_workloads() {
        let spec = CampaignSpec::parse(
            "[[platform]]\nid = \"sg2044-oc\"\nbase = \"sg2044\"\nfreq_ghz = 3.0\n\n\
             [[fleet]]\nplatform = \"sg2044-oc\"\ncount = 2\n\n\
             [[workload]]\nkind = \"hpl\"\nname = \"h\"\nplatform = \"sg2044-oc\"\npartition = \"sg2044\"\ncores_per_node = 64\n",
        )
        .unwrap();
        assert_eq!(spec.custom_platforms.len(), 1);
        let inv = spec.build_inventory().unwrap();
        assert_eq!(inv.nodes.len(), 2);
        assert!(
            (inv.node(0).platform.desc.sockets[0].core.freq_hz - 3.0e9).abs() < 1.0
        );
    }

    #[test]
    fn descriptors_build_matching_workloads() {
        for w in CampaignSpec::paper_default().workloads {
            let built = w.build();
            assert_eq!(built.name(), w.name());
            assert!(built.nodes() >= 1);
            assert_eq!(built.nodes(), w.nodes());
        }
    }

    #[test]
    fn paper_default_renders_and_reparses_to_an_equal_spec() {
        let spec = CampaignSpec::paper_default();
        let back = CampaignSpec::parse(&spec.render()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn spec_with_fleet_and_custom_platform_round_trips() {
        let spec = CampaignSpec::parse(
            "[campaign]\nvalidate_n = 48\n\n\
             [[platform]]\nid = \"sg2044-oc\"\nbase = \"sg2044\"\nfreq_ghz = 3.0\nidle_w = 70.0\npartition = \"oc\"\n\n\
             [[fleet]]\nplatform = \"sg2044-oc\"\ncount = 2\n\n\
             [[workload]]\nkind = \"hpl\"\nname = \"h\"\nplatform = \"sg2044-oc\"\npartition = \"oc\"\ncores_per_node = 64\n\n\
             [[workload]]\nkind = \"blis-ablation\"\nname = \"b\"\npartition = \"mcv2\"\nlib = \"blis-opt\"\nruntime_s = 60.5\n",
        )
        .unwrap();
        let text = spec.render();
        let back = CampaignSpec::parse(&text).unwrap();
        assert_eq!(back, spec);
        // only overridden platform keys are written back out
        assert!(text.contains("freq_ghz = 3.0"), "{text}");
        assert!(text.contains("idle_w = 70.0"), "{text}");
        assert!(!text.contains("mem_gb"), "inherited keys must not render: {text}");
    }

    #[test]
    fn chained_custom_platforms_round_trip() {
        // oc2 derives from oc1, which derives from a built-in
        let spec = CampaignSpec::parse(
            "[[platform]]\nid = \"oc1\"\nbase = \"sg2044\"\nfreq_ghz = 3.0\n\n\
             [[platform]]\nid = \"oc2\"\nbase = \"oc1\"\nidle_w = 80.0\n",
        )
        .unwrap();
        let back = CampaignSpec::parse(&spec.render()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn fleet_fabric_key_sets_the_machine_interconnect() {
        let spec = CampaignSpec::parse(
            "[[fleet]]\nplatform = \"mcv2-pioneer\"\ncount = 4\nfabric = \"10gbe\"\n",
        )
        .unwrap();
        // the alias is canonicalized to the registry id at load time
        assert_eq!(spec.fabric.as_deref(), Some("ten-gbe-flat"));
        assert_eq!(spec.build_inventory().unwrap().fabric.id, "ten-gbe-flat");
        // without the key, the leading platform's default fabric rules
        let spec =
            CampaignSpec::parse("[[fleet]]\nplatform = \"mcv3\"\ncount = 2\n").unwrap();
        assert!(spec.fabric.is_none());
        assert_eq!(spec.build_inventory().unwrap().fabric.id, "ten-gbe-flat");
    }

    #[test]
    fn conflicting_fleet_fabrics_are_rejected() {
        let err = CampaignSpec::parse(
            "[[fleet]]\nplatform = \"mcv1-u740\"\ncount = 2\nfabric = \"gbe-flat\"\n\n\
             [[fleet]]\nplatform = \"mcv2-pioneer\"\ncount = 2\nfabric = \"ten-gbe-flat\"\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("conflicting machine fabrics")));
    }

    #[test]
    fn unknown_fabric_names_are_typed_at_load_time() {
        // machine-level
        let err = CampaignSpec::parse("[campaign]\nfabric = \"infiniband\"\n").unwrap_err();
        assert!(matches!(err, CimoneError::UnknownFabric { ref id, .. } if id == "infiniband"));
        // workload-level
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"hpl\"\nname = \"h\"\nplatform = \"mcv2\"\npartition = \"mcv2\"\n\
             cores_per_node = 64\nfabric = \"infiniband\"\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::UnknownFabric { ref id, .. } if id == "infiniband"));
    }

    #[test]
    fn fleet_wider_than_the_fabric_switch_is_typed_at_load_time() {
        // 17 Pioneers cannot hang off the paper's 16-port ToR switch
        let err = CampaignSpec::parse("[[fleet]]\nplatform = \"mcv2-pioneer\"\ncount = 17\n")
            .unwrap_err();
        assert!(matches!(
            err,
            CimoneError::FabricTooSmall { ports: 16, nodes: 17, .. }
        ));
        // ...but a wider custom fabric carries them
        let spec = CampaignSpec::parse(
            "[[fabric]]\nid = \"gbe-big\"\nbase = \"gbe-flat\"\nports = 24\n\n\
             [[fleet]]\nplatform = \"mcv2-pioneer\"\ncount = 17\nfabric = \"gbe-big\"\n",
        )
        .unwrap();
        assert_eq!(spec.build_inventory().unwrap().fabric.ports, 24);
        // an HPL job's fabric override is held to the same port check
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"hpl\"\nname = \"h\"\nplatform = \"mcv2\"\npartition = \"mcv2\"\n\
             nodes = 2\ncluster_nodes = 17\ncores_per_node = 64\nfabric = \"gbe-flat\"\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::FabricTooSmall { nodes: 17, .. }));
    }

    #[test]
    fn custom_fabric_sections_feed_workloads_and_round_trip() {
        let spec = CampaignSpec::parse(
            "[campaign]\nvalidate_n = 48\nfabric = \"gbe-8to1\"\n\n\
             [[fabric]]\nid = \"gbe-8to1\"\nbase = \"gbe-flat\"\nbackplane_factor = 0.125\n\n\
             [[fleet]]\nplatform = \"mcv2-pioneer\"\ncount = 4\n\n\
             [[workload]]\nkind = \"hpl\"\nname = \"h\"\nplatform = \"mcv2\"\npartition = \"mcv2\"\n\
             nodes = 2\ncores_per_node = 64\nfabric = \"ten-gbe\"\n",
        )
        .unwrap();
        assert_eq!(spec.custom_fabrics.len(), 1);
        assert_eq!(spec.build_inventory().unwrap().fabric.id, "gbe-8to1");
        match &spec.workloads[0] {
            WorkloadSpec::Hpl { fabric, .. } => {
                assert_eq!(fabric.as_deref(), Some("ten-gbe-flat"))
            }
            other => panic!("expected Hpl, got {other:?}"),
        }
        let text = spec.render();
        let back = CampaignSpec::parse(&text).unwrap();
        assert_eq!(back, spec);
        // only overridden fabric keys render back out
        assert!(text.contains("backplane_factor = 0.125"), "{text}");
        assert!(!text.contains("latency_us"), "inherited keys must not render: {text}");
    }

    #[test]
    fn custom_kernel_sections_feed_workloads_and_round_trip() {
        let spec = CampaignSpec::parse(
            "[campaign]\nvalidate_n = 48\n\n\
             [[kernel]]\nid = \"blis-rvv1-u8\"\nbase = \"blis-rvv1-lmul2\"\nk_unroll = 8\nhost_overhead = 0.15\n\n\
             [[platform]]\nid = \"sg2044-tuned\"\nbase = \"sg2044\"\ndefault_lib = \"blis-rvv1-u8\"\n\n\
             [[fleet]]\nplatform = \"sg2044-tuned\"\ncount = 2\n\n\
             [[workload]]\nkind = \"blis-ablation\"\nname = \"b\"\npartition = \"sg2044\"\n\
             platform = \"sg2044-tuned\"\nlib = \"blis-rvv1-u8\"\ncores = 64\n",
        )
        .unwrap();
        assert_eq!(spec.custom_kernels.len(), 1);
        // the custom kernel reaches the inventory's registry
        let inv = spec.build_inventory().unwrap();
        let k = inv.kernels.get("blis-rvv1-u8").unwrap();
        assert_eq!(k.k_unroll, 8);
        // ...and the custom platform's default_lib points at it
        assert_eq!(inv.node(0).platform.default_lib, "blis-rvv1-u8");
        let text = spec.render();
        let back = CampaignSpec::parse(&text).unwrap();
        assert_eq!(back, spec);
        // only overridden kernel keys render back out
        assert!(text.contains("k_unroll = 8"), "{text}");
        assert!(text.contains("host_overhead = 0.15"), "{text}");
        assert!(!text.contains("lmul ="), "inherited keys must not render: {text}");
    }

    #[test]
    fn unknown_kernel_names_are_typed_at_load_time() {
        // workload-level
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"hpl\"\nname = \"h\"\nplatform = \"mcv2\"\npartition = \"mcv2\"\n\
             cores_per_node = 64\nlib = \"mkl\"\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::UnknownKernel { ref name, .. } if name == "mkl"));
        // custom-platform default_lib
        let err = CampaignSpec::parse(
            "[[platform]]\nid = \"oc\"\nbase = \"sg2044\"\ndefault_lib = \"mkl\"\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::UnknownKernel { ref name, .. } if name == "mkl"));
        // a malformed [[kernel]] override is typed too
        let err = CampaignSpec::parse(
            "[[kernel]]\nid = \"dud\"\nbase = \"blis-lmul4\"\nlmul = 8\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::InvalidKernel { .. }));
    }

    #[test]
    fn chained_custom_kernels_round_trip() {
        // k2 derives from k1, which derives from a built-in; the
        // native_rvv10 dialect flag survives the render round-trip
        let spec = CampaignSpec::parse(
            "[[kernel]]\nid = \"k1\"\nbase = \"blis-lmul4\"\nk_unroll = 2\nnative_rvv10 = true\n\n\
             [[kernel]]\nid = \"k2\"\nbase = \"k1\"\nhost_overhead = 0.1\n",
        )
        .unwrap();
        assert!(spec.custom_kernels[0].kernel.native_rvv10);
        assert!(spec.custom_kernels[1].kernel.native_rvv10, "inherited through the chain");
        let back = CampaignSpec::parse(&spec.render()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn fabric_unit_conversions_round_trip_for_awkward_floats() {
        // latency_us parses through the inexact constant 1e-6, so a
        // naive `latency_s * 1e6` render lands 1 ulp off for ~a quarter
        // of all values; exact_preimage must absorb that
        for (i, us) in [420.5773751150367f64, 65.0, 19.999999999999996, 0.3333333333333333, 123.456]
            .iter()
            .enumerate()
        {
            let spec = CampaignSpec::parse(&format!(
                "[[fabric]]\nid = \"lat-{i}\"\nbase = \"gbe-flat\"\nlatency_us = {us}\nraw_gbps = {us}\n",
            ))
            .unwrap();
            let back = CampaignSpec::parse(&spec.render()).unwrap();
            assert_eq!(back, spec, "latency_us/raw_gbps = {us} did not round-trip");
        }
    }

    const QUEUED: &str = r#"
[[workload]]
kind = "hpl"
name = "hpl-small"
platform = "mcv2"
partition = "mcv2"
cores_per_node = 64

[[queue]]
user = "alice"
workload = "hpl-small"
count = 3
start_s = 10.0
interval_s = 60.0
priority = 2

[[queue]]
user = "bob"
workload = "hpl-small"

[[outage]]
node = 9
down_s = 100.0
up_s = 400.0

[[outage]]
node = 10
down_s = 0.0
up_s = 50.0
repeat = 3
every = 200.0
"#;

    #[test]
    fn queue_and_outage_sections_parse_with_defaults() {
        let spec = CampaignSpec::parse(QUEUED).unwrap();
        assert_eq!(spec.queues.len(), 2);
        let a = &spec.queues[0];
        assert_eq!((a.user.as_str(), a.count, a.priority), ("alice", 3, 2));
        assert_eq!(a.job_name(1), "alice/hpl-small.1");
        assert_eq!(a.arrival_s(2), 130.0);
        let b = &spec.queues[1];
        assert_eq!((b.count, b.start_s, b.interval_s, b.priority), (1, 0.0, 0.0, 0));
        // the flap expands into shifted copies of its window
        assert_eq!(spec.outages[0].windows(), vec![(100.0, Some(400.0))]);
        assert_eq!(
            spec.outages[1].windows(),
            vec![(0.0, Some(50.0)), (200.0, Some(250.0)), (400.0, Some(450.0))]
        );
    }

    #[test]
    fn queue_and_outage_sections_round_trip() {
        let spec = CampaignSpec::parse(QUEUED).unwrap();
        let back = CampaignSpec::parse(&spec.render()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn queue_without_a_matching_template_is_rejected() {
        let err = CampaignSpec::parse(
            "[[workload]]\nkind = \"stream\"\nname = \"s\"\nplatform = \"mcv2\"\npartition = \"mcv2\"\nthreads = 64\n\n\
             [[queue]]\nuser = \"alice\"\nworkload = \"hpl\"\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("no workload named `hpl`")));
    }

    #[test]
    fn queue_and_outage_key_typos_are_rejected() {
        let err = CampaignSpec::parse("[[queue]]\nuser = \"a\"\nworkload = \"w\"\ncuont = 3\n")
            .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("unknown key `cuont`")));
        let err = CampaignSpec::parse("[[outage]]\nnode = 3\ndown = 5.0\n").unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("unknown key `down`")));
    }

    #[test]
    fn outage_invariants_are_load_time_errors() {
        // the paper fleet has 12 nodes: node 12 does not exist
        let err = CampaignSpec::parse("[[outage]]\nnode = 12\ndown_s = 0.0\n").unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("fleet has 12 nodes")));
        // a window that closes before it opens
        let err = CampaignSpec::parse("[[outage]]\nnode = 0\ndown_s = 10.0\nup_s = 5.0\n")
            .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("up_s")));
        // a flap needs a closing edge and a spacing that covers the window
        let err = CampaignSpec::parse("[[outage]]\nnode = 0\ndown_s = 0.0\nrepeat = 2\n")
            .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("needs `up_s`")));
        let err = CampaignSpec::parse(
            "[[outage]]\nnode = 0\ndown_s = 0.0\nup_s = 100.0\nrepeat = 2\nevery = 50.0\n",
        )
        .unwrap_err();
        assert!(matches!(err, CimoneError::Spec(ref m) if m.contains("cover the window")));
    }
}
