//! The campaign engine: execute a declarative [`CampaignSpec`] end to
//! end over a simulated Monte Cimone fleet.
//!
//! The engine (1) anchors the campaign in real numerics by running the
//! host HPL solve + STREAM validation, (2) instantiates every workload
//! descriptor and *estimates them in parallel* (rayon) against the
//! inventory, (3) submits the jobs to the SLURM-like scheduler in spec
//! order — deterministic queueing — recording each workload's metrics in
//! the ExaMon-like monitor, and (4) drains the partitions concurrently
//! ([`Scheduler::drain_parallel`](crate::sched::Scheduler::drain_parallel)),
//! which keeps simulated-time accounting identical to a serial drain.
//! This is what `examples/e2e_cluster.rs` and `cimone campaign` run.

use rayon::prelude::*;

use crate::cluster::{monte_cimone_v2, Inventory, Monitor};
use crate::error::CimoneError;
use crate::hpl::driver::{run as hpl_run, Backend, HplConfig};
use crate::stream::kernels::validate_kernels;

use super::campaign::CampaignSpec;
use super::workload::{JobEstimate, Workload};

/// Campaign outcome.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    /// (job name, simulated seconds, headline metric value)
    pub jobs: Vec<(String, f64, f64)>,
    pub makespan_s: f64,
    /// real-numerics validation outcomes
    pub hpl_residual: f64,
    pub hpl_passed: bool,
    pub stream_validated: bool,
    pub monitor: Monitor,
}

/// Run the paper's campaign on the standard fleet.
pub fn run_campaign(validate_n: usize) -> Result<CampaignReport, CimoneError> {
    let inv = monte_cimone_v2();
    run_campaign_on(&inv, validate_n)
}

/// Run the paper's campaign on a given inventory.
pub fn run_campaign_on(inv: &Inventory, validate_n: usize) -> Result<CampaignReport, CimoneError> {
    let mut spec = CampaignSpec::paper_default();
    spec.validate_n = validate_n;
    run_campaign_spec(inv, &spec)
}

/// Run an arbitrary campaign spec on a given inventory.
pub fn run_campaign_spec(
    inv: &Inventory,
    spec: &CampaignSpec,
) -> Result<CampaignReport, CimoneError> {
    spec.validate()?;
    let mut sched = inv.scheduler();
    let mut mon = Monitor::new();

    // --- 1. real-numerics validation runs (host execution) ---
    let hpl = hpl_run(&HplConfig {
        n: spec.validate_n,
        nb: 32.min(spec.validate_n),
        seed: 42,
        backend: Backend::Native,
    })
    .map_err(|e| CimoneError::ValidationRun { n: spec.validate_n, cause: Box::new(e) })?;
    let stream_ok = validate_kernels(1 << 16).is_ok();
    mon.record("frontend.hpl.residual", 0.0, hpl.residual);

    // --- 2. instantiate + estimate every workload, in parallel ---
    let workloads: Vec<Box<dyn Workload>> = spec.workloads.iter().map(|w| w.build()).collect();
    let estimates: Vec<Result<JobEstimate, CimoneError>> =
        workloads.par_iter().map(|w| w.estimate(inv)).collect();

    // --- 3. submit in spec order (deterministic queueing + metrics) ---
    let mut jobs = Vec::with_capacity(workloads.len());
    for (w, est) in workloads.iter().zip(estimates) {
        let est = est?;
        sched.submit(w.name(), w.partition(), w.nodes(), est.runtime_s)?;
        w.metrics(&mut mon, sched.now, &est);
        jobs.push((w.name().to_string(), est.runtime_s, est.headline));
    }

    // --- 4. drain independent partitions concurrently ---
    let makespan = sched.drain_parallel();
    Ok(CampaignReport {
        jobs,
        makespan_s: makespan,
        hpl_residual: hpl.residual,
        hpl_passed: hpl.passed,
        stream_validated: stream_ok,
        monitor: mon,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn campaign_runs_and_validates() {
        let r = run_campaign(96).unwrap();
        assert!(r.hpl_passed, "residual {}", r.hpl_residual);
        assert!(r.stream_validated);
        assert_eq!(r.jobs.len(), 9);
        assert!(r.makespan_s > 0.0);
    }

    #[test]
    fn campaign_metrics_recorded() {
        let r = run_campaign(64).unwrap();
        assert!(r.monitor.latest("hpl-mcv2-1s.gflops").unwrap() > 100.0);
        assert!(r.monitor.metric_count() >= 9);
    }

    #[test]
    fn campaign_fig5_ordering() {
        let r = run_campaign(64).unwrap();
        let get = |n: &str| r.monitor.latest(n).unwrap();
        assert!(get("hpl-mcv1-full.gflops") < get("hpl-mcv2-1s.gflops"));
        assert!(get("hpl-mcv2-2n.gflops") < get("hpl-mcv2-2s.gflops"));
        assert!(get("hpl-blis-opt.gflops") > get("hpl-blis-vanilla.gflops"));
    }

    #[test]
    fn empty_spec_drains_to_zero_makespan() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec { workloads: vec![], validate_n: 64 };
        let r = run_campaign_spec(&inv, &spec).unwrap();
        assert!(r.jobs.is_empty());
        assert_eq!(r.makespan_s, 0.0);
        assert!(r.hpl_passed);
    }

    #[test]
    fn spec_engine_matches_legacy_campaign_shape() {
        // the declarative path must reproduce the seed campaign exactly
        let r = run_campaign(64).unwrap();
        let names: Vec<&str> = r.jobs.iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(
            names,
            [
                "stream-mcv1",
                "stream-mcv2-1s",
                "stream-mcv2-2s",
                "hpl-mcv1-full",
                "hpl-mcv2-1s",
                "hpl-mcv2-2n",
                "hpl-mcv2-2s",
                "hpl-blis-vanilla",
                "hpl-blis-opt",
            ]
        );
        // blis jobs occupy their fixed 3600 s slot
        assert_eq!(r.jobs[7].1, 3600.0);
        assert_eq!(r.jobs[8].1, 3600.0);
    }

    #[test]
    fn config_driven_spec_runs() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec::parse(
            "[campaign]\nvalidate_n = 48\n\n\
             [[workload]]\nkind = \"stream\"\nname = \"s1\"\nnode = \"mcv2\"\npartition = \"mcv2\"\nthreads = 64\n\n\
             [[workload]]\nkind = \"hpl\"\nname = \"h1\"\nnode = \"mcv2-dual\"\npartition = \"mcv2\"\ncores_per_node = 128\n",
        )
        .unwrap();
        let r = run_campaign_spec(&inv, &spec).unwrap();
        assert_eq!(r.jobs.len(), 2);
        assert!(r.monitor.latest("s1.bandwidth").unwrap() > 1e9);
        assert!(r.monitor.latest("h1.gflops").unwrap() > 100.0);
    }

    #[test]
    fn duplicate_job_names_rejected_by_engine() {
        use super::super::campaign::WorkloadSpec;
        use crate::arch::soc::NodeKind;
        let inv = monte_cimone_v2();
        let mut spec = CampaignSpec::new();
        for _ in 0..2 {
            spec.push(WorkloadSpec::Stream {
                name: "dup".into(),
                partition: "mcv2".into(),
                nodes: 1,
                kind: NodeKind::Mcv2Pioneer,
                threads: 64,
            });
        }
        assert!(matches!(
            run_campaign_spec(&inv, &spec),
            Err(CimoneError::Spec(ref m)) if m.contains("duplicate")
        ));
    }

    #[test]
    fn unknown_partition_in_spec_is_typed() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec::parse(
            "[[workload]]\nkind = \"stream\"\nname = \"s\"\nnode = \"mcv1\"\npartition = \"gpu\"\nthreads = 4\n",
        )
        .unwrap();
        assert!(matches!(
            run_campaign_spec(&inv, &spec),
            Err(CimoneError::UnknownPartition(ref p)) if p == "gpu"
        ));
    }
}
