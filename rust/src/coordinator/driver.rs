//! The campaign engine: execute a declarative [`CampaignSpec`] end to
//! end over a simulated Monte Cimone fleet.
//!
//! The engine (1) anchors the campaign in real numerics by running the
//! host HPL solve + STREAM validation, (2) instantiates every workload
//! descriptor and *estimates them in parallel* (rayon) against the
//! inventory, (3) submits the jobs to the SLURM-like scheduler in spec
//! order — deterministic queueing — recording each workload's metrics
//! (headline + power/energy) in the ExaMon-like monitor, and (4) drains
//! the partitions concurrently
//! ([`Scheduler::drain_parallel`](crate::sched::Scheduler::drain_parallel)),
//! which keeps simulated-time accounting identical to a serial drain.
//! This is what `examples/e2e_cluster.rs` and `cimone campaign` run.
//!
//! [`dry_run_spec`] is the scheduling-free variant: it validates the
//! spec, estimates every job and checks partition fit, but runs neither
//! the real-numerics solve nor the drain — `cimone campaign --dry-run`.

use std::collections::BTreeMap;

use rayon::prelude::*;

use crate::cluster::{monte_cimone_v2, Inventory, Monitor};
use crate::error::CimoneError;
use crate::hpl::driver::{run as hpl_run, Backend, HplConfig};
use crate::stream::kernels::validate_kernels;
use crate::util::json::Json;

use super::campaign::CampaignSpec;
use super::workload::{JobEstimate, Workload};

/// One campaign job's outcome: runtime, headline metric, and the
/// power/energy numbers derived from its platform's power model.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRow {
    pub name: String,
    /// Metric family (`gflops` | `bandwidth`), so consumers like the
    /// scenario comparison can classify rows without re-parsing names.
    pub metric: &'static str,
    /// Simulated seconds the job occupies its nodes.
    pub runtime_s: f64,
    /// Headline metric (GB/s for STREAM, GFLOP/s for HPL).
    pub headline: f64,
    /// Average per-node draw while running (W).
    pub avg_node_w: f64,
    /// Energy-to-solution across all allocated nodes (J).
    pub energy_j: f64,
    /// GFLOP/s per watt for compute jobs; `None` for bandwidth jobs.
    pub gflops_per_w: Option<f64>,
}

fn job_row(w: &dyn Workload, est: &JobEstimate) -> JobRow {
    // derive total draw from the estimate itself (energy / runtime) so
    // efficiency uses exactly the node count the metric was modeled on
    let total_w = if est.runtime_s > 0.0 { est.energy_j / est.runtime_s } else { 0.0 };
    let gflops_per_w =
        if est.metric == "gflops" && total_w > 0.0 { Some(est.value / total_w) } else { None };
    JobRow {
        name: w.name().to_string(),
        metric: est.metric,
        runtime_s: est.runtime_s,
        headline: est.headline,
        avg_node_w: est.avg_node_w,
        energy_j: est.energy_j,
        gflops_per_w,
    }
}

/// Campaign outcome.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub jobs: Vec<JobRow>,
    pub makespan_s: f64,
    /// real-numerics validation outcomes
    pub hpl_residual: f64,
    pub hpl_passed: bool,
    pub stream_validated: bool,
    pub monitor: Monitor,
}

impl CampaignReport {
    /// Machine-readable export for the artifacts pipeline
    /// (`cimone campaign --json`).
    pub fn to_json(&self) -> Json {
        let metrics: BTreeMap<String, Json> = self
            .monitor
            .query_prefix("")
            .into_iter()
            .map(|(k, v)| (k.to_string(), Json::Num(v)))
            .collect();
        Json::obj([
            ("makespan_s", Json::Num(self.makespan_s)),
            ("hpl_residual", Json::Num(self.hpl_residual)),
            ("hpl_passed", Json::Bool(self.hpl_passed)),
            ("stream_validated", Json::Bool(self.stream_validated)),
            ("jobs", Json::Arr(self.jobs.iter().map(JobRow::to_json).collect())),
            ("metrics", Json::Obj(metrics)),
        ])
    }
}

impl JobRow {
    /// Machine-readable form, used by both `--json` and `--dry-run --json`.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::Str(self.name.clone())),
            ("metric", Json::Str(self.metric.to_string())),
            ("runtime_s", Json::Num(self.runtime_s)),
            ("headline", Json::Num(self.headline)),
            ("avg_node_w", Json::Num(self.avg_node_w)),
            ("energy_j", Json::Num(self.energy_j)),
            ("gflops_per_w", self.gflops_per_w.map(Json::Num).unwrap_or(Json::Null)),
        ])
    }
}

/// Run the paper's campaign on the standard fleet.
pub fn run_campaign(validate_n: usize) -> Result<CampaignReport, CimoneError> {
    let inv = monte_cimone_v2();
    run_campaign_on(&inv, validate_n)
}

/// Run the paper's campaign on a given inventory.
pub fn run_campaign_on(inv: &Inventory, validate_n: usize) -> Result<CampaignReport, CimoneError> {
    let mut spec = CampaignSpec::paper_default();
    spec.validate_n = validate_n;
    run_campaign_spec(inv, &spec)
}

/// Instantiate the spec's workloads and estimate them in parallel.
/// Callers are expected to have run `spec.validate()` first.
fn estimate_all(
    inv: &Inventory,
    spec: &CampaignSpec,
) -> Result<Vec<(Box<dyn Workload>, JobEstimate)>, CimoneError> {
    let workloads: Vec<Box<dyn Workload>> = spec.workloads.iter().map(|w| w.build()).collect();
    let estimates: Vec<Result<JobEstimate, CimoneError>> =
        workloads.par_iter().map(|w| w.estimate(inv)).collect();
    workloads
        .into_iter()
        .zip(estimates)
        .map(|(w, est)| est.map(|e| (w, e)))
        .collect()
}

/// Run an arbitrary campaign spec on a given inventory.
pub fn run_campaign_spec(
    inv: &Inventory,
    spec: &CampaignSpec,
) -> Result<CampaignReport, CimoneError> {
    spec.validate()?;
    let mut sched = inv.scheduler();
    let mut mon = Monitor::new();

    // --- 1. real-numerics validation runs (host execution) ---
    let hpl = hpl_run(&HplConfig {
        n: spec.validate_n,
        nb: 32.min(spec.validate_n),
        seed: 42,
        backend: Backend::Native,
    })
    .map_err(|e| CimoneError::ValidationRun { n: spec.validate_n, cause: Box::new(e) })?;
    let stream_ok = validate_kernels(1 << 16).is_ok();
    mon.record("frontend.hpl.residual", 0.0, hpl.residual);

    // --- 2. instantiate + estimate every workload, in parallel ---
    let estimated = estimate_all(inv, spec)?;

    // --- 3. submit in spec order (deterministic queueing + metrics) ---
    let mut jobs = Vec::with_capacity(estimated.len());
    for (w, est) in &estimated {
        sched.submit(w.name(), w.partition(), w.nodes(), est.runtime_s)?;
        w.metrics(&mut mon, sched.now, est);
        jobs.push(job_row(w.as_ref(), est));
    }

    // --- 4. drain independent partitions concurrently ---
    let makespan = sched.drain_parallel();
    Ok(CampaignReport {
        jobs,
        makespan_s: makespan,
        hpl_residual: hpl.residual,
        hpl_passed: hpl.passed,
        stream_validated: stream_ok,
        monitor: mon,
    })
}

/// Validate a spec against an inventory without scheduling anything:
/// parse-level invariants, per-workload estimation (platform resolution,
/// finite runtimes) and partition fit are all checked; the real-numerics
/// solve and the drain are skipped. Returns the per-job estimates.
pub fn dry_run_spec(inv: &Inventory, spec: &CampaignSpec) -> Result<Vec<JobRow>, CimoneError> {
    spec.validate()?;
    let estimated = estimate_all(inv, spec)?;
    // a scratch scheduler checks partition existence, width and runtime
    // validity exactly as the real submission path would
    let mut sched = inv.scheduler();
    let mut rows = Vec::with_capacity(estimated.len());
    for (w, est) in &estimated {
        sched.submit(w.name(), w.partition(), w.nodes(), est.runtime_s)?;
        rows.push(job_row(w.as_ref(), est));
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn campaign_runs_and_validates() {
        let r = run_campaign(96).unwrap();
        assert!(r.hpl_passed, "residual {}", r.hpl_residual);
        assert!(r.stream_validated);
        assert_eq!(r.jobs.len(), 9);
        assert!(r.makespan_s > 0.0);
    }

    #[test]
    fn campaign_metrics_recorded() {
        let r = run_campaign(64).unwrap();
        assert!(r.monitor.latest("hpl-mcv2-1s.gflops").unwrap() > 100.0);
        assert!(r.monitor.metric_count() >= 9);
    }

    #[test]
    fn campaign_fig5_ordering() {
        let r = run_campaign(64).unwrap();
        let get = |n: &str| r.monitor.latest(n).unwrap();
        assert!(get("hpl-mcv1-full.gflops") < get("hpl-mcv2-1s.gflops"));
        assert!(get("hpl-mcv2-2n.gflops") < get("hpl-mcv2-2s.gflops"));
        assert!(get("hpl-blis-opt.gflops") > get("hpl-blis-vanilla.gflops"));
    }

    #[test]
    fn per_job_power_metrics_recorded() {
        let r = run_campaign(64).unwrap();
        // every job reports power and energy series
        for j in &r.jobs {
            assert!(j.avg_node_w > 0.0, "{}: {}", j.name, j.avg_node_w);
            assert!(j.energy_j > 0.0, "{}: {}", j.name, j.energy_j);
            assert_eq!(r.monitor.latest(&format!("{}.power_w", j.name)), Some(j.avg_node_w));
            assert_eq!(r.monitor.latest(&format!("{}.energy_j", j.name)), Some(j.energy_j));
        }
        // HPL jobs surface GFLOP/s-per-W; STREAM jobs don't
        let by_name = |n: &str| r.jobs.iter().find(|j| j.name == n).unwrap().clone();
        assert!(by_name("hpl-mcv2-1s").gflops_per_w.unwrap() > 0.5);
        assert!(by_name("stream-mcv1").gflops_per_w.is_none());
        // MCv2 is an order of magnitude more efficient than MCv1 (the
        // paper's Top500/Green500 argument)
        let v1 = by_name("hpl-mcv1-full").gflops_per_w.unwrap();
        let v2 = by_name("hpl-mcv2-1s").gflops_per_w.unwrap();
        assert!(v2 > 5.0 * v1, "v2 {v2:.2} vs v1 {v1:.2}");
    }

    #[test]
    fn empty_spec_drains_to_zero_makespan() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec { workloads: vec![], validate_n: 64, ..Default::default() };
        let r = run_campaign_spec(&inv, &spec).unwrap();
        assert!(r.jobs.is_empty());
        assert_eq!(r.makespan_s, 0.0);
        assert!(r.hpl_passed);
    }

    #[test]
    fn spec_engine_matches_legacy_campaign_shape() {
        // the declarative path must reproduce the seed campaign exactly
        let r = run_campaign(64).unwrap();
        let names: Vec<&str> = r.jobs.iter().map(|j| j.name.as_str()).collect();
        assert_eq!(
            names,
            [
                "stream-mcv1",
                "stream-mcv2-1s",
                "stream-mcv2-2s",
                "hpl-mcv1-full",
                "hpl-mcv2-1s",
                "hpl-mcv2-2n",
                "hpl-mcv2-2s",
                "hpl-blis-vanilla",
                "hpl-blis-opt",
            ]
        );
        // blis jobs occupy their fixed 3600 s slot
        assert_eq!(r.jobs[7].runtime_s, 3600.0);
        assert_eq!(r.jobs[8].runtime_s, 3600.0);
    }

    #[test]
    fn config_driven_spec_runs() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec::parse(
            "[campaign]\nvalidate_n = 48\n\n\
             [[workload]]\nkind = \"stream\"\nname = \"s1\"\nnode = \"mcv2\"\npartition = \"mcv2\"\nthreads = 64\n\n\
             [[workload]]\nkind = \"hpl\"\nname = \"h1\"\nnode = \"mcv2-dual\"\npartition = \"mcv2\"\ncores_per_node = 128\n",
        )
        .unwrap();
        let r = run_campaign_spec(&inv, &spec).unwrap();
        assert_eq!(r.jobs.len(), 2);
        assert!(r.monitor.latest("s1.bandwidth").unwrap() > 1e9);
        assert!(r.monitor.latest("h1.gflops").unwrap() > 100.0);
    }

    #[test]
    fn dry_run_estimates_without_scheduling() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec::paper_default();
        let rows = dry_run_spec(&inv, &spec).unwrap();
        assert_eq!(rows.len(), 9);
        for r in &rows {
            assert!(r.runtime_s.is_finite() && r.runtime_s > 0.0);
            assert!(r.headline.is_finite() && r.headline > 0.0);
        }
        // dry-run numbers match the real run's rows
        let full = run_campaign_spec(&inv, &spec).unwrap();
        for (a, b) in rows.iter().zip(&full.jobs) {
            assert_eq!(a.name, b.name);
            assert!((a.headline - b.headline).abs() < 1e-9);
        }
    }

    #[test]
    fn dry_run_rejects_invalid_specs() {
        let inv = monte_cimone_v2();
        // partition that doesn't exist
        let spec = CampaignSpec::parse(
            "[[workload]]\nkind = \"stream\"\nname = \"s\"\nnode = \"mcv1\"\npartition = \"gpu\"\nthreads = 4\n",
        )
        .unwrap();
        assert!(matches!(
            dry_run_spec(&inv, &spec),
            Err(CimoneError::UnknownPartition(ref p)) if p == "gpu"
        ));
        // wider than the partition
        let spec = CampaignSpec::parse(
            "[[workload]]\nkind = \"hpl\"\nname = \"w\"\nnode = \"mcv2\"\npartition = \"mcv2\"\nnodes = 9\ncluster_nodes = 9\ncores_per_node = 64\n",
        )
        .unwrap();
        assert!(matches!(
            dry_run_spec(&inv, &spec),
            Err(CimoneError::PartitionTooSmall { .. })
        ));
    }

    #[test]
    fn report_exports_json() {
        let r = run_campaign(48).unwrap();
        let j = r.to_json();
        let text = j.render();
        // round-trips through the parser
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("hpl_passed"), Some(&Json::Bool(true)));
        assert_eq!(back.get("jobs").unwrap().as_arr().unwrap().len(), 9);
        let job0 = back.get("jobs").unwrap().idx(0).unwrap();
        assert_eq!(job0.get("name").unwrap().as_str(), Some("stream-mcv1"));
        assert_eq!(job0.get("metric").unwrap().as_str(), Some("bandwidth"));
        assert!(job0.get("avg_node_w").unwrap().as_f64().unwrap() > 0.0);
        assert!(back.get("metrics").unwrap().get("hpl-mcv2-1s.gflops").is_some());
    }

    #[test]
    fn duplicate_job_names_rejected_by_engine() {
        use super::super::campaign::WorkloadSpec;
        let inv = monte_cimone_v2();
        let mut spec = CampaignSpec::new();
        for _ in 0..2 {
            spec.push(WorkloadSpec::Stream {
                name: "dup".into(),
                partition: "mcv2".into(),
                nodes: 1,
                platform: "mcv2-pioneer".into(),
                threads: 64,
            });
        }
        assert!(matches!(
            run_campaign_spec(&inv, &spec),
            Err(CimoneError::Spec(ref m)) if m.contains("duplicate")
        ));
    }

    #[test]
    fn unknown_partition_in_spec_is_typed() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec::parse(
            "[[workload]]\nkind = \"stream\"\nname = \"s\"\nnode = \"mcv1\"\npartition = \"gpu\"\nthreads = 4\n",
        )
        .unwrap();
        assert!(matches!(
            run_campaign_spec(&inv, &spec),
            Err(CimoneError::UnknownPartition(ref p)) if p == "gpu"
        ));
    }
}
