//! The campaign engine: execute a declarative [`CampaignSpec`] end to
//! end over a simulated Monte Cimone fleet.
//!
//! The engine (1) anchors the campaign in real numerics by running the
//! host HPL solve + STREAM validation, (2) instantiates every workload
//! descriptor and *estimates them in parallel* (rayon) against the
//! inventory, (3) submits the jobs to the SLURM-like scheduler in spec
//! order — deterministic queueing — recording each workload's metrics
//! (headline + power/energy) in the ExaMon-like monitor, and (4) drains
//! the partitions concurrently
//! ([`Scheduler::drain_parallel`](crate::sched::Scheduler::drain_parallel)),
//! which keeps simulated-time accounting identical to a serial drain.
//! This is what `examples/e2e_cluster.rs` and `cimone campaign` run.
//!
//! [`dry_run_spec`] is the scheduling-free variant: it validates the
//! spec, estimates every job and checks partition fit, but runs neither
//! the real-numerics solve nor the drain — `cimone campaign --dry-run`.

use std::collections::{BTreeMap, BTreeSet};

use rayon::prelude::*;

use crate::cluster::{monte_cimone_v2, Inventory, Monitor};
use crate::error::CimoneError;
use crate::hpl::driver::{run as hpl_run, Backend, HplConfig};
use crate::sched::{JobRequest, Scheduler};
use crate::stream::kernels::validate_kernels;
use crate::util::json::Json;

use super::campaign::CampaignSpec;
use super::workload::{JobEstimate, Workload};

/// One campaign job's outcome: runtime, headline metric, and the
/// power/energy numbers derived from its platform's power model.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRow {
    pub name: String,
    /// Metric family (`gflops` | `bandwidth`), so consumers like the
    /// scenario comparison can classify rows without re-parsing names.
    pub metric: &'static str,
    /// Simulated seconds the job occupies its nodes.
    pub runtime_s: f64,
    /// Headline metric (GB/s for STREAM, GFLOP/s for HPL).
    pub headline: f64,
    /// Average per-node draw while running (W).
    pub avg_node_w: f64,
    /// Energy-to-solution across all allocated nodes (J).
    pub energy_j: f64,
    /// GFLOP/s per watt for compute jobs; `None` for bandwidth jobs.
    pub gflops_per_w: Option<f64>,
}

fn job_row(w: &dyn Workload, est: &JobEstimate) -> JobRow {
    // derive total draw from the estimate itself (energy / runtime) so
    // efficiency uses exactly the node count the metric was modeled on
    let total_w = if est.runtime_s > 0.0 { est.energy_j / est.runtime_s } else { 0.0 };
    let gflops_per_w =
        if est.metric == "gflops" && total_w > 0.0 { Some(est.value / total_w) } else { None };
    JobRow {
        name: w.name().to_string(),
        metric: est.metric,
        runtime_s: est.runtime_s,
        headline: est.headline,
        avg_node_w: est.avg_node_w,
        energy_j: est.energy_j,
        gflops_per_w,
    }
}

/// Aggregated outcome of one `[[queue]]` job stream after the drain.
#[derive(Debug, Clone, PartialEq)]
pub struct QueueOutcome {
    pub user: String,
    /// Template workload the stream cloned.
    pub workload: String,
    /// Jobs in the stream.
    pub jobs: usize,
    /// Mean queue wait (start - arrival) across the stream (s).
    pub mean_wait_s: f64,
    /// Worst queue wait in the stream (s).
    pub max_wait_s: f64,
    /// When the stream's last job completed (s).
    pub end_s: f64,
}

impl QueueOutcome {
    /// Machine-readable form for the `queues` array of the report JSON.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("user", Json::Str(self.user.clone())),
            ("workload", Json::Str(self.workload.clone())),
            ("jobs", Json::Num(self.jobs as f64)),
            ("mean_wait_s", Json::Num(self.mean_wait_s)),
            ("max_wait_s", Json::Num(self.max_wait_s)),
            ("end_s", Json::Num(self.end_s)),
        ])
    }
}

/// Campaign outcome.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub jobs: Vec<JobRow>,
    /// Per-queue wait/throughput aggregates (empty without `[[queue]]`s).
    pub queues: Vec<QueueOutcome>,
    pub makespan_s: f64,
    /// real-numerics validation outcomes
    pub hpl_residual: f64,
    pub hpl_passed: bool,
    pub stream_validated: bool,
    pub monitor: Monitor,
}

impl CampaignReport {
    /// Machine-readable export for the artifacts pipeline
    /// (`cimone campaign --json`).
    pub fn to_json(&self) -> Json {
        let metrics: BTreeMap<String, Json> = self
            .monitor
            .query_prefix("")
            .into_iter()
            .map(|(k, v)| (k.to_string(), Json::Num(v)))
            .collect();
        Json::obj([
            ("makespan_s", Json::Num(self.makespan_s)),
            ("hpl_residual", Json::Num(self.hpl_residual)),
            ("hpl_passed", Json::Bool(self.hpl_passed)),
            ("stream_validated", Json::Bool(self.stream_validated)),
            ("jobs", Json::Arr(self.jobs.iter().map(JobRow::to_json).collect())),
            ("queues", Json::Arr(self.queues.iter().map(QueueOutcome::to_json).collect())),
            ("metrics", Json::Obj(metrics)),
        ])
    }
}

impl JobRow {
    /// Machine-readable form, used by both `--json` and `--dry-run --json`.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::Str(self.name.clone())),
            ("metric", Json::Str(self.metric.to_string())),
            ("runtime_s", Json::Num(self.runtime_s)),
            ("headline", Json::Num(self.headline)),
            ("avg_node_w", Json::Num(self.avg_node_w)),
            ("energy_j", Json::Num(self.energy_j)),
            ("gflops_per_w", self.gflops_per_w.map(Json::Num).unwrap_or(Json::Null)),
        ])
    }
}

/// Run the paper's campaign on the standard fleet.
pub fn run_campaign(validate_n: usize) -> Result<CampaignReport, CimoneError> {
    let inv = monte_cimone_v2();
    run_campaign_on(&inv, validate_n)
}

/// Run the paper's campaign on a given inventory.
pub fn run_campaign_on(inv: &Inventory, validate_n: usize) -> Result<CampaignReport, CimoneError> {
    let mut spec = CampaignSpec::paper_default();
    spec.validate_n = validate_n;
    run_campaign_spec(inv, &spec)
}

/// Instantiate the spec's workloads and estimate them in parallel.
/// Callers are expected to have run `spec.validate()` first.
fn estimate_all(
    inv: &Inventory,
    spec: &CampaignSpec,
) -> Result<Vec<(Box<dyn Workload>, JobEstimate)>, CimoneError> {
    let workloads: Vec<Box<dyn Workload>> = spec.workloads.iter().map(|w| w.build()).collect();
    let estimates: Vec<Result<JobEstimate, CimoneError>> =
        workloads.par_iter().map(|w| w.estimate(inv)).collect();
    workloads
        .into_iter()
        .zip(estimates)
        .map(|(w, est)| est.map(|e| (w, e)))
        .collect()
}

/// Run an arbitrary campaign spec on a given inventory.
pub fn run_campaign_spec(
    inv: &Inventory,
    spec: &CampaignSpec,
) -> Result<CampaignReport, CimoneError> {
    spec.validate()?;
    let mut sched = inv.scheduler();
    let mut mon = Monitor::new();

    // --- 1. real-numerics validation runs (host execution) ---
    let hpl = hpl_run(&HplConfig {
        n: spec.validate_n,
        nb: 32.min(spec.validate_n),
        seed: 42,
        backend: Backend::Native,
    })
    .map_err(|e| CimoneError::ValidationRun { n: spec.validate_n, cause: Box::new(e) })?;
    let stream_ok = validate_kernels(1 << 16).is_ok();
    mon.record("frontend.hpl.residual", 0.0, hpl.residual);

    // --- 2. instantiate + estimate every workload, in parallel ---
    let estimated = estimate_all(inv, spec)?;

    // --- 3. degraded-fleet ablations: availability windows first, so
    //        every submission sees the schedulable capacity it will get
    apply_outages(&mut sched, spec)?;

    // --- 4. submit in spec order (deterministic queueing + metrics);
    //        workloads serving as queue templates are expanded into
    //        their per-user streams instead of running once standalone
    let templates: BTreeSet<&str> = spec.queues.iter().map(|q| q.workload.as_str()).collect();
    let mut jobs = Vec::with_capacity(estimated.len());
    for (w, est) in &estimated {
        if templates.contains(w.name()) {
            continue;
        }
        sched.submit(w.name(), w.partition(), w.nodes(), est.runtime_s)?;
        w.metrics(&mut mon, sched.now, est);
        jobs.push(job_row(w.as_ref(), est));
    }
    for q in &spec.queues {
        let (w, est) = estimated
            .iter()
            .find(|(w, _)| w.name() == q.workload)
            .expect("validated: queue template exists");
        for i in 0..q.count {
            sched.submit_request(
                JobRequest::new(q.job_name(i), w.partition(), w.nodes(), est.runtime_s)
                    .arriving_at(q.arrival_s(i))
                    .with_priority(q.priority)
                    .with_user(&q.user),
            )?;
        }
    }

    // --- 5. drain independent partitions concurrently ---
    let makespan = sched.drain_parallel();

    // --- 6. per-queue wait/throughput aggregates from the drained state
    let by_name: BTreeMap<&str, &crate::sched::Job> =
        sched.jobs.iter().map(|j| (j.name.as_str(), j)).collect();
    let mut queues = Vec::with_capacity(spec.queues.len());
    for q in &spec.queues {
        let mut wait_sum = 0.0f64;
        let mut wait_max = 0.0f64;
        let mut end_s = 0.0f64;
        for i in 0..q.count {
            let name = q.job_name(i);
            let j = by_name.get(name.as_str()).expect("queue jobs were submitted");
            let wait = j.wait_time().unwrap_or(0.0);
            wait_sum += wait;
            wait_max = wait_max.max(wait);
            if let Some(e) = j.end_time() {
                end_s = end_s.max(e);
            }
        }
        let mean_wait_s = wait_sum / q.count as f64;
        let prefix = format!("queue.{}.{}", q.user, q.workload);
        mon.record(&format!("{prefix}.jobs"), makespan, q.count as f64);
        mon.record(&format!("{prefix}.wait_mean_s"), makespan, mean_wait_s);
        mon.record(&format!("{prefix}.wait_max_s"), makespan, wait_max);
        queues.push(QueueOutcome {
            user: q.user.clone(),
            workload: q.workload.clone(),
            jobs: q.count,
            mean_wait_s,
            max_wait_s: wait_max,
            end_s,
        });
    }

    Ok(CampaignReport {
        jobs,
        queues,
        makespan_s: makespan,
        hpl_residual: hpl.residual,
        hpl_passed: hpl.passed,
        stream_validated: stream_ok,
        monitor: mon,
    })
}

/// Feed the spec's expanded outage windows into a scheduler.
fn apply_outages(sched: &mut Scheduler, spec: &CampaignSpec) -> Result<(), CimoneError> {
    for o in &spec.outages {
        for (down, up) in o.windows() {
            sched.schedule_outage(o.node, down, up)?;
        }
    }
    Ok(())
}

/// Validate a spec against an inventory without scheduling anything:
/// parse-level invariants, per-workload estimation (platform resolution,
/// finite runtimes) and partition fit are all checked; the real-numerics
/// solve and the drain are skipped. Returns the per-job estimates.
pub fn dry_run_spec(inv: &Inventory, spec: &CampaignSpec) -> Result<Vec<JobRow>, CimoneError> {
    spec.validate()?;
    let estimated = estimate_all(inv, spec)?;
    // a scratch scheduler checks partition existence, width and runtime
    // validity exactly as the real submission path would — outages
    // applied first, so a job that cannot fit the degraded fleet is a
    // dry-run error too (queue templates are fit-checked once here
    // rather than `count` times)
    let mut sched = inv.scheduler();
    apply_outages(&mut sched, spec)?;
    let mut rows = Vec::with_capacity(estimated.len());
    for (w, est) in &estimated {
        sched.submit(w.name(), w.partition(), w.nodes(), est.runtime_s)?;
        rows.push(job_row(w.as_ref(), est));
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn campaign_runs_and_validates() {
        let r = run_campaign(96).unwrap();
        assert!(r.hpl_passed, "residual {}", r.hpl_residual);
        assert!(r.stream_validated);
        assert_eq!(r.jobs.len(), 9);
        assert!(r.makespan_s > 0.0);
    }

    #[test]
    fn campaign_metrics_recorded() {
        let r = run_campaign(64).unwrap();
        assert!(r.monitor.latest("hpl-mcv2-1s.gflops").unwrap() > 100.0);
        assert!(r.monitor.metric_count() >= 9);
    }

    #[test]
    fn campaign_fig5_ordering() {
        let r = run_campaign(64).unwrap();
        let get = |n: &str| r.monitor.latest(n).unwrap();
        assert!(get("hpl-mcv1-full.gflops") < get("hpl-mcv2-1s.gflops"));
        assert!(get("hpl-mcv2-2n.gflops") < get("hpl-mcv2-2s.gflops"));
        assert!(get("hpl-blis-opt.gflops") > get("hpl-blis-vanilla.gflops"));
    }

    #[test]
    fn per_job_power_metrics_recorded() {
        let r = run_campaign(64).unwrap();
        // every job reports power and energy series
        for j in &r.jobs {
            assert!(j.avg_node_w > 0.0, "{}: {}", j.name, j.avg_node_w);
            assert!(j.energy_j > 0.0, "{}: {}", j.name, j.energy_j);
            assert_eq!(r.monitor.latest(&format!("{}.power_w", j.name)), Some(j.avg_node_w));
            assert_eq!(r.monitor.latest(&format!("{}.energy_j", j.name)), Some(j.energy_j));
        }
        // HPL jobs surface GFLOP/s-per-W; STREAM jobs don't
        let by_name = |n: &str| r.jobs.iter().find(|j| j.name == n).unwrap().clone();
        assert!(by_name("hpl-mcv2-1s").gflops_per_w.unwrap() > 0.5);
        assert!(by_name("stream-mcv1").gflops_per_w.is_none());
        // MCv2 is an order of magnitude more efficient than MCv1 (the
        // paper's Top500/Green500 argument)
        let v1 = by_name("hpl-mcv1-full").gflops_per_w.unwrap();
        let v2 = by_name("hpl-mcv2-1s").gflops_per_w.unwrap();
        assert!(v2 > 5.0 * v1, "v2 {v2:.2} vs v1 {v1:.2}");
    }

    #[test]
    fn empty_spec_drains_to_zero_makespan() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec { workloads: vec![], validate_n: 64, ..Default::default() };
        let r = run_campaign_spec(&inv, &spec).unwrap();
        assert!(r.jobs.is_empty());
        assert_eq!(r.makespan_s, 0.0);
        assert!(r.hpl_passed);
    }

    #[test]
    fn spec_engine_matches_legacy_campaign_shape() {
        // the declarative path must reproduce the seed campaign exactly
        let r = run_campaign(64).unwrap();
        let names: Vec<&str> = r.jobs.iter().map(|j| j.name.as_str()).collect();
        assert_eq!(
            names,
            [
                "stream-mcv1",
                "stream-mcv2-1s",
                "stream-mcv2-2s",
                "hpl-mcv1-full",
                "hpl-mcv2-1s",
                "hpl-mcv2-2n",
                "hpl-mcv2-2s",
                "hpl-blis-vanilla",
                "hpl-blis-opt",
            ]
        );
        // blis jobs occupy their fixed 3600 s slot
        assert_eq!(r.jobs[7].runtime_s, 3600.0);
        assert_eq!(r.jobs[8].runtime_s, 3600.0);
    }

    #[test]
    fn config_driven_spec_runs() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec::parse(
            "[campaign]\nvalidate_n = 48\n\n\
             [[workload]]\nkind = \"stream\"\nname = \"s1\"\nnode = \"mcv2\"\npartition = \"mcv2\"\nthreads = 64\n\n\
             [[workload]]\nkind = \"hpl\"\nname = \"h1\"\nnode = \"mcv2-dual\"\npartition = \"mcv2\"\ncores_per_node = 128\n",
        )
        .unwrap();
        let r = run_campaign_spec(&inv, &spec).unwrap();
        assert_eq!(r.jobs.len(), 2);
        assert!(r.monitor.latest("s1.bandwidth").unwrap() > 1e9);
        assert!(r.monitor.latest("h1.gflops").unwrap() > 100.0);
    }

    #[test]
    fn dry_run_estimates_without_scheduling() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec::paper_default();
        let rows = dry_run_spec(&inv, &spec).unwrap();
        assert_eq!(rows.len(), 9);
        for r in &rows {
            assert!(r.runtime_s.is_finite() && r.runtime_s > 0.0);
            assert!(r.headline.is_finite() && r.headline > 0.0);
        }
        // dry-run numbers match the real run's rows
        let full = run_campaign_spec(&inv, &spec).unwrap();
        for (a, b) in rows.iter().zip(&full.jobs) {
            assert_eq!(a.name, b.name);
            assert!((a.headline - b.headline).abs() < 1e-9);
        }
    }

    #[test]
    fn dry_run_rejects_invalid_specs() {
        let inv = monte_cimone_v2();
        // partition that doesn't exist
        let spec = CampaignSpec::parse(
            "[[workload]]\nkind = \"stream\"\nname = \"s\"\nnode = \"mcv1\"\npartition = \"gpu\"\nthreads = 4\n",
        )
        .unwrap();
        assert!(matches!(
            dry_run_spec(&inv, &spec),
            Err(CimoneError::UnknownPartition(ref p)) if p == "gpu"
        ));
        // wider than the partition
        let spec = CampaignSpec::parse(
            "[[workload]]\nkind = \"hpl\"\nname = \"w\"\nnode = \"mcv2\"\npartition = \"mcv2\"\nnodes = 9\ncluster_nodes = 9\ncores_per_node = 64\n",
        )
        .unwrap();
        assert!(matches!(
            dry_run_spec(&inv, &spec),
            Err(CimoneError::PartitionTooSmall { .. })
        ));
    }

    #[test]
    fn report_exports_json() {
        let r = run_campaign(48).unwrap();
        let j = r.to_json();
        let text = j.render();
        // round-trips through the parser
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("hpl_passed"), Some(&Json::Bool(true)));
        assert_eq!(back.get("jobs").unwrap().as_arr().unwrap().len(), 9);
        let job0 = back.get("jobs").unwrap().idx(0).unwrap();
        assert_eq!(job0.get("name").unwrap().as_str(), Some("stream-mcv1"));
        assert_eq!(job0.get("metric").unwrap().as_str(), Some("bandwidth"));
        assert!(job0.get("avg_node_w").unwrap().as_f64().unwrap() > 0.0);
        assert!(back.get("metrics").unwrap().get("hpl-mcv2-1s.gflops").is_some());
    }

    #[test]
    fn duplicate_job_names_rejected_by_engine() {
        use super::super::campaign::WorkloadSpec;
        let inv = monte_cimone_v2();
        let mut spec = CampaignSpec::new();
        for _ in 0..2 {
            spec.push(WorkloadSpec::Stream {
                name: "dup".into(),
                partition: "mcv2".into(),
                nodes: 1,
                platform: "mcv2-pioneer".into(),
                threads: 64,
            });
        }
        assert!(matches!(
            run_campaign_spec(&inv, &spec),
            Err(CimoneError::Spec(ref m)) if m.contains("duplicate")
        ));
    }

    #[test]
    fn queue_sections_expand_into_multi_user_streams() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec::parse(
            "[campaign]\nvalidate_n = 48\n\n\
             [[workload]]\nkind = \"hpl\"\nname = \"hpl-1s\"\nplatform = \"mcv2-pioneer\"\n\
             partition = \"mcv2\"\ncores_per_node = 64\n\n\
             [[queue]]\nuser = \"alice\"\nworkload = \"hpl-1s\"\ncount = 4\ninterval_s = 10.0\npriority = 1\n\n\
             [[queue]]\nuser = \"bob\"\nworkload = \"hpl-1s\"\ncount = 2\nstart_s = 5.0\n",
        )
        .unwrap();
        let r = run_campaign_spec(&inv, &spec).unwrap();
        // the template ran only as stream clones, not standalone
        assert!(r.jobs.is_empty(), "{:?}", r.jobs);
        assert_eq!(r.queues.len(), 2);
        let alice = &r.queues[0];
        assert_eq!((alice.user.as_str(), alice.jobs), ("alice", 4));
        assert!(alice.end_s > 0.0 && alice.end_s <= r.makespan_s);
        assert!(alice.mean_wait_s >= 0.0 && alice.max_wait_s >= alice.mean_wait_s);
        // the monitor carries the per-queue aggregates
        assert_eq!(r.monitor.latest("queue.alice.hpl-1s.jobs"), Some(4.0));
        assert_eq!(r.monitor.latest("queue.bob.hpl-1s.jobs"), Some(2.0));
        assert_eq!(
            r.monitor.latest("queue.alice.hpl-1s.wait_mean_s"),
            Some(alice.mean_wait_s)
        );
        // ...and the JSON export carries the queues array
        let back = Json::parse(&r.to_json().render()).unwrap();
        let queues = back.get("queues").unwrap().as_arr().unwrap();
        assert_eq!(queues.len(), 2);
        assert_eq!(queues[0].get("user").unwrap().as_str(), Some("alice"));
        assert_eq!(queues[0].get("jobs").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn queue_campaign_is_deterministic() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec::parse(
            "[campaign]\nvalidate_n = 48\n\n\
             [[workload]]\nkind = \"stream\"\nname = \"st\"\nplatform = \"mcv2-pioneer\"\n\
             partition = \"mcv2\"\nthreads = 64\n\n\
             [[queue]]\nuser = \"u\"\nworkload = \"st\"\ncount = 16\ninterval_s = 3.0\n",
        )
        .unwrap();
        let a = run_campaign_spec(&inv, &spec).unwrap();
        let b = run_campaign_spec(&inv, &spec).unwrap();
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.queues, b.queues);
    }

    #[test]
    fn outages_reshape_the_campaign_and_dry_run_sees_them() {
        let inv = monte_cimone_v2();
        let base = "[campaign]\nvalidate_n = 48\n\n\
             [[workload]]\nkind = \"hpl\"\nname = \"h2\"\nplatform = \"mcv2-pioneer\"\n\
             partition = \"mcv2\"\nnodes = 2\ncores_per_node = 64\n";
        let free = run_campaign_spec(&inv, &CampaignSpec::parse(base).unwrap()).unwrap();
        // nodes 8+9 down from the start: the 2-node job waits for 10/11
        // or reroutes — either way it still completes
        let degraded = format!(
            "{base}\n[[outage]]\nnode = 8\ndown_s = 0.0\n\n[[outage]]\nnode = 9\ndown_s = 0.0\n"
        );
        let spec = CampaignSpec::parse(&degraded).unwrap();
        let r = run_campaign_spec(&inv, &spec).unwrap();
        assert_eq!(r.jobs.len(), 1);
        assert!(r.makespan_s >= free.makespan_s);
        // downing the whole mcv2 partition makes the job unschedulable,
        // and the dry run reports it with the same typed error
        let dead = format!(
            "{base}\n[[outage]]\nnode = 8\ndown_s = 0.0\n\n[[outage]]\nnode = 9\ndown_s = 0.0\n\n\
             [[outage]]\nnode = 10\ndown_s = 0.0\n\n[[outage]]\nnode = 11\ndown_s = 0.0\n"
        );
        let spec = CampaignSpec::parse(&dead).unwrap();
        assert!(matches!(
            dry_run_spec(&inv, &spec),
            Err(CimoneError::PartitionTooSmall { .. })
        ));
        assert!(matches!(
            run_campaign_spec(&inv, &spec),
            Err(CimoneError::PartitionTooSmall { .. })
        ));
    }

    #[test]
    fn unknown_partition_in_spec_is_typed() {
        let inv = monte_cimone_v2();
        let spec = CampaignSpec::parse(
            "[[workload]]\nkind = \"stream\"\nname = \"s\"\nnode = \"mcv1\"\npartition = \"gpu\"\nthreads = 4\n",
        )
        .unwrap();
        assert!(matches!(
            run_campaign_spec(&inv, &spec),
            Err(CimoneError::UnknownPartition(ref p)) if p == "gpu"
        ));
    }
}
