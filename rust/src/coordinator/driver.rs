//! End-to-end cluster driver: the full system composed.
//!
//! Submits the paper's benchmark campaign to the SLURM-like scheduler
//! over the simulated Monte Cimone fleet, runs the real-numerics HPL and
//! STREAM kernels (through the PJRT artifacts when available, natively
//! otherwise), records every metric into the ExaMon-like monitor, and
//! returns a campaign report. This is what `examples/e2e_cluster.rs` and
//! `cimone campaign` run.

use crate::arch::soc::NodeKind;
use crate::blas::perf::PerfModel;
use crate::cluster::{monte_cimone_v2, Inventory, Monitor};
use crate::hpl::driver::{run as hpl_run, Backend, HplConfig};
use crate::hpl::model::{project, ClusterConfig};
use crate::mem::stream_model::predict_node_bandwidth;
use crate::stream::kernels::validate_kernels;
use crate::ukernel::UkernelId;

/// Campaign outcome.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    /// (job name, simulated seconds, metric value)
    pub jobs: Vec<(String, f64, f64)>,
    pub makespan_s: f64,
    /// real-numerics validation outcomes
    pub hpl_residual: f64,
    pub hpl_passed: bool,
    pub stream_validated: bool,
    pub monitor: Monitor,
}

/// Run the full campaign on the standard fleet.
pub fn run_campaign(validate_n: usize) -> Result<CampaignReport, String> {
    let inv = monte_cimone_v2();
    run_campaign_on(&inv, validate_n)
}

/// Run the campaign on a given inventory.
pub fn run_campaign_on(inv: &Inventory, validate_n: usize) -> Result<CampaignReport, String> {
    let mut sched = inv.scheduler();
    let mut mon = Monitor::new();
    let mut jobs = Vec::new();

    // --- 1. real-numerics validation runs (host execution) ---
    let hpl = hpl_run(&HplConfig {
        n: validate_n,
        nb: 32.min(validate_n),
        seed: 42,
        backend: Backend::Native,
    })
    .map_err(|e| format!("validation HPL: {e}"))?;
    let stream_ok = validate_kernels(1 << 16).is_ok();
    mon.record("frontend.hpl.residual", 0.0, hpl.residual);

    // --- 2. the paper's campaign as SLURM jobs with modelled runtimes ---
    // STREAM on each node kind
    for (name, kind, part, nodes, threads) in [
        ("stream-mcv1", NodeKind::Mcv1U740, "mcv1", 1usize, 4usize),
        ("stream-mcv2-1s", NodeKind::Mcv2Pioneer, "mcv2", 1, 64),
        ("stream-mcv2-2s", NodeKind::Mcv2DualSocket, "mcv2", 1, 64),
    ] {
        let node_id = inv.ids_of_kind(kind)[0];
        let bw = predict_node_bandwidth(&inv.node(node_id).desc, threads, true);
        // STREAM runtime: 10 iterations x 3 arrays x 8 MiB-ish / bw
        let bytes = 10.0 * 3.0 * 128e6;
        let runtime = (bytes / bw).max(1.0);
        sched.submit(name, part, nodes, runtime)?;
        mon.record(&format!("{name}.bandwidth", ), sched.now, bw);
        jobs.push((name.to_string(), runtime, bw / 1e9));
    }

    // HPL node configurations (Fig 5)
    let single = ClusterConfig::mcv2_default(
        inv.node(inv.ids_of_kind(NodeKind::Mcv2Pioneer)[0]).desc.clone(),
        1,
        64,
    );
    let two_node = ClusterConfig { nodes: 2, ..single.clone() };
    let dual = ClusterConfig::mcv2_default(
        inv.node(inv.ids_of_kind(NodeKind::Mcv2DualSocket)[0]).desc.clone(),
        1,
        128,
    );
    let mut mcv1 = ClusterConfig::mcv2_default(
        inv.node(inv.ids_of_kind(NodeKind::Mcv1U740)[0]).desc.clone(),
        8,
        4,
    );
    mcv1.lib = UkernelId::OpenblasGeneric;
    for (name, part, nodes, cfg) in [
        ("hpl-mcv1-full", "mcv1", 8usize, &mcv1),
        ("hpl-mcv2-1s", "mcv2", 1, &single),
        ("hpl-mcv2-2n", "mcv2", 2, &two_node),
        ("hpl-mcv2-2s", "mcv2", 1, &dual),
    ] {
        let p = project(cfg);
        let runtime = p.t_comp + p.t_comm;
        sched.submit(name, part, nodes, runtime)?;
        mon.record(&format!("{name}.gflops"), sched.now, p.gflops);
        jobs.push((name.to_string(), runtime, p.gflops));
    }

    // BLIS comparison (Fig 7 @128)
    let dual_desc = inv.node(11).desc.clone();
    for (name, lib) in [
        ("hpl-blis-vanilla", UkernelId::BlisLmul1),
        ("hpl-blis-opt", UkernelId::BlisLmul4),
    ] {
        let gf = PerfModel::new(&dual_desc, lib).node_gflops(128);
        sched.submit(name, "mcv2", 1, 3600.0)?;
        mon.record(&format!("{name}.gflops"), sched.now, gf);
        jobs.push((name.to_string(), 3600.0, gf));
    }

    let makespan = sched.drain();
    Ok(CampaignReport {
        jobs,
        makespan_s: makespan,
        hpl_residual: hpl.residual,
        hpl_passed: hpl.passed,
        stream_validated: stream_ok,
        monitor: mon,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn campaign_runs_and_validates() {
        let r = run_campaign(96).unwrap();
        assert!(r.hpl_passed, "residual {}", r.hpl_residual);
        assert!(r.stream_validated);
        assert_eq!(r.jobs.len(), 9);
        assert!(r.makespan_s > 0.0);
    }

    #[test]
    fn campaign_metrics_recorded() {
        let r = run_campaign(64).unwrap();
        assert!(r.monitor.latest("hpl-mcv2-1s.gflops").unwrap() > 100.0);
        assert!(r.monitor.metric_count() >= 9);
    }

    #[test]
    fn campaign_fig5_ordering() {
        let r = run_campaign(64).unwrap();
        let get = |n: &str| r.monitor.latest(n).unwrap();
        assert!(get("hpl-mcv1-full.gflops") < get("hpl-mcv2-1s.gflops"));
        assert!(get("hpl-mcv2-2n.gflops") < get("hpl-mcv2-2s.gflops"));
        assert!(get("hpl-blis-opt.gflops") > get("hpl-blis-vanilla.gflops"));
    }
}
