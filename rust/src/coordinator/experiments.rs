//! One function per paper figure, returning the same rows/series the
//! paper plots. The bench targets (`rust/benches/fig*.rs`) and the CLI
//! both call these.

use crate::arch::platform::{mcv1_u740, mcv2_dual, mcv2_pioneer};
use crate::arch::presets;
use crate::blas::blocking::Blocking;
use crate::blas::perf::PerfModel;
use crate::cache::{simulate_gemm, GemmTraceConfig};
use crate::hpl::model::{cluster_hpl_gflops, ClusterConfig};
use crate::mem::stream_model::predict_node_bandwidth;
use crate::ukernel::KernelRegistry;

/// Fig 3 — STREAM bandwidth: one row per node configuration.
/// Returns (label, threads, GB/s).
pub fn fig3() -> Vec<(String, usize, f64)> {
    vec![
        (
            "MCv1 (U740), 4 threads".into(),
            4,
            predict_node_bandwidth(&presets::u740(), 4, true) / 1e9,
        ),
        (
            "MCv2 1-socket, 64 threads".into(),
            64,
            predict_node_bandwidth(&presets::sg2042(), 64, true) / 1e9,
        ),
        (
            "MCv2 2-socket, 64 threads (symmetric)".into(),
            64,
            predict_node_bandwidth(&presets::sg2042_dual(), 64, true) / 1e9,
        ),
        (
            "MCv2 2-socket, 128 threads".into(),
            128,
            predict_node_bandwidth(&presets::sg2042_dual(), 128, true) / 1e9,
        ),
    ]
}

/// Fig 4 — HPL vs core count for generic/optimized OpenBLAS on one MCv2
/// socket. Returns (cores, generic GF/s, optimized GF/s).
pub fn fig4(core_counts: &[usize]) -> Vec<(usize, f64, f64)> {
    let reg = KernelRegistry::builtin();
    let d = mcv2_pioneer();
    let gen = PerfModel::new(&d, reg.get("openblas-generic").expect("built-in kernel"));
    let opt = PerfModel::new(&d, reg.get("openblas-c920").expect("built-in kernel"));
    core_counts
        .iter()
        .map(|&c| (c, gen.node_gflops(c), opt.node_gflops(c)))
        .collect()
}

/// Default Fig 4 x-axis.
pub const FIG4_CORES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Fig 5 — HPL across node configurations. Returns (label, GF/s).
pub fn fig5() -> Vec<(String, f64)> {
    // the mcv1-u740 platform's default library is OpenBLAS-generic
    let mcv1 = ClusterConfig::hpl_default(mcv1_u740(), 8, 4);
    vec![
        ("MCv1 32-cores (8 nodes, 1GbE)".into(), cluster_hpl_gflops(&mcv1)),
        (
            "MCv2 64-cores (1 socket)".into(),
            cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv2_pioneer(), 1, 64)),
        ),
        (
            "MCv2 128-cores (2 nodes, 1GbE)".into(),
            cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64)),
        ),
        (
            "MCv2 128-cores (1 dual-socket node)".into(),
            cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv2_dual(), 1, 128)),
        ),
    ]
}

/// Fig 6 — L1/L3 miss rates, HPL's dominant DGEMM, OpenBLAS-opt vs
/// BLIS-vanilla. Returns (cores, ob_l1, ob_l3, blis_l1, blis_l3) in %.
///
/// Geometry: m = n = 512*scale, k = 768 (deep enough that OpenBLAS's
/// x86-sized KC=768 fully unfolds — the condition under which its
/// micro-panels overflow the C920's L1D). `scale` shrinks m/n so tests
/// can trade fidelity for time; the CLI/bench use 1.0.
pub fn fig6(core_counts: &[usize], scale: f64) -> Vec<(usize, f64, f64, f64, f64)> {
    let socket = presets::sg2042().sockets[0].clone();
    let mn = ((512.0 * scale) as usize).max(192);
    let k = 768;
    let run = |blocking: Blocking, cores: usize| {
        let st = simulate_gemm(
            &GemmTraceConfig { m: mn, n: mn, k, blocking, cores },
            &socket,
        );
        (st.l1_miss_rate() * 100.0, st.l3_misses_per_load() * 100.0)
    };
    core_counts
        .iter()
        .map(|&c| {
            let cc = c.min(socket.cores);
            let (ob1, ob3) = run(Blocking::openblas_fixed(8, 4), cc);
            let (bl1, bl3) = run(Blocking::blis_for(&socket, 8, 4), cc);
            (cc, ob1, ob3, bl1, bl3)
        })
        .collect()
}

/// Default Fig 6 x-axis.
pub const FIG6_CORES: [usize; 4] = [1, 8, 16, 32];

/// Fig 7 — HPL with OpenBLAS-opt / BLIS-vanilla / BLIS-opt across core
/// counts on the MCv2 dual-socket node. Returns
/// (cores, openblas, blis_vanilla, blis_opt).
pub fn fig7(core_counts: &[usize]) -> Vec<(usize, f64, f64, f64)> {
    let reg = KernelRegistry::builtin();
    let d = mcv2_dual();
    let ob = PerfModel::new(&d, reg.get("openblas-c920").expect("built-in kernel"));
    let bv = PerfModel::new(&d, reg.get("blis-lmul1").expect("built-in kernel"));
    let bo = PerfModel::new(&d, reg.get("blis-lmul4").expect("built-in kernel"));
    core_counts
        .iter()
        .map(|&c| (c, ob.node_gflops(c), bv.node_gflops(c), bo.node_gflops(c)))
        .collect()
}

/// Default Fig 7 x-axis.
pub const FIG7_CORES: [usize; 6] = [1, 8, 16, 32, 64, 128];

/// The abstract's headline: node-level uplift MCv2 vs MCv1.
/// Returns (hpl_uplift, stream_uplift).
pub fn headline() -> (f64, f64) {
    let reg = KernelRegistry::builtin();
    let v1 = mcv1_u740();
    let v2 = mcv2_dual();
    let hpl_old =
        PerfModel::new(&v1, reg.get("openblas-generic").expect("built-in kernel")).node_gflops(4);
    let hpl_new =
        PerfModel::new(&v2, reg.get("openblas-c920").expect("built-in kernel")).node_gflops(128);
    let st_old = predict_node_bandwidth(&presets::u740(), 4, true);
    let st_new = predict_node_bandwidth(&presets::sg2042_dual(), 64, true);
    (hpl_new / hpl_old, st_new / st_old)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape() {
        let rows = fig3();
        assert_eq!(rows.len(), 4);
        assert!((rows[0].2 - 1.1).abs() < 0.1); // MCv1
        assert!((rows[1].2 - 41.9).abs() < 1.0); // MCv2 1S
        assert!((rows[2].2 - 82.9).abs() < 3.0); // MCv2 2S
    }

    #[test]
    fn fig4_efficiency_rises() {
        let rows = fig4(&FIG4_CORES);
        let first = rows[0].1 / rows[0].2;
        let last = rows.last().unwrap().1 / rows.last().unwrap().2;
        assert!(last > first, "ratio must rise: {first:.3} -> {last:.3}");
    }

    #[test]
    fn fig5_ordering_matches_paper() {
        let rows = fig5();
        // single-socket < 2-node < dual-socket, MCv1 smallest
        assert!(rows[0].1 < rows[1].1);
        assert!(rows[1].1 < rows[2].1);
        assert!(rows[2].1 < rows[3].1);
    }

    #[test]
    fn fig6_blis_wins_both_levels() {
        for (c, ob1, ob3, bl1, bl3) in fig6(&[1, 4], 0.5) {
            assert!(bl1 < ob1, "L1 at {c} cores: blis {bl1:.2}% vs ob {ob1:.2}%");
            assert!(bl3 <= ob3 + 1.0, "L3 at {c} cores: blis {bl3:.2}% vs ob {ob3:.2}%");
        }
    }

    #[test]
    fn fig7_blis_opt_catches_openblas() {
        let rows = fig7(&FIG7_CORES);
        let (_, ob, bv, bo) = rows.last().unwrap();
        assert!(bo > bv, "optimized must beat vanilla");
        assert!((bo / ob - 1.0).abs() < 0.06, "parity: {bo:.1} vs {ob:.1}");
    }

    #[test]
    fn headline_ratios() {
        let (hpl, stream) = headline();
        assert!((100.0..160.0).contains(&hpl), "{hpl:.0}");
        assert!((60.0..85.0).contains(&stream), "{stream:.0}");
    }
}
