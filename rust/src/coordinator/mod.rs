//! The coordinator: experiment definitions for every paper figure, report
//! rendering, and the end-to-end cluster driver (scheduler + monitor +
//! PJRT-validated numerics).

pub mod driver;
pub mod experiments;
pub mod report;
pub mod sweeps;

pub use experiments::{fig3, fig4, fig5, fig6, fig7, headline};
