//! The coordinator: a declarative campaign engine over simulated
//! Monte Cimone fleets, plus the per-figure experiment definitions and
//! report rendering.
//!
//! The experiment-execution path is data-driven end to end:
//!
//! - [`Workload`] (in [`workload`]) is the unit of execution — name,
//!   partition, node count, an `estimate(&Inventory)` that models the
//!   job's runtime, metric and power/energy, and a
//!   `metrics(&mut Monitor, ..)` hook. Workloads name their platform by
//!   [`crate::arch::PlatformRegistry`] id, so they run unchanged on any
//!   registered platform (the paper fleet, SG2044, MCv3, custom specs).
//! - [`CampaignSpec`] (in [`campaign`]) describes a campaign as an
//!   ordered list of [`campaign::WorkloadSpec`] descriptors plus the
//!   fleet (`(platform_id, count)` pairs) it runs on — built in code or
//!   parsed from a `util::config` file with `[[platform]]` / `[[fleet]]`
//!   / `[[workload]]` sections.
//!   [`CampaignSpec::paper_default`] is the paper's exact 9-job campaign.
//! - [`driver::run_campaign_spec`] executes a spec: real-numerics
//!   validation, parallel workload estimation (rayon), deterministic
//!   submission to the SLURM-like scheduler, concurrent per-partition
//!   drain, and an ExaMon-style metric report with per-job power, energy
//!   and GFLOP/s-per-W. [`driver::dry_run_spec`] validates and estimates
//!   without scheduling, and [`driver::CampaignReport::to_json`] exports
//!   the report for the artifacts pipeline.
//!
//! - [`scenario`] multiplies the whole stack: a
//!   [`scenario::ScenarioMatrix`] expands a base spec across axes
//!   (platforms, fleet sizes, node counts, libraries, interconnect
//!   fabrics, workload subsets) into named scenarios, runs them with
//!   rayon fan-out, and aggregates the campaign reports into a
//!   Green500-style [`scenario::ComparisonReport`] with
//!   speedup-vs-baseline columns (`cimone sweep`). The built-in
//!   `generations` matrix reproduces the paper's 127x HPL / 69x STREAM
//!   MCv1 -> MCv2 headline; `fabric-scaling` crosses generations with
//!   fabrics (via the [`crate::net::FabricRegistry`]) to reproduce the
//!   Fig 5 interconnect collapse.
//!
//! [`experiments`] / [`report`] / [`sweeps`] regenerate every paper
//! figure (and the SG2044/MCv3 extension sweeps) on top of the same
//! models; all failures are typed [`crate::CimoneError`]s.

pub mod campaign;
pub mod driver;
pub mod experiments;
pub mod report;
pub mod scenario;
pub mod sweeps;
pub mod workload;

pub use campaign::{
    CampaignSpec, FabricDef, KernelDef, OutageSpec, PlatformDef, QueueSpec, WorkloadSpec,
};
pub use driver::{
    dry_run_spec, run_campaign, run_campaign_on, run_campaign_spec, CampaignReport, JobRow,
    QueueOutcome,
};
pub use experiments::{fig3, fig4, fig5, fig6, fig7, headline};
pub use scenario::{
    dry_run_matrix, dry_run_matrix_with, run_matrix, run_matrix_with, ComparisonReport, Scenario,
    ScenarioMatrix, ScenarioOutcome, ScenarioSpec, SweepOptions,
};
pub use workload::{JobEstimate, Workload};
