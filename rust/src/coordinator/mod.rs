//! The coordinator: a declarative campaign engine over the simulated
//! Monte Cimone fleet, plus the per-figure experiment definitions and
//! report rendering.
//!
//! The experiment-execution path is data-driven:
//!
//! - [`Workload`] (in [`workload`]) is the unit of execution — name,
//!   partition, node count, an `estimate(&Inventory)` that models the
//!   job's runtime and metric, and a `metrics(&mut Monitor, ..)` hook.
//!   [`workload::StreamWorkload`], [`workload::HplWorkload`] and
//!   [`workload::BlisAblationWorkload`] cover the paper's evaluation.
//! - [`CampaignSpec`] (in [`campaign`]) describes a campaign as an
//!   ordered list of [`campaign::WorkloadSpec`] descriptors — built in
//!   code or parsed from a `util::config` file.
//!   [`CampaignSpec::paper_default`] is the paper's exact 9-job campaign.
//! - [`driver::run_campaign_spec`] executes a spec: real-numerics
//!   validation, parallel workload estimation (rayon), deterministic
//!   submission to the SLURM-like scheduler, concurrent per-partition
//!   drain, and an ExaMon-style metric report.
//!
//! [`experiments`] / [`report`] / [`sweeps`] regenerate every paper
//! figure on top of the same models; all failures are typed
//! [`crate::CimoneError`]s.

pub mod campaign;
pub mod driver;
pub mod experiments;
pub mod report;
pub mod sweeps;
pub mod workload;

pub use campaign::{CampaignSpec, WorkloadSpec};
pub use driver::{run_campaign, run_campaign_on, run_campaign_spec, CampaignReport};
pub use experiments::{fig3, fig4, fig5, fig6, fig7, headline};
pub use workload::{JobEstimate, Workload};
