//! Report rendering: every figure as an ASCII table + bar chart, in the
//! paper's own units and row order.

use super::experiments;
use crate::util::table::{bar_chart, Table};

pub fn render_fig3() -> String {
    let rows = experiments::fig3();
    let mut t = Table::new(vec!["configuration", "threads", "GB/s"]);
    for (label, threads, gbs) in &rows {
        t.row(vec![label.clone(), threads.to_string(), format!("{gbs:.1}")]);
    }
    let bars: Vec<(String, f64)> =
        rows.iter().map(|(l, _, v)| (l.clone(), *v)).collect();
    format!(
        "Fig 3 — STREAM bandwidth (paper: MCv1 1.1, MCv2 1S 41.9, 2S 82.9 GB/s)\n{}\n{}",
        t.render(),
        bar_chart("STREAM triad-class bandwidth", &bars, "GB/s", 40)
    )
}

pub fn render_fig4() -> String {
    let rows = experiments::fig4(&experiments::FIG4_CORES);
    let mut t = Table::new(vec!["cores", "OpenBLAS generic", "OpenBLAS optimized", "ratio"]);
    for (c, g, o) in &rows {
        t.row(vec![
            c.to_string(),
            format!("{g:.1}"),
            format!("{o:.1}"),
            format!("{:.0}%", 100.0 * g / o),
        ]);
    }
    format!(
        "Fig 4 — HPL vs cores, MCv2 socket (paper: ratio 68% @1 core -> 89%)\n{}",
        t.render()
    )
}

pub fn render_fig5() -> String {
    let rows = experiments::fig5();
    let mut t = Table::new(vec!["configuration", "Gflop/s"]);
    for (label, gf) in &rows {
        t.row(vec![label.clone(), format!("{gf:.1}")]);
    }
    let single = rows[1].1;
    let extra = format!(
        "2-node scaling: {:.2}x (paper 1.33x) | dual-socket: {:.2}x (paper 1.76x)\n",
        rows[2].1 / single,
        rows[3].1 / single
    );
    format!(
        "Fig 5 — HPL across node configurations (paper: 13 / 139 / 185 / 245 Gflop/s)\n{}\n{extra}",
        t.render()
    )
}

pub fn render_fig6(scale: f64) -> String {
    let rows = experiments::fig6(&experiments::FIG6_CORES, scale);
    let mut t = Table::new(vec![
        "cores",
        "OpenBLAS L1 miss%",
        "BLIS L1 miss%",
        "OpenBLAS L3 miss%",
        "BLIS L3 miss%",
    ]);
    for (c, ob1, ob3, bl1, bl3) in &rows {
        t.row(vec![
            c.to_string(),
            format!("{ob1:.2}"),
            format!("{bl1:.2}"),
            format!("{ob3:.2}"),
            format!("{bl3:.2}"),
        ]);
    }
    format!(
        "Fig 6 — cache miss rates, HPL DGEMM (paper: BLIS < OpenBLAS at L1 and L3)\n{}",
        t.render()
    )
}

pub fn render_fig7() -> String {
    let rows = experiments::fig7(&experiments::FIG7_CORES);
    let mut t =
        Table::new(vec!["cores", "OpenBLAS opt", "BLIS vanilla", "BLIS optimized", "opt/vanilla"]);
    for (c, ob, bv, bo) in &rows {
        t.row(vec![
            c.to_string(),
            format!("{ob:.1}"),
            format!("{bv:.1}"),
            format!("{bo:.1}"),
            format!("{:+.0}%", 100.0 * (bo / bv - 1.0)),
        ]);
    }
    format!(
        "Fig 7 — HPL by BLAS library (paper @128: 244.9 / 165.0 / 245.8, +49%)\n{}",
        t.render()
    )
}

pub fn render_headline() -> String {
    let (hpl, stream) = experiments::headline();
    format!(
        "Headline (abstract): node uplift MCv2 vs MCv1\n  HPL DP FLOP/s : {hpl:.0}x (paper: 127x)\n  STREAM BW     : {stream:.0}x (paper: 69x)\n"
    )
}

/// The Green500-style generation table: the built-in scenario matrix,
/// dry-run (pure modelling) and rendered with its speedup-vs-MCv1
/// columns — the table form of [`render_headline`], extended down the
/// road. `cimone sweep` runs the same matrix for real.
pub fn render_green500() -> String {
    use super::scenario::{dry_run_matrix, ScenarioMatrix};
    let report = dry_run_matrix(&ScenarioMatrix::generations())
        .expect("the built-in generation matrix is valid");
    report.render()
}

pub fn render_all(fig6_scale: f64) -> String {
    [
        render_fig3(),
        render_fig4(),
        render_fig5(),
        render_fig6(fig6_scale),
        render_fig7(),
        render_headline(),
        render_green500(),
    ]
    .join("\n\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reports_render_nonempty() {
        for s in [render_fig3(), render_fig4(), render_fig5(), render_fig7(), render_headline()] {
            assert!(s.len() > 100, "{s}");
        }
    }

    #[test]
    fn fig5_mentions_ratios() {
        let s = render_fig5();
        assert!(s.contains("paper 1.33x"));
        assert!(s.contains("paper 1.76x"));
    }

    #[test]
    fn fig6_small_scale_renders() {
        let s = render_fig6(0.25);
        assert!(s.contains("BLIS L1"));
    }

    #[test]
    fn green500_table_names_every_generation() {
        let s = render_green500();
        for id in ["mcv1-u740", "mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3"] {
            assert!(s.contains(id), "missing {id} in:\n{s}");
        }
        assert!(s.contains("127x HPL"), "{s}");
    }
}
