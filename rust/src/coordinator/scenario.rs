//! Scenario sweeps: run *many* campaigns as one batch and compare them.
//!
//! A [`ScenarioMatrix`] expands a base [`CampaignSpec`] into N named
//! scenarios — either explicit `[[scenario]]` sections or the cartesian
//! product of `[matrix]` axes (platform ids, fleet sizes, BLAS
//! libraries, workload subsets). [`run_matrix`] fans the scenarios out
//! on rayon (each campaign still drains its partitions concurrently via
//! [`Scheduler::drain_parallel`](crate::sched::Scheduler::drain_parallel))
//! and aggregates the per-scenario [`CampaignReport`]s into a
//! [`ComparisonReport`]: a Green500-style table of HPL GFLOP/s, STREAM
//! GB/s, average node power, GFLOP/s-per-W and speedup-vs-baseline
//! (baseline = first scenario). The built-in [`ScenarioMatrix::generations`]
//! matrix reproduces the paper's headline deltas — ~127x HPL and ~69x
//! STREAM per node from MCv1 to MCv2 — and extends them down the road to
//! the SG2044 (arXiv 2508.13840) and MCv3 (arXiv 2605.22831) platforms;
//! [`ScenarioMatrix::fabric_scaling`] crosses node counts with
//! interconnect fabrics to reproduce the Fig 5 scaling collapse.
//!
//! Sweeps stream: scenarios are decoded lazily out of the axis product
//! ([`ScenarioMatrix::spec_at`] — a mixed-radix decode, never a
//! materialized cartesian product), fanned out shard by shard
//! ([`SweepOptions::shard_size`]) and reduced into the
//! [`ComparisonReport`] as each shard lands, so memory stays bounded by
//! the shard (plus the kept rows) no matter how many scenarios the axes
//! denote. The optional top-k mode ([`SweepOptions::top_k`]) keeps only
//! the baseline row plus the best scenarios by HPL GFLOP/s and records
//! how many rows were truncated ([`ComparisonReport::truncated`]).
//!
//! Spec file format (`cimone sweep --spec file.toml`), on top of the
//! normal campaign sections:
//!
//! ```text
//! [matrix]                   # cartesian product of the listed axes
//! platforms = ["mcv1-u740", "mcv2-dual", "sg2044"]
//! fleet_sizes = [1, 4]       # overrides every fleet entry's count
//! node_counts = [2, 8]       # widens every HPL job (scaling sweeps)
//! libs = ["openblas-c920", "blis-rvv1-lmul2"]   # any registered kernel id
//! fabrics = ["gbe-flat", "ten-gbe-flat"]   # machine interconnects
//! power_caps = [120.0, 250.0]   # per-node W caps (clamp active cores)
//! nodes_down = [0, 2]        # degraded-fleet ablation: last N nodes out
//! workloads = ["stream"]     # subset filters: kind, job name, `prefix*`
//!
//! [[scenario]]               # explicit named scenario, same knobs
//! name = "mcv1-full-rack"
//! platform = "mcv1-u740"
//! count = 8
//! # nodes = 4 / fabric = "ten-gbe-flat" / power_cap_w = 120.0 /
//! # nodes_down = 2 also accepted
//! ```
//!
//! Retargeting a scenario onto a platform rewrites every workload's
//! platform + partition, clamps thread/core counts to the platform's
//! cores, and swaps the fleet for `(platform, count)` — so one base
//! campaign describes every generation. Unknown platforms, libraries and
//! filters that select nothing are typed [`CimoneError`]s at load time.
//!
//! [`CampaignReport`]: super::driver::CampaignReport

use std::collections::BTreeSet;

use rayon::prelude::*;

use crate::error::CimoneError;
use crate::util::config::{Config, Section, Value};
use crate::util::json::Json;
use crate::util::table::Table;

use super::campaign::{CampaignSpec, OutageSpec, WorkloadSpec};
use super::driver::{dry_run_spec, run_campaign_spec, JobRow};

/// The `[matrix]` axes; empty axes do not participate in the product.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MatrixAxes {
    /// Platform ids (or aliases) to retarget the base campaign onto.
    pub platforms: Vec<String>,
    /// Fleet sizes (node counts) to run each combination at.
    pub fleet_sizes: Vec<usize>,
    /// HPL cluster widths: every HPL job (and the fleet, when retargeted)
    /// is widened to this many nodes — the node-count-scaling axis.
    pub node_counts: Vec<usize>,
    /// BLAS kernel ids to force onto the HPL / BLIS-ablation workloads
    /// (canonicalized registry ids; any registered kernel works).
    pub libs: Vec<String>,
    /// Interconnect fabrics (registry ids or aliases) to run on.
    pub fabrics: Vec<String>,
    /// Per-node power caps in watts: each scenario clamps every job's
    /// active cores to what fits under the cap on its platform — the
    /// operating-point axis behind `cimone sweep --matrix power-cap`.
    pub power_caps: Vec<f64>,
    /// Degraded-fleet ablation: take the last N nodes out of service
    /// from t = 0 (0 = the healthy baseline row).
    pub nodes_down: Vec<usize>,
    /// Workload subset filters (kind, exact job name, or `prefix*`).
    pub workloads: Vec<String>,
}

impl MatrixAxes {
    pub fn is_empty(&self) -> bool {
        self.platforms.is_empty()
            && self.fleet_sizes.is_empty()
            && self.node_counts.is_empty()
            && self.libs.is_empty()
            && self.fabrics.is_empty()
            && self.power_caps.is_empty()
            && self.nodes_down.is_empty()
            && self.workloads.is_empty()
    }
}

/// One named scenario as overrides on the base campaign — either parsed
/// from a `[[scenario]]` section or generated by the matrix expansion.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ScenarioSpec {
    pub name: String,
    /// Retarget every workload (and the fleet) onto this platform.
    pub platform: Option<String>,
    /// Fleet size: nodes of the target platform (or every fleet entry).
    pub count: Option<usize>,
    /// Widen every HPL job to this many nodes (`nodes` + `cluster_nodes`).
    pub nodes: Option<usize>,
    /// Force this BLAS kernel (registry id or alias) on HPL /
    /// BLIS-ablation workloads.
    pub lib: Option<String>,
    /// Run the machine on this interconnect (fabric id or alias).
    pub fabric: Option<String>,
    /// Per-node power cap in watts: clamp every job's active cores to
    /// what its platform's affine power model fits under the cap.
    pub power_cap_w: Option<f64>,
    /// Take the last N fleet nodes out of service from t = 0.
    pub nodes_down: Option<usize>,
    /// Keep only workloads matching at least one filter.
    pub workloads: Option<Vec<String>>,
}

/// A scenario resolved against its base: a name plus a runnable spec.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub spec: CampaignSpec,
}

/// A base campaign expanded across explicit scenarios and matrix axes.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioMatrix {
    pub base: CampaignSpec,
    pub scenarios: Vec<ScenarioSpec>,
    pub axes: MatrixAxes,
}

fn workload_matches(w: &WorkloadSpec, filter: &str) -> bool {
    if filter == w.kind() {
        return true;
    }
    if let Some(prefix) = filter.strip_suffix('*') {
        return w.name().starts_with(prefix);
    }
    w.name() == filter
}

impl ScenarioSpec {
    /// Parse one `[[scenario]]` section.
    pub fn from_section(sec: &Section) -> Result<ScenarioSpec, CimoneError> {
        const KNOWN_KEYS: &[&str] = &[
            "name",
            "platform",
            "count",
            "nodes",
            "lib",
            "fabric",
            "power_cap_w",
            "nodes_down",
            "workloads",
        ];
        let name = sec
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| CimoneError::Spec("[[scenario]]: missing string key `name`".into()))?
            .to_string();
        let err = |m: String| CimoneError::Spec(format!("scenario `{name}`: {m}"));
        if let Some(unknown) = sec.keys().find(|k| !KNOWN_KEYS.contains(&k.as_str())) {
            return Err(err(format!("unknown key `{unknown}` (known: {})", KNOWN_KEYS.join(", "))));
        }
        let platform = match sec.get("platform") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| err("`platform` must be a string".into()))?
                    .to_string(),
            ),
        };
        let count = match sec.get("count") {
            None => None,
            Some(v) => Some(
                v.as_int()
                    .filter(|i| *i > 0)
                    .ok_or_else(|| err("`count` must be a positive int".into()))?
                    as usize,
            ),
        };
        let nodes = match sec.get("nodes") {
            None => None,
            Some(v) => Some(
                v.as_int()
                    .filter(|i| *i > 0)
                    .ok_or_else(|| err("`nodes` must be a positive int".into()))?
                    as usize,
            ),
        };
        let fabric = match sec.get("fabric") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| err("`fabric` must be a string".into()))?
                    .to_string(),
            ),
        };
        let lib = match sec.get("lib") {
            None => None,
            Some(v) => {
                Some(v.as_str().ok_or_else(|| err("`lib` must be a string".into()))?.to_string())
            }
        };
        let power_cap_w = match sec.get("power_cap_w") {
            None => None,
            Some(v) => Some(
                v.as_float()
                    .filter(|x| x.is_finite() && *x > 0.0)
                    .ok_or_else(|| err("`power_cap_w` must be a positive number".into()))?,
            ),
        };
        let nodes_down = match sec.get("nodes_down") {
            None => None,
            Some(v) => Some(
                v.as_int()
                    .filter(|i| *i >= 0)
                    .ok_or_else(|| err("`nodes_down` must be a non-negative int".into()))?
                    as usize,
            ),
        };
        let workloads = match sec.get("workloads") {
            None => None,
            Some(Value::Array(items)) => Some(
                items
                    .iter()
                    .map(|v| {
                        v.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| err("`workloads` entries must be strings".into()))
                    })
                    .collect::<Result<Vec<String>, CimoneError>>()?,
            ),
            Some(_) => return Err(err("`workloads` must be an array of strings".into())),
        };
        Ok(ScenarioSpec {
            name,
            platform,
            count,
            nodes,
            lib,
            fabric,
            power_cap_w,
            nodes_down,
            workloads,
        })
    }

    /// Apply the overrides to the base campaign, producing the runnable
    /// scenario. Every unknown value is a typed error here, so a bad
    /// axis fails at load time rather than mid-sweep.
    pub fn derive(&self, base: &CampaignSpec) -> Result<Scenario, CimoneError> {
        let err = |m: String| CimoneError::Spec(format!("scenario `{}`: {m}", self.name));
        let mut spec = base.clone();

        if let Some(filters) = &self.workloads {
            let mut keep = vec![false; spec.workloads.len()];
            for f in filters {
                let mut hit = false;
                for (i, w) in spec.workloads.iter().enumerate() {
                    if workload_matches(w, f) {
                        keep[i] = true;
                        hit = true;
                    }
                }
                if !hit {
                    return Err(err(format!(
                        "workload filter `{f}` matches nothing \
                         (use a kind: stream | hpl | hpl-mxp | spmv | blis-ablation, \
                         a job name, or `prefix*`)"
                    )));
                }
            }
            let mut it = keep.iter();
            spec.workloads.retain(|_| *it.next().unwrap());
        }

        if let Some(lib) = &self.lib {
            // canonicalized here so an unknown kernel on the axis fails
            // at load time, not mid-sweep ([[kernel]] defs in scope)
            let id = spec.kernel_registry()?.get(lib)?.id.clone();
            for w in &mut spec.workloads {
                match w {
                    WorkloadSpec::Hpl { lib: l, .. } | WorkloadSpec::HplMxp { lib: l, .. } => {
                        *l = Some(id.clone())
                    }
                    WorkloadSpec::BlisAblation { lib: l, .. } => *l = id.clone(),
                    WorkloadSpec::Stream { .. } | WorkloadSpec::Spmv { .. } => {}
                }
            }
        }

        // the machine interconnect — canonicalized here so an unknown
        // fabric on the axis fails at load time, not mid-sweep
        if let Some(fname) = &self.fabric {
            let freg = spec.fabric_registry()?;
            spec.fabric = Some(freg.get(fname)?.id.clone());
        }

        // widen every HPL job (the node-count-scaling axis); the fleet
        // follows via the platform/count logic below, or is fit-checked
        if let Some(n) = self.nodes {
            for w in &mut spec.workloads {
                match w {
                    WorkloadSpec::Hpl { nodes, cluster_nodes, .. }
                    | WorkloadSpec::HplMxp { nodes, cluster_nodes, .. } => {
                        *nodes = n;
                        *cluster_nodes = n;
                    }
                    _ => {}
                }
            }
        }

        if let Some(pname) = &self.platform {
            let reg = spec.registry()?;
            let p = reg.get(pname)?; // unknown platform: typed error
            let cores = p.desc.total_cores();
            for w in &mut spec.workloads {
                match w {
                    WorkloadSpec::Stream { platform, partition, threads, .. } => {
                        *platform = p.id.clone();
                        *partition = p.partition.clone();
                        *threads = (*threads).min(cores).max(1);
                    }
                    WorkloadSpec::Hpl { platform, partition, cores_per_node, .. }
                    | WorkloadSpec::HplMxp { platform, partition, cores_per_node, .. } => {
                        *platform = p.id.clone();
                        *partition = p.partition.clone();
                        *cores_per_node = (*cores_per_node).min(cores).max(1);
                    }
                    WorkloadSpec::Spmv { platform, partition, threads, .. } => {
                        *platform = p.id.clone();
                        *partition = p.partition.clone();
                        *threads = (*threads).min(cores).max(1);
                    }
                    WorkloadSpec::BlisAblation { platform, partition, cores: c, .. } => {
                        *platform = p.id.clone();
                        *partition = p.partition.clone();
                        *c = (*c).min(cores).max(1);
                    }
                }
            }
            let widest = spec.workloads.iter().map(WorkloadSpec::nodes).max().unwrap_or(1);
            // default fleet size: keep the base machine's node count (so
            // retargeting doesn't silently shrink an 8-node rack), or the
            // widest job when the base runs on the default paper fleet
            let base_nodes: usize = spec.fleet.iter().map(|(_, c)| *c).sum();
            let count = self.count.unwrap_or_else(|| widest.max(base_nodes));
            if count < widest {
                return Err(err(format!(
                    "count {count} is smaller than the widest job ({widest} nodes)"
                )));
            }
            spec.fleet = vec![(p.id.clone(), count)];
        } else if let Some(count) = self.count {
            if spec.fleet.is_empty() {
                return Err(err(
                    "count needs a platform (axis or key) or a [[fleet]] in the base spec".into(),
                ));
            }
            for (_, c) in &mut spec.fleet {
                *c = count;
            }
            // the resized fleet must still fit every job, so an
            // undersized count fails here (load time), not mid-sweep
            let sched = spec.build_inventory()?.scheduler();
            for w in &spec.workloads {
                let have = sched.partitions.get(w.partition()).map(|p| p.size()).unwrap_or(0);
                if w.nodes() > have {
                    return Err(err(format!(
                        "count {count}: job `{}` wants {} nodes but partition `{}` has {have}",
                        w.name(),
                        w.nodes(),
                        w.partition()
                    )));
                }
            }
        } else if let Some(n) = self.nodes {
            // jobs widened without a platform or count must still fit
            // the base fleet (the paper machine when none is given)
            let sched = spec.build_inventory()?.scheduler();
            for w in &spec.workloads {
                let have = sched.partitions.get(w.partition()).map(|p| p.size()).unwrap_or(0);
                if w.nodes() > have {
                    return Err(err(format!(
                        "nodes {n}: job `{}` wants {} nodes but partition `{}` has {have}",
                        w.name(),
                        w.nodes(),
                        w.partition()
                    )));
                }
            }
        }

        // the power-cap operating point: clamp every job's active cores
        // to what its platform's affine model fits under the per-node
        // cap (the inverse of `PowerModel::node_power`); an infeasible
        // cap — below one active core — is a load-time error
        if let Some(cap) = self.power_cap_w {
            let reg = spec.registry()?;
            for w in &mut spec.workloads {
                let p = reg.get(w.platform())?;
                let fit = crate::cluster::power::max_cores_under_cap(
                    &p.power,
                    cap,
                    p.desc.total_cores(),
                )
                .ok_or_else(|| {
                    err(format!(
                        "power_cap_w {cap} W is below one active core on `{}` ({:.1} W)",
                        p.id,
                        p.power.node_power(1)
                    ))
                })?;
                match w {
                    WorkloadSpec::Stream { threads, .. }
                    | WorkloadSpec::Spmv { threads, .. } => *threads = (*threads).min(fit),
                    WorkloadSpec::Hpl { cores_per_node, .. }
                    | WorkloadSpec::HplMxp { cores_per_node, .. } => {
                        *cores_per_node = (*cores_per_node).min(fit)
                    }
                    WorkloadSpec::BlisAblation { cores, .. } => *cores = (*cores).min(fit),
                }
            }
        }

        // degraded-fleet ablation: mark the last N fleet nodes down from
        // t = 0 (permanent outages the scheduler routes jobs around)
        if let Some(down) = self.nodes_down {
            let total: usize = if spec.fleet.is_empty() {
                // empty fleet = the default 12-node paper machine
                crate::cluster::inventory::PAPER_FLEET.iter().map(|(_, c)| *c).sum()
            } else {
                spec.fleet.iter().map(|(_, c)| *c).sum()
            };
            if down >= total {
                return Err(err(format!(
                    "nodes_down {down} would empty the {total}-node fleet"
                )));
            }
            for k in 0..down {
                spec.outages.push(OutageSpec {
                    node: total - 1 - k,
                    down_s: 0.0,
                    up_s: None,
                    repeat: 1,
                    every: 0.0,
                });
            }
        }

        spec.validate()?;
        Ok(Scenario { name: self.name.clone(), spec })
    }
}

fn str_list(sec: &Section, key: &str) -> Result<Vec<String>, CimoneError> {
    match sec.get(key) {
        None => Ok(Vec::new()),
        Some(Value::Array(items)) => items
            .iter()
            .map(|v| {
                v.as_str().map(str::to_string).ok_or_else(|| {
                    CimoneError::Spec(format!("[matrix].{key}: entries must be strings"))
                })
            })
            .collect(),
        Some(_) => Err(CimoneError::Spec(format!("[matrix].{key}: must be an array"))),
    }
}

/// Reject empty and duplicate scenario names while streaming specs.
fn check_name(seen: &mut BTreeSet<String>, s: &ScenarioSpec) -> Result<(), CimoneError> {
    if s.name.is_empty() {
        return Err(CimoneError::Spec("scenario with an empty name".into()));
    }
    if !seen.insert(s.name.clone()) {
        return Err(CimoneError::Spec(format!("duplicate scenario name `{}`", s.name)));
    }
    Ok(())
}

fn usize_list(sec: &Section, key: &str) -> Result<Vec<usize>, CimoneError> {
    match sec.get(key) {
        None => Ok(Vec::new()),
        Some(Value::Array(items)) => items
            .iter()
            .map(|v| {
                v.as_int().filter(|i| *i > 0).map(|i| i as usize).ok_or_else(|| {
                    CimoneError::Spec(format!("[matrix].{key}: entries must be positive ints"))
                })
            })
            .collect(),
        Some(_) => Err(CimoneError::Spec(format!("[matrix].{key}: must be an array"))),
    }
}

/// Like [`usize_list`] but 0 is allowed (the healthy `nodes_down` row).
fn down_list(sec: &Section, key: &str) -> Result<Vec<usize>, CimoneError> {
    match sec.get(key) {
        None => Ok(Vec::new()),
        Some(Value::Array(items)) => items
            .iter()
            .map(|v| {
                v.as_int().filter(|i| *i >= 0).map(|i| i as usize).ok_or_else(|| {
                    CimoneError::Spec(format!("[matrix].{key}: entries must be non-negative ints"))
                })
            })
            .collect(),
        Some(_) => Err(CimoneError::Spec(format!("[matrix].{key}: must be an array"))),
    }
}

fn f64_list(sec: &Section, key: &str) -> Result<Vec<f64>, CimoneError> {
    match sec.get(key) {
        None => Ok(Vec::new()),
        Some(Value::Array(items)) => items
            .iter()
            .map(|v| {
                v.as_float().filter(|x| x.is_finite() && *x > 0.0).ok_or_else(|| {
                    CimoneError::Spec(format!("[matrix].{key}: entries must be positive numbers"))
                })
            })
            .collect(),
        Some(_) => Err(CimoneError::Spec(format!("[matrix].{key}: must be an array"))),
    }
}

impl ScenarioMatrix {
    /// The built-in generation-vs-generation matrix behind the paper's
    /// headline table: one STREAM + one single-node HPL job, retargeted
    /// across every built-in platform in chronological order. Baseline =
    /// MCv1, so the MCv2 dual-socket row reproduces the abstract's ~127x
    /// HPL and ~69x STREAM per-node uplifts, and the SG2044/MCv3 rows
    /// extend the table down the road.
    pub fn generations() -> ScenarioMatrix {
        let mut base = CampaignSpec::new();
        base.validate_n = 48;
        base.push(WorkloadSpec::Stream {
            name: "stream".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-dual".into(),
            // 64 threads is the paper's symmetric dual-socket STREAM
            // configuration (82.9 GB/s); narrower platforms clamp down
            threads: 64,
        });
        base.push(WorkloadSpec::Hpl {
            name: "hpl".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-dual".into(),
            cluster_nodes: 1,
            cores_per_node: 128,
            lib: None, // each platform's own default library
            fabric: None,
        });
        ScenarioMatrix {
            base,
            scenarios: Vec::new(),
            axes: MatrixAxes {
                platforms: ["mcv1-u740", "mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect(),
                ..MatrixAxes::default()
            },
        }
    }

    /// The built-in interconnect-aware scaling matrix behind the paper's
    /// Fig 5 punchline: one HPL job, widened across node counts
    /// (1/2/4/8) on both generations (MCv1, MCv2 Pioneer) and both
    /// fabrics (the paper's 1 GbE, the MCv3 10 GbE). Dry-run it and the
    /// table shows the whole story — MCv1 scales almost linearly on
    /// 1 GbE, MCv2's ~127x-faster nodes collapse on the same wire, and
    /// 10 GbE restores the scaling ("the 1 Gb/s network ... is no longer
    /// sufficient").
    pub fn fabric_scaling() -> ScenarioMatrix {
        let mut base = CampaignSpec::new();
        base.validate_n = 48;
        base.push(WorkloadSpec::Hpl {
            name: "hpl".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-pioneer".into(),
            cluster_nodes: 1,
            cores_per_node: 64, // clamped to 4 on the U740
            lib: None,
            fabric: None,
        });
        ScenarioMatrix {
            base,
            scenarios: Vec::new(),
            axes: MatrixAxes {
                platforms: vec!["mcv1-u740".into(), "mcv2-pioneer".into()],
                node_counts: vec![1, 2, 4, 8],
                fabrics: vec!["gbe-flat".into(), "ten-gbe-flat".into()],
                ..MatrixAxes::default()
            },
        }
    }

    /// The built-in kernel-tuning matrix behind the paper's Fig 2 BLAS
    /// exploration, extended down the road: one 64-core DGEMM ablation
    /// job crossed over platforms (SG2042, SG2044) x registered BLIS
    /// kernels (the shipped LMUL=1, the paper's LMUL=4 rewrite, and the
    /// two native RVV 1.0 tuning points). Dry-run it and the table
    /// shows both punchlines at once: on the SG2042 the LMUL=4 rewrite
    /// delivers Fig 2's uplift over LMUL=1, while on the SG2044 the
    /// native-RVV 1.0 kernel overtakes every 0.7.1-era kernel
    /// (arXiv 2508.13840) — `cimone sweep --matrix blas-tuning`.
    pub fn blas_tuning() -> ScenarioMatrix {
        let mut base = CampaignSpec::new();
        base.validate_n = 48;
        base.push(WorkloadSpec::BlisAblation {
            name: "dgemm".into(),
            partition: "mcv2".into(),
            platform: "mcv2-pioneer".into(),
            lib: "blis-lmul1".into(),
            cores: 64,
            runtime_s: 3600.0,
        });
        ScenarioMatrix {
            base,
            scenarios: Vec::new(),
            axes: MatrixAxes {
                platforms: vec!["mcv2-pioneer".into(), "sg2044".into()],
                libs: ["blis-lmul1", "blis-lmul4", "blis-rvv1-lmul2", "blis-rvv1-lmul4"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect(),
                ..MatrixAxes::default()
            },
        }
    }

    /// The built-in power-cap operating-point matrix: one HPL job
    /// crossed over every generation x node count (1, 2) x per-node
    /// power cap (120 / 180 / 250 W, all above the dual-socket MCv2's
    /// 111.4 W single-core floor). Each scenario clamps the job's
    /// active cores to what the platform's affine power model fits
    /// under the cap, so the Green500-style table directly shows each
    /// generation's best GF/s-per-W operating point under power
    /// capping — `cimone sweep --matrix power-cap`.
    pub fn power_cap() -> ScenarioMatrix {
        let mut base = CampaignSpec::new();
        base.validate_n = 48;
        base.push(WorkloadSpec::Hpl {
            name: "hpl".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-dual".into(),
            cluster_nodes: 1,
            cores_per_node: 128, // clamped per platform, then per cap
            lib: None,
            fabric: None,
        });
        ScenarioMatrix {
            base,
            scenarios: Vec::new(),
            axes: MatrixAxes {
                platforms: ["mcv1-u740", "mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect(),
                node_counts: vec![1, 2],
                power_caps: vec![120.0, 180.0, 250.0],
                ..MatrixAxes::default()
            },
        }
    }

    /// The built-in mixed-precision matrix: FP64 HPL next to HPL-MxP
    /// (the same job with its kernel rebuilt at SEW=32, which packs two
    /// elements per 64-bit lane) on every *vector* platform — the
    /// HPL-MxP benchmark's question, "what does dropping to 32-bit
    /// precision buy this machine?", answered per generation. The MCv1
    /// U740 is deliberately absent: its scalar FP64 pipeline has no
    /// element width to narrow, and an MxP job on it is a typed
    /// `InvalidKernel` error — `cimone sweep --matrix precision`.
    pub fn precision() -> ScenarioMatrix {
        let mut base = CampaignSpec::new();
        base.validate_n = 48;
        base.push(WorkloadSpec::Hpl {
            name: "hpl".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-dual".into(),
            cluster_nodes: 1,
            cores_per_node: 128, // clamped per platform
            lib: None,           // each platform's own default library
            fabric: None,
        });
        base.push(WorkloadSpec::HplMxp {
            name: "hpl-mxp".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-dual".into(),
            cluster_nodes: 1,
            cores_per_node: 128,
            lib: None,
            fabric: None,
        });
        ScenarioMatrix {
            base,
            scenarios: Vec::new(),
            axes: MatrixAxes {
                platforms: ["mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect(),
                ..MatrixAxes::default()
            },
        }
    }

    /// The built-in sparse matrix: STREAM triad next to an HPCG-shaped
    /// SpMV (2^20 rows of a 27-point stencil in int32 CSR) on every
    /// generation. Both jobs ride the same DDR stream model, so the
    /// table reads as one roofline story: SpMV's GFLOP/s column is the
    /// bandwidth column divided by the sparse flop:byte ratio, never
    /// above it — `cimone sweep --matrix sparse`.
    pub fn sparse() -> ScenarioMatrix {
        let mut base = CampaignSpec::new();
        base.validate_n = 48;
        base.push(WorkloadSpec::Stream {
            name: "stream".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-dual".into(),
            threads: 64, // clamped per platform
        });
        base.push(WorkloadSpec::Spmv {
            name: "spmv".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-dual".into(),
            threads: 64,
            // the HPCG reference problem
            rows: 1 << 20,
            nnz_per_row: 27,
            index_bytes: 4,
        });
        ScenarioMatrix {
            base,
            scenarios: Vec::new(),
            axes: MatrixAxes {
                platforms: ["mcv1-u740", "mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect(),
                ..MatrixAxes::default()
            },
        }
    }

    /// The built-in full co-design product — `cimone sweep --matrix
    /// full-codesign`: every vector platform (the C930-class what-if
    /// included) x fleet size x HPL width x registered kernel x fabric
    /// x power cap x degraded-fleet state x workload family, ~10^5
    /// scenarios. This is the matrix the streaming sweep machinery
    /// exists for: nothing materializes the product — specs decode on
    /// demand ([`spec_at`](Self::spec_at)), name uniqueness is checked
    /// per axis, and `--top-k` keeps the report bounded. MCv1 and the
    /// scalar kernel sit out: the scalar U740 pipeline has no vector
    /// datapath to co-design, and the scalar kernel has no SEW=32 twin
    /// for the HPL-MxP rows.
    pub fn full_codesign() -> ScenarioMatrix {
        let mut base = CampaignSpec::new();
        base.validate_n = 48;
        base.push(WorkloadSpec::Hpl {
            name: "hpl".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-dual".into(),
            cluster_nodes: 1,
            cores_per_node: 128, // clamped per platform, then per cap
            lib: None,
            fabric: None,
        });
        base.push(WorkloadSpec::HplMxp {
            name: "hpl-mxp".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-dual".into(),
            cluster_nodes: 1,
            cores_per_node: 128,
            lib: None,
            fabric: None,
        });
        base.push(WorkloadSpec::Stream {
            name: "stream".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-dual".into(),
            threads: 64,
        });
        base.push(WorkloadSpec::Spmv {
            name: "spmv".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-dual".into(),
            threads: 64,
            rows: 1 << 20,
            nnz_per_row: 27,
            index_bytes: 4,
        });
        base.push(WorkloadSpec::BlisAblation {
            name: "dgemm".into(),
            partition: "mcv2".into(),
            platform: "mcv2-dual".into(),
            lib: "blis-lmul1".into(),
            cores: 64,
            runtime_s: 3600.0,
        });
        ScenarioMatrix {
            base,
            scenarios: Vec::new(),
            axes: MatrixAxes {
                platforms: ["mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3", "c930-eval"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect(),
                fleet_sizes: vec![4, 8, 16, 32],
                node_counts: vec![1, 2, 4],
                libs: [
                    "openblas-c920",
                    "blis-lmul1",
                    "blis-lmul4",
                    "blis-rvv1-lmul2",
                    "blis-rvv1-lmul4",
                    "blis-rvv1-vl256",
                ]
                .iter()
                .map(|s| s.to_string())
                .collect(),
                fabrics: vec!["gbe-flat".into(), "ten-gbe-flat".into()],
                // all above every platform's single-active-core floor
                // (the dual-socket MCv2's 111.4 W is the tallest)
                power_caps: vec![120.0, 140.0, 160.0, 180.0, 200.0, 220.0, 250.0],
                nodes_down: vec![0, 1, 2, 3],
                workloads: ["hpl", "hpl-mxp", "stream", "spmv", "dgemm"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect(),
            },
        }
    }

    /// How many scenario specs this matrix denotes — the explicit
    /// `[[scenario]]`s plus the full axis product (or the single `base`
    /// fallback) — without materializing any of them.
    pub fn spec_count(&self) -> usize {
        let axis = if self.axes.is_empty() {
            0
        } else {
            // empty axes contribute a single `None` step, like expand()
            let dim = |n: usize| n.max(1);
            dim(self.axes.platforms.len())
                * dim(self.axes.fleet_sizes.len())
                * dim(self.axes.node_counts.len())
                * dim(self.axes.libs.len())
                * dim(self.axes.fabrics.len())
                * dim(self.axes.power_caps.len())
                * dim(self.axes.nodes_down.len())
                * dim(self.axes.workloads.len())
        };
        let n = self.scenarios.len() + axis;
        if n == 0 {
            1 // the bare base campaign runs as one scenario
        } else {
            n
        }
    }

    /// The `i`-th scenario spec, in [`expand`](Self::expand) order:
    /// explicit `[[scenario]]`s first, then a mixed-radix decode of the
    /// axis product (platforms outermost, workload subsets
    /// fastest-varying). This is how sweeps stream — no caller ever
    /// needs the whole product in memory. `i` must be `< spec_count()`.
    pub fn spec_at(&self, i: usize) -> ScenarioSpec {
        if let Some(s) = self.scenarios.get(i) {
            return s.clone();
        }
        let mut rem = i - self.scenarios.len();
        if self.axes.is_empty() {
            return ScenarioSpec { name: "base".into(), ..ScenarioSpec::default() };
        }
        fn pick<T: Clone>(v: &[T], rem: &mut usize) -> Option<T> {
            if v.is_empty() {
                return None;
            }
            let i = *rem % v.len();
            *rem /= v.len();
            Some(v[i].clone())
        }
        // decode innermost-first: the last axis varies fastest, exactly
        // like the nested loops the product used to be written as
        let ws = pick(&self.axes.workloads, &mut rem);
        let d = pick(&self.axes.nodes_down, &mut rem);
        let c = pick(&self.axes.power_caps, &mut rem);
        let f = pick(&self.axes.fabrics, &mut rem);
        let l = pick(&self.axes.libs, &mut rem);
        let n = pick(&self.axes.node_counts, &mut rem);
        let s = pick(&self.axes.fleet_sizes, &mut rem);
        let p = pick(&self.axes.platforms, &mut rem);
        let mut parts: Vec<String> = Vec::new();
        if let Some(p) = &p {
            parts.push(p.clone());
        }
        if let Some(s) = s {
            parts.push(format!("n{s}"));
        }
        if let Some(n) = n {
            parts.push(format!("{n}n"));
        }
        if let Some(l) = &l {
            parts.push(l.clone());
        }
        if let Some(f) = &f {
            parts.push(f.clone());
        }
        if let Some(c) = c {
            parts.push(format!("cap{c}W"));
        }
        if let Some(d) = d {
            parts.push(format!("down{d}"));
        }
        if let Some(ws) = &ws {
            parts.push(ws.clone());
        }
        ScenarioSpec {
            name: parts.join("/"),
            platform: p,
            count: s,
            nodes: n,
            lib: l,
            fabric: f,
            power_cap_w: c,
            nodes_down: d,
            workloads: ws.map(|x| vec![x]),
        }
    }

    /// Reject empty and duplicate scenario names. For a pure axis
    /// product (no explicit `[[scenario]]`s) every name is the
    /// positional `/`-join of one rendered part per non-empty axis, so
    /// two specs collide iff some single axis repeats a rendered value —
    /// checked per axis in O(sum of axis lengths) memory, never
    /// O(product). Matrices with explicit scenarios (or axis values
    /// that degenerate to empty parts) fall back to streaming every
    /// name through one set.
    pub(crate) fn check_names(&self) -> Result<(), CimoneError> {
        let a = &self.axes;
        let str_axes_sane = a
            .platforms
            .iter()
            .chain(&a.libs)
            .chain(&a.fabrics)
            .chain(&a.workloads)
            .all(|s| !s.is_empty());
        if self.scenarios.is_empty() && !a.is_empty() && str_axes_sane {
            fn distinct(
                axis: &str,
                parts: impl Iterator<Item = String>,
            ) -> Result<(), CimoneError> {
                let mut seen = BTreeSet::new();
                for p in parts {
                    if !seen.insert(p.clone()) {
                        return Err(CimoneError::Spec(format!(
                            "duplicate scenario name: [matrix].{axis} repeats `{p}`"
                        )));
                    }
                }
                Ok(())
            }
            distinct("platforms", a.platforms.iter().cloned())?;
            distinct("fleet_sizes", a.fleet_sizes.iter().map(|s| format!("n{s}")))?;
            distinct("node_counts", a.node_counts.iter().map(|n| format!("{n}n")))?;
            distinct("libs", a.libs.iter().cloned())?;
            distinct("fabrics", a.fabrics.iter().cloned())?;
            distinct("power_caps", a.power_caps.iter().map(|c| format!("cap{c}W")))?;
            distinct("nodes_down", a.nodes_down.iter().map(|d| format!("down{d}")))?;
            distinct("workloads", a.workloads.iter().cloned())?;
            return Ok(());
        }
        let mut seen = BTreeSet::new();
        for i in 0..self.spec_count() {
            check_name(&mut seen, &self.spec_at(i))?;
        }
        Ok(())
    }

    /// Derive every scenario once — names checked, overrides resolved —
    /// without keeping any of them, so load-time validation of an
    /// arbitrarily large axis product stays O(shard) in memory.
    pub fn validate(&self) -> Result<(), CimoneError> {
        self.check_names()?;
        for i in 0..self.spec_count() {
            self.spec_at(i).derive(&self.base)?;
        }
        Ok(())
    }

    /// Expand into runnable scenarios: the explicit `[[scenario]]`s
    /// first, then the cartesian product of the non-empty axes. With
    /// neither, the base campaign runs as one scenario named `base`.
    /// Duplicate scenario names are typed errors. This materializes the
    /// whole product — sweeps should prefer [`run_matrix_with`] /
    /// [`dry_run_matrix_with`], which stream over [`spec_at`](Self::spec_at).
    pub fn expand(&self) -> Result<Vec<Scenario>, CimoneError> {
        self.check_names()?;
        let mut out = Vec::with_capacity(self.spec_count());
        for i in 0..self.spec_count() {
            out.push(self.spec_at(i).derive(&self.base)?);
        }
        Ok(out)
    }

    /// Build from a parsed config: the base campaign sections plus
    /// `[matrix]` and `[[scenario]]`. The matrix is expanded once here so
    /// unknown platforms/libraries/filters fail at load time.
    pub fn from_config(cfg: &Config) -> Result<ScenarioMatrix, CimoneError> {
        // a misspelled section header must not silently vanish (the key
        // checks inside each section already guarantee this at key level)
        for name in cfg.sections.keys() {
            if !name.is_empty() && name != "campaign" && name != "matrix" {
                return Err(CimoneError::Spec(format!(
                    "unknown section `[{name}]` (known: campaign, matrix)"
                )));
            }
        }
        for name in cfg.table_arrays.keys() {
            if !["platform", "fabric", "kernel", "fleet", "workload", "queue", "outage", "scenario"]
                .contains(&name.as_str())
            {
                return Err(CimoneError::Spec(format!(
                    "unknown section `[[{name}]]` \
                     (known: platform, fabric, kernel, fleet, workload, queue, outage, scenario)"
                )));
            }
        }
        let base = CampaignSpec::from_config(cfg)?;
        let mut axes = MatrixAxes::default();
        if let Some(sec) = cfg.section("matrix") {
            const KNOWN_KEYS: &[&str] = &[
                "platforms",
                "fleet_sizes",
                "node_counts",
                "libs",
                "fabrics",
                "power_caps",
                "nodes_down",
                "workloads",
            ];
            if let Some(unknown) = sec.keys().find(|k| !KNOWN_KEYS.contains(&k.as_str())) {
                return Err(CimoneError::Spec(format!(
                    "[matrix]: unknown key `{unknown}` (known: {})",
                    KNOWN_KEYS.join(", ")
                )));
            }
            axes.platforms = str_list(sec, "platforms")?;
            axes.fleet_sizes = usize_list(sec, "fleet_sizes")?;
            axes.node_counts = usize_list(sec, "node_counts")?;
            axes.fabrics = str_list(sec, "fabrics")?;
            axes.power_caps = f64_list(sec, "power_caps")?;
            axes.nodes_down = down_list(sec, "nodes_down")?;
            axes.workloads = str_list(sec, "workloads")?;
            // canonicalize against the base spec's kernel registry so a
            // bad axis value (or alias) resolves at load time, wrapped
            // as a spec error naming the key it sits under
            let kreg = base.kernel_registry()?;
            for s in str_list(sec, "libs")? {
                match kreg.get(&s) {
                    Ok(k) => axes.libs.push(k.id.clone()),
                    Err(CimoneError::UnknownKernel { name, known }) => {
                        return Err(CimoneError::Spec(format!(
                            "[matrix].libs: unknown library `{name}` (registered: {known})"
                        )))
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        let mut scenarios = Vec::new();
        for sec in cfg.table_arrays.get("scenario").map(Vec::as_slice).unwrap_or(&[]) {
            scenarios.push(ScenarioSpec::from_section(sec)?);
        }
        let m = ScenarioMatrix { base, scenarios, axes };
        m.validate()?; // streaming: derives each spec once, keeps none
        Ok(m)
    }

    /// Parse a sweep spec from config text.
    pub fn parse(text: &str) -> Result<ScenarioMatrix, CimoneError> {
        let cfg = Config::parse(text).map_err(CimoneError::Spec)?;
        ScenarioMatrix::from_config(&cfg)
    }

    /// Load a sweep spec file from disk.
    pub fn load(path: &str) -> Result<ScenarioMatrix, CimoneError> {
        let cfg = Config::load(path).map_err(CimoneError::Spec)?;
        ScenarioMatrix::from_config(&cfg)
    }

    /// Render back to spec-file text; `ScenarioMatrix::parse` on the
    /// result reconstructs an equal matrix.
    pub fn render(&self) -> String {
        let mut out = self.base.render();
        if !self.axes.is_empty() {
            out.push_str("\n[matrix]\n");
            if !self.axes.platforms.is_empty() {
                out.push_str(&format!("platforms = [{}]\n", quote_list(&self.axes.platforms)));
            }
            if !self.axes.fleet_sizes.is_empty() {
                let sizes: Vec<String> =
                    self.axes.fleet_sizes.iter().map(|n| n.to_string()).collect();
                out.push_str(&format!("fleet_sizes = [{}]\n", sizes.join(", ")));
            }
            if !self.axes.node_counts.is_empty() {
                let widths: Vec<String> =
                    self.axes.node_counts.iter().map(|n| n.to_string()).collect();
                out.push_str(&format!("node_counts = [{}]\n", widths.join(", ")));
            }
            if !self.axes.libs.is_empty() {
                out.push_str(&format!("libs = [{}]\n", quote_list(&self.axes.libs)));
            }
            if !self.axes.fabrics.is_empty() {
                out.push_str(&format!("fabrics = [{}]\n", quote_list(&self.axes.fabrics)));
            }
            if !self.axes.power_caps.is_empty() {
                let caps: Vec<String> =
                    self.axes.power_caps.iter().map(|c| c.to_string()).collect();
                out.push_str(&format!("power_caps = [{}]\n", caps.join(", ")));
            }
            if !self.axes.nodes_down.is_empty() {
                let downs: Vec<String> =
                    self.axes.nodes_down.iter().map(|d| d.to_string()).collect();
                out.push_str(&format!("nodes_down = [{}]\n", downs.join(", ")));
            }
            if !self.axes.workloads.is_empty() {
                out.push_str(&format!("workloads = [{}]\n", quote_list(&self.axes.workloads)));
            }
        }
        for sc in &self.scenarios {
            out.push_str(&format!("\n[[scenario]]\nname = \"{}\"\n", sc.name));
            if let Some(p) = &sc.platform {
                out.push_str(&format!("platform = \"{p}\"\n"));
            }
            if let Some(c) = sc.count {
                out.push_str(&format!("count = {c}\n"));
            }
            if let Some(n) = sc.nodes {
                out.push_str(&format!("nodes = {n}\n"));
            }
            if let Some(l) = &sc.lib {
                out.push_str(&format!("lib = \"{l}\"\n"));
            }
            if let Some(f) = &sc.fabric {
                out.push_str(&format!("fabric = \"{f}\"\n"));
            }
            if let Some(c) = sc.power_cap_w {
                out.push_str(&format!("power_cap_w = {c}\n"));
            }
            if let Some(d) = sc.nodes_down {
                out.push_str(&format!("nodes_down = {d}\n"));
            }
            if let Some(ws) = &sc.workloads {
                out.push_str(&format!("workloads = [{}]\n", quote_list(ws)));
            }
        }
        out
    }
}

fn quote_list(items: &[String]) -> String {
    items.iter().map(|s| format!("\"{s}\"")).collect::<Vec<_>>().join(", ")
}

/// Speedup cell shared by every comparison table (`-` = not comparable).
pub(crate) fn fmt_speedup(x: Option<f64>) -> String {
    match x {
        Some(v) => format!("{v:.1}x"),
        None => "-".to_string(),
    }
}

/// One scenario's aggregated outcome — the Green500-style row.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioOutcome {
    pub name: String,
    /// Nodes in the scenario's fleet.
    pub nodes: usize,
    /// Best HPL-class (GFLOP/s) job headline; 0 when the scenario ran none.
    pub hpl_gflops: f64,
    /// Best STREAM-class (GB/s) job headline; 0 when the scenario ran none.
    pub stream_gbs: f64,
    /// Average node power of the job the table ranks by (HPL if present).
    pub avg_node_w: f64,
    /// GFLOP/s per watt of the best HPL-class job (the Green500 column).
    pub gflops_per_w: f64,
    /// Campaign makespan; 0 for dry runs (nothing was scheduled).
    pub makespan_s: f64,
    pub jobs: Vec<JobRow>,
}

fn outcome_of(name: &str, nodes: usize, makespan_s: f64, jobs: Vec<JobRow>) -> ScenarioOutcome {
    let best = |metric: &str| -> Option<&JobRow> {
        jobs.iter()
            .filter(|j| j.metric == metric)
            .max_by(|a, b| a.headline.total_cmp(&b.headline))
    };
    let stream = best("bandwidth");
    let stream_gbs = stream.map(|j| j.headline).unwrap_or(0.0);
    let (hpl_gflops, avg_node_w, gflops_per_w) = match best("gflops") {
        Some(j) => (j.headline, j.avg_node_w, j.gflops_per_w.unwrap_or(0.0)),
        None => (0.0, stream.map(|j| j.avg_node_w).unwrap_or(0.0), 0.0),
    };
    ScenarioOutcome {
        name: name.to_string(),
        nodes,
        hpl_gflops,
        stream_gbs,
        avg_node_w,
        gflops_per_w,
        makespan_s,
        jobs,
    }
}

/// Per-scenario outcomes in scenario order; the first is the baseline
/// the speedup columns compare against.
#[derive(Debug, Clone, PartialEq)]
pub struct ComparisonReport {
    pub scenarios: Vec<ScenarioOutcome>,
    /// How many scenarios the matrix denoted (before any top-k cut).
    pub total: usize,
    /// Rows dropped by [`SweepOptions::top_k`]; 0 on a full sweep.
    pub truncated: usize,
}

impl ComparisonReport {
    /// The scenario the speedup columns are relative to.
    pub fn baseline(&self) -> Option<&ScenarioOutcome> {
        self.scenarios.first()
    }

    /// Look an outcome up by scenario name.
    pub fn outcome(&self, name: &str) -> Option<&ScenarioOutcome> {
        self.scenarios.iter().find(|o| o.name == name)
    }

    /// `(hpl, stream)` speedups of `o` vs the baseline; `None` where
    /// either side lacks the metric.
    pub fn speedup_of(&self, o: &ScenarioOutcome) -> (Option<f64>, Option<f64>) {
        let ratio = |v: f64, base: f64| {
            if base > 0.0 && v > 0.0 {
                Some(v / base)
            } else {
                None
            }
        };
        match self.baseline() {
            Some(b) => (ratio(o.hpl_gflops, b.hpl_gflops), ratio(o.stream_gbs, b.stream_gbs)),
            None => (None, None),
        }
    }

    /// The Green500-style comparison table.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "scenario",
            "nodes",
            "HPL GF/s",
            "STREAM GB/s",
            "W/node",
            "GF/s/W",
            "HPL x",
            "STREAM x",
        ]);
        for o in &self.scenarios {
            let (hx, sx) = self.speedup_of(o);
            t.row(vec![
                o.name.clone(),
                o.nodes.to_string(),
                format!("{:.1}", o.hpl_gflops),
                format!("{:.1}", o.stream_gbs),
                format!("{:.0}", o.avg_node_w),
                format!("{:.2}", o.gflops_per_w),
                fmt_speedup(hx),
                fmt_speedup(sx),
            ]);
        }
        let note = if self.truncated > 0 {
            format!(
                "\n{} of {} scenarios truncated (top-k); the table keeps the baseline + the {} best by HPL GFLOP/s",
                self.truncated,
                self.total,
                self.scenarios.len().saturating_sub(1)
            )
        } else {
            String::new()
        };
        format!(
            "Green500-style comparison, baseline = `{}` (paper MCv1 -> MCv2: 127x HPL, 69x STREAM)\n{}{}",
            self.baseline().map(|b| b.name.as_str()).unwrap_or("-"),
            t.render(),
            note
        )
    }

    /// Machine-readable export (`cimone sweep --json`).
    pub fn to_json(&self) -> Json {
        Json::obj([
            (
                "baseline",
                self.baseline().map(|b| Json::Str(b.name.clone())).unwrap_or(Json::Null),
            ),
            ("total", Json::Num(self.total as f64)),
            ("truncated", Json::Num(self.truncated as f64)),
            (
                "scenarios",
                Json::Arr(
                    self.scenarios
                        .iter()
                        .map(|o| {
                            let (hx, sx) = self.speedup_of(o);
                            Json::obj([
                                ("name", Json::Str(o.name.clone())),
                                ("nodes", Json::Num(o.nodes as f64)),
                                ("hpl_gflops", Json::Num(o.hpl_gflops)),
                                ("stream_gbs", Json::Num(o.stream_gbs)),
                                ("avg_node_w", Json::Num(o.avg_node_w)),
                                ("gflops_per_w", Json::Num(o.gflops_per_w)),
                                ("makespan_s", Json::Num(o.makespan_s)),
                                ("hpl_speedup", hx.map(Json::Num).unwrap_or(Json::Null)),
                                ("stream_speedup", sx.map(Json::Num).unwrap_or(Json::Null)),
                                (
                                    "jobs",
                                    Json::Arr(o.jobs.iter().map(JobRow::to_json).collect()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Knobs for the streaming fan-out ([`run_matrix_with`] /
/// [`dry_run_matrix_with`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepOptions {
    /// Scenarios decoded + run per rayon batch; memory stays bounded by
    /// this (plus the kept rows) however large the axis product is.
    pub shard_size: usize,
    /// Keep at most this many rows: the baseline plus the best
    /// `top_k - 1` scenarios by (HPL GFLOP/s desc, name asc). The
    /// selection is shard-order independent; dropped rows are counted
    /// in [`ComparisonReport::truncated`]. `None` keeps everything.
    pub top_k: Option<usize>,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions { shard_size: 64, top_k: None }
    }
}

/// Cut `rows` down to the baseline (row 0 of the whole sweep) plus the
/// best `k - 1` others. Total comparator — (HPL desc, name asc), names
/// are unique — so applying this per shard and re-applying after each
/// merge selects exactly the global top, whatever the shard size.
fn truncate_top_k(rows: &mut Vec<ScenarioOutcome>, k: usize) {
    let k = k.max(1); // the baseline row always survives
    if rows.len() <= k {
        return;
    }
    let baseline = rows.remove(0);
    rows.sort_by(|a, b| b.hpl_gflops.total_cmp(&a.hpl_gflops).then_with(|| a.name.cmp(&b.name)));
    rows.truncate(k - 1);
    rows.insert(0, baseline);
}

/// Shared streaming fan-out: check every scenario name up front, then
/// decode + derive + run shard by shard — rayon inside a shard,
/// in-order aggregation across shards — folding each shard into the
/// kept rows (top-k cut included) before the next one is decoded. The
/// report, and the first failure if any, are deterministic regardless
/// of finish order; nothing ever materializes the full axis product.
fn collect_matrix(
    matrix: &ScenarioMatrix,
    opts: &SweepOptions,
    run_one: impl Fn(&Scenario) -> Result<ScenarioOutcome, CimoneError> + Sync,
) -> Result<ComparisonReport, CimoneError> {
    let total = matrix.spec_count();
    // name uniqueness before any work: per-axis (O(axes) memory) for
    // pure products, streamed through one set otherwise
    matrix.check_names()?;
    let shard = opts.shard_size.max(1);
    let mut kept: Vec<ScenarioOutcome> = Vec::new();
    let mut start = 0;
    while start < total {
        let end = (start + shard).min(total);
        let results: Vec<Result<ScenarioOutcome, CimoneError>> = (start..end)
            .into_par_iter()
            .map(|i| run_one(&matrix.spec_at(i).derive(&matrix.base)?))
            .collect();
        for r in results {
            kept.push(r?); // first failure in scenario order
        }
        if let Some(k) = opts.top_k {
            truncate_top_k(&mut kept, k);
        }
        start = end;
    }
    let truncated = total - kept.len();
    Ok(ComparisonReport { scenarios: kept, total, truncated })
}

/// Run every scenario of the matrix — rayon fan-out across scenarios,
/// each a full campaign (real-numerics validation, parallel estimation,
/// scheduling, concurrent partition drain). Scenario order in the report
/// matches the matrix regardless of which finished first.
pub fn run_matrix(matrix: &ScenarioMatrix) -> Result<ComparisonReport, CimoneError> {
    run_matrix_with(matrix, &SweepOptions::default())
}

/// [`run_matrix`] with explicit streaming knobs (shard size, top-k).
pub fn run_matrix_with(
    matrix: &ScenarioMatrix,
    opts: &SweepOptions,
) -> Result<ComparisonReport, CimoneError> {
    collect_matrix(matrix, opts, |s| {
        let inv = s.spec.build_inventory()?;
        let r = run_campaign_spec(&inv, &s.spec)?;
        Ok(outcome_of(&s.name, inv.nodes.len(), r.makespan_s, r.jobs))
    })
}

/// Estimate every scenario without scheduling anything (`--dry-run`):
/// same fan-out and aggregation, but via
/// [`dry_run_spec`](super::driver::dry_run_spec), so no real-numerics
/// solve runs and every makespan is 0.
pub fn dry_run_matrix(matrix: &ScenarioMatrix) -> Result<ComparisonReport, CimoneError> {
    dry_run_matrix_with(matrix, &SweepOptions::default())
}

/// [`dry_run_matrix`] with explicit streaming knobs (shard size, top-k).
pub fn dry_run_matrix_with(
    matrix: &ScenarioMatrix,
    opts: &SweepOptions,
) -> Result<ComparisonReport, CimoneError> {
    collect_matrix(matrix, opts, |s| {
        let inv = s.spec.build_inventory()?;
        let rows = dry_run_spec(&inv, &s.spec)?;
        Ok(outcome_of(&s.name, inv.nodes.len(), 0.0, rows))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generations_matrix_expands_in_chronological_order() {
        let scenarios = ScenarioMatrix::generations().expand().unwrap();
        let names: Vec<&str> = scenarios.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["mcv1-u740", "mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3"]);
        // retargeting rewrote platform, partition, fleet and clamped widths
        let mcv1 = &scenarios[0].spec;
        assert_eq!(mcv1.fleet, vec![("mcv1-u740".to_string(), 1)]);
        match &mcv1.workloads[0] {
            WorkloadSpec::Stream { platform, partition, threads, .. } => {
                assert_eq!(platform, "mcv1-u740");
                assert_eq!(partition, "mcv1");
                assert_eq!(*threads, 4, "clamped to the U740's 4 cores");
            }
            other => panic!("expected Stream, got {other:?}"),
        }
        match &mcv1.workloads[1] {
            WorkloadSpec::Hpl { cores_per_node, .. } => assert_eq!(*cores_per_node, 4),
            other => panic!("expected Hpl, got {other:?}"),
        }
    }

    #[test]
    fn generation_table_reproduces_the_headline_uplifts() {
        let report = dry_run_matrix(&ScenarioMatrix::generations()).unwrap();
        assert_eq!(report.scenarios.len(), 5);
        assert_eq!(report.baseline().unwrap().name, "mcv1-u740");
        let dual = report.outcome("mcv2-dual").unwrap();
        let (hpl_x, stream_x) = report.speedup_of(dual);
        // the abstract's ~127x HPL / ~69x STREAM per-node deltas
        let hpl_x = hpl_x.unwrap();
        let stream_x = stream_x.unwrap();
        assert!((100.0..160.0).contains(&hpl_x), "HPL uplift {hpl_x:.0}x");
        assert!((55.0..85.0).contains(&stream_x), "STREAM uplift {stream_x:.0}x");
        // rendering carries the table + baseline note
        let s = report.render();
        assert!(s.contains("mcv1-u740") && s.contains("STREAM x"), "{s}");
    }

    #[test]
    fn degenerate_outcomes_never_render_nan_speedups() {
        // a stream-only baseline (hpl_gflops = 0) against a compute row:
        // every speedup where either side lacks the metric must be a
        // typed None — rendered `-`, JSON null — never NaN or inf
        let row = |name: &str, hpl: f64, stream: f64| ScenarioOutcome {
            name: name.into(),
            nodes: 1,
            hpl_gflops: hpl,
            stream_gbs: stream,
            avg_node_w: 30.0,
            gflops_per_w: 0.0,
            makespan_s: 0.0,
            jobs: Vec::new(),
        };
        let report = ComparisonReport {
            scenarios: vec![row("stream-only", 0.0, 12.0), row("hpl-only", 40.0, 0.0)],
            total: 2,
            truncated: 0,
        };
        let (hx, sx) = report.speedup_of(&report.scenarios[1]);
        assert_eq!(hx, None, "0-baseline HPL speedup must be None, not inf");
        assert_eq!(sx, None, "0-valued STREAM speedup must be None, not 0/NaN");
        let s = report.render();
        assert!(!s.contains("NaN") && !s.contains("inf"), "{s}");
        assert_eq!(fmt_speedup(None), "-");
        let j = report.to_json().render();
        assert!(j.contains("\"hpl_speedup\":null"), "{j}");
        assert!(!j.contains("NaN"), "{j}");
    }

    #[test]
    fn matrix_product_covers_all_axis_combinations() {
        let mut base = CampaignSpec::new();
        base.push(WorkloadSpec::Stream {
            name: "s".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-pioneer".into(),
            threads: 64,
        });
        base.push(WorkloadSpec::Hpl {
            name: "h".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-pioneer".into(),
            cluster_nodes: 1,
            cores_per_node: 64,
            lib: None,
            fabric: None,
        });
        let m = ScenarioMatrix {
            base,
            scenarios: Vec::new(),
            axes: MatrixAxes {
                platforms: vec!["mcv2-pioneer".into(), "sg2044".into()],
                fleet_sizes: vec![1, 2],
                libs: vec!["openblas-c920".into()],
                workloads: vec!["hpl".into()],
                ..MatrixAxes::default()
            },
        };
        let scenarios = m.expand().unwrap();
        assert_eq!(scenarios.len(), 4); // 2 platforms x 2 sizes x 1 lib x 1 subset
        assert_eq!(scenarios[0].name, "mcv2-pioneer/n1/openblas-c920/hpl");
        // the subset filter dropped the stream job everywhere
        for s in &scenarios {
            assert_eq!(s.spec.workloads.len(), 1);
            assert_eq!(s.spec.workloads[0].kind(), "hpl");
        }
        assert_eq!(scenarios[3].spec.fleet, vec![("sg2044".to_string(), 2)]);
    }

    #[test]
    fn unknown_axis_values_are_typed_errors() {
        let gens = ScenarioMatrix::generations();
        // unknown platform
        let mut m = gens.clone();
        m.axes.platforms.push("epyc".into());
        assert!(matches!(
            m.expand(),
            Err(CimoneError::UnknownPlatform { ref id, .. }) if id == "epyc"
        ));
        // filter that selects nothing
        let mut m = gens.clone();
        m.axes.workloads = vec!["dgemm-*".into()];
        assert!(matches!(
            m.expand(),
            Err(CimoneError::Spec(ref msg)) if msg.contains("matches nothing")
        ));
        // duplicate scenario names
        let mut m = gens.clone();
        m.scenarios.push(ScenarioSpec { name: "sg2044".into(), ..ScenarioSpec::default() });
        assert!(matches!(
            m.expand(),
            Err(CimoneError::Spec(ref msg)) if msg.contains("duplicate scenario name")
        ));
    }

    #[test]
    fn sweep_spec_text_parses_and_validates_at_load_time() {
        let text = "\
[[workload]]
kind = \"hpl\"
name = \"hpl\"
platform = \"mcv2-dual\"
partition = \"mcv2\"
cores_per_node = 128

[matrix]
platforms = [\"mcv1-u740\", \"mcv2-dual\"]
";
        let m = ScenarioMatrix::parse(text).unwrap();
        assert_eq!(m.axes.platforms.len(), 2);
        assert_eq!(m.expand().unwrap().len(), 2);
        // a bad library name in the matrix is rejected while loading,
        // as a spec error naming the `[matrix].libs` key it sits under
        let bad = text.replace(
            "platforms = [\"mcv1-u740\", \"mcv2-dual\"]",
            "libs = [\"mkl\"]",
        );
        assert!(matches!(
            ScenarioMatrix::parse(&bad),
            Err(CimoneError::Spec(ref msg))
                if msg.contains("[matrix].libs: unknown library `mkl`")
        ));
        // unknown [matrix] keys are rejected too
        let bad = text.replace("platforms =", "platfroms =");
        assert!(matches!(
            ScenarioMatrix::parse(&bad),
            Err(CimoneError::Spec(ref msg)) if msg.contains("unknown key `platfroms`")
        ));
        // ...and so are misspelled section headers, which would otherwise
        // make a whole scenario (or the matrix) silently vanish
        let bad = text.replace("[matrix]", "[matrics]");
        assert!(matches!(
            ScenarioMatrix::parse(&bad),
            Err(CimoneError::Spec(ref msg)) if msg.contains("unknown section `[matrics]`")
        ));
        let bad = format!("{text}\n[[scenaro]]\nname = \"x\"\n");
        assert!(matches!(
            ScenarioMatrix::parse(&bad),
            Err(CimoneError::Spec(ref msg)) if msg.contains("unknown section `[[scenaro]]`")
        ));
    }

    #[test]
    fn matrix_renders_and_reparses_to_an_equal_value() {
        let mut m = ScenarioMatrix::generations();
        m.axes.libs = vec!["blis-lmul4".into()];
        m.scenarios.push(ScenarioSpec {
            name: "rack".into(),
            platform: Some("mcv1-u740".into()),
            count: Some(8),
            nodes: None,
            lib: None,
            fabric: Some("ten-gbe-flat".into()),
            power_cap_w: Some(120.0),
            nodes_down: Some(2),
            workloads: Some(vec!["hpl".into()]),
        });
        let back = ScenarioMatrix::parse(&m.render()).unwrap();
        assert_eq!(back, m);
        // the fabric-scaling built-in (fabrics + node_counts axes) too
        let fs = ScenarioMatrix::fabric_scaling();
        assert_eq!(ScenarioMatrix::parse(&fs.render()).unwrap(), fs);
        // ...and power-cap (power_caps + node_counts axes)
        let pc = ScenarioMatrix::power_cap();
        assert_eq!(ScenarioMatrix::parse(&pc.render()).unwrap(), pc);
        // ...and the mixed-precision / sparse built-ins (new kinds)
        let pr = ScenarioMatrix::precision();
        assert_eq!(ScenarioMatrix::parse(&pr.render()).unwrap(), pr);
        let sp = ScenarioMatrix::sparse();
        assert_eq!(ScenarioMatrix::parse(&sp.render()).unwrap(), sp);
    }

    #[test]
    fn fabric_scaling_matrix_expands_the_full_grid() {
        let scenarios = ScenarioMatrix::fabric_scaling().expand().unwrap();
        // 2 platforms x 4 widths x 2 fabrics
        assert_eq!(scenarios.len(), 16);
        assert_eq!(scenarios[0].name, "mcv1-u740/1n/gbe-flat");
        assert_eq!(scenarios[15].name, "mcv2-pioneer/8n/ten-gbe-flat");
        // the width axis rewrote the HPL job and the fleet followed
        let wide = &scenarios[15].spec;
        assert_eq!(wide.fleet, vec![("mcv2-pioneer".to_string(), 8)]);
        assert_eq!(wide.fabric.as_deref(), Some("ten-gbe-flat"));
        match &wide.workloads[0] {
            WorkloadSpec::Hpl { nodes, cluster_nodes, .. } => {
                assert_eq!((*nodes, *cluster_nodes), (8, 8));
            }
            other => panic!("expected Hpl, got {other:?}"),
        }
    }

    #[test]
    fn blas_tuning_matrix_reports_both_tuning_punchlines() {
        let m = ScenarioMatrix::blas_tuning();
        let scenarios = m.expand().unwrap();
        assert_eq!(scenarios.len(), 8, "2 platforms x 4 kernels");
        assert_eq!(scenarios[0].name, "mcv2-pioneer/blis-lmul1");
        let report = dry_run_matrix(&m).unwrap();
        let gf = |name: &str| report.outcome(name).unwrap().hpl_gflops;
        // Fig 2's LMUL=1 -> LMUL=4 uplift on the SG2042
        let (v1, v4) = (gf("mcv2-pioneer/blis-lmul1"), gf("mcv2-pioneer/blis-lmul4"));
        assert!(v4 > 1.3 * v1, "SG2042 LMUL uplift: {v4:.1} vs {v1:.1}");
        // ...and the native RVV 1.0 kernel wins the SG2044 column
        let sg2044_best = report
            .scenarios
            .iter()
            .filter(|o| o.name.starts_with("sg2044/"))
            .max_by(|a, b| a.hpl_gflops.total_cmp(&b.hpl_gflops))
            .unwrap();
        assert_eq!(sg2044_best.name, "sg2044/blis-rvv1-lmul2", "{:.1}", sg2044_best.hpl_gflops);
        // lib axis values canonicalize through aliases too
        let mut m = ScenarioMatrix::blas_tuning();
        m.axes.libs = vec!["blis-opt".into()];
        let m = ScenarioMatrix::parse(&m.render()).unwrap();
        assert_eq!(m.axes.libs, vec!["blis-lmul4".to_string()]);
        // unknown kernels on the axis are typed errors at load time
        let mut m = ScenarioMatrix::blas_tuning();
        m.scenarios.push(ScenarioSpec {
            name: "bad".into(),
            lib: Some("mkl".into()),
            ..ScenarioSpec::default()
        });
        assert!(matches!(
            m.expand(),
            Err(CimoneError::UnknownKernel { ref name, .. }) if name == "mkl"
        ));
    }

    #[test]
    fn blas_tuning_matrix_round_trips_through_render() {
        let m = ScenarioMatrix::blas_tuning();
        assert_eq!(ScenarioMatrix::parse(&m.render()).unwrap(), m);
    }

    #[test]
    fn power_cap_matrix_clamps_cores_to_the_operating_point() {
        let m = ScenarioMatrix::power_cap();
        let scenarios = m.expand().unwrap();
        assert_eq!(scenarios.len(), 30, "5 platforms x 2 widths x 3 caps");
        assert_eq!(scenarios[0].name, "mcv1-u740/1n/cap120W");
        // the dual-socket MCv2 idles at 110 W: a 120 W cap leaves
        // floor((120 - 110) / 1.4) = 7 active cores of its 128
        let capped = scenarios.iter().find(|s| s.name == "mcv2-dual/1n/cap120W").unwrap();
        match &capped.spec.workloads[0] {
            WorkloadSpec::Hpl { cores_per_node, .. } => assert_eq!(*cores_per_node, 7),
            other => panic!("expected Hpl, got {other:?}"),
        }
        // the U740's 4 cores fit under every cap (25 + 1.2c W)
        let v1 = scenarios.iter().find(|s| s.name == "mcv1-u740/1n/cap120W").unwrap();
        match &v1.spec.workloads[0] {
            WorkloadSpec::Hpl { cores_per_node, .. } => assert_eq!(*cores_per_node, 4),
            other => panic!("expected Hpl, got {other:?}"),
        }
        // a cap below a platform's one-core floor is a load-time error
        let mut m = ScenarioMatrix::power_cap();
        m.axes.power_caps = vec![100.0]; // < the dual-socket 111.4 W floor
        assert!(matches!(
            m.expand(),
            Err(CimoneError::Spec(ref msg)) if msg.contains("below one active core")
        ));
    }

    #[test]
    fn precision_matrix_shows_the_mixed_precision_uplift_everywhere() {
        let m = ScenarioMatrix::precision();
        let report = dry_run_matrix(&m).unwrap();
        assert_eq!(report.scenarios.len(), 4, "the four vector generations");
        for o in &report.scenarios {
            let gf = |job: &str| -> f64 {
                o.jobs.iter().find(|j| j.name == job).map(|j| j.headline).unwrap_or(0.0)
            };
            let (hpl, mxp) = (gf("hpl"), gf("hpl-mxp"));
            assert!(hpl > 0.0, "{}: no FP64 HPL row", o.name);
            // the HPL-MxP punchline: SEW=32 packs two elements per lane,
            // so mixed precision strictly beats FP64 on every RVV
            // platform — but never by more than the 2x lane-packing
            // bound (the iterative-refinement overhead eats into it)
            assert!(mxp > hpl, "{}: MxP {mxp:.1} !> HPL {hpl:.1}", o.name);
            assert!(mxp < 2.5 * hpl, "{}: MxP {mxp:.1} vs HPL {hpl:.1}", o.name);
        }
        // warm rerun through the content-addressed estimate cache is
        // bit-identical to the cold one (SEW feeds the cache key)
        let again = dry_run_matrix(&m).unwrap();
        assert_eq!(again, report);
    }

    #[test]
    fn precision_matrix_on_the_scalar_generation_is_a_typed_error() {
        // the U740's scalar FP64 pipeline has no element width to
        // narrow: retargeting the MxP job onto it must fail with the
        // kernel's typed FP64-only error, not a silent wrong number
        let mut m = ScenarioMatrix::precision();
        m.axes.platforms = vec!["mcv1-u740".into()];
        let err = dry_run_matrix(&m).unwrap_err();
        assert!(
            matches!(err, CimoneError::InvalidKernel { ref reason, .. } if reason.contains("FP64-only")),
            "{err:?}"
        );
    }

    #[test]
    fn sparse_matrix_stays_under_the_stream_roof() {
        let m = ScenarioMatrix::sparse();
        let report = dry_run_matrix(&m).unwrap();
        assert_eq!(report.scenarios.len(), 5, "every generation, scalar included");
        for o in &report.scenarios {
            let spmv = o.jobs.iter().find(|j| j.name == "spmv").expect("spmv row");
            assert_eq!(spmv.metric, "gflops");
            assert!(spmv.headline > 0.0, "{}: SpMV projected 0 GF/s", o.name);
            // roofline sanity: each CSR nonzero moves >= 12 bytes
            // (8 B value + 4 B index) for 2 flops, so SpMV GF/s can
            // never exceed the platform's triad bandwidth (the STREAM
            // row times the triad kernel factor) divided by 6
            let triad_roof = o.stream_gbs * crate::mem::stream_model::SPMV_STREAM_FACTOR / 6.0;
            assert!(
                spmv.headline <= triad_roof,
                "{}: SpMV {:.2} GF/s breaks the {:.2} GF/s triad roof",
                o.name,
                spmv.headline,
                triad_roof
            );
        }
        // the sparse table is cache-stable too: warm == cold, bit for bit
        let again = dry_run_matrix(&m).unwrap();
        assert_eq!(again, report);
    }

    #[test]
    fn nodes_down_scenarios_take_the_fleet_tail_out_of_service() {
        let mut m = ScenarioMatrix::generations();
        m.axes = MatrixAxes::default();
        m.scenarios = vec![ScenarioSpec {
            name: "degraded".into(),
            platform: Some("mcv2-pioneer".into()),
            count: Some(4),
            nodes_down: Some(2),
            ..ScenarioSpec::default()
        }];
        let scenarios = m.expand().unwrap();
        let spec = &scenarios[0].spec;
        assert_eq!(spec.outages.len(), 2);
        let nodes: Vec<usize> = spec.outages.iter().map(|o| o.node).collect();
        assert_eq!(nodes, vec![3, 2], "the fleet tail goes first");
        assert!(spec.outages.iter().all(|o| o.down_s == 0.0 && o.up_s.is_none()));
        // taking every node down is rejected at load time
        m.scenarios[0].nodes_down = Some(4);
        assert!(matches!(
            m.expand(),
            Err(CimoneError::Spec(ref msg)) if msg.contains("would empty the 4-node fleet")
        ));
        // on the default paper fleet the tail node is id 11 (mcv2)
        let mut m = ScenarioMatrix::generations();
        m.axes = MatrixAxes::default();
        m.scenarios = vec![ScenarioSpec {
            name: "paper-degraded".into(),
            nodes_down: Some(1),
            ..ScenarioSpec::default()
        }];
        let scenarios = m.expand().unwrap();
        assert_eq!(scenarios[0].spec.outages[0].node, 11);
    }

    #[test]
    fn fabric_axis_values_resolve_and_canonicalize() {
        let mut m = ScenarioMatrix::fabric_scaling();
        // aliases on the axis canonicalize to registry ids in the spec
        m.axes.fabrics = vec!["10gbe".into()];
        let scenarios = m.expand().unwrap();
        assert!(scenarios.iter().all(|s| s.spec.fabric.as_deref() == Some("ten-gbe-flat")));
        // unknown fabrics are typed errors at expansion (= load) time
        m.axes.fabrics = vec!["infiniband".into()];
        assert!(matches!(
            m.expand(),
            Err(CimoneError::UnknownFabric { ref id, .. }) if id == "infiniband"
        ));
    }

    #[test]
    fn nodes_override_must_fit_the_base_fleet() {
        // the paper fleet's mcv2 partition has 4 nodes; widening the HPL
        // job to 8 without retargeting cannot fit
        let mut m = ScenarioMatrix::generations();
        m.axes = MatrixAxes::default();
        m.scenarios = vec![ScenarioSpec {
            name: "too-wide".into(),
            nodes: Some(8),
            ..ScenarioSpec::default()
        }];
        assert!(matches!(
            m.expand(),
            Err(CimoneError::Spec(ref msg)) if msg.contains("wants 8 nodes")
        ));
        // nodes = 2 fits the mcv2 partition and widens the job
        m.scenarios[0].nodes = Some(2);
        let scenarios = m.expand().unwrap();
        match &scenarios[0].spec.workloads[1] {
            WorkloadSpec::Hpl { nodes, cluster_nodes, .. } => {
                assert_eq!((*nodes, *cluster_nodes), (2, 2));
            }
            other => panic!("expected Hpl, got {other:?}"),
        }
    }

    #[test]
    fn plain_campaign_is_a_single_base_scenario() {
        let m = ScenarioMatrix::parse(
            "[[workload]]\nkind = \"stream\"\nname = \"s\"\nplatform = \"mcv2\"\npartition = \"mcv2\"\nthreads = 64\n",
        )
        .unwrap();
        let scenarios = m.expand().unwrap();
        assert_eq!(scenarios.len(), 1);
        assert_eq!(scenarios[0].name, "base");
        assert_eq!(scenarios[0].spec, m.base);
    }

    #[test]
    fn count_without_platform_or_fleet_is_rejected() {
        let m = ScenarioMatrix {
            base: CampaignSpec::new(),
            scenarios: vec![ScenarioSpec {
                name: "x".into(),
                count: Some(4),
                ..ScenarioSpec::default()
            }],
            axes: MatrixAxes::default(),
        };
        assert!(matches!(
            m.expand(),
            Err(CimoneError::Spec(ref msg)) if msg.contains("count needs a platform")
        ));
    }

    #[test]
    fn platform_retarget_keeps_the_base_fleet_size() {
        // an 8-node base rack stays 8 nodes when retargeted, unless the
        // scenario names its own count
        let mut m = ScenarioMatrix::generations();
        m.base.fleet = vec![("mcv2-pioneer".to_string(), 8)];
        let scenarios = m.expand().unwrap();
        assert_eq!(scenarios[0].spec.fleet, vec![("mcv1-u740".to_string(), 8)]);
        m.scenarios.push(ScenarioSpec {
            name: "small".into(),
            platform: Some("sg2044".into()),
            count: Some(2),
            ..ScenarioSpec::default()
        });
        let scenarios = m.expand().unwrap();
        assert_eq!(scenarios[0].spec.fleet, vec![("sg2044".to_string(), 2)]);
    }

    #[test]
    fn undersized_count_on_a_base_fleet_fails_at_load_time() {
        // base fleet of 4 with a 2-node job; count = 1 can't fit it, and
        // must be rejected while expanding, not mid-sweep
        let text = "\
[[fleet]]
platform = \"sg2044\"
count = 4

[[workload]]
kind = \"hpl\"
name = \"h2\"
platform = \"sg2044\"
partition = \"sg2044\"
nodes = 2
cores_per_node = 64

[[scenario]]
name = \"small\"
count = 1
";
        assert!(matches!(
            ScenarioMatrix::parse(text),
            Err(CimoneError::Spec(ref msg))
                if msg.contains("wants 2 nodes") && msg.contains("has 1")
        ));
        // count = 2 fits and loads
        let ok = ScenarioMatrix::parse(&text.replace("count = 1", "count = 2")).unwrap();
        assert_eq!(ok.expand().unwrap()[0].spec.fleet, vec![("sg2044".to_string(), 2)]);
    }

    #[test]
    fn comparison_json_round_trips_through_the_parser() {
        let report = dry_run_matrix(&ScenarioMatrix::generations()).unwrap();
        let j = report.to_json();
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back.get("baseline").unwrap().as_str(), Some("mcv1-u740"));
        let rows = back.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 5);
        // baseline speedups are exactly 1
        let base = &rows[0];
        assert_eq!(base.get("hpl_speedup").unwrap().as_f64(), Some(1.0));
        // the dual-socket row carries the headline uplift
        let dual = rows.iter().find(|r| r.get("name").unwrap().as_str() == Some("mcv2-dual"));
        let hx = dual.unwrap().get("hpl_speedup").unwrap().as_f64().unwrap();
        assert!(hx > 100.0, "{hx}");
    }

    #[test]
    fn spec_decode_matches_expand_order() {
        for m in [
            ScenarioMatrix::generations(),
            ScenarioMatrix::fabric_scaling(),
            ScenarioMatrix::blas_tuning(),
            ScenarioMatrix::power_cap(),
            ScenarioMatrix::precision(),
            ScenarioMatrix::sparse(),
        ] {
            let expanded = m.expand().unwrap();
            assert_eq!(expanded.len(), m.spec_count());
            for (i, s) in expanded.iter().enumerate() {
                assert_eq!(m.spec_at(i).name, s.name, "index {i}");
            }
        }
        // explicit scenarios come first, then the axis product
        let mut m = ScenarioMatrix::generations();
        m.scenarios.push(ScenarioSpec { name: "explicit".into(), ..ScenarioSpec::default() });
        assert_eq!(m.spec_count(), 6);
        assert_eq!(m.spec_at(0).name, "explicit");
        assert_eq!(m.spec_at(1).name, "mcv1-u740");
        // a bare campaign is the single `base` scenario
        let bare = ScenarioMatrix {
            base: CampaignSpec::new(),
            scenarios: Vec::new(),
            axes: MatrixAxes::default(),
        };
        assert_eq!(bare.spec_count(), 1);
        assert_eq!(bare.spec_at(0).name, "base");
        // full eight-axis decode: last axis fastest, like the old loops
        let m = ScenarioMatrix {
            base: CampaignSpec::new(),
            scenarios: Vec::new(),
            axes: MatrixAxes {
                platforms: vec!["a".into(), "b".into()],
                fleet_sizes: vec![1],
                node_counts: vec![2, 4],
                libs: vec!["x".into()],
                fabrics: vec!["f1".into(), "f2".into()],
                power_caps: vec![100.0],
                nodes_down: vec![0, 1],
                workloads: vec!["w".into()],
            },
        };
        assert_eq!(m.spec_count(), 16);
        let names: Vec<String> = (0..16).map(|i| m.spec_at(i).name).collect();
        let want = [
            "a/n1/2n/x/f1/cap100W/down0/w",
            "a/n1/2n/x/f1/cap100W/down1/w",
            "a/n1/2n/x/f2/cap100W/down0/w",
            "a/n1/2n/x/f2/cap100W/down1/w",
            "a/n1/4n/x/f1/cap100W/down0/w",
            "a/n1/4n/x/f1/cap100W/down1/w",
            "a/n1/4n/x/f2/cap100W/down0/w",
            "a/n1/4n/x/f2/cap100W/down1/w",
            "b/n1/2n/x/f1/cap100W/down0/w",
            "b/n1/2n/x/f1/cap100W/down1/w",
            "b/n1/2n/x/f2/cap100W/down0/w",
            "b/n1/2n/x/f2/cap100W/down1/w",
            "b/n1/4n/x/f1/cap100W/down0/w",
            "b/n1/4n/x/f1/cap100W/down1/w",
            "b/n1/4n/x/f2/cap100W/down0/w",
            "b/n1/4n/x/f2/cap100W/down1/w",
        ];
        assert_eq!(names, want);
    }

    #[test]
    fn full_codesign_matrix_streams_at_codesign_scale() {
        let m = ScenarioMatrix::full_codesign();
        // 5 platforms x 4 fleets x 3 widths x 6 kernels x 2 fabrics x
        // 7 caps x 4 degraded states x 5 workload families
        assert_eq!(m.spec_count(), 100_800);
        // the per-axis name check accepts the product without ever
        // materializing it
        m.check_names().unwrap();
        // mixed-radix decode at the corners and interior points: every
        // spec derives (all axis combinations are valid by construction)
        let last = m.spec_count() - 1;
        assert_eq!(
            m.spec_at(0).name,
            "mcv2-pioneer/n4/1n/openblas-c920/gbe-flat/cap120W/down0/hpl"
        );
        assert_eq!(
            m.spec_at(last).name,
            "c930-eval/n32/4n/blis-rvv1-vl256/ten-gbe-flat/cap250W/down3/dgemm"
        );
        for i in [0, 1, 7 * 4 * 5, last / 3, last / 2, last - 1, last] {
            let s = m.spec_at(i);
            s.derive(&m.base).unwrap_or_else(|e| panic!("spec {i} `{}`: {e:?}", s.name));
        }
        // round-trips through render like every other built-in
        assert_eq!(ScenarioMatrix::parse(&m.render()).unwrap(), m);
    }

    #[test]
    fn per_axis_name_check_catches_duplicates_without_streaming() {
        // a repeated axis value is exactly a duplicate-name collision;
        // the fast path must report it as such
        let mut m = ScenarioMatrix::full_codesign();
        m.axes.libs.push("blis-lmul4".into());
        assert!(matches!(
            m.check_names(),
            Err(CimoneError::Spec(ref msg))
                if msg.contains("duplicate scenario name") && msg.contains("blis-lmul4")
        ));
        // with explicit scenarios in play the streaming path takes over
        // and still catches a clash against a product name
        let mut m = ScenarioMatrix::fabric_scaling();
        m.scenarios.push(ScenarioSpec {
            name: "mcv1-u740/1n/gbe-flat".into(),
            ..ScenarioSpec::default()
        });
        assert!(matches!(
            m.check_names(),
            Err(CimoneError::Spec(ref msg)) if msg.contains("duplicate scenario name")
        ));
    }

    #[test]
    fn top_k_keeps_baseline_and_best_and_counts_truncation() {
        let m = ScenarioMatrix::fabric_scaling();
        let full = dry_run_matrix(&m).unwrap();
        assert_eq!((full.total, full.truncated), (16, 0));
        let top =
            dry_run_matrix_with(&m, &SweepOptions { shard_size: 3, top_k: Some(4) }).unwrap();
        assert_eq!(top.scenarios.len(), 4);
        assert_eq!((top.total, top.truncated), (16, 12));
        // the baseline survives as row 0, so speedups stay comparable
        assert_eq!(top.baseline().unwrap().name, full.baseline().unwrap().name);
        // the rest are the global best by (HPL GFLOP/s desc, name asc)
        let mut rest: Vec<&ScenarioOutcome> = full.scenarios[1..].iter().collect();
        rest.sort_by(|a, b| {
            b.hpl_gflops.total_cmp(&a.hpl_gflops).then_with(|| a.name.cmp(&b.name))
        });
        let want: Vec<&str> = rest[..3].iter().map(|o| o.name.as_str()).collect();
        let got: Vec<&str> = top.scenarios[1..].iter().map(|o| o.name.as_str()).collect();
        assert_eq!(got, want);
        // shard size cannot change the selection or the rows
        for shard in [1, 5, 64] {
            let again =
                dry_run_matrix_with(&m, &SweepOptions { shard_size: shard, top_k: Some(4) })
                    .unwrap();
            assert_eq!(again, top);
        }
        // the render calls the truncation out; the JSON carries totals
        let s = top.render();
        assert!(s.contains("12 of 16 scenarios truncated"), "{s}");
        let j = Json::parse(&top.to_json().render()).unwrap();
        assert_eq!(j.get("total").unwrap().as_f64(), Some(16.0));
        assert_eq!(j.get("truncated").unwrap().as_f64(), Some(12.0));
    }

    #[test]
    fn sharded_full_sweep_equals_the_unsharded_report() {
        let m = ScenarioMatrix::blas_tuning();
        let whole = dry_run_matrix(&m).unwrap();
        let sharded =
            dry_run_matrix_with(&m, &SweepOptions { shard_size: 1, top_k: None }).unwrap();
        assert_eq!(sharded, whole);
        assert_eq!((whole.total, whole.truncated), (8, 0));
    }
}
