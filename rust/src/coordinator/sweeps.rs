//! Parameter-sweep engine: the full evaluation grid beyond the paper's
//! figures — core-count x library, node-count scaling (extending Fig 5
//! past 2 nodes), NB sensitivity, the LMUL ablation, and the
//! "down the road" generation sweep across every registered platform
//! (MCv1 -> MCv2 -> SG2044 -> MCv3). These are the "what the paper would
//! have shown with more pages" experiments that DESIGN.md's ablation
//! list calls out.

use crate::arch::platform::{self, PlatformRegistry};
use crate::arch::presets;
use crate::blas::perf::PerfModel;
use crate::hpl::model::{project, ClusterConfig};
use crate::isa::rvv::Lmul;
use crate::net::Fabric;
use crate::ukernel::{ablation, KernelRegistry};
use crate::util::table::Table;

use super::scenario::{dry_run_matrix, fmt_speedup, ComparisonReport, ScenarioMatrix};

/// Core-count x library grid on the dual-socket node (the superset of
/// Figs 4 and 7).
pub fn grid_cores_by_library(core_counts: &[usize]) -> Table {
    let d = platform::mcv2_dual();
    let reg = KernelRegistry::builtin();
    let ids = ["openblas-generic", "openblas-c920", "blis-lmul1", "blis-lmul4"];
    let models: Vec<PerfModel> = ids
        .iter()
        .map(|id| PerfModel::new(&d, reg.get(id).expect("built-in kernel")))
        .collect();
    let mut t = Table::new(vec![
        "cores",
        "OpenBLAS generic",
        "OpenBLAS opt",
        "BLIS vanilla",
        "BLIS opt",
    ]);
    for &c in core_counts {
        let mut row = vec![c.to_string()];
        for m in &models {
            row.push(format!("{:.1}", m.node_gflops(c)));
        }
        t.row(row);
    }
    t
}

/// Node-count scaling on the `gbe-flat` and `ten-gbe-flat` fabrics —
/// extends Fig 5 to the whole MCv2 partition (and hypothetical growth).
pub fn node_scaling(max_nodes: usize) -> Table {
    let mut t = Table::new(vec![
        "nodes",
        "1GbE Gflop/s",
        "1GbE efficiency",
        "10GbE Gflop/s",
        "10GbE efficiency",
    ]);
    for nodes in 1..=max_nodes {
        let mut cfg = ClusterConfig::hpl_default(platform::mcv2_pioneer(), nodes, 64);
        let p1 = project(&cfg);
        cfg.fabric = Fabric::ten_gbe_flat();
        let p10 = project(&cfg);
        t.row(vec![
            nodes.to_string(),
            format!("{:.1}", p1.gflops),
            format!("{:.0}%", 100.0 * p1.efficiency_vs_one_node),
            format!("{:.1}", p10.gflops),
            format!("{:.0}%", 100.0 * p10.efficiency_vs_one_node),
        ]);
    }
    t
}

/// The Fig 5 punchline as one table: the built-in
/// [`ScenarioMatrix::fabric_scaling`] matrix (generation x fabric x node
/// count), dry-run and pivoted so each `(platform, fabric)` pair is a
/// row of HPL GFLOP/s per node count plus its scaling efficiency at the
/// widest point — near-linear MCv1 on 1 GbE, collapsing MCv2 on the
/// same wire, restored by 10 GbE.
pub fn fabric_scaling_table() -> Table {
    let matrix = ScenarioMatrix::fabric_scaling();
    let report = dry_run_matrix(&matrix)
        .expect("the built-in fabric-scaling matrix is valid");
    let widths = &matrix.axes.node_counts;
    let widest = *widths.last().expect("the scaling axis is non-empty");
    let mut headers = vec!["platform".to_string(), "fabric".to_string()];
    headers.extend(widths.iter().map(|n| format!("{n}n GF/s")));
    headers.push(format!("eff@{widest}n"));
    let mut t = Table::new(headers);
    for p in &matrix.axes.platforms {
        for f in &matrix.axes.fabrics {
            // a missing name means the built-in matrix and this pivot
            // drifted apart — a programmer error, never a zero row
            let gf = |n: usize| -> f64 {
                report
                    .outcome(&format!("{p}/{n}n/{f}"))
                    .unwrap_or_else(|| {
                        panic!("fabric-scaling scenario `{p}/{n}n/{f}` missing from the report")
                    })
                    .hpl_gflops
            };
            // per-node rate at the widest point over the rate at the
            // narrowest — correct whatever width the axis starts at
            let base_per_node = gf(widths[0]) / widths[0] as f64;
            let eff = gf(widest) / widest as f64 / base_per_node.max(1e-30);
            let mut row = vec![p.clone(), f.clone()];
            row.extend(widths.iter().map(|&n| format!("{:.1}", gf(n))));
            row.push(format!("{:.0}%", 100.0 * eff));
            t.row(row);
        }
    }
    t
}

/// NB (HPL block size) sensitivity at fixed N — the classic HPL tuning
/// knob; the DGEMM fraction and comm granularity fight each other.
pub fn nb_sensitivity(n: usize, nbs: &[usize]) -> Table {
    let mut t = Table::new(vec!["NB", "2-node Gflop/s", "comm share"]);
    for &nb in nbs {
        let mut cfg = ClusterConfig::hpl_default(platform::mcv2_pioneer(), 2, 64);
        cfg.n = n;
        cfg.nb = nb;
        let p = project(&cfg);
        t.row(vec![
            nb.to_string(),
            format!("{:.1}", p.gflops),
            format!("{:.0}%", 100.0 * p.t_comm / (p.t_comp + p.t_comm)),
        ]);
    }
    t
}

/// The LMUL ablation (M1/M2/M4 + infeasible M8) — why the paper stops
/// at 4. Descriptor-driven: every row is an `ablation::point` sweep
/// descriptor, and M8's row is its typed validation failure.
pub fn lmul_ablation() -> Table {
    let core = presets::c920();
    let mut t = Table::new(vec!["LMUL", "insts/k-step", "cycles/k-step", "feasible"]);
    for row in ablation::sweep(&[128], &[Lmul::M1, Lmul::M2, Lmul::M4, Lmul::M8], &[1], 64, &core)
    {
        match (row.insts_per_kstep, row.cycles_per_kstep) {
            (Some(i), Some(c)) => {
                t.row(vec![
                    format!("{:?}", row.desc.lmul),
                    format!("{i:.1}"),
                    format!("{c:.1}"),
                    "yes".to_string(),
                ]);
            }
            _ => {
                let reason = row.desc.validate().unwrap_err().to_string();
                t.row(vec![
                    format!("{:?}", row.desc.lmul),
                    "-".to_string(),
                    "-".to_string(),
                    format!("no ({reason})"),
                ]);
            }
        }
    }
    t
}

/// The kernel-tuning punchline as one table: the built-in
/// [`ScenarioMatrix::blas_tuning`] matrix, dry-run and pivoted so each
/// platform is a row of node GFLOP/s per registered kernel plus the
/// winning kernel — Fig 2's LMUL uplift on the SG2042 next to the
/// native-RVV 1.0 takeover on the SG2044.
pub fn blas_tuning_table() -> Table {
    let matrix = ScenarioMatrix::blas_tuning();
    let report =
        dry_run_matrix(&matrix).expect("the built-in blas-tuning matrix is valid");
    let mut headers = vec!["platform".to_string()];
    headers.extend(matrix.axes.libs.iter().map(|l| format!("{l} GF/s")));
    headers.push("best".to_string());
    let mut t = Table::new(headers);
    for p in &matrix.axes.platforms {
        let gf = |l: &String| -> f64 {
            report
                .outcome(&format!("{p}/{l}"))
                .unwrap_or_else(|| {
                    panic!("blas-tuning scenario `{p}/{l}` missing from the report")
                })
                .hpl_gflops
        };
        let best = matrix
            .axes
            .libs
            .iter()
            .max_by(|a, b| gf(a).total_cmp(&gf(b)))
            .expect("the libs axis is non-empty");
        let mut row = vec![p.clone()];
        row.extend(matrix.axes.libs.iter().map(|l| format!("{:.1}", gf(l))));
        row.push(best.clone());
        t.row(row);
    }
    t
}

/// The power-cap operating-point table: the built-in
/// [`ScenarioMatrix::power_cap`] matrix, dry-run and pivoted so each
/// generation is a row of GF/s-per-W per (node count, per-node cap)
/// operating point plus the best one — the Green500 question asked
/// under power capping, answered per generation.
pub fn power_cap_table() -> Table {
    let matrix = ScenarioMatrix::power_cap();
    let report = dry_run_matrix(&matrix).expect("the built-in power-cap matrix is valid");
    let points: Vec<(usize, f64)> = matrix
        .axes
        .node_counts
        .iter()
        .flat_map(|&n| matrix.axes.power_caps.iter().map(move |&c| (n, c)))
        .collect();
    let mut headers = vec!["platform".to_string()];
    headers.extend(points.iter().map(|(n, c)| format!("{n}n@{c}W GF/s/W")));
    headers.push("best".to_string());
    let mut t = Table::new(headers);
    for p in &matrix.axes.platforms {
        // a missing name means the built-in matrix and this pivot
        // drifted apart — a programmer error, never a zero row
        let eff = |&(n, c): &(usize, f64)| -> f64 {
            report
                .outcome(&format!("{p}/{n}n/cap{c}W"))
                .unwrap_or_else(|| {
                    panic!("power-cap scenario `{p}/{n}n/cap{c}W` missing from the report")
                })
                .gflops_per_w
        };
        let best = points
            .iter()
            .max_by(|a, b| eff(a).total_cmp(&eff(b)))
            .expect("the operating-point grid is non-empty");
        let mut row = vec![p.clone()];
        row.extend(points.iter().map(|pt| format!("{:.2}", eff(pt))));
        row.push(format!("{}n@{}W", best.0, best.1));
        t.row(row);
    }
    t
}

/// The mixed-precision punchline as one table: the built-in
/// [`ScenarioMatrix::precision`] matrix, dry-run and pivoted so each
/// vector generation is a row of FP64 HPL next to HPL-MxP (the same
/// job with its kernel rebuilt at SEW=32) — the HPL-MxP benchmark's
/// Green500-style question, "what does dropping precision buy?",
/// answered per generation with the uplift ratio and the
/// mixed-precision GF/s-per-W.
pub fn precision_table() -> Table {
    let matrix = ScenarioMatrix::precision();
    let report = dry_run_matrix(&matrix).expect("the built-in precision matrix is valid");
    let mut t = Table::new(vec![
        "platform",
        "HPL GF/s",
        "MxP GF/s",
        "MxP/HPL",
        "MxP GF/s/W",
    ]);
    for p in &matrix.axes.platforms {
        // a missing name means the built-in matrix and this pivot
        // drifted apart — a programmer error, never a zero row
        let o = report
            .outcome(p)
            .unwrap_or_else(|| panic!("precision scenario `{p}` missing from the report"));
        let job = |name: &str| {
            o.jobs
                .iter()
                .find(|j| j.name == name)
                .unwrap_or_else(|| panic!("precision scenario `{p}` has no `{name}` job"))
        };
        let (hpl, mxp) = (job("hpl"), job("hpl-mxp"));
        t.row(vec![
            p.clone(),
            format!("{:.1}", hpl.headline),
            format!("{:.1}", mxp.headline),
            format!("{:.2}x", mxp.headline / hpl.headline.max(1e-30)),
            mxp.gflops_per_w
                .map(|e| format!("{e:.2}"))
                .unwrap_or_else(|| "-".to_string()),
        ]);
    }
    t
}

/// The sparse roofline as one table: the built-in
/// [`ScenarioMatrix::sparse`] matrix, dry-run and pivoted so each
/// generation is a row of STREAM triad GB/s next to the HPCG-shaped
/// SpMV's projected GF/s and its share of the triad roof — one
/// roofline story per generation, the memory-bound companion to the
/// HPL tables.
pub fn sparse_table() -> Table {
    let matrix = ScenarioMatrix::sparse();
    let report = dry_run_matrix(&matrix).expect("the built-in sparse matrix is valid");
    let mut t = Table::new(vec![
        "platform",
        "STREAM GB/s",
        "SpMV GF/s",
        "roof GF/s",
        "% of roof",
    ]);
    for p in &matrix.axes.platforms {
        let o = report
            .outcome(p)
            .unwrap_or_else(|| panic!("sparse scenario `{p}` missing from the report"));
        let spmv = o
            .jobs
            .iter()
            .find(|j| j.name == "spmv")
            .unwrap_or_else(|| panic!("sparse scenario `{p}` has no `spmv` job"));
        // each CSR nonzero moves >= 12 streamed bytes for 2 flops, so
        // the triad rate over 6 is the hard SpMV ceiling
        let roof = o.stream_gbs * crate::mem::stream_model::SPMV_STREAM_FACTOR / 6.0;
        t.row(vec![
            p.clone(),
            format!("{:.1}", o.stream_gbs),
            format!("{:.2}", spmv.headline),
            format!("{roof:.2}"),
            format!("{:.0}%", 100.0 * spmv.headline / roof.max(1e-30)),
        ]);
    }
    t
}

/// The generation comparison every "down the road" table derives from:
/// the built-in [`ScenarioMatrix::generations`] matrix, dry-run (pure
/// modelling, nothing scheduled).
fn generation_report() -> ComparisonReport {
    dry_run_matrix(&ScenarioMatrix::generations())
        .expect("the built-in generation matrix is valid")
}

/// Energy-to-solution: the generation matrix's HPL jobs (fixed
/// N = 57600) — the efficiency argument implicit in the paper's Top500
/// comparison, extended down the road to the SG2044 and MCv3 platforms.
pub fn energy_to_solution() -> Table {
    energy_table(&generation_report())
}

fn energy_table(report: &ComparisonReport) -> Table {
    let mut t = Table::new(vec![
        "node",
        "Gflop/s",
        "power (W)",
        "time (h)",
        "energy (kWh)",
        "Gflop/s/W",
    ]);
    for o in &report.scenarios {
        let Some(hpl) = o.jobs.iter().find(|j| j.metric == "gflops") else {
            continue;
        };
        t.row(vec![
            o.name.clone(),
            format!("{:.1}", hpl.headline),
            format!("{:.0}", hpl.avg_node_w),
            format!("{:.2}", hpl.runtime_s / 3600.0),
            format!("{:.2}", hpl.energy_j / 3.6e6),
            format!("{:.2}", o.gflops_per_w),
        ]);
    }
    t
}

/// "Down the road": single-node HPL, STREAM and speedup-vs-MCv1 across
/// the platform generations — the matrix-driven replacement for the old
/// hard-coded case list, sharing its rows with `cimone sweep`.
pub fn generation_sweep() -> Table {
    generation_table(&generation_report())
}

fn generation_table(report: &ComparisonReport) -> Table {
    let reg = PlatformRegistry::builtin();
    let mut t = Table::new(vec![
        "platform",
        "peak GF/s",
        "HPL GF/s",
        "HPL %peak",
        "STREAM GB/s",
        "HPL x",
        "STREAM x",
    ]);
    for o in &report.scenarios {
        // scenario names of the generations matrix are platform ids
        let peak = reg.get(&o.name).map(|p| p.peak_gflops()).unwrap_or(0.0);
        let (hpl_x, stream_x) = report.speedup_of(o);
        t.row(vec![
            o.name.clone(),
            format!("{peak:.1}"),
            format!("{:.1}", o.hpl_gflops),
            format!("{:.0}%", 100.0 * o.hpl_gflops / peak.max(1e-30)),
            format!("{:.1}", o.stream_gbs),
            fmt_speedup(hpl_x),
            fmt_speedup(stream_x),
        ]);
    }
    t
}

/// Render the whole extension suite. The generation matrix is dry-run
/// once and shared by both generation tables.
pub fn render_all() -> String {
    let report = generation_report();
    format!(
        "== Extension: cores x library grid (dual-socket MCv2) ==\n{}\n\n\
         == Extension: node-count scaling, 1 vs 10 GbE (N=57600) ==\n{}\n\n\
         == Extension: fabric scaling, generation x interconnect (Fig 5 effect) ==\n{}\n\n\
         == Extension: NB sensitivity (N=57600, 2 nodes, 1 GbE) ==\n{}\n\n\
         == Extension: LMUL ablation (why the paper stops at 4) ==\n{}\n\n\
         == Extension: kernel tuning, SG2042 vs SG2044 (blas-tuning matrix) ==\n{}\n\n\
         == Extension: power-cap operating points, GF/s-per-W (power-cap matrix) ==\n{}\n\n\
         == Extension: mixed precision, HPL vs HPL-MxP (precision matrix) ==\n{}\n\n\
         == Extension: sparse roofline, STREAM vs SpMV (sparse matrix) ==\n{}\n\n\
         == Extension: energy to solution (HPL N=57600) ==\n{}\n\n\
         == Extension: down the road (MCv1 -> MCv2 -> SG2044 -> MCv3) ==\n{}",
        grid_cores_by_library(&[1, 4, 16, 64, 128]).render(),
        node_scaling(4).render(),
        fabric_scaling_table().render(),
        nb_sensitivity(57_600, &[64, 128, 192, 256, 384]).render(),
        lmul_ablation().render(),
        blas_tuning_table().render(),
        power_cap_table().render(),
        precision_table().render(),
        sparse_table().render(),
        energy_table(&report).render(),
        generation_table(&report).render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_has_all_libraries_and_counts() {
        let t = grid_cores_by_library(&[1, 64]);
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    fn node_scaling_efficiency_decreases_on_gbe() {
        let s = node_scaling(4).render();
        assert!(s.contains('%'));
        // 4 nodes on 1 GbE must be well below linear
        let mut cfg = ClusterConfig::hpl_default(platform::mcv2_pioneer(), 4, 64);
        let p = project(&cfg);
        assert!(p.efficiency_vs_one_node < 0.55, "{}", p.efficiency_vs_one_node);
        cfg.fabric = Fabric::ten_gbe_flat();
        assert!(project(&cfg).efficiency_vs_one_node > p.efficiency_vs_one_node);
    }

    #[test]
    fn fabric_scaling_table_carries_the_fig5_story() {
        let s = fabric_scaling_table().render();
        // one row per (platform, fabric) pair, widths as columns
        assert!(s.contains("mcv1-u740") && s.contains("mcv2-pioneer"), "{s}");
        assert!(s.contains("gbe-flat") && s.contains("ten-gbe-flat"), "{s}");
        assert!(s.contains("8n GF/s") && s.contains("eff@8n"), "{s}");
        assert_eq!(fabric_scaling_table().n_rows(), 4);
    }

    #[test]
    fn nb_sweep_has_an_interior_optimum_or_plateau() {
        let nbs = [64usize, 128, 192, 256, 384];
        let vals: Vec<f64> = nbs
            .iter()
            .map(|&nb| {
                let mut cfg = ClusterConfig::hpl_default(platform::mcv2_pioneer(), 2, 64);
                cfg.nb = nb;
                project(&cfg).gflops
            })
            .collect();
        // larger NB -> fewer, bigger messages -> monotone or peaked, never wild
        for w in vals.windows(2) {
            assert!((w[1] / w[0] - 1.0).abs() < 0.25, "{vals:?}");
        }
    }

    #[test]
    fn mcv2_wins_energy_to_solution() {
        // the generation matrix's HPL jobs carry energy-to-solution at
        // the shared N = 57600 calibration point
        let report = generation_report();
        let energy = |name: &str| {
            report
                .outcome(name)
                .unwrap()
                .jobs
                .iter()
                .find(|j| j.metric == "gflops")
                .unwrap()
                .energy_j
        };
        let e_old = energy("mcv1-u740");
        let e_new = energy("mcv2-dual");
        // MCv2 burns ~10x the power but is ~130x faster
        assert!(e_new < e_old / 10.0, "{e_new:.0} J vs {e_old:.0} J");
        let s = energy_to_solution().render();
        assert!(s.contains("kWh") && s.contains("mcv3"), "{s}");
    }

    #[test]
    fn generation_sweep_is_monotone_down_the_road() {
        // HPL GF/s must rise with every generation in the sweep
        let report = generation_report();
        let gfs: Vec<f64> = report.scenarios.iter().map(|o| o.hpl_gflops).collect();
        assert_eq!(gfs.len(), 5);
        for w in gfs.windows(2) {
            assert!(w[1] > w[0], "{gfs:?}");
        }
        let s = generation_sweep().render();
        assert!(s.contains("sg2044") && s.contains("mcv3"), "{s}");
        assert!(s.contains("STREAM x"), "{s}");
    }

    #[test]
    fn blas_tuning_table_carries_both_punchlines() {
        let t = blas_tuning_table();
        let s = t.render();
        assert!(s.contains("mcv2-pioneer") && s.contains("sg2044"), "{s}");
        assert!(s.contains("blis-lmul1 GF/s") && s.contains("blis-rvv1-lmul2 GF/s"), "{s}");
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    fn power_cap_table_names_an_operating_point_per_generation() {
        let t = power_cap_table();
        let s = t.render();
        assert_eq!(t.n_rows(), 5, "one row per generation");
        assert!(s.contains("1n@120W GF/s/W") && s.contains("2n@250W GF/s/W"), "{s}");
        // every generation row names its best operating point
        for p in ["mcv1-u740", "mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3"] {
            let line = s.lines().find(|l| l.contains(p)).unwrap_or_else(|| panic!("{p}: {s}"));
            assert!(line.matches("n@").count() >= 1, "{line}");
        }
    }

    #[test]
    fn precision_table_shows_the_uplift_on_every_vector_row() {
        let t = precision_table();
        let s = t.render();
        assert_eq!(t.n_rows(), 4, "one row per vector generation");
        assert!(s.contains("MxP GF/s") && s.contains("MxP/HPL"), "{s}");
        assert!(!s.contains("mcv1-u740"), "the scalar U740 has no SEW to narrow: {s}");
        // every ratio cell reads as a strict >1x uplift
        for p in ["mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3"] {
            let line = s.lines().find(|l| l.contains(p)).unwrap_or_else(|| panic!("{p}: {s}"));
            let ratio = line
                .split_whitespace()
                .find_map(|c| c.strip_suffix('x').and_then(|v| v.parse::<f64>().ok()))
                .unwrap_or_else(|| panic!("no ratio cell in `{line}`"));
            assert!(ratio > 1.0 && ratio < 2.5, "{p}: {ratio}");
        }
    }

    #[test]
    fn sparse_table_rows_stay_at_or_under_the_roof() {
        let t = sparse_table();
        let s = t.render();
        assert_eq!(t.n_rows(), 5, "every generation, scalar included");
        assert!(s.contains("SpMV GF/s") && s.contains("% of roof"), "{s}");
        for p in ["mcv1-u740", "mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3"] {
            let line = s.lines().find(|l| l.contains(p)).unwrap_or_else(|| panic!("{p}: {s}"));
            let pct = line
                .split_whitespace()
                .find_map(|c| c.strip_suffix('%').and_then(|v| v.parse::<f64>().ok()))
                .unwrap_or_else(|| panic!("no roof-share cell in `{line}`"));
            assert!(pct > 0.0 && pct <= 100.0, "{p}: {pct}% of the triad roof");
        }
    }

    #[test]
    fn lmul_ablation_marks_m8_infeasible() {
        let s = lmul_ablation().render();
        assert!(s.contains("M8"), "{s}");
        assert!(s.contains("invalid kernel"), "M8 row must carry the typed reason: {s}");
    }

    #[test]
    fn render_all_nonempty() {
        let s = render_all();
        assert!(s.contains("LMUL ablation"));
        assert!(s.contains("down the road"));
        assert!(s.contains("fabric scaling"));
        assert!(s.contains("kernel tuning"));
        assert!(s.contains("mixed precision"));
        assert!(s.contains("sparse roofline"));
        assert!(s.len() > 500);
    }
}
