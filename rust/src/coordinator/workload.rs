//! The [`Workload`] trait: one schedulable benchmark of a campaign.
//!
//! A workload knows its job identity (`name`, `partition`, `nodes`), how
//! to *estimate* itself against a concrete [`Inventory`] (simulated
//! runtime + the metric it produces + per-job power/energy from the
//! platform's power model), and how to record its metrics into the
//! ExaMon-like [`Monitor`]. Workloads name the platform they run on by
//! registry id (or alias) and resolve it against the inventory at
//! estimation time — a missing platform is a typed
//! [`CimoneError::NoNodeOfPlatform`], and a new SoC generation needs no
//! workload-layer change at all.
//!
//! Estimates are memoized through the content-addressed cache
//! ([`crate::util::memo`]): after name resolution, each estimator keys
//! the full [`JobEstimate`] on a canonical digest of its *resolved*
//! inputs (platform geometry/power/calibration, kernel descriptor,
//! fabric, problem shape), so a sweep revisiting a coordinate — every
//! warm replay, and most scenarios of a dense matrix — skips the HPL
//! projection and cycle-model work entirely. Cached values ARE cold
//! outputs, so hits are bit-identical by construction; resolution and
//! validation errors stay typed and are never cached.

use std::sync::Arc;

use crate::arch::platform::Platform;
use crate::blas::perf::PerfModel;
use crate::cluster::{Inventory, Monitor};
use crate::error::CimoneError;
use crate::hpl::model::{project, ClusterConfig};
use crate::isa::rvv::Sew;
use crate::mem::stream_model::{predict_node_bandwidth, predict_spmv, SparseShape};
use crate::util::hash::ContentHasher;
use crate::util::memo::{CacheStats, MemoCache};

/// Bytes one simulated STREAM job moves: 10 iterations x 3 arrays x
/// ~128 MB, matching the paper-scale working set.
const STREAM_JOB_BYTES: f64 = 10.0 * 3.0 * 128e6;

/// Matrix-vector sweeps one simulated SpMV job performs (HPCG-style
/// repeated applications of the same operator).
const SPMV_JOB_ITERS: f64 = 50.0;

/// Fractional time HPL-MxP spends outside the FP32 factorization
/// (GMRES-based iterative refinement back to FP64 accuracy). Small by
/// construction — refinement is O(N^2) against the O(N^3) solve.
const MXP_IR_OVERHEAD: f64 = 0.06;

/// The estimate cache: one [`JobEstimate`] per resolved-input digest.
static ESTIMATE_CACHE: MemoCache<JobEstimate> = MemoCache::new();

/// Snapshot of the estimate-cache counters (for `cimone bench`).
pub fn estimate_cache_stats() -> CacheStats {
    ESTIMATE_CACHE.stats()
}

/// Drop the estimate cache — the perf harness's cold start.
pub fn reset_estimate_cache() {
    ESTIMATE_CACHE.reset();
}

/// What a workload contributes to the campaign once estimated on a fleet.
#[derive(Debug, Clone)]
pub struct JobEstimate {
    /// Simulated wall-clock the job occupies its nodes for.
    pub runtime_s: f64,
    /// Metric suffix recorded as `<job-name>.<metric>` (ExaMon dotted).
    pub metric: &'static str,
    /// Raw metric value (bytes/s for STREAM, GFLOP/s for HPL).
    pub value: f64,
    /// Headline value reported in `CampaignReport::jobs` (GB/s, GFLOP/s).
    pub headline: f64,
    /// Average per-node draw while the job runs (platform power model).
    pub avg_node_w: f64,
    /// Total energy-to-solution across every allocated node (J).
    pub energy_j: f64,
}

/// One schedulable benchmark workload of a campaign.
pub trait Workload: Send + Sync {
    /// Job name, unique within a campaign (e.g. `hpl-mcv2-2n`).
    fn name(&self) -> &str;

    /// SLURM partition the job is submitted to.
    fn partition(&self) -> &str;

    /// Number of nodes the job allocates.
    fn nodes(&self) -> usize;

    /// Model this workload against a concrete fleet.
    fn estimate(&self, inv: &Inventory) -> Result<JobEstimate, CimoneError>;

    /// Record the workload's metrics at simulated time `t`: the headline
    /// metric plus the per-job power/energy series.
    fn metrics(&self, mon: &mut Monitor, t: f64, est: &JobEstimate) {
        mon.record(&format!("{}.{}", self.name(), est.metric), t, est.value);
        mon.record(&format!("{}.power_w", self.name()), t, est.avg_node_w);
        mon.record(&format!("{}.energy_j", self.name()), t, est.energy_j);
    }
}

/// Find the platform of the first inventory node matching `name` (id or
/// alias), so estimates survive reordered or pruned fleets.
fn platform_of<'a>(inv: &'a Inventory, name: &str) -> Result<&'a Arc<Platform>, CimoneError> {
    inv.nodes
        .iter()
        .find(|n| n.platform.matches(name))
        .map(|n| &n.platform)
        .ok_or_else(|| CimoneError::NoNodeOfPlatform(name.to_string()))
}

/// STREAM bandwidth on one platform (a Fig 3 row).
#[derive(Debug, Clone)]
pub struct StreamWorkload {
    pub name: String,
    pub partition: String,
    pub nodes: usize,
    /// Registry id (or alias) of the platform supplying the memory model.
    pub platform: String,
    pub threads: usize,
}

impl Workload for StreamWorkload {
    fn name(&self) -> &str {
        &self.name
    }

    fn partition(&self) -> &str {
        &self.partition
    }

    fn nodes(&self) -> usize {
        self.nodes
    }

    fn estimate(&self, inv: &Inventory) -> Result<JobEstimate, CimoneError> {
        let p = platform_of(inv, &self.platform)?;
        let mut h = ContentHasher::new();
        h.write_str("estimate-stream/v1");
        p.feed_content(&mut h);
        h.write_usize(self.threads).write_usize(self.nodes);
        let p = Arc::clone(p);
        let threads = self.threads;
        let nodes = self.nodes;
        Ok(ESTIMATE_CACHE.get_or_insert_with(h.finish(), move || {
            let bw = predict_node_bandwidth(&p.desc, threads, true);
            let runtime_s = (STREAM_JOB_BYTES / bw).max(1.0);
            let active = threads.min(p.desc.total_cores());
            let avg_node_w = p.power.node_power(active);
            JobEstimate {
                runtime_s,
                metric: "bandwidth",
                value: bw,
                headline: bw / 1e9,
                avg_node_w,
                energy_j: avg_node_w * nodes as f64 * runtime_s,
            }
        }))
    }
}

/// HPL on one node configuration (a Fig 5 bar).
#[derive(Debug, Clone)]
pub struct HplWorkload {
    pub name: String,
    pub partition: String,
    /// Nodes allocated from the scheduler partition.
    pub nodes: usize,
    /// Registry id (or alias) of the platform supplying the node model.
    pub platform: String,
    /// Nodes in the HPL cluster-projection model (usually == `nodes`).
    pub cluster_nodes: usize,
    pub cores_per_node: usize,
    /// BLAS kernel override (registry id or alias); `None` uses the
    /// platform's `default_lib`. Both resolve against the inventory's
    /// kernel registry, so custom `[[kernel]]` sections reach HPL.
    pub lib: Option<String>,
    /// Fabric override (registry id or alias); `None` uses the
    /// inventory's machine fabric.
    pub fabric: Option<String>,
}

impl Workload for HplWorkload {
    fn name(&self) -> &str {
        &self.name
    }

    fn partition(&self) -> &str {
        &self.partition
    }

    fn nodes(&self) -> usize {
        self.nodes
    }

    fn estimate(&self, inv: &Inventory) -> Result<JobEstimate, CimoneError> {
        let p = platform_of(inv, &self.platform)?;
        // the machine's resolved fabric, unless the job names its own
        let fabric = match &self.fabric {
            Some(id) => inv.fabrics.get(id)?,
            None => Arc::clone(&inv.fabric),
        };
        // resolve the kernel against the inventory's registry (typed
        // UnknownKernel; custom [[kernel]] definitions are in scope)
        let lib = match &self.lib {
            Some(id) => inv.kernels.get(id)?,
            None => inv.kernels.get(&p.default_lib)?,
        };
        let cfg = ClusterConfig::with_lib_fabric(
            Arc::clone(p),
            self.cluster_nodes,
            self.cores_per_node,
            lib,
            (*fabric).clone(),
        );
        cfg.validate()?; // a cluster wider than the switch is typed here
        // key on the RESOLVED inputs the projection reads: the scheduler
        // allocation (`self.nodes`) never enters the estimate, so
        // scenarios differing only in allocation width share one entry
        let mut h = ContentHasher::new();
        h.write_str("estimate-hpl/v1");
        p.feed_content(&mut h);
        cfg.lib.feed_content(&mut h);
        cfg.fabric.feed_content(&mut h);
        h.write_usize(cfg.nodes).write_usize(cfg.cores_per_node);
        h.write_usize(cfg.n).write_usize(cfg.nb);
        let p = Arc::clone(p);
        Ok(ESTIMATE_CACHE.get_or_insert_with(h.finish(), move || {
            let proj = project(&cfg);
            let runtime_s = proj.t_comp + proj.t_comm;
            let active = cfg.cores_per_node.min(p.desc.total_cores());
            let avg_node_w = p.power.node_power(active);
            JobEstimate {
                runtime_s,
                metric: "gflops",
                value: proj.gflops,
                headline: proj.gflops,
                avg_node_w,
                // energy follows the *modeled* cluster (`cluster_nodes`,
                // the same node count the GFLOP/s projection uses), not
                // the scheduler allocation, so energy and efficiency stay
                // consistent when the two differ
                energy_j: avg_node_w * cfg.nodes as f64 * runtime_s,
            }
        }))
    }
}

/// Sparse matrix-vector product (the HPCG-style memory-bound workload):
/// CSR SpMV projected through the DDR stream model and cache hierarchy.
/// The headline is GF/s, but the governing quantity is effective DDR
/// bandwidth — which [`predict_spmv`] keeps at or below the platform's
/// STREAM triad rate by construction.
#[derive(Debug, Clone)]
pub struct SparseSpmvWorkload {
    pub name: String,
    pub partition: String,
    pub nodes: usize,
    /// Registry id (or alias) of the platform supplying the memory model.
    pub platform: String,
    pub threads: usize,
    /// CSR problem shape (rows, nnz/row, index width).
    pub shape: SparseShape,
}

impl SparseSpmvWorkload {
    fn shape_err(&self, reason: impl Into<String>) -> CimoneError {
        CimoneError::SparseShape { job: self.name.clone(), reason: reason.into() }
    }
}

impl Workload for SparseSpmvWorkload {
    fn name(&self) -> &str {
        &self.name
    }

    fn partition(&self) -> &str {
        &self.partition
    }

    fn nodes(&self) -> usize {
        self.nodes
    }

    fn estimate(&self, inv: &Inventory) -> Result<JobEstimate, CimoneError> {
        let p = platform_of(inv, &self.platform)?;
        // degenerate shapes are typed errors BEFORE any projection math
        // runs — no NaN can reach the report — and errors never cache
        self.shape.check().map_err(|reason| self.shape_err(reason))?;
        if self.threads == 0 {
            return Err(self.shape_err("threads must be >= 1"));
        }
        let mut h = ContentHasher::new();
        h.write_str("estimate-spmv/v1");
        p.feed_content(&mut h);
        self.shape.feed_content(&mut h);
        h.write_usize(self.threads).write_usize(self.nodes);
        let p = Arc::clone(p);
        let threads = self.threads;
        let nodes = self.nodes;
        let shape = self.shape;
        Ok(ESTIMATE_CACHE.get_or_insert_with(h.finish(), move || {
            let proj = predict_spmv(&p.desc, threads, shape)
                .expect("shape and threads validated above; platform bandwidth is positive");
            let runtime_s = (SPMV_JOB_ITERS * proj.time_s).max(1.0);
            let active = threads.min(p.desc.total_cores());
            let avg_node_w = p.power.node_power(active);
            JobEstimate {
                runtime_s,
                metric: "gflops",
                value: proj.gflops,
                headline: proj.gflops,
                avg_node_w,
                energy_j: avg_node_w * nodes as f64 * runtime_s,
            }
        }))
    }
}

/// HPL-MxP (mixed-precision LU + iterative refinement): the same
/// cluster projection as [`HplWorkload`], run on a SEW=32 twin of the
/// platform's BLAS kernel — double the elements per register group at
/// an identical schedule — then taxed with the refinement overhead.
/// Scalar (VLEN=0) kernels have no FP32 vector path, so an MxP job on
/// such a platform is a typed [`CimoneError::InvalidKernel`].
#[derive(Debug, Clone)]
pub struct HplMxpWorkload {
    pub name: String,
    pub partition: String,
    /// Nodes allocated from the scheduler partition.
    pub nodes: usize,
    /// Registry id (or alias) of the platform supplying the node model.
    pub platform: String,
    /// Nodes in the cluster-projection model (usually == `nodes`).
    pub cluster_nodes: usize,
    pub cores_per_node: usize,
    /// BLAS kernel override (registry id or alias); `None` uses the
    /// platform's `default_lib`. The resolved kernel is rebuilt at
    /// SEW=32 with a doubled MR tile before projection.
    pub lib: Option<String>,
    /// Fabric override (registry id or alias); `None` uses the
    /// inventory's machine fabric.
    pub fabric: Option<String>,
}

impl Workload for HplMxpWorkload {
    fn name(&self) -> &str {
        &self.name
    }

    fn partition(&self) -> &str {
        &self.partition
    }

    fn nodes(&self) -> usize {
        self.nodes
    }

    fn estimate(&self, inv: &Inventory) -> Result<JobEstimate, CimoneError> {
        let p = platform_of(inv, &self.platform)?;
        let fabric = match &self.fabric {
            Some(id) => inv.fabrics.get(id)?,
            None => Arc::clone(&inv.fabric),
        };
        let base = match &self.lib {
            Some(id) => inv.kernels.get(id)?,
            None => inv.kernels.get(&p.default_lib)?,
        };
        // the SEW=32 twin: same schedule family and register budget
        // (MR doubles exactly as the elements-per-group do)
        let mut mxp = (*base).clone();
        mxp.id = format!("{}-mxp-e32", base.id);
        mxp.label = format!("{} (MxP, SEW=32)", base.label);
        mxp.aliases = Vec::new();
        mxp.sew = Sew::E32;
        mxp.mr *= 2;
        // scalar kernels (VLEN=0) reject SEW=32 here — typed, per job
        mxp.validate()?;
        let cfg = ClusterConfig::with_lib_fabric(
            Arc::clone(p),
            self.cluster_nodes,
            self.cores_per_node,
            Arc::new(mxp),
            (*fabric).clone(),
        );
        cfg.validate()?;
        let mut h = ContentHasher::new();
        h.write_str("estimate-hpl-mxp/v1");
        p.feed_content(&mut h);
        cfg.lib.feed_content(&mut h); // the E32 twin: sew feeds here
        cfg.fabric.feed_content(&mut h);
        h.write_usize(cfg.nodes).write_usize(cfg.cores_per_node);
        h.write_usize(cfg.n).write_usize(cfg.nb);
        let p = Arc::clone(p);
        Ok(ESTIMATE_CACHE.get_or_insert_with(h.finish(), move || {
            let proj = project(&cfg);
            // the FP32 solve, plus GMRES refinement back to FP64 accuracy
            let runtime_s = (proj.t_comp + proj.t_comm) * (1.0 + MXP_IR_OVERHEAD);
            let gflops = proj.gflops / (1.0 + MXP_IR_OVERHEAD);
            let active = cfg.cores_per_node.min(p.desc.total_cores());
            let avg_node_w = p.power.node_power(active);
            JobEstimate {
                runtime_s,
                metric: "gflops",
                value: gflops,
                headline: gflops,
                avg_node_w,
                energy_j: avg_node_w * cfg.nodes as f64 * runtime_s,
            }
        }))
    }
}

/// BLIS micro-kernel ablation on the dual-socket node (Fig 7 @ 128
/// cores): same HPL job shape, different micro-kernel.
#[derive(Debug, Clone)]
pub struct BlisAblationWorkload {
    pub name: String,
    pub partition: String,
    /// Registry id of the node platform (the paper uses `mcv2-dual`).
    pub platform: String,
    /// Kernel registry id (or alias) of the ablated micro-kernel.
    pub lib: String,
    pub cores: usize,
    /// Fixed simulated runtime (the ablation compares rates, not time).
    pub runtime_s: f64,
}

impl Workload for BlisAblationWorkload {
    fn name(&self) -> &str {
        &self.name
    }

    fn partition(&self) -> &str {
        &self.partition
    }

    fn nodes(&self) -> usize {
        1
    }

    fn estimate(&self, inv: &Inventory) -> Result<JobEstimate, CimoneError> {
        let p = platform_of(inv, &self.platform)?;
        let lib = inv.kernels.get(&self.lib)?;
        let mut h = ContentHasher::new();
        h.write_str("estimate-blis/v1");
        p.feed_content(&mut h);
        lib.feed_content(&mut h);
        h.write_usize(self.cores).write_f64(self.runtime_s);
        let p = Arc::clone(p);
        let cores = self.cores;
        let runtime_s = self.runtime_s;
        Ok(ESTIMATE_CACHE.get_or_insert_with(h.finish(), move || {
            let gf = PerfModel::new(p.as_ref(), lib).node_gflops(cores);
            let active = cores.min(p.desc.total_cores());
            let avg_node_w = p.power.node_power(active);
            JobEstimate {
                runtime_s,
                metric: "gflops",
                value: gf,
                headline: gf,
                avg_node_w,
                energy_j: avg_node_w * runtime_s,
            }
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::monte_cimone_v2;

    #[test]
    fn stream_workload_estimates_positive_bandwidth() {
        let inv = monte_cimone_v2();
        let w = StreamWorkload {
            name: "stream-mcv2-1s".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-pioneer".into(),
            threads: 64,
        };
        let est = w.estimate(&inv).unwrap();
        assert!(est.value > 1e9, "{}", est.value);
        assert!(est.runtime_s >= 1.0);
        assert_eq!(est.metric, "bandwidth");
        // power/energy are populated from the platform's power model
        assert!(est.avg_node_w > 60.0, "{}", est.avg_node_w);
        assert!((est.energy_j - est.avg_node_w * est.runtime_s).abs() < 1e-9);
    }

    #[test]
    fn hpl_workload_matches_direct_projection() {
        let inv = monte_cimone_v2();
        let w = HplWorkload {
            name: "hpl-mcv2-1s".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-pioneer".into(),
            cluster_nodes: 1,
            cores_per_node: 64,
            lib: None,
            fabric: None,
        };
        let est = w.estimate(&inv).unwrap();
        let direct = project(&ClusterConfig::hpl_default(
            crate::arch::platform::mcv2_pioneer(),
            1,
            64,
        ));
        assert!((est.value - direct.gflops).abs() < 1e-9);
    }

    #[test]
    fn workloads_resolve_platform_by_alias_not_index() {
        // an inventory where the dual-socket node is NOT at index 11 and
        // node ids no longer match vector positions
        let mut inv = monte_cimone_v2();
        inv.nodes.rotate_right(1);
        let w = BlisAblationWorkload {
            name: "hpl-blis-opt".into(),
            partition: "mcv2".into(),
            platform: "sg2042-dual".into(), // alias of mcv2-dual
            lib: "blis-opt".into(), // kernel aliases resolve too
            cores: 128,
            runtime_s: 3600.0,
        };
        let est = w.estimate(&inv).unwrap();
        assert!(est.value > 100.0, "{}", est.value);
    }

    #[test]
    fn missing_platform_is_a_typed_error() {
        let mut inv = monte_cimone_v2();
        inv.nodes.retain(|n| !n.platform.matches("mcv2-dual"));
        let w = BlisAblationWorkload {
            name: "x".into(),
            partition: "mcv2".into(),
            platform: "mcv2-dual".into(),
            lib: "blis-lmul1".into(),
            cores: 128,
            runtime_s: 3600.0,
        };
        assert!(matches!(w.estimate(&inv), Err(CimoneError::NoNodeOfPlatform(_))));
    }

    #[test]
    fn default_metric_recording_uses_dotted_name() {
        let inv = monte_cimone_v2();
        let w = StreamWorkload {
            name: "stream-mcv1".into(),
            partition: "mcv1".into(),
            nodes: 1,
            platform: "mcv1-u740".into(),
            threads: 4,
        };
        let est = w.estimate(&inv).unwrap();
        let mut mon = Monitor::new();
        w.metrics(&mut mon, 0.0, &est);
        assert_eq!(mon.latest("stream-mcv1.bandwidth"), Some(est.value));
        assert_eq!(mon.latest("stream-mcv1.power_w"), Some(est.avg_node_w));
        assert_eq!(mon.latest("stream-mcv1.energy_j"), Some(est.energy_j));
    }

    #[test]
    fn sg2044_workload_runs_on_a_next_gen_fleet() {
        use crate::arch::platform::PlatformRegistry;
        let inv =
            Inventory::from_fleet(&PlatformRegistry::builtin(), &[("sg2044", 2), ("mcv3", 1)])
                .unwrap();
        let w = HplWorkload {
            name: "hpl-sg2044".into(),
            partition: "sg2044".into(),
            nodes: 1,
            platform: "sg2044".into(),
            cluster_nodes: 1,
            cores_per_node: 64,
            lib: None,
            fabric: None,
        };
        let est = w.estimate(&inv).unwrap();
        assert!(est.value.is_finite() && est.value > 0.0);
        assert!(est.energy_j.is_finite() && est.energy_j > 0.0);
    }

    #[test]
    fn spmv_workload_estimates_bandwidth_bound_gflops() {
        let inv = monte_cimone_v2();
        let w = SparseSpmvWorkload {
            name: "spmv-mcv2".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-pioneer".into(),
            threads: 64,
            shape: SparseShape { rows: 1 << 20, nnz_per_row: 27, index_bytes: 4 },
        };
        let est = w.estimate(&inv).unwrap();
        assert_eq!(est.metric, "gflops");
        assert!(est.value > 0.1 && est.value.is_finite(), "{}", est.value);
        assert!(est.runtime_s >= 1.0);
        assert!(est.avg_node_w > 60.0, "{}", est.avg_node_w);
        // memory-bound: far below the platform's dense-HPL rate
        let hpl = HplWorkload {
            name: "hpl".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-pioneer".into(),
            cluster_nodes: 1,
            cores_per_node: 64,
            lib: None,
            fabric: None,
        }
        .estimate(&inv)
        .unwrap();
        assert!(est.value < 0.25 * hpl.value, "SpMV {} !<< HPL {}", est.value, hpl.value);
    }

    #[test]
    fn degenerate_spmv_shape_is_a_typed_error_not_a_nan() {
        let inv = monte_cimone_v2();
        let mk = |rows, nnz, idx, threads| SparseSpmvWorkload {
            name: "spmv-bad".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-pioneer".into(),
            threads,
            shape: SparseShape { rows, nnz_per_row: nnz, index_bytes: idx },
        };
        for w in [
            mk(0, 27, 4, 64),      // no rows
            mk(1 << 20, 0, 4, 64), // empty matrix: zero FLOPs
            mk(1 << 20, 27, 0, 64),
            mk(1 << 20, 27, 16, 64),
            mk(1 << 20, 27, 4, 0), // no threads
        ] {
            match w.estimate(&inv) {
                Err(CimoneError::SparseShape { job, reason }) => {
                    assert_eq!(job, "spmv-bad");
                    assert!(!reason.is_empty());
                }
                other => panic!("expected SparseShape, got {other:?}"),
            }
        }
    }

    #[test]
    fn hpl_mxp_beats_fp64_hpl_on_the_vector_node() {
        let inv = monte_cimone_v2();
        let hpl = HplWorkload {
            name: "hpl".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-pioneer".into(),
            cluster_nodes: 1,
            cores_per_node: 64,
            lib: None,
            fabric: None,
        }
        .estimate(&inv)
        .unwrap();
        let mxp = HplMxpWorkload {
            name: "hpl-mxp".into(),
            partition: "mcv2".into(),
            nodes: 1,
            platform: "mcv2-pioneer".into(),
            cluster_nodes: 1,
            cores_per_node: 64,
            lib: None,
            fabric: None,
        }
        .estimate(&inv)
        .unwrap();
        // SEW=32 doubles the per-core rate; refinement taxes ~6% back
        assert!(mxp.value > hpl.value, "MxP {} !> HPL {}", mxp.value, hpl.value);
        assert!(mxp.value < 2.5 * hpl.value, "MxP {} implausibly high", mxp.value);
        assert!(mxp.runtime_s.is_finite() && mxp.runtime_s > 0.0);
    }

    #[test]
    fn hpl_mxp_on_a_scalar_platform_is_a_typed_error() {
        // MCv1's U740 has no vector unit: its default lib is the scalar
        // fmadd.d kernel, which has no SEW=32 path — typed, per job
        let inv = monte_cimone_v2();
        let w = HplMxpWorkload {
            name: "hpl-mxp-mcv1".into(),
            partition: "mcv1".into(),
            nodes: 1,
            platform: "mcv1-u740".into(),
            cluster_nodes: 1,
            cores_per_node: 4,
            lib: None,
            fabric: None,
        };
        match w.estimate(&inv) {
            Err(CimoneError::InvalidKernel { reason, .. }) => {
                assert!(reason.contains("FP64-only"), "{reason}")
            }
            other => panic!("expected InvalidKernel, got {other:?}"),
        }
    }

    #[test]
    fn hpl_fabric_override_beats_the_machine_fabric() {
        let inv = monte_cimone_v2(); // machine fabric: gbe-flat
        let mk = |fabric: Option<&str>| HplWorkload {
            name: "hpl-2n".into(),
            partition: "mcv2".into(),
            nodes: 2,
            platform: "mcv2-pioneer".into(),
            cluster_nodes: 2,
            cores_per_node: 64,
            lib: None,
            fabric: fabric.map(str::to_string),
        };
        let on_gbe = mk(None).estimate(&inv).unwrap();
        let on_ten = mk(Some("ten-gbe-flat")).estimate(&inv).unwrap();
        assert!(
            on_ten.value > 1.1 * on_gbe.value,
            "10 GbE {:.1} !>> 1 GbE {:.1}",
            on_ten.value,
            on_gbe.value
        );
        // unknown override: typed at estimation time
        assert!(matches!(
            mk(Some("infiniband")).estimate(&inv),
            Err(CimoneError::UnknownFabric { .. })
        ));
        // a modeled cluster wider than the switch: typed, not a panic
        let mut w = mk(Some("gbe-flat"));
        w.cluster_nodes = 17;
        assert!(matches!(w.estimate(&inv), Err(CimoneError::FabricTooSmall { .. })));
    }
}
