//! The [`Workload`] trait: one schedulable benchmark of a campaign.
//!
//! A workload knows its job identity (`name`, `partition`, `nodes`), how
//! to *estimate* itself against a concrete [`Inventory`] (simulated
//! runtime + the metric it produces), and how to record its metrics into
//! the ExaMon-like [`Monitor`]. The campaign engine
//! ([`super::driver::run_campaign_spec`]) estimates workloads in
//! parallel, submits them to the SLURM-like scheduler in spec order, and
//! drains the partitions concurrently — so adding a new experiment type
//! to the fleet means implementing this trait, not editing the driver.

use crate::arch::soc::NodeKind;
use crate::blas::perf::PerfModel;
use crate::cluster::{Inventory, Monitor};
use crate::error::CimoneError;
use crate::hpl::model::{project, ClusterConfig};
use crate::mem::stream_model::predict_node_bandwidth;
use crate::ukernel::UkernelId;

/// Bytes one simulated STREAM job moves: 10 iterations x 3 arrays x
/// ~128 MB, matching the paper-scale working set.
const STREAM_JOB_BYTES: f64 = 10.0 * 3.0 * 128e6;

/// What a workload contributes to the campaign once estimated on a fleet.
#[derive(Debug, Clone)]
pub struct JobEstimate {
    /// Simulated wall-clock the job occupies its nodes for.
    pub runtime_s: f64,
    /// Metric suffix recorded as `<job-name>.<metric>` (ExaMon dotted).
    pub metric: &'static str,
    /// Raw metric value (bytes/s for STREAM, GFLOP/s for HPL).
    pub value: f64,
    /// Headline value reported in `CampaignReport::jobs` (GB/s, GFLOP/s).
    pub headline: f64,
}

/// One schedulable benchmark workload of a campaign.
pub trait Workload: Send + Sync {
    /// Job name, unique within a campaign (e.g. `hpl-mcv2-2n`).
    fn name(&self) -> &str;

    /// SLURM partition the job is submitted to.
    fn partition(&self) -> &str;

    /// Number of nodes the job allocates.
    fn nodes(&self) -> usize;

    /// Model this workload against a concrete fleet.
    fn estimate(&self, inv: &Inventory) -> Result<JobEstimate, CimoneError>;

    /// Record the workload's metrics at simulated time `t`.
    fn metrics(&self, mon: &mut Monitor, t: f64, est: &JobEstimate) {
        mon.record(&format!("{}.{}", self.name(), est.metric), t, est.value);
    }
}

/// Find the descriptor of the first inventory node of `kind`, so
/// estimates survive reordered or pruned fleets (no fixed node index).
fn desc_of_kind<'a>(
    inv: &'a Inventory,
    kind: NodeKind,
) -> Result<&'a crate::arch::soc::SocDescriptor, CimoneError> {
    inv.nodes
        .iter()
        .find(|n| n.desc.kind == kind)
        .map(|n| &n.desc)
        .ok_or(CimoneError::NoNodeOfKind(kind.label()))
}

/// STREAM bandwidth on one node kind (a Fig 3 row).
#[derive(Debug, Clone)]
pub struct StreamWorkload {
    pub name: String,
    pub partition: String,
    pub nodes: usize,
    /// Which node kind supplies the memory-system model.
    pub kind: NodeKind,
    pub threads: usize,
}

impl Workload for StreamWorkload {
    fn name(&self) -> &str {
        &self.name
    }

    fn partition(&self) -> &str {
        &self.partition
    }

    fn nodes(&self) -> usize {
        self.nodes
    }

    fn estimate(&self, inv: &Inventory) -> Result<JobEstimate, CimoneError> {
        let desc = desc_of_kind(inv, self.kind)?;
        let bw = predict_node_bandwidth(desc, self.threads, true);
        let runtime_s = (STREAM_JOB_BYTES / bw).max(1.0);
        Ok(JobEstimate { runtime_s, metric: "bandwidth", value: bw, headline: bw / 1e9 })
    }
}

/// HPL on one node configuration (a Fig 5 bar).
#[derive(Debug, Clone)]
pub struct HplWorkload {
    pub name: String,
    pub partition: String,
    /// Nodes allocated from the scheduler partition.
    pub nodes: usize,
    /// Which node kind supplies the SoC descriptor.
    pub kind: NodeKind,
    /// Nodes in the HPL cluster-projection model (usually == `nodes`).
    pub cluster_nodes: usize,
    pub cores_per_node: usize,
    /// BLAS library override; `None` keeps the MCv2 default (OpenBLAS
    /// C920-optimized).
    pub lib: Option<UkernelId>,
}

impl Workload for HplWorkload {
    fn name(&self) -> &str {
        &self.name
    }

    fn partition(&self) -> &str {
        &self.partition
    }

    fn nodes(&self) -> usize {
        self.nodes
    }

    fn estimate(&self, inv: &Inventory) -> Result<JobEstimate, CimoneError> {
        let desc = desc_of_kind(inv, self.kind)?;
        let mut cfg =
            ClusterConfig::mcv2_default(desc.clone(), self.cluster_nodes, self.cores_per_node);
        if let Some(lib) = self.lib {
            cfg.lib = lib;
        }
        let p = project(&cfg);
        Ok(JobEstimate {
            runtime_s: p.t_comp + p.t_comm,
            metric: "gflops",
            value: p.gflops,
            headline: p.gflops,
        })
    }
}

/// BLIS micro-kernel ablation on the dual-socket node (Fig 7 @ 128
/// cores): same HPL job shape, different micro-kernel.
#[derive(Debug, Clone)]
pub struct BlisAblationWorkload {
    pub name: String,
    pub partition: String,
    pub lib: UkernelId,
    pub cores: usize,
    /// Fixed simulated runtime (the ablation compares rates, not time).
    pub runtime_s: f64,
}

impl Workload for BlisAblationWorkload {
    fn name(&self) -> &str {
        &self.name
    }

    fn partition(&self) -> &str {
        &self.partition
    }

    fn nodes(&self) -> usize {
        1
    }

    fn estimate(&self, inv: &Inventory) -> Result<JobEstimate, CimoneError> {
        // look the dual-socket node up by kind, not by hardcoded index,
        // so the ablation survives inventory changes
        let desc = desc_of_kind(inv, NodeKind::Mcv2DualSocket)?;
        let gf = PerfModel::new(desc, self.lib).node_gflops(self.cores);
        Ok(JobEstimate { runtime_s: self.runtime_s, metric: "gflops", value: gf, headline: gf })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::monte_cimone_v2;

    #[test]
    fn stream_workload_estimates_positive_bandwidth() {
        let inv = monte_cimone_v2();
        let w = StreamWorkload {
            name: "stream-mcv2-1s".into(),
            partition: "mcv2".into(),
            nodes: 1,
            kind: NodeKind::Mcv2Pioneer,
            threads: 64,
        };
        let est = w.estimate(&inv).unwrap();
        assert!(est.value > 1e9, "{}", est.value);
        assert!(est.runtime_s >= 1.0);
        assert_eq!(est.metric, "bandwidth");
    }

    #[test]
    fn hpl_workload_matches_direct_projection() {
        let inv = monte_cimone_v2();
        let w = HplWorkload {
            name: "hpl-mcv2-1s".into(),
            partition: "mcv2".into(),
            nodes: 1,
            kind: NodeKind::Mcv2Pioneer,
            cluster_nodes: 1,
            cores_per_node: 64,
            lib: None,
        };
        let est = w.estimate(&inv).unwrap();
        let direct = project(&ClusterConfig::mcv2_default(
            crate::arch::presets::sg2042(),
            1,
            64,
        ));
        assert!((est.value - direct.gflops).abs() < 1e-9);
    }

    #[test]
    fn blis_ablation_uses_kind_lookup_not_index() {
        // an inventory where the dual-socket node is NOT at index 11 and
        // node ids no longer match vector positions
        let mut inv = monte_cimone_v2();
        inv.nodes.rotate_right(1);
        let w = BlisAblationWorkload {
            name: "hpl-blis-opt".into(),
            partition: "mcv2".into(),
            lib: UkernelId::BlisLmul4,
            cores: 128,
            runtime_s: 3600.0,
        };
        let est = w.estimate(&inv).unwrap();
        assert!(est.value > 100.0, "{}", est.value);
    }

    #[test]
    fn missing_node_kind_is_a_typed_error() {
        let mut inv = monte_cimone_v2();
        inv.nodes.retain(|n| n.desc.kind != NodeKind::Mcv2DualSocket);
        let w = BlisAblationWorkload {
            name: "x".into(),
            partition: "mcv2".into(),
            lib: UkernelId::BlisLmul1,
            cores: 128,
            runtime_s: 3600.0,
        };
        assert!(matches!(w.estimate(&inv), Err(CimoneError::NoNodeOfKind(_))));
    }

    #[test]
    fn default_metric_recording_uses_dotted_name() {
        let inv = monte_cimone_v2();
        let w = StreamWorkload {
            name: "stream-mcv1".into(),
            partition: "mcv1".into(),
            nodes: 1,
            kind: NodeKind::Mcv1U740,
            threads: 4,
        };
        let est = w.estimate(&inv).unwrap();
        let mut mon = Monitor::new();
        w.metrics(&mut mon, 0.0, &est);
        assert_eq!(mon.latest("stream-mcv1.bandwidth"), Some(est.value));
    }
}
