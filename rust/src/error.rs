//! Typed error surface for the experiment-execution path.
//!
//! Every layer the campaign engine composes — scheduler, cluster,
//! BLAS/micro-kernel execution, HPL, STREAM, CLI — reports failures as a
//! [`CimoneError`] variant instead of a bare `String`, so callers can
//! match on the failure mode (unknown partition vs. singular matrix vs.
//! spec typo) and the crate-wide [`crate::Result`] (`anyhow`) absorbs
//! them with full context via the standard `?` conversion.

use thiserror::Error;

/// All failure modes of the campaign/scheduler/benchmark layers.
#[derive(Debug, Error)]
pub enum CimoneError {
    /// A job was submitted to a partition the scheduler does not know.
    #[error("no such partition `{0}`")]
    UnknownPartition(String),

    /// A job requested more nodes than its partition can ever provide.
    #[error("job `{job}` wants {want} nodes, partition `{partition}` has {have}")]
    PartitionTooSmall { job: String, partition: String, want: usize, have: usize },

    /// A platform id was looked up in a registry that does not know it.
    #[error("unknown platform `{id}` (registered: {known})")]
    UnknownPlatform { id: String, known: String },

    /// A platform (or one of its aliases) was registered twice.
    #[error("platform name `{0}` is already registered (id or alias clash)")]
    DuplicatePlatform(String),

    /// A platform descriptor violates its own invariants (zero frequency,
    /// empty socket list, incoherent core counts, ...).
    #[error("invalid platform `{id}`: {reason}")]
    InvalidPlatform { id: String, reason: String },

    /// A workload asked for a platform absent from the inventory.
    #[error("no node of platform `{0}` in the inventory")]
    NoNodeOfPlatform(String),

    /// A kernel id was looked up in a registry that does not know it.
    #[error("unknown kernel `{name}` (registered: {known})")]
    UnknownKernel { name: String, known: String },

    /// A kernel (or one of its aliases) was registered twice.
    #[error("kernel name `{0}` is already registered (id or alias clash)")]
    DuplicateKernel(String),

    /// A kernel descriptor violates its own invariants (unsupported
    /// VLEN, register file overflow, zero tile, ...) — caught at load
    /// time, like `FabricTooSmall`, so generators never see it.
    #[error("invalid kernel `{id}`: {reason}")]
    InvalidKernel { id: String, reason: String },

    /// A fabric id was looked up in a registry that does not know it.
    #[error("unknown fabric `{id}` (registered: {known})")]
    UnknownFabric { id: String, known: String },

    /// A fabric (or one of its aliases) was registered twice.
    #[error("fabric name `{0}` is already registered (id or alias clash)")]
    DuplicateFabric(String),

    /// A fabric violates its own invariants (zero bandwidth, no ports,
    /// out-of-range backplane factor, ...).
    #[error("invalid fabric `{id}`: {reason}")]
    InvalidFabric { id: String, reason: String },

    /// A fleet or HPL cluster is wider than its fabric's switch — caught
    /// at campaign load time so the flow model never indexes past its
    /// port arrays.
    #[error("fabric `{fabric}` has {ports} ports but the cluster needs {nodes}")]
    FabricTooSmall { fabric: String, ports: usize, nodes: usize },

    /// A job was submitted with a non-finite or non-positive runtime
    /// (would hang or panic the simulated-time event loop).
    #[error("job `{job}` has invalid runtime {runtime_s}s (must be finite and > 0)")]
    InvalidRuntime { job: String, runtime_s: f64 },

    /// A job was submitted with an arrival time in the past or not a
    /// finite number (the event queue only moves forward).
    #[error("job `{job}` has invalid arrival time {arrival_s}s (must be finite and >= now)")]
    InvalidArrival { job: String, arrival_s: f64 },

    /// LU factorization requires a square system.
    #[error("lu_blocked requires a square matrix, got {rows}x{cols}")]
    NonSquareMatrix { rows: usize, cols: usize },

    /// Exact zero pivot column during factorization.
    #[error("singular at column {0}")]
    SingularMatrix(usize),

    /// GEMM operand shapes are inconsistent.
    #[error("gemm shape mismatch: C{cm}x{cn} A{am}x{ak} B{bk}x{bn}")]
    GemmShape { cm: usize, cn: usize, am: usize, ak: usize, bk: usize, bn: usize },

    /// The functional vector machine rejected or faulted on a program.
    #[error("vector machine: {0}")]
    Machine(String),

    /// A [`crate::isa::Program`] violates an architectural invariant
    /// (register-group misalignment, register-file overflow) — caught by
    /// `Program::validate_register_groups` before any instruction runs.
    #[error("invalid program at inst {inst}: {reason}")]
    InvalidProgram { inst: usize, reason: String },

    /// An assembly listing failed to assemble. Carries the full
    /// source-located error (file/line/col plus a caret excerpt) from
    /// [`crate::isa::assembler`].
    #[error("{0}")]
    Asm(#[from] crate::isa::assembler::AsmError),

    /// A sparse workload was given a shape the bandwidth model cannot
    /// project (zero rows, zero nnz/row, or a nonsense index width) —
    /// caught before any divide so no NaN reaches the report.
    #[error("job `{job}` has degenerate sparse shape: {reason}")]
    SparseShape { job: String, reason: String },

    /// A STREAM sweep was asked for a projection at a thread count it
    /// never ran.
    #[error("kernel `{kernel}` has no projection at {threads} threads (available: {available})")]
    NoProjection { kernel: String, threads: usize, available: String },

    /// stream.c-style end-of-run validation failed.
    #[error("STREAM validation failed at {index}: a={a} b={b} c={c}")]
    StreamValidation { index: usize, a: f64, b: f64, c: f64 },

    /// HPL's residual acceptance criterion failed.
    #[error("HPL residual {residual:.3e} exceeds threshold {threshold}")]
    ResidualCheck { residual: f64, threshold: f64 },

    /// The campaign's pre-flight real-numerics validation solve failed.
    /// (`cause` is folded into the message rather than exposed as a
    /// thiserror source, so chain-printing doesn't repeat it.)
    #[error("validation HPL (n={n}): {cause}")]
    ValidationRun { n: usize, cause: Box<CimoneError> },

    /// A campaign spec (file or `util::config` text) is malformed.
    #[error("campaign spec: {0}")]
    Spec(String),

    /// Command-line usage error.
    #[error("{0}")]
    Cli(String),

    /// PJRT runtime / artifact failure (wrapped from `anyhow`).
    #[error("runtime: {0}")]
    Runtime(String),
}

impl From<anyhow::Error> for CimoneError {
    fn from(e: anyhow::Error) -> Self {
        CimoneError::Runtime(format!("{e:#}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_render_with_context() {
        let e = CimoneError::UnknownPartition("gpu".into());
        assert_eq!(e.to_string(), "no such partition `gpu`");
        let e = CimoneError::PartitionTooSmall {
            job: "hpl".into(),
            partition: "mcv2".into(),
            want: 5,
            have: 4,
        };
        assert!(e.to_string().contains("wants 5 nodes"));
    }

    #[test]
    fn converts_into_anyhow_and_back() {
        let e: anyhow::Error = CimoneError::SingularMatrix(3).into();
        assert!(e.to_string().contains("column 3"));
        let back: CimoneError = e.into();
        assert!(matches!(back, CimoneError::Runtime(_)));
    }

    #[test]
    fn question_mark_into_crate_result() {
        fn typed() -> Result<(), CimoneError> {
            Err(CimoneError::NoNodeOfPlatform("mcv2-dual".into()))
        }
        fn inner() -> crate::Result<()> {
            typed()?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
