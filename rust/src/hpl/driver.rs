//! Runnable HPL benchmark (real numerics at reduced N).
//!
//! This is the end-to-end driver: generate the HPL random system, factor
//! it through a chosen backend (simulated-BLAS micro-kernels, the PJRT
//! artifacts, or native), solve, validate with HPL's residual criterion,
//! and report wall-clock GFLOP/s of this host plus the projected GFLOP/s
//! of the modelled RISC-V target.

use std::time::Instant;

use super::lu::{lu_blocked, lu_solve, native_update};
use super::validate::{hpl_residual, HPL_THRESHOLD};
use crate::blas::gemm::gemm_acc;
use crate::blas::library::BlasLibrary;
use crate::error::CimoneError;
use crate::util::stats::hpl_flops;
use crate::util::{Matrix, Rng};

/// Which engine performs the trailing updates.
#[derive(Debug, Clone, PartialEq)]
pub enum Backend {
    /// Host-native triple loop (fast path; used by the perf benches).
    Native,
    /// The functional-vector-machine BLAS library simulation (slow but
    /// exercises the micro-kernel programs end to end) through one
    /// registered kernel descriptor.
    SimulatedBlas(std::sync::Arc<crate::ukernel::KernelDescriptor>),
}

/// One HPL run configuration.
#[derive(Debug, Clone)]
pub struct HplConfig {
    pub n: usize,
    pub nb: usize,
    pub seed: u64,
    pub backend: Backend,
}

impl Default for HplConfig {
    fn default() -> Self {
        HplConfig { n: 256, nb: 32, seed: 42, backend: Backend::Native }
    }
}

/// Result of a real run.
#[derive(Debug, Clone)]
pub struct HplResult {
    pub n: usize,
    pub seconds: f64,
    pub host_gflops: f64,
    pub residual: f64,
    pub passed: bool,
    pub dgemm_fraction: f64,
}

/// Execute the benchmark.
pub fn run(cfg: &HplConfig) -> Result<HplResult, CimoneError> {
    let a = Matrix::random_hpl(cfg.n, cfg.n, cfg.seed);
    let mut rng = Rng::new(cfg.seed ^ 0xB00B5);
    let b: Vec<f64> = (0..cfg.n).map(|_| rng.hpl_entry()).collect();

    let t0 = Instant::now();
    let factors = match &cfg.backend {
        Backend::Native => lu_blocked(&a, cfg.nb, &mut native_update)?,
        Backend::SimulatedBlas(kernel) => {
            let socket = crate::arch::presets::sg2042().sockets[0].clone();
            let lib = BlasLibrary::for_socket(std::sync::Arc::clone(kernel), &socket);
            let mut update = |c: &mut Matrix, l: &Matrix, u: &Matrix| {
                // C -= L*U via the library (negate L like native_update)
                let mut neg = l.clone();
                for v in neg.as_mut_slice() {
                    *v = -*v;
                }
                gemm_acc(&lib, c, &neg, u)
            };
            lu_blocked(&a, cfg.nb, &mut update)?
        }
    };
    let x = lu_solve(&factors, &b);
    let seconds = t0.elapsed().as_secs_f64();

    let residual = hpl_residual(&a, &x, &b);
    Ok(HplResult {
        n: cfg.n,
        seconds,
        host_gflops: hpl_flops(cfg.n) / seconds / 1e9,
        residual,
        passed: residual < HPL_THRESHOLD,
        dgemm_fraction: factors.trace.dgemm_fraction(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ukernel::KernelRegistry;

    #[test]
    fn native_run_passes_validation() {
        let r = run(&HplConfig { n: 128, nb: 32, seed: 1, backend: Backend::Native }).unwrap();
        assert!(r.passed, "residual {}", r.residual);
        assert!(r.host_gflops > 0.0);
        assert!(r.dgemm_fraction > 0.6);
    }

    #[test]
    fn simulated_blas_backends_pass_validation() {
        let reg = KernelRegistry::builtin();
        for id in ["blis-lmul4", "openblas-c920", "blis-rvv1-lmul2"] {
            let r = run(&HplConfig {
                n: 64,
                nb: 16,
                seed: 2,
                backend: Backend::SimulatedBlas(reg.get(id).unwrap()),
            })
            .unwrap();
            assert!(r.passed, "{id} residual {}", r.residual);
        }
    }

    #[test]
    fn backends_agree_numerically() {
        // same seed => same system; all backends must produce passing and
        // near-identical residual magnitudes
        let native =
            run(&HplConfig { n: 64, nb: 16, seed: 3, backend: Backend::Native }).unwrap();
        let sim = run(&HplConfig {
            n: 64,
            nb: 16,
            seed: 3,
            backend: Backend::SimulatedBlas(KernelRegistry::builtin().get("blis-lmul1").unwrap()),
        })
        .unwrap();
        assert!(native.passed && sim.passed);
        // both tiny; ratio bounded (different summation orders)
        assert!(sim.residual < 16.0 && native.residual < 16.0);
    }

    #[test]
    fn default_config_sane() {
        let c = HplConfig::default();
        assert_eq!(c.n % c.nb, 0);
    }
}
