//! Right-looking blocked LU factorization with partial pivoting — the
//! algorithm inside HPL, structured exactly like the reference: panel
//! factorization, row-swap, triangular solve of the row slab (DTRSM),
//! trailing-submatrix DGEMM update.
//!
//! The trailing update is pluggable so the same factorization can run
//! (a) through the micro-kernel-simulated BLAS libraries, (b) through the
//! PJRT artifacts (`runtime::gemm`), or (c) natively — all three must and
//! do agree, which ties every layer of the stack together.

use crate::blas::trace::{BlasCall, CallTrace};
use crate::error::CimoneError;
use crate::util::Matrix;

/// The pluggable trailing-update: C -= A * B.
pub type TrailingUpdate<'a> =
    dyn FnMut(&mut Matrix, &Matrix, &Matrix) -> Result<(), CimoneError> + 'a;

/// Outcome of a factorization.
#[derive(Debug, Clone)]
pub struct LuFactors {
    /// In-place LU factors (L unit-lower below diagonal, U upper).
    pub lu: Matrix,
    /// Row permutation: row i of the factored matrix is row `perm[i]` of A.
    pub perm: Vec<usize>,
    /// BLAS call trace (for the cache simulator and the perf model).
    pub trace: CallTrace,
}

/// Native trailing update (used when no BLAS model/runtime is supplied).
pub fn native_update(c: &mut Matrix, a: &Matrix, b: &Matrix) -> Result<(), CimoneError> {
    Matrix::gemm_sub(c, a, b);
    Ok(())
}

/// Blocked LU with partial pivoting, block size `nb`.
pub fn lu_blocked(
    a: &Matrix,
    nb: usize,
    update: &mut TrailingUpdate<'_>,
) -> Result<LuFactors, CimoneError> {
    let n = a.rows();
    if a.cols() != n {
        return Err(CimoneError::NonSquareMatrix { rows: n, cols: a.cols() });
    }
    let mut lu = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut trace = CallTrace::new();

    let mut k0 = 0;
    while k0 < n {
        let kb = nb.min(n - k0);

        // --- panel factorization (unblocked, with partial pivoting) ---
        for k in k0..k0 + kb {
            // pivot search in column k, rows k..n
            let mut piv = k;
            let mut max = lu[(k, k)].abs();
            for i in k + 1..n {
                let v = lu[(i, k)].abs();
                if v > max {
                    max = v;
                    piv = i;
                }
            }
            if max == 0.0 {
                return Err(CimoneError::SingularMatrix(k));
            }
            if piv != k {
                lu.swap_rows(piv, k, 0, n);
                perm.swap(piv, k);
            }
            // scale multipliers and rank-1 update within the panel
            let dkk = lu[(k, k)];
            for i in k + 1..n {
                lu[(i, k)] /= dkk;
            }
            for j in k + 1..k0 + kb {
                let ukj = lu[(k, j)];
                if ukj != 0.0 {
                    for i in k + 1..n {
                        let l = lu[(i, k)];
                        lu[(i, j)] -= l * ukj;
                    }
                }
            }
            trace.record(BlasCall::PanelUpdate { rows: n - k - 1, cols: k0 + kb - k - 1 });
        }

        let rest = n - (k0 + kb);
        if rest > 0 {
            // --- DTRSM: solve L11 * U12 = A12 for the row slab ---
            for j in k0 + kb..n {
                for k in k0..k0 + kb {
                    let ukj = lu[(k, j)];
                    if ukj != 0.0 {
                        for i in k + 1..k0 + kb {
                            let l = lu[(i, k)];
                            lu[(i, j)] -= l * ukj;
                        }
                    }
                }
            }
            trace.record(BlasCall::Dtrsm { nb: kb, n: rest });

            // --- DGEMM trailing update: A22 -= L21 * U12 ---
            let l21 = lu.block(k0 + kb, k0, rest, kb);
            let u12 = lu.block(k0, k0 + kb, kb, rest);
            let mut a22 = lu.block(k0 + kb, k0 + kb, rest, rest);
            update(&mut a22, &l21, &u12)?;
            lu.set_block(k0 + kb, k0 + kb, &a22);
            trace.record(BlasCall::Dgemm { m: rest, n: rest, k: kb });
        }
        k0 += kb;
    }
    Ok(LuFactors { lu, perm, trace })
}

/// Solve A x = b given the factors (forward + backward substitution).
pub fn lu_solve(f: &LuFactors, b: &[f64]) -> Vec<f64> {
    let n = f.lu.rows();
    assert_eq!(b.len(), n);
    // apply permutation
    let mut y: Vec<f64> = (0..n).map(|i| b[f.perm[i]]).collect();
    // Ly = Pb (L unit lower)
    for i in 0..n {
        let mut s = y[i];
        for j in 0..i {
            s -= f.lu[(i, j)] * y[j];
        }
        y[i] = s;
    }
    // Ux = y
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in i + 1..n {
            s -= f.lu[(i, j)] * y[j];
        }
        y[i] = s / f.lu[(i, i)];
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpl::validate::hpl_residual;
    use crate::util::prop;
    use crate::util::Rng;

    fn factor_native(a: &Matrix, nb: usize) -> LuFactors {
        lu_blocked(a, nb, &mut native_update).unwrap()
    }

    #[test]
    fn identity_factors_trivially() {
        let f = factor_native(&Matrix::eye(8), 4);
        assert_eq!(f.perm, (0..8).collect::<Vec<_>>());
        assert!(f.lu.allclose(&Matrix::eye(8), 0.0, 0.0));
    }

    #[test]
    fn solves_known_system() {
        // A = [[2,1],[1,3]], b = [3,5] -> x = [0.8, 1.4]
        let a = Matrix::from_rows(2, 2, &[2.0, 1.0, 1.0, 3.0]);
        let f = factor_native(&a, 1);
        let x = lu_solve(&f, &[3.0, 5.0]);
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        let a = Matrix::from_rows(2, 2, &[0.0, 1.0, 1.0, 0.0]);
        let f = factor_native(&a, 2);
        let x = lu_solve(&f, &[2.0, 3.0]);
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn singular_matrix_reports_error() {
        let a = Matrix::from_rows(2, 2, &[1.0, 2.0, 2.0, 4.0]);
        assert!(lu_blocked(&a, 1, &mut native_update).is_err());
    }

    #[test]
    fn hpl_style_matrix_passes_residual_check() {
        let n = 96;
        let a = Matrix::random_hpl(n, n, 7);
        let mut rng = Rng::new(8);
        let b: Vec<f64> = (0..n).map(|_| rng.hpl_entry()).collect();
        let f = factor_native(&a, 32);
        let x = lu_solve(&f, &b);
        let r = hpl_residual(&a, &x, &b);
        assert!(r < 16.0, "HPL residual {r} (must be < 16)");
    }

    #[test]
    fn block_size_does_not_change_result() {
        let n = 40;
        let a = Matrix::random_dd(n, 3);
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let x1 = lu_solve(&factor_native(&a, 1), &b);
        let x2 = lu_solve(&factor_native(&a, 8), &b);
        let x3 = lu_solve(&factor_native(&a, 64), &b); // nb > n
        for i in 0..n {
            assert!((x1[i] - x2[i]).abs() < 1e-9, "nb=1 vs 8 at {i}");
            assert!((x1[i] - x3[i]).abs() < 1e-9, "nb=1 vs 64 at {i}");
        }
    }

    #[test]
    fn trace_is_dgemm_dominated() {
        // at N/nb = 8 panels the update already dominates; at HPL's real
        // N/nb (hundreds) the fraction approaches 1
        let a = Matrix::random_dd(256, 5);
        let f = factor_native(&a, 32);
        let frac = f.trace.dgemm_fraction();
        assert!(frac > 0.7, "dgemm fraction {frac:.2}");
        let small = factor_native(&Matrix::random_dd(64, 6), 32);
        assert!(small.trace.dgemm_fraction() < frac, "fraction must grow with N/nb");
    }

    #[test]
    fn property_random_dd_systems_solve() {
        prop::check(
            "blocked LU solves diagonally dominant systems",
            0x1517,
            10,
            |rng: &mut Rng, size: usize| {
                let n = 4 + (size % 40);
                (n, rng.next_u64(), 1 + (rng.below(3) as usize) * 7)
            },
            |&(n, seed, nb)| {
                let a = Matrix::random_dd(n, seed);
                let mut rng = Rng::new(seed ^ 0xF00D);
                let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let f = lu_blocked(&a, nb, &mut native_update).map_err(|e| e.to_string())?;
                let x = lu_solve(&f, &b);
                let y = a.matvec(&x);
                for i in 0..n {
                    if (y[i] - b[i]).abs() > 1e-8 * (1.0 + b[i].abs()) {
                        return Err(format!("residual at row {i}: {}", (y[i] - b[i]).abs()));
                    }
                }
                Ok(())
            },
        );
    }
}
