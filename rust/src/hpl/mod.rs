//! HPL — High-Performance Linpack, the paper's FP64 benchmark.
//!
//! Two faces, like the real benchmark:
//! - **numerics** ([`lu`], [`validate`]): a right-looking blocked LU with
//!   partial pivoting whose trailing updates can run through any BLAS
//!   library model or through the AOT'd PJRT artifacts, validated with
//!   HPL's own residual criterion;
//! - **performance** ([`model`]): GFLOP/s projection for node and cluster
//!   configurations, combining the per-node machine model
//!   ([`crate::blas::perf`]) with the interconnect cost model
//!   ([`crate::net`]) — the generator behind Figs 4, 5 and 7.

pub mod driver;
pub mod lu;
pub mod model;
pub mod validate;

pub use driver::{HplConfig, HplResult};
pub use lu::{lu_blocked, lu_solve};
pub use model::{cluster_hpl_gflops, ClusterConfig};
