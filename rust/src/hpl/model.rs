//! HPL cluster-performance projection — the generator behind Figs 4/5/7.
//!
//! Combines the calibrated node model ([`crate::blas::perf`]) with the
//! interconnect cost model ([`crate::net`]) using HPL's communication
//! structure: per panel, a panel broadcast + a row-slab exchange; per
//! column, a pivot-search allreduce.

use std::sync::Arc;

use crate::arch::platform::Platform;
use crate::blas::perf::PerfModel;
use crate::net::{Collectives, Link};
use crate::ukernel::UkernelId;
use crate::util::stats::hpl_flops;

/// A homogeneous cluster HPL run. The platform is shared (`Arc`) so
/// estimates cloned out of an inventory don't deep-copy descriptors.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub platform: Arc<Platform>,
    pub nodes: usize,
    pub cores_per_node: usize,
    pub lib: UkernelId,
    /// HPL problem size. The paper never states theirs; EXPERIMENTS.md
    /// documents N = 57600, NB = 192 as the calibration point that
    /// reproduces Fig 5's scaling ratios.
    pub n: usize,
    pub nb: usize,
    pub link: Link,
}

impl ClusterConfig {
    /// The standard run shape: the platform's default BLAS library, the
    /// calibration problem size, and the paper's 1 GbE fabric. Accepts a
    /// `Platform` by value or an already-shared `Arc<Platform>`.
    pub fn hpl_default(
        platform: impl Into<Arc<Platform>>,
        nodes: usize,
        cores_per_node: usize,
    ) -> Self {
        let platform = platform.into();
        let lib = platform.default_lib;
        ClusterConfig { platform, nodes, cores_per_node, lib, n: 57_600, nb: 192, link: Link::gbe() }
    }
}

/// Breakdown of one projected run.
#[derive(Debug, Clone, Copy)]
pub struct HplProjection {
    pub gflops: f64,
    pub t_comp: f64,
    pub t_comm: f64,
    pub efficiency_vs_one_node: f64,
}

/// Project the HPL performance of a cluster configuration.
pub fn project(cfg: &ClusterConfig) -> HplProjection {
    let node_rate = PerfModel::new(&cfg.platform, cfg.lib).node_gflops(cfg.cores_per_node) * 1e9;
    let flops = hpl_flops(cfg.n);
    let p = cfg.nodes;
    let t_comp = flops / (p as f64 * node_rate);

    let t_comm = if p <= 1 {
        0.0
    } else {
        let coll = Collectives::new(cfg.link, p);
        let panels = cfg.n / cfg.nb;
        let mut t = 0.0;
        for pi in 0..panels {
            let rows = (cfg.n - pi * cfg.nb) as f64;
            let panel_bytes = rows * cfg.nb as f64 * 8.0;
            t += coll.bcast(panel_bytes); // L panel broadcast
            t += coll.exchange(panel_bytes); // U row-slab swap traffic
        }
        // pivot search: one tiny allreduce per column
        t += cfg.n as f64 * coll.allreduce(8.0);
        t
    };

    let total = t_comp + t_comm;
    let gflops = flops / total / 1e9;
    let one_node = flops / (flops / node_rate) / 1e9; // = node_rate/1e9
    HplProjection {
        gflops,
        t_comp,
        t_comm,
        efficiency_vs_one_node: gflops / (one_node * p as f64),
    }
}

/// Convenience: projected GFLOP/s.
pub fn cluster_hpl_gflops(cfg: &ClusterConfig) -> f64 {
    project(cfg).gflops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::platform::{mcv1_u740, mcv2_dual, mcv2_pioneer, mcv3, sg2044};

    fn mcv2_single() -> ClusterConfig {
        ClusterConfig::hpl_default(mcv2_pioneer(), 1, 64)
    }

    #[test]
    fn fig5_mcv2_single_socket_node() {
        let g = cluster_hpl_gflops(&mcv2_single());
        assert!((125.0..155.0).contains(&g), "{g:.1}");
    }

    #[test]
    fn fig5_two_nodes_only_133x() {
        // "increasing the number of parallel processes reduces the HPL
        // efficiency (only the 1.33x w.r.t single node performance)"
        let one = cluster_hpl_gflops(&mcv2_single());
        let two = cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64));
        let ratio = two / one;
        assert!((1.20..1.45).contains(&ratio), "2-node scaling {ratio:.2}");
    }

    #[test]
    fn fig5_dual_socket_beats_two_networked_nodes() {
        // the paper's architectural point: one dual-socket node (1.76x)
        // outperforms two single-socket nodes over 1 GbE (1.33x)
        let two_net = cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64));
        let dual = cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv2_dual(), 1, 128));
        assert!(dual > two_net, "dual {dual:.1} vs 2-node {two_net:.1}");
    }

    #[test]
    fn fig5_mcv1_cluster_13_gflops_near_linear() {
        // the mcv1 platform's default library is already the generic one
        let p = project(&ClusterConfig::hpl_default(mcv1_u740(), 8, 4));
        assert!((11.0..15.0).contains(&p.gflops), "MCv1 8-node {:.1}", p.gflops);
        // "the 1 Gb/s network was sufficient for obtaining almost an HPL
        // linear scaling"
        assert!(p.efficiency_vs_one_node > 0.90, "{:.3}", p.efficiency_vs_one_node);
    }

    #[test]
    fn mcv2_network_efficiency_is_poor() {
        let cfg = ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64);
        let p = project(&cfg);
        assert!(p.efficiency_vs_one_node < 0.75, "{:.3}", p.efficiency_vs_one_node);
        assert!(p.t_comm > 0.3 * p.t_comp, "comm {:.0}s comp {:.0}s", p.t_comm, p.t_comp);
    }

    #[test]
    fn ten_gbe_ablation_restores_scaling() {
        // DESIGN.md ablation: a 10 GbE fabric would have fixed MCv2 scaling
        let mut cfg = ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64);
        cfg.link = Link::ten_gbe();
        let p = project(&cfg);
        assert!(p.efficiency_vs_one_node > 0.85, "{:.3}", p.efficiency_vs_one_node);
    }

    #[test]
    fn single_node_has_no_comm() {
        let p = project(&mcv2_single());
        assert_eq!(p.t_comm, 0.0);
        assert!((p.efficiency_vs_one_node - 1.0).abs() < 1e-9);
    }

    #[test]
    fn headline_127x() {
        // dual-socket MCv2 node vs one MCv1 node
        let old = cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv1_u740(), 1, 4));
        let new = cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv2_dual(), 1, 128));
        let r = new / old;
        assert!((100.0..160.0).contains(&r), "{r:.0}x");
    }

    #[test]
    fn down_the_road_generations_are_ordered() {
        // single-node HPL must improve monotonically across generations:
        // MCv1 < MCv2 1S < MCv2 2S, SG2044 > MCv2 1S, MCv3 > MCv2 2S
        let g = |p: Platform, cores| {
            cluster_hpl_gflops(&ClusterConfig::hpl_default(p, 1, cores))
        };
        let v1 = g(mcv1_u740(), 4);
        let v2s = g(mcv2_pioneer(), 64);
        let v2d = g(mcv2_dual(), 128);
        let s44 = g(sg2044(), 64);
        let v3 = g(mcv3(), 128);
        for v in [v1, v2s, v2d, s44, v3] {
            assert!(v.is_finite() && v > 0.0, "{v}");
        }
        assert!(v1 < v2s && v2s < v2d, "{v1:.1} {v2s:.1} {v2d:.1}");
        assert!(s44 > v2s, "sg2044 {s44:.1} vs mcv2 {v2s:.1}");
        assert!(v3 > v2d, "mcv3 {v3:.1} vs mcv2-dual {v2d:.1}");
    }
}
