//! HPL cluster-performance projection — the generator behind Figs 4/5/7.
//!
//! Combines the calibrated node model ([`crate::blas::perf`]) with the
//! interconnect cost model ([`crate::net`]) using HPL's communication
//! structure: per panel, a panel broadcast, a row-slab exchange routed
//! through the switch ([`crate::net::Switch::flows_time`]) and a
//! pivot-row fan-in gather to the panel root; per column, a pivot-search
//! allreduce. The fabric is data: a resolved [`Fabric`], defaulting to
//! the platform's own `default_fabric` registry entry.

use std::sync::Arc;

use crate::arch::platform::Platform;
use crate::blas::perf::PerfModel;
use crate::error::CimoneError;
use crate::net::{Fabric, FabricRegistry};
use crate::ukernel::registry as kernels;
use crate::ukernel::{KernelDescriptor, KernelRegistry};
use crate::util::stats::hpl_flops;

/// A homogeneous cluster HPL run. The platform is shared (`Arc`) so
/// estimates cloned out of an inventory don't deep-copy descriptors.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub platform: Arc<Platform>,
    pub nodes: usize,
    pub cores_per_node: usize,
    /// The resolved micro-kernel descriptor HPL's DGEMM runs through.
    pub lib: Arc<KernelDescriptor>,
    /// HPL problem size. The paper never states theirs; EXPERIMENTS.md
    /// documents N = 57600, NB = 192 as the calibration point that
    /// reproduces Fig 5's scaling ratios.
    pub n: usize,
    pub nb: usize,
    /// The resolved interconnect the cluster hangs off.
    pub fabric: Fabric,
}

impl ClusterConfig {
    /// The standard run shape: the platform's default BLAS library, the
    /// calibration problem size, and the platform's own interconnect
    /// (`default_fabric`, resolved against the built-in
    /// [`FabricRegistry`] — so MCv1/MCv2 model the paper's 1 GbE and the
    /// MCv3 projection its 10 GbE). Accepts a `Platform` by value or an
    /// already-shared `Arc<Platform>`.
    ///
    /// A `default_fabric` (or `default_lib`) naming a custom
    /// (non-built-in) entry falls back to the paper's `gbe-flat` /
    /// `openblas-c920` here; the campaign layer resolves custom fabrics
    /// and kernels explicitly against its own registries.
    pub fn hpl_default(
        platform: impl Into<Arc<Platform>>,
        nodes: usize,
        cores_per_node: usize,
    ) -> Self {
        let platform = platform.into();
        let fabric = FabricRegistry::builtin()
            .get(&platform.default_fabric)
            .map(|f| (*f).clone())
            .unwrap_or_else(|_| Fabric::gbe_flat());
        ClusterConfig::with_fabric(platform, nodes, cores_per_node, fabric)
    }

    /// The standard run shape on an explicitly resolved fabric; the
    /// BLAS kernel is the platform's `default_lib` resolved against the
    /// built-in [`KernelRegistry`] (campaign paths override `lib` with
    /// their own resolution, custom `[[kernel]]` sections included).
    pub fn with_fabric(
        platform: impl Into<Arc<Platform>>,
        nodes: usize,
        cores_per_node: usize,
        fabric: Fabric,
    ) -> Self {
        let platform = platform.into();
        let lib = KernelRegistry::builtin()
            .get(&platform.default_lib)
            .unwrap_or_else(|_| Arc::new(kernels::openblas_c920()));
        ClusterConfig::with_lib_fabric(platform, nodes, cores_per_node, lib, fabric)
    }

    /// The standard run shape with both the kernel and the fabric
    /// already resolved — the campaign path, where the inventory's own
    /// registries (custom `[[kernel]]`/`[[fabric]]` sections included)
    /// did the resolution and no built-in fallback belongs.
    pub fn with_lib_fabric(
        platform: impl Into<Arc<Platform>>,
        nodes: usize,
        cores_per_node: usize,
        lib: Arc<KernelDescriptor>,
        fabric: Fabric,
    ) -> Self {
        let platform = platform.into();
        ClusterConfig { platform, nodes, cores_per_node, lib, n: 57_600, nb: 192, fabric }
    }

    /// Cross-checks between the cluster shape and its fabric: the switch
    /// must have a port per node. Campaign loading runs this before any
    /// flow model sees the configuration.
    pub fn validate(&self) -> Result<(), CimoneError> {
        self.fabric.validate()?;
        self.fabric.validate_cluster(self.nodes)
    }
}

/// Breakdown of one projected run.
#[derive(Debug, Clone, Copy)]
pub struct HplProjection {
    pub gflops: f64,
    pub t_comp: f64,
    pub t_comm: f64,
    pub efficiency_vs_one_node: f64,
}

/// Project the HPL performance of a cluster configuration.
pub fn project(cfg: &ClusterConfig) -> HplProjection {
    let node_rate =
        PerfModel::new(&cfg.platform, Arc::clone(&cfg.lib)).node_gflops(cfg.cores_per_node) * 1e9;
    let flops = hpl_flops(cfg.n);
    let p = cfg.nodes;
    let t_comp = flops / (p as f64 * node_rate);

    let t_comm = if p <= 1 {
        0.0
    } else {
        let coll = cfg.fabric.collectives(p);
        // switch_for keeps what-if sweeps total past the physical port
        // count (an idealized larger switch of the same class); real
        // fleets are port-checked as typed errors by ClusterConfig/
        // campaign validation before they reach this model
        let sw = cfg.fabric.switch_for(p);
        let panels = cfg.n / cfg.nb;
        // per-peer pivot-row block gathered to the panel root each panel
        let pivot_bytes = (cfg.nb * cfg.nb * 8) as f64;
        let mut t = 0.0;
        for pi in 0..panels {
            let rows = (cfg.n - pi * cfg.nb) as f64;
            let panel_bytes = rows * cfg.nb as f64 * 8.0;
            t += coll.bcast(panel_bytes); // L panel broadcast
            // U row-slab swap: a ring shift through the switch — equal
            // to the flat-link exchange on a non-blocking fabric, but
            // the backplane bound engages on oversubscribed ones
            t += sw.ring_shift_time(p, panel_bytes);
            // pivot-row swap: every peer sends its pivot block to the
            // panel root — the fan-in the flat model cannot see
            t += sw.gather_time(p, pivot_bytes);
        }
        // pivot search: one tiny allreduce per column
        t += cfg.n as f64 * coll.allreduce(8.0);
        t
    };

    let total = t_comp + t_comm;
    let gflops = flops / total / 1e9;
    let one_node = flops / (flops / node_rate) / 1e9; // = node_rate/1e9
    HplProjection {
        gflops,
        t_comp,
        t_comm,
        efficiency_vs_one_node: gflops / (one_node * p as f64),
    }
}

/// Convenience: projected GFLOP/s.
pub fn cluster_hpl_gflops(cfg: &ClusterConfig) -> f64 {
    project(cfg).gflops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::platform::{mcv1_u740, mcv2_dual, mcv2_pioneer, mcv3, sg2044};

    fn mcv2_single() -> ClusterConfig {
        ClusterConfig::hpl_default(mcv2_pioneer(), 1, 64)
    }

    #[test]
    fn fig5_mcv2_single_socket_node() {
        let g = cluster_hpl_gflops(&mcv2_single());
        assert!((125.0..155.0).contains(&g), "{g:.1}");
    }

    #[test]
    fn fig5_two_nodes_only_133x() {
        // "increasing the number of parallel processes reduces the HPL
        // efficiency (only the 1.33x w.r.t single node performance)"
        let one = cluster_hpl_gflops(&mcv2_single());
        let two = cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64));
        let ratio = two / one;
        assert!((1.20..1.45).contains(&ratio), "2-node scaling {ratio:.2}");
    }

    #[test]
    fn fig5_dual_socket_beats_two_networked_nodes() {
        // the paper's architectural point: one dual-socket node (1.76x)
        // outperforms two single-socket nodes over 1 GbE (1.33x)
        let two_net = cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64));
        let dual = cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv2_dual(), 1, 128));
        assert!(dual > two_net, "dual {dual:.1} vs 2-node {two_net:.1}");
    }

    #[test]
    fn fig5_mcv1_cluster_13_gflops_near_linear() {
        // the mcv1 platform's default library is already the generic one
        let p = project(&ClusterConfig::hpl_default(mcv1_u740(), 8, 4));
        assert!((11.0..15.0).contains(&p.gflops), "MCv1 8-node {:.1}", p.gflops);
        // "the 1 Gb/s network was sufficient for obtaining almost an HPL
        // linear scaling"
        assert!(p.efficiency_vs_one_node > 0.90, "{:.3}", p.efficiency_vs_one_node);
    }

    #[test]
    fn mcv2_network_efficiency_is_poor() {
        let cfg = ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64);
        let p = project(&cfg);
        assert!(p.efficiency_vs_one_node < 0.75, "{:.3}", p.efficiency_vs_one_node);
        assert!(p.t_comm > 0.3 * p.t_comp, "comm {:.0}s comp {:.0}s", p.t_comm, p.t_comp);
    }

    #[test]
    fn ten_gbe_ablation_restores_scaling() {
        // DESIGN.md ablation: a 10 GbE fabric would have fixed MCv2 scaling
        let mut cfg = ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64);
        cfg.fabric = Fabric::ten_gbe_flat();
        let p = project(&cfg);
        assert!(p.efficiency_vs_one_node > 0.85, "{:.3}", p.efficiency_vs_one_node);
    }

    #[test]
    fn hpl_default_resolves_the_platforms_own_fabric() {
        // MCv1/MCv2 model the paper's 1 GbE; MCv3 its 10 GbE upgrade
        assert_eq!(ClusterConfig::hpl_default(mcv2_pioneer(), 2, 64).fabric.id, "gbe-flat");
        assert_eq!(ClusterConfig::hpl_default(mcv1_u740(), 8, 4).fabric.id, "gbe-flat");
        assert_eq!(ClusterConfig::hpl_default(mcv3(), 2, 128).fabric.id, "ten-gbe-flat");
    }

    #[test]
    fn oversubscribed_fabric_collapses_scaling_further() {
        let flat = project(&ClusterConfig::hpl_default(mcv2_pioneer(), 8, 64));
        let mut cfg = ClusterConfig::hpl_default(mcv2_pioneer(), 8, 64);
        cfg.fabric = Fabric::gbe_oversub();
        let over = project(&cfg);
        assert!(
            over.efficiency_vs_one_node < flat.efficiency_vs_one_node,
            "oversub {:.3} !< flat {:.3}",
            over.efficiency_vs_one_node,
            flat.efficiency_vs_one_node
        );
    }

    #[test]
    fn cluster_wider_than_the_switch_is_a_typed_error() {
        let cfg = ClusterConfig::hpl_default(mcv2_pioneer(), 17, 64);
        assert!(matches!(
            cfg.validate(),
            Err(CimoneError::FabricTooSmall { ports: 16, nodes: 17, .. })
        ));
        assert!(ClusterConfig::hpl_default(mcv2_pioneer(), 16, 64).validate().is_ok());
        // ...but the projection itself stays total for what-if sweeps:
        // past the port count it models an idealized larger switch of
        // the same class instead of panicking
        let p = project(&cfg);
        assert!(p.gflops.is_finite() && p.gflops > 0.0, "{}", p.gflops);
        assert!(p.efficiency_vs_one_node < 1.0);
    }

    #[test]
    fn single_node_has_no_comm() {
        let p = project(&mcv2_single());
        assert_eq!(p.t_comm, 0.0);
        assert!((p.efficiency_vs_one_node - 1.0).abs() < 1e-9);
    }

    #[test]
    fn headline_127x() {
        // dual-socket MCv2 node vs one MCv1 node
        let old = cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv1_u740(), 1, 4));
        let new = cluster_hpl_gflops(&ClusterConfig::hpl_default(mcv2_dual(), 1, 128));
        let r = new / old;
        assert!((100.0..160.0).contains(&r), "{r:.0}x");
    }

    #[test]
    fn down_the_road_generations_are_ordered() {
        // single-node HPL must improve monotonically across generations:
        // MCv1 < MCv2 1S < MCv2 2S, SG2044 > MCv2 1S, MCv3 > MCv2 2S
        let g = |p: Platform, cores| {
            cluster_hpl_gflops(&ClusterConfig::hpl_default(p, 1, cores))
        };
        let v1 = g(mcv1_u740(), 4);
        let v2s = g(mcv2_pioneer(), 64);
        let v2d = g(mcv2_dual(), 128);
        let s44 = g(sg2044(), 64);
        let v3 = g(mcv3(), 128);
        for v in [v1, v2s, v2d, s44, v3] {
            assert!(v.is_finite() && v > 0.0, "{v}");
        }
        assert!(v1 < v2s && v2s < v2d, "{v1:.1} {v2s:.1} {v2d:.1}");
        assert!(s44 > v2s, "sg2044 {s44:.1} vs mcv2 {v2s:.1}");
        assert!(v3 > v2d, "mcv3 {v3:.1} vs mcv2-dual {v2d:.1}");
    }
}
