//! HPL's correctness criterion.
//!
//! The benchmark accepts a run iff
//! `||Ax-b||_inf / (eps * (||A||_inf * ||x||_inf + ||b||_inf) * N) < 16`.

use crate::util::Matrix;

/// Infinity norm of a vector.
pub fn inf_norm(v: &[f64]) -> f64 {
    v.iter().fold(0.0_f64, |m, x| m.max(x.abs()))
}

/// Infinity norm of a matrix (max row sum).
pub fn mat_inf_norm(a: &Matrix) -> f64 {
    let mut max = 0.0_f64;
    for i in 0..a.rows() {
        let mut s = 0.0;
        for j in 0..a.cols() {
            s += a[(i, j)].abs();
        }
        max = max.max(s);
    }
    max
}

/// HPL's scaled residual; a run "passes" when this is < 16.
pub fn hpl_residual(a: &Matrix, x: &[f64], b: &[f64]) -> f64 {
    let n = a.rows();
    let ax = a.matvec(x);
    let r: Vec<f64> = ax.iter().zip(b).map(|(y, bb)| y - bb).collect();
    let eps = f64::EPSILON;
    let denom = eps * (mat_inf_norm(a) * inf_norm(x) + inf_norm(b)) * n as f64;
    if denom == 0.0 {
        return f64::INFINITY;
    }
    inf_norm(&r) / denom
}

/// The acceptance threshold from the HPL source.
pub const HPL_THRESHOLD: f64 = 16.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_solution_passes() {
        let a = Matrix::random_dd(16, 1);
        let x: Vec<f64> = (0..16).map(|i| (i as f64) * 0.25 - 2.0).collect();
        let b = a.matvec(&x);
        assert!(hpl_residual(&a, &x, &b) < HPL_THRESHOLD);
    }

    #[test]
    fn corrupted_solution_fails() {
        let a = Matrix::random_dd(16, 2);
        let x: Vec<f64> = (0..16).map(|i| i as f64).collect();
        let b = a.matvec(&x);
        let mut bad = x.clone();
        bad[7] += 0.5;
        assert!(hpl_residual(&a, &bad, &b) > HPL_THRESHOLD);
    }

    #[test]
    fn norms_basic() {
        assert_eq!(inf_norm(&[1.0, -3.0, 2.0]), 3.0);
        let a = Matrix::from_rows(2, 2, &[1.0, -2.0, 0.5, 0.5]);
        assert_eq!(mat_inf_norm(&a), 3.0);
    }

    #[test]
    fn degenerate_zero_system_is_infinite() {
        let a = Matrix::zeros(2, 2);
        assert!(hpl_residual(&a, &[0.0, 0.0], &[0.0, 0.0]).is_infinite());
    }
}
