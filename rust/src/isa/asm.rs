//! Assembly text rendering for both dialects.
//!
//! The paper's Section 3.3.1 retrofit is a *textual* port: RVV 1.0 mnemonics
//! (`vle64.v`, `vsetvli ... e64, m1, ta, ma`) become XuanTie theadvector
//! ones (`th.vle.v`, `th.vsetvli ... e64, m1` — element width lives in
//! vtype, no tail/mask flags, and GCC 14 requires the `th.` prefix to
//! recognize them). Rendering both lets tests diff our translator's output
//! against hand-written expectations.

use super::inst::{Dialect, Inst, Program};
use super::rvv::{Lmul, Sew};

fn lmul_text(l: Lmul) -> &'static str {
    match l {
        Lmul::M1 => "m1",
        Lmul::M2 => "m2",
        Lmul::M4 => "m4",
        Lmul::M8 => "m8",
        Lmul::Fractional => "mf2",
    }
}

fn sew_text(s: Sew) -> &'static str {
    match s {
        Sew::E32 => "e32",
        Sew::E64 => "e64",
    }
}

/// Render one instruction in the given dialect.
pub fn render_inst(inst: &Inst, dialect: Dialect) -> String {
    let pre = match dialect {
        Dialect::Rvv10 => "",
        Dialect::Thead071 => "th.",
    };
    match *inst {
        Inst::Vsetvli { avl, vtype } => match dialect {
            Dialect::Rvv10 => format!(
                "vsetvli t0, {avl}, {}, {}, ta, ma",
                sew_text(vtype.sew),
                lmul_text(vtype.lmul)
            ),
            // 0.7.1: no tail/mask-agnostic flags
            Dialect::Thead071 => format!(
                "th.vsetvli t0, {avl}, {}, {}",
                sew_text(vtype.sew),
                lmul_text(vtype.lmul)
            ),
        },
        Inst::Vle { sew, vd, addr } => match dialect {
            // 1.0 encodes EEW in the mnemonic...
            Dialect::Rvv10 => format!("vle{}.v v{vd}, {addr}(a0)", sew.bits()),
            // ...0.7.1 takes it from vtype
            Dialect::Thead071 => format!("th.vle.v v{vd}, {addr}(a0)"),
        },
        Inst::Vse { sew, vs, addr } => match dialect {
            Dialect::Rvv10 => format!("vse{}.v v{vs}, {addr}(a0)", sew.bits()),
            Dialect::Thead071 => format!("th.vse.v v{vs}, {addr}(a0)"),
        },
        Inst::VfmaccVf { vd, fs, vs2 } => format!("{pre}vfmacc.vf v{vd}, f{fs}, v{vs2}"),
        Inst::VfmulVf { vd, fs, vs2 } => format!("{pre}vfmul.vf v{vd}, f{fs}, v{vs2}"),
        Inst::VfmvVf { vd, fs } => format!("{pre}vfmv.v.f v{vd}, f{fs}"),
        Inst::VfaddVv { vd, vs1, vs2 } => format!("{pre}vfadd.vv v{vd}, v{vs1}, v{vs2}"),
        Inst::Fld { fd, addr } => format!("fld f{fd}, {addr}(a1)"),
        Inst::Fsd { fs, addr } => format!("fsd f{fs}, {addr}(a1)"),
        Inst::FmaddD { fd, fs1, fs2, fs3 } => {
            format!("fmadd.d f{fd}, f{fs1}, f{fs2}, f{fs3}")
        }
        Inst::Addi => "addi a0, a0, 8".to_string(),
        Inst::Bnez => "bnez t1, .loop".to_string(),
    }
}

/// Render a whole program as assembly listing. If the program contains
/// any loop back-edge (`bnez t1, .loop`), the `.loop:` label is emitted
/// as the first line so the listing assembles under
/// [`crate::isa::assembler`]'s backward-branch validation — which makes
/// `assemble(render_program(p)) == p` hold for every well-formed
/// program (labels are structure, not instructions).
pub fn render_program(prog: &Program) -> String {
    let mut lines = Vec::with_capacity(prog.insts.len() + 1);
    if prog.insts.iter().any(|i| matches!(i, Inst::Bnez)) {
        lines.push(".loop:".to_string());
    }
    lines.extend(prog.insts.iter().map(|i| format!("    {}", render_inst(i, prog.dialect))));
    lines.join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::rvv::VType;

    #[test]
    fn vsetvli_dialect_difference() {
        let i = Inst::Vsetvli { avl: 8, vtype: VType::new(Sew::E64, Lmul::M4) };
        assert_eq!(render_inst(&i, Dialect::Rvv10), "vsetvli t0, 8, e64, m4, ta, ma");
        assert_eq!(render_inst(&i, Dialect::Thead071), "th.vsetvli t0, 8, e64, m4");
    }

    #[test]
    fn load_mnemonic_difference() {
        let i = Inst::Vle { sew: Sew::E64, vd: 8, addr: 64 };
        assert_eq!(render_inst(&i, Dialect::Rvv10), "vle64.v v8, 64(a0)");
        assert_eq!(render_inst(&i, Dialect::Thead071), "th.vle.v v8, 64(a0)");
    }

    #[test]
    fn th_prefix_on_arith() {
        let i = Inst::VfmaccVf { vd: 0, fs: 1, vs2: 8 };
        assert_eq!(render_inst(&i, Dialect::Rvv10), "vfmacc.vf v0, f1, v8");
        assert_eq!(render_inst(&i, Dialect::Thead071), "th.vfmacc.vf v0, f1, v8");
    }

    #[test]
    fn scalar_insts_unprefixed() {
        let i = Inst::FmaddD { fd: 0, fs1: 1, fs2: 2, fs3: 0 };
        assert_eq!(render_inst(&i, Dialect::Thead071), "fmadd.d f0, f1, f2, f0");
    }

    #[test]
    fn listing_has_one_line_per_inst_plus_loop_label() {
        let mut p = Program::new(Dialect::Thead071);
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
        let text = render_program(&p);
        assert_eq!(text.lines().count(), 3);
        assert_eq!(text.lines().next(), Some(".loop:"));

        // no back-edge, no label
        let mut straight = Program::new(Dialect::Rvv10);
        straight.push(Inst::Addi);
        assert_eq!(render_program(&straight).lines().count(), 1);
    }
}
