//! Two-pass RVV assembler: real `.S` listings -> [`Program`].
//!
//! [`crate::isa::parse`] was line-oriented: it skipped labels, ignored
//! directives and reported errors as a bare line number. This module
//! lifts it into a real assembler front end, so *published* micro-kernel
//! listings (OpenBLAS/BLIS `.S` files, the paper's Section 3.3.1
//! retrofit sources) assemble into registry kernels without Rust edits:
//!
//! - **Two passes.** Pass one collects labels and filters directives
//!   (`.globl`, `.align`, `.text`, ... are accepted and ignored;
//!   `.macro` is rejected — this assembler is deliberately macro-free);
//!   pass two parses instructions and resolves branch targets against
//!   the symbol table. Branches must be *backward* (loop back-edges):
//!   an undefined or forward target is a typed error, which is exactly
//!   the loop-structure guarantee the kernel expander relies on.
//! - **Source-located errors.** Every failure is an [`AsmError`] with
//!   file, 1-based line/column, the token span and the offending source
//!   line, rendered with a caret excerpt (`^^^^`) like a real toolchain.
//! - **Single source of truth.** The mnemonic set is exactly what
//!   [`Inst`] encodes (plus the scalar bookkeeping spellings real
//!   listings use — `li`/`mv`/`add`/... map onto the [`Inst::Addi`]
//!   marker, branch spellings onto [`Inst::Bnez`]); anything else is
//!   rejected at parse time with an edit-distance suggestion.
//! - **Round trip.** [`disassemble`] renders a program back to canonical
//!   text (via [`crate::isa::asm`]) and `assemble(disassemble(p)) == p`
//!   holds for both dialects — property-tested in
//!   `rust/tests/integration_isa.rs`.
//! - **Kernel mode.** [`assemble_kernel`] additionally recovers the
//!   micro-kernel structure — prologue / loop body / epilogue around the
//!   single backward branch, with memory operands classified by base
//!   register (`a0` = packed A panel, `a1` = packed B panel, `a2` = C
//!   tile) — and [`AsmKernel::expand`] re-synthesizes the full KC-step
//!   program for any [`PanelLayout`], which is what lets an `asm-source`
//!   [`crate::ukernel::KernelDescriptor`] drive the same analysis and
//!   execution paths as the generator families.

use std::collections::BTreeMap;
use std::fmt;

use super::asm::render_program;
use super::inst::{Dialect, Inst, Program};
use super::rvv::{vsetvl, Lmul, Sew, VType};
use crate::ukernel::PanelLayout;
use crate::util::hash::ContentHasher;

/// A source-located assembly error: file, 1-based line and column, the
/// width of the offending token and the source line it sits on. The
/// `Display` impl renders a compiler-style caret excerpt:
///
/// ```text
/// kernel.S:3:5: unknown mnemonic `vfmaac.vf` (did you mean `vfmacc.vf`?)
///     vfmaac.vf v0, f1, v8
///     ^^^^^^^^^
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AsmError {
    /// Source name (`<memory>` for in-process text).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// 1-based column of the offending token.
    pub col: usize,
    /// Token width in characters (>= 1), for the caret run.
    pub span: usize,
    pub message: String,
    /// The offending source line, kept so the error renders its own
    /// excerpt without needing the original text.
    pub source_line: String,
}

impl fmt::Display for AsmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}:{}:{}: {}", self.file, self.line, self.col, self.message)?;
        writeln!(f, "    {}", self.source_line)?;
        write!(f, "    {}{}", " ".repeat(self.col.saturating_sub(1)), "^".repeat(self.span.max(1)))
    }
}

impl std::error::Error for AsmError {}

/// Directives we accept and ignore (layout/linkage noise in real `.S`
/// files). Anything else dotted is an error; `.macro` gets a dedicated
/// message because it is a deliberate non-feature.
const IGNORED_DIRECTIVES: &[&str] = &[
    ".align",
    ".attribute",
    ".balign",
    ".global",
    ".globl",
    ".option",
    ".p2align",
    ".section",
    ".size",
    ".text",
    ".type",
];

/// Scalar bookkeeping spellings that map onto the [`Inst::Addi`] marker
/// (address bumps / loop-counter arithmetic; functional no-ops for FP
/// state, charged by the cycle model).
const ADDI_LIKE: &[&str] = &["add", "addi", "addiw", "andi", "li", "mv", "slli", "srli", "sub"];

/// Branch spellings that map onto the [`Inst::Bnez`] back-edge marker.
/// The last operand is the target label.
const BRANCH_LIKE: &[&str] = &["beqz", "bge", "bgtz", "blt", "bne", "bnez"];

/// Every mnemonic the instruction tables encode, used for suggestions.
const KNOWN_MNEMONICS: &[&str] = &[
    "vsetvli",
    "vle32.v",
    "vle64.v",
    "vle.v",
    "vse32.v",
    "vse64.v",
    "vse.v",
    "vfmacc.vf",
    "vfmul.vf",
    "vfmv.v.f",
    "vfadd.vv",
    "fld",
    "fsd",
    "fmadd.d",
    "add",
    "addi",
    "addiw",
    "andi",
    "li",
    "mv",
    "slli",
    "srli",
    "sub",
    "beqz",
    "bge",
    "bgtz",
    "blt",
    "bne",
    "bnez",
];

/// Which packed panel a micro-kernel memory operand addresses, keyed by
/// its base register: `a0` = A panel, `a1` = B panel, `a2` = C tile —
/// the calling convention the BLIS/OpenBLAS micro-kernels (and our
/// [`PanelLayout`]) share.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PanelBase {
    A,
    B,
    C,
}

/// One assembled instruction plus the panel its memory operand (if any)
/// addresses. In kernel mode the `addr` field of `inst` holds the
/// *panel-relative* element offset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelInst {
    pub inst: Inst,
    pub base: Option<PanelBase>,
}

/// A micro-kernel recovered from a listing: straight-line prologue (C
/// loads, `vsetvli`), the single-backward-branch loop body covering
/// `k_unroll` rank-1 steps, and the epilogue (C stores). Memory operands
/// are panel-relative (see [`PanelBase`]); [`AsmKernel::expand`]
/// re-synthesizes the absolute-addressed program for any layout.
#[derive(Debug, Clone, PartialEq)]
pub struct AsmKernel {
    pub dialect: Dialect,
    /// The loop label the back-edge targets.
    pub label: String,
    pub prologue: Vec<KernelInst>,
    pub body: Vec<KernelInst>,
    pub epilogue: Vec<KernelInst>,
}

/// Assemble a listing from in-process text (file shown as `<memory>`).
pub fn assemble(text: &str) -> Result<Program, AsmError> {
    assemble_named(text, "<memory>")
}

/// Assemble a listing, reporting errors against `file`.
pub fn assemble_named(text: &str, file: &str) -> Result<Program, AsmError> {
    let unit = Unit::parse(text, file)?;
    let mut p = Program::new(unit.dialect);
    for li in unit.insts {
        p.push(li.ki.inst);
    }
    Ok(p)
}

/// Render a program back to canonical assembly text, such that
/// `assemble(disassemble(p)) == p` for both dialects. Delegates to
/// [`render_program`] — one renderer, shared with the translator demo —
/// which emits the `.loop:` label any `bnez` back-edge targets.
pub fn disassemble(p: &Program) -> String {
    render_program(p)
}

/// Assemble a listing into its micro-kernel structure (see
/// [`AsmKernel`]). On top of [`assemble_named`]'s checks this requires
/// exactly one backward branch (the k-loop) and classifies every memory
/// operand by panel base register.
pub fn assemble_kernel(text: &str, file: &str) -> Result<AsmKernel, AsmError> {
    let unit = Unit::parse(text, file)?;
    let branches: Vec<usize> = unit
        .insts
        .iter()
        .enumerate()
        .filter(|(_, li)| matches!(li.ki.inst, Inst::Bnez))
        .map(|(i, _)| i)
        .collect();
    let err = |line: usize, col: usize, span: usize, msg: String| AsmError {
        file: file.to_string(),
        line,
        col,
        span,
        message: msg,
        source_line: unit.source_line(line),
    };
    let (branch_idx, label) = match branches.as_slice() {
        [one] => (*one, unit.insts[*one].target.clone().unwrap_or_default()),
        [] => {
            return Err(err(
                1,
                1,
                1,
                "micro-kernel listings need exactly one backward loop branch, found none".into(),
            ))
        }
        more => {
            let li = &unit.insts[more[1]];
            return Err(err(
                li.line,
                li.col,
                li.span,
                format!("micro-kernel listings need exactly one loop branch, found {}", more.len()),
            ));
        }
    };
    let label_line = unit.labels[&label];
    let mut k = AsmKernel {
        dialect: unit.dialect,
        label,
        prologue: Vec::new(),
        body: Vec::new(),
        epilogue: Vec::new(),
    };
    for (i, li) in unit.insts.iter().enumerate() {
        if li.line <= label_line {
            k.prologue.push(li.ki);
        } else if i <= branch_idx {
            k.body.push(li.ki);
        } else {
            k.epilogue.push(li.ki);
        }
    }
    // panel-base discipline: prologue/epilogue touch only the C tile,
    // the body may touch all three panels
    for (ki, where_) in k
        .prologue
        .iter()
        .map(|ki| (ki, "prologue"))
        .chain(k.epilogue.iter().map(|ki| (ki, "epilogue")))
    {
        if matches!(ki.base, Some(PanelBase::A) | Some(PanelBase::B)) {
            return Err(err(
                1,
                1,
                1,
                format!(
                    "{where_} addresses the k-indexed {} panel — A/B panel operands \
                     only make sense inside the loop body",
                    if ki.base == Some(PanelBase::A) { "A" } else { "B" }
                ),
            ));
        }
    }
    Ok(k)
}

impl AsmKernel {
    /// Re-synthesize the absolute-addressed program for `layout`: the
    /// prologue, `ceil(kc / k_unroll)` expansions of the loop body with
    /// A/B panel offsets advanced per block (a partial tail block keeps
    /// only the k-steps it covers, exactly like the generator families),
    /// and the epilogue. Panel-relative offsets are resolved through
    /// [`PanelLayout`]; call [`AsmKernel::check`] first (the descriptor
    /// validation path does) so offsets are known in range.
    pub fn expand(&self, l: PanelLayout, k_unroll: usize) -> Program {
        let c_base = l.c_offset(0);
        let mut p = Program::new(self.dialect);
        for ki in &self.prologue {
            p.push(rebase(ki, c_base, 0, 0));
        }
        let mut k = 0;
        while k < l.kc {
            let block = k_unroll.min(l.kc - k);
            // which k-step of the unrolled body an inst belongs to: its
            // own panel offset for A/B operands; everything else (FMA
            // bursts) rides with the preceding load, as in every real
            // schedule. A partial tail block keeps only the first
            // `block` k-steps; bookkeeping is per-block, kept always.
            let mut step = 0;
            for ki in &self.body {
                if matches!(ki.inst, Inst::Addi | Inst::Bnez) {
                    p.push(ki.inst);
                    continue;
                }
                match (ki.base, addr_of(&ki.inst)) {
                    (Some(PanelBase::A), Some(a)) => step = a / l.mr,
                    (Some(PanelBase::B), Some(a)) => step = a / l.nr,
                    _ => {}
                }
                if step < block {
                    p.push(rebase(ki, c_base, l.a_offset(k), l.b_offset(k)));
                }
            }
            k += block;
        }
        for ki in &self.epilogue {
            p.push(rebase(ki, c_base, 0, 0));
        }
        p
    }

    /// Validate the kernel against the descriptor's declared geometry:
    /// panel offsets in range for an `mr` x `nr` tile unrolled
    /// `k_unroll` deep, every `vsetvli` feasible at `vlen_bits`, and the
    /// expanded program's register groups legal. Returns a reason string
    /// (the descriptor wraps it as `CimoneError::InvalidKernel`).
    pub fn check(
        &self,
        mr: usize,
        nr: usize,
        k_unroll: usize,
        vlen_bits: usize,
    ) -> Result<(), String> {
        let mut steps_seen = vec![false; k_unroll];
        for (ki, where_) in self
            .prologue
            .iter()
            .map(|ki| (ki, "prologue"))
            .chain(self.body.iter().map(|ki| (ki, "body")))
            .chain(self.epilogue.iter().map(|ki| (ki, "epilogue")))
        {
            if let Inst::Vsetvli { avl, vtype } = ki.inst {
                if vtype.lmul.is_fractional() {
                    return Err("fractional LMUL is not a GEMM-kernel configuration".into());
                }
                let got = vsetvl(avl, vtype, vlen_bits);
                if got != avl {
                    return Err(format!(
                        "vsetvli avl={avl} is infeasible at VLEN={vlen_bits} \
                         (vsetvl grants vl={got})"
                    ));
                }
            }
            let (base, addr) = (ki.base, addr_of(&ki.inst));
            if let (Some(b), Some(a)) = (base, addr) {
                let (limit, what) = match b {
                    PanelBase::A => (k_unroll * mr, "A-panel"),
                    PanelBase::B => (k_unroll * nr, "B-panel"),
                    PanelBase::C => (mr * nr, "C-tile"),
                };
                if a >= limit {
                    return Err(format!(
                        "{where_} {what} offset {a} out of range for mr={mr} nr={nr} \
                         k_unroll={k_unroll} (limit {limit})"
                    ));
                }
                if where_ == "body" {
                    match b {
                        PanelBase::A => steps_seen[a / mr] = true,
                        PanelBase::B => steps_seen[a / nr] = true,
                        PanelBase::C => {}
                    }
                }
            }
        }
        if let Some(missing) = steps_seen.iter().position(|s| !s) {
            return Err(format!(
                "loop body never addresses k-step {missing} of the declared \
                 k_unroll={k_unroll} (A offsets cover [k*mr, (k+1)*mr), B offsets [k*nr, (k+1)*nr))"
            ));
        }
        // two blocks exercise the loop re-entry; register-group rules
        // must hold over the whole expansion
        let probe = PanelLayout::new(mr, nr, (2 * k_unroll).max(1));
        self.expand(probe, k_unroll)
            .validate_register_groups(vlen_bits)
            .map_err(|e| e.to_string())
    }

    /// Canonical content feed for the estimation cache: the dialect plus
    /// every instruction with its panel tag — a pure function of the
    /// *resolved* kernel (comments, label spelling and whitespace do not
    /// feed), so cosmetic edits to a listing keep cache keys stable.
    pub fn feed_content(&self, h: &mut ContentHasher) {
        h.write_str(match self.dialect {
            Dialect::Rvv10 => "rvv10",
            Dialect::Thead071 => "thead071",
        });
        for (part, insts) in [("p", &self.prologue), ("b", &self.body), ("e", &self.epilogue)] {
            h.write_str(part).write_usize(insts.len());
            for ki in insts {
                h.write_str(&super::asm::render_inst(&ki.inst, self.dialect));
                h.write_usize(match ki.base {
                    None => 0,
                    Some(PanelBase::A) => 1,
                    Some(PanelBase::B) => 2,
                    Some(PanelBase::C) => 3,
                });
            }
        }
    }
}

/// The absolute-addressed copy of a panel-relative instruction.
fn rebase(ki: &KernelInst, c_base: usize, a_base: usize, b_base: usize) -> Inst {
    let shift = match ki.base {
        None => 0,
        Some(PanelBase::A) => a_base,
        Some(PanelBase::B) => b_base,
        Some(PanelBase::C) => c_base,
    };
    match ki.inst {
        Inst::Vle { sew, vd, addr } => Inst::Vle { sew, vd, addr: addr + shift },
        Inst::Vse { sew, vs, addr } => Inst::Vse { sew, vs, addr: addr + shift },
        Inst::Fld { fd, addr } => Inst::Fld { fd, addr: addr + shift },
        Inst::Fsd { fs, addr } => Inst::Fsd { fs, addr: addr + shift },
        other => other,
    }
}

fn addr_of(inst: &Inst) -> Option<usize> {
    match inst {
        Inst::Vle { addr, .. }
        | Inst::Vse { addr, .. }
        | Inst::Fld { addr, .. }
        | Inst::Fsd { addr, .. } => Some(*addr),
        _ => None,
    }
}

// ---------------------------------------------------------------------
// The two-pass front end.
// ---------------------------------------------------------------------

/// One parsed instruction with its source location, (for branches) the
/// target label, and the dialect its spelling implies, if any.
struct LocatedInst {
    ki: KernelInst,
    line: usize,
    col: usize,
    span: usize,
    target: Option<String>,
    dialect_hint: Option<Dialect>,
}

impl LocatedInst {
    fn new(ki: KernelInst, line: usize, col: usize, span: usize) -> LocatedInst {
        LocatedInst { ki, line, col, span, target: None, dialect_hint: None }
    }
}

/// A fully parsed listing: instructions in order, the symbol table, the
/// inferred dialect.
struct Unit<'t> {
    text: &'t str,
    file: String,
    dialect: Dialect,
    insts: Vec<LocatedInst>,
    labels: BTreeMap<String, usize>,
}

impl<'t> Unit<'t> {
    fn source_line(&self, line: usize) -> String {
        self.text.lines().nth(line.saturating_sub(1)).unwrap_or("").to_string()
    }

    fn parse(text: &'t str, file: &str) -> Result<Unit<'t>, AsmError> {
        let mut u = Unit {
            text,
            file: file.to_string(),
            dialect: Dialect::Rvv10,
            insts: Vec::new(),
            labels: BTreeMap::new(),
        };
        let err = |line: usize, col: usize, span: usize, msg: String| AsmError {
            file: file.to_string(),
            line,
            col,
            span,
            message: msg,
            source_line: text.lines().nth(line - 1).unwrap_or("").to_string(),
        };

        // Pass 1: labels and directives. A label stands alone on its
        // line (`name:`); directives start with `.` and are either
        // known-ignored or rejected.
        let mut code_lines: Vec<(usize, &str, usize)> = Vec::new(); // (lineno, code, col)
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let code = raw.split('#').next().unwrap_or("");
            let trimmed = code.trim();
            if trimmed.is_empty() {
                continue;
            }
            let col = code.len() - code.trim_start().len() + 1;
            if let Some(name) = trimmed.strip_suffix(':') {
                if name.is_empty() || name.contains(char::is_whitespace) {
                    let span = trimmed.chars().count();
                    return Err(err(lineno, col, span, format!("malformed label `{trimmed}`")));
                }
                if u.labels.insert(name.to_string(), lineno).is_some() {
                    return Err(err(
                        lineno,
                        col,
                        name.chars().count(),
                        format!("label `{name}` is defined twice"),
                    ));
                }
                continue;
            }
            if trimmed.starts_with('.') {
                let dname = trimmed.split_whitespace().next().unwrap_or(trimmed);
                if dname == ".macro" {
                    return Err(err(
                        lineno,
                        col,
                        dname.chars().count(),
                        "directive `.macro` is not supported (this assembler is \
                         deliberately macro-free; expand macros before ingesting)"
                            .into(),
                    ));
                }
                if !IGNORED_DIRECTIVES.contains(&dname) {
                    return Err(err(
                        lineno,
                        col,
                        dname.chars().count(),
                        format!(
                            "unknown directive `{dname}` (accepted and ignored: {})",
                            IGNORED_DIRECTIVES.join(", ")
                        ),
                    ));
                }
                continue;
            }
            code_lines.push((lineno, code, col));
        }

        // Pass 2: instructions, dialect inference, branch resolution.
        let mut dialect: Option<Dialect> = None;
        for (lineno, code, col) in code_lines {
            let li = parse_inst_line(&u, lineno, code, col)?;
            if let Some(d) = li.dialect_hint {
                match dialect {
                    None => dialect = Some(d),
                    Some(prev) if prev != d => {
                        return Err(err(
                            lineno,
                            li.col,
                            li.span,
                            format!("mixed dialects: {prev:?} then {d:?}"),
                        ))
                    }
                    _ => {}
                }
            }
            if let Some(target) = &li.target {
                match u.labels.get(target) {
                    None => {
                        return Err(err(
                            lineno,
                            li.col,
                            li.span,
                            format!("branch target `{target}` is not defined"),
                        ))
                    }
                    Some(def) if *def > lineno => {
                        return Err(err(
                            lineno,
                            li.col,
                            li.span,
                            format!(
                                "branch target `{target}` (line {def}) is forward — only \
                                 backward loop branches are supported"
                            ),
                        ))
                    }
                    _ => {}
                }
            }
            u.insts.push(li);
        }
        u.dialect = dialect.unwrap_or(Dialect::Rvv10);
        Ok(u)
    }
}

/// Split one code line (comment already stripped) into the mnemonic and
/// comma-separated operands, each with its 1-based column.
fn split_operands(code: &str) -> (&str, usize, Vec<(&str, usize)>) {
    let lead = code.len() - code.trim_start().len();
    let rest = &code[lead..];
    let mlen = rest.find(char::is_whitespace).unwrap_or(rest.len());
    let mnemonic = &rest[..mlen];
    let mut ops = Vec::new();
    let tail_start = lead + mlen;
    let tail = &code[tail_start..];
    let mut off = 0;
    for seg in tail.split(',') {
        let t = seg.trim();
        if !t.is_empty() {
            let col = tail_start + off + (seg.len() - seg.trim_start().len()) + 1;
            ops.push((t, col));
        }
        off += seg.len() + 1;
    }
    (mnemonic, lead + 1, ops)
}

fn levenshtein(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, cb) in b.iter().enumerate() {
            let cost = usize::from(ca != cb);
            cur.push((prev[j] + cost).min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// Closest known mnemonic within edit distance 2, for error hints.
fn suggest(bare: &str) -> Option<&'static str> {
    KNOWN_MNEMONICS
        .iter()
        .map(|m| (levenshtein(bare, m), *m))
        .min()
        .filter(|(d, _)| *d <= 2)
        .map(|(_, m)| m)
}

fn parse_inst_line(
    u: &Unit<'_>,
    lineno: usize,
    code: &str,
    _col: usize,
) -> Result<LocatedInst, AsmError> {
    let (mnemonic, mcol, ops) = split_operands(code);
    let mspan = mnemonic.chars().count();
    let err = |col: usize, span: usize, msg: String| AsmError {
        file: u.file.clone(),
        line: lineno,
        col,
        span: span.max(1),
        message: msg,
        source_line: u.source_line(lineno),
    };
    let (bare, mut hint) = match mnemonic.strip_prefix("th.") {
        Some(b) => (b, Some(Dialect::Thead071)),
        None => (mnemonic, None),
    };

    let op = |i: usize| -> Result<(&str, usize), AsmError> {
        ops.get(i).copied().ok_or_else(|| {
            err(mcol, mspan, format!("`{mnemonic}` is missing operand {}", i + 1))
        })
    };
    let reg = |i: usize, class: char| -> Result<u8, AsmError> {
        let (tok, col) = op(i)?;
        let span = tok.chars().count();
        let rest = tok.strip_prefix(class).ok_or_else(|| {
            err(col, span, format!("expected {class}-register, got `{tok}`"))
        })?;
        let n: u8 = rest
            .parse()
            .map_err(|_| err(col, span, format!("bad register `{tok}`")))?;
        if n >= 32 {
            return Err(err(col, span, format!("register `{tok}` out of file (v0..v31)")));
        }
        Ok(n)
    };
    // `<offset>(<base>)` memory operand -> (offset, base register name)
    let addr = |i: usize| -> Result<(usize, String), AsmError> {
        let (tok, col) = op(i)?;
        let span = tok.chars().count();
        let (off_s, rest) = tok
            .split_once('(')
            .ok_or_else(|| err(col, span, format!("bad address `{tok}` (want `off(reg)`)")))?;
        let base = rest
            .strip_suffix(')')
            .ok_or_else(|| err(col, span, format!("bad address `{tok}` (unclosed `(`)")))?;
        let off: usize = off_s
            .trim()
            .parse()
            .map_err(|_| err(col, span, format!("bad address offset `{off_s}`")))?;
        Ok((off, base.trim().to_string()))
    };
    let panel = |base: &str| -> Option<PanelBase> {
        match base {
            "a0" => Some(PanelBase::A),
            "a1" => Some(PanelBase::B),
            "a2" => Some(PanelBase::C),
            _ => None,
        }
    };

    let mut target = None;
    let (inst, base) = match bare {
        "vsetvli" => {
            // vsetvli rd, <avl>, e<sew>, m<lmul>[, ta, ma]
            if ops.len() < 4 {
                return Err(err(mcol, mspan, "vsetvli needs rd, avl, sew, lmul".into()));
            }
            let (avl_s, avl_col) = ops[1];
            let avl: usize = avl_s
                .parse()
                .map_err(|_| err(avl_col, avl_s.chars().count(), format!("bad avl `{avl_s}`")))?;
            let sew = match ops[2].0 {
                "e32" => Sew::E32,
                "e64" => Sew::E64,
                o => {
                    return Err(err(ops[2].1, o.chars().count(), format!("bad sew `{o}`")));
                }
            };
            let lmul = match ops[3].0 {
                "m1" => Lmul::M1,
                "m2" => Lmul::M2,
                "m4" => Lmul::M4,
                "m8" => Lmul::M8,
                "mf2" | "mf4" | "mf8" => Lmul::Fractional,
                o => {
                    return Err(err(ops[3].1, o.chars().count(), format!("bad lmul `{o}`")));
                }
            };
            let has_flags = ops.len() >= 6 && ops[4].0 == "ta" && ops[5].0 == "ma";
            if hint == Some(Dialect::Thead071) && has_flags {
                return Err(err(ops[4].1, 2, "theadvector vsetvli takes no ta/ma flags".into()));
            }
            if has_flags && hint.is_none() {
                // ta/ma spelling exists only in RVV 1.0
                hint = Some(Dialect::Rvv10);
            }
            let mut vt = VType::new(sew, lmul);
            vt.tail_agnostic = has_flags;
            vt.mask_agnostic = has_flags;
            (Inst::Vsetvli { avl, vtype: vt }, None)
        }
        // NOTE: an EEW-suffixed load without `th.` carries no dialect
        // hint — a theadvector listing may legitimately spell explicit
        // widths, and the historical parser accepted that mix.
        m if m.starts_with("vle") && m.ends_with(".v") => {
            let sew = parse_eew(m, hint).map_err(|msg| err(mcol, mspan, msg))?;
            let vd = reg(0, 'v')?;
            let (a, b) = addr(1)?;
            (Inst::Vle { sew, vd, addr: a }, panel(&b))
        }
        m if m.starts_with("vse") && m.ends_with(".v") => {
            let sew = parse_eew(m, hint).map_err(|msg| err(mcol, mspan, msg))?;
            let vs = reg(0, 'v')?;
            let (a, b) = addr(1)?;
            (Inst::Vse { sew, vs, addr: a }, panel(&b))
        }
        "vfmacc.vf" => {
            (Inst::VfmaccVf { vd: reg(0, 'v')?, fs: reg(1, 'f')?, vs2: reg(2, 'v')? }, None)
        }
        "vfmul.vf" => {
            (Inst::VfmulVf { vd: reg(0, 'v')?, fs: reg(1, 'f')?, vs2: reg(2, 'v')? }, None)
        }
        "vfmv.v.f" => (Inst::VfmvVf { vd: reg(0, 'v')?, fs: reg(1, 'f')? }, None),
        "vfadd.vv" => {
            (Inst::VfaddVv { vd: reg(0, 'v')?, vs1: reg(1, 'v')?, vs2: reg(2, 'v')? }, None)
        }
        "fld" => {
            let fd = reg(0, 'f')?;
            let (a, b) = addr(1)?;
            (Inst::Fld { fd, addr: a }, panel(&b))
        }
        "fsd" => {
            let fs = reg(0, 'f')?;
            let (a, b) = addr(1)?;
            (Inst::Fsd { fs, addr: a }, panel(&b))
        }
        "fmadd.d" => (
            Inst::FmaddD {
                fd: reg(0, 'f')?,
                fs1: reg(1, 'f')?,
                fs2: reg(2, 'f')?,
                fs3: reg(3, 'f')?,
            },
            None,
        ),
        m if ADDI_LIKE.contains(&m) => (Inst::Addi, None),
        m if BRANCH_LIKE.contains(&m) => {
            let (tok, _col) = op(if m.ends_with('z') { 1 } else { 2 })?;
            target = Some(tok.to_string());
            (Inst::Bnez, None)
        }
        other => {
            let hint_msg = match suggest(other) {
                Some(s) => format!(" (did you mean `{s}`?)"),
                None => String::new(),
            };
            return Err(err(mcol, mspan, format!("unknown mnemonic `{other}`{hint_msg}")));
        }
    };
    let mut li = LocatedInst::new(KernelInst { inst, base }, lineno, mcol, mspan);
    li.target = target;
    li.dialect_hint = hint;
    Ok(li)
}

/// EEW from a load/store mnemonic: RVV 1.0 spells it (`vle64.v`),
/// theadvector takes it from vtype (we default E64, the only
/// theadvector element width in this codebase).
fn parse_eew(m: &str, hint: Option<Dialect>) -> Result<Sew, String> {
    let digits: String = m.chars().filter(|c| c.is_ascii_digit()).collect();
    match (digits.as_str(), hint) {
        ("64", _) => Ok(Sew::E64),
        ("32", _) => Ok(Sew::E32),
        ("", Some(Dialect::Thead071)) => Ok(Sew::E64),
        ("", None) => Err("RVV 1.0 load/store needs an EEW suffix (vle64.v / vle32.v)".into()),
        (d, _) => Err(format!("unsupported EEW `{d}`")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::exec::VecMachine;

    #[test]
    fn assembles_labels_directives_and_comments() {
        let text = "
# BLIS-style fragment
.globl dgemm
.align 2
dgemm:
    vsetvli t0, 2, e64, m1, ta, ma
.loop:
    vle64.v v8, 0(a0)       # A column
    fld f0, 4(a1)
    vfmacc.vf v0, f0, v8
    addi a0, a0, 16
    bnez t1, .loop
    vse64.v v0, 6(a0)
";
        let p = assemble(text).unwrap();
        assert_eq!(p.dialect, Dialect::Rvv10);
        assert_eq!(p.len(), 7);
        assert!(matches!(p.insts[3], Inst::VfmaccVf { vd: 0, fs: 0, vs2: 8 }));
    }

    #[test]
    fn error_carries_file_line_col_and_caret() {
        let text = "addi a0, a0, 8\n    vfmaac.vf v0, f1, v8\n";
        let e = assemble_named(text, "kern.S").unwrap_err();
        assert_eq!((e.file.as_str(), e.line, e.col), ("kern.S", 2, 5));
        assert_eq!(e.span, "vfmaac.vf".len());
        let shown = e.to_string();
        assert!(shown.contains("kern.S:2:5"), "{shown}");
        assert!(shown.contains("did you mean `vfmacc.vf`?"), "{shown}");
        assert!(shown.contains("    ^^^^^^^^^"), "{shown}");
        assert!(shown.contains("vfmaac.vf v0, f1, v8"), "{shown}");
    }

    #[test]
    fn operand_errors_point_at_the_operand() {
        let e = assemble("vfmacc.vf v0, x1, v8\n").unwrap_err();
        assert_eq!((e.line, e.col), (1, 15));
        assert!(e.message.contains("expected f-register"), "{e}");
    }

    #[test]
    fn undefined_and_forward_branch_targets_rejected() {
        let e = assemble("addi a0, a0, 8\nbnez t1, .loop\n").unwrap_err();
        assert!(e.message.contains("`.loop` is not defined"), "{e}");
        let e = assemble("bnez t1, .done\n.done:\n    addi a0, a0, 8\n").unwrap_err();
        assert!(e.message.contains("forward"), "{e}");
    }

    #[test]
    fn macro_directive_rejected_with_dedicated_message() {
        let e = assemble(".macro rank1 n\n.endm\n").unwrap_err();
        assert!(e.message.contains(".macro"), "{e}");
        assert!(e.message.contains("macro-free"), "{e}");
        // unknown directives are errors too (not silently skipped)
        let e = assemble(".wibble 4\n").unwrap_err();
        assert!(e.message.contains("unknown directive"), "{e}");
    }

    #[test]
    fn scalar_bookkeeping_spellings_map_to_markers() {
        let text = "top:\n    li t1, 128\n    mv t2, a0\n    slli t3, t1, 3\n    sub t1, t1, t2\n    bnez t1, top\n";
        let p = assemble(text).unwrap();
        assert_eq!(p.insts, vec![Inst::Addi, Inst::Addi, Inst::Addi, Inst::Addi, Inst::Bnez]);
    }

    #[test]
    fn roundtrip_assemble_disassemble_builtins() {
        use crate::ukernel::KernelRegistry;
        for k in KernelRegistry::builtin().kernels() {
            let (mr, nr) = k.tile();
            let p = k.program(PanelLayout::new(mr, nr, 5));
            let back = assemble(&disassemble(&p)).unwrap_or_else(|e| panic!("{}: {e}", k.id));
            assert_eq!(back, p, "{}", k.id);
        }
    }

    #[test]
    fn kernel_mode_recovers_loop_structure() {
        let text = "
    vsetvli t0, 2, e64, m1, ta, ma
    vle64.v v0, 0(a2)
.loop:
    vle64.v v8, 0(a0)
    fld f0, 0(a1)
    vfmacc.vf v0, f0, v8
    addi a0, a0, 16
    addi a1, a1, 8
    bnez t1, .loop
    vse64.v v0, 0(a2)
";
        let k = assemble_kernel(text, "<t>").unwrap();
        assert_eq!(k.label, ".loop");
        assert_eq!((k.prologue.len(), k.body.len(), k.epilogue.len()), (2, 6, 1));
        assert_eq!(k.prologue[1].base, Some(PanelBase::C));
        assert_eq!(k.body[0].base, Some(PanelBase::A));
        assert_eq!(k.body[1].base, Some(PanelBase::B));
        assert!(k.check(2, 1, 1, 128).is_ok());

        // expansion covers every k-step and executes correctly
        let l = PanelLayout::new(2, 1, 4);
        let p = k.expand(l, 1);
        let mut m = VecMachine::new(128, l.mem_words()).unwrap();
        let a = crate::util::Matrix::random_hpl(2, 4, 1);
        let b = crate::util::Matrix::random_hpl(4, 1, 2);
        let c = crate::util::Matrix::random_hpl(2, 1, 3);
        m.mem = l.pack(&a, &b, &c);
        m.run(&p).unwrap();
        let out = l.unpack_c(&m.mem);
        let mut want = c.clone();
        crate::util::Matrix::gemm_acc(&mut want, &a, &b);
        assert!(out.allclose(&want, 1e-13, 1e-13));
    }

    #[test]
    fn kernel_mode_requires_exactly_one_loop() {
        let no_loop = "vsetvli t0, 2, e64, m1, ta, ma\nvle64.v v0, 0(a2)\n";
        let e = assemble_kernel(no_loop, "<t>").unwrap_err();
        assert!(e.message.contains("found none"), "{e}");
    }

    #[test]
    fn kernel_check_catches_out_of_range_panel_offsets() {
        let text = "
.loop:
    vle64.v v8, 16(a0)
    fld f0, 0(a1)
    vfmacc.vf v0, f0, v8
    bnez t1, .loop
";
        let k = assemble_kernel(text, "<t>").unwrap();
        // A offset 16 needs k_unroll*mr > 16; at mr=2, u=1 it's out
        let e = k.check(2, 1, 1, 128).unwrap_err();
        assert!(e.contains("A-panel offset 16 out of range"), "{e}");
    }

    #[test]
    fn kernel_check_catches_infeasible_vsetvli() {
        let text = "
    vsetvli t0, 8, e64, m1, ta, ma
.loop:
    vle64.v v8, 0(a0)
    fld f0, 0(a1)
    vfmacc.vf v0, f0, v8
    bnez t1, .loop
";
        let k = assemble_kernel(text, "<t>").unwrap();
        // avl=8 at LMUL=1 needs VLEN>=512
        let e = k.check(8, 1, 1, 128).unwrap_err();
        assert!(e.contains("infeasible at VLEN=128"), "{e}");
        assert!(k.check(8, 1, 1, 512).is_ok());
    }

    #[test]
    fn suggestion_metric_is_sane() {
        assert_eq!(suggest("vfmaac.vf"), Some("vfmacc.vf"));
        assert_eq!(suggest("vsetvl"), Some("vsetvli"));
        assert_eq!(suggest("frobnicate"), None);
    }
}
