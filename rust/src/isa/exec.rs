//! Functional RVV machine: executes [`Program`]s on real f64 data.
//!
//! This is what makes the micro-kernel comparison *real* rather than a
//! spreadsheet: the LMUL=1 and LMUL=4 kernels run on this machine and
//! must produce bit-identical GEMM tiles (tested against the naive
//! [`crate::util::Matrix`] oracle and, transitively, against the Pallas
//! kernels through the shared seeds in the integration tests).
//!
//! The machine is VLEN-generic: any power-of-two VLEN >= 64 builds a
//! register file of `32 x VLEN/64` f64 lanes, so descriptor-driven
//! kernel sweeps (`ukernel::ablation`) can explore 64/128/256/512-bit
//! configurations. An unsupported VLEN is a typed load-time
//! [`CimoneError::InvalidKernel`], not a panic.
//!
//! The machine is also SEW-generic: `vsetvli` with `e32` doubles the
//! lanes per register and switches the arithmetic arms to f32
//! rounding — each lane holds the f32 value widened to f64, so memory
//! stays a flat f64 array and mixed-precision kernels (HPL-MxP's
//! SEW=32 GEMM) execute with exactly single-precision numerics.
//!
//! The hot loop allocates nothing: loads/stores are `copy_from_slice`
//! over the flat lane file, splats are `fill`, and the FMA/MUL arms
//! stream both register groups as slices when they don't alias
//! (falling back to the lane-by-lane order only on partial overlap, so
//! the numerics stay bit-identical to the reference semantics).

use super::inst::{Inst, Program};
use super::rvv::{vsetvl, Lmul, Sew, VType};
use crate::error::CimoneError;

/// RVV's architectural VLEN ceiling (2^16 bits) — also what keeps a
/// typo'd spec VLEN from turning into a multi-terabyte register-file
/// allocation instead of a typed error.
pub const MAX_VLEN_BITS: usize = 1 << 16;

/// FP64 lanes per architectural register at a given VLEN.
const fn lanes_per_reg(vlen_bits: usize) -> usize {
    vlen_bits / 64
}

/// Mutable `v[d..d+len]` alongside shared `v[s..s+len]`. The two
/// ranges must be disjoint — the fast-path callers check overlap and
/// take the lane-by-lane fallback otherwise.
fn disjoint_pair(v: &mut [f64], d: usize, s: usize, len: usize) -> (&mut [f64], &[f64]) {
    if d < s {
        let (lo, hi) = v.split_at_mut(s);
        (&mut lo[d..d + len], &hi[..len])
    } else {
        let (lo, hi) = v.split_at_mut(d);
        (&mut hi[..len], &lo[s..s + len])
    }
}

/// The machine state.
#[derive(Debug, Clone)]
pub struct VecMachine {
    pub vlen_bits: usize,
    /// log2(lanes per register) at the *current* SEW — lanes are a
    /// power of two, so group indexing uses shifts/masks instead of
    /// div/mod (hot path). Updated by `vsetvli` (e32 doubles it).
    lane_shift: u32,
    /// 32 architectural vector registers, flattened to `32 x vlen/32`
    /// f64 lanes (sized for SEW=32, the narrowest element width; SEW=64
    /// uses the low half); a register *group* rooted at `v` is the
    /// contiguous lane run starting at `v << lane_shift` (as in
    /// hardware, where LMUL groups span consecutive registers). Under
    /// SEW=32 each lane holds an exact f32 value widened to f64.
    v: Vec<f64>,
    /// 32 scalar FP registers.
    pub f: [f64; 32],
    /// Flat f64 memory, element-addressed.
    pub mem: Vec<f64>,
    /// Current vl (elements) and vtype.
    pub vl: usize,
    pub vtype: VType,
    /// Retired instruction count (for the paper's fetched-instruction metric).
    pub retired: u64,
    /// Retired FP64 FLOPs.
    pub flops: u64,
}

impl VecMachine {
    /// New machine with `mem_elems` f64 words of zeroed memory. VLEN
    /// must be a power of two in 64..=[`MAX_VLEN_BITS`] (RVV's
    /// architectural ceiling); anything else is a typed
    /// [`CimoneError::InvalidKernel`] at construction time.
    pub fn new(vlen_bits: usize, mem_elems: usize) -> Result<Self, CimoneError> {
        if vlen_bits < 64 || vlen_bits > MAX_VLEN_BITS || !vlen_bits.is_power_of_two() {
            return Err(CimoneError::InvalidKernel {
                id: "vec-machine".into(),
                reason: format!(
                    "unsupported VLEN {vlen_bits} (need a power of two in 64..={MAX_VLEN_BITS})"
                ),
            });
        }
        let lanes = lanes_per_reg(vlen_bits);
        Ok(VecMachine {
            vlen_bits,
            lane_shift: lanes.trailing_zeros(),
            // sized for SEW=32 (vlen/32 lanes per register) so a later
            // `vsetvli ... e32` never reallocates; SEW=64 uses a prefix
            v: vec![0.0; 32 * (vlen_bits / 32)],
            f: [0.0; 32],
            mem: vec![0.0; mem_elems],
            vl: 0,
            vtype: VType::new(Sew::E64, Lmul::M1),
            retired: 0,
            flops: 0,
        })
    }

    /// Lanes per register at the current SEW.
    fn lanes(&self) -> usize {
        1 << self.lane_shift
    }

    /// Is the machine currently in 32-bit-element mode?
    #[inline(always)]
    fn e32(&self) -> bool {
        self.vtype.sew == Sew::E32
    }

    /// Lane `lane` of architectural register `vreg` (debug/test access).
    pub fn reg_lane(&self, vreg: u8, lane: usize) -> f64 {
        self.v[((vreg as usize) << self.lane_shift) + lane]
    }

    /// Read lane `i` of the *group* rooted at `vreg` (crosses register
    /// boundaries under LMUL>1, as hardware does).
    #[inline(always)]
    fn group_get(&self, vreg: u8, i: usize) -> f64 {
        self.v[((vreg as usize) << self.lane_shift) + i]
    }

    #[inline(always)]
    fn group_set(&mut self, vreg: u8, i: usize, val: f64) {
        self.v[((vreg as usize) << self.lane_shift) + i] = val;
    }

    /// Execute one instruction. Runtime faults (OOB access, SEW
    /// mismatch, register-file overflow) come back as typed
    /// [`CimoneError::Machine`].
    pub fn step(&mut self, inst: &Inst) -> Result<(), CimoneError> {
        let fault = |msg: String| Err(CimoneError::Machine(msg));
        match *inst {
            Inst::Vsetvli { avl, vtype } => {
                if vtype.lmul.is_fractional() {
                    return fault("fractional LMUL unsupported on this machine".into());
                }
                self.vtype = vtype;
                self.vl = vsetvl(avl, vtype, self.vlen_bits);
                // e32 doubles the lanes per register; group indexing
                // below shifts by the SEW-adjusted lane count
                self.lane_shift = (self.vlen_bits / vtype.sew.bits()).trailing_zeros();
            }
            Inst::Vle { sew, vd, addr } => {
                self.check_sew(sew)?;
                self.check_group(vd)?;
                if addr + self.vl > self.mem.len() {
                    return fault(format!("vle OOB at {}..{}", addr, addr + self.vl));
                }
                let d = (vd as usize) << self.lane_shift;
                if self.e32() {
                    // an e32 load rounds each memory word to f32
                    for i in 0..self.vl {
                        self.v[d + i] = (self.mem[addr + i] as f32) as f64;
                    }
                } else {
                    self.v[d..d + self.vl].copy_from_slice(&self.mem[addr..addr + self.vl]);
                }
            }
            Inst::Vse { sew, vs, addr } => {
                self.check_sew(sew)?;
                self.check_group(vs)?;
                if addr + self.vl > self.mem.len() {
                    return fault(format!("vse OOB at {}..{}", addr, addr + self.vl));
                }
                let s = (vs as usize) << self.lane_shift;
                self.mem[addr..addr + self.vl].copy_from_slice(&self.v[s..s + self.vl]);
            }
            Inst::VfmaccVf { vd, fs, vs2 } => {
                self.check_group(vd)?;
                self.check_group(vs2)?;
                let s = self.f[fs as usize];
                let vl = self.vl;
                let d = (vd as usize) << self.lane_shift;
                let a = (vs2 as usize) << self.lane_shift;
                if self.e32() {
                    // f32 numerics: the same non-fused add/mul order as
                    // the f64 arms, rounded at 32 bits per operation
                    let s32 = s as f32;
                    if d == a {
                        for x in &mut self.v[d..d + vl] {
                            *x = ((*x as f32) + s32 * (*x as f32)) as f64;
                        }
                    } else if d.abs_diff(a) >= vl {
                        let (dst, src) = disjoint_pair(&mut self.v, d, a, vl);
                        for (x, y) in dst.iter_mut().zip(src) {
                            *x = ((*x as f32) + s32 * (*y as f32)) as f64;
                        }
                    } else {
                        for i in 0..vl {
                            let acc = (self.group_get(vd, i) as f32)
                                + s32 * (self.group_get(vs2, i) as f32);
                            self.group_set(vd, i, acc as f64);
                        }
                    }
                } else if d == a {
                    for x in &mut self.v[d..d + vl] {
                        *x += s * *x;
                    }
                } else if d.abs_diff(a) >= vl {
                    let (dst, src) = disjoint_pair(&mut self.v, d, a, vl);
                    for (x, y) in dst.iter_mut().zip(src) {
                        *x += s * *y;
                    }
                } else {
                    // partial group overlap: keep the lane-by-lane
                    // order so each write is visible to later reads
                    for i in 0..vl {
                        let acc = self.group_get(vd, i) + s * self.group_get(vs2, i);
                        self.group_set(vd, i, acc);
                    }
                }
                self.flops += 2 * vl as u64;
            }
            Inst::VfmulVf { vd, fs, vs2 } => {
                self.check_group(vd)?;
                self.check_group(vs2)?;
                let s = self.f[fs as usize];
                let vl = self.vl;
                let d = (vd as usize) << self.lane_shift;
                let a = (vs2 as usize) << self.lane_shift;
                if self.e32() {
                    let s32 = s as f32;
                    if d == a {
                        for x in &mut self.v[d..d + vl] {
                            *x = (s32 * (*x as f32)) as f64;
                        }
                    } else if d.abs_diff(a) >= vl {
                        let (dst, src) = disjoint_pair(&mut self.v, d, a, vl);
                        for (x, y) in dst.iter_mut().zip(src) {
                            *x = (s32 * (*y as f32)) as f64;
                        }
                    } else {
                        for i in 0..vl {
                            let prod = s32 * (self.group_get(vs2, i) as f32);
                            self.group_set(vd, i, prod as f64);
                        }
                    }
                } else if d == a {
                    for x in &mut self.v[d..d + vl] {
                        *x = s * *x;
                    }
                } else if d.abs_diff(a) >= vl {
                    let (dst, src) = disjoint_pair(&mut self.v, d, a, vl);
                    for (x, y) in dst.iter_mut().zip(src) {
                        *x = s * *y;
                    }
                } else {
                    for i in 0..vl {
                        self.group_set(vd, i, s * self.group_get(vs2, i));
                    }
                }
                self.flops += vl as u64;
            }
            Inst::VfmvVf { vd, fs } => {
                self.check_group(vd)?;
                let s = self.f[fs as usize];
                let s = if self.e32() { (s as f32) as f64 } else { s };
                let d = (vd as usize) << self.lane_shift;
                self.v[d..d + self.vl].fill(s);
            }
            Inst::VfaddVv { vd, vs1, vs2 } => {
                self.check_group(vd)?;
                self.check_group(vs1)?;
                self.check_group(vs2)?;
                let e32 = self.e32();
                for i in 0..self.vl {
                    let (a, b) = (self.group_get(vs1, i), self.group_get(vs2, i));
                    let sum =
                        if e32 { ((a as f32) + (b as f32)) as f64 } else { a + b };
                    self.group_set(vd, i, sum);
                }
                self.flops += self.vl as u64;
            }
            Inst::Fld { fd, addr } => {
                self.f[fd as usize] = *self
                    .mem
                    .get(addr)
                    .ok_or_else(|| CimoneError::Machine(format!("fld OOB at {addr}")))?;
            }
            Inst::Fsd { fs, addr } => {
                let v = self.f[fs as usize];
                *self
                    .mem
                    .get_mut(addr)
                    .ok_or_else(|| CimoneError::Machine(format!("fsd OOB at {addr}")))? = v;
            }
            Inst::FmaddD { fd, fs1, fs2, fs3 } => {
                self.f[fd as usize] =
                    self.f[fs1 as usize].mul_add(self.f[fs2 as usize], self.f[fs3 as usize]);
                self.flops += 2;
            }
            Inst::Addi | Inst::Bnez => {}
        }
        self.retired += 1;
        Ok(())
    }

    /// Run a whole program: typed validation
    /// ([`CimoneError::InvalidProgram`]) before any instruction runs,
    /// then typed runtime faults ([`CimoneError::Machine`]) per step.
    pub fn run(&mut self, prog: &Program) -> Result<(), CimoneError> {
        prog.validate_register_groups(self.vlen_bits)?;
        for inst in &prog.insts {
            self.step(inst)?;
        }
        Ok(())
    }

    fn check_sew(&self, sew: Sew) -> Result<(), CimoneError> {
        if sew != self.vtype.sew {
            return Err(CimoneError::Machine(format!(
                "SEW mismatch: inst {:?}, vtype {:?}",
                sew, self.vtype.sew
            )));
        }
        Ok(())
    }

    fn check_group(&self, vreg: u8) -> Result<(), CimoneError> {
        let need = self.vl.div_ceil(self.lanes().max(1)).max(1);
        if vreg as usize + need > 32 {
            return Err(CimoneError::Machine(format!(
                "register group v{vreg} (+{need}) out of file"
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::inst::Dialect;

    fn m128() -> VecMachine {
        VecMachine::new(128, 256).unwrap()
    }

    fn vt(lmul: Lmul) -> VType {
        VType::new(Sew::E64, lmul)
    }

    #[test]
    fn vle_vse_roundtrip_m1() {
        let mut m = m128();
        m.mem[0] = 1.5;
        m.mem[1] = -2.5;
        m.step(&Inst::Vsetvli { avl: 2, vtype: vt(Lmul::M1) }).unwrap();
        m.step(&Inst::Vle { sew: Sew::E64, vd: 0, addr: 0 }).unwrap();
        m.step(&Inst::Vse { sew: Sew::E64, vs: 0, addr: 10 }).unwrap();
        assert_eq!(m.mem[10], 1.5);
        assert_eq!(m.mem[11], -2.5);
    }

    #[test]
    fn lmul4_load_spans_four_registers() {
        let mut m = m128();
        for i in 0..8 {
            m.mem[i] = i as f64;
        }
        m.step(&Inst::Vsetvli { avl: 8, vtype: vt(Lmul::M4) }).unwrap();
        assert_eq!(m.vl, 8);
        m.step(&Inst::Vle { sew: Sew::E64, vd: 4, addr: 0 }).unwrap();
        // lanes must land across v4..v7
        assert_eq!(m.reg_lane(4, 0), 0.0);
        assert_eq!(m.reg_lane(4, 1), 1.0);
        assert_eq!(m.reg_lane(5, 0), 2.0);
        assert_eq!(m.reg_lane(7, 1), 7.0);
    }

    #[test]
    fn vfmacc_vf_computes_fma() {
        let mut m = m128();
        m.mem[0] = 2.0;
        m.mem[1] = 3.0;
        m.f[1] = 10.0;
        m.step(&Inst::Vsetvli { avl: 2, vtype: vt(Lmul::M1) }).unwrap();
        m.step(&Inst::Vle { sew: Sew::E64, vd: 8, addr: 0 }).unwrap();
        // v0 starts zero: v0 += f1 * v8
        m.step(&Inst::VfmaccVf { vd: 0, fs: 1, vs2: 8 }).unwrap();
        m.step(&Inst::Vse { sew: Sew::E64, vs: 0, addr: 4 }).unwrap();
        assert_eq!(m.mem[4], 20.0);
        assert_eq!(m.mem[5], 30.0);
        assert_eq!(m.flops, 4);
    }

    #[test]
    fn vfmacc_lmul4_rank1_column() {
        // the paper's Fig 2b: ONE vfmacc updates an 8-element column
        let mut m = m128();
        for i in 0..8 {
            m.mem[i] = (i + 1) as f64; // column of A
        }
        m.f[0] = 2.0; // b scalar
        m.step(&Inst::Vsetvli { avl: 8, vtype: vt(Lmul::M4) }).unwrap();
        m.step(&Inst::Vle { sew: Sew::E64, vd: 8, addr: 0 }).unwrap();
        m.step(&Inst::VfmaccVf { vd: 0, fs: 0, vs2: 8 }).unwrap();
        m.step(&Inst::Vse { sew: Sew::E64, vs: 0, addr: 16 }).unwrap();
        for i in 0..8 {
            assert_eq!(m.mem[16 + i], 2.0 * (i + 1) as f64);
        }
        assert_eq!(m.flops, 16);
    }

    #[test]
    fn scalar_fmadd_matches_mul_add() {
        let mut m = m128();
        m.f[1] = 3.0;
        m.f[2] = 4.0;
        m.f[3] = 0.5;
        m.step(&Inst::FmaddD { fd: 0, fs1: 1, fs2: 2, fs3: 3 }).unwrap();
        assert_eq!(m.f[0], 12.5);
    }

    #[test]
    fn oob_load_is_error_not_panic() {
        let mut m = VecMachine::new(128, 4).unwrap();
        m.step(&Inst::Vsetvli { avl: 2, vtype: vt(Lmul::M1) }).unwrap();
        assert!(m.step(&Inst::Vle { sew: Sew::E64, vd: 0, addr: 3 }).is_err());
        assert!(m.step(&Inst::Fld { fd: 0, addr: 99 }).is_err());
    }

    #[test]
    fn unsupported_vlen_is_a_typed_error_not_a_panic() {
        // the seed asserted on anything but {128, 256}; now 64/512/1024
        // build and bad widths are typed errors
        for bad in [0usize, 32, 96, 100, 130, MAX_VLEN_BITS * 2] {
            match VecMachine::new(bad, 16) {
                Err(CimoneError::InvalidKernel { reason, .. }) => {
                    assert!(reason.contains("VLEN"), "{reason}");
                }
                other => panic!("VLEN {bad}: expected InvalidKernel, got {other:?}"),
            }
        }
        for good in [64usize, 128, 256, 512, 1024] {
            assert!(VecMachine::new(good, 16).is_ok(), "VLEN {good}");
        }
    }

    #[test]
    fn vlen64_machine_has_one_lane_per_register() {
        let mut m = VecMachine::new(64, 16).unwrap();
        m.mem[0] = 7.0;
        m.mem[1] = 8.0;
        m.step(&Inst::Vsetvli { avl: 2, vtype: vt(Lmul::M2) }).unwrap();
        assert_eq!(m.vl, 2, "VLEN=64 m2 group holds 2 f64 lanes");
        m.step(&Inst::Vle { sew: Sew::E64, vd: 2, addr: 0 }).unwrap();
        // the group spans v2 and v3, one lane each
        assert_eq!(m.reg_lane(2, 0), 7.0);
        assert_eq!(m.reg_lane(3, 0), 8.0);
    }

    #[test]
    fn vlen512_lmul1_holds_a_whole_column() {
        let mut m = VecMachine::new(512, 32).unwrap();
        for i in 0..8 {
            m.mem[i] = i as f64;
        }
        m.step(&Inst::Vsetvli { avl: 8, vtype: vt(Lmul::M1) }).unwrap();
        assert_eq!(m.vl, 8, "512/64 = 8 lanes in ONE register");
        m.step(&Inst::Vle { sew: Sew::E64, vd: 31, addr: 0 }).unwrap();
        assert_eq!(m.reg_lane(31, 7), 7.0);
    }

    #[test]
    fn sew_mismatch_detected() {
        let mut m = m128();
        m.step(&Inst::Vsetvli { avl: 2, vtype: vt(Lmul::M1) }).unwrap();
        assert!(m.step(&Inst::Vle { sew: Sew::E32, vd: 0, addr: 0 }).is_err());
    }

    #[test]
    fn program_run_validates_groups() {
        let mut p = Program::new(Dialect::Rvv10);
        p.push(Inst::Vsetvli { avl: 8, vtype: vt(Lmul::M4) });
        p.push(Inst::Vle { sew: Sew::E64, vd: 3, addr: 0 }); // misaligned
        assert!(m128().run(&p).is_err());
    }

    #[test]
    fn retired_and_flops_counted() {
        let mut m = m128();
        let mut p = Program::new(Dialect::Rvv10);
        p.push(Inst::Vsetvli { avl: 2, vtype: vt(Lmul::M1) });
        p.push(Inst::VfmaccVf { vd: 0, fs: 0, vs2: 4 });
        p.push(Inst::Addi);
        m.run(&p).unwrap();
        assert_eq!(m.retired, 3);
        assert_eq!(m.flops, 4);
    }

    #[test]
    fn fractional_lmul_rejected() {
        let mut m = m128();
        let bad = VType::new(Sew::E64, Lmul::Fractional);
        assert!(m.step(&Inst::Vsetvli { avl: 2, vtype: bad }).is_err());
    }

    #[test]
    fn aliased_fmacc_updates_in_place() {
        // vd == vs2 takes the in-place path: x += s * x
        let mut m = m128();
        m.f[0] = 3.0;
        m.mem[0] = 1.0;
        m.mem[1] = 2.0;
        m.step(&Inst::Vsetvli { avl: 2, vtype: vt(Lmul::M1) }).unwrap();
        m.step(&Inst::Vle { sew: Sew::E64, vd: 4, addr: 0 }).unwrap();
        m.step(&Inst::VfmaccVf { vd: 4, fs: 0, vs2: 4 }).unwrap();
        m.step(&Inst::Vse { sew: Sew::E64, vs: 4, addr: 8 }).unwrap();
        assert_eq!(m.mem[8], 4.0);
        assert_eq!(m.mem[9], 8.0);
        assert_eq!(m.flops, 4);
    }

    #[test]
    fn partially_overlapping_groups_keep_lane_order_semantics() {
        // vd=1 overlaps vs2=0 by all but one register at LMUL=4: lane i
        // of the source is read *after* destination lane i-2 was
        // written, so the fallback's sequential feedback must survive
        let mut m = m128();
        for i in 0..8 {
            m.mem[i] = (i + 1) as f64;
        }
        m.f[0] = 2.0;
        m.step(&Inst::Vsetvli { avl: 8, vtype: vt(Lmul::M4) }).unwrap();
        m.step(&Inst::Vle { sew: Sew::E64, vd: 0, addr: 0 }).unwrap();
        m.step(&Inst::VfmaccVf { vd: 1, fs: 0, vs2: 0 }).unwrap();
        // reference: the flat lane file v[0..10], updated lane by lane
        let mut arr = [0.0f64; 10];
        for (i, a) in arr.iter_mut().take(8).enumerate() {
            *a = (i + 1) as f64;
        }
        for i in 0..8 {
            arr[2 + i] += 2.0 * arr[i];
        }
        for (i, want) in arr[2..].iter().enumerate() {
            assert_eq!(m.reg_lane(1, i), *want, "lane {i}");
        }
    }

    #[test]
    fn e32_doubles_the_lanes_per_register() {
        let mut m = m128();
        m.step(&Inst::Vsetvli { avl: 8, vtype: VType::new(Sew::E32, Lmul::M1) }).unwrap();
        assert_eq!(m.vl, 4, "VLEN=128 e32 m1 holds 4 lanes (vs 2 at e64)");
        m.step(&Inst::Vsetvli { avl: 8, vtype: vt(Lmul::M1) }).unwrap();
        assert_eq!(m.vl, 2, "switching back to e64 restores the lane count");
    }

    #[test]
    fn e32_arithmetic_rounds_at_single_precision() {
        let mut m = m128();
        // 0.1 is inexact in both widths; the f32 rounding must show
        m.mem[0] = 0.1;
        m.f[0] = 0.1;
        m.step(&Inst::Vsetvli { avl: 4, vtype: VType::new(Sew::E32, Lmul::M1) }).unwrap();
        m.step(&Inst::Vle { sew: Sew::E32, vd: 8, addr: 0 }).unwrap();
        assert_eq!(m.reg_lane(8, 0), (0.1f32) as f64, "e32 load rounds to f32");
        m.step(&Inst::VfmulVf { vd: 4, fs: 0, vs2: 8 }).unwrap();
        let want = ((0.1f64 as f32) * (0.1f32)) as f64;
        assert_eq!(m.reg_lane(4, 0).to_bits(), want.to_bits());
        assert_ne!(m.reg_lane(4, 0), 0.1 * 0.1, "f64 product would differ");
    }

    #[test]
    fn e32_sew_mismatch_detected_both_ways() {
        let mut m = m128();
        m.step(&Inst::Vsetvli { avl: 2, vtype: VType::new(Sew::E32, Lmul::M1) }).unwrap();
        assert!(m.step(&Inst::Vle { sew: Sew::E64, vd: 0, addr: 0 }).is_err());
        assert!(m.step(&Inst::Vle { sew: Sew::E32, vd: 0, addr: 0 }).is_ok());
    }

    #[test]
    fn e32_fast_paths_are_bit_identical_across_vlens() {
        // the e32 mirror of the f64 cross-VLEN suite: same program, any
        // VLEN, bit-identical f32-rounded lanes
        for vlen in [64usize, 128, 256, 512] {
            let mut m = VecMachine::new(vlen, 128).unwrap();
            for i in 0..32 {
                m.mem[i] = (i as f64) * 0.375 - 2.0;
            }
            m.f[0] = 1.0 / 3.0;
            let e32 = VType::new(Sew::E32, Lmul::M4);
            m.step(&Inst::Vsetvli { avl: 16, vtype: e32 }).unwrap();
            let vl = m.vl;
            assert_eq!(vl, (16).min(4 * vlen / 32), "VLEN {vlen}");
            m.step(&Inst::Vle { sew: Sew::E32, vd: 8, addr: 0 }).unwrap();
            m.step(&Inst::VfmvVf { vd: 0, fs: 0 }).unwrap();
            m.step(&Inst::VfmaccVf { vd: 0, fs: 0, vs2: 8 }).unwrap();
            m.step(&Inst::VfmulVf { vd: 16, fs: 0, vs2: 0 }).unwrap();
            m.step(&Inst::Vse { sew: Sew::E32, vs: 16, addr: 64 }).unwrap();
            let s = (1.0f64 / 3.0) as f32;
            for i in 0..vl {
                let x = ((i as f64) * 0.375 - 2.0) as f32;
                let want = (s * (s + s * x)) as f64;
                assert_eq!(
                    m.mem[64 + i].to_bits(),
                    want.to_bits(),
                    "VLEN {vlen} lane {i}"
                );
            }
        }
    }

    #[test]
    fn fast_paths_are_bit_identical_across_vlens() {
        // the slice fast paths must retire exactly the per-lane
        // arithmetic: same program, any VLEN, bit-identical lanes
        for vlen in [64usize, 128, 256, 512] {
            let mut m = VecMachine::new(vlen, 64).unwrap();
            for i in 0..16 {
                m.mem[i] = (i as f64) * 0.375 - 2.0;
            }
            m.f[0] = 1.0 / 3.0; // rounding-sensitive scalar
            m.step(&Inst::Vsetvli { avl: 8, vtype: vt(Lmul::M4) }).unwrap();
            let vl = m.vl;
            m.step(&Inst::Vle { sew: Sew::E64, vd: 8, addr: 0 }).unwrap();
            m.step(&Inst::VfmvVf { vd: 0, fs: 0 }).unwrap();
            m.step(&Inst::VfmaccVf { vd: 0, fs: 0, vs2: 8 }).unwrap();
            m.step(&Inst::VfmulVf { vd: 16, fs: 0, vs2: 0 }).unwrap();
            m.step(&Inst::Vse { sew: Sew::E64, vs: 16, addr: 32 }).unwrap();
            let s = 1.0f64 / 3.0;
            for i in 0..vl {
                let x = (i as f64) * 0.375 - 2.0;
                let want = s * (s + s * x);
                assert_eq!(
                    m.mem[32 + i].to_bits(),
                    want.to_bits(),
                    "VLEN {vlen} lane {i}"
                );
            }
        }
    }
}
