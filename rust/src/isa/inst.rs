//! Instruction IR for the GEMM micro-kernels.
//!
//! Deliberately small: exactly the instructions appearing in BLIS's RVV
//! rank-1-update micro-kernel and OpenBLAS's C920 DGEMM kernel, plus the
//! scalar bookkeeping (address bumps, loop branches) that contributes to
//! the fetched-instruction count the paper optimizes.
//!
//! Addresses are *element indices* into the machine's flat f64 memory —
//! the cycle model charges them like byte addresses and the functional
//! executor indexes with them directly.

use super::rvv::{Lmul, Sew, VType};
use crate::error::CimoneError;

/// Which assembly dialect a program is written in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dialect {
    /// RVV 1.0 (`rv64iv` target) — what BLIS ships.
    Rvv10,
    /// RVV 0.7.1 / XuanTie `theadvector` (`th.` prefixed mnemonics) —
    /// what the SG2042 executes.
    Thead071,
}

/// One instruction. `v*` fields are vector register numbers (0..32),
/// `f*` scalar FP registers, `x*` integer registers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Inst {
    /// `vsetvli rd, avl, <vtype>` — set vl/vtype. avl is immediate here.
    Vsetvli { avl: usize, vtype: VType },
    /// Unit-stride vector load of the current register group at `vd`.
    Vle { sew: Sew, vd: u8, addr: usize },
    /// Unit-stride vector store.
    Vse { sew: Sew, vs: u8, addr: usize },
    /// `vfmacc.vf vd, fs, vs2` — vd[i] += f[fs] * vs2[i].
    VfmaccVf { vd: u8, fs: u8, vs2: u8 },
    /// `vfmul.vf vd, fs, vs2`.
    VfmulVf { vd: u8, fs: u8, vs2: u8 },
    /// Splat scalar into a vector group: `vfmv.v.f vd, fs`.
    VfmvVf { vd: u8, fs: u8 },
    /// Vector-vector add (used by stream kernels): vd = vs1 + vs2.
    VfaddVv { vd: u8, vs1: u8, vs2: u8 },
    /// Scalar FP64 load `fld fd, addr`.
    Fld { fd: u8, addr: usize },
    /// Scalar FP64 store `fsd fs, addr`.
    Fsd { fs: u8, addr: usize },
    /// Scalar fused multiply-add `fmadd.d fd, fs1, fs2, fs3`
    /// (fd = fs1*fs2 + fs3) — the whole OpenBLAS generic kernel.
    FmaddD { fd: u8, fs1: u8, fs2: u8, fs3: u8 },
    /// Scalar address bump / loop counter op (functionally a no-op for
    /// FP state; charged by the cycle model).
    Addi,
    /// Loop back-edge (functionally a no-op marker; charged as a branch).
    Bnez,
}

impl Inst {
    /// Is this a vector-unit instruction?
    pub fn is_vector(&self) -> bool {
        matches!(
            self,
            Inst::Vle { .. }
                | Inst::Vse { .. }
                | Inst::VfmaccVf { .. }
                | Inst::VfmulVf { .. }
                | Inst::VfmvVf { .. }
                | Inst::VfaddVv { .. }
        )
    }

    /// Does this instruction use the load/store unit?
    pub fn is_mem(&self) -> bool {
        matches!(self, Inst::Vle { .. } | Inst::Vse { .. } | Inst::Fld { .. } | Inst::Fsd { .. })
    }

    /// FP64 FLOPs retired (given current vl for vector ops).
    pub fn flops(&self, vl: usize) -> usize {
        match self {
            Inst::VfmaccVf { .. } => 2 * vl,
            Inst::VfmulVf { .. } => vl,
            Inst::VfaddVv { .. } => vl,
            Inst::FmaddD { .. } => 2,
            _ => 0,
        }
    }
}

/// A straight-line instruction sequence tagged with its dialect.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    pub dialect: Dialect,
    pub insts: Vec<Inst>,
}

impl Program {
    pub fn new(dialect: Dialect) -> Self {
        Program { dialect, insts: Vec::new() }
    }

    pub fn push(&mut self, i: Inst) -> &mut Self {
        self.insts.push(i);
        self
    }

    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    /// Instruction-mix counts: (vector, scalar-mem, scalar-other).
    pub fn mix(&self) -> (usize, usize, usize) {
        let mut v = 0;
        let mut m = 0;
        let mut s = 0;
        for i in &self.insts {
            if i.is_vector() {
                v += 1;
            } else if i.is_mem() {
                m += 1;
            } else {
                s += 1;
            }
        }
        (v, m, s)
    }

    /// Largest register-group alignment used; LMUL=4 ops must address
    /// v0/v4/v8/... — validated here (a real RVV constraint that bites
    /// when retrofitting kernels). Violations are typed
    /// [`CimoneError::InvalidProgram`] carrying the faulting
    /// instruction's index.
    pub fn validate_register_groups(&self, vlen_bits: usize) -> Result<(), CimoneError> {
        let _ = vlen_bits; // group rules depend only on LMUL (32 arch regs)
        let fail = |inst: usize, reason: String| Err(CimoneError::InvalidProgram { inst, reason });
        let mut vtype = VType::new(Sew::E64, Lmul::M1);
        for (idx, inst) in self.insts.iter().enumerate() {
            match inst {
                Inst::Vsetvli { vtype: vt, .. } => vtype = *vt,
                Inst::Vle { vd, .. } | Inst::Vse { vs: vd, .. } => {
                    let m = vtype.lmul.multiplier();
                    if *vd as usize % m != 0 {
                        return fail(idx, format!("v{vd} not aligned to LMUL={m} group"));
                    }
                    if *vd as usize + m > 32 {
                        return fail(idx, format!("group v{vd}..v{} overflows", vd + m as u8));
                    }
                }
                Inst::VfmaccVf { vd, vs2, .. }
                | Inst::VfmulVf { vd, vs2, .. }
                | Inst::VfaddVv { vd, vs1: _, vs2 } => {
                    let m = vtype.lmul.multiplier();
                    for r in [*vd, *vs2] {
                        if r as usize % m != 0 {
                            return fail(idx, format!("v{r} not aligned to LMUL={m}"));
                        }
                    }
                }
                Inst::VfmvVf { vd, .. } => {
                    let m = vtype.lmul.multiplier();
                    if *vd as usize % m != 0 {
                        return fail(idx, format!("v{vd} not aligned to LMUL={m}"));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vt(lmul: Lmul) -> VType {
        VType::new(Sew::E64, lmul)
    }

    #[test]
    fn mix_counts() {
        let mut p = Program::new(Dialect::Rvv10);
        p.push(Inst::Vsetvli { avl: 2, vtype: vt(Lmul::M1) });
        p.push(Inst::Vle { sew: Sew::E64, vd: 0, addr: 0 });
        p.push(Inst::Fld { fd: 0, addr: 10 });
        p.push(Inst::VfmaccVf { vd: 4, fs: 0, vs2: 0 });
        p.push(Inst::Addi);
        let (v, m, s) = p.mix();
        assert_eq!((v, m, s), (2, 1, 2)); // vsetvli counts as scalar-other
    }

    #[test]
    fn flops_per_inst() {
        assert_eq!(Inst::VfmaccVf { vd: 0, fs: 0, vs2: 4 }.flops(8), 16);
        assert_eq!(Inst::FmaddD { fd: 0, fs1: 1, fs2: 2, fs3: 0 }.flops(8), 2);
        assert_eq!(Inst::Vle { sew: Sew::E64, vd: 0, addr: 0 }.flops(8), 0);
    }

    #[test]
    fn group_alignment_enforced() {
        let mut p = Program::new(Dialect::Rvv10);
        p.push(Inst::Vsetvli { avl: 8, vtype: vt(Lmul::M4) });
        p.push(Inst::Vle { sew: Sew::E64, vd: 2, addr: 0 }); // v2 not /4
        assert!(p.validate_register_groups(128).is_err());

        let mut ok = Program::new(Dialect::Rvv10);
        ok.push(Inst::Vsetvli { avl: 8, vtype: vt(Lmul::M4) });
        ok.push(Inst::Vle { sew: Sew::E64, vd: 4, addr: 0 });
        assert!(ok.validate_register_groups(128).is_ok());
    }

    #[test]
    fn group_overflow_detected() {
        let mut p = Program::new(Dialect::Rvv10);
        p.push(Inst::Vsetvli { avl: 16, vtype: vt(Lmul::M8) });
        p.push(Inst::Vle { sew: Sew::E64, vd: 28, addr: 0 }); // v28..v36 overflows... wait 28%8!=0
        assert!(p.validate_register_groups(128).is_err());
    }
}
