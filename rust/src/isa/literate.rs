//! Literate ISA conformance suite: markdown in, assertions out.
//!
//! A `.cim.md` file is ordinary markdown documenting an ISA behaviour,
//! with fenced code blocks the test harness executes (modeled on
//! nullbyte-directive's `tests/isa/*.n1.md` conformance format):
//!
//! - ` ```asm ` — an assembly listing, assembled by
//!   [`crate::isa::assembler`]. It becomes the *current program* for
//!   the expectation blocks that follow.
//! - ` ```expect ` — `key = value` assertions against the current
//!   program: static properties (`dialect`, `insts`, `mix = v, m, s`),
//!   executed lane state (`vlen`, `mem`, `mem.in[i]`, `f.in[i]`,
//!   `mem.out[i]`, `flops`, `retired` — run on a [`VecMachine`]), and
//!   analyzed timing (`cycles = lo .. hi` on the C920 model).
//! - ` ```expect-error ` — the *listing must fail to assemble*, with
//!   `line`/`col`/`contains` assertions against the [`AsmError`].
//!
//! Every `asm` block must be followed by at least one expectation block
//! (a listing nobody checks is a vacuous conformance case, and an
//! assembly failure without an `expect-error` is a real failure). The
//! runner reports failures as `file:line: message` against the markdown
//! source, so a broken case points at the exact fenced block.

use std::path::Path;

use super::assembler::{assemble_named, AsmError};
use super::exec::VecMachine;
use super::inst::{Dialect, Program};
use super::timing::CycleModel;
use crate::arch::presets::c920;

/// Run one `.cim.md` file; returns the number of expectation blocks that
/// passed, or the first failure as `file:line: message`.
pub fn run_file(path: &Path) -> Result<usize, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    run_str(&text, &path.display().to_string())
}

/// [`run_file`] over in-memory text (unit tests, doc examples).
pub fn run_str(text: &str, name: &str) -> Result<usize, String> {
    let blocks = fenced_blocks(text, name)?;
    let mut passed = 0usize;
    let mut current: Option<(Result<Program, AsmError>, usize)> = None; // (result, block line)
    let mut checked = true;
    for b in &blocks {
        match b.kind {
            BlockKind::Asm => {
                finish_case(&current, checked, name)?;
                current = Some((assemble_named(&b.text, name), b.line));
                checked = false;
            }
            BlockKind::Expect => {
                let (res, _) = current
                    .as_ref()
                    .ok_or_else(|| format!("{name}:{}: expect block before any asm block", b.line))?;
                let p = res.as_ref().map_err(|e| {
                    format!("{name}:{}: listing failed to assemble: {e}", b.line)
                })?;
                check_expect(p, &b.text, name, b.line)?;
                checked = true;
                passed += 1;
            }
            BlockKind::ExpectError => {
                let (res, _) = current
                    .as_ref()
                    .ok_or_else(|| format!("{name}:{}: expect-error before any asm block", b.line))?;
                let e = match res {
                    Err(e) => e,
                    Ok(_) => {
                        return Err(format!(
                            "{name}:{}: listing assembled but expect-error demands failure",
                            b.line
                        ))
                    }
                };
                check_expect_error(e, &b.text, name, b.line)?;
                checked = true;
                passed += 1;
            }
        }
    }
    finish_case(&current, checked, name)?;
    if passed == 0 {
        return Err(format!("{name}: no conformance cases found (no fenced asm/expect blocks)"));
    }
    Ok(passed)
}

fn finish_case(
    current: &Option<(Result<Program, AsmError>, usize)>,
    checked: bool,
    name: &str,
) -> Result<(), String> {
    if let Some((res, line)) = current {
        if !checked {
            return match res {
                Ok(_) => Err(format!(
                    "{name}:{line}: asm block has no expect/expect-error block — vacuous case"
                )),
                Err(e) => Err(format!("{name}:{line}: listing failed to assemble: {e}")),
            };
        }
    }
    Ok(())
}

enum BlockKind {
    Asm,
    Expect,
    ExpectError,
}

struct Block {
    kind: BlockKind,
    /// 1-based markdown line of the opening fence.
    line: usize,
    text: String,
}

fn fenced_blocks(text: &str, name: &str) -> Result<Vec<Block>, String> {
    let mut blocks = Vec::new();
    let mut open: Option<(Option<BlockKind>, usize, Vec<&str>)> = None;
    for (idx, raw) in text.lines().enumerate() {
        let line = idx + 1;
        let trimmed = raw.trim_start();
        if let Some(info) = trimmed.strip_prefix("```") {
            match open.take() {
                None => {
                    let kind = match info.trim() {
                        "asm" => Some(BlockKind::Asm),
                        "expect" => Some(BlockKind::Expect),
                        "expect-error" => Some(BlockKind::ExpectError),
                        _ => None, // plain prose fence — collected but ignored
                    };
                    open = Some((kind, line, Vec::new()));
                }
                Some((kind, start, body)) => {
                    if let Some(kind) = kind {
                        blocks.push(Block { kind, line: start, text: body.join("\n") });
                    }
                }
            }
            continue;
        }
        if let Some((_, _, body)) = open.as_mut() {
            body.push(raw);
        }
    }
    if let Some((_, start, _)) = open {
        return Err(format!("{name}:{start}: unterminated fenced block"));
    }
    Ok(blocks)
}

/// `key = value` pairs from an expectation block (`#` comments allowed).
fn pairs(text: &str, name: &str, line: usize) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    for raw in text.lines() {
        let code = raw.split('#').next().unwrap_or("").trim();
        if code.is_empty() {
            continue;
        }
        let (k, v) = code
            .split_once('=')
            .ok_or_else(|| format!("{name}:{line}: expectation line `{code}` is not key = value"))?;
        out.push((k.trim().to_string(), v.trim().to_string()));
    }
    Ok(out)
}

fn check_expect(p: &Program, text: &str, name: &str, line: usize) -> Result<(), String> {
    let fail = |msg: String| Err(format!("{name}:{line}: {msg}"));
    let mut vlen = 128usize;
    let mut mem_words = 64usize;
    let mut mem_in: Vec<(usize, f64)> = Vec::new();
    let mut f_in: Vec<(usize, f64)> = Vec::new();
    let mut mem_out: Vec<(usize, f64)> = Vec::new();
    let mut want_flops: Option<u64> = None;
    let mut want_retired: Option<u64> = None;
    let mut want_cycles: Option<(f64, f64)> = None;

    for (k, v) in pairs(text, name, line)? {
        match k.as_str() {
            "dialect" => {
                let want = match v.as_str() {
                    "rvv10" => Dialect::Rvv10,
                    "thead071" => Dialect::Thead071,
                    other => return fail(format!("unknown dialect `{other}`")),
                };
                if p.dialect != want {
                    return fail(format!("dialect: want {want:?}, got {:?}", p.dialect));
                }
            }
            "insts" => {
                let want: usize = parse_num(&v, &k, name, line)?;
                if p.len() != want {
                    return fail(format!("insts: want {want}, got {}", p.len()));
                }
            }
            "mix" => {
                let got = p.mix();
                let parts: Vec<&str> = v.split(',').map(str::trim).collect();
                if parts.len() != 3 {
                    return fail(format!("mix wants `v, m, s`, got `{v}`"));
                }
                let want = (
                    parse_num::<usize>(parts[0], &k, name, line)?,
                    parse_num::<usize>(parts[1], &k, name, line)?,
                    parse_num::<usize>(parts[2], &k, name, line)?,
                );
                if got != want {
                    return fail(format!("mix: want {want:?}, got {got:?}"));
                }
            }
            "vlen" => vlen = parse_num(&v, &k, name, line)?,
            "mem" => mem_words = parse_num(&v, &k, name, line)?,
            "flops" => want_flops = Some(parse_num(&v, &k, name, line)?),
            "retired" => want_retired = Some(parse_num(&v, &k, name, line)?),
            "cycles" => {
                let (lo, hi) = v
                    .split_once("..")
                    .ok_or_else(|| format!("{name}:{line}: cycles wants `lo .. hi`, got `{v}`"))?;
                want_cycles = Some((
                    parse_num(lo.trim(), &k, name, line)?,
                    parse_num(hi.trim(), &k, name, line)?,
                ));
            }
            _ if k.starts_with("mem.in[") => {
                mem_in.push((index_of(&k, "mem.in", name, line)?, parse_num(&v, &k, name, line)?))
            }
            _ if k.starts_with("f.in[") => {
                f_in.push((index_of(&k, "f.in", name, line)?, parse_num(&v, &k, name, line)?))
            }
            _ if k.starts_with("mem.out[") => {
                mem_out.push((index_of(&k, "mem.out", name, line)?, parse_num(&v, &k, name, line)?))
            }
            other => return fail(format!("unknown expectation key `{other}`")),
        }
    }

    let must_execute = want_flops.is_some()
        || want_retired.is_some()
        || !mem_out.is_empty()
        || !mem_in.is_empty()
        || !f_in.is_empty();
    if must_execute {
        let mut m = VecMachine::new(vlen, mem_words).map_err(|e| format!("{name}:{line}: {e}"))?;
        for (i, x) in &mem_in {
            if *i >= m.mem.len() {
                return fail(format!("mem.in[{i}] outside mem = {mem_words}"));
            }
            m.mem[*i] = *x;
        }
        for (i, x) in &f_in {
            if *i >= 32 {
                return fail(format!("f.in[{i}] outside the 32-entry FP file"));
            }
            m.f[*i] = *x;
        }
        m.run(p).map_err(|e| format!("{name}:{line}: execution failed: {e}"))?;
        if let Some(want) = want_flops {
            if m.flops != want {
                return fail(format!("flops: want {want}, got {}", m.flops));
            }
        }
        if let Some(want) = want_retired {
            if m.retired != want {
                return fail(format!("retired: want {want}, got {}", m.retired));
            }
        }
        for (i, want) in &mem_out {
            if *i >= m.mem.len() {
                return fail(format!("mem.out[{i}] outside mem = {mem_words}"));
            }
            let got = m.mem[*i];
            if (got - want).abs() > 1e-12 * want.abs().max(1.0) {
                return fail(format!("mem.out[{i}]: want {want}, got {got}"));
            }
        }
    }
    if let Some((lo, hi)) = want_cycles {
        let core = c920();
        let t = CycleModel::new(&core).analyze_at(p, vlen);
        if !(lo..=hi).contains(&t.cycles) {
            return fail(format!("cycles: want {lo}..{hi} on c920, got {:.3}", t.cycles));
        }
    }
    Ok(())
}

fn check_expect_error(e: &AsmError, text: &str, name: &str, line: usize) -> Result<(), String> {
    let fail = |msg: String| Err(format!("{name}:{line}: {msg}"));
    for (k, v) in pairs(text, name, line)? {
        match k.as_str() {
            "line" => {
                let want: usize = parse_num(&v, &k, name, line)?;
                if e.line != want {
                    return fail(format!("error line: want {want}, got {} ({e})", e.line));
                }
            }
            "col" => {
                let want: usize = parse_num(&v, &k, name, line)?;
                if e.col != want {
                    return fail(format!("error col: want {want}, got {} ({e})", e.col));
                }
            }
            "contains" => {
                if !e.to_string().contains(&v) {
                    return fail(format!("error does not contain `{v}`: {e}"));
                }
            }
            other => return fail(format!("unknown expect-error key `{other}`")),
        }
    }
    Ok(())
}

fn index_of(key: &str, prefix: &str, name: &str, line: usize) -> Result<usize, String> {
    key.strip_prefix(prefix)
        .and_then(|s| s.strip_prefix('['))
        .and_then(|s| s.strip_suffix(']'))
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("{name}:{line}: malformed indexed key `{key}`"))
}

fn parse_num<T: std::str::FromStr>(
    v: &str,
    key: &str,
    name: &str,
    line: usize,
) -> Result<T, String> {
    v.parse()
        .map_err(|_| format!("{name}:{line}: bad number `{v}` for `{key}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_case_passes() {
        let md = "
# doc

```asm
    vsetvli t0, 2, e64, m1, ta, ma
    vle64.v v8, 0(a0)
    vfmacc.vf v0, f1, v8
```

```expect
dialect = rvv10
insts = 3
mix = 2, 0, 1
```
";
        assert_eq!(run_str(md, "<t>"), Ok(1));
    }

    #[test]
    fn executed_state_checked() {
        let md = "
```asm
    vsetvli t0, 2, e64, m1, ta, ma
    fld f0, 4(a1)
    vle64.v v8, 0(a0)
    vfmacc.vf v0, f0, v8
    vse64.v v0, 6(a0)
```

```expect
mem.in[0] = 2.0
mem.in[1] = 5.0
mem.in[4] = 3.0
mem.out[6] = 6.0
mem.out[7] = 15.0
flops = 4
retired = 5
```
";
        assert_eq!(run_str(md, "<t>"), Ok(1));
        let bad = md.replace("mem.out[6] = 6.0", "mem.out[6] = 7.0");
        let e = run_str(&bad, "<t>").unwrap_err();
        assert!(e.contains("mem.out[6]"), "{e}");
    }

    #[test]
    fn error_cases_need_expect_error() {
        let md = "
```asm
    vfmaac.vf v0, f1, v8
```

```expect-error
line = 1
contains = did you mean
```
";
        assert_eq!(run_str(md, "<t>"), Ok(1));
        // a failing listing with a plain expect block is a failure
        let md2 = md.replace("expect-error", "expect").replace("contains = did you mean", "");
        assert!(run_str(&md2, "<t>").unwrap_err().contains("failed to assemble"));
    }

    #[test]
    fn vacuous_asm_block_rejected() {
        let md = "
```asm
    addi a0, a0, 8
```
";
        assert!(run_str(md, "<t>").unwrap_err().contains("vacuous"));
    }

    #[test]
    fn prose_fences_are_ignored() {
        let md = "
```text
this is documentation, not a test
```

```asm
    addi a0, a0, 8
```

```expect
insts = 1
mix = 0, 0, 1
```
";
        assert_eq!(run_str(md, "<t>"), Ok(1));
    }
}
