//! RISC-V Vector (RVV) instruction-set substrate.
//!
//! The paper's software contribution lives at the ISA level: BLIS ships
//! micro-kernels written for RVV 1.0 (`rv64iv`), the SG2042's C920 cores
//! only speak RVV 0.7.1 (`theadvector` in GCC 14 terms), and the authors
//! (a) retrofit the kernels 1.0 -> 0.7.1 (Section 3.3.1) and (b) rewrite the
//! schedule from per-register rank-1 updates to LMUL=4 register groups
//! (Section 3.3.2).
//!
//! This module implements that substrate for real:
//! - [`rvv`] — vtype/SEW/LMUL semantics and `vsetvl` behaviour.
//! - [`inst`] — a small instruction IR covering the GEMM micro-kernels.
//! - [`asm`] — assembly text rendering in *both* dialects (RVV 1.0 and
//!   XuanTie/theadvector 0.7.1 with the `th.` prefix).
//! - [`assembler`] — the two-pass assembler front end: labels,
//!   directives, branch resolution, source-located [`assembler::AsmError`]
//!   with caret excerpts, a disassembler round trip, and kernel-mode
//!   ingestion of real `.S` micro-kernels ([`assembler::AsmKernel`]).
//! - [`parse`] — the historical line-oriented entry points, now thin
//!   delegations into [`assembler`].
//! - [`translate`] — the verified 1.0 -> 0.7.1 retrofit pass.
//! - [`exec`] — a functional vector machine executing the IR on real f64
//!   data (numerics tested against [`crate::util::Matrix`] GEMM).
//! - [`timing`] — the per-instruction cycle model that reproduces the
//!   fetched-instruction bottleneck the paper optimizes.
//! - [`literate`] — runner for the markdown ISA conformance suite
//!   (`rust/tests/isa/*.cim.md`: fenced asm blocks assembled and
//!   executed against fenced expectation blocks).

pub mod asm;
pub mod assembler;
pub mod exec;
pub mod inst;
pub mod literate;
pub mod parse;
pub mod rvv;
pub mod timing;
pub mod translate;

pub use assembler::{assemble, assemble_named, disassemble, AsmError, AsmKernel};
pub use exec::VecMachine;
pub use inst::{Dialect, Inst, Program};
pub use rvv::{Lmul, Sew, VType};
pub use timing::{CycleModel, TimingBreakdown};
