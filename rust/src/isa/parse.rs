//! Assembly parser: text -> [`Program`] for both dialects.
//!
//! Completes the §3.3.1 story: the paper's retrofit was a *textual* port
//! of BLIS's `.S` files, so the repo carries the full round trip —
//! `render_program` (asm.rs) emits text, this module parses it back, and
//! property tests assert `parse(render(p)) == p` for arbitrary kernel
//! programs. It also lets users feed hand-written kernel listings to the
//! cycle model (`cimone` consumes listings through this path).

use super::inst::{Dialect, Inst, Program};
use super::rvv::{Lmul, Sew, VType};

/// Parse error with line context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, message: impl Into<String>) -> ParseError {
    ParseError { line, message: message.into() }
}

/// Parse an assembly listing. The dialect is inferred from the mnemonics
/// (`th.`-prefixed => theadvector) and must be consistent.
pub fn parse_program(text: &str) -> Result<Program, ParseError> {
    let mut dialect: Option<Dialect> = None;
    let mut insts = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() || line.ends_with(':') {
            continue; // blank or label
        }
        let (inst, d) = parse_line(lineno + 1, line)?;
        match (dialect, d) {
            (None, Some(d)) => dialect = Some(d),
            (Some(a), Some(b)) if a != b => {
                return Err(err(lineno + 1, format!("mixed dialects: {a:?} then {b:?}")))
            }
            _ => {}
        }
        insts.push(inst);
    }
    let mut p = Program::new(dialect.unwrap_or(Dialect::Rvv10));
    for i in insts {
        p.push(i);
    }
    Ok(p)
}

/// One line -> (instruction, dialect hint).
fn parse_line(lineno: usize, line: &str) -> Result<(Inst, Option<Dialect>), ParseError> {
    let (mnemonic, rest) = line.split_once(char::is_whitespace).unwrap_or((line, ""));
    let (bare, dialect) = match mnemonic.strip_prefix("th.") {
        Some(b) => (b, Some(Dialect::Thead071)),
        None => (mnemonic, None),
    };
    let ops: Vec<&str> = rest.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    let inst = match bare {
        "vsetvli" => parse_vsetvli(lineno, &ops, dialect)?,
        m if m.starts_with("vle") && m.ends_with(".v") => {
            let sew = parse_eew(lineno, m, dialect)?;
            let (vd, addr) = parse_vreg_addr(lineno, &ops)?;
            Inst::Vle { sew, vd, addr }
        }
        m if m.starts_with("vse") && m.ends_with(".v") => {
            let sew = parse_eew(lineno, m, dialect)?;
            let (vs, addr) = parse_vreg_addr(lineno, &ops)?;
            Inst::Vse { sew, vs, addr }
        }
        "vfmacc.vf" => {
            let (vd, fs, vs2) = parse_vfv(lineno, &ops)?;
            Inst::VfmaccVf { vd, fs, vs2 }
        }
        "vfmul.vf" => {
            let (vd, fs, vs2) = parse_vfv(lineno, &ops)?;
            Inst::VfmulVf { vd, fs, vs2 }
        }
        "vfmv.v.f" => {
            let vd = parse_reg(lineno, ops.first().copied(), 'v')?;
            let fs = parse_reg(lineno, ops.get(1).copied(), 'f')?;
            Inst::VfmvVf { vd, fs }
        }
        "vfadd.vv" => {
            let vd = parse_reg(lineno, ops.first().copied(), 'v')?;
            let vs1 = parse_reg(lineno, ops.get(1).copied(), 'v')?;
            let vs2 = parse_reg(lineno, ops.get(2).copied(), 'v')?;
            Inst::VfaddVv { vd, vs1, vs2 }
        }
        "fld" => {
            let fd = parse_reg(lineno, ops.first().copied(), 'f')?;
            let addr = parse_addr(lineno, ops.get(1).copied())?;
            Inst::Fld { fd, addr }
        }
        "fsd" => {
            let fs = parse_reg(lineno, ops.first().copied(), 'f')?;
            let addr = parse_addr(lineno, ops.get(1).copied())?;
            Inst::Fsd { fs, addr }
        }
        "fmadd.d" => {
            let fd = parse_reg(lineno, ops.first().copied(), 'f')?;
            let fs1 = parse_reg(lineno, ops.get(1).copied(), 'f')?;
            let fs2 = parse_reg(lineno, ops.get(2).copied(), 'f')?;
            let fs3 = parse_reg(lineno, ops.get(3).copied(), 'f')?;
            Inst::FmaddD { fd, fs1, fs2, fs3 }
        }
        "addi" => Inst::Addi,
        "bnez" => Inst::Bnez,
        other => return Err(err(lineno, format!("unknown mnemonic `{other}`"))),
    };
    Ok((inst, dialect))
}

fn parse_vsetvli(
    lineno: usize,
    ops: &[&str],
    dialect: Option<Dialect>,
) -> Result<Inst, ParseError> {
    // vsetvli t0, <avl>, e64, m4[, ta, ma]
    if ops.len() < 4 {
        return Err(err(lineno, "vsetvli needs rd, avl, sew, lmul"));
    }
    let avl: usize =
        ops[1].parse().map_err(|_| err(lineno, format!("bad avl `{}`", ops[1])))?;
    let sew = match ops[2] {
        "e32" => Sew::E32,
        "e64" => Sew::E64,
        o => return Err(err(lineno, format!("bad sew `{o}`"))),
    };
    let lmul = match ops[3] {
        "m1" => Lmul::M1,
        "m2" => Lmul::M2,
        "m4" => Lmul::M4,
        "m8" => Lmul::M8,
        "mf2" | "mf4" | "mf8" => Lmul::Fractional,
        o => return Err(err(lineno, format!("bad lmul `{o}`"))),
    };
    let has_flags = ops.len() >= 6 && ops[4] == "ta" && ops[5] == "ma";
    if dialect == Some(Dialect::Thead071) && has_flags {
        return Err(err(lineno, "theadvector vsetvli takes no ta/ma flags"));
    }
    let mut vt = VType::new(sew, lmul);
    vt.tail_agnostic = has_flags;
    vt.mask_agnostic = has_flags;
    Ok(Inst::Vsetvli { avl, vtype: vt })
}

fn parse_eew(lineno: usize, m: &str, dialect: Option<Dialect>) -> Result<Sew, ParseError> {
    // RVV 1.0: vle64.v / vse64.v; thead 0.7.1: th.vle.v (EEW from vtype,
    // rendered without digits — parser then defaults to E64, our only
    // theadvector element width in this codebase)
    let digits: String = m.chars().filter(|c| c.is_ascii_digit()).collect();
    match (digits.as_str(), dialect) {
        ("64", _) => Ok(Sew::E64),
        ("32", _) => Ok(Sew::E32),
        ("", Some(Dialect::Thead071)) => Ok(Sew::E64),
        ("", None) => Err(err(lineno, "RVV 1.0 load/store needs an EEW suffix")),
        (d, _) => Err(err(lineno, format!("unsupported EEW `{d}`"))),
    }
}

fn parse_vreg_addr(lineno: usize, ops: &[&str]) -> Result<(u8, usize), ParseError> {
    let v = parse_reg(lineno, ops.first().copied(), 'v')?;
    let addr = parse_addr(lineno, ops.get(1).copied())?;
    Ok((v, addr))
}

fn parse_vfv(lineno: usize, ops: &[&str]) -> Result<(u8, u8, u8), ParseError> {
    Ok((
        parse_reg(lineno, ops.first().copied(), 'v')?,
        parse_reg(lineno, ops.get(1).copied(), 'f')?,
        parse_reg(lineno, ops.get(2).copied(), 'v')?,
    ))
}

fn parse_reg(lineno: usize, tok: Option<&str>, class: char) -> Result<u8, ParseError> {
    let tok = tok.ok_or_else(|| err(lineno, "missing register operand"))?;
    let rest = tok
        .strip_prefix(class)
        .ok_or_else(|| err(lineno, format!("expected {class}-register, got `{tok}`")))?;
    let n: u8 = rest.parse().map_err(|_| err(lineno, format!("bad register `{tok}`")))?;
    if n >= 32 {
        return Err(err(lineno, format!("register `{tok}` out of file")));
    }
    Ok(n)
}

fn parse_addr(lineno: usize, tok: Option<&str>) -> Result<usize, ParseError> {
    // form: <offset>(aN)
    let tok = tok.ok_or_else(|| err(lineno, "missing address operand"))?;
    let off = tok
        .split('(')
        .next()
        .and_then(|s| s.parse::<usize>().ok())
        .ok_or_else(|| err(lineno, format!("bad address `{tok}`")))?;
    Ok(off)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::render_program;
    use crate::ukernel::{KernelRegistry, PanelLayout};

    #[test]
    fn roundtrip_all_kernel_programs() {
        // parse(render(p)) == p for every registered micro-kernel, both
        // dialects
        for k in KernelRegistry::builtin().kernels() {
            let (mr, nr) = k.tile();
            let p = k.program(PanelLayout::new(mr, nr, 3));
            let text = render_program(&p);
            let back = parse_program(&text).unwrap_or_else(|e| panic!("{}: {e}", k.id));
            assert_eq!(back.dialect, p.dialect, "{}", k.id);
            assert_eq!(back.insts, p.insts, "{}", k.id);
        }
    }

    #[test]
    fn roundtrip_translated_program() {
        let k = KernelRegistry::builtin().get("blis-lmul1").unwrap();
        let p10 = k.program(PanelLayout::new(8, 4, 2));
        let p07 = crate::isa::translate::rvv10_to_thead(&p10).unwrap();
        let back = parse_program(&render_program(&p07)).unwrap();
        assert_eq!(back.insts, p07.insts);
        assert_eq!(back.dialect, Dialect::Thead071);
    }

    #[test]
    fn parses_handwritten_listing() {
        let text = "
.loop:
    vsetvli t0, 8, e64, m4, ta, ma   # configure
    vle64.v v8, 0(a0)
    fld f1, 64(a1)
    vfmacc.vf v0, f1, v8
    addi a0, a0, 8
    bnez t1, .loop
";
        let p = parse_program(text).unwrap();
        assert_eq!(p.len(), 6);
        assert_eq!(p.dialect, Dialect::Rvv10);
        assert!(matches!(p.insts[3], Inst::VfmaccVf { vd: 0, fs: 1, vs2: 8 }));
    }

    #[test]
    fn infers_thead_dialect_from_prefix() {
        let p = parse_program("th.vsetvli t0, 8, e64, m4\nth.vle.v v4, 0(a0)\n").unwrap();
        assert_eq!(p.dialect, Dialect::Thead071);
        assert!(matches!(p.insts[1], Inst::Vle { sew: Sew::E64, vd: 4, .. }));
    }

    #[test]
    fn rejects_mixed_dialects() {
        let e = parse_program("th.vsetvli t0, 8, e64, m4\nvle64.v v0, 0(a0)\n");
        // bare vle64.v carries no dialect hint, so this parses; but a bare
        // RVV1.0-only construct after a th. one must fail:
        assert!(e.is_ok());
        let e2 = parse_program("vsetvli t0, 2, e64, m1, ta, ma\nth.vsetvli t0, 2, e64, m1\n");
        assert!(e2.is_err() || e2.unwrap().dialect == Dialect::Thead071);
    }

    #[test]
    fn error_reports_line_numbers() {
        let e = parse_program("addi a0, a0, 8\nfrobnicate x0\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.message.contains("frobnicate"));
    }

    #[test]
    fn rejects_bad_registers_and_eew() {
        assert!(parse_program("vfmacc.vf v32, f0, v8").is_err());
        assert!(parse_program("vle128.v v0, 0(a0)").is_err());
        assert!(parse_program("fld x1, 0(a1)").is_err());
    }

    #[test]
    fn fractional_lmul_parses_then_translator_rejects() {
        let p = parse_program("vsetvli t0, 1, e64, mf2, ta, ma").unwrap();
        assert!(crate::isa::translate::rvv10_to_thead(&p).is_err());
    }

    #[test]
    fn parsed_program_executes() {
        use crate::isa::exec::VecMachine;
        let text = "
    vsetvli t0, 2, e64, m1, ta, ma
    fld f0, 4(a1)
    vle64.v v8, 0(a0)
    vfmacc.vf v0, f0, v8
    vse64.v v0, 6(a0)
";
        let p = parse_program(text).unwrap();
        let mut m = VecMachine::new(128, 16).unwrap();
        m.mem[0] = 2.0;
        m.mem[1] = 5.0;
        m.mem[4] = 3.0;
        m.run(&p).unwrap();
        assert_eq!(m.mem[6], 6.0);
        assert_eq!(m.mem[7], 15.0);
    }
}
