//! Assembly parsing compat surface: text -> [`Program`].
//!
//! The original line-oriented parser grew into the full two-pass
//! [`crate::isa::assembler`] (labels, directives, branch resolution,
//! source-located errors); this module keeps the historical entry
//! points as thin delegations so existing callers and the
//! `parse(render(p)) == p` property suite keep working unchanged.
//! [`ParseError`] *is* [`crate::isa::assembler::AsmError`] now — the
//! old `{ line, message }` fields are still there, joined by
//! `file`/`col`/`span` and a caret-excerpt `Display`.

use super::inst::Program;

pub use super::assembler::AsmError as ParseError;

/// Parse an assembly listing. The dialect is inferred from the mnemonics
/// (`th.`-prefixed or `ta, ma`-flagged `vsetvli` spellings) and must be
/// consistent. Labels, comments and layout directives are accepted;
/// branch targets must resolve to previously defined labels.
pub fn parse_program(text: &str) -> Result<Program, ParseError> {
    super::assembler::assemble(text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::render_program;
    use crate::isa::inst::{Dialect, Inst};
    use crate::isa::rvv::Sew;
    use crate::ukernel::{KernelRegistry, PanelLayout};

    #[test]
    fn roundtrip_all_kernel_programs() {
        // parse(render(p)) == p for every registered micro-kernel, both
        // dialects
        for k in KernelRegistry::builtin().kernels() {
            let (mr, nr) = k.tile();
            let p = k.program(PanelLayout::new(mr, nr, 3));
            let text = render_program(&p);
            let back = parse_program(&text).unwrap_or_else(|e| panic!("{}: {e}", k.id));
            assert_eq!(back.dialect, p.dialect, "{}", k.id);
            assert_eq!(back.insts, p.insts, "{}", k.id);
        }
    }

    #[test]
    fn roundtrip_translated_program() {
        let k = KernelRegistry::builtin().get("blis-lmul1").unwrap();
        let p10 = k.program(PanelLayout::new(8, 4, 2));
        let p07 = crate::isa::translate::rvv10_to_thead(&p10).unwrap();
        let back = parse_program(&render_program(&p07)).unwrap();
        assert_eq!(back.insts, p07.insts);
        assert_eq!(back.dialect, Dialect::Thead071);
    }

    #[test]
    fn parses_handwritten_listing() {
        let text = "
.loop:
    vsetvli t0, 8, e64, m4, ta, ma   # configure
    vle64.v v8, 0(a0)
    fld f1, 64(a1)
    vfmacc.vf v0, f1, v8
    addi a0, a0, 8
    bnez t1, .loop
";
        let p = parse_program(text).unwrap();
        assert_eq!(p.len(), 6);
        assert_eq!(p.dialect, Dialect::Rvv10);
        assert!(matches!(p.insts[3], Inst::VfmaccVf { vd: 0, fs: 1, vs2: 8 }));
    }

    #[test]
    fn infers_thead_dialect_from_prefix() {
        let p = parse_program("th.vsetvli t0, 8, e64, m4\nth.vle.v v4, 0(a0)\n").unwrap();
        assert_eq!(p.dialect, Dialect::Thead071);
        assert!(matches!(p.insts[1], Inst::Vle { sew: Sew::E64, vd: 4, .. }));
    }

    #[test]
    fn rejects_mixed_dialects() {
        let e = parse_program("th.vsetvli t0, 8, e64, m4\nvle64.v v0, 0(a0)\n");
        // bare vle64.v carries no dialect hint, so this parses; but a bare
        // RVV1.0-only construct after a th. one must fail:
        assert!(e.is_ok());
        let e2 = parse_program("vsetvli t0, 2, e64, m1, ta, ma\nth.vsetvli t0, 2, e64, m1\n");
        assert!(e2.is_err() || e2.unwrap().dialect == Dialect::Thead071);
    }

    #[test]
    fn error_reports_line_numbers() {
        let e = parse_program("addi a0, a0, 8\nfrobnicate x0\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.message.contains("frobnicate"));
    }

    #[test]
    fn rejects_bad_registers_and_eew() {
        assert!(parse_program("vfmacc.vf v32, f0, v8").is_err());
        assert!(parse_program("vle128.v v0, 0(a0)").is_err());
        assert!(parse_program("fld x1, 0(a1)").is_err());
    }

    #[test]
    fn fractional_lmul_parses_then_translator_rejects() {
        let p = parse_program("vsetvli t0, 1, e64, mf2, ta, ma").unwrap();
        assert!(crate::isa::translate::rvv10_to_thead(&p).is_err());
    }

    #[test]
    fn parsed_program_executes() {
        use crate::isa::exec::VecMachine;
        let text = "
    vsetvli t0, 2, e64, m1, ta, ma
    fld f0, 4(a1)
    vle64.v v8, 0(a0)
    vfmacc.vf v0, f0, v8
    vse64.v v0, 6(a0)
";
        let p = parse_program(text).unwrap();
        let mut m = VecMachine::new(128, 16).unwrap();
        m.mem[0] = 2.0;
        m.mem[1] = 5.0;
        m.mem[4] = 3.0;
        m.run(&p).unwrap();
        assert_eq!(m.mem[6], 6.0);
        assert_eq!(m.mem[7], 15.0);
    }

    #[test]
    fn parse_error_is_the_assembler_error() {
        // ParseError IS AsmError: the historical fields are intact and
        // the new source-location fields ride along
        let e: ParseError = parse_program("vle64.v v0\n").unwrap_err();
        assert_eq!(e.line, 1);
        assert_eq!(e.file, "<memory>");
        assert!(e.col >= 1 && e.span >= 1);
        // and it converts into the typed error surface via From
        let typed: crate::error::CimoneError = e.into();
        assert!(matches!(typed, crate::error::CimoneError::Asm(_)));
    }
}
