//! RVV configuration state: SEW, LMUL, vtype and `vsetvl` semantics.
//!
//! Only the features the GEMM micro-kernels use are modelled; notably
//! RVV 0.7.1 has **no fractional LMUL** and no tail/mask agnosticism
//! flags — exactly the differences `translate` must police.

/// Selected element width.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Sew {
    E32,
    E64,
}

impl Sew {
    pub fn bits(&self) -> usize {
        match self {
            Sew::E32 => 32,
            Sew::E64 => 64,
        }
    }
}

/// Register-group multiplier. RVV 1.0 additionally defines fractional
/// LMUL (mf2/mf4/mf8) which 0.7.1 lacks; we model the integer ones plus a
/// marker for fractional so the translator can reject it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lmul {
    M1,
    M2,
    M4,
    M8,
    /// Fractional LMUL (RVV 1.0 only) — carried so translation fails loudly.
    Fractional,
}

impl Lmul {
    pub fn multiplier(&self) -> usize {
        match self {
            Lmul::M1 => 1,
            Lmul::M2 => 2,
            Lmul::M4 => 4,
            Lmul::M8 => 8,
            Lmul::Fractional => panic!("fractional LMUL has no integer multiplier"),
        }
    }

    pub fn is_fractional(&self) -> bool {
        matches!(self, Lmul::Fractional)
    }
}

/// The dynamic vector configuration set by `vsetvli`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VType {
    pub sew: Sew,
    pub lmul: Lmul,
    /// Tail-agnostic flag — RVV 1.0 syntax only (`ta`); 0.7.1 has no
    /// notion of it (tail-undisturbed always).
    pub tail_agnostic: bool,
    pub mask_agnostic: bool,
}

impl VType {
    pub fn new(sew: Sew, lmul: Lmul) -> Self {
        VType { sew, lmul, tail_agnostic: false, mask_agnostic: false }
    }

    /// Elements per register group for a given VLEN.
    pub fn vlmax(&self, vlen_bits: usize) -> usize {
        vlen_bits / self.sew.bits() * self.lmul.multiplier()
    }
}

/// `vsetvl` result: vl = min(avl, VLMAX).
pub fn vsetvl(avl: usize, vtype: VType, vlen_bits: usize) -> usize {
    avl.min(vtype.vlmax(vlen_bits))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vlmax_e64() {
        // VLEN=128: m1 -> 2 lanes, m4 -> 8 lanes (the paper's key numbers)
        assert_eq!(VType::new(Sew::E64, Lmul::M1).vlmax(128), 2);
        assert_eq!(VType::new(Sew::E64, Lmul::M4).vlmax(128), 8);
        assert_eq!(VType::new(Sew::E64, Lmul::M8).vlmax(128), 16);
    }

    #[test]
    fn vlmax_e32_doubles() {
        assert_eq!(VType::new(Sew::E32, Lmul::M1).vlmax(128), 4);
    }

    #[test]
    fn vsetvl_clamps_to_vlmax() {
        let vt = VType::new(Sew::E64, Lmul::M4);
        assert_eq!(vsetvl(100, vt, 128), 8);
        assert_eq!(vsetvl(5, vt, 128), 5);
        assert_eq!(vsetvl(0, vt, 128), 0);
    }

    #[test]
    #[should_panic]
    fn fractional_multiplier_panics() {
        Lmul::Fractional.multiplier();
    }
}
