//! Per-instruction cycle model for the C920 (and scalar U74).
//!
//! The quantity the paper optimizes is *fetched instructions per unit of
//! work*: the C920's in-order front end serializes on vector-instruction
//! dispatch, so a schedule that does the same FLOPs with fewer, longer
//! (higher-LMUL) vector instructions runs faster even though the vector
//! datapath is equally busy. The model:
//!
//! - vector instruction:  max(dispatch_overhead, active_lanes / lane_rate)
//!   cycles of pipeline occupancy;
//! - scalar FP load (`fld`): 1 LSU cycle + `FLD_USE_STALL` (the in-order
//!   core stalls the dependent `vfmacc.vf` on the freshly loaded scalar);
//! - other scalar ops: dual-issued (1/issue_width cycles each);
//! - scalar `fmadd.d`: limited by `scalar_fma_per_cycle`.
//!
//! Calibration (see EXPERIMENTS.md 'Calibration'): with the C920 preset
//! (dispatch = 2.0 cycles), the BLIS LMUL=1 -> LMUL=4 rewrite speeds the
//! micro-kernel up by ~1.9x, which propagates through the HPL model to
//! the paper's +49% at 128 cores.

use super::inst::{Inst, Program};
use super::rvv::{Lmul, Sew, VType};
use crate::arch::soc::CoreModel;

/// Extra stall cycles charged when a scalar FP load feeds the vector unit
/// (in-order bypass latency).
pub const FLD_USE_STALL: f64 = 1.5;

/// 64-bit-equivalent datapath lanes occupied by `vl` elements at the
/// current SEW: E64 is 1 lane/element, E32 packs two elements per lane.
fn eff_lanes(vl: usize, vtype: VType) -> f64 {
    vl as f64 * (vtype.sew.bits() as f64 / 64.0)
}

/// Cycle accounting for one program execution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingBreakdown {
    pub cycles: f64,
    pub vector_cycles: f64,
    pub scalar_mem_cycles: f64,
    pub scalar_fma_cycles: f64,
    pub scalar_other_cycles: f64,
    pub insts: usize,
    pub flops: usize,
}

impl TimingBreakdown {
    pub fn flops_per_cycle(&self) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            self.flops as f64 / self.cycles
        }
    }

    /// GFLOP/s at the core's frequency.
    pub fn gflops(&self, core: &CoreModel) -> f64 {
        self.flops_per_cycle() * core.freq_hz / 1e9
    }
}

/// The cycle model: walks a straight-line program tracking vtype/vl like
/// the functional machine, charging cycles per the rules above.
pub struct CycleModel<'a> {
    pub core: &'a CoreModel,
}

impl<'a> CycleModel<'a> {
    pub fn new(core: &'a CoreModel) -> Self {
        CycleModel { core }
    }

    /// Cost of one vector instruction at the given active lane count
    /// (64-bit-equivalent lanes; see [`CycleModel::vector_cost_eff`]).
    fn vector_cost(&self, lanes: usize) -> f64 {
        self.vector_cost_eff(lanes as f64)
    }

    /// Cost of one vector instruction occupying `eff_lanes` 64-bit
    /// datapath lanes. SEW=32 elements pack two per lane, so an E32
    /// instruction at vl elements occupies vl/2 effective lanes — the
    /// mechanism behind mixed-precision (HPL-MxP) speedup: same datapath
    /// width, twice the elements per cycle.
    fn vector_cost_eff(&self, eff_lanes: f64) -> f64 {
        let busy = eff_lanes / self.core.vfma_lanes_per_cycle.max(1) as f64;
        busy.max(self.core.vinst_dispatch_cycles)
    }

    /// Analyze a program. `Program`s are straight-line; loops must be
    /// peeled/multiplied by the caller (ukernel::analysis does this).
    /// vl tracking assumes the core's own VLEN (>= 128); programs built
    /// for a wider machine go through [`CycleModel::analyze_at`].
    pub fn analyze(&self, prog: &Program) -> TimingBreakdown {
        self.analyze_at(prog, self.core.vlen_bits.max(128))
    }

    /// [`CycleModel::analyze`] with an explicit VLEN for the vl/vsetvl
    /// tracking — the descriptor-driven kernel sweeps time programs
    /// written for VLENs other than the core's shipping width. This is
    /// deliberately total: a wider-VLEN kernel on narrower silicon is a
    /// *what-if* projection (the ROADMAP's codesign direction), not an
    /// error — nothing anywhere rejects a kernel-VLEN/core-VLEN
    /// mismatch, by design.
    pub fn analyze_at(&self, prog: &Program, vlen_bits: usize) -> TimingBreakdown {
        let mut vtype = VType::new(Sew::E64, Lmul::M1);
        let mut vl = 0usize;
        let vlen = vlen_bits.max(64);
        let mut t = TimingBreakdown {
            cycles: 0.0,
            vector_cycles: 0.0,
            scalar_mem_cycles: 0.0,
            scalar_fma_cycles: 0.0,
            scalar_other_cycles: 0.0,
            insts: prog.len(),
            flops: 0,
        };
        for (idx, inst) in prog.insts.iter().enumerate() {
            match inst {
                Inst::Vsetvli { avl, vtype: vt } => {
                    vtype = *vt;
                    vl = super::rvv::vsetvl(*avl, *vt, vlen);
                    // vsetvli itself is a cheap scalar op
                    t.scalar_other_cycles += 1.0 / self.core.issue_width as f64;
                }
                Inst::Vle { .. } | Inst::Vse { .. } => {
                    t.vector_cycles += self.vector_cost_eff(eff_lanes(vl, vtype));
                }
                Inst::VfmaccVf { .. } | Inst::VfmulVf { .. } | Inst::VfaddVv { .. } => {
                    t.vector_cycles += self.vector_cost_eff(eff_lanes(vl, vtype));
                    t.flops += inst.flops(vl);
                }
                Inst::VfmvVf { .. } => {
                    t.vector_cycles += self.vector_cost_eff(eff_lanes(vl, vtype));
                }
                Inst::Fld { fd, .. } => {
                    t.scalar_mem_cycles += 1.0 / self.core.lsu_per_cycle;
                    // In-order bypass stall: charged only when a vector op
                    // consumes the freshly loaded scalar within the next
                    // two slots. Software-pipelined kernels (OpenBLAS's
                    // C920 asm) hoist their flds and dodge this; BLIS's
                    // naive rank-1 schedule eats it every column.
                    let consumed_soon = prog.insts[idx + 1..].iter().take(2).any(|n| {
                        matches!(n,
                            Inst::VfmaccVf { fs, .. }
                            | Inst::VfmulVf { fs, .. }
                            | Inst::VfmvVf { fs, .. } if fs == fd)
                    });
                    if consumed_soon {
                        t.scalar_mem_cycles += FLD_USE_STALL;
                    }
                }
                Inst::Fsd { .. } => {
                    t.scalar_mem_cycles += 1.0 / self.core.lsu_per_cycle;
                }
                Inst::FmaddD { .. } => {
                    t.scalar_fma_cycles += 1.0 / self.core.scalar_fma_per_cycle.max(0.01);
                    t.flops += 2;
                }
                Inst::Addi | Inst::Bnez => {
                    t.scalar_other_cycles += 1.0 / self.core.issue_width as f64;
                }
            }
        }
        // In-order pipe: vector occupancy serializes with scalar memory
        // traffic (shared LSU) and with the scalar FMA pipe; cheap scalar
        // ALU bookkeeping overlaps ~half.
        t.cycles = t.vector_cycles
            + t.scalar_mem_cycles
            + t.scalar_fma_cycles
            + 0.5 * t.scalar_other_cycles;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::c920;
    use crate::isa::inst::Dialect;

    fn vt(lmul: Lmul) -> VType {
        VType::new(Sew::E64, lmul)
    }

    /// One k-step of the Fig-2a (LMUL=1) schedule for an 8x8 tile:
    /// 4 A-loads + per column (8): fld + 4 vfmacc.
    fn lmul1_kstep() -> Program {
        let mut p = Program::new(Dialect::Rvv10);
        p.push(Inst::Vsetvli { avl: 2, vtype: vt(Lmul::M1) });
        for r in 0..4 {
            p.push(Inst::Vle { sew: Sew::E64, vd: 24 + r, addr: 0 });
        }
        for _col in 0..8 {
            p.push(Inst::Fld { fd: 0, addr: 0 });
            for r in 0..4 {
                p.push(Inst::VfmaccVf { vd: r * 2, fs: 0, vs2: 24 + r });
            }
        }
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
        p
    }

    /// One k-step of the Fig-2b (LMUL=4) schedule:
    /// 1 grouped A-load + per column: fld + 1 grouped vfmacc.
    fn lmul4_kstep() -> Program {
        let mut p = Program::new(Dialect::Rvv10);
        p.push(Inst::Vsetvli { avl: 8, vtype: vt(Lmul::M4) });
        p.push(Inst::Vle { sew: Sew::E64, vd: 24, addr: 0 });
        for col in 0..8u8 {
            p.push(Inst::Fld { fd: 0, addr: 0 });
            p.push(Inst::VfmaccVf { vd: (col % 2) * 4, fs: 0, vs2: 24 });
        }
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
        p
    }

    #[test]
    fn lmul4_schedule_is_faster_same_flops() {
        let core = c920();
        let m = CycleModel::new(&core);
        let t1 = m.analyze(&lmul1_kstep());
        let t4 = m.analyze(&lmul4_kstep());
        assert_eq!(t1.flops, 128);
        assert_eq!(t4.flops, 128);
        let speedup = t1.cycles / t4.cycles;
        assert!(
            (1.5..2.5).contains(&speedup),
            "LMUL=4 speedup {speedup:.2} outside paper-plausible band (t1={:.1}, t4={:.1})",
            t1.cycles,
            t4.cycles
        );
    }

    #[test]
    fn fewer_instructions_is_the_mechanism() {
        // the paper: "reducing the number of fetched instructions"
        let p1 = lmul1_kstep();
        let p4 = lmul4_kstep();
        assert!(p4.len() < p1.len() / 2, "{} vs {}", p4.len(), p1.len());
    }

    #[test]
    fn vector_cost_respects_dispatch_floor() {
        let core = c920();
        let m = CycleModel::new(&core);
        // LMUL=1: 2 lanes / 2 per cycle = 1 < dispatch 2 -> cost 2
        assert!((m.vector_cost(2) - core.vinst_dispatch_cycles).abs() < 1e-12);
        // LMUL=4: 8 lanes / 2 = 4 > dispatch -> cost 4
        assert!((m.vector_cost(8) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn e32_packs_two_elements_per_lane() {
        let core = c920();
        let m = CycleModel::new(&core);
        // Same element count: E32 occupies half the effective lanes, so
        // the grouped vfmacc finishes in half the busy cycles (down to
        // the dispatch floor) — the HPL-MxP mechanism.
        let mut p64 = Program::new(Dialect::Rvv10);
        p64.push(Inst::Vsetvli { avl: 8, vtype: VType::new(Sew::E64, Lmul::M4) });
        p64.push(Inst::VfmaccVf { vd: 0, fs: 0, vs2: 8 });
        let mut p32 = Program::new(Dialect::Rvv10);
        p32.push(Inst::Vsetvli { avl: 8, vtype: VType::new(Sew::E32, Lmul::M2) });
        p32.push(Inst::VfmaccVf { vd: 0, fs: 0, vs2: 8 });
        let t64 = m.analyze_at(&p64, 128);
        let t32 = m.analyze_at(&p32, 128);
        // both run all 8 elements (same FLOPs), E32 in half the occupancy
        assert_eq!(t64.flops, 16);
        assert_eq!(t32.flops, 16);
        assert!((t64.vector_cycles - 4.0).abs() < 1e-12, "{}", t64.vector_cycles);
        assert!(
            (t32.vector_cycles - core.vinst_dispatch_cycles.max(2.0)).abs() < 1e-12,
            "{}",
            t32.vector_cycles
        );
    }

    #[test]
    fn scalar_kernel_counts_fma_throughput() {
        let core = c920();
        let m = CycleModel::new(&core);
        let mut p = Program::new(Dialect::Rvv10);
        for _ in 0..10 {
            p.push(Inst::FmaddD { fd: 0, fs1: 1, fs2: 2, fs3: 0 });
        }
        let t = m.analyze(&p);
        assert_eq!(t.flops, 20);
        assert!(t.scalar_fma_cycles >= 10.0);
        assert!(t.cycles >= t.scalar_fma_cycles);
    }

    #[test]
    fn gflops_scales_with_frequency() {
        let mut core = c920();
        let t = CycleModel::new(&core).analyze(&lmul4_kstep());
        let g1 = t.gflops(&core);
        core.freq_hz *= 2.0;
        let g2 = t.gflops(&core);
        assert!((g2 / g1 - 2.0).abs() < 1e-9);
    }
}
