//! The RVV 1.0 -> 0.7.1 (theadvector) retrofit pass — Section 3.3.1 of the
//! paper, implemented as a verified IR transformation.
//!
//! What the paper did by hand on BLIS's assembly, we do mechanically:
//! 1. adapt load/store instructions (`vle64.v` -> `th.vle.v`; EEW moves
//!    from the mnemonic into vtype — we *verify* the SEW already matches
//!    the active vtype, the condition under which the textual rewrite is
//!    sound);
//! 2. adapt `vsetvl` operations to the older syntax (drop `ta, ma`);
//! 3. add the `th.` prefix so GCC 14's `theadvector` target recognizes
//!    the mnemonics (in our IR: retag the dialect).
//!
//! The pass also *rejects* programs using RVV 1.0 features with no 0.7.1
//! equivalent (fractional LMUL), which is exactly where a blind textual
//! port would miscompile.

use super::inst::{Dialect, Inst, Program};
use super::rvv::{Sew, VType};

/// Translation failure modes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TranslateError {
    /// Program is not RVV 1.0 to begin with.
    WrongSourceDialect,
    /// Fractional LMUL has no theadvector encoding.
    FractionalLmul { at: usize },
    /// A load/store EEW disagrees with the active vtype SEW; the 0.7.1
    /// form (EEW from vtype) would change semantics.
    EewMismatch { at: usize, inst_sew: Sew, vtype_sew: Sew },
}

impl std::fmt::Display for TranslateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TranslateError::WrongSourceDialect => write!(f, "source is not RVV 1.0"),
            TranslateError::FractionalLmul { at } => {
                write!(f, "inst {at}: fractional LMUL unsupported in RVV 0.7.1")
            }
            TranslateError::EewMismatch { at, inst_sew, vtype_sew } => write!(
                f,
                "inst {at}: load/store EEW {:?} != vtype SEW {:?}; textual port unsound",
                inst_sew, vtype_sew
            ),
        }
    }
}

impl std::error::Error for TranslateError {}

/// Translate an RVV 1.0 program to theadvector 0.7.1.
pub fn rvv10_to_thead(prog: &Program) -> Result<Program, TranslateError> {
    if prog.dialect != Dialect::Rvv10 {
        return Err(TranslateError::WrongSourceDialect);
    }
    let mut out = Program::new(Dialect::Thead071);
    let mut vtype: Option<VType> = None;
    for (at, inst) in prog.insts.iter().enumerate() {
        let new = match *inst {
            Inst::Vsetvli { avl, vtype: vt } => {
                if vt.lmul.is_fractional() {
                    return Err(TranslateError::FractionalLmul { at });
                }
                vtype = Some(vt);
                // 0.7.1 vsetvli has no ta/ma flags: normalize them away so
                // the rendered text matches the old syntax.
                Inst::Vsetvli {
                    avl,
                    vtype: VType { tail_agnostic: false, mask_agnostic: false, ..vt },
                }
            }
            Inst::Vle { sew, vd, addr } => {
                check_eew(at, sew, vtype)?;
                Inst::Vle { sew, vd, addr }
            }
            Inst::Vse { sew, vs, addr } => {
                check_eew(at, sew, vtype)?;
                Inst::Vse { sew, vs, addr }
            }
            other => other,
        };
        out.push(new);
    }
    Ok(out)
}

fn check_eew(at: usize, inst_sew: Sew, vtype: Option<VType>) -> Result<(), TranslateError> {
    match vtype {
        Some(vt) if vt.sew != inst_sew => Err(TranslateError::EewMismatch {
            at,
            inst_sew,
            vtype_sew: vt.sew,
        }),
        // No vsetvli seen yet: a real kernel always configures first; treat
        // as mismatch at position `at` against an undefined vtype.
        None => Err(TranslateError::EewMismatch { at, inst_sew, vtype_sew: inst_sew }),
        _ => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::render_program;
    use crate::isa::exec::VecMachine;
    use crate::isa::rvv::{Lmul, Sew, VType};

    fn vt(lmul: Lmul) -> VType {
        let mut v = VType::new(Sew::E64, lmul);
        v.tail_agnostic = true; // RVV 1.0 style
        v.mask_agnostic = true;
        v
    }

    fn sample_rvv10() -> Program {
        let mut p = Program::new(Dialect::Rvv10);
        p.push(Inst::Vsetvli { avl: 8, vtype: vt(Lmul::M4) });
        p.push(Inst::Vle { sew: Sew::E64, vd: 8, addr: 0 });
        p.push(Inst::Fld { fd: 0, addr: 100 });
        p.push(Inst::VfmaccVf { vd: 0, fs: 0, vs2: 8 });
        p.push(Inst::Vse { sew: Sew::E64, vs: 0, addr: 16 });
        p
    }

    #[test]
    fn translation_retags_and_strips_flags() {
        let t = rvv10_to_thead(&sample_rvv10()).unwrap();
        assert_eq!(t.dialect, Dialect::Thead071);
        match t.insts[0] {
            Inst::Vsetvli { vtype, .. } => {
                assert!(!vtype.tail_agnostic && !vtype.mask_agnostic)
            }
            _ => panic!(),
        }
        let text = render_program(&t);
        assert!(text.contains("th.vle.v"));
        assert!(text.contains("th.vfmacc.vf"));
        assert!(!text.contains("ta, ma"));
    }

    #[test]
    fn translation_preserves_numerics() {
        // The paper's correctness criterion: the retrofitted kernel computes
        // the same result. Run both programs on identical machines.
        let src = sample_rvv10();
        let dst = rvv10_to_thead(&src).unwrap();
        let mut m1 = VecMachine::new(128, 256).unwrap();
        let mut m2 = VecMachine::new(128, 256).unwrap();
        for i in 0..8 {
            m1.mem[i] = (i as f64) * 1.25 - 2.0;
            m2.mem[i] = (i as f64) * 1.25 - 2.0;
        }
        m1.mem[100] = 3.5;
        m2.mem[100] = 3.5;
        m1.run(&src).unwrap();
        m2.run(&dst).unwrap();
        assert_eq!(m1.mem, m2.mem);
        assert_eq!(m1.flops, m2.flops);
    }

    #[test]
    fn fractional_lmul_rejected() {
        let mut p = Program::new(Dialect::Rvv10);
        p.push(Inst::Vsetvli { avl: 1, vtype: VType::new(Sew::E64, Lmul::Fractional) });
        assert_eq!(
            rvv10_to_thead(&p).unwrap_err(),
            TranslateError::FractionalLmul { at: 0 }
        );
    }

    #[test]
    fn eew_mismatch_rejected() {
        let mut p = Program::new(Dialect::Rvv10);
        p.push(Inst::Vsetvli { avl: 4, vtype: vt(Lmul::M1) }); // e64
        p.push(Inst::Vle { sew: Sew::E32, vd: 0, addr: 0 }); // e32 load
        match rvv10_to_thead(&p).unwrap_err() {
            TranslateError::EewMismatch { at: 1, .. } => {}
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn load_before_vsetvli_rejected() {
        let mut p = Program::new(Dialect::Rvv10);
        p.push(Inst::Vle { sew: Sew::E64, vd: 0, addr: 0 });
        assert!(rvv10_to_thead(&p).is_err());
    }

    #[test]
    fn wrong_source_dialect() {
        let p = Program::new(Dialect::Thead071);
        assert_eq!(rvv10_to_thead(&p).unwrap_err(), TranslateError::WrongSourceDialect);
    }
}
