//! # cimone — Monte Cimone v2 reproduction stack
//!
//! A Rust + JAX + Pallas three-layer reproduction of *"Monte Cimone v2:
//! Down the Road of RISC-V High-Performance Computers"* (CS.DC 2025).
//!
//! The paper evaluates the MCv2 RISC-V cluster (Sophgo SG2042 / Milk-V
//! Pioneer nodes) with STREAM and HPL across BLAS libraries, and
//! contributes a BLIS micro-kernel optimization for the C920's RVV 0.7.1
//! vector unit (LMUL register grouping). Since the physical testbed is
//! unavailable, this crate implements the complete substrate as a
//! simulation + real-numerics stack:
//!
//! - [`isa`] — RVV 0.7.1 (theadvector) / RVV 1.0 instruction model with a
//!   *functional* vector machine (real f64 numerics) and a timing model.
//! - [`ukernel`] — the data-driven micro-kernel registry: GEMM kernels
//!   are [`ukernel::KernelDescriptor`]s (generator family + VLEN, LMUL,
//!   tile, K-unroll, blocking tunables) in a
//!   [`ukernel::KernelRegistry`]; built-ins cover the paper's four
//!   (OpenBLAS generic/C920, BLIS LMUL=1 of Fig 2a, BLIS LMUL=4 of
//!   Fig 2b) plus the native RVV 1.0 tuning points of the SG2044/MCv3
//!   successors, and spec files derive more via `[[kernel]]` sections.
//! - [`blas`] — BLIS-style blocked GEMM over the micro-kernels, cache
//!   blocking derivation and the calibrated per-library performance model.
//! - [`cache`] — trace-driven set-associative L1/L2/L3 simulator (Fig 6).
//! - [`mem`] — DDR4 multi-channel bandwidth model (Fig 3).
//! - [`net`] — 1 GbE + MPI-collective cost models (Fig 5).
//! - [`hpl`] / [`stream`] — the benchmarks themselves, with real numerics.
//! - [`arch`] — the open platform API: SoC descriptors bundled with
//!   power models and perf calibration into [`arch::Platform`]s,
//!   registered by string id in an [`arch::PlatformRegistry`] (built-in:
//!   MCv1 U740, MCv2 SG2042 single/dual, and the SG2044 / MCv3
//!   successors; user-defined platforms load from campaign spec files).
//! - [`sched`] / [`cluster`] — SLURM-like scheduler and node inventories
//!   built from `(platform_id, count)` fleet specs, with a parallel
//!   per-partition drain for independent job streams.
//! - [`runtime`] — PJRT client executing the JAX/Pallas-authored HLO
//!   artifacts (`artifacts/*.hlo.txt`); Python never runs at this layer.
//! - [`coordinator`] — the declarative campaign engine: a
//!   [`coordinator::Workload`] trait (STREAM, HPL, BLIS-ablation
//!   implementations) plus a [`coordinator::CampaignSpec`] describing a
//!   benchmark campaign as *data* — workloads, fleet and even custom
//!   platforms, buildable in code or parsed from a `util::config` file —
//!   which `run_campaign_spec` estimates in parallel (with per-job
//!   power/energy), schedules, monitors, and reports (human-readable or
//!   JSON). The paper's own 9-job campaign is
//!   `CampaignSpec::paper_default()`; figure renderers live alongside in
//!   [`coordinator::report`]. On top sits the scenario sweep engine
//!   ([`coordinator::scenario`]): a `ScenarioMatrix` expands one base
//!   campaign across axes (platforms, fleet sizes, BLAS libraries,
//!   workload subsets) into named scenarios, runs them with rayon
//!   fan-out, and aggregates them into a Green500-style
//!   `ComparisonReport` with speedup-vs-baseline columns — the built-in
//!   generation matrix reproduces the abstract's 127x HPL / 69x STREAM
//!   MCv1 -> MCv2 uplifts (`cimone sweep`).
//! - [`error`] — the typed [`CimoneError`] every layer above reports
//!   failures with (convertible into the crate-wide [`Result`]).

pub mod error;
pub mod util;
pub mod arch;
pub mod isa;
pub mod ukernel;
pub mod blas;
pub mod cache;
pub mod mem;
pub mod net;
pub mod hpl;
pub mod stream;
pub mod sched;
pub mod cluster;
pub mod runtime;
pub mod coordinator;
pub mod perfsuite;

pub use error::CimoneError;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
