//! `cimone` — the Monte Cimone v2 reproduction CLI.
//!
//! Subcommands map one-to-one onto the paper's evaluation:
//!
//! ```text
//! cimone stream                      Fig 3: STREAM bandwidth table
//! cimone hpl [--cores a,b,..]        Fig 4: HPL vs cores, OpenBLAS variants
//! cimone cluster-hpl                 Fig 5: node-configuration comparison
//! cimone cache-miss [--scale 0.5]    Fig 6: L1/L3 miss rates OB vs BLIS
//! cimone blis-compare                Fig 7: three-library comparison
//! cimone headline                    the abstract's 127x / 69x
//! cimone report-all                  everything above
//! cimone run-hpl [--n 256 --nb 32]   real-numerics HPL + residual check
//! cimone validate [--artifacts dir]  PJRT artifacts vs native numerics
//! cimone campaign [--n 96]           end-to-end: SLURM sim + monitor
//!         [--spec file.toml]         ... driven by a declarative campaign spec
//!         [--dry-run]                ... validate + estimate only, no scheduling
//!         [--json]                   ... machine-readable CampaignReport
//! cimone sweep [--spec file.toml]    scenario sweep -> Green500-style table
//!         [--dry-run] [--json]       ... default: the built-in generation
//!                                        matrix (127x HPL / 69x STREAM)
//!         [--matrix full-codesign]   ... or the full co-design product:
//!                                        kernels x platforms x fabrics x
//!                                        fleets x caps x outages x workloads,
//!                                        ~10^5 scenarios streamed through
//!                                        the sharded --top-k aggregator
//!         [--matrix fabric-scaling]  ... or another built-in matrix: the
//!                                        Fig 5 node-count x fabric sweep
//!         [--matrix blas-tuning]     ... or the kernel-tuning sweep: the
//!                                        Fig 2 LMUL uplift on SG2042 vs the
//!                                        native-RVV 1.0 winner on SG2044
//!         [--matrix power-cap]       ... or the power-cap sweep: node count
//!                                        x per-node W cap per generation,
//!                                        best GF/s-per-W operating point
//!         [--matrix precision]       ... or the mixed-precision sweep: FP64
//!                                        HPL vs HPL-MxP (SEW=32, two elems
//!                                        per lane) on every vector platform
//!         [--matrix sparse]          ... or the sparse roofline: STREAM
//!                                        triad vs an HPCG-shaped SpMV per
//!                                        generation, both DDR-stream bound
//!         [--top-k 4] [--shard 64]   ... streaming knobs: keep baseline +
//!                                        best k rows; scenarios per batch
//! cimone bench [--quick] [--json]    estimation-stack perf suite: simulated
//!         [--out BENCH.json]         ... insts/s, analyses/s, scenarios/s
//!                                        cold vs warm + determinism fingerprint
//! cimone platforms                   the registered platform fleet (SoC table)
//! cimone fabrics                     the registered interconnects
//! cimone kernels                     the registered BLAS micro-kernels
//! cimone translate-demo              section 3.3.1 RVV 1.0 -> 0.7.1 retrofit
//! cimone asm file.S                  assemble a micro-kernel listing:
//!         [--check]                  ... validate + summary (the default)
//!         [--disasm]                 ... canonical disassembly round-trip
//!         [--analyze] [--vlen 128]   ... cycle-model timing at a VLEN
//!         [--json]                   ... machine-readable output
//! ```
//!
//! Campaign specs name platforms by registry id or alias (`mcv2-pioneer`,
//! `sg2044`, ...), may define their own via `[[platform]]` sections, and
//! pick the simulated machine with `[[fleet]]` entries — including its
//! interconnect (`fabric =` keys, `[[fabric]]` overrides). `[[queue]]`
//! sections expand a workload into a per-user job stream (arrival times,
//! priorities), and `[[outage]]` sections take nodes out of service over
//! time windows (link flaps via `repeat` / `every`). Sweep specs add
//! `[matrix]` axes and `[[scenario]]` sections that expand one base
//! campaign into many named scenarios compared against the first —
//! including `power_caps` / `nodes_down` operating-point axes.

use cimone::arch::PlatformRegistry;
use cimone::coordinator::scenario::{self, ScenarioMatrix};
use cimone::coordinator::{driver, report, CampaignSpec};
use cimone::error::CimoneError;
use cimone::hpl::driver::{run as hpl_run, Backend, HplConfig};
use cimone::hpl::validate::HPL_THRESHOLD;
use cimone::isa::asm::render_program;
use cimone::isa::translate::rvv10_to_thead;
use cimone::ukernel::{KernelRegistry, PanelLayout};
use cimone::util::cli::Args;
use cimone::util::table::Table;
use cimone::util::Matrix;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<(), CimoneError> {
    match args.subcommand.as_deref() {
        Some("stream") => {
            println!("{}", report::render_fig3());
        }
        Some("hpl") => {
            println!("{}", report::render_fig4());
        }
        Some("cluster-hpl") => {
            println!("{}", report::render_fig5());
        }
        Some("cache-miss") => {
            let scale = args.get_f64("scale", 1.0)?;
            println!("{}", report::render_fig6(scale));
        }
        Some("blis-compare") => {
            println!("{}", report::render_fig7());
        }
        Some("headline") => {
            println!("{}", report::render_headline());
        }
        Some("report-all") => {
            let scale = args.get_f64("scale", 0.5)?;
            println!("{}", report::render_all(scale));
        }
        Some("sweeps") => {
            println!("{}", cimone::coordinator::sweeps::render_all());
        }
        Some("run-hpl") => {
            let n = args.get_usize("n", 256)?;
            let nb = args.get_usize("nb", 32)?;
            let backend = match args.get("lib") {
                None => Backend::Native,
                // typed UnknownKernel (listing the registered ids) on a typo
                Some(l) => Backend::SimulatedBlas(KernelRegistry::builtin().get(l)?),
            };
            let r =
                hpl_run(&HplConfig { n, nb, seed: args.get_usize("seed", 42)? as u64, backend })?;
            println!(
                "HPL n={} : {:.3}s host ({:.2} Gflop/s), residual {:.3e} -> {}",
                r.n,
                r.seconds,
                r.host_gflops,
                r.residual,
                if r.passed { "PASSED" } else { "FAILED" }
            );
            if !r.passed {
                return Err(CimoneError::ResidualCheck {
                    residual: r.residual,
                    threshold: HPL_THRESHOLD,
                });
            }
        }
        Some("validate") => {
            validate_artifacts(args)?;
        }
        Some("campaign") => {
            // declarative path: --spec <file> describes the campaign
            // (workloads + fleet + custom platforms); without it the
            // paper's 9-job default runs on the paper's 12-node machine
            let mut spec = match args.get("spec") {
                Some(path) => CampaignSpec::load(path)?,
                None => CampaignSpec::paper_default(),
            };
            // an explicit --n overrides the spec's validation size
            if args.get("n").is_some() {
                spec.validate_n = args.get_usize("n", spec.validate_n)?;
            }
            let inv = spec.build_inventory()?;
            if args.flag("dry-run") {
                // validate + estimate only; any spec problem exits non-zero
                let rows = driver::dry_run_spec(&inv, &spec)?;
                if args.flag("json") {
                    let jobs: Vec<_> = rows.iter().map(|j| j.to_json()).collect();
                    println!("{}", cimone::util::json::Json::Arr(jobs).render());
                } else {
                    println!(
                        "dry run: spec OK — {} jobs on {} nodes, nothing scheduled",
                        rows.len(),
                        inv.nodes.len()
                    );
                    print_job_rows(&rows);
                }
            } else {
                let r = driver::run_campaign_spec(&inv, &spec)?;
                if args.flag("json") {
                    println!("{}", r.to_json().render());
                } else {
                    println!(
                        "campaign: {} jobs, makespan {:.0}s (simulated)",
                        r.jobs.len(),
                        r.makespan_s
                    );
                    println!(
                        "validation: HPL residual {:.3e} ({}), STREAM {}",
                        r.hpl_residual,
                        if r.hpl_passed { "passed" } else { "FAILED" },
                        if r.stream_validated { "validated" } else { "FAILED" }
                    );
                    print_job_rows(&r.jobs);
                }
            }
        }
        Some("sweep") => {
            // scenario sweep: a matrix spec expands into N campaigns run
            // as one batch; without --spec, a built-in matrix runs — the
            // generation table (127x / 69x headline) by default, or the
            // Fig 5 node-count x fabric sweep via --matrix
            let matrix = match (args.get("spec"), args.get("matrix")) {
                (Some(_), Some(_)) => {
                    return Err(CimoneError::Cli(
                        "--spec and --matrix are mutually exclusive".into(),
                    ));
                }
                (Some(path), None) => ScenarioMatrix::load(path)?,
                (None, Some("generations")) | (None, None) => ScenarioMatrix::generations(),
                (None, Some("fabric-scaling")) => ScenarioMatrix::fabric_scaling(),
                (None, Some("blas-tuning")) => ScenarioMatrix::blas_tuning(),
                (None, Some("power-cap")) => ScenarioMatrix::power_cap(),
                (None, Some("precision")) => ScenarioMatrix::precision(),
                (None, Some("sparse")) => ScenarioMatrix::sparse(),
                (None, Some("full-codesign")) => ScenarioMatrix::full_codesign(),
                (None, Some(other)) => {
                    return Err(CimoneError::Cli(format!(
                        "unknown built-in matrix `{other}` \
                         (generations | fabric-scaling | blas-tuning | power-cap | \
                          precision | sparse | full-codesign)"
                    )));
                }
            };
            let opts = scenario::SweepOptions {
                shard_size: args
                    .get_usize("shard", scenario::SweepOptions::default().shard_size)?,
                top_k: match args.get("top-k") {
                    Some(_) => Some(args.get_usize("top-k", 0)?),
                    None => None,
                },
            };
            let report = if args.flag("dry-run") {
                scenario::dry_run_matrix_with(&matrix, &opts)?
            } else {
                scenario::run_matrix_with(&matrix, &opts)?
            };
            if args.flag("json") {
                println!("{}", report.to_json().render());
            } else {
                if args.flag("dry-run") {
                    println!(
                        "dry run: {} of {} scenarios estimated, nothing scheduled",
                        report.scenarios.len(),
                        report.total
                    );
                }
                println!("{}", report.render());
            }
        }
        Some("bench") => {
            // the estimation-stack perf suite (recorded trajectory in
            // BENCH_6.json); --quick is the CI smoke configuration
            let suite = cimone::perfsuite::run(args.flag("quick"))?;
            if args.flag("json") {
                println!("{}", suite.json.render());
            } else {
                println!("{}", suite.render());
            }
            if let Some(path) = args.get("out") {
                std::fs::write(path, suite.json.render() + "\n")
                    .map_err(|e| CimoneError::Cli(format!("cannot write `{path}`: {e}")))?;
                eprintln!("wrote {path}");
            }
        }
        Some("platforms") => {
            let reg = PlatformRegistry::builtin();
            let mut t = Table::new(vec![
                "id",
                "label",
                "partition",
                "cores",
                "peak GF/s",
                "idle W",
                "aliases",
            ]);
            for p in reg.platforms() {
                t.row(vec![
                    p.id.clone(),
                    p.label.clone(),
                    p.partition.clone(),
                    p.desc.total_cores().to_string(),
                    format!("{:.1}", p.peak_gflops()),
                    format!("{:.0}", p.power.idle_w),
                    p.aliases.join(", "),
                ]);
            }
            println!("{}", t.render());
        }
        Some("fabrics") => {
            let reg = cimone::net::FabricRegistry::builtin();
            let mut t = Table::new(vec![
                "id",
                "label",
                "Gb/s",
                "latency us",
                "ports",
                "backplane",
                "aliases",
            ]);
            for f in reg.fabrics() {
                t.row(vec![
                    f.id.clone(),
                    f.label.clone(),
                    format!("{:.0}", f.link.raw_bps / 1e9),
                    format!("{:.0}", f.link.latency_s * 1e6),
                    f.ports.to_string(),
                    format!("{:.2}", f.backplane_factor),
                    f.aliases.join(", "),
                ]);
            }
            println!("{}", t.render());
        }
        Some("kernels") => {
            let reg = KernelRegistry::builtin();
            let mut t = Table::new(vec![
                "id",
                "label",
                "family",
                "VLEN",
                "LMUL",
                "tile",
                "unroll",
                "blocking",
                "overhead",
                "aliases",
            ]);
            for k in reg.kernels() {
                t.row(vec![
                    k.id.clone(),
                    k.label.clone(),
                    k.family.spec_name().to_string(),
                    if k.vlen_bits == 0 { "scalar".into() } else { k.vlen_bits.to_string() },
                    format!("m{}", k.lmul.multiplier()),
                    format!("{}x{}", k.mr, k.nr),
                    k.k_unroll.to_string(),
                    k.blocking.spec_name().to_string(),
                    format!("{:.0}%", 100.0 * k.host_overhead),
                    k.aliases.join(", "),
                ]);
            }
            println!("{}", t.render());
        }
        Some("asm") => {
            asm_command(args)?;
        }
        Some("translate-demo") => {
            let kernel = KernelRegistry::builtin().get("blis-lmul1")?;
            let prog = kernel.program(PanelLayout::new(8, 4, 1));
            println!("--- BLIS rv64iv micro-kernel (RVV 1.0), one k-step ---");
            println!("{}", render_program(&prog));
            let translated =
                rvv10_to_thead(&prog).map_err(|e| CimoneError::Machine(e.to_string()))?;
            println!("\n--- retrofitted to XuanTie theadvector (RVV 0.7.1) ---");
            println!("{}", render_program(&translated));
        }
        Some(other) => {
            return Err(CimoneError::Cli(format!(
                "unknown subcommand `{other}` (see --help in README)"
            )));
        }
        None => {
            println!("usage: cimone <stream|hpl|cluster-hpl|cache-miss|blis-compare|headline|report-all|sweeps|run-hpl|validate|campaign|sweep|bench|platforms|fabrics|kernels|translate-demo|asm>");
        }
    }
    Ok(())
}

/// Per-job table shared by `campaign` and `campaign --dry-run`.
fn print_job_rows(rows: &[cimone::coordinator::JobRow]) {
    for j in rows {
        let eff = match j.gflops_per_w {
            Some(e) => format!("{e:>6.2} GF/W"),
            None => "      -    ".to_string(),
        };
        println!(
            "  {:<18} {:>10.1}s  -> {:>8.1}  {:>6.0} W/node  {:>10.0} J  {}",
            j.name, j.runtime_s, j.headline, j.avg_node_w, j.energy_j, eff
        );
    }
}

/// `cimone asm <file.S>`: assemble a hand-written micro-kernel listing.
/// `--check` (the default) validates and prints a summary; `--disasm`
/// prints the canonical round-trip listing; `--analyze` runs the cycle
/// model at `--vlen` (default 128). `--json` makes any mode
/// machine-readable. The positional path comes first: `--check file.S`
/// would swallow the path as the flag's value.
fn asm_command(args: &Args) -> Result<(), CimoneError> {
    use cimone::arch::presets::c920;
    use cimone::isa::assembler;
    use cimone::isa::inst::Dialect;
    use cimone::isa::timing::CycleModel;
    use cimone::util::json::Json;

    let path = args.positional.first().ok_or_else(|| {
        CimoneError::Cli("asm: expected a listing path (usage: cimone asm <file.S>)".into())
    })?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| CimoneError::Cli(format!("cannot read `{path}`: {e}")))?;
    let prog = assembler::assemble_named(&text, path)?;
    let dialect = match prog.dialect {
        Dialect::Rvv10 => "rvv10",
        Dialect::Thead071 => "thead071",
    };
    let (v, m, s) = prog.mix();

    if args.flag("disasm") {
        print!("{}", assembler::disassemble(&prog));
        return Ok(());
    }
    if args.flag("analyze") {
        let vlen = args.get_usize("vlen", 128)?;
        let t = CycleModel::new(&c920()).analyze_at(&prog, vlen);
        if args.flag("json") {
            let j = Json::obj([
                ("file", Json::Str(path.to_string())),
                ("dialect", Json::Str(dialect.into())),
                ("vlen", Json::Num(vlen as f64)),
                ("insts", Json::Num(t.insts as f64)),
                ("flops", Json::Num(t.flops as f64)),
                ("cycles", Json::Num(t.cycles)),
                ("vector_cycles", Json::Num(t.vector_cycles)),
                ("scalar_mem_cycles", Json::Num(t.scalar_mem_cycles)),
                ("scalar_fma_cycles", Json::Num(t.scalar_fma_cycles)),
                ("scalar_other_cycles", Json::Num(t.scalar_other_cycles)),
            ]);
            println!("{}", j.render());
        } else {
            println!("{path}: {dialect}, {} insts, {} flops @ VLEN={vlen}", t.insts, t.flops);
            println!(
                "  {:.1} cycles ({:.1} vector, {:.1} scalar mem, {:.1} scalar fma, {:.1} other)",
                t.cycles,
                t.vector_cycles,
                t.scalar_mem_cycles,
                t.scalar_fma_cycles,
                t.scalar_other_cycles
            );
        }
        return Ok(());
    }
    // --check / default: assembly already succeeded; report the summary
    if args.flag("json") {
        let j = Json::obj([
            ("file", Json::Str(path.to_string())),
            ("dialect", Json::Str(dialect.into())),
            ("insts", Json::Num(prog.insts.len() as f64)),
            ("vector", Json::Num(v as f64)),
            ("scalar_mem", Json::Num(m as f64)),
            ("scalar_other", Json::Num(s as f64)),
        ]);
        println!("{}", j.render());
    } else {
        println!(
            "{path}: OK — {dialect}, {} insts ({v} vector, {m} scalar mem, {s} scalar other)",
            prog.insts.len()
        );
    }
    Ok(())
}

/// `cimone validate`: run the PJRT artifacts against native numerics.
fn validate_artifacts(args: &Args) -> Result<(), CimoneError> {
    use cimone::runtime::{entries, Runtime};
    let dir =
        args.get_or("artifacts", &cimone::runtime::ArtifactManifest::default_dir()).to_string();
    let mut rt = Runtime::with_dir(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let n = rt.manifest.n_gemm;

    // GEMM artifact vs native
    let a = Matrix::random_hpl(n, n, 1);
    let b = Matrix::random_hpl(n, n, 2);
    let got = entries::gemm(&mut rt, &a, &b)?;
    let mut want = Matrix::zeros(n, n);
    Matrix::gemm_acc(&mut want, &a, &b);
    if !got.allclose(&want, 1e-10, 1e-10) {
        return Err(CimoneError::Runtime("gemm_256 artifact disagrees with native GEMM".into()));
    }
    println!("gemm_256          OK ({n}x{n})");

    // micro-kernel artifacts vs the ISA machine
    let a8 = Matrix::random_hpl(8, 64, 3);
    let b8 = Matrix::random_hpl(64, 8, 4);
    let c8 = Matrix::random_hpl(8, 8, 5);
    for variant in ["lmul1", "lmul4"] {
        let got = entries::ukernel(&mut rt, variant, &a8, &b8, &c8)?;
        let mut want = c8.clone();
        Matrix::gemm_acc(&mut want, &a8, &b8);
        if !got.allclose(&want, 1e-10, 1e-10) {
            return Err(CimoneError::Runtime(format!("ukernel_{variant} artifact mismatch")));
        }
        println!("ukernel_{variant}     OK (8x8x64)");
    }

    // STREAM triad artifact
    let ns = rt.manifest.n_stream;
    let sa: Vec<f64> = (0..ns).map(|i| (i % 97) as f64 * 0.5).collect();
    let sb: Vec<f64> = (0..ns).map(|i| (i % 89) as f64 * 0.25).collect();
    let got = entries::stream(&mut rt, "triad", &sa, Some(&sb))?;
    for i in (0..ns).step_by(ns / 17) {
        let want = sa[i] + 3.0 * sb[i];
        if (got[i] - want).abs() > 1e-12 {
            return Err(CimoneError::Runtime(format!(
                "stream_triad mismatch at {i}: {} vs {want}",
                got[i]
            )));
        }
    }
    println!("stream_triad      OK ({ns} elems)");
    println!("all artifacts validated against native numerics");
    Ok(())
}
