//! DDR4 multi-channel bandwidth model.
//!
//! The shape the paper's Fig 3 reports: attained bandwidth ramps with
//! thread count (each in-order core can only keep ~1.35 GB/s of requests
//! in flight on the SG2042), saturates at the controller's attainable
//! ceiling, and *degrades* under oversubscription ("increasing the number
//! of OpenMP threads reduces the attained bandwidth").

use crate::arch::soc::MemorySystem;

/// Per-thread oversubscription penalty beyond the core count (fraction of
/// ceiling lost per extra thread: context switching + bank conflicts).
pub const OVERSUB_PENALTY: f64 = 0.004;

/// Bandwidth model for one socket's memory system.
#[derive(Debug, Clone, Copy)]
pub struct DdrModel {
    pub mem: MemorySystem,
    pub cores: usize,
}

impl DdrModel {
    pub fn new(mem: MemorySystem, cores: usize) -> Self {
        DdrModel { mem, cores }
    }

    /// Attained STREAM bandwidth (bytes/s) with `threads` on this socket.
    pub fn bandwidth(&self, threads: usize) -> f64 {
        if threads == 0 {
            return 0.0;
        }
        let ceiling = self.mem.attainable_bw();
        let ramp = threads as f64 * self.mem.per_core_bw_bytes;
        let base = ramp.min(ceiling);
        if threads > self.cores {
            let over = (threads - self.cores) as f64;
            (base * (1.0 - OVERSUB_PENALTY * over)).max(0.1 * ceiling)
        } else {
            base
        }
    }

    /// Threads needed to reach 95% of the ceiling.
    pub fn saturation_threads(&self) -> usize {
        let ceiling = self.mem.attainable_bw();
        ((0.95 * ceiling) / self.mem.per_core_bw_bytes).ceil() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    fn sg() -> DdrModel {
        let s = &presets::sg2042().sockets[0];
        DdrModel::new(s.mem, s.cores)
    }

    fn u7() -> DdrModel {
        let s = &presets::u740().sockets[0];
        DdrModel::new(s.mem, s.cores)
    }

    #[test]
    fn sg2042_saturates_to_41_9() {
        let m = sg();
        let bw = m.bandwidth(64);
        assert!((bw - 41.9e9).abs() < 0.5e9, "{bw}");
    }

    #[test]
    fn ramp_is_linear_before_saturation() {
        let m = sg();
        let b8 = m.bandwidth(8);
        let b16 = m.bandwidth(16);
        assert!((b16 / b8 - 2.0).abs() < 0.01);
    }

    #[test]
    fn oversubscription_degrades() {
        let m = sg();
        assert!(m.bandwidth(96) < m.bandwidth(64));
        assert!(m.bandwidth(128) < m.bandwidth(96));
    }

    #[test]
    fn u740_saturates_at_1_1_with_4_threads() {
        let m = u7();
        let bw = m.bandwidth(4);
        assert!((bw - 1.1e9).abs() < 0.1e9, "{bw}");
        // and ~saturated already at 4 threads (paper's configuration)
        assert!(m.saturation_threads() <= 4);
    }

    #[test]
    fn zero_threads_zero_bandwidth() {
        assert_eq!(sg().bandwidth(0), 0.0);
    }

    #[test]
    fn sg2042_saturation_point_below_64() {
        // per-core 0.9 GB/s -> ~45 threads to saturate; 64 certainly does
        let t = sg().saturation_threads();
        assert!(t <= 64, "{t}");
    }
}
