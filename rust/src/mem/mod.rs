//! Memory-system models: DDR4 channel bandwidth and the STREAM
//! bandwidth-vs-threads saturation curve (Fig 3).

pub mod ddr;
pub mod stream_model;

pub use ddr::DdrModel;
pub use stream_model::{predict_node_bandwidth, KERNEL_FACTORS};
