//! Node-level STREAM prediction: composes per-socket DDR models with the
//! thread-pinning policy, producing the Fig 3 numbers.

use super::ddr::DdrModel;
use crate::arch::soc::SocDescriptor;
use crate::util::hash::ContentHasher;

/// Relative bandwidth of the four STREAM kernels vs copy (empirical:
/// add/triad slightly beat copy/scale on most DDR4 systems because two
/// read streams amortize write-allocate traffic).
pub const KERNEL_FACTORS: [(&str, f64); 4] =
    [("copy", 1.00), ("scale", 0.985), ("add", 1.04), ("triad", 1.045)];

/// The triad factor from [`KERNEL_FACTORS`] — SpMV's streaming phase
/// (values + column indices + y) behaves like triad: two read streams
/// and one write stream amortizing write-allocate traffic.
pub const SPMV_STREAM_FACTOR: f64 = 1.045;

/// Efficiency of indexed-gather traffic relative to unit-stride
/// streaming: each x[col[j]] miss pulls a whole line but uses 8 bytes,
/// and the open-page locality the DDR model's `efficiency` assumes is
/// gone. Calibrated so an SG2042-class socket lands at the ~10-15% of
/// triad bandwidth HPCG-style SpMV typically sustains uncached.
pub const SPMV_GATHER_EFF: f64 = 0.6;

/// CSR problem shape of a sparse matrix-vector workload: `y = A*x` with
/// `rows` rows averaging `nnz_per_row` nonzeros, column indices stored
/// in `index_bytes`-wide integers. (A 27-point stencil at 1M rows is the
/// HPCG-style default.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparseShape {
    pub rows: usize,
    pub nnz_per_row: usize,
    /// Width of one CSR column index / row pointer (4 = int32 CSR).
    pub index_bytes: usize,
}

impl SparseShape {
    /// Total nonzeros.
    pub fn nnz(&self) -> f64 {
        self.rows as f64 * self.nnz_per_row as f64
    }

    /// Degenerate-shape guard: a zero dimension would put 0 in a
    /// denominator downstream and surface as a NaN GF/s row. Returns the
    /// reason string callers wrap into
    /// [`crate::error::CimoneError::SparseShape`].
    pub fn check(&self) -> Result<(), String> {
        if self.rows == 0 {
            return Err("rows must be >= 1".into());
        }
        if self.nnz_per_row == 0 {
            return Err("nnz_per_row must be >= 1 (an empty matrix has no FLOPs)".into());
        }
        if self.index_bytes == 0 || self.index_bytes > 8 {
            return Err(format!(
                "index_bytes must be in 1..=8, got {} (4 = int32 CSR)",
                self.index_bytes
            ));
        }
        Ok(())
    }

    /// Canonical content feed for the estimation cache.
    pub fn feed_content(&self, h: &mut ContentHasher) {
        h.write_usize(self.rows).write_usize(self.nnz_per_row).write_usize(self.index_bytes);
    }
}

/// Projected SpMV performance of one node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpmvProjection {
    /// Time for one y = A*x sweep (seconds).
    pub time_s: f64,
    /// 2 * nnz FLOPs over `time_s`.
    pub gflops: f64,
    /// Effective DDR traffic rate: (streamed + gathered bytes) / time.
    /// A weighted harmonic mean of the streaming and gather rates, so it
    /// never exceeds the node's triad bandwidth.
    pub ddr_bytes_per_s: f64,
    /// Fraction of x resident in the last-level caches (0..=1).
    pub x_hit: f64,
}

/// Last-level cache bytes reachable from `threads` cores: the L3 where
/// one exists, else the per-cluster L2 instances the threads span.
fn llc_bytes(desc: &SocDescriptor) -> f64 {
    desc.sockets
        .iter()
        .map(|s| match &s.l3 {
            Some(l3) => l3.size_bytes as f64,
            None => {
                let instances = (s.cores / s.l2.shared_by.max(1)).max(1);
                (s.l2.size_bytes * instances) as f64
            }
        })
        .sum()
}

/// Project CSR SpMV (`y = A*x`) on a node: the streaming phase (values,
/// column indices, row pointers, y) runs at triad bandwidth; the
/// x-gather phase pays a full cache line per miss at
/// [`SPMV_GATHER_EFF`] of that rate, with the hit fraction set by how
/// much of x the last-level caches hold. Bandwidth-bound like STREAM,
/// compute-free like HPCG's SpMV kernel.
pub fn predict_spmv(
    desc: &SocDescriptor,
    threads: usize,
    shape: SparseShape,
) -> Result<SpmvProjection, String> {
    shape.check()?;
    let bw = predict_node_bandwidth(desc, threads, true) * SPMV_STREAM_FACTOR;
    if bw <= 0.0 {
        return Err(format!("no projected bandwidth at {threads} threads"));
    }
    let rows = shape.rows as f64;
    let nnz = shape.nnz();
    let idx = shape.index_bytes as f64;
    // unit-stride traffic: values + column indices per nonzero, one row
    // pointer and the y element per row
    let stream_bytes = nnz * (8.0 + idx) + rows * (idx + 8.0);
    // x residency: the gather stream hits wherever x fits in the LLCs
    let x_bytes = rows * 8.0;
    let x_hit = (llc_bytes(desc) / x_bytes).min(1.0);
    let line = desc.sockets[0].l2.line_bytes.max(8) as f64;
    let gather_bytes = nnz * (1.0 - x_hit) * line;
    let time_s = stream_bytes / bw + gather_bytes / (bw * SPMV_GATHER_EFF);
    Ok(SpmvProjection {
        time_s,
        gflops: 2.0 * nnz / time_s / 1e9,
        ddr_bytes_per_s: (stream_bytes + gather_bytes) / time_s,
        x_hit,
    })
}

/// Predicted aggregate bandwidth (bytes/s) for `threads` spread over the
/// node. `symmetric_pinning` splits threads evenly across sockets (the
/// paper's best configuration); otherwise all threads land on socket 0
/// until full, then spill.
pub fn predict_node_bandwidth(
    desc: &SocDescriptor,
    threads: usize,
    symmetric_pinning: bool,
) -> f64 {
    if threads == 0 {
        return 0.0;
    }
    let n_sock = desc.sockets.len();
    let mut per_socket_threads = vec![0usize; n_sock];
    if symmetric_pinning {
        for s in 0..n_sock {
            per_socket_threads[s] = threads / n_sock + usize::from(s < threads % n_sock);
        }
    } else {
        let mut left = threads;
        for (s, sock) in desc.sockets.iter().enumerate() {
            let take = left.min(sock.cores);
            per_socket_threads[s] = take;
            left -= take;
        }
        // oversubscription: leftover threads pile on socket 0
        per_socket_threads[0] += left;
    }
    desc.sockets
        .iter()
        .zip(&per_socket_threads)
        .map(|(sock, &t)| DdrModel::new(sock.mem, sock.cores).bandwidth(t))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn fig3_mcv2_single_socket() {
        let d = presets::sg2042();
        let bw = predict_node_bandwidth(&d, 64, true);
        assert!((bw - 41.9e9).abs() < 0.5e9, "{bw}");
    }

    #[test]
    fn fig3_mcv2_dual_socket_symmetric() {
        // paper: 82.9 GB/s with 64 threads pinned symmetrically
        let d = presets::sg2042_dual();
        let bw = predict_node_bandwidth(&d, 64, true);
        assert!((82.0e9..86.0e9).contains(&bw), "{bw}");
    }

    #[test]
    fn fig3_mcv1() {
        let d = presets::u740();
        let bw = predict_node_bandwidth(&d, 4, true);
        assert!((bw - 1.1e9).abs() < 0.1e9, "{bw}");
    }

    #[test]
    fn dual_socket_more_threads_reduces_bandwidth() {
        // "increasing the number of OpenMP threads reduces the attained
        // bandwidth" — 128 threads oversubscribe nothing (128 cores) but
        // on the single socket 128 threads certainly degrade:
        let d1 = presets::sg2042();
        assert!(
            predict_node_bandwidth(&d1, 128, true) < predict_node_bandwidth(&d1, 64, true)
        );
    }

    #[test]
    fn asymmetric_pinning_hurts_dual_socket() {
        let d = presets::sg2042_dual();
        let sym = predict_node_bandwidth(&d, 64, true);
        let asym = predict_node_bandwidth(&d, 64, false);
        assert!(asym < sym, "sym={sym} asym={asym}");
    }

    #[test]
    fn headline_69x_stream_uplift() {
        // abstract: "69x on Stream Memory Bandwidth" (node vs node)
        let v1 = predict_node_bandwidth(&presets::u740(), 4, true);
        let v2 = predict_node_bandwidth(&presets::sg2042_dual(), 64, true);
        let ratio = v2 / v1;
        assert!((60.0..85.0).contains(&ratio), "uplift {ratio:.0}x");
    }

    #[test]
    fn kernel_factors_cover_all_four() {
        let names: Vec<&str> = KERNEL_FACTORS.iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["copy", "scale", "add", "triad"]);
    }

    /// HPCG-style default shape: 1M rows, 27-point stencil, int32 CSR.
    fn hpcg_shape() -> SparseShape {
        SparseShape { rows: 1 << 20, nnz_per_row: 27, index_bytes: 4 }
    }

    #[test]
    fn spmv_never_exceeds_triad_bandwidth() {
        // the acceptance invariant: effective DDR rate is a harmonic
        // mean of the stream and gather rates, <= triad by construction
        for d in [presets::u740(), presets::sg2042(), presets::sg2042_dual()] {
            let threads = d.total_cores();
            let triad = predict_node_bandwidth(&d, threads, true) * SPMV_STREAM_FACTOR;
            for shape in [
                hpcg_shape(),
                SparseShape { rows: 1 << 12, nnz_per_row: 7, index_bytes: 4 },
                SparseShape { rows: 1 << 24, nnz_per_row: 50, index_bytes: 8 },
            ] {
                let p = predict_spmv(&d, threads, shape).unwrap();
                assert!(
                    p.ddr_bytes_per_s <= triad * (1.0 + 1e-12),
                    "{}: {} > {triad}",
                    d.name,
                    p.ddr_bytes_per_s
                );
                assert!(p.gflops > 0.0 && p.gflops.is_finite());
            }
        }
    }

    #[test]
    fn cached_x_runs_at_stream_rate() {
        // when x fits in the LLC the gather term vanishes and the
        // effective rate IS the triad rate
        let d = presets::sg2042();
        let small = SparseShape { rows: 1 << 10, nnz_per_row: 27, index_bytes: 4 };
        let p = predict_spmv(&d, 64, small).unwrap();
        assert_eq!(p.x_hit, 1.0);
        let triad = predict_node_bandwidth(&d, 64, true) * SPMV_STREAM_FACTOR;
        assert!((p.ddr_bytes_per_s - triad).abs() < 1e-3 * triad);
        // ...and a DDR-resident x is strictly slower per nonzero
        let big = predict_spmv(&d, 64, hpcg_shape()).unwrap();
        assert!(big.x_hit < 1.0);
        assert!(big.gflops < p.gflops);
    }

    #[test]
    fn degenerate_sparse_shapes_are_errors_not_nans() {
        let d = presets::sg2042();
        for shape in [
            SparseShape { rows: 0, nnz_per_row: 27, index_bytes: 4 },
            SparseShape { rows: 100, nnz_per_row: 0, index_bytes: 4 },
            SparseShape { rows: 100, nnz_per_row: 27, index_bytes: 0 },
            SparseShape { rows: 100, nnz_per_row: 27, index_bytes: 16 },
        ] {
            assert!(predict_spmv(&d, 64, shape).is_err(), "{shape:?}");
        }
        // zero threads: typed, not a division by zero bandwidth
        assert!(predict_spmv(&d, 0, hpcg_shape()).is_err());
    }

    #[test]
    fn spmv_scales_with_the_memory_system() {
        // bandwidth-bound: the dual-socket node roughly doubles SpMV
        let one = predict_spmv(&presets::sg2042(), 64, hpcg_shape()).unwrap();
        let two = predict_spmv(&presets::sg2042_dual(), 128, hpcg_shape()).unwrap();
        let ratio = two.gflops / one.gflops;
        assert!((1.5..2.5).contains(&ratio), "{ratio}");
    }
}
