//! Node-level STREAM prediction: composes per-socket DDR models with the
//! thread-pinning policy, producing the Fig 3 numbers.

use super::ddr::DdrModel;
use crate::arch::soc::SocDescriptor;

/// Relative bandwidth of the four STREAM kernels vs copy (empirical:
/// add/triad slightly beat copy/scale on most DDR4 systems because two
/// read streams amortize write-allocate traffic).
pub const KERNEL_FACTORS: [(&str, f64); 4] =
    [("copy", 1.00), ("scale", 0.985), ("add", 1.04), ("triad", 1.045)];

/// Predicted aggregate bandwidth (bytes/s) for `threads` spread over the
/// node. `symmetric_pinning` splits threads evenly across sockets (the
/// paper's best configuration); otherwise all threads land on socket 0
/// until full, then spill.
pub fn predict_node_bandwidth(
    desc: &SocDescriptor,
    threads: usize,
    symmetric_pinning: bool,
) -> f64 {
    if threads == 0 {
        return 0.0;
    }
    let n_sock = desc.sockets.len();
    let mut per_socket_threads = vec![0usize; n_sock];
    if symmetric_pinning {
        for s in 0..n_sock {
            per_socket_threads[s] = threads / n_sock + usize::from(s < threads % n_sock);
        }
    } else {
        let mut left = threads;
        for (s, sock) in desc.sockets.iter().enumerate() {
            let take = left.min(sock.cores);
            per_socket_threads[s] = take;
            left -= take;
        }
        // oversubscription: leftover threads pile on socket 0
        per_socket_threads[0] += left;
    }
    desc.sockets
        .iter()
        .zip(&per_socket_threads)
        .map(|(sock, &t)| DdrModel::new(sock.mem, sock.cores).bandwidth(t))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn fig3_mcv2_single_socket() {
        let d = presets::sg2042();
        let bw = predict_node_bandwidth(&d, 64, true);
        assert!((bw - 41.9e9).abs() < 0.5e9, "{bw}");
    }

    #[test]
    fn fig3_mcv2_dual_socket_symmetric() {
        // paper: 82.9 GB/s with 64 threads pinned symmetrically
        let d = presets::sg2042_dual();
        let bw = predict_node_bandwidth(&d, 64, true);
        assert!((82.0e9..86.0e9).contains(&bw), "{bw}");
    }

    #[test]
    fn fig3_mcv1() {
        let d = presets::u740();
        let bw = predict_node_bandwidth(&d, 4, true);
        assert!((bw - 1.1e9).abs() < 0.1e9, "{bw}");
    }

    #[test]
    fn dual_socket_more_threads_reduces_bandwidth() {
        // "increasing the number of OpenMP threads reduces the attained
        // bandwidth" — 128 threads oversubscribe nothing (128 cores) but
        // on the single socket 128 threads certainly degrade:
        let d1 = presets::sg2042();
        assert!(
            predict_node_bandwidth(&d1, 128, true) < predict_node_bandwidth(&d1, 64, true)
        );
    }

    #[test]
    fn asymmetric_pinning_hurts_dual_socket() {
        let d = presets::sg2042_dual();
        let sym = predict_node_bandwidth(&d, 64, true);
        let asym = predict_node_bandwidth(&d, 64, false);
        assert!(asym < sym, "sym={sym} asym={asym}");
    }

    #[test]
    fn headline_69x_stream_uplift() {
        // abstract: "69x on Stream Memory Bandwidth" (node vs node)
        let v1 = predict_node_bandwidth(&presets::u740(), 4, true);
        let v2 = predict_node_bandwidth(&presets::sg2042_dual(), 64, true);
        let ratio = v2 / v1;
        assert!((60.0..85.0).contains(&ratio), "uplift {ratio:.0}x");
    }

    #[test]
    fn kernel_factors_cover_all_four() {
        let names: Vec<&str> = KERNEL_FACTORS.iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["copy", "scale", "add", "triad"]);
    }
}
