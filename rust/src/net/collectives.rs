//! MPI collective cost models over a flat switched fabric (Monte Cimone's
//! topology: every node one hop from the switch).
//!
//! Standard LogP-flavoured formulas: ring broadcast/allreduce for large
//! payloads, binomial trees for small ones — what OpenMPI selects on
//! ethernet at these scales.

use super::link::Link;

/// Collective-time calculator for `p` ranks over `link`.
#[derive(Debug, Clone, Copy)]
pub struct Collectives {
    pub link: Link,
    pub p: usize,
}

impl Collectives {
    pub fn new(link: Link, p: usize) -> Self {
        assert!(p >= 1);
        Collectives { link, p }
    }

    fn log2p(&self) -> f64 {
        (self.p as f64).log2().ceil().max(1.0)
    }

    /// Broadcast `bytes` from one rank to all others.
    /// Binomial for small messages, pipelined ring for large.
    pub fn bcast(&self, bytes: f64) -> f64 {
        if self.p == 1 {
            return 0.0;
        }
        let binomial = self.log2p() * self.link.msg_time(bytes);
        let ring = (self.p - 1) as f64 * self.link.latency_s
            + bytes / self.link.payload_bytes_per_sec();
        binomial.min(ring)
    }

    /// Allreduce of `bytes` (ring algorithm: 2(p-1)/p of the data crosses
    /// each link, 2(p-1) message latencies).
    pub fn allreduce(&self, bytes: f64) -> f64 {
        if self.p == 1 {
            return 0.0;
        }
        let pf = self.p as f64;
        2.0 * (pf - 1.0) * self.link.latency_s
            + 2.0 * (pf - 1.0) / pf * bytes / self.link.payload_bytes_per_sec()
    }

    /// Pairwise exchange: each rank sends/receives `bytes` once. This is
    /// the *flat-link baseline* for HPL's U row-slab swap — the HPL
    /// projection now routes that swap through
    /// [`crate::net::Switch::ring_shift_time`], which reduces to exactly
    /// this on a non-blocking fabric (property-tested in
    /// `integration_net.rs`) but additionally models the backplane bound
    /// on oversubscribed ones.
    pub fn exchange(&self, bytes: f64) -> f64 {
        if self.p == 1 {
            return 0.0;
        }
        self.link.msg_time(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_costs_nothing() {
        let c = Collectives::new(Link::gbe(), 1);
        assert_eq!(c.bcast(1e9), 0.0);
        assert_eq!(c.allreduce(1e9), 0.0);
    }

    #[test]
    fn bcast_monotone_in_ranks() {
        let small = Collectives::new(Link::gbe(), 2).bcast(1e6);
        let large = Collectives::new(Link::gbe(), 8).bcast(1e6);
        assert!(large >= small);
    }

    #[test]
    fn large_bcast_approaches_bandwidth_bound() {
        // pipelined ring: T -> bytes/bw for big payloads
        let c = Collectives::new(Link::gbe(), 8);
        let bytes = 1e9;
        let t = c.bcast(bytes);
        let bw_bound = bytes / c.link.payload_bytes_per_sec();
        assert!(t < 1.2 * bw_bound, "t={t:.2} bound={bw_bound:.2}");
    }

    #[test]
    fn allreduce_costs_about_twice_the_data() {
        let c = Collectives::new(Link::gbe(), 8);
        let bytes = 1e8;
        let t = c.allreduce(bytes);
        let one_pass = bytes / c.link.payload_bytes_per_sec();
        assert!(t > 1.5 * one_pass && t < 2.5 * one_pass, "{t}");
    }

    #[test]
    fn small_allreduce_latency_dominated() {
        let c = Collectives::new(Link::gbe(), 4);
        let t = c.allreduce(8.0);
        assert!(t >= 6.0 * c.link.latency_s * 0.99);
    }
}
