//! Data-driven interconnect fabrics — the network analogue of the open
//! platform API ([`crate::arch::platform`]).
//!
//! A [`Fabric`] bundles everything the models need to know about one
//! cluster interconnect: identity (id, label, aliases), the per-port
//! [`Link`], and the switch topology parameters (port count,
//! backplane oversubscription). Fabrics are registered by string id in a
//! [`FabricRegistry`] and resolved wherever the stack used to hardcode
//! `Link::gbe()` — the HPL projection, inventories, campaign specs and
//! the scenario matrix. The built-ins:
//!
//! | id             | wire                                | source            |
//! |----------------|-------------------------------------|-------------------|
//! | `gbe-flat`     | 1 GbE, unmanaged 16-port ToR switch | the paper (Fig 5) |
//! | `ten-gbe-flat` | 10 GbE, non-blocking 32-port switch | MCv3, arXiv 2605.22831 |
//! | `gbe-oversub`  | 1 GbE, 16 ports, 4:1 oversubscribed | worst-case ablation |
//!
//! Fabrics validate their own invariants on registration as typed
//! [`CimoneError::InvalidFabric`] values, and the campaign layer checks
//! `ports >= fleet node count` at load time
//! ([`CimoneError::FabricTooSmall`]) so [`Switch::flows_time`] never
//! sees an out-of-range port.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::collectives::Collectives;
use super::link::Link;
use super::topo::Switch;
use crate::error::CimoneError;
use crate::util::config::Section;
use crate::util::hash::ContentHasher;

/// One registrable cluster interconnect: identity + link + topology.
#[derive(Debug, Clone, PartialEq)]
pub struct Fabric {
    /// Registry key and spec-file spelling (e.g. `gbe-flat`).
    pub id: String,
    /// Human label used in reports (e.g. `1 GbE flat (unmanaged ToR)`).
    pub label: String,
    /// Alternate spec-file spellings (`gbe`, `10gbe`, ...).
    pub aliases: Vec<String>,
    /// The per-port link (bandwidth, latency, protocol efficiency).
    pub link: Link,
    /// Switch port count — the hard ceiling on fleet size.
    pub ports: usize,
    /// Backplane speedup vs the sum of ports (1.0 = non-blocking,
    /// < 1.0 = oversubscribed).
    pub backplane_factor: f64,
}

impl Fabric {
    /// The paper's fabric: Monte Cimone's unmanaged 1 GbE ToR switch.
    pub fn gbe_flat() -> Fabric {
        Fabric {
            id: "gbe-flat".into(),
            label: "1 GbE flat (unmanaged ToR)".into(),
            aliases: vec!["gbe".into(), "1gbe".into()],
            link: Link::gbe(),
            ports: 16,
            backplane_factor: 1.0,
        }
    }

    /// The MCv3 direction (arXiv 2605.22831): 10 GbE, non-blocking.
    pub fn ten_gbe_flat() -> Fabric {
        Fabric {
            id: "ten-gbe-flat".into(),
            label: "10 GbE flat (non-blocking)".into(),
            aliases: vec!["10gbe".into(), "ten-gbe".into()],
            link: Link::ten_gbe(),
            ports: 32,
            backplane_factor: 1.0,
        }
    }

    /// Worst-case ablation: the paper's 1 GbE wire behind a 4:1
    /// oversubscribed backplane — what a cheap stacked switch would do.
    pub fn gbe_oversub() -> Fabric {
        Fabric {
            id: "gbe-oversub".into(),
            label: "1 GbE 4:1 oversubscribed".into(),
            aliases: vec!["gbe-4to1".into()],
            link: Link::gbe(),
            ports: 16,
            backplane_factor: 0.25,
        }
    }

    /// Does `name` refer to this fabric (id or alias)?
    pub fn matches(&self, name: &str) -> bool {
        self.id == name || self.aliases.iter().any(|a| a == name)
    }

    /// Canonical content feed for the estimation cache: identity plus
    /// every parameter the network models read.
    pub fn feed_content(&self, h: &mut ContentHasher) {
        h.write_str(&self.id);
        h.write_f64(self.link.raw_bps)
            .write_f64(self.link.latency_s)
            .write_f64(self.link.efficiency);
        h.write_usize(self.ports).write_f64(self.backplane_factor);
    }

    /// The 128-bit content digest of [`Fabric::feed_content`].
    pub fn content_hash(&self) -> u128 {
        let mut h = ContentHasher::new();
        self.feed_content(&mut h);
        h.finish()
    }

    /// The switch topology model of this fabric.
    pub fn switch(&self) -> Switch {
        Switch { link: self.link, ports: self.ports, backplane_factor: self.backplane_factor }
    }

    /// A switch of this fabric's class with at least `ranks` ports: the
    /// real port count where the cluster fits, otherwise an idealized
    /// larger switch of the same wire and oversubscription ratio. The
    /// HPL projection uses this so what-if scaling sweeps stay total;
    /// *physical* port limits are enforced separately, as typed
    /// [`CimoneError::FabricTooSmall`], by [`Fabric::validate_cluster`]
    /// on every campaign path.
    pub fn switch_for(&self, ranks: usize) -> Switch {
        Switch {
            link: self.link,
            ports: self.ports.max(ranks),
            backplane_factor: self.backplane_factor,
        }
    }

    /// Collective cost calculator for `p` ranks over this fabric's link.
    pub fn collectives(&self, p: usize) -> Collectives {
        Collectives::new(self.link, p)
    }

    fn err(&self, reason: impl Into<String>) -> CimoneError {
        CimoneError::InvalidFabric { id: self.id.clone(), reason: reason.into() }
    }

    /// Check the fabric's own invariants; every registration path runs
    /// this, so malformed fabrics never reach the models.
    pub fn validate(&self) -> Result<(), CimoneError> {
        if self.id.is_empty() || self.id.contains(char::is_whitespace) {
            return Err(self.err("id must be non-empty and free of whitespace"));
        }
        if !(self.link.raw_bps.is_finite() && self.link.raw_bps > 0.0) {
            return Err(self.err("link bandwidth must be finite and > 0"));
        }
        if !(self.link.latency_s.is_finite() && self.link.latency_s >= 0.0) {
            return Err(self.err("link latency must be finite and >= 0"));
        }
        if !(self.link.efficiency > 0.0 && self.link.efficiency <= 1.0) {
            return Err(self.err("link efficiency must be in (0, 1]"));
        }
        if self.ports < 2 {
            return Err(self.err("a switch needs at least 2 ports"));
        }
        if !(self.backplane_factor > 0.0 && self.backplane_factor <= 1.0) {
            return Err(self.err("backplane_factor must be in (0, 1]"));
        }
        Ok(())
    }

    /// Can a `nodes`-wide cluster hang off this fabric? The campaign
    /// layer runs this at load time so [`Switch::flows_time`] never
    /// indexes past its port arrays mid-sweep.
    pub fn validate_cluster(&self, nodes: usize) -> Result<(), CimoneError> {
        if nodes > self.ports {
            return Err(CimoneError::FabricTooSmall {
                fabric: self.id.clone(),
                ports: self.ports,
                nodes,
            });
        }
        Ok(())
    }
}

/// Fabrics keyed by id, resolvable by id or alias.
#[derive(Debug, Clone, Default)]
pub struct FabricRegistry {
    by_id: BTreeMap<String, Arc<Fabric>>,
}

impl FabricRegistry {
    /// An empty registry.
    pub fn new() -> FabricRegistry {
        FabricRegistry::default()
    }

    /// The built-in fabrics: the paper's 1 GbE, the MCv3 10 GbE, and the
    /// oversubscribed ablation variant.
    pub fn builtin() -> FabricRegistry {
        let mut reg = FabricRegistry::new();
        for f in [Fabric::gbe_flat(), Fabric::ten_gbe_flat(), Fabric::gbe_oversub()] {
            reg.register(f).expect("built-in fabrics are valid and unique");
        }
        reg
    }

    /// Validate and add a fabric. Ids and aliases share one namespace;
    /// any clash with an already-registered name is rejected.
    pub fn register(&mut self, fabric: Fabric) -> Result<Arc<Fabric>, CimoneError> {
        fabric.validate()?;
        for name in std::iter::once(&fabric.id).chain(fabric.aliases.iter()) {
            if self.resolve(name).is_some() {
                return Err(CimoneError::DuplicateFabric(name.clone()));
            }
        }
        let arc = Arc::new(fabric);
        self.by_id.insert(arc.id.clone(), Arc::clone(&arc));
        Ok(arc)
    }

    fn resolve(&self, name: &str) -> Option<&Arc<Fabric>> {
        self.by_id.get(name).or_else(|| self.by_id.values().find(|f| f.matches(name)))
    }

    /// Look a fabric up by id or alias.
    pub fn get(&self, name: &str) -> Result<Arc<Fabric>, CimoneError> {
        self.resolve(name).cloned().ok_or_else(|| CimoneError::UnknownFabric {
            id: name.to_string(),
            known: self.ids().join(", "),
        })
    }

    pub fn contains(&self, name: &str) -> bool {
        self.resolve(name).is_some()
    }

    /// Registered ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        self.by_id.keys().cloned().collect()
    }

    /// All registered fabrics, in id order.
    pub fn fabrics(&self) -> impl Iterator<Item = &Arc<Fabric>> {
        self.by_id.values()
    }

    /// Register a fabric described by a `[[fabric]]` campaign-spec
    /// section: a required `base` fabric (id or alias) plus overrides.
    ///
    /// ```text
    /// [[fabric]]
    /// id = "gbe-8to1"
    /// base = "gbe-flat"
    /// backplane_factor = 0.125
    /// # other overrides: label, raw_gbps, latency_us, efficiency, ports
    /// ```
    pub fn register_section(&mut self, sec: &Section) -> Result<Arc<Fabric>, CimoneError> {
        const KNOWN_KEYS: &[&str] = &[
            "id",
            "base",
            "label",
            "raw_gbps",
            "latency_us",
            "efficiency",
            "ports",
            "backplane_factor",
        ];
        let id = sec
            .get("id")
            .and_then(|v| v.as_str())
            .ok_or_else(|| CimoneError::Spec("[[fabric]]: missing string key `id`".into()))?
            .to_string();
        let spec_err =
            |msg: String| -> CimoneError { CimoneError::Spec(format!("fabric `{id}`: {msg}")) };
        // a misspelled override must be a load-time error, not a fabric
        // silently identical to its base
        if let Some(unknown) = sec.keys().find(|k| !KNOWN_KEYS.contains(&k.as_str())) {
            return Err(spec_err(format!(
                "unknown key `{unknown}` (known: {})",
                KNOWN_KEYS.join(", ")
            )));
        }
        let base = sec
            .get("base")
            .and_then(|v| v.as_str())
            .ok_or_else(|| spec_err("missing string key `base`".into()))?;
        let mut f: Fabric = (*self.get(base)?).clone();
        let base_label = f.label.clone();
        f.id = id.clone();
        f.aliases = Vec::new();
        f.label = format!("{id} (custom, from {base_label})");

        if let Some(v) = sec.get("label") {
            f.label = v
                .as_str()
                .ok_or_else(|| spec_err("`label` must be a string".into()))?
                .to_string();
        }
        let get_f64 = |key: &str| -> Result<Option<f64>, CimoneError> {
            match sec.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_float()
                    .filter(|x| x.is_finite())
                    .map(Some)
                    .ok_or_else(|| spec_err(format!("`{key}` must be a finite number"))),
            }
        };
        if let Some(g) = get_f64("raw_gbps")? {
            f.link.raw_bps = g * 1e9;
        }
        if let Some(us) = get_f64("latency_us")? {
            f.link.latency_s = us * 1e-6;
        }
        if let Some(e) = get_f64("efficiency")? {
            f.link.efficiency = e;
        }
        if let Some(b) = get_f64("backplane_factor")? {
            f.backplane_factor = b;
        }
        if let Some(v) = sec.get("ports") {
            f.ports = v
                .as_int()
                .filter(|i| *i > 0)
                .ok_or_else(|| spec_err("`ports` must be a positive int".into()))?
                as usize;
        }
        self.register(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_fabrics_register_and_resolve_aliases() {
        let reg = FabricRegistry::builtin();
        assert_eq!(reg.ids(), ["gbe-flat", "gbe-oversub", "ten-gbe-flat"]);
        assert_eq!(reg.get("gbe").unwrap().id, "gbe-flat");
        assert_eq!(reg.get("10gbe").unwrap().id, "ten-gbe-flat");
        assert_eq!(reg.get("gbe-4to1").unwrap().id, "gbe-oversub");
        assert!(reg.contains("1gbe"));
    }

    #[test]
    fn unknown_fabric_is_typed_and_lists_known_ids() {
        let reg = FabricRegistry::builtin();
        match reg.get("infiniband") {
            Err(CimoneError::UnknownFabric { id, known }) => {
                assert_eq!(id, "infiniband");
                assert!(known.contains("gbe-flat"), "{known}");
            }
            other => panic!("expected UnknownFabric, got {other:?}"),
        }
    }

    #[test]
    fn duplicate_id_and_alias_rejected() {
        let mut reg = FabricRegistry::builtin();
        assert!(matches!(
            reg.register(Fabric::gbe_flat()),
            Err(CimoneError::DuplicateFabric(_))
        ));
        let mut f = Fabric::gbe_flat();
        f.id = "gbe-b".into();
        f.aliases = vec!["10gbe".into()]; // clashes with ten-gbe-flat's alias
        assert!(matches!(reg.register(f), Err(CimoneError::DuplicateFabric(_))));
    }

    #[test]
    fn validation_catches_broken_invariants() {
        let breakers: [fn(&mut Fabric); 5] = [
            |f| f.link.raw_bps = 0.0,
            |f| f.link.efficiency = 1.5,
            |f| f.ports = 1,
            |f| f.backplane_factor = 0.0,
            |f| f.id = "has space".into(),
        ];
        for broken in breakers {
            let mut f = Fabric::gbe_flat();
            broken(&mut f);
            assert!(matches!(f.validate(), Err(CimoneError::InvalidFabric { .. })), "{f:?}");
        }
    }

    #[test]
    fn cluster_fit_is_a_typed_error() {
        let f = Fabric::gbe_flat();
        assert!(f.validate_cluster(16).is_ok());
        match f.validate_cluster(17) {
            Err(CimoneError::FabricTooSmall { fabric, ports, nodes }) => {
                assert_eq!((fabric.as_str(), ports, nodes), ("gbe-flat", 16, 17));
            }
            other => panic!("expected FabricTooSmall, got {other:?}"),
        }
    }

    #[test]
    fn switch_and_collectives_carry_the_fabric_link() {
        let f = Fabric::ten_gbe_flat();
        let sw = f.switch();
        assert_eq!(sw.ports, 32);
        assert_eq!(sw.link, f.link);
        assert_eq!(f.collectives(4).p, 4);
    }

    #[test]
    fn custom_fabric_from_section_inherits_and_overrides() {
        use crate::util::config::Config;
        let cfg = Config::parse(
            "[[fabric]]\nid = \"gbe-8to1\"\nbase = \"gbe-flat\"\nbackplane_factor = 0.125\nports = 48\n",
        )
        .unwrap();
        let mut reg = FabricRegistry::builtin();
        let f = reg.register_section(&cfg.table_arrays["fabric"][0]).unwrap();
        assert_eq!(f.id, "gbe-8to1");
        assert_eq!(f.ports, 48);
        assert!((f.backplane_factor - 0.125).abs() < 1e-12);
        // inherited wire
        assert_eq!(f.link, Link::gbe());
        assert_eq!(reg.get("gbe-8to1").unwrap().id, "gbe-8to1");
    }

    #[test]
    fn custom_fabric_unknown_key_is_rejected() {
        use crate::util::config::Config;
        let cfg = Config::parse(
            "[[fabric]]\nid = \"typo\"\nbase = \"gbe-flat\"\nprots = 48\n",
        )
        .unwrap();
        let mut reg = FabricRegistry::builtin();
        match reg.register_section(&cfg.table_arrays["fabric"][0]) {
            Err(CimoneError::Spec(m)) => assert!(m.contains("unknown key `prots`"), "{m}"),
            other => panic!("expected Spec error, got {other:?}"),
        }
    }

    #[test]
    fn custom_fabric_bad_override_is_rejected() {
        use crate::util::config::Config;
        let cfg = Config::parse(
            "[[fabric]]\nid = \"dud\"\nbase = \"gbe-flat\"\nefficiency = 2.0\n",
        )
        .unwrap();
        let mut reg = FabricRegistry::builtin();
        assert!(matches!(
            reg.register_section(&cfg.table_arrays["fabric"][0]),
            Err(CimoneError::InvalidFabric { .. })
        ));
    }
}
