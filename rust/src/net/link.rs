//! Point-to-point link model: bandwidth + latency + protocol efficiency.

/// A network link (full duplex).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    /// Raw signalling rate, bits per second.
    pub raw_bps: f64,
    /// One-way small-message latency in seconds (TCP/IP over GbE: ~60 us
    /// with interrupt coalescing — RISC-V NIC drivers of the era were not
    /// tuned; the paper's SLURM/MPI stack rode TCP).
    pub latency_s: f64,
    /// Fraction of raw bandwidth attainable by MPI payloads (TCP + MPI
    /// envelope overhead).
    pub efficiency: f64,
}

impl Link {
    /// Monte Cimone's 1 Gb/s Ethernet.
    pub fn gbe() -> Link {
        Link { raw_bps: 1e9, latency_s: 65e-6, efficiency: 0.94 }
    }

    /// A hypothetical upgrade used by the ablation benches.
    pub fn ten_gbe() -> Link {
        Link { raw_bps: 10e9, latency_s: 20e-6, efficiency: 0.95 }
    }

    /// Attainable payload bytes/s.
    pub fn payload_bytes_per_sec(&self) -> f64 {
        self.raw_bps * self.efficiency / 8.0
    }

    /// Time to move one message of `bytes`.
    pub fn msg_time(&self, bytes: f64) -> f64 {
        self.latency_s + bytes / self.payload_bytes_per_sec()
    }

    /// Time for `count` messages totalling `bytes` (latency per message).
    pub fn burst_time(&self, bytes: f64, count: f64) -> f64 {
        count * self.latency_s + bytes / self.payload_bytes_per_sec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gbe_payload_rate() {
        let l = Link::gbe();
        let r = l.payload_bytes_per_sec();
        assert!((r - 117.5e6).abs() < 1e6, "{r}");
    }

    #[test]
    fn small_message_latency_bound() {
        let l = Link::gbe();
        let t = l.msg_time(64.0);
        assert!(t > 0.9 * l.latency_s && t < 2.0 * l.latency_s);
    }

    #[test]
    fn large_message_bandwidth_bound() {
        let l = Link::gbe();
        let t = l.msg_time(1e9);
        assert!((t - 1e9 / l.payload_bytes_per_sec()).abs() / t < 0.01);
    }

    #[test]
    fn burst_charges_per_message_latency() {
        let l = Link::gbe();
        let one = l.burst_time(1e6, 1.0);
        let many = l.burst_time(1e6, 1000.0);
        assert!(many > one + 0.9 * 999.0 * l.latency_s);
    }

    #[test]
    fn ten_gbe_is_faster() {
        assert!(Link::ten_gbe().msg_time(1e8) < Link::gbe().msg_time(1e8));
    }
}
