//! Cluster-interconnect models: the 1 Gb/s Ethernet fabric Monte Cimone
//! uses for MPI, plus collective-operation cost models.
//!
//! Fig 5's punchline depends on this substrate: the same 1 GbE that let
//! MCv1 scale HPL almost linearly is "no longer sufficient" for MCv2's
//! 100x-faster nodes — a pure compute/communication-ratio effect.
//!
//! The layer is data-driven: a [`Fabric`] (identity + [`Link`] +
//! [`Switch`] topology parameters) is registered by id/alias in a
//! [`FabricRegistry`] — `gbe-flat` (the paper), `ten-gbe-flat` (MCv3,
//! arXiv 2605.22831) and the oversubscribed `gbe-oversub` ablation — and
//! resolved wherever the stack used to hardcode `Link::gbe()`.

pub mod collectives;
pub mod fabric;
pub mod link;
pub mod topo;

pub use collectives::Collectives;
pub use fabric::{Fabric, FabricRegistry};
pub use link::Link;
pub use topo::Switch;
