//! Cluster-interconnect models: the 1 Gb/s Ethernet fabric Monte Cimone
//! uses for MPI, plus collective-operation cost models.
//!
//! Fig 5's punchline depends on this substrate: the same 1 GbE that let
//! MCv1 scale HPL almost linearly is "no longer sufficient" for MCv2's
//! 100x-faster nodes — a pure compute/communication-ratio effect.

pub mod collectives;
pub mod link;
pub mod topo;

pub use collectives::Collectives;
pub use link::Link;
pub use topo::Switch;
