//! Switch topology: Monte Cimone's single 1 GbE top-of-rack switch.
//!
//! Every node hangs one hop off the switch; what the flat [`super::link`]
//! model misses is *fan-in contention*: when several ranks send to the
//! same destination (HPL's panel broadcast root, or an all-to-one
//! gather), the destination port serializes the flows. This module adds
//! that — the difference is invisible at P=2 (Fig 5) but matters for the
//! node-count-scaling extension sweeps.

use super::link::Link;

/// A non-blocking switch with per-port capacity equal to the link rate.
#[derive(Debug, Clone, Copy)]
pub struct Switch {
    pub link: Link,
    pub ports: usize,
    /// Internal speedup of the backplane vs sum of ports (1.0 =
    /// non-blocking, <1.0 = oversubscribed fabric).
    pub backplane_factor: f64,
}

impl Switch {
    /// Monte Cimone's unmanaged 1 GbE switch: non-blocking at this scale.
    pub fn monte_cimone() -> Switch {
        Switch { link: Link::gbe(), ports: 16, backplane_factor: 1.0 }
    }

    /// Time to complete a set of point-to-point flows, each `(src, dst,
    /// bytes)`, all starting simultaneously. Ports serialize: a port's
    /// finish time is the sum of its flows' transmission times (fair
    /// sharing makes the *last* finisher identical to serialization for
    /// equal-start flows), plus one latency.
    pub fn flows_time(&self, flows: &[(usize, usize, f64)]) -> f64 {
        if flows.is_empty() {
            return 0.0;
        }
        let rate = self.link.payload_bytes_per_sec();
        let mut tx_load = vec![0.0f64; self.ports];
        let mut rx_load = vec![0.0f64; self.ports];
        for &(src, dst, bytes) in flows {
            assert!(src < self.ports && dst < self.ports, "port out of range");
            if src == dst {
                continue; // loopback is free at this fidelity
            }
            tx_load[src] += bytes;
            rx_load[dst] += bytes;
        }
        // backplane limit: aggregate bytes / (ports x rate x factor)
        let aggregate: f64 = tx_load.iter().sum();
        let backplane =
            aggregate / (self.ports as f64 * rate * self.backplane_factor);
        let port_bound = tx_load
            .iter()
            .chain(rx_load.iter())
            .fold(0.0f64, |m, &b| m.max(b / rate));
        self.link.latency_s + port_bound.max(backplane)
    }

    /// All-to-one gather of `bytes` from `p-1` senders to rank 0 — the
    /// fan-in worst case the flat model underestimates by (p-1)x.
    pub fn gather_time(&self, p: usize, bytes: f64) -> f64 {
        let flows: Vec<(usize, usize, f64)> =
            (1..p).map(|src| (src, 0usize, bytes)).collect();
        self.flows_time(&flows)
    }

    /// Pairwise ring shift (rank i -> i+1): no fan-in, full parallelism.
    pub fn ring_shift_time(&self, p: usize, bytes: f64) -> f64 {
        let flows: Vec<(usize, usize, f64)> =
            (0..p).map(|i| (i, (i + 1) % p, bytes)).collect();
        self.flows_time(&flows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sw() -> Switch {
        Switch::monte_cimone()
    }

    #[test]
    fn empty_flows_cost_nothing() {
        assert_eq!(sw().flows_time(&[]), 0.0);
    }

    #[test]
    fn single_flow_matches_link_model() {
        let s = sw();
        let t = s.flows_time(&[(0, 1, 1e8)]);
        let expect = s.link.msg_time(1e8);
        assert!((t - expect).abs() / expect < 0.01, "{t} vs {expect}");
    }

    #[test]
    fn fan_in_serializes_on_the_destination_port() {
        let s = sw();
        let one = s.flows_time(&[(1, 0, 1e8)]);
        let four = s.gather_time(5, 1e8);
        // 4 senders into one port: ~4x one transfer
        assert!(four > 3.5 * one && four < 4.5 * one, "{four} vs {one}");
    }

    #[test]
    fn ring_shift_is_fully_parallel() {
        let s = sw();
        let solo = s.flows_time(&[(0, 1, 1e8)]);
        let ring = s.ring_shift_time(8, 1e8);
        assert!(ring < 1.1 * solo, "{ring} vs {solo}");
    }

    #[test]
    fn disjoint_pairs_run_concurrently() {
        let s = sw();
        let t = s.flows_time(&[(0, 1, 1e8), (2, 3, 1e8), (4, 5, 1e8)]);
        let solo = s.flows_time(&[(0, 1, 1e8)]);
        assert!((t - solo).abs() / solo < 0.05);
    }

    #[test]
    fn oversubscribed_backplane_caps_aggregate() {
        let mut s = sw();
        s.backplane_factor = 0.1; // 10:1 oversubscription
        let parallel = s.flows_time(&[(0, 1, 1e8), (2, 3, 1e8), (4, 5, 1e8), (6, 7, 1e8)]);
        let nonblocking = sw().flows_time(&[(0, 1, 1e8), (2, 3, 1e8), (4, 5, 1e8), (6, 7, 1e8)]);
        assert!(parallel > 2.0 * nonblocking, "{parallel} vs {nonblocking}");
    }

    #[test]
    #[should_panic(expected = "port out of range")]
    fn port_bounds_checked() {
        sw().flows_time(&[(0, 99, 1.0)]);
    }
}
