//! The `cimone bench` harness: a recorded perf trajectory for the
//! estimation stack.
//!
//! Times the three hot layers end to end —
//!
//! - the functional vector machine ([`crate::isa::exec::VecMachine`]):
//!   simulated instructions retired per second on the interned LMUL=4
//!   micro-kernel program;
//! - kernel generation + cycle analysis: programs decoded per second
//!   and [`CycleModel::analyze_at`] passes per second, cold, vs the
//!   memoized [`analysis::analyze`] path warm;
//! - whole scenario sweeps: the built-in generation matrix estimated
//!   per second with cold caches (reset every iteration) vs warm —
//!   the headline the content-addressed cache exists for.
//!
//! Every run also emits a *determinism fingerprint*: the content hash
//! of the cold sweep's `ComparisonReport` JSON. The warm rerun must
//! fingerprint identically (cache hits are bit-identical to cold
//! computation by construction) — a mismatch is a typed error, and CI
//! compares the fingerprint across two fresh processes. Timings vary
//! run to run; the fingerprint never may.

use crate::arch::presets;
use crate::coordinator::scenario::{dry_run_matrix, ScenarioMatrix};
use crate::coordinator::workload;
use crate::error::CimoneError;
use crate::isa::exec::VecMachine;
use crate::isa::timing::CycleModel;
use crate::ukernel::{analysis, KernelRegistry, PanelLayout};
use crate::util::bench::Bench;
use crate::util::hash;
use crate::util::json::Json;

/// Everything one `cimone bench` run produced.
#[derive(Debug, Clone)]
pub struct SuiteReport {
    /// Human-readable report, one measurement per line.
    pub lines: Vec<String>,
    /// Machine-readable export (`cimone bench --json` / `BENCH_6.json`).
    pub json: Json,
    /// Content hash of the cold sweep's report JSON — must be identical
    /// across runs, machines and cache states.
    pub fingerprint: String,
}

impl SuiteReport {
    pub fn render(&self) -> String {
        self.lines.join("\n")
    }
}

/// Drop every estimation cache in the stack — the true cold start the
/// cold-side measurements (and the warm-vs-cold golden test) need.
pub fn reset_caches() {
    analysis::reset_caches();
    workload::reset_estimate_cache();
}

/// Run the suite. `quick` trades sample count for latency (the CI
/// smoke); the defaults are the recorded-trajectory configuration.
pub fn run(quick: bool) -> Result<SuiteReport, CimoneError> {
    let b = if quick { Bench::quick() } else { Bench::default() };
    let mut lines = vec!["=== cimone bench: estimation-stack hot paths ===".to_string()];

    let desc = KernelRegistry::builtin().get("blis-lmul4")?;
    let core = presets::c920();

    // --- functional vector machine: simulated instructions / s ---
    let layout = PanelLayout::new(desc.mr, desc.nr, 256);
    let prog = analysis::interned_program(&desc, layout);
    let mut vm = VecMachine::new(desc.vlen_bits, layout.mem_words())?;
    let m = b.run("vec machine: lmul4 ukernel kc=256", || {
        vm.run(&prog).expect("builtin program executes");
        std::hint::black_box(vm.flops);
    });
    let vec_machine_insts_per_s = m.throughput(prog.len() as f64);
    lines.push(format!(
        "{}   ({:.1} M simulated insts/s)",
        m.report(),
        vec_machine_insts_per_s / 1e6
    ));

    // --- kernel generation: programs decoded / s (the intern-miss cost) ---
    let m = b.run("program generation: blis-lmul4 kc=128", || {
        std::hint::black_box(desc.program(PanelLayout::new(desc.mr, desc.nr, 128)));
    });
    let program_gen_per_s = m.throughput(1.0);
    lines.push(format!("{}   ({:.0} programs/s)", m.report(), program_gen_per_s));

    // --- cycle analysis: cold analyze_at vs the memoized warm path ---
    let vlen = analysis::timing_vlen(&desc, &core);
    let m = b.run("analyze_at (cold cycle model)", || {
        std::hint::black_box(CycleModel::new(&core).analyze_at(&prog, vlen));
    });
    let analyze_cold_per_s = m.throughput(1.0);
    lines.push(format!("{}   ({:.0} analyses/s)", m.report(), analyze_cold_per_s));

    analysis::analyze(&desc, &core); // prime the coordinate
    let m = b.run("analyze (warm memoized)", || {
        std::hint::black_box(analysis::analyze(&desc, &core));
    });
    let analyze_warm_per_s = m.throughput(1.0);
    lines.push(format!("{}   ({:.0} analyses/s)", m.report(), analyze_warm_per_s));

    // --- whole sweeps: cold (caches reset each iteration) vs warm ---
    let matrix = ScenarioMatrix::generations();
    let n_scen = matrix.spec_count() as f64;
    let mut cold_json = String::new();
    let m = b.run("sweep generations (cold caches)", || {
        reset_caches();
        let r = dry_run_matrix(&matrix).expect("builtin matrix runs");
        cold_json = r.to_json().render();
        std::hint::black_box(&cold_json);
    });
    let scenarios_per_s_cold = m.throughput(n_scen);
    lines.push(format!("{}   ({:.1} scenarios/s cold)", m.report(), scenarios_per_s_cold));
    let fingerprint = hash::fingerprint(&cold_json);

    let m = b.run("sweep generations (warm cache)", || {
        let r = dry_run_matrix(&matrix).expect("builtin matrix runs");
        std::hint::black_box(r.scenarios.len());
    });
    let scenarios_per_s_warm = m.throughput(n_scen);
    lines.push(format!("{}   ({:.1} scenarios/s warm)", m.report(), scenarios_per_s_warm));

    // the warm rerun must be bit-identical to the cold one — that is
    // the cache's correctness contract, enforced on every bench run
    let warm_json = dry_run_matrix(&matrix)?.to_json().render();
    let warm_fp = hash::fingerprint(&warm_json);
    if warm_fp != fingerprint {
        return Err(CimoneError::Cli(format!(
            "determinism fingerprint mismatch: cold {fingerprint} vs warm {warm_fp} \
             (warm-cache sweep output is not bit-identical to cold)"
        )));
    }

    let warm_speedup = scenarios_per_s_warm / scenarios_per_s_cold;
    let (prog_stats, an_stats) = analysis::cache_stats();
    let est_stats = workload::estimate_cache_stats();
    lines.push(format!(
        "warm/cold sweep speedup: {warm_speedup:.1}x   (cache hit rates: programs {:.0}%, analyses {:.0}%, estimates {:.0}%)",
        prog_stats.hit_rate() * 100.0,
        an_stats.hit_rate() * 100.0,
        est_stats.hit_rate() * 100.0
    ));
    lines.push(format!("determinism fingerprint: {fingerprint}"));

    let json = Json::obj([
        ("bench", Json::Num(6.0)),
        ("determinism_fingerprint", Json::Str(fingerprint.clone())),
        ("vec_machine_insts_per_s", Json::Num(vec_machine_insts_per_s)),
        ("program_gen_per_s", Json::Num(program_gen_per_s)),
        ("analyze_cold_per_s", Json::Num(analyze_cold_per_s)),
        ("analyze_warm_per_s", Json::Num(analyze_warm_per_s)),
        ("scenarios_per_s_cold", Json::Num(scenarios_per_s_cold)),
        ("scenarios_per_s_warm", Json::Num(scenarios_per_s_warm)),
        ("warm_speedup", Json::Num(warm_speedup)),
    ]);
    Ok(SuiteReport { lines, json, fingerprint })
}
