//! The `cimone bench` harness: a recorded perf trajectory for the
//! estimation stack.
//!
//! Times the four hot layers end to end —
//!
//! - the functional vector machine ([`crate::isa::exec::VecMachine`]):
//!   simulated instructions retired per second on the interned LMUL=4
//!   micro-kernel program;
//! - kernel generation + cycle analysis: programs decoded per second
//!   and [`CycleModel::analyze_at`] passes per second, cold, vs the
//!   memoized [`analysis::analyze`] path warm;
//! - the cache-trace simulator: element-weighted accesses replayed per
//!   second through the interval engine vs the retained per-access
//!   reference loop on the default Fig 6 GEMM trace set (both engines
//!   produce bit-identical [`LevelStats`](crate::cache::LevelStats)),
//!   plus the memoized `(GemmTraceConfig, Socket)` lookup path warm;
//! - whole scenario sweeps: the built-in generation matrix estimated
//!   per second with cold caches (reset every iteration) vs warm, and
//!   one streamed pass over the full-codesign product (kernels x
//!   platforms x fabrics x fleets x caps x outages x workloads) through
//!   the sharded top-k aggregator — the 10^5-scenario headline.
//!
//! Every run also emits a *determinism fingerprint*: the content hash
//! of the cold sweep's `ComparisonReport` JSON. The warm rerun must
//! fingerprint identically (cache hits are bit-identical to cold
//! computation by construction) — a mismatch is a typed error, and CI
//! compares the fingerprint across two fresh processes AND against the
//! committed `BENCH_10.json`. Timings vary run to run; the fingerprint
//! never may.

use std::time::Instant;

use crate::arch::presets;
use crate::blas::blocking::Blocking;
use crate::cache::{self, GemmTraceConfig, TraceEngine};
use crate::coordinator::scenario::{
    dry_run_matrix, dry_run_matrix_with, ScenarioMatrix, SweepOptions,
};
use crate::coordinator::workload;
use crate::error::CimoneError;
use crate::isa::exec::VecMachine;
use crate::isa::timing::CycleModel;
use crate::ukernel::{analysis, KernelRegistry, PanelLayout};
use crate::util::bench::Bench;
use crate::util::hash;
use crate::util::json::Json;
use crate::util::memo::CacheStats;

/// Everything one `cimone bench` run produced.
#[derive(Debug, Clone)]
pub struct SuiteReport {
    /// Human-readable report, one measurement per line.
    pub lines: Vec<String>,
    /// Machine-readable export (`cimone bench --json` / `BENCH_10.json`).
    pub json: Json,
    /// Content hash of the cold sweep's report JSON — must be identical
    /// across runs, machines and cache states.
    pub fingerprint: String,
}

impl SuiteReport {
    pub fn render(&self) -> String {
        self.lines.join("\n")
    }
}

/// Drop every estimation cache in the stack — the true cold start the
/// cold-side measurements (and the warm-vs-cold golden test) need.
pub fn reset_caches() {
    analysis::reset_caches();
    workload::reset_estimate_cache();
    cache::reset_trace_cache();
}

/// One cache's counters as a JSON object for the bench export.
fn cache_json(s: CacheStats) -> Json {
    Json::obj([
        ("hits", Json::Num(s.hits as f64)),
        ("misses", Json::Num(s.misses as f64)),
        ("entries", Json::Num(s.entries as f64)),
        ("hit_rate", Json::Num(s.hit_rate())),
    ])
}

/// Run the suite. `quick` trades sample count (and the full-codesign
/// product for a truncated one) for latency — the CI smoke; the
/// defaults are the recorded-trajectory configuration.
pub fn run(quick: bool) -> Result<SuiteReport, CimoneError> {
    let b = if quick { Bench::quick() } else { Bench::default() };
    let mut lines = vec!["=== cimone bench: estimation-stack hot paths ===".to_string()];

    let desc = KernelRegistry::builtin().get("blis-lmul4")?;
    let core = presets::c920();

    // --- functional vector machine: simulated instructions / s ---
    let layout = PanelLayout::new(desc.mr, desc.nr, 256);
    let prog = analysis::interned_program(&desc, layout);
    let mut vm = VecMachine::new(desc.vlen_bits, layout.mem_words())?;
    let m = b.run("vec machine: lmul4 ukernel kc=256", || {
        vm.run(&prog).expect("builtin program executes");
        std::hint::black_box(vm.flops);
    });
    let vec_machine_insts_per_s = m.throughput(prog.len() as f64);
    lines.push(format!(
        "{}   ({:.1} M simulated insts/s)",
        m.report(),
        vec_machine_insts_per_s / 1e6
    ));

    // --- kernel generation: programs decoded / s (the intern-miss cost) ---
    let m = b.run("program generation: blis-lmul4 kc=128", || {
        std::hint::black_box(desc.program(PanelLayout::new(desc.mr, desc.nr, 128)));
    });
    let program_gen_per_s = m.throughput(1.0);
    lines.push(format!("{}   ({:.0} programs/s)", m.report(), program_gen_per_s));

    // --- cycle analysis: cold analyze_at vs the memoized warm path ---
    let vlen = analysis::timing_vlen(&desc, &core);
    let m = b.run("analyze_at (cold cycle model)", || {
        std::hint::black_box(CycleModel::new(&core).analyze_at(&prog, vlen));
    });
    let analyze_cold_per_s = m.throughput(1.0);
    lines.push(format!("{}   ({:.0} analyses/s)", m.report(), analyze_cold_per_s));

    analysis::analyze(&desc, &core); // prime the coordinate
    let m = b.run("analyze (warm memoized)", || {
        std::hint::black_box(analysis::analyze(&desc, &core));
    });
    let analyze_warm_per_s = m.throughput(1.0);
    lines.push(format!("{}   ({:.0} analyses/s)", m.report(), analyze_warm_per_s));

    // --- cache-trace simulator: interval engine vs per-access loop ---
    // the default trace set is the Fig 6 deep-K pair (BLIS derived
    // blocking vs OpenBLAS fixed) on the SG2042 socket, two cores
    let socket = presets::sg2042().sockets[0].clone();
    let deep = |blocking: Blocking| GemmTraceConfig { m: 256, n: 256, k: 768, blocking, cores: 2 };
    let trace_set = [deep(Blocking::blis_for(&socket, 8, 4)), deep(Blocking::openblas_fixed(8, 4))];
    let mut accesses = 0.0;
    for cfg in &trace_set {
        let st = cache::simulate_gemm_with(cfg, &socket, TraceEngine::Interval);
        accesses += st.l1_accesses as f64;
    }
    let m = b.run("trace sim: fig6 set (interval engine)", || {
        for cfg in &trace_set {
            std::hint::black_box(cache::simulate_gemm_with(cfg, &socket, TraceEngine::Interval));
        }
    });
    let trace_interval_per_s = m.throughput(accesses);
    lines.push(format!("{}   ({:.1} M accesses/s)", m.report(), trace_interval_per_s / 1e6));
    let m = b.run("trace sim: fig6 set (per-access ref)", || {
        for cfg in &trace_set {
            std::hint::black_box(cache::simulate_gemm_with(cfg, &socket, TraceEngine::PerAccess));
        }
    });
    let trace_per_access_per_s = m.throughput(accesses);
    lines.push(format!("{}   ({:.1} M accesses/s)", m.report(), trace_per_access_per_s / 1e6));
    let trace_speedup = trace_interval_per_s / trace_per_access_per_s;
    lines.push(format!("interval/per-access trace speedup: {trace_speedup:.1}x"));

    // --- whole sweeps: cold (caches reset each iteration) vs warm ---
    let matrix = ScenarioMatrix::generations();
    let n_scen = matrix.spec_count() as f64;
    let mut cold_json = String::new();
    let m = b.run("sweep generations (cold caches)", || {
        reset_caches();
        let r = dry_run_matrix(&matrix).expect("builtin matrix runs");
        cold_json = r.to_json().render();
        std::hint::black_box(&cold_json);
    });
    let scenarios_per_s_cold = m.throughput(n_scen);
    lines.push(format!("{}   ({:.1} scenarios/s cold)", m.report(), scenarios_per_s_cold));
    let fingerprint = hash::fingerprint(&cold_json);

    let m = b.run("sweep generations (warm cache)", || {
        let r = dry_run_matrix(&matrix).expect("builtin matrix runs");
        std::hint::black_box(r.scenarios.len());
    });
    let scenarios_per_s_warm = m.throughput(n_scen);
    lines.push(format!("{}   ({:.1} scenarios/s warm)", m.report(), scenarios_per_s_warm));

    // the warm rerun must be bit-identical to the cold one — that is
    // the cache's correctness contract, enforced on every bench run
    let warm_json = dry_run_matrix(&matrix)?.to_json().render();
    let warm_fp = hash::fingerprint(&warm_json);
    if warm_fp != fingerprint {
        return Err(CimoneError::Cli(format!(
            "determinism fingerprint mismatch: cold {fingerprint} vs warm {warm_fp} \
             (warm-cache sweep output is not bit-identical to cold)"
        )));
    }

    // --- the full co-design product, streamed through top-k ---
    // one timed pass (not Bench-sampled: the full product is 10^5
    // scenarios); --quick truncates the operating-point axes so CI
    // exercises the identical code path at 300 scenarios
    let mut fc = ScenarioMatrix::full_codesign();
    if quick {
        fc.axes.fleet_sizes.truncate(1);
        fc.axes.node_counts.truncate(1);
        fc.axes.power_caps.truncate(1);
        fc.axes.nodes_down.truncate(1);
    }
    let fc_total = fc.spec_count() as f64;
    let opts = SweepOptions { top_k: Some(8), ..Default::default() };
    let t = Instant::now();
    let fc_report = dry_run_matrix_with(&fc, &opts)?;
    let fc_secs = t.elapsed().as_secs_f64();
    let full_codesign_scenarios_per_s = fc_total / fc_secs;
    lines.push(format!(
        "full-codesign sweep: {} scenarios, top-k 8, {} rows kept   ({:.0} scenarios/s)",
        fc_report.total,
        fc_report.scenarios.len(),
        full_codesign_scenarios_per_s
    ));

    // the memoized `(config, socket)` trace lookup path, warm — measured
    // after the sweeps so the cold-sweep cache resets cannot zero the
    // counters the report snapshots below
    cache::simulate_gemm(&trace_set[0], &socket); // prime the coordinate
    let m = b.run("trace sim (warm memoized)", || {
        std::hint::black_box(cache::simulate_gemm(&trace_set[0], &socket));
    });
    let trace_memo_lookups_per_s = m.throughput(1.0);
    lines.push(format!("{}   ({:.0} lookups/s)", m.report(), trace_memo_lookups_per_s));

    let warm_speedup = scenarios_per_s_warm / scenarios_per_s_cold;
    let (prog_stats, an_stats) = analysis::cache_stats();
    let est_stats = workload::estimate_cache_stats();
    let trace_stats = cache::trace_cache_stats();
    lines.push(format!(
        "warm/cold sweep speedup: {warm_speedup:.1}x   (cache hit rates: programs {:.0}%, \
         analyses {:.0}%, estimates {:.0}%, traces {:.0}%)",
        prog_stats.hit_rate() * 100.0,
        an_stats.hit_rate() * 100.0,
        est_stats.hit_rate() * 100.0,
        trace_stats.hit_rate() * 100.0
    ));
    lines.push(format!("determinism fingerprint: {fingerprint}"));

    let json = Json::obj([
        ("bench", Json::Num(10.0)),
        ("determinism_fingerprint", Json::Str(fingerprint.clone())),
        ("vec_machine_insts_per_s", Json::Num(vec_machine_insts_per_s)),
        ("program_gen_per_s", Json::Num(program_gen_per_s)),
        ("analyze_cold_per_s", Json::Num(analyze_cold_per_s)),
        ("analyze_warm_per_s", Json::Num(analyze_warm_per_s)),
        ("trace_sim_interval_accesses_per_s", Json::Num(trace_interval_per_s)),
        ("trace_sim_per_access_accesses_per_s", Json::Num(trace_per_access_per_s)),
        ("trace_sim_speedup", Json::Num(trace_speedup)),
        ("trace_memo_lookups_per_s", Json::Num(trace_memo_lookups_per_s)),
        ("scenarios_per_s_cold", Json::Num(scenarios_per_s_cold)),
        ("scenarios_per_s_warm", Json::Num(scenarios_per_s_warm)),
        ("warm_speedup", Json::Num(warm_speedup)),
        ("full_codesign_total", Json::Num(fc_total)),
        ("full_codesign_scenarios_per_s", Json::Num(full_codesign_scenarios_per_s)),
        (
            "caches",
            Json::obj([
                ("programs", cache_json(prog_stats)),
                ("analyses", cache_json(an_stats)),
                ("estimates", cache_json(est_stats)),
                ("traces", cache_json(trace_stats)),
            ]),
        ),
    ]);
    Ok(SuiteReport { lines, json, fingerprint })
}
