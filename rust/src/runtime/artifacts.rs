//! Artifact manifest: what `python/compile/aot.py` exported.

use crate::util::json::Json;

/// Shape+dtype of one input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
}

impl TensorMeta {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT entry point.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: String,
    pub nb: usize,
    pub n_gemm: usize,
    pub n_stream: usize,
    pub entries: Vec<EntryMeta>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<ArtifactManifest, String> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{path}: {e} (run `make artifacts` first)"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &str, text: &str) -> Result<ArtifactManifest, String> {
        let j = Json::parse(text)?;
        let req_usize = |k: &str| {
            j.get(k).and_then(Json::as_usize).ok_or_else(|| format!("manifest missing `{k}`"))
        };
        let tensors = |e: &Json, k: &str| -> Result<Vec<TensorMeta>, String> {
            e.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("entry missing `{k}`"))?
                .iter()
                .map(|t| {
                    let shape = t
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or("tensor missing shape")?
                        .iter()
                        .map(|d| d.as_usize().ok_or("bad dim"))
                        .collect::<Result<Vec<_>, _>>()?;
                    Ok(TensorMeta { shape })
                })
                .collect()
        };
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("manifest missing `entries`")?
            .iter()
            .map(|e| {
                Ok(EntryMeta {
                    name: e.get("name").and_then(Json::as_str).ok_or("entry missing name")?.into(),
                    file: e.get("file").and_then(Json::as_str).ok_or("entry missing file")?.into(),
                    inputs: tensors(e, "inputs")?,
                    outputs: tensors(e, "outputs")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(ArtifactManifest {
            dir: dir.to_string(),
            nb: req_usize("nb")?,
            n_gemm: req_usize("n_gemm")?,
            n_stream: req_usize("n_stream")?,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Option<&EntryMeta> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn path_of(&self, e: &EntryMeta) -> String {
        format!("{}/{}", self.dir, e.file)
    }

    /// Default artifact location: `$CIMONE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> String {
        std::env::var("CIMONE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": 1, "nb": 32, "n_gemm": 256, "n_stream": 1048576,
        "entries": [
            {"name": "gemm_256", "file": "gemm_256.hlo.txt", "sha256": "xx",
             "inputs": [{"shape": [256, 256], "dtype": "f64"},
                        {"shape": [256, 256], "dtype": "f64"}],
             "outputs": [{"shape": [256, 256], "dtype": "f64"}]},
            {"name": "residual_256", "file": "residual_256.hlo.txt",
             "inputs": [{"shape": [256, 256], "dtype": "f64"},
                        {"shape": [256], "dtype": "f64"},
                        {"shape": [256], "dtype": "f64"}],
             "outputs": [{"shape": [], "dtype": "f64"}]}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse("artifacts", SAMPLE).unwrap();
        assert_eq!(m.nb, 32);
        assert_eq!(m.entries.len(), 2);
        let g = m.entry("gemm_256").unwrap();
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[0].elems(), 65536);
        assert_eq!(m.path_of(g), "artifacts/gemm_256.hlo.txt");
    }

    #[test]
    fn scalar_output_has_one_elem() {
        let m = ArtifactManifest::parse("a", SAMPLE).unwrap();
        let r = m.entry("residual_256").unwrap();
        assert_eq!(r.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(r.outputs[0].elems(), 1);
    }

    #[test]
    fn missing_entry_is_none() {
        let m = ArtifactManifest::parse("a", SAMPLE).unwrap();
        assert!(m.entry("nope").is_none());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // exercised for real in integration tests; here only if present
        if std::path::Path::new("artifacts/manifest.json").exists() {
            let m = ArtifactManifest::load("artifacts").unwrap();
            assert!(m.entry("gemm_256").is_some());
            assert!(m.entry("ukernel_lmul4").is_some());
        }
    }
}
