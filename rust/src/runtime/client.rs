//! PJRT client wrapper: one CPU client, a cache of compiled executables.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use super::artifacts::ArtifactManifest;
use super::executable::LoadedExecutable;
// without the `pjrt` feature the xla-rs bindings are replaced by a stub
// whose client constructor fails gracefully (see xla_stub.rs)
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

/// The runtime: PJRT client + manifest + compiled-executable cache.
///
/// Compilation happens once per entry (first use); execution after that is
/// pure PJRT with no Python anywhere.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: ArtifactManifest,
    cache: BTreeMap<String, LoadedExecutable>,
}

impl Runtime {
    /// Create against the default artifact dir (`$CIMONE_ARTIFACTS` or
    /// `./artifacts`).
    pub fn new() -> Result<Runtime> {
        Self::with_dir(&ArtifactManifest::default_dir())
    }

    pub fn with_dir(dir: &str) -> Result<Runtime> {
        let manifest = ArtifactManifest::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: BTreeMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) the executable for a manifest entry.
    pub fn load(&mut self, name: &str) -> Result<&LoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .entry(name)
                .ok_or_else(|| anyhow!("no artifact named `{name}` in manifest"))?
                .clone();
            let path = self.manifest.path_of(&entry);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), LoadedExecutable::new(entry, exe));
        }
        Ok(self.cache.get(name).unwrap())
    }

    /// Execute an entry on f64 buffers (shapes validated vs the manifest).
    pub fn call(&mut self, name: &str, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        // (borrow dance: load mutates the cache, execute doesn't)
        self.load(name)?;
        self.cache.get(name).unwrap().execute_f64(inputs)
    }

    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }
}
