//! Typed convenience wrappers over the AOT entry points — the API the
//! benchmarks and examples actually call.

use anyhow::{anyhow, Result};

use super::client::Runtime;
use crate::util::Matrix;

/// C = A @ B via the `gemm_256` artifact (A, B must be n_gemm x n_gemm).
pub fn gemm(rt: &mut Runtime, a: &Matrix, b: &Matrix) -> Result<Matrix> {
    let n = rt.manifest.n_gemm;
    check_square(a, n)?;
    check_square(b, n)?;
    let out = rt.call("gemm_256", &[&a.to_row_major(), &b.to_row_major()])?;
    Ok(Matrix::from_row_major(n, n, &out[0]))
}

/// C -= A @ B via `trailing_update_256`, zero-padding to the artifact's
/// fixed geometry (padding contributes exact zeros — the property
/// python/tests/test_model.py::test_zero_padding_invariance proves).
pub fn trailing_update(rt: &mut Runtime, c: &mut Matrix, a: &Matrix, b: &Matrix) -> Result<()> {
    let n = rt.manifest.n_gemm;
    let nb = rt.manifest.nb;
    let (rows, cols, k) = (c.rows(), c.cols(), a.cols());
    if rows > n || cols > n || k > nb {
        return Err(anyhow!(
            "trailing_update: live region {rows}x{cols} (k={k}) exceeds artifact {n}x{n} (nb={nb})"
        ));
    }
    let mut cp = Matrix::zeros(n, n);
    cp.set_block(0, 0, c);
    let mut ap = Matrix::zeros(n, nb);
    ap.set_block(0, 0, a);
    let mut bp = Matrix::zeros(nb, n);
    bp.set_block(0, 0, b);
    let out = rt.call(
        "trailing_update_256",
        &[&cp.to_row_major(), &ap.to_row_major(), &bp.to_row_major()],
    )?;
    let full = Matrix::from_row_major(n, n, &out[0]);
    *c = full.block(0, 0, rows, cols);
    Ok(())
}

/// HPL residual numerator max|Ax-b| via `residual_256`.
pub fn residual_inf(rt: &mut Runtime, a: &Matrix, x: &[f64], b: &[f64]) -> Result<f64> {
    let n = rt.manifest.n_gemm;
    check_square(a, n)?;
    if x.len() != n || b.len() != n {
        return Err(anyhow!("residual_256 wants vectors of len {n}"));
    }
    let out = rt.call("residual_256", &[&a.to_row_major(), x, b])?;
    Ok(out[0][0])
}

/// One STREAM kernel via its artifact; returns the output array.
pub fn stream(rt: &mut Runtime, kernel: &str, a: &[f64], b: Option<&[f64]>) -> Result<Vec<f64>> {
    let name = match kernel {
        "copy" => "stream_copy",
        "scale" => "stream_scale",
        "add" => "stream_add",
        "triad" => "stream_triad",
        other => return Err(anyhow!("unknown STREAM kernel `{other}`")),
    };
    let needs_two = matches!(kernel, "add" | "triad");
    let out = match (needs_two, b) {
        (true, Some(b)) => rt.call(name, &[a, b])?,
        (false, None) => rt.call(name, &[a])?,
        _ => return Err(anyhow!("{kernel}: wrong operand count")),
    };
    Ok(out.into_iter().next().unwrap())
}

/// The two micro-kernel artifacts (8x64 @ 64x8 + 8x8 accumulator); used by
/// the integration tests to tie the Pallas schedules to the Rust ISA ones.
pub fn ukernel(rt: &mut Runtime, variant: &str, a: &Matrix, b: &Matrix, c: &Matrix) -> Result<Matrix> {
    let name = match variant {
        "lmul1" => "ukernel_lmul1",
        "lmul4" => "ukernel_lmul4",
        other => return Err(anyhow!("unknown ukernel variant `{other}`")),
    };
    let out = rt.call(name, &[&a.to_row_major(), &b.to_row_major(), &c.to_row_major()])?;
    Ok(Matrix::from_row_major(c.rows(), c.cols(), &out[0]))
}

fn check_square(m: &Matrix, n: usize) -> Result<()> {
    if m.rows() != n || m.cols() != n {
        return Err(anyhow!("expected {n}x{n}, got {}x{}", m.rows(), m.cols()));
    }
    Ok(())
}
