//! A compiled artifact with typed, shape-checked execution.

use anyhow::{anyhow, Context, Result};

use super::artifacts::EntryMeta;
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

/// One compiled entry point.
pub struct LoadedExecutable {
    pub meta: EntryMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedExecutable {
    pub fn new(meta: EntryMeta, exe: xla::PjRtLoadedExecutable) -> Self {
        LoadedExecutable { meta, exe }
    }

    /// Execute on f64 inputs; returns one Vec per declared output.
    ///
    /// Inputs are row-major (jax convention); shapes must match the
    /// manifest exactly — AOT artifacts are shape-specialized.
    pub fn execute_f64(&self, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (buf, meta)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            if buf.len() != meta.elems() {
                return Err(anyhow!(
                    "{} input {i}: expected {} elems (shape {:?}), got {}",
                    self.meta.name,
                    meta.elems(),
                    meta.shape,
                    buf.len()
                ));
            }
            let lit = xla::Literal::vec1(buf);
            let dims: Vec<i64> = meta.shape.iter().map(|&d| d as i64).collect();
            let lit = if dims.is_empty() {
                lit
            } else {
                lit.reshape(&dims).with_context(|| format!("reshape input {i}"))?
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // aot.py lowers with return_tuple=True: unwrap the tuple
        let elems = result.to_tuple()?;
        if elems.len() != self.meta.outputs.len() {
            return Err(anyhow!(
                "{}: expected {} outputs, got {}",
                self.meta.name,
                self.meta.outputs.len(),
                elems.len()
            ));
        }
        let mut out = Vec::with_capacity(elems.len());
        for (lit, meta) in elems.into_iter().zip(&self.meta.outputs) {
            let v = lit.to_vec::<f64>().context("output to_vec")?;
            if v.len() != meta.elems() {
                return Err(anyhow!(
                    "{}: output had {} elems, manifest says {}",
                    self.meta.name,
                    v.len(),
                    meta.elems()
                ));
            }
            out.push(v);
        }
        Ok(out)
    }
}
