//! PJRT runtime — the Layer-3 side of the AOT bridge.
//!
//! `make artifacts` lowers the JAX/Pallas compute graphs to HLO *text*
//! (xla_extension 0.5.1 rejects jax>=0.5 serialized protos; the text
//! parser reassigns instruction ids). This module loads those artifacts
//! with `xla::PjRtClient::cpu()`, compiles them once, and exposes typed
//! entry points the benchmarks call on the hot path. Python never runs
//! here.

pub mod artifacts;
pub mod client;
pub mod executable;
pub mod entries;
#[cfg(not(feature = "pjrt"))]
pub(crate) mod xla_stub;

pub use artifacts::{ArtifactManifest, EntryMeta};
pub use client::Runtime;
pub use executable::LoadedExecutable;
