//! Compile-time stub for the `xla` (xla-rs) PJRT bindings.
//!
//! The real PJRT path links xla-rs against a libxla build, which the
//! offline build environment cannot provide, so the dependency is gated
//! behind the `pjrt` cargo feature. Without it, this stub supplies the
//! exact API surface [`super::client`] / [`super::executable`] use:
//! every entry point reports PJRT as unavailable, so `Runtime::new()`
//! fails gracefully, artifact-driven tests and examples skip, and the
//! rest of the crate builds and runs normally. Build with
//! `--features pjrt` (adding the xla-rs dependency) for real execution.

use std::fmt;

/// The error every stubbed entry point returns.
#[derive(Debug)]
pub struct StubUnavailable;

impl fmt::Display for StubUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PJRT unavailable: cimone built without the `pjrt` feature (xla-rs not linked)")
    }
}

impl std::error::Error for StubUnavailable {}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, StubUnavailable> {
        Err(StubUnavailable)
    }

    pub fn platform_name(&self) -> String {
        "pjrt-stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, StubUnavailable> {
        Err(StubUnavailable)
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, StubUnavailable> {
        Err(StubUnavailable)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, StubUnavailable> {
        Err(StubUnavailable)
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, StubUnavailable> {
        Err(StubUnavailable)
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, StubUnavailable> {
        Err(StubUnavailable)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, StubUnavailable> {
        Err(StubUnavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, StubUnavailable> {
        Err(StubUnavailable)
    }
}
