//! Core-level allocation within a node: thread pinning policies.
//!
//! The paper pins STREAM threads "symmetrically in the two sockets" on the
//! dual-socket node; HPL ranks get whole nodes. This module captures those
//! policies so experiments state their pinning explicitly.

use crate::arch::soc::SocDescriptor;

/// How threads map onto a node's sockets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pinning {
    /// Spread evenly across sockets (paper's best configuration).
    Symmetric,
    /// Fill socket 0 first, then socket 1 (OpenMP default without binding).
    Packed,
}

/// Threads assigned to each socket under a policy.
pub fn threads_per_socket(desc: &SocDescriptor, threads: usize, pinning: Pinning) -> Vec<usize> {
    let n = desc.sockets.len();
    let mut out = vec![0usize; n];
    match pinning {
        Pinning::Symmetric => {
            for s in 0..n {
                out[s] = threads / n + usize::from(s < threads % n);
            }
        }
        Pinning::Packed => {
            let mut left = threads;
            for (s, sock) in desc.sockets.iter().enumerate() {
                let take = left.min(sock.cores);
                out[s] = take;
                left -= take;
            }
            out[0] += left; // oversubscription lands on socket 0
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn symmetric_splits_evenly() {
        let d = presets::sg2042_dual();
        assert_eq!(threads_per_socket(&d, 64, Pinning::Symmetric), vec![32, 32]);
        assert_eq!(threads_per_socket(&d, 65, Pinning::Symmetric), vec![33, 32]);
    }

    #[test]
    fn packed_fills_socket_zero_first() {
        let d = presets::sg2042_dual();
        assert_eq!(threads_per_socket(&d, 64, Pinning::Packed), vec![64, 0]);
        assert_eq!(threads_per_socket(&d, 100, Pinning::Packed), vec![64, 36]);
    }

    #[test]
    fn packed_oversubscribes_socket_zero() {
        let d = presets::sg2042();
        assert_eq!(threads_per_socket(&d, 80, Pinning::Packed), vec![80]);
    }
}
