//! Batch jobs.

/// Job identifier.
pub type JobId = u64;

/// Lifecycle of a job.
///
/// The end time is computed **once**, when the job starts, and stored
/// exactly; every later comparison (event ordering, completion matching)
/// uses the stored bits. Recomputing `start + runtime` at match time and
/// comparing within an absolute epsilon breaks down at large simulated
/// times, where 1e-9 is far below the spacing of representable doubles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobState {
    Pending,
    Running { start: f64, end: f64 },
    Completed { start: f64, end: f64 },
}

/// A batch job: a resource request plus a (simulated) runtime.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub name: String,
    pub partition: String,
    pub nodes: usize,
    /// Simulated wall-clock the job occupies its nodes for.
    pub runtime_s: f64,
    /// Arrival time: the job enters the queue at this simulated time.
    pub submit_s: f64,
    /// Higher priorities are considered first; ties break on arrival
    /// time, then submission order. The default is 0.
    pub priority: i64,
    /// Owning user for multi-tenant accounting (empty for system jobs).
    pub user: String,
    pub state: JobState,
    /// Node indices allocated (filled when running).
    pub allocated: Vec<usize>,
}

impl Job {
    pub fn new(
        id: JobId,
        name: impl Into<String>,
        partition: impl Into<String>,
        nodes: usize,
        runtime_s: f64,
        submit_s: f64,
    ) -> Job {
        assert!(nodes >= 1);
        assert!(runtime_s > 0.0);
        Job {
            id,
            name: name.into(),
            partition: partition.into(),
            nodes,
            runtime_s,
            submit_s,
            priority: 0,
            user: String::new(),
            state: JobState::Pending,
            allocated: vec![],
        }
    }

    pub fn is_pending(&self) -> bool {
        matches!(self.state, JobState::Pending)
    }

    /// Exact stored end time (never recomputed from `start + runtime`).
    pub fn end_time(&self) -> Option<f64> {
        match self.state {
            JobState::Running { end, .. } | JobState::Completed { end, .. } => Some(end),
            JobState::Pending => None,
        }
    }

    /// Queue wait time, defined once the job has started.
    pub fn wait_time(&self) -> Option<f64> {
        match self.state {
            JobState::Running { start, .. } | JobState::Completed { start, .. } => {
                Some(start - self.submit_s)
            }
            JobState::Pending => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_accessors() {
        let mut j = Job::new(1, "hpl", "mcv2", 2, 100.0, 5.0);
        assert!(j.is_pending());
        assert_eq!(j.end_time(), None);
        j.state = JobState::Running { start: 10.0, end: 110.0 };
        assert_eq!(j.end_time(), Some(110.0));
        assert_eq!(j.wait_time(), Some(5.0));
        j.state = JobState::Completed { start: 10.0, end: 110.0 };
        assert_eq!(j.end_time(), Some(110.0));
    }

    #[test]
    fn defaults_are_system_priority_zero() {
        let j = Job::new(2, "x", "p", 1, 1.0, 0.0);
        assert_eq!(j.priority, 0);
        assert!(j.user.is_empty());
    }

    #[test]
    #[should_panic]
    fn zero_nodes_rejected() {
        Job::new(1, "x", "p", 0, 1.0, 0.0);
    }
}
