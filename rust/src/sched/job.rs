//! Batch jobs.

/// Job identifier.
pub type JobId = u64;

/// Lifecycle of a job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobState {
    Pending,
    Running { start: f64 },
    Completed { start: f64, end: f64 },
}

/// A batch job: a resource request plus a (simulated) runtime.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub name: String,
    pub partition: String,
    pub nodes: usize,
    /// Simulated wall-clock the job occupies its nodes for.
    pub runtime_s: f64,
    pub submit_s: f64,
    pub state: JobState,
    /// Node indices allocated (filled when running).
    pub allocated: Vec<usize>,
}

impl Job {
    pub fn new(
        id: JobId,
        name: impl Into<String>,
        partition: impl Into<String>,
        nodes: usize,
        runtime_s: f64,
        submit_s: f64,
    ) -> Job {
        assert!(nodes >= 1);
        assert!(runtime_s > 0.0);
        Job {
            id,
            name: name.into(),
            partition: partition.into(),
            nodes,
            runtime_s,
            submit_s,
            state: JobState::Pending,
            allocated: vec![],
        }
    }

    pub fn is_pending(&self) -> bool {
        matches!(self.state, JobState::Pending)
    }

    pub fn end_time(&self) -> Option<f64> {
        match self.state {
            JobState::Running { start } => Some(start + self.runtime_s),
            JobState::Completed { end, .. } => Some(end),
            JobState::Pending => None,
        }
    }

    /// Queue wait time, defined once the job has started.
    pub fn wait_time(&self) -> Option<f64> {
        match self.state {
            JobState::Running { start } | JobState::Completed { start, .. } => {
                Some(start - self.submit_s)
            }
            JobState::Pending => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_accessors() {
        let mut j = Job::new(1, "hpl", "mcv2", 2, 100.0, 5.0);
        assert!(j.is_pending());
        assert_eq!(j.end_time(), None);
        j.state = JobState::Running { start: 10.0 };
        assert_eq!(j.end_time(), Some(110.0));
        assert_eq!(j.wait_time(), Some(5.0));
        j.state = JobState::Completed { start: 10.0, end: 110.0 };
        assert_eq!(j.end_time(), Some(110.0));
    }

    #[test]
    #[should_panic]
    fn zero_nodes_rejected() {
        Job::new(1, "x", "p", 0, 1.0, 0.0);
    }
}
