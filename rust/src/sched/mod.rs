//! SLURM-like batch scheduler substrate.
//!
//! Monte Cimone exposes its MCv1 and MCv2 machines as SLURM partitions;
//! the multi-node experiments (Fig 5) submit jobs against them, and the
//! production-shaped scenarios drain multi-user queues under outages.
//! This module implements the orchestration layer: partitions with
//! availability state, a priority job queue with FIFO + EASY-backfill
//! scheduling driven by an exact-time event heap (completions, arrivals,
//! node availability windows), and node allocation tracking.

pub mod allocation;
pub mod job;
pub mod partition;
pub mod scheduler;

pub use job::{Job, JobId, JobState};
pub use partition::Partition;
pub use scheduler::{JobRequest, Scheduler};
