//! SLURM-like batch scheduler substrate.
//!
//! Monte Cimone exposes its MCv1 and MCv2 machines as SLURM partitions;
//! the multi-node experiments (Fig 5) submit jobs against them. This
//! module implements the orchestration layer: partitions, a job queue
//! with FIFO + conservative-backfill scheduling over a simulated-time
//! event loop, and node allocation tracking.

pub mod allocation;
pub mod job;
pub mod partition;
pub mod scheduler;

pub use job::{Job, JobId, JobState};
pub use partition::Partition;
pub use scheduler::Scheduler;
