//! SLURM-style partitions: named groups of nodes with availability state.

/// Per-node state within a partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    Idle,
    Busy,
    /// Drained by the operator / failed (failure injection for tests and
    /// resilience experiments) — never allocated until marked up.
    Down,
}

/// A partition of the cluster (e.g. `mcv1`, `mcv2`).
#[derive(Debug, Clone)]
pub struct Partition {
    pub name: String,
    /// Global node indices belonging to this partition.
    pub node_ids: Vec<usize>,
    /// state[i] corresponds to node_ids[i].
    state: Vec<SlotState>,
}

impl Partition {
    pub fn new(name: impl Into<String>, node_ids: Vec<usize>) -> Partition {
        let n = node_ids.len();
        Partition { name: name.into(), node_ids, state: vec![SlotState::Idle; n] }
    }

    /// Schedulable size (up nodes only).
    pub fn size(&self) -> usize {
        self.state.iter().filter(|s| **s != SlotState::Down).count()
    }

    pub fn idle_count(&self) -> usize {
        self.state.iter().filter(|s| **s == SlotState::Idle).count()
    }

    /// Mark a node down (failure injection / drain). Busy nodes finish
    /// their job first in this model (graceful drain); returns false if
    /// the id is not in this partition.
    pub fn mark_down(&mut self, id: usize) -> bool {
        match self.node_ids.iter().position(|n| *n == id) {
            Some(slot) if self.state[slot] == SlotState::Idle => {
                self.state[slot] = SlotState::Down;
                true
            }
            Some(_) => false, // busy: cannot hard-down in this model
            None => false,
        }
    }

    /// Return a downed node to service.
    pub fn mark_up(&mut self, id: usize) -> bool {
        match self.node_ids.iter().position(|n| *n == id) {
            Some(slot) if self.state[slot] == SlotState::Down => {
                self.state[slot] = SlotState::Idle;
                true
            }
            _ => false,
        }
    }

    /// Try to allocate `n` nodes; returns their global ids.
    pub fn allocate(&mut self, n: usize) -> Option<Vec<usize>> {
        if self.idle_count() < n {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for (slot, s) in self.state.iter_mut().enumerate() {
            if *s == SlotState::Idle {
                *s = SlotState::Busy;
                out.push(self.node_ids[slot]);
                if out.len() == n {
                    break;
                }
            }
        }
        Some(out)
    }

    /// Release nodes by global id.
    pub fn release(&mut self, ids: &[usize]) {
        for id in ids {
            if let Some(slot) = self.node_ids.iter().position(|n| n == id) {
                if self.state[slot] == SlotState::Busy {
                    self.state[slot] = SlotState::Idle;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release() {
        let mut p = Partition::new("mcv2", vec![8, 9, 10, 11]);
        assert_eq!(p.idle_count(), 4);
        let got = p.allocate(2).unwrap();
        assert_eq!(got, vec![8, 9]);
        assert_eq!(p.idle_count(), 2);
        assert!(p.allocate(3).is_none());
        p.release(&got);
        assert_eq!(p.idle_count(), 4);
    }

    #[test]
    fn release_unknown_id_is_harmless() {
        let mut p = Partition::new("x", vec![1]);
        p.release(&[99]);
        assert_eq!(p.idle_count(), 1);
    }

    #[test]
    fn downed_node_not_allocated() {
        let mut p = Partition::new("mcv2", vec![8, 9, 10, 11]);
        assert!(p.mark_down(9));
        assert_eq!(p.size(), 3);
        assert_eq!(p.idle_count(), 3);
        let got = p.allocate(3).unwrap();
        assert!(!got.contains(&9));
        assert!(p.allocate(1).is_none());
        assert!(p.mark_up(9));
        assert!(p.allocate(1).unwrap().contains(&9));
    }

    #[test]
    fn busy_node_cannot_be_hard_downed() {
        let mut p = Partition::new("x", vec![1, 2]);
        let got = p.allocate(1).unwrap();
        assert!(!p.mark_down(got[0]), "busy nodes drain gracefully");
        p.release(&got);
        assert!(p.mark_down(got[0]));
    }

    #[test]
    fn release_does_not_resurrect_downed_node() {
        let mut p = Partition::new("x", vec![1]);
        p.mark_down(1);
        p.release(&[1]); // stray release of a downed node
        assert_eq!(p.idle_count(), 0);
    }
}
