//! SLURM-style partitions: named groups of nodes with availability state.

/// Per-node state within a partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    Idle,
    Busy,
    /// Busy, but scheduled to go [`Down`](SlotState::Down) when its job
    /// releases it (graceful drain: the running job finishes first).
    Draining,
    /// Drained by the operator / failed (failure injection for tests and
    /// resilience experiments) — never allocated until marked up.
    Down,
}

/// A partition of the cluster (e.g. `mcv1`, `mcv2`).
#[derive(Debug, Clone)]
pub struct Partition {
    pub name: String,
    /// Global node indices belonging to this partition.
    pub node_ids: Vec<usize>,
    /// state[i] corresponds to node_ids[i].
    state: Vec<SlotState>,
}

impl Partition {
    pub fn new(name: impl Into<String>, node_ids: Vec<usize>) -> Partition {
        let n = node_ids.len();
        Partition { name: name.into(), node_ids, state: vec![SlotState::Idle; n] }
    }

    /// Schedulable size: nodes that are up and not on their way down.
    pub fn size(&self) -> usize {
        self.state
            .iter()
            .filter(|s| !matches!(s, SlotState::Down | SlotState::Draining))
            .count()
    }

    pub fn idle_count(&self) -> usize {
        self.state.iter().filter(|s| **s == SlotState::Idle).count()
    }

    /// Does this partition own global node `id`?
    pub fn contains(&self, id: usize) -> bool {
        self.node_ids.contains(&id)
    }

    /// Mark a node down (failure injection / drain). Busy nodes finish
    /// their job first in this model (graceful drain); returns false if
    /// the id is not in this partition.
    pub fn mark_down(&mut self, id: usize) -> bool {
        match self.node_ids.iter().position(|n| *n == id) {
            Some(slot) if self.state[slot] == SlotState::Idle => {
                self.state[slot] = SlotState::Down;
                true
            }
            Some(_) => false, // busy: cannot hard-down in this model
            None => false,
        }
    }

    /// Take a node out of service, draining gracefully: an idle node goes
    /// down immediately, a busy node finishes its job first and goes down
    /// on release. Returns false if the id is not in this partition.
    pub fn request_down(&mut self, id: usize) -> bool {
        match self.node_ids.iter().position(|n| *n == id) {
            Some(slot) => {
                match self.state[slot] {
                    SlotState::Idle => self.state[slot] = SlotState::Down,
                    SlotState::Busy => self.state[slot] = SlotState::Draining,
                    SlotState::Draining | SlotState::Down => {}
                }
                true
            }
            None => false,
        }
    }

    /// Return a downed (or draining) node to service.
    pub fn mark_up(&mut self, id: usize) -> bool {
        match self.node_ids.iter().position(|n| *n == id) {
            Some(slot) if self.state[slot] == SlotState::Down => {
                self.state[slot] = SlotState::Idle;
                true
            }
            Some(slot) if self.state[slot] == SlotState::Draining => {
                // drain cancelled before the job finished: stays busy
                self.state[slot] = SlotState::Busy;
                true
            }
            _ => false,
        }
    }

    /// Of the given allocated node ids, how many will return to the idle
    /// pool when released (i.e. are not draining toward `Down`)?
    pub fn returning_count(&self, ids: &[usize]) -> usize {
        ids.iter()
            .filter(|id| {
                self.node_ids
                    .iter()
                    .position(|n| n == *id)
                    .is_some_and(|slot| self.state[slot] == SlotState::Busy)
            })
            .count()
    }

    /// Try to allocate `n` nodes; returns their global ids.
    pub fn allocate(&mut self, n: usize) -> Option<Vec<usize>> {
        if self.idle_count() < n {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for (slot, s) in self.state.iter_mut().enumerate() {
            if *s == SlotState::Idle {
                *s = SlotState::Busy;
                out.push(self.node_ids[slot]);
                if out.len() == n {
                    break;
                }
            }
        }
        Some(out)
    }

    /// Release nodes by global id. Draining nodes go down instead of idle.
    pub fn release(&mut self, ids: &[usize]) {
        for id in ids {
            if let Some(slot) = self.node_ids.iter().position(|n| n == id) {
                match self.state[slot] {
                    SlotState::Busy => self.state[slot] = SlotState::Idle,
                    SlotState::Draining => self.state[slot] = SlotState::Down,
                    SlotState::Idle | SlotState::Down => {}
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release() {
        let mut p = Partition::new("mcv2", vec![8, 9, 10, 11]);
        assert_eq!(p.idle_count(), 4);
        let got = p.allocate(2).unwrap();
        assert_eq!(got, vec![8, 9]);
        assert_eq!(p.idle_count(), 2);
        assert!(p.allocate(3).is_none());
        p.release(&got);
        assert_eq!(p.idle_count(), 4);
    }

    #[test]
    fn release_unknown_id_is_harmless() {
        let mut p = Partition::new("x", vec![1]);
        p.release(&[99]);
        assert_eq!(p.idle_count(), 1);
    }

    #[test]
    fn downed_node_not_allocated() {
        let mut p = Partition::new("mcv2", vec![8, 9, 10, 11]);
        assert!(p.mark_down(9));
        assert_eq!(p.size(), 3);
        assert_eq!(p.idle_count(), 3);
        let got = p.allocate(3).unwrap();
        assert!(!got.contains(&9));
        assert!(p.allocate(1).is_none());
        assert!(p.mark_up(9));
        assert!(p.allocate(1).unwrap().contains(&9));
    }

    #[test]
    fn busy_node_cannot_be_hard_downed() {
        let mut p = Partition::new("x", vec![1, 2]);
        let got = p.allocate(1).unwrap();
        assert!(!p.mark_down(got[0]), "busy nodes drain gracefully");
        p.release(&got);
        assert!(p.mark_down(got[0]));
    }

    #[test]
    fn release_does_not_resurrect_downed_node() {
        let mut p = Partition::new("x", vec![1]);
        p.mark_down(1);
        p.release(&[1]); // stray release of a downed node
        assert_eq!(p.idle_count(), 0);
    }

    #[test]
    fn request_down_drains_busy_node_gracefully() {
        let mut p = Partition::new("x", vec![1, 2]);
        let got = p.allocate(1).unwrap();
        assert!(p.request_down(got[0]));
        // still occupied by its job, but no longer schedulable
        assert_eq!(p.size(), 1);
        assert_eq!(p.returning_count(&got), 0);
        p.release(&got);
        // released straight into Down, never back to the idle pool
        assert_eq!(p.idle_count(), 1);
        assert_eq!(p.size(), 1);
        assert!(p.mark_up(got[0]));
        assert_eq!(p.size(), 2);
    }

    #[test]
    fn mark_up_cancels_pending_drain() {
        let mut p = Partition::new("x", vec![1]);
        let got = p.allocate(1).unwrap();
        assert!(p.request_down(1));
        assert!(p.mark_up(1), "drain can be cancelled while the job runs");
        assert_eq!(p.returning_count(&got), 1);
        p.release(&got);
        assert_eq!(p.idle_count(), 1);
    }

    #[test]
    fn contains_checks_membership() {
        let p = Partition::new("x", vec![3, 5]);
        assert!(p.contains(5));
        assert!(!p.contains(4));
    }
}
