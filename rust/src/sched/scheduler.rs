//! FIFO + conservative-backfill scheduler over simulated time.
//!
//! Semantics match SLURM's default behaviour closely enough for the
//! experiments: jobs are considered in submit order; the head-of-queue
//! job reserves the earliest time enough nodes free up; later jobs may
//! backfill onto idle nodes only if they finish before that reservation.
//!
//! Partitions never share nodes, so their event streams are independent;
//! [`Scheduler::drain_parallel`] exploits this to drain each partition on
//! its own OS thread while producing bit-identical simulated-time
//! accounting to the serial [`Scheduler::drain`].

use std::collections::BTreeMap;

use super::job::{Job, JobId, JobState};
use super::partition::Partition;
use crate::error::CimoneError;

/// The scheduler: owns partitions and the job queue.
pub struct Scheduler {
    pub partitions: BTreeMap<String, Partition>,
    pub jobs: Vec<Job>,
    pub now: f64,
    next_id: JobId,
}

impl Scheduler {
    pub fn new(partitions: Vec<Partition>) -> Scheduler {
        Scheduler {
            partitions: partitions.into_iter().map(|p| (p.name.clone(), p)).collect(),
            jobs: Vec::new(),
            now: 0.0,
            next_id: 1,
        }
    }

    /// Submit a job at the current simulated time; returns its id.
    pub fn submit(
        &mut self,
        name: &str,
        partition: &str,
        nodes: usize,
        runtime_s: f64,
    ) -> Result<JobId, CimoneError> {
        let p = self
            .partitions
            .get(partition)
            .ok_or_else(|| CimoneError::UnknownPartition(partition.to_string()))?;
        if nodes > p.size() {
            return Err(CimoneError::PartitionTooSmall {
                job: name.to_string(),
                partition: partition.to_string(),
                want: nodes,
                have: p.size(),
            });
        }
        // an infinite runtime would make `advance_to` spin forever (its
        // completion check degrades to NaN comparisons); a non-positive
        // one would rewind simulated time
        if !runtime_s.is_finite() || runtime_s <= 0.0 {
            return Err(CimoneError::InvalidRuntime { job: name.to_string(), runtime_s });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.jobs.push(Job::new(id, name, partition, nodes, runtime_s, self.now));
        self.try_start();
        Ok(id)
    }

    /// Earliest running-job end time, if any.
    fn next_completion(&self) -> Option<f64> {
        self.jobs
            .iter()
            .filter_map(|j| match j.state {
                JobState::Running { .. } => j.end_time(),
                _ => None,
            })
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Earliest time `extra` nodes will be free in `partition`, given the
    /// currently running jobs (the head job's EASY-backfill reservation).
    fn reservation_time(&self, partition: &str, want: usize) -> f64 {
        let part = &self.partitions[partition];
        let mut idle = part.idle_count();
        if idle >= want {
            return self.now;
        }
        // accumulate releases in end-time order
        let mut ends: Vec<(f64, usize)> = self
            .jobs
            .iter()
            .filter(|j| j.partition == partition)
            .filter_map(|j| match j.state {
                JobState::Running { .. } => j.end_time().map(|e| (e, j.nodes)),
                _ => None,
            })
            .collect();
        ends.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for (end, nodes) in ends {
            idle += nodes;
            if idle >= want {
                return end;
            }
        }
        f64::INFINITY
    }

    /// Start every job that can start right now: FIFO head first, then
    /// EASY backfill (later jobs may jump the queue only if they finish
    /// before the head job's reservation time).
    fn try_start(&mut self) {
        // per-partition head-of-line reservation: (demand, reserved time)
        let mut hol: BTreeMap<String, f64> = BTreeMap::new();
        let now = self.now;
        for idx in 0..self.jobs.len() {
            if !self.jobs[idx].is_pending() {
                continue;
            }
            let (part_name, want, runtime) = (
                self.jobs[idx].partition.clone(),
                self.jobs[idx].nodes,
                self.jobs[idx].runtime_s,
            );
            let head_reservation = hol.get(&part_name).copied();
            let idle = self.partitions[&part_name].idle_count();
            let can_start = match head_reservation {
                None => idle >= want,
                // backfill window: must complete before the head's start
                Some(t_res) => idle >= want && now + runtime <= t_res + 1e-9,
            };
            if can_start {
                let part = self.partitions.get_mut(&part_name).unwrap();
                let alloc = part.allocate(want).expect("idle_count said yes");
                let job = &mut self.jobs[idx];
                job.allocated = alloc;
                job.state = JobState::Running { start: now };
            } else if head_reservation.is_none() {
                let t = self.reservation_time(&part_name, want);
                hol.insert(part_name, t);
            }
        }
    }

    /// Advance simulated time to `t`, completing and starting jobs.
    pub fn advance_to(&mut self, t: f64) {
        assert!(t >= self.now);
        loop {
            match self.next_completion() {
                Some(end) if end <= t => {
                    self.now = end;
                    // complete everything ending at `end`
                    let mut released: Vec<(String, Vec<usize>)> = vec![];
                    for j in self.jobs.iter_mut() {
                        if let JobState::Running { start } = j.state {
                            if (start + j.runtime_s - end).abs() < 1e-9 {
                                j.state = JobState::Completed { start, end };
                                released.push((j.partition.clone(), j.allocated.clone()));
                            }
                        }
                    }
                    for (part, ids) in released {
                        self.partitions.get_mut(&part).unwrap().release(&ids);
                    }
                    self.try_start();
                }
                _ => break,
            }
        }
        self.now = t;
    }

    /// Run until all jobs complete; returns the makespan.
    pub fn drain(&mut self) -> f64 {
        while let Some(end) = self.next_completion() {
            self.advance_to(end);
        }
        self.now
    }

    /// Drain every partition concurrently, one OS thread per partition.
    ///
    /// Correctness relies on partitions being disjoint node sets: a job's
    /// start/backfill decisions depend only on its own partition's state
    /// and on the relative submit order within that partition, both of
    /// which are preserved when the queue is split. The result — per-job
    /// start/end times and the overall makespan — is therefore identical
    /// to the serial [`drain`](Self::drain), while independent workload
    /// streams retire in parallel wall-clock time. (One femtosecond-scale
    /// caveat: the serial drain's `advance_to` snaps completions in
    /// *other* partitions that land within its 1e-9 tie epsilon onto the
    /// same instant; the split drain keeps each partition's exact times.)
    pub fn drain_parallel(&mut self) -> f64 {
        if self.partitions.len() <= 1 {
            return self.drain();
        }
        let start_now = self.now;
        let partitions = std::mem::take(&mut self.partitions);
        let mut by_part: BTreeMap<String, Vec<Job>> = BTreeMap::new();
        for job in std::mem::take(&mut self.jobs) {
            by_part.entry(job.partition.clone()).or_default().push(job);
        }
        let mut subs: Vec<Scheduler> = partitions
            .into_iter()
            .map(|(name, part)| Scheduler {
                jobs: by_part.remove(&name).unwrap_or_default(),
                partitions: BTreeMap::from([(name, part)]),
                now: start_now,
                next_id: self.next_id,
            })
            .collect();

        // the scope joins every spawned thread on exit and propagates
        // any panic, so no explicit join bookkeeping is needed
        std::thread::scope(|scope| {
            for sub in subs.iter_mut() {
                let _ = scope.spawn(move || sub.drain());
            }
        });

        let mut makespan = start_now;
        for sub in subs {
            makespan = makespan.max(sub.now);
            self.partitions.extend(sub.partitions);
            self.jobs.extend(sub.jobs);
        }
        self.jobs.sort_by_key(|j| j.id);
        self.now = makespan;
        makespan
    }

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.iter().find(|j| j.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_partition_sched() -> Scheduler {
        Scheduler::new(vec![
            Partition::new("mcv1", (0..8).collect()),
            Partition::new("mcv2", (8..12).collect()),
        ])
    }

    #[test]
    fn fifo_runs_immediately_when_idle() {
        let mut s = two_partition_sched();
        let id = s.submit("hpl", "mcv2", 2, 100.0).unwrap();
        assert!(matches!(s.job(id).unwrap().state, JobState::Running { .. }));
    }

    #[test]
    fn queues_when_full_then_starts() {
        let mut s = two_partition_sched();
        let a = s.submit("a", "mcv2", 4, 50.0).unwrap();
        let b = s.submit("b", "mcv2", 4, 50.0).unwrap();
        assert!(s.job(b).unwrap().is_pending());
        s.advance_to(50.0);
        assert!(matches!(s.job(b).unwrap().state, JobState::Running { start } if start == 50.0));
        assert!(matches!(s.job(a).unwrap().state, JobState::Completed { .. }));
    }

    #[test]
    fn backfill_small_job_jumps_queue_safely() {
        let mut s = two_partition_sched();
        s.submit("big-running", "mcv2", 3, 100.0).unwrap();
        let blocked = s.submit("blocked-head", "mcv2", 4, 10.0).unwrap(); // must wait for all 4
        let small = s.submit("small", "mcv2", 1, 5.0).unwrap();
        // head job can't start (needs 4, only 1 idle); small one can backfill
        // because it finishes (t=5) before the head's reservation (t=100)
        assert!(s.job(blocked).unwrap().is_pending());
        assert!(matches!(s.job(small).unwrap().state, JobState::Running { .. }));
        // a long small job must NOT backfill (would delay the head)
        let long_small = s.submit("long-small", "mcv2", 1, 500.0).unwrap();
        assert!(s.job(long_small).unwrap().is_pending());
        // head starts exactly when the big job drains
        s.advance_to(100.0);
        assert!(
            matches!(s.job(blocked).unwrap().state, JobState::Running { start } if start == 100.0)
        );
    }

    #[test]
    fn drain_completes_everything() {
        let mut s = two_partition_sched();
        for i in 0..6 {
            s.submit(&format!("j{i}"), "mcv1", 4, 10.0).unwrap();
        }
        let makespan = s.drain();
        assert!((makespan - 30.0).abs() < 1e-9, "{makespan}"); // 6 jobs, 2 at a time
        assert!(s.jobs.iter().all(|j| matches!(j.state, JobState::Completed { .. })));
    }

    #[test]
    fn submit_validates_partition_and_size() {
        let mut s = two_partition_sched();
        assert!(s.submit("x", "gpu", 1, 1.0).is_err());
        assert!(s.submit("x", "mcv2", 5, 1.0).is_err());
    }

    #[test]
    fn submit_errors_are_typed() {
        let mut s = two_partition_sched();
        match s.submit("x", "gpu", 1, 1.0) {
            Err(CimoneError::UnknownPartition(p)) => assert_eq!(p, "gpu"),
            other => panic!("expected UnknownPartition, got {other:?}"),
        }
        match s.submit("wide", "mcv2", 5, 1.0) {
            Err(CimoneError::PartitionTooSmall { job, partition, want, have }) => {
                assert_eq!((job.as_str(), partition.as_str(), want, have), ("wide", "mcv2", 5, 4));
            }
            other => panic!("expected PartitionTooSmall, got {other:?}"),
        }
    }

    #[test]
    fn invalid_runtimes_rejected_not_hung() {
        // inf would spin advance_to forever; <= 0 would rewind time
        let mut s = two_partition_sched();
        for bad in [0.0, -5.0, f64::INFINITY, f64::NAN] {
            assert!(
                matches!(
                    s.submit("bad", "mcv2", 1, bad),
                    Err(CimoneError::InvalidRuntime { .. })
                ),
                "runtime {bad} must be rejected"
            );
        }
        assert!(s.jobs.is_empty());
    }

    #[test]
    fn parallel_drain_matches_serial() {
        let submit_all = |s: &mut Scheduler| {
            // oversubscribe both partitions so queueing + backfill engage
            for i in 0..6 {
                s.submit(&format!("v1-{i}"), "mcv1", 4, 10.0 + i as f64).unwrap();
            }
            for i in 0..5 {
                s.submit(&format!("v2-{i}"), "mcv2", 3, 25.0 - 2.0 * i as f64).unwrap();
            }
            s.submit("v2-small", "mcv2", 1, 1.5).unwrap();
        };
        let mut serial = two_partition_sched();
        submit_all(&mut serial);
        let mut parallel = two_partition_sched();
        submit_all(&mut parallel);

        let m1 = serial.drain();
        let m2 = parallel.drain_parallel();
        assert_eq!(m1, m2, "makespan must be identical");
        assert_eq!(serial.jobs.len(), parallel.jobs.len());
        for (a, b) in serial.jobs.iter().zip(parallel.jobs.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.state, b.state, "job `{}` diverged", a.name);
            assert_eq!(a.allocated, b.allocated);
        }
    }

    #[test]
    fn parallel_drain_on_empty_queue_is_zero() {
        let mut s = two_partition_sched();
        assert_eq!(s.drain_parallel(), 0.0);
        assert!(s.jobs.is_empty());
        assert_eq!(s.partitions.len(), 2, "partitions must be restored");
    }

    #[test]
    fn scheduler_usable_after_parallel_drain() {
        let mut s = two_partition_sched();
        s.submit("a", "mcv2", 4, 10.0).unwrap();
        s.drain_parallel();
        // partitions and the id counter survive the split/merge round-trip
        let id = s.submit("b", "mcv1", 8, 5.0).unwrap();
        assert!(id > 1);
        assert!((s.drain_parallel() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn wait_times_accumulate_in_fifo_order() {
        let mut s = two_partition_sched();
        let a = s.submit("a", "mcv2", 4, 20.0).unwrap();
        let b = s.submit("b", "mcv2", 4, 20.0).unwrap();
        s.drain();
        assert_eq!(s.job(a).unwrap().wait_time(), Some(0.0));
        assert_eq!(s.job(b).unwrap().wait_time(), Some(20.0));
    }
}
