//! Event-driven FIFO + EASY-backfill scheduler over simulated time.
//!
//! Semantics match SLURM's default behaviour closely enough for the
//! experiments: arrived jobs are considered in queue order (priority
//! desc, arrival asc, submission order); the head-of-queue job reserves
//! the earliest time enough nodes free up; later jobs may backfill onto
//! idle nodes only if they finish no later than that reservation.
//!
//! Time is advanced by a binary-heap event queue keyed on **exact stored
//! times**: a job's end is computed once at start (`now + runtime`) and
//! every later comparison uses those bits verbatim — no epsilon scans, no
//! O(jobs) rescan per completion. This is what keeps week- and year-long
//! simulated horizons exact: an absolute `1e-9` tolerance is far below
//! the spacing of representable doubles near `1e9` seconds, so epsilon
//! matching silently changes behaviour with the magnitude of `now`.
//!
//! Besides completions the queue carries job arrivals (future
//! `submit_s`) and node availability windows
//! ([`Scheduler::schedule_outage`]): degraded-fleet experiments mark
//! nodes unavailable for `[down, up)` windows and the queue reschedules
//! around them, busy nodes draining gracefully.
//!
//! Partitions never share nodes, so their event streams are independent;
//! [`Scheduler::drain_parallel`] exploits this to drain each partition on
//! its own OS thread while producing bit-identical simulated-time
//! accounting to the serial [`Scheduler::drain`].

use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeMap, BinaryHeap};

use super::job::{Job, JobId, JobState};
use super::partition::Partition;
use crate::error::CimoneError;

/// A scheduler event: something that changes cluster or queue state at an
/// exact simulated time.
#[derive(Debug, Clone, Copy)]
enum EventKind {
    /// A running job (by index into `Scheduler::jobs`) reaches its stored
    /// end time and releases its nodes.
    Completion { job: usize },
    /// A downed node returns to service.
    NodeUp { node: usize },
    /// A node leaves service (graceful drain if currently busy).
    NodeDown { node: usize },
    /// A job (by index) enters the queue at its arrival time.
    Arrival { job: usize },
}

impl EventKind {
    /// Processing order within one instant: completions release nodes
    /// first, then availability changes, then arrivals — and a single
    /// scheduling pass runs after the whole batch.
    fn rank(&self) -> (u8, usize) {
        match *self {
            EventKind::Completion { job } => (0, job),
            EventKind::NodeUp { node } => (1, node),
            EventKind::NodeDown { node } => (2, node),
            EventKind::Arrival { job } => (3, job),
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // total_cmp gives a total order even for pathological floats, so
        // a poisoned time can never panic the heap
        self.time.total_cmp(&other.time).then_with(|| self.kind.rank().cmp(&other.kind.rank()))
    }
}

/// A job submission: resource request plus queue metadata. Defaults model
/// the legacy API (arrives now, priority 0, system user).
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub name: String,
    pub partition: String,
    pub nodes: usize,
    pub runtime_s: f64,
    /// Arrival time; `None` means "at the current simulated time".
    pub arrival_s: Option<f64>,
    pub priority: i64,
    pub user: String,
}

impl JobRequest {
    pub fn new(
        name: impl Into<String>,
        partition: impl Into<String>,
        nodes: usize,
        runtime_s: f64,
    ) -> JobRequest {
        JobRequest {
            name: name.into(),
            partition: partition.into(),
            nodes,
            runtime_s,
            arrival_s: None,
            priority: 0,
            user: String::new(),
        }
    }

    /// Set a (future) arrival time.
    pub fn arriving_at(mut self, t: f64) -> JobRequest {
        self.arrival_s = Some(t);
        self
    }

    pub fn with_priority(mut self, priority: i64) -> JobRequest {
        self.priority = priority;
        self
    }

    pub fn with_user(mut self, user: impl Into<String>) -> JobRequest {
        self.user = user.into();
        self
    }
}

/// Queue order: priority desc, then arrival asc, then submission order.
/// With default priorities and same-instant arrivals this degrades to
/// exact submission order, which is what keeps the paper campaign
/// bit-for-bit against the pre-event-queue scheduler.
fn queue_cmp(a: &Job, b: &Job) -> Ordering {
    b.priority
        .cmp(&a.priority)
        .then(a.submit_s.total_cmp(&b.submit_s))
        .then(a.id.cmp(&b.id))
}

/// The scheduler: owns partitions, the job queue, and the event queue.
pub struct Scheduler {
    pub partitions: BTreeMap<String, Partition>,
    pub jobs: Vec<Job>,
    pub now: f64,
    next_id: JobId,
    /// Min-heap of future events keyed on exact stored times.
    events: BinaryHeap<Reverse<Event>>,
    /// Indices of `Pending` jobs in queue order (see [`queue_cmp`]).
    pending: Vec<usize>,
    /// Running job indices per partition (for reservation lookups).
    running: BTreeMap<String, Vec<usize>>,
    /// Jobs not yet completed; lets `drain` stop without scanning.
    incomplete: usize,
}

impl Scheduler {
    pub fn new(partitions: Vec<Partition>) -> Scheduler {
        Scheduler::from_parts(partitions, Vec::new(), 0.0, 1, Vec::new())
    }

    /// Assemble a scheduler from parts, deriving the queue/event state
    /// from the job states. Used by [`new`](Self::new) and by the
    /// split/merge in [`drain_parallel`](Self::drain_parallel).
    fn from_parts(
        partitions: Vec<Partition>,
        jobs: Vec<Job>,
        now: f64,
        next_id: JobId,
        node_events: Vec<Event>,
    ) -> Scheduler {
        let mut s = Scheduler {
            partitions: partitions.into_iter().map(|p| (p.name.clone(), p)).collect(),
            jobs,
            now,
            next_id,
            events: BinaryHeap::new(),
            pending: Vec::new(),
            running: BTreeMap::new(),
            incomplete: 0,
        };
        s.rebuild_job_state();
        for ev in node_events {
            s.events.push(Reverse(ev));
        }
        s
    }

    /// Rebuild `events`/`pending`/`running`/`incomplete` from the job
    /// states. Job state is the single source of truth, so splitting or
    /// merging the queue cannot desynchronise the derived structures.
    fn rebuild_job_state(&mut self) {
        self.events.clear();
        self.pending.clear();
        self.running.clear();
        self.incomplete = 0;
        for idx in 0..self.jobs.len() {
            match self.jobs[idx].state {
                JobState::Pending => {
                    self.incomplete += 1;
                    self.pending.push(idx);
                    let arrival = self.jobs[idx].submit_s;
                    if arrival > self.now {
                        self.events
                            .push(Reverse(Event { time: arrival, kind: EventKind::Arrival { job: idx } }));
                    }
                }
                JobState::Running { end, .. } => {
                    self.incomplete += 1;
                    let part = self.jobs[idx].partition.clone();
                    self.running.entry(part).or_default().push(idx);
                    self.events
                        .push(Reverse(Event { time: end, kind: EventKind::Completion { job: idx } }));
                }
                JobState::Completed { .. } => {}
            }
        }
        let jobs = &self.jobs;
        self.pending.sort_by(|&a, &b| queue_cmp(&jobs[a], &jobs[b]));
    }

    /// Submit a job arriving at the current simulated time; returns its id.
    pub fn submit(
        &mut self,
        name: &str,
        partition: &str,
        nodes: usize,
        runtime_s: f64,
    ) -> Result<JobId, CimoneError> {
        self.submit_request(JobRequest::new(name, partition, nodes, runtime_s))
    }

    /// Submit a job with full queue metadata (arrival time, priority,
    /// owning user); returns its id.
    pub fn submit_request(&mut self, req: JobRequest) -> Result<JobId, CimoneError> {
        let have = match self.partitions.get(&req.partition) {
            Some(p) => p.size(),
            None => return Err(CimoneError::UnknownPartition(req.partition.clone())),
        };
        if req.nodes > have {
            return Err(CimoneError::PartitionTooSmall {
                job: req.name.clone(),
                partition: req.partition.clone(),
                want: req.nodes,
                have,
            });
        }
        // an infinite runtime would leave a completion event that never
        // fires; a non-positive one would rewind simulated time
        if !req.runtime_s.is_finite() || req.runtime_s <= 0.0 {
            return Err(CimoneError::InvalidRuntime {
                job: req.name.clone(),
                runtime_s: req.runtime_s,
            });
        }
        let arrival = req.arrival_s.unwrap_or(self.now);
        if !arrival.is_finite() || arrival < self.now {
            return Err(CimoneError::InvalidArrival { job: req.name.clone(), arrival_s: arrival });
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut job = Job::new(id, req.name, req.partition, req.nodes, req.runtime_s, arrival);
        job.priority = req.priority;
        job.user = req.user;
        let idx = self.jobs.len();
        self.jobs.push(job);
        self.incomplete += 1;
        self.insert_pending(idx);
        if arrival > self.now {
            self.events.push(Reverse(Event { time: arrival, kind: EventKind::Arrival { job: idx } }));
        } else {
            self.try_start();
        }
        Ok(id)
    }

    /// Insert a pending job index keeping `pending` in queue order.
    fn insert_pending(&mut self, idx: usize) {
        let jobs = &self.jobs;
        let pos = match self.pending.binary_search_by(|&i| queue_cmp(&jobs[i], &jobs[idx])) {
            Ok(p) | Err(p) => p,
        };
        self.pending.insert(pos, idx);
    }

    /// Take `node` out of service during `[down_at, up_at)`; `up_at =
    /// None` downs it for good. Boundaries at or before `now` apply
    /// immediately; future ones become events. A busy node drains
    /// gracefully: its running job finishes before the node goes down.
    pub fn schedule_outage(
        &mut self,
        node: usize,
        down_at: f64,
        up_at: Option<f64>,
    ) -> Result<(), CimoneError> {
        if !self.partitions.values().any(|p| p.contains(node)) {
            return Err(CimoneError::Spec(format!("outage references unknown node id {node}")));
        }
        if !down_at.is_finite() || down_at < 0.0 {
            return Err(CimoneError::Spec(format!(
                "outage down time must be finite and >= 0, got {down_at}"
            )));
        }
        if let Some(u) = up_at {
            if !u.is_finite() || u <= down_at {
                return Err(CimoneError::Spec(format!(
                    "outage up time must be finite and after its down time, got [{down_at}, {u})"
                )));
            }
        }
        let down = Event { time: down_at, kind: EventKind::NodeDown { node } };
        if down_at <= self.now {
            self.apply(down);
        } else {
            self.events.push(Reverse(down));
        }
        if let Some(u) = up_at {
            let up = Event { time: u, kind: EventKind::NodeUp { node } };
            if u <= self.now {
                self.apply(up);
            } else {
                self.events.push(Reverse(up));
            }
        }
        self.try_start();
        Ok(())
    }

    /// Earliest time `want` nodes will be free in `partition`, given the
    /// currently running jobs (the head job's EASY-backfill reservation).
    /// Draining nodes never return to the pool, so they do not count; a
    /// head that cannot be satisfied by running-job releases (e.g. during
    /// an outage window) gets an infinite reservation and waits for the
    /// next availability event.
    fn reservation_time(&self, partition: &str, want: usize) -> f64 {
        let part = &self.partitions[partition];
        let mut idle = part.idle_count();
        if idle >= want {
            return self.now;
        }
        // accumulate releases in stored-end order
        let mut ends: Vec<(f64, usize)> = self
            .running
            .get(partition)
            .map(|idxs| {
                idxs.iter()
                    .map(|&i| {
                        let j = &self.jobs[i];
                        let end = match j.state {
                            JobState::Running { end, .. } => end,
                            _ => unreachable!("running set holds only running jobs"),
                        };
                        (end, part.returning_count(&j.allocated))
                    })
                    .collect()
            })
            .unwrap_or_default();
        ends.sort_by(|a, b| a.0.total_cmp(&b.0));
        for (end, nodes) in ends {
            idle += nodes;
            if idle >= want {
                return end;
            }
        }
        f64::INFINITY
    }

    /// Start every job that can start right now: FIFO head first, then
    /// EASY backfill (later jobs may jump the queue only if they finish
    /// no later than the head job's reservation time — exactly, with no
    /// slack: a backfill ending any amount past the reservation would
    /// delay the head).
    fn try_start(&mut self) {
        // per-partition head-of-line reservation: (partition, reserved time)
        let mut hol: BTreeMap<String, f64> = BTreeMap::new();
        let now = self.now;
        let mut i = 0;
        while i < self.pending.len() {
            let idx = self.pending[i];
            if self.jobs[idx].submit_s > now {
                // not yet arrived: invisible to the queue until its
                // arrival event fires
                i += 1;
                continue;
            }
            let (part_name, want, runtime) = {
                let j = &self.jobs[idx];
                (j.partition.clone(), j.nodes, j.runtime_s)
            };
            let head_reservation = hol.get(&part_name).copied();
            let idle = self.partitions[&part_name].idle_count();
            let can_start = match head_reservation {
                None => idle >= want,
                // backfill window: must complete by the head's start
                Some(t_res) => idle >= want && now + runtime <= t_res,
            };
            if can_start {
                let alloc = {
                    let part = self.partitions.get_mut(&part_name).unwrap();
                    part.allocate(want).expect("idle_count said yes")
                };
                let end = now + runtime;
                {
                    let job = &mut self.jobs[idx];
                    job.allocated = alloc;
                    job.state = JobState::Running { start: now, end };
                }
                self.events.push(Reverse(Event { time: end, kind: EventKind::Completion { job: idx } }));
                self.running.entry(part_name).or_default().push(idx);
                self.pending.remove(i);
            } else {
                if head_reservation.is_none() {
                    let t = self.reservation_time(&part_name, want);
                    hol.insert(part_name, t);
                }
                i += 1;
            }
        }
    }

    /// Apply one event's state change (no scheduling pass; the caller
    /// runs [`try_start`](Self::try_start) once per same-instant batch).
    fn apply(&mut self, ev: Event) {
        match ev.kind {
            EventKind::Completion { job: idx } => {
                let (part, alloc) = {
                    let j = &mut self.jobs[idx];
                    match j.state {
                        JobState::Running { start, end } => {
                            j.state = JobState::Completed { start, end };
                        }
                        _ => unreachable!("completion event for a non-running job"),
                    }
                    (j.partition.clone(), j.allocated.clone())
                };
                self.partitions.get_mut(&part).unwrap().release(&alloc);
                if let Some(v) = self.running.get_mut(&part) {
                    if let Some(pos) = v.iter().position(|&i| i == idx) {
                        v.swap_remove(pos);
                    }
                }
                self.incomplete -= 1;
            }
            EventKind::NodeUp { node } => {
                for p in self.partitions.values_mut() {
                    if p.mark_up(node) {
                        break;
                    }
                }
            }
            EventKind::NodeDown { node } => {
                for p in self.partitions.values_mut() {
                    if p.request_down(node) {
                        break;
                    }
                }
            }
            // the job is pending with submit_s == now; the batch's
            // try_start pass will consider it
            EventKind::Arrival { .. } => {}
        }
    }

    /// Advance simulated time to `t`, firing every event up to and
    /// including `t`. Events at one instant (exact bit-equal times) are
    /// applied as a batch — completions first, then availability
    /// changes, then arrivals — followed by a single scheduling pass.
    pub fn advance_to(&mut self, t: f64) {
        assert!(t >= self.now);
        while let Some(&Reverse(ev)) = self.events.peek() {
            if ev.time > t {
                break;
            }
            let t_ev = ev.time;
            debug_assert!(t_ev >= self.now, "event queue may not rewind time");
            self.now = t_ev;
            while let Some(&Reverse(next)) = self.events.peek() {
                // exact equality: times are stored once, never recomputed
                if next.time != t_ev {
                    break;
                }
                let Reverse(next) = self.events.pop().unwrap();
                self.apply(next);
            }
            self.try_start();
        }
        self.now = t;
    }

    /// Run until every job completes (or nothing further can complete);
    /// returns the makespan.
    pub fn drain(&mut self) -> f64 {
        while self.incomplete > 0 {
            let Some(&Reverse(ev)) = self.events.peek() else {
                // pending jobs that no remaining event can unblock (e.g.
                // nodes downed for good): stop, leaving them pending
                break;
            };
            let t = ev.time;
            self.advance_to(t);
        }
        self.now
    }

    /// Drain every partition concurrently, one OS thread per partition.
    ///
    /// Correctness relies on partitions being disjoint node sets: a job's
    /// start/backfill decisions depend only on its own partition's state
    /// and on the relative queue order within that partition, both of
    /// which are preserved when the queue is split. The result — per-job
    /// start/end times and the overall makespan — is identical to the
    /// serial [`drain`](Self::drain): with event times stored exactly,
    /// neither path has any cross-partition tie epsilon to disagree on.
    pub fn drain_parallel(&mut self) -> f64 {
        if self.partitions.len() <= 1 {
            return self.drain();
        }
        let start_now = self.now;
        let partitions = std::mem::take(&mut self.partitions);
        let all_jobs = std::mem::take(&mut self.jobs);
        let events = std::mem::take(&mut self.events);
        self.pending.clear();
        self.running.clear();
        self.incomplete = 0;

        let mut by_part: BTreeMap<String, Vec<Job>> = BTreeMap::new();
        for job in all_jobs {
            by_part.entry(job.partition.clone()).or_default().push(job);
        }
        // node availability events follow the partition owning the node;
        // job events are rebuilt per sub-scheduler from job state
        let mut node_events: BTreeMap<String, Vec<Event>> = BTreeMap::new();
        for Reverse(ev) in events.into_vec() {
            if let EventKind::NodeUp { node } | EventKind::NodeDown { node } = ev.kind {
                if let Some(p) = partitions.values().find(|p| p.contains(node)) {
                    node_events.entry(p.name.clone()).or_default().push(ev);
                }
            }
        }
        let next_id = self.next_id;
        let mut subs: Vec<Scheduler> = partitions
            .into_iter()
            .map(|(name, part)| {
                let jobs = by_part.remove(&name).unwrap_or_default();
                let evs = node_events.remove(&name).unwrap_or_default();
                Scheduler::from_parts(vec![part], jobs, start_now, next_id, evs)
            })
            .collect();

        // the scope joins every spawned thread on exit and propagates
        // any panic, so no explicit join bookkeeping is needed
        std::thread::scope(|scope| {
            for sub in subs.iter_mut() {
                let _ = scope.spawn(move || sub.drain());
            }
        });

        let mut makespan = start_now;
        let mut leftover: Vec<Event> = Vec::new();
        for sub in subs {
            let Scheduler { partitions: sub_parts, jobs: sub_jobs, events: sub_events, now, .. } =
                sub;
            makespan = makespan.max(now);
            self.partitions.extend(sub_parts);
            self.jobs.extend(sub_jobs);
            for Reverse(ev) in sub_events.into_vec() {
                if matches!(ev.kind, EventKind::NodeUp { .. } | EventKind::NodeDown { .. }) {
                    leftover.push(ev);
                }
            }
        }
        self.jobs.sort_by_key(|j| j.id);
        self.now = makespan;
        self.rebuild_job_state();
        // sub-schedulers stop at their last completion, so an availability
        // boundary may still lie at or before the merged makespan
        for ev in leftover {
            if ev.time <= self.now {
                self.apply(ev);
            } else {
                self.events.push(Reverse(ev));
            }
        }
        makespan
    }

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.iter().find(|j| j.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_partition_sched() -> Scheduler {
        Scheduler::new(vec![
            Partition::new("mcv1", (0..8).collect()),
            Partition::new("mcv2", (8..12).collect()),
        ])
    }

    #[test]
    fn fifo_runs_immediately_when_idle() {
        let mut s = two_partition_sched();
        let id = s.submit("hpl", "mcv2", 2, 100.0).unwrap();
        assert!(matches!(s.job(id).unwrap().state, JobState::Running { .. }));
    }

    #[test]
    fn queues_when_full_then_starts() {
        let mut s = two_partition_sched();
        let a = s.submit("a", "mcv2", 4, 50.0).unwrap();
        let b = s.submit("b", "mcv2", 4, 50.0).unwrap();
        assert!(s.job(b).unwrap().is_pending());
        s.advance_to(50.0);
        assert!(
            matches!(s.job(b).unwrap().state, JobState::Running { start, .. } if start == 50.0)
        );
        assert!(matches!(s.job(a).unwrap().state, JobState::Completed { .. }));
    }

    #[test]
    fn backfill_small_job_jumps_queue_safely() {
        let mut s = two_partition_sched();
        s.submit("big-running", "mcv2", 3, 100.0).unwrap();
        let blocked = s.submit("blocked-head", "mcv2", 4, 10.0).unwrap(); // must wait for all 4
        let small = s.submit("small", "mcv2", 1, 5.0).unwrap();
        // head job can't start (needs 4, only 1 idle); small one can backfill
        // because it finishes (t=5) before the head's reservation (t=100)
        assert!(s.job(blocked).unwrap().is_pending());
        assert!(matches!(s.job(small).unwrap().state, JobState::Running { .. }));
        // a long small job must NOT backfill (would delay the head)
        let long_small = s.submit("long-small", "mcv2", 1, 500.0).unwrap();
        assert!(s.job(long_small).unwrap().is_pending());
        // head starts exactly when the big job drains
        s.advance_to(100.0);
        assert!(
            matches!(s.job(blocked).unwrap().state, JobState::Running { start, .. } if start == 100.0)
        );
    }

    #[test]
    fn backfill_ending_exactly_at_reservation_is_safe() {
        let mut s = two_partition_sched();
        s.submit("wall", "mcv2", 3, 100.0).unwrap(); // 3 of 4 busy until t=100
        let head = s.submit("head", "mcv2", 4, 10.0).unwrap(); // reserves t=100
        // regression: under the old `+ 1e-9` slack this job backfilled and
        // pushed the head's start past its reservation
        let over = s.submit("over-by-epsilon", "mcv2", 1, 100.0 + 1e-10).unwrap();
        assert!(
            s.job(over).unwrap().is_pending(),
            "a backfill ending past the reservation must not start"
        );
        // ending *exactly* at the reservation is safe: it releases its
        // node at the same instant the head starts
        let exact = s.submit("exact-fit", "mcv2", 1, 100.0).unwrap();
        assert!(matches!(s.job(exact).unwrap().state, JobState::Running { .. }));
        s.drain();
        assert_eq!(s.job(exact).unwrap().end_time(), Some(100.0));
        assert_eq!(s.job(head).unwrap().wait_time(), Some(100.0), "head must start at exactly 100");
        // the shut-out backfill runs after the head
        assert!(
            matches!(s.job(over).unwrap().state, JobState::Completed { start, .. } if start == 110.0)
        );
    }

    #[test]
    fn completions_match_exactly_at_large_times() {
        // regression for the old epsilon completion scan: at simulated
        // times past 1e9 s an absolute 1e-9 tolerance is below one ULP,
        // so behaviour silently depended on the magnitude of `now`;
        // stored ends make completion matching exact at every scale
        let mut s = two_partition_sched();
        let era = 3.0e9; // ~95 simulated years
        let a = s.submit("era", "mcv2", 4, era).unwrap();
        let b = s.submit("b", "mcv2", 2, 10.0).unwrap();
        let c = s.submit("c", "mcv2", 2, 10.5).unwrap();
        let makespan = s.drain();
        assert_eq!(s.job(a).unwrap().end_time(), Some(era));
        assert_eq!(s.job(b).unwrap().state, JobState::Completed { start: era, end: era + 10.0 });
        assert_eq!(s.job(c).unwrap().state, JobState::Completed { start: era, end: era + 10.5 });
        assert_eq!(makespan, era + 10.5);
    }

    #[test]
    fn near_coincident_completions_keep_exact_distinct_ends() {
        // the old scan snapped completions within 1e-9 onto one instant,
        // recording the wrong end for the later job
        let mut s = two_partition_sched();
        let a = s.submit("a", "mcv1", 4, 10.0).unwrap();
        let b = s.submit("b", "mcv1", 4, 10.0 + 1e-10).unwrap();
        s.drain();
        assert_eq!(s.job(a).unwrap().end_time(), Some(10.0));
        assert_eq!(s.job(b).unwrap().end_time(), Some(10.0 + 1e-10));
    }

    #[test]
    fn pathological_queue_cannot_panic_drain() {
        // runtimes spanning 24 orders of magnitude: every comparison goes
        // through total_cmp (events, reservations), so the drain orders
        // them without panicking
        let mut s = two_partition_sched();
        for (i, rt) in [1e-12, 1e12, 5e-7, 3.5, 1e9, 2.0e-3].iter().enumerate() {
            s.submit(&format!("p{i}"), "mcv2", 1 + i % 4, *rt).unwrap();
        }
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0] {
            assert!(s.submit("bad", "mcv2", 1, bad).is_err());
        }
        let makespan = s.drain_parallel();
        assert!(makespan.is_finite());
        assert!(s.jobs.iter().all(|j| matches!(j.state, JobState::Completed { .. })));
    }

    #[test]
    fn future_arrivals_enter_queue_at_their_time() {
        let mut s = two_partition_sched();
        let a = s
            .submit_request(JobRequest::new("later", "mcv2", 2, 10.0).arriving_at(50.0))
            .unwrap();
        assert!(s.job(a).unwrap().is_pending());
        s.advance_to(49.0);
        assert!(s.job(a).unwrap().is_pending(), "must not start before it arrives");
        s.advance_to(50.0);
        assert!(
            matches!(s.job(a).unwrap().state, JobState::Running { start, .. } if start == 50.0)
        );
        // waits count from arrival, not from the submit call
        assert_eq!(s.job(a).unwrap().wait_time(), Some(0.0));
        // arrivals in the past are rejected
        assert!(matches!(
            s.submit_request(JobRequest::new("late", "mcv2", 1, 1.0).arriving_at(10.0)),
            Err(CimoneError::InvalidArrival { .. })
        ));
    }

    #[test]
    fn higher_priority_jobs_jump_the_queue() {
        let mut s = two_partition_sched();
        s.submit("occupier", "mcv2", 4, 10.0).unwrap();
        let lo = s.submit_request(JobRequest::new("lo", "mcv2", 4, 10.0)).unwrap();
        let hi = s
            .submit_request(JobRequest::new("hi", "mcv2", 4, 10.0).with_priority(10).with_user("root"))
            .unwrap();
        s.drain();
        assert_eq!(s.job(hi).unwrap().wait_time(), Some(10.0), "high priority runs first");
        assert_eq!(s.job(lo).unwrap().wait_time(), Some(20.0));
        assert_eq!(s.job(hi).unwrap().user, "root");
    }

    #[test]
    fn drain_completes_everything() {
        let mut s = two_partition_sched();
        for i in 0..6 {
            s.submit(&format!("j{i}"), "mcv1", 4, 10.0).unwrap();
        }
        let makespan = s.drain();
        assert!((makespan - 30.0).abs() < 1e-9, "{makespan}"); // 6 jobs, 2 at a time
        assert!(s.jobs.iter().all(|j| matches!(j.state, JobState::Completed { .. })));
    }

    #[test]
    fn submit_validates_partition_and_size() {
        let mut s = two_partition_sched();
        assert!(s.submit("x", "gpu", 1, 1.0).is_err());
        assert!(s.submit("x", "mcv2", 5, 1.0).is_err());
    }

    #[test]
    fn submit_errors_are_typed() {
        let mut s = two_partition_sched();
        match s.submit("x", "gpu", 1, 1.0) {
            Err(CimoneError::UnknownPartition(p)) => assert_eq!(p, "gpu"),
            other => panic!("expected UnknownPartition, got {other:?}"),
        }
        match s.submit("wide", "mcv2", 5, 1.0) {
            Err(CimoneError::PartitionTooSmall { job, partition, want, have }) => {
                assert_eq!((job.as_str(), partition.as_str(), want, have), ("wide", "mcv2", 5, 4));
            }
            other => panic!("expected PartitionTooSmall, got {other:?}"),
        }
    }

    #[test]
    fn invalid_runtimes_rejected_not_hung() {
        // inf would leave a completion event that never fires; <= 0 would
        // rewind time
        let mut s = two_partition_sched();
        for bad in [0.0, -5.0, f64::INFINITY, f64::NAN] {
            assert!(
                matches!(
                    s.submit("bad", "mcv2", 1, bad),
                    Err(CimoneError::InvalidRuntime { .. })
                ),
                "runtime {bad} must be rejected"
            );
        }
        assert!(s.jobs.is_empty());
    }

    #[test]
    fn parallel_drain_matches_serial() {
        let submit_all = |s: &mut Scheduler| {
            // oversubscribe both partitions so queueing + backfill engage
            for i in 0..6 {
                s.submit(&format!("v1-{i}"), "mcv1", 4, 10.0 + i as f64).unwrap();
            }
            for i in 0..5 {
                s.submit(&format!("v2-{i}"), "mcv2", 3, 25.0 - 2.0 * i as f64).unwrap();
            }
            s.submit("v2-small", "mcv2", 1, 1.5).unwrap();
        };
        let mut serial = two_partition_sched();
        submit_all(&mut serial);
        let mut parallel = two_partition_sched();
        submit_all(&mut parallel);

        let m1 = serial.drain();
        let m2 = parallel.drain_parallel();
        assert_eq!(m1, m2, "makespan must be identical");
        assert_eq!(serial.jobs.len(), parallel.jobs.len());
        for (a, b) in serial.jobs.iter().zip(parallel.jobs.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.state, b.state, "job `{}` diverged", a.name);
            assert_eq!(a.allocated, b.allocated);
        }
    }

    #[test]
    fn parallel_drain_on_empty_queue_is_zero() {
        let mut s = two_partition_sched();
        assert_eq!(s.drain_parallel(), 0.0);
        assert!(s.jobs.is_empty());
        assert_eq!(s.partitions.len(), 2, "partitions must be restored");
    }

    #[test]
    fn scheduler_usable_after_parallel_drain() {
        let mut s = two_partition_sched();
        s.submit("a", "mcv2", 4, 10.0).unwrap();
        s.drain_parallel();
        // partitions and the id counter survive the split/merge round-trip
        let id = s.submit("b", "mcv1", 8, 5.0).unwrap();
        assert!(id > 1);
        assert!((s.drain_parallel() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn wait_times_accumulate_in_fifo_order() {
        let mut s = two_partition_sched();
        let a = s.submit("a", "mcv2", 4, 20.0).unwrap();
        let b = s.submit("b", "mcv2", 4, 20.0).unwrap();
        s.drain();
        assert_eq!(s.job(a).unwrap().wait_time(), Some(0.0));
        assert_eq!(s.job(b).unwrap().wait_time(), Some(20.0));
    }

    #[test]
    fn immediate_outage_shrinks_schedulable_size() {
        let mut s = two_partition_sched();
        s.schedule_outage(11, 0.0, None).unwrap();
        assert!(s.submit("wide", "mcv2", 4, 1.0).is_err(), "only 3 nodes remain up");
        assert!(s.submit("fits", "mcv2", 3, 1.0).is_ok());
        // unknown nodes and inverted windows are typed spec errors
        assert!(s.schedule_outage(99, 0.0, None).is_err());
        assert!(s.schedule_outage(8, 5.0, Some(5.0)).is_err());
    }

    #[test]
    fn outage_window_reroutes_jobs_and_restores_capacity() {
        let mut s = two_partition_sched();
        // take half of mcv1 out during [5, 30)
        for n in 4..8 {
            s.schedule_outage(n, 5.0, Some(30.0)).unwrap();
        }
        s.submit("a", "mcv1", 4, 10.0).unwrap();
        let wide = s.submit("wide", "mcv1", 8, 10.0).unwrap();
        let makespan = s.drain();
        // the wide job needs every node: it must wait out the window
        assert!(
            matches!(s.job(wide).unwrap().state, JobState::Completed { start, .. } if start == 30.0)
        );
        assert_eq!(makespan, 40.0);
    }

    #[test]
    fn outage_on_busy_node_drains_gracefully() {
        let mut s = two_partition_sched();
        let a = s.submit("a", "mcv2", 4, 10.0).unwrap();
        // node 8 is busy with `a`: the outage lets the job finish first
        s.schedule_outage(8, 2.0, None).unwrap();
        let b = s.submit("b", "mcv2", 4, 5.0).unwrap();
        let makespan = s.drain();
        assert_eq!(s.job(a).unwrap().end_time(), Some(10.0), "running job is not preempted");
        assert_eq!(makespan, 10.0);
        // with node 8 gone for good, the 4-wide follow-up can never run
        assert!(s.job(b).unwrap().is_pending());
        assert_eq!(s.partitions["mcv2"].size(), 3);
    }
}
