//! STREAM sweep harness: measures the real kernels on this host AND
//! projects the RISC-V targets through the DDR model — the two columns
//! every Fig 3 row needs.

use std::time::Instant;

use super::kernels;
use crate::arch::soc::SocDescriptor;
use crate::error::CimoneError;
use crate::mem::stream_model::{predict_node_bandwidth, KERNEL_FACTORS};

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Elements per array (stream.c default scale: >= 4x LLC).
    pub n: usize,
    /// Repetitions; best-of like stream.c.
    pub reps: usize,
    /// Thread counts to report (the projection's x-axis).
    pub thread_counts: Vec<usize>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig { n: 1 << 22, reps: 3, thread_counts: vec![1, 2, 4, 8, 16, 32, 64, 128] }
    }
}

/// One kernel's outcome.
#[derive(Debug, Clone)]
pub struct KernelResult {
    pub kernel: &'static str,
    pub host_bytes_per_sec: f64,
    /// projected (threads, bytes/s) series for the target node
    pub projected: Vec<(usize, f64)>,
}

impl KernelResult {
    /// Projected bandwidth (bytes/s) at `threads`. The sweep only runs
    /// the thread counts its config lists, so an absent count is a typed
    /// [`CimoneError::NoProjection`], not a panic.
    pub fn projected_at(&self, threads: usize) -> Result<f64, CimoneError> {
        self.projected
            .iter()
            .find(|(t, _)| *t == threads)
            .map(|(_, bw)| *bw)
            .ok_or_else(|| CimoneError::NoProjection {
                kernel: self.kernel.to_string(),
                threads,
                available: self
                    .projected
                    .iter()
                    .map(|(t, _)| t.to_string())
                    .collect::<Vec<_>>()
                    .join(", "),
            })
    }
}

/// Full report.
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub results: Vec<KernelResult>,
    pub validated: bool,
}

/// Measure host bandwidth of one kernel (best of `reps`).
fn measure_host(kernel: &'static str, n: usize, reps: usize) -> f64 {
    let a = vec![1.0_f64; n];
    let b = vec![2.0_f64; n];
    let mut out = vec![0.0_f64; n];
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Instant::now();
        match kernel {
            "copy" => kernels::copy(&mut out, &a),
            "scale" => kernels::scale(&mut out, &a),
            "add" => kernels::add(&mut out, &a, &b),
            "triad" => kernels::triad(&mut out, &a, &b),
            _ => unreachable!(),
        }
        best = best.min(t.elapsed().as_secs_f64());
        std::hint::black_box(&out);
    }
    kernels::bytes_per_elem(kernel) as f64 * n as f64 / best
}

/// Run the sweep for a target node descriptor.
pub fn run_sweep(cfg: &StreamConfig, target: &SocDescriptor) -> StreamReport {
    let validated = kernels::validate_kernels(4096).is_ok();
    let results = KERNEL_FACTORS
        .iter()
        .map(|&(kernel, factor)| {
            let kernel: &'static str = kernel;
            let host = measure_host(kernel, cfg.n, cfg.reps);
            let projected = cfg
                .thread_counts
                .iter()
                .map(|&t| (t, predict_node_bandwidth(target, t, true) * factor))
                .collect();
            KernelResult { kernel, host_bytes_per_sec: host, projected }
        })
        .collect();
    StreamReport { results, validated }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    fn tiny() -> StreamConfig {
        StreamConfig { n: 1 << 14, reps: 1, thread_counts: vec![1, 64] }
    }

    #[test]
    fn sweep_produces_all_kernels() {
        let r = run_sweep(&tiny(), &presets::sg2042());
        assert!(r.validated);
        assert_eq!(r.results.len(), 4);
        for k in &r.results {
            assert!(k.host_bytes_per_sec > 0.0);
            assert_eq!(k.projected.len(), 2);
        }
    }

    #[test]
    fn projection_hits_paper_number_at_64_threads() -> Result<(), CimoneError> {
        let r = run_sweep(&tiny(), &presets::sg2042());
        // `?` through the typed NoProjection path PR 3 introduced
        let at64 = r.results[0].projected_at(64)?;
        assert!((at64 - 41.9e9).abs() < 1e9, "{at64}");
        Ok(())
    }

    #[test]
    fn missing_thread_count_is_a_typed_error_not_a_panic() {
        let r = run_sweep(&tiny(), &presets::sg2042());
        match r.results[0].projected_at(7) {
            Err(CimoneError::NoProjection { kernel, threads, available }) => {
                assert_eq!(kernel, "copy");
                assert_eq!(threads, 7);
                // the error names what the sweep did run
                assert_eq!(available, "1, 64");
            }
            other => panic!("expected NoProjection, got {other:?}"),
        }
    }

    #[test]
    fn triad_projects_slightly_above_copy() {
        let r = run_sweep(&tiny(), &presets::sg2042());
        let copy = r.results[0].projected[1].1;
        let triad = r.results[3].projected[1].1;
        assert!(triad > copy);
    }
}
