//! The four STREAM kernels, exactly as stream.c defines them
//! (FP64, q = 3.0), plus the validation pass stream.c performs.

use crate::error::CimoneError;

pub const Q: f64 = 3.0;

/// c[i] = a[i]
pub fn copy(c: &mut [f64], a: &[f64]) {
    assert_eq!(c.len(), a.len());
    c.copy_from_slice(a);
}

/// b[i] = q * c[i]
pub fn scale(b: &mut [f64], c: &[f64]) {
    assert_eq!(b.len(), c.len());
    for (bo, ci) in b.iter_mut().zip(c) {
        *bo = Q * ci;
    }
}

/// c[i] = a[i] + b[i]
pub fn add(c: &mut [f64], a: &[f64], b: &[f64]) {
    assert_eq!(c.len(), a.len());
    for ((co, ai), bi) in c.iter_mut().zip(a).zip(b) {
        *co = ai + bi;
    }
}

/// a[i] = b[i] + q * c[i]
pub fn triad(a: &mut [f64], b: &[f64], c: &[f64]) {
    assert_eq!(a.len(), b.len());
    for ((ao, bi), ci) in a.iter_mut().zip(b).zip(c) {
        *ao = bi + Q * ci;
    }
}

/// Bytes moved per element per kernel (copy/scale 16, add/triad 24) —
/// STREAM's own accounting.
pub fn bytes_per_elem(kernel: &str) -> u64 {
    match kernel {
        "copy" | "scale" => 16,
        "add" | "triad" => 24,
        other => panic!("unknown STREAM kernel {other}"),
    }
}

/// stream.c's end-of-run validation: run the canonical sequence from the
/// canonical initial values and check the final arrays.
pub fn validate_kernels(n: usize) -> Result<(), CimoneError> {
    let mut a = vec![1.0; n];
    let mut b = vec![2.0; n];
    let mut c = vec![0.0; n];
    // the canonical iteration: copy, scale, add, triad
    copy(&mut c, &a);
    scale(&mut b, &c);
    let a_snapshot = a.clone();
    add(&mut c, &a_snapshot, &b);
    triad(&mut a, &b, &c);
    // expected: c0=1, b=3, c=1+3=4, a=3+3*4=15
    for (i, (&ai, (&bi, &ci))) in a.iter().zip(b.iter().zip(c.iter())).enumerate() {
        if (ai - 15.0).abs() > 1e-13 || (bi - 3.0).abs() > 1e-13 || (ci - 4.0).abs() > 1e-13 {
            return Err(CimoneError::StreamValidation { index: i, a: ai, b: bi, c: ci });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_sequence_validates() {
        validate_kernels(1024).unwrap();
    }

    #[test]
    fn triad_formula() {
        let mut a = vec![0.0; 4];
        triad(&mut a, &[1.0, 2.0, 3.0, 4.0], &[10.0, 20.0, 30.0, 40.0]);
        assert_eq!(a, vec![31.0, 62.0, 93.0, 124.0]);
    }

    #[test]
    fn add_formula() {
        let mut c = vec![0.0; 2];
        add(&mut c, &[1.5, 2.5], &[0.5, 0.5]);
        assert_eq!(c, vec![2.0, 3.0]);
    }

    #[test]
    fn byte_accounting_matches_stream_c() {
        assert_eq!(bytes_per_elem("copy"), 16);
        assert_eq!(bytes_per_elem("triad"), 24);
    }

    #[test]
    #[should_panic]
    fn unknown_kernel_panics() {
        bytes_per_elem("saxpy");
    }
}
