//! STREAM — McCalpin's memory-bandwidth benchmark (Fig 3).
//!
//! [`kernels`] are the real four loops (native Rust; the PJRT-artifact
//! variants live in [`crate::runtime::stream`]); [`harness`] runs the
//! sweep and combines measured host behaviour with the DDR model's
//! RISC-V-target projection.

pub mod harness;
pub mod kernels;

pub use harness::{run_sweep, StreamConfig, StreamReport};
pub use kernels::{add, copy, scale, triad, validate_kernels};
