//! LMUL ablation: the paper jumps from LMUL=1 to LMUL=4 — this module
//! fills in the design space (M1/M2/M4 and the *infeasible* M8) so the
//! ablation bench can show WHY 4 is the right grouping for an 8-row
//! micro-tile on VLEN=128:
//!
//! - LMUL=1: 4 loads + 4 FMAs per column (Fig 2a, BLIS's shipped kernel);
//! - LMUL=2: 2 + 2 — halves the instruction count;
//! - LMUL=4: 1 + 1 — one register group IS the column (Fig 2b, the paper);
//! - LMUL=8: the column only fills half a group, and the four C-column
//!   accumulator groups alone need all 32 registers — the kernel cannot
//!   be register-allocated. `grouped_program` still emits it so tests can
//!   show validation rejecting it (the paper's implicit reason for
//!   stopping at 4).

use super::layout::PanelLayout;
use crate::isa::inst::{Dialect, Inst, Program};
use crate::isa::rvv::{Lmul, Sew, VType};

pub const MR: usize = 8;
pub const NR: usize = 4;
/// FP64 lanes per register at VLEN=128.
const LANES: usize = 2;

/// Emit the grouped micro-kernel for an arbitrary LMUL.
///
/// Register map generalizes blis_lmul1/blis_lmul4: C column j occupies the
/// group starting at `j * regs_per_col`, the A column lives at v16 (or the
/// first group boundary past the accumulators).
pub fn grouped_program(lmul: Lmul, l: PanelLayout) -> Program {
    assert_eq!((l.mr, l.nr), (MR, NR));
    let group = lmul.multiplier();
    let elems_per_group = group * LANES;
    // how many architectural registers one 8-element column needs
    let regs_per_col = MR.div_ceil(elems_per_group) * group;
    let ops_per_col = MR.div_ceil(elems_per_group);
    let a_base = ((NR * regs_per_col).div_ceil(group) * group).max(16) as u8;

    let mut p = Program::new(Dialect::Rvv10);
    let mut vt = VType::new(Sew::E64, lmul);
    vt.tail_agnostic = true;
    vt.mask_agnostic = true;
    p.push(Inst::Vsetvli { avl: elems_per_group.min(MR), vtype: vt });

    for j in 0..NR {
        for r in 0..ops_per_col {
            p.push(Inst::Vle {
                sew: Sew::E64,
                vd: (j * regs_per_col + r * group) as u8,
                addr: l.c_offset(j) + r * elems_per_group,
            });
        }
    }
    for k in 0..l.kc {
        for r in 0..ops_per_col {
            p.push(Inst::Vle {
                sew: Sew::E64,
                vd: a_base + (r * group) as u8,
                addr: l.a_offset(k) + r * elems_per_group,
            });
        }
        for j in 0..NR {
            p.push(Inst::Fld { fd: j as u8, addr: l.b_offset(k) + j });
            for r in 0..ops_per_col {
                p.push(Inst::VfmaccVf {
                    vd: (j * regs_per_col + r * group) as u8,
                    fs: j as u8,
                    vs2: a_base + (r * group) as u8,
                });
            }
        }
        p.push(Inst::Addi);
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
    }
    for j in 0..NR {
        for r in 0..ops_per_col {
            p.push(Inst::Vse {
                sew: Sew::E64,
                vs: (j * regs_per_col + r * group) as u8,
                addr: l.c_offset(j) + r * elems_per_group,
            });
        }
    }
    p
}

/// Is this LMUL register-allocatable for the 8x4 kernel on a 32-register
/// file? (The constraint that stops the paper at LMUL=4.)
pub fn feasible(lmul: Lmul) -> bool {
    let group = lmul.multiplier();
    let elems_per_group = group * LANES;
    let regs_per_col = MR.div_ceil(elems_per_group) * group;
    let a_regs = MR.div_ceil(elems_per_group) * group;
    NR * regs_per_col + a_regs <= 32 - group // leave one group of headroom
}

/// Ablation row: cycles/k-step and instructions/k-step for one LMUL.
pub fn analyze_lmul(lmul: Lmul, kc: usize, core: &crate::arch::soc::CoreModel) -> (f64, f64) {
    let p = grouped_program(lmul, PanelLayout::new(MR, NR, kc));
    let t = crate::isa::timing::CycleModel::new(core).analyze(&p);
    (t.insts as f64 / kc as f64, t.cycles / kc as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::c920;
    use crate::isa::exec::VecMachine;
    use crate::util::Matrix;

    fn run_numeric(lmul: Lmul, kc: usize) -> Matrix {
        let l = PanelLayout::new(MR, NR, kc);
        let p = grouped_program(lmul, l);
        let a = Matrix::random_hpl(MR, kc, 1);
        let b = Matrix::random_hpl(kc, NR, 2);
        let c = Matrix::random_hpl(MR, NR, 3);
        let mut m = VecMachine::new(128, l.mem_words());
        m.mem = l.pack(&a, &b, &c);
        m.run(&p).unwrap();
        l.unpack_c(&m.mem)
    }

    #[test]
    fn m1_m2_m4_all_compute_the_same_tile() {
        let want = run_numeric(Lmul::M1, 16);
        for lmul in [Lmul::M2, Lmul::M4] {
            let got = run_numeric(lmul, 16);
            assert!(got.allclose(&want, 0.0, 0.0), "{lmul:?}");
        }
    }

    #[test]
    fn instruction_count_halves_per_lmul_step() {
        let core = c920();
        let (i1, _) = analyze_lmul(Lmul::M1, 64, &core);
        let (i2, _) = analyze_lmul(Lmul::M2, 64, &core);
        let (i4, _) = analyze_lmul(Lmul::M4, 64, &core);
        // per k-step: M1: 4+4x(1+4)+3=27, M2: 2+4x3+3=17, M4: 1+4x2+3=12
        assert!((i1 - 27.0).abs() < 0.6, "{i1}");
        assert!((i2 - 17.0).abs() < 0.6, "{i2}");
        assert!((i4 - 12.0).abs() < 0.6, "{i4}");
    }

    #[test]
    fn cycles_improve_then_saturate() {
        // The cycle model's finding: M1 -> M2 wins big (each M1 vector op
        // wastes dispatch slots on 1 busy cycle of work); M2 -> M4 is
        // cycle-neutral on the VPU (same lanes/cycle once busy >=
        // dispatch) and its benefit is the *fetched-instruction* halving
        // (17 -> 12/k-step) that relieves the in-order front end — exactly
        // the quantity the paper says it optimized.
        let core = c920();
        let (_, c1) = analyze_lmul(Lmul::M1, 64, &core);
        let (_, c2) = analyze_lmul(Lmul::M2, 64, &core);
        let (_, c4) = analyze_lmul(Lmul::M4, 64, &core);
        assert!(c1 > c2 * 1.3, "{c1:.1} vs {c2:.1}");
        assert!(c4 <= c2 + 1e-9, "{c2:.1} vs {c4:.1}");
    }

    #[test]
    fn m8_is_not_register_allocatable() {
        assert!(feasible(Lmul::M1));
        assert!(feasible(Lmul::M2));
        assert!(feasible(Lmul::M4));
        assert!(!feasible(Lmul::M8), "LMUL=8 must fail: 4 col groups of 8 regs = 32");
    }

    #[test]
    fn m4_matches_the_dedicated_kernel() {
        use crate::ukernel::registry::{MicroKernel, UkernelId};
        let core = c920();
        let (i_gen, _) = analyze_lmul(Lmul::M4, 64, &core);
        let k = UkernelId::BlisLmul4.build();
        let p = k.program(PanelLayout::new(MR, NR, 64));
        let i_ded = p.len() as f64 / 64.0;
        assert!((i_gen - i_ded).abs() < 0.6, "{i_gen} vs {i_ded}");
    }
}
