//! Descriptor-driven kernel-parameter ablation: the paper jumps from
//! LMUL=1 straight to LMUL=4 — this module fills in the design space as
//! *data*, sweeping [`KernelDescriptor`]s over LMUL x K-unroll x VLEN
//! instead of the seed's hard-coded case list:
//!
//! - LMUL=1: 4 loads + 4 FMAs per column (Fig 2a, BLIS's shipped kernel);
//! - LMUL=2: 2 + 2 — halves the instruction count;
//! - LMUL=4: 1 + 1 — one register group IS the column (Fig 2b, the paper);
//! - LMUL=8: the column only fills half a group, and the four C-column
//!   accumulator groups alone need all 32 registers — the descriptor
//!   fails validation with a typed [`CimoneError::InvalidKernel`], the
//!   paper's implicit reason for stopping at 4.
//!
//! The K-unroll and VLEN axes are what the SG2044's native RVV 1.0
//! pipeline re-opens (arXiv 2508.13840): once vector dispatch stops
//! being the bottleneck, deeper unroll and wider registers move the
//! tuning point — the story `cimone sweep --matrix blas-tuning` tells
//! at node level.
//!
//! [`CimoneError::InvalidKernel`]: crate::error::CimoneError::InvalidKernel

use super::registry::{blis_lmul4, BlockingPolicy, KernelDescriptor, KernelFamily};
use super::PanelLayout;
use crate::arch::soc::CoreModel;
use crate::isa::rvv::{Lmul, Sew};
use crate::isa::timing::CycleModel;

/// The paper's register-tile geometry, shared by every sweep point.
pub const MR: usize = 8;
pub const NR: usize = 4;

fn lmul_tag(lmul: Lmul) -> &'static str {
    match lmul {
        Lmul::M1 => "m1",
        Lmul::M2 => "m2",
        Lmul::M4 => "m4",
        Lmul::M8 => "m8",
        Lmul::Fractional => "mf",
    }
}

/// One sweep point: a `blis-rvv` descriptor for the paper's 8x4 tile at
/// the given (VLEN, LMUL, K-unroll). Not necessarily feasible — callers
/// gate on [`KernelDescriptor::validate`], which is the point: the
/// infeasible corners of the grid are *typed errors*, not panics.
pub fn point(vlen_bits: usize, lmul: Lmul, k_unroll: usize) -> KernelDescriptor {
    KernelDescriptor {
        id: format!("blis-v{vlen_bits}-{}-u{k_unroll}", lmul_tag(lmul)),
        label: format!(
            "BLIS sweep point (VLEN={vlen_bits}, LMUL={}, unroll {k_unroll})",
            lmul.multiplier()
        ),
        aliases: Vec::new(),
        family: KernelFamily::BlisRvv,
        vlen_bits,
        lmul,
        sew: Sew::E64,
        native_rvv10: false,
        mr: MR,
        nr: NR,
        k_unroll,
        blocking: BlockingPolicy::CacheDerived,
        host_overhead: blis_lmul4().host_overhead,
        asm: None,
    }
}

/// Is this LMUL register-allocatable for the 8x4 kernel at VLEN=128?
/// (The constraint that stops the paper at LMUL=4.)
pub fn feasible(lmul: Lmul) -> bool {
    point(128, lmul, 1).validate().is_ok()
}

/// Ablation row: instructions/k-step and cycles/k-step for one sweep
/// point on a core model.
pub fn analyze_point(desc: &KernelDescriptor, kc: usize, core: &CoreModel) -> (f64, f64) {
    let p = desc.program(PanelLayout::new(desc.mr, desc.nr, kc));
    let t = CycleModel::new(core).analyze_at(&p, super::analysis::timing_vlen(desc, core));
    (t.insts as f64 / kc as f64, t.cycles / kc as f64)
}

/// The classic LMUL-only cut of the sweep (VLEN=128, no unroll) — what
/// `sweeps::lmul_ablation` tabulates.
pub fn analyze_lmul(lmul: Lmul, kc: usize, core: &CoreModel) -> (f64, f64) {
    analyze_point(&point(128, lmul, 1), kc, core)
}

/// One row of the full LMUL x K-unroll x VLEN grid.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub desc: KernelDescriptor,
    /// `None` when the descriptor fails validation (register-file
    /// overflow): the grid stays total, infeasibility is data too.
    pub insts_per_kstep: Option<f64>,
    pub cycles_per_kstep: Option<f64>,
}

/// Sweep the full grid on one core model. Infeasible points (e.g.
/// LMUL=8, or 8x4 at VLEN=64) come back with `None` metrics instead of
/// being silently dropped.
pub fn sweep(
    vlens: &[usize],
    lmuls: &[Lmul],
    unrolls: &[usize],
    kc: usize,
    core: &CoreModel,
) -> Vec<AblationRow> {
    let mut rows = Vec::new();
    for &vlen in vlens {
        for &lmul in lmuls {
            for &unroll in unrolls {
                let desc = point(vlen, lmul, unroll);
                let (insts, cycles) = match desc.validate() {
                    Ok(()) => {
                        let (i, c) = analyze_point(&desc, kc, core);
                        (Some(i), Some(c))
                    }
                    Err(_) => (None, None),
                };
                rows.push(AblationRow { desc, insts_per_kstep: insts, cycles_per_kstep: cycles });
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{c920, c920v2};
    use crate::util::Matrix;

    fn run_numeric(lmul: Lmul, kc: usize) -> Matrix {
        let a = Matrix::random_hpl(MR, kc, 1);
        let b = Matrix::random_hpl(kc, NR, 2);
        let c = Matrix::random_hpl(MR, NR, 3);
        point(128, lmul, 1).run(&a, &b, &c).unwrap()
    }

    #[test]
    fn m1_m2_m4_all_compute_the_same_tile() {
        let want = run_numeric(Lmul::M1, 16);
        for lmul in [Lmul::M2, Lmul::M4] {
            let got = run_numeric(lmul, 16);
            assert!(got.allclose(&want, 0.0, 0.0), "{lmul:?}");
        }
    }

    #[test]
    fn unroll_depth_never_changes_the_numerics() {
        let a = Matrix::random_hpl(MR, 13, 4);
        let b = Matrix::random_hpl(13, NR, 5);
        let c = Matrix::random_hpl(MR, NR, 6);
        let want = point(128, Lmul::M2, 1).run(&a, &b, &c).unwrap();
        for unroll in [2usize, 4, 8, 32] {
            let got = point(128, Lmul::M2, unroll).run(&a, &b, &c).unwrap();
            assert!(got.allclose(&want, 0.0, 0.0), "unroll {unroll}");
        }
    }

    #[test]
    fn instruction_count_halves_per_lmul_step() {
        let core = c920();
        let (i1, _) = analyze_lmul(Lmul::M1, 64, &core);
        let (i2, _) = analyze_lmul(Lmul::M2, 64, &core);
        let (i4, _) = analyze_lmul(Lmul::M4, 64, &core);
        // per k-step: M1: 4+4x(1+4)+3=27, M2: 2+4x3+3=17, M4: 1+4x2+3=12
        assert!((i1 - 27.0).abs() < 0.6, "{i1}");
        assert!((i2 - 17.0).abs() < 0.6, "{i2}");
        assert!((i4 - 12.0).abs() < 0.6, "{i4}");
    }

    #[test]
    fn cycles_improve_then_saturate() {
        // The cycle model's finding: M1 -> M2 wins big (each M1 vector op
        // wastes dispatch slots on 1 busy cycle of work); M2 -> M4 is
        // cycle-neutral on the VPU (same lanes/cycle once busy >=
        // dispatch) and its benefit is the *fetched-instruction* halving
        // (17 -> 12/k-step) that relieves the in-order front end — exactly
        // the quantity the paper says it optimized.
        let core = c920();
        let (_, c1) = analyze_lmul(Lmul::M1, 64, &core);
        let (_, c2) = analyze_lmul(Lmul::M2, 64, &core);
        let (_, c4) = analyze_lmul(Lmul::M4, 64, &core);
        assert!(c1 > c2 * 1.3, "{c1:.1} vs {c2:.1}");
        assert!(c4 <= c2 + 1e-9, "{c2:.1} vs {c4:.1}");
    }

    #[test]
    fn c920v2_flattens_the_lmul_axis() {
        // the native RVV 1.0 front end (dispatch floor 1.0) erases the
        // LMUL=1 penalty — which is why the SG2044 tuning point moves to
        // unroll depth instead (the blas-tuning story)
        let core = c920v2();
        let (_, c1) = analyze_lmul(Lmul::M1, 64, &core);
        let (_, c4) = analyze_lmul(Lmul::M4, 64, &core);
        assert!((c1 / c4 - 1.0).abs() < 0.05, "{c1:.1} vs {c4:.1}");
        // deeper unroll still helps (bookkeeping amortization)
        let (_, u1) = analyze_point(&point(128, Lmul::M2, 1), 64, &core);
        let (_, u8) = analyze_point(&point(128, Lmul::M2, 8), 64, &core);
        assert!(u8 < u1, "{u8:.2} !< {u1:.2}");
    }

    #[test]
    fn m8_is_not_register_allocatable() {
        assert!(feasible(Lmul::M1));
        assert!(feasible(Lmul::M2));
        assert!(feasible(Lmul::M4));
        assert!(!feasible(Lmul::M8), "LMUL=8 must fail: 4 col groups of 8 regs = 32");
    }

    #[test]
    fn m4_point_is_exactly_the_registered_paper_kernel() {
        // the sweep generator and the built-in descriptor share one code
        // path: identical programs, instruction for instruction
        let l = PanelLayout::new(MR, NR, 64);
        let sweep_prog = point(128, Lmul::M4, 1).program(l);
        let builtin_prog = blis_lmul4().program(l);
        assert_eq!(sweep_prog.insts, builtin_prog.insts);
        assert_eq!(sweep_prog.dialect, builtin_prog.dialect);
    }

    #[test]
    fn grid_sweep_is_total_with_typed_infeasibility() {
        let core = c920();
        let lmuls = [Lmul::M1, Lmul::M2, Lmul::M4, Lmul::M8];
        let rows = sweep(&[64, 128, 256], &lmuls, &[1, 4], 32, &core);
        assert_eq!(rows.len(), 3 * 4 * 2);
        // LMUL=8 at VLEN=128 is marked infeasible, not dropped
        let m8 = rows
            .iter()
            .find(|r| r.desc.vlen_bits == 128 && r.desc.lmul == Lmul::M8 && r.desc.k_unroll == 1)
            .unwrap();
        assert!(m8.insts_per_kstep.is_none());
        // the Fig 2b point is present and measured
        let m4 = rows
            .iter()
            .find(|r| r.desc.vlen_bits == 128 && r.desc.lmul == Lmul::M4 && r.desc.k_unroll == 1)
            .unwrap();
        assert!((m4.insts_per_kstep.unwrap() - 12.0).abs() < 0.6);
        // wider registers cut instructions further at the same LMUL
        let v256 = rows
            .iter()
            .find(|r| r.desc.vlen_bits == 256 && r.desc.lmul == Lmul::M2 && r.desc.k_unroll == 1)
            .unwrap();
        let v128 = rows
            .iter()
            .find(|r| r.desc.vlen_bits == 128 && r.desc.lmul == Lmul::M2 && r.desc.k_unroll == 1)
            .unwrap();
        assert!(v256.insts_per_kstep.unwrap() < v128.insts_per_kstep.unwrap());
    }
}
