//! Micro-kernel analysis: per-core performance + instruction-mix metrics.
//!
//! Bridges [`crate::isa::timing`] to [`crate::blas::perf`]: for each
//! kernel, builds a representative KC-step program, runs the cycle model,
//! and reports raw (in-kernel) and effective (host-overhead-adjusted)
//! per-core GFLOP/s — the numbers HPL's projection is built on.

use super::registry::UkernelId;
use super::PanelLayout;
use crate::arch::soc::CoreModel;
use crate::isa::timing::CycleModel;

/// Representative KC depth used for steady-state analysis (deep enough
/// that C load/store amortizes, like a real KC~256 blocked DGEMM).
pub const ANALYSIS_KC: usize = 128;

/// Analysis result for one kernel on one core model.
#[derive(Debug, Clone, Copy)]
pub struct UkernelPerf {
    pub id: UkernelId,
    pub insts_per_kstep: f64,
    pub cycles_per_kstep: f64,
    pub flops_per_cycle: f64,
    /// In-kernel GFLOP/s on this core.
    pub raw_gflops: f64,
    /// After library host overhead (packing/framework) — the per-core
    /// DGEMM rate HPL actually sees.
    pub effective_gflops: f64,
}

/// Analyze one kernel against a core model.
pub fn analyze(id: UkernelId, core: &CoreModel) -> UkernelPerf {
    let k = id.build();
    let (mr, nr) = k.tile();
    let prog = k.program(PanelLayout::new(mr, nr, ANALYSIS_KC));
    let t = CycleModel::new(core).analyze(&prog);
    let raw = t.gflops(core);
    UkernelPerf {
        id,
        insts_per_kstep: t.insts as f64 / ANALYSIS_KC as f64,
        cycles_per_kstep: t.cycles / ANALYSIS_KC as f64,
        flops_per_cycle: t.flops_per_cycle(),
        raw_gflops: raw,
        effective_gflops: raw * (1.0 - k.host_overhead()),
    }
}

/// The paper's headline micro-kernel comparison: LMUL=4 vs LMUL=1 speedup.
pub fn lmul_speedup(core: &CoreModel) -> f64 {
    let t1 = analyze(UkernelId::BlisLmul1, core);
    let t4 = analyze(UkernelId::BlisLmul4, core);
    t4.raw_gflops / t1.raw_gflops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{c920, u74};

    #[test]
    fn lmul4_speedup_in_paper_band() {
        // kernel-level speedup must propagate to the paper's +49% end to
        // end; at the kernel level that's ~1.5-2.1x
        let s = lmul_speedup(&c920());
        assert!((1.4..2.2).contains(&s), "speedup {s:.2}");
    }

    #[test]
    fn effective_rates_match_calibration_targets() {
        // EXPERIMENTS.md 'Calibration': per-core DGEMM rates on the C920
        // that reproduce Figs 4/7 through the HPL projection.
        let core = c920();
        let check = |id, lo, hi| {
            let e = analyze(id, &core).effective_gflops;
            assert!((lo..hi).contains(&e), "{id:?}: {e:.2} GF/s outside [{lo}, {hi}]");
        };
        check(UkernelId::OpenblasC920, 2.9, 3.5);
        check(UkernelId::OpenblasGeneric, 1.9, 2.4);
        check(UkernelId::BlisLmul1, 1.4, 1.9);
        check(UkernelId::BlisLmul4, 2.9, 3.5);
    }

    #[test]
    fn generic_is_68_percent_of_optimized_at_one_core() {
        // Fig 4: "relative efficiency of 68% with one core"
        let core = c920();
        let g = analyze(UkernelId::OpenblasGeneric, &core).effective_gflops;
        let o = analyze(UkernelId::OpenblasC920, &core).effective_gflops;
        let ratio = g / o;
        assert!((0.60..0.76).contains(&ratio), "ratio {ratio:.3}");
    }

    #[test]
    fn optimized_blis_reaches_openblas_parity() {
        // Fig 7: "results are now comparable to those of OpenBLAS and, in
        // some cases, even superior"
        let core = c920();
        let blis = analyze(UkernelId::BlisLmul4, &core).effective_gflops;
        let ob = analyze(UkernelId::OpenblasC920, &core).effective_gflops;
        assert!((blis / ob - 1.0).abs() < 0.08, "blis={blis:.2} ob={ob:.2}");
    }

    #[test]
    fn instruction_reduction_is_the_mechanism() {
        let core = c920();
        let i1 = analyze(UkernelId::BlisLmul1, &core).insts_per_kstep;
        let i4 = analyze(UkernelId::BlisLmul4, &core).insts_per_kstep;
        assert!(i4 < i1 / 2.0, "{i4:.1} vs {i1:.1}");
    }

    #[test]
    fn scalar_kernel_slowest_on_c920() {
        let core = c920();
        let g = analyze(UkernelId::OpenblasGeneric, &core).raw_gflops;
        let v = analyze(UkernelId::OpenblasC920, &core).raw_gflops;
        assert!(g < v);
    }

    #[test]
    fn u74_has_no_vector_path() {
        // only the scalar kernel is meaningful on MCv1; it must still analyze
        let core = u74();
        let p = analyze(UkernelId::OpenblasGeneric, &core);
        assert!(p.raw_gflops > 0.2 && p.raw_gflops < 2.0, "{}", p.raw_gflops);
    }
}
