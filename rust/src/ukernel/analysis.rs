//! Micro-kernel analysis: per-core performance + instruction-mix metrics.
//!
//! Bridges [`crate::isa::timing`] to [`crate::blas::perf`]: for each
//! kernel *descriptor*, builds a representative KC-step program, runs
//! the cycle model, and reports raw (in-kernel) and effective
//! (host-overhead-adjusted) per-core GFLOP/s — the numbers HPL's
//! projection is built on. Any registered [`KernelDescriptor`] analyzes
//! against any [`CoreModel`]; nothing here enumerates kernels.
//!
//! Both steps are pure functions of their resolved inputs and dominate
//! every scenario estimate, so they run behind the content-addressed
//! memoization layer ([`crate::util::memo`]):
//!
//! - [`interned_program`] builds each distinct (generator tunables,
//!   layout) program once and shares it as an `Arc<Program>` — keyed by
//!   tunables only, so descriptors differing merely in id/overhead share
//!   one program;
//! - [`analyze`] memoizes the full [`UkernelPerf`] per (descriptor,
//!   core) content digest.
//!
//! Cache hits are bit-identical to cold computation by construction
//! (the cached value IS a cold run's output), which the golden suite
//! asserts end to end. [`reset_caches`] gives `cimone bench` a true
//! cold start; [`cache_stats`] feeds its hit-rate report.

use std::sync::Arc;

use super::registry::{blis_lmul1, blis_lmul4, BlockingPolicy, KernelDescriptor};
use super::PanelLayout;
use crate::arch::soc::{CoreModel, Socket};
use crate::blas::blocking::Blocking;
use crate::cache::{simulate_gemm, GemmTraceConfig};
use crate::isa::inst::Program;
use crate::isa::timing::CycleModel;
use crate::util::hash::ContentHasher;
use crate::util::memo::{CacheStats, MemoCache};

/// Representative KC depth used for steady-state analysis (deep enough
/// that C load/store amortizes, like a real KC~256 blocked DGEMM).
pub const ANALYSIS_KC: usize = 128;

/// Extra host-overhead fraction charged when a vector kernel runs on a
/// core speaking the *other* RVV dialect: 0.7.1-era kernels (the
/// paper's four) need a port to run on a ratified-RVV 1.0 pipeline,
/// and native RVV 1.0 kernels run through the Section 3.3.1 retrofit
/// on theadvector cores. Scalar kernels are portable C and never pay
/// it. Calibrated so the SG2042's best kernel stays the paper's
/// LMUL=4 retrofit while the SG2044's becomes the native tuning point
/// (arXiv 2508.13840) — the `blas-tuning` sweep's contrast.
pub const PORT_TAX: f64 = 0.08;

/// The deep-K single-core trace shape host-overhead calibration replays
/// (KC only unfolds fully when k reaches OpenBLAS's fixed 768, so both
/// blocking policies are exercised at their real depths).
const CALIB_SHAPE: (usize, usize, usize) = (256, 256, 768);

/// Host-overhead floor: even a perfectly cache-resident library pays
/// packing, loop framework and threading costs.
const CALIB_FLOOR: f64 = 0.10;

/// Calibrate a kernel's `host_overhead` from the cache-trace simulator
/// instead of a hand-set constant (the PR 5 open note): replay the
/// kernel's own blocking through [`simulate_gemm`] on `socket` and
/// charge overhead for the packing traffic the simulated L2 miss rate
/// and per-load L3 misses reveal. Memoized per `(blocking, socket)`
/// through the trace cache, so calibrating every kernel of a registry
/// replays each distinct blocking once.
pub fn calibrated_host_overhead(desc: &KernelDescriptor, socket: &Socket) -> f64 {
    let (mr, nr) = desc.tile();
    let blocking = match desc.blocking {
        BlockingPolicy::CacheDerived => Blocking::blis_for(socket, mr, nr),
        BlockingPolicy::Fixed => Blocking::openblas_fixed(mr, nr),
    };
    let (m, n, k) = CALIB_SHAPE;
    let st = simulate_gemm(&GemmTraceConfig { m, n, k, blocking, cores: 1 }, socket);
    // L2 misses say the packed block overflowed its cluster share; L3
    // misses per retired load say the panel spilled to DRAM (the Fig 6
    // contrast). Both terms are bounded so the result stays a fraction.
    let l3_term = (50.0 * st.l3_misses_per_load()).min(1.0);
    (CALIB_FLOOR + 0.25 * st.l2_miss_rate() + 0.10 * l3_term).min(0.45)
}

/// Analysis result for one kernel on one core model.
#[derive(Debug, Clone)]
pub struct UkernelPerf {
    /// Registry id of the analyzed kernel.
    pub id: String,
    pub insts_per_kstep: f64,
    pub cycles_per_kstep: f64,
    pub flops_per_cycle: f64,
    /// In-kernel GFLOP/s on this core.
    pub raw_gflops: f64,
    /// After library host overhead (packing/framework) and any
    /// cross-dialect port tax — the per-core DGEMM rate HPL actually
    /// sees.
    pub effective_gflops: f64,
}

/// The VLEN the cycle model tracks vl at for one (kernel, core) pair:
/// the widest of the two (floored at 128). Program avl's never exceed
/// the kernel's own VLMAX, so this reproduces the schedule's intended
/// element counts exactly — one contract, shared by [`analyze`] and
/// the ablation sweeps.
pub fn timing_vlen(desc: &KernelDescriptor, core: &CoreModel) -> usize {
    desc.vlen_bits.max(core.vlen_bits).max(128)
}

/// The interned-program cache: one shared `Arc<Program>` per distinct
/// (generator tunables, layout) coordinate.
static PROGRAM_CACHE: MemoCache<Arc<Program>> = MemoCache::new();

/// The analysis cache: one [`UkernelPerf`] per (descriptor, core)
/// content digest.
static ANALYZE_CACHE: MemoCache<UkernelPerf> = MemoCache::new();

/// Build (or fetch) the shared program for `desc` at `layout`. Keyed by
/// the generator inputs only — family, VLEN, LMUL, tile, K-unroll and
/// the layout — NOT the descriptor's id, so e.g. `blis-lmul4` and a
/// spec-file derivative differing only in `host_overhead` intern one
/// program.
pub fn interned_program(desc: &KernelDescriptor, layout: PanelLayout) -> Arc<Program> {
    let mut h = ContentHasher::new();
    h.write_str("ukernel-program/v1");
    h.write_str(desc.family.spec_name());
    h.write_usize(desc.vlen_bits);
    h.write_usize(desc.lmul.multiplier());
    h.write_usize(desc.sew.bits());
    h.write_usize(desc.k_unroll);
    h.write_usize(layout.mr).write_usize(layout.nr).write_usize(layout.kc);
    // asm-source kernels: the program comes from the assembled listing,
    // not a generator, so the listing's canonical unit joins the key
    if let Some(a) = &desc.asm {
        a.unit.feed_content(&mut h);
    }
    PROGRAM_CACHE.get_or_insert_with(h.finish(), || Arc::new(desc.program(layout)))
}

/// Analyze one kernel descriptor against a core model. Memoized on the
/// (descriptor, core) content digest; the first call per coordinate
/// runs [`analyze_uncached`] and later calls return the identical
/// cached value.
pub fn analyze(desc: &KernelDescriptor, core: &CoreModel) -> UkernelPerf {
    let mut h = ContentHasher::new();
    h.write_str("ukernel-analyze/v1");
    desc.feed_content(&mut h);
    core.feed_content(&mut h);
    ANALYZE_CACHE.get_or_insert_with(h.finish(), || analyze_uncached(desc, core))
}

/// The uncached analysis pass — what a cache miss computes. Public so
/// the perf harness can time the cold path explicitly.
pub fn analyze_uncached(desc: &KernelDescriptor, core: &CoreModel) -> UkernelPerf {
    let (mr, nr) = desc.tile();
    let prog = interned_program(desc, PanelLayout::new(mr, nr, ANALYSIS_KC));
    let t = CycleModel::new(core).analyze_at(&prog, timing_vlen(desc, core));
    let raw = t.gflops(core);
    let tax = if desc.vlen_bits > 0 && desc.native_rvv10 != core.native_rvv10 {
        PORT_TAX
    } else {
        0.0
    };
    UkernelPerf {
        id: desc.id.clone(),
        insts_per_kstep: t.insts as f64 / ANALYSIS_KC as f64,
        cycles_per_kstep: t.cycles / ANALYSIS_KC as f64,
        flops_per_cycle: t.flops_per_cycle(),
        raw_gflops: raw,
        effective_gflops: raw * (1.0 - desc.host_overhead - tax).max(0.0),
    }
}

/// The paper's headline micro-kernel comparison: LMUL=4 vs LMUL=1 speedup.
pub fn lmul_speedup(core: &CoreModel) -> f64 {
    let t1 = analyze(&blis_lmul1(), core);
    let t4 = analyze(&blis_lmul4(), core);
    t4.raw_gflops / t1.raw_gflops
}

/// Snapshot of the (program-intern, analyze) cache counters.
pub fn cache_stats() -> (CacheStats, CacheStats) {
    (PROGRAM_CACHE.stats(), ANALYZE_CACHE.stats())
}

/// Drop both caches — the perf harness's cold start. Safe at any time:
/// concurrent users just recompute identical values.
pub fn reset_caches() {
    PROGRAM_CACHE.reset();
    ANALYZE_CACHE.reset();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{c920, c920v2, c930, u74};
    use crate::ukernel::registry::KernelRegistry;

    #[test]
    fn memoized_analyze_matches_uncached_bit_for_bit() {
        let reg = KernelRegistry::builtin();
        let core = c920();
        for id in ["openblas-generic", "openblas-c920", "blis-lmul1", "blis-lmul4"] {
            let desc = reg.get(id).unwrap();
            let cold = analyze_uncached(&desc, &core);
            let cached = analyze(&desc, &core);
            let again = analyze(&desc, &core);
            for (a, b) in [(&cold, &cached), (&cached, &again)] {
                assert_eq!(a.id, b.id);
                assert_eq!(a.insts_per_kstep.to_bits(), b.insts_per_kstep.to_bits(), "{id}");
                assert_eq!(a.cycles_per_kstep.to_bits(), b.cycles_per_kstep.to_bits(), "{id}");
                assert_eq!(a.raw_gflops.to_bits(), b.raw_gflops.to_bits(), "{id}");
                assert_eq!(a.effective_gflops.to_bits(), b.effective_gflops.to_bits(), "{id}");
            }
        }
    }

    #[test]
    fn interned_program_is_shared_across_ids() {
        // descriptors differing only in identity/overhead share one program
        let a = blis_lmul4();
        let mut b = blis_lmul4();
        b.id = "blis-lmul4-respun".into();
        b.host_overhead = 0.31;
        let l = PanelLayout::new(a.mr, a.nr, 64);
        let pa = interned_program(&a, l);
        let pb = interned_program(&b, l);
        assert!(Arc::ptr_eq(&pa, &pb));
        // and the interned program is the generator's output, verbatim
        assert_eq!(*pa, a.program(l));
        // a different layout is a different coordinate
        let pc = interned_program(&a, PanelLayout::new(a.mr, a.nr, 32));
        assert!(!Arc::ptr_eq(&pa, &pc));
    }

    #[test]
    fn lmul4_speedup_in_paper_band() {
        // kernel-level speedup must propagate to the paper's +49% end to
        // end; at the kernel level that's ~1.5-2.1x
        let s = lmul_speedup(&c920());
        assert!((1.4..2.2).contains(&s), "speedup {s:.2}");
    }

    #[test]
    fn effective_rates_match_calibration_targets() {
        // EXPERIMENTS.md 'Calibration': per-core DGEMM rates on the C920
        // that reproduce Figs 4/7 through the HPL projection. The
        // refactor must not move these: built-in descriptors generate
        // the seed's programs bit for bit.
        let reg = KernelRegistry::builtin();
        let core = c920();
        let check = |id: &str, lo: f64, hi: f64| {
            let e = analyze(&reg.get(id).unwrap(), &core).effective_gflops;
            assert!((lo..hi).contains(&e), "{id}: {e:.2} GF/s outside [{lo}, {hi}]");
        };
        check("openblas-c920", 2.9, 3.5);
        check("openblas-generic", 1.9, 2.4);
        check("blis-lmul1", 1.4, 1.9);
        check("blis-lmul4", 2.9, 3.5);
    }

    #[test]
    fn generic_is_68_percent_of_optimized_at_one_core() {
        // Fig 4: "relative efficiency of 68% with one core"
        let reg = KernelRegistry::builtin();
        let core = c920();
        let g = analyze(&reg.get("openblas-generic").unwrap(), &core).effective_gflops;
        let o = analyze(&reg.get("openblas-c920").unwrap(), &core).effective_gflops;
        let ratio = g / o;
        assert!((0.60..0.76).contains(&ratio), "ratio {ratio:.3}");
    }

    #[test]
    fn optimized_blis_reaches_openblas_parity() {
        // Fig 7: "results are now comparable to those of OpenBLAS and, in
        // some cases, even superior"
        let reg = KernelRegistry::builtin();
        let core = c920();
        let blis = analyze(&reg.get("blis-lmul4").unwrap(), &core).effective_gflops;
        let ob = analyze(&reg.get("openblas-c920").unwrap(), &core).effective_gflops;
        assert!((blis / ob - 1.0).abs() < 0.08, "blis={blis:.2} ob={ob:.2}");
    }

    #[test]
    fn instruction_reduction_is_the_mechanism() {
        let reg = KernelRegistry::builtin();
        let core = c920();
        let i1 = analyze(&reg.get("blis-lmul1").unwrap(), &core).insts_per_kstep;
        let i4 = analyze(&reg.get("blis-lmul4").unwrap(), &core).insts_per_kstep;
        assert!(i4 < i1 / 2.0, "{i4:.1} vs {i1:.1}");
    }

    #[test]
    fn scalar_kernel_slowest_on_c920() {
        let reg = KernelRegistry::builtin();
        let core = c920();
        let g = analyze(&reg.get("openblas-generic").unwrap(), &core).raw_gflops;
        let v = analyze(&reg.get("openblas-c920").unwrap(), &core).raw_gflops;
        assert!(g < v);
    }

    #[test]
    fn u74_has_no_vector_path() {
        // only the scalar kernel is meaningful on MCv1; it must still analyze
        let core = u74();
        let p = analyze(&crate::ukernel::registry::openblas_generic(), &core);
        assert!(p.raw_gflops > 0.2 && p.raw_gflops < 2.0, "{}", p.raw_gflops);
    }

    #[test]
    fn e32_kernel_analyzes_at_twice_the_e64_rate() {
        // the HPL-MxP premise at the per-core level: the doubled-MR
        // SEW=32 twin issues the same schedule (same effective datapath
        // occupancy) while moving twice the elements
        use crate::isa::rvv::Sew;
        use crate::ukernel::registry::blis_lmul4;
        let core = c920();
        let mut sp = blis_lmul4();
        sp.id = "blis-lmul4-e32".into();
        sp.aliases = Vec::new();
        sp.sew = Sew::E32;
        sp.mr = 16;
        sp.validate().unwrap();
        let r64 = analyze(&blis_lmul4(), &core).raw_gflops;
        let r32 = analyze(&sp, &core).raw_gflops;
        let ratio = r32 / r64;
        assert!((1.9..2.1).contains(&ratio), "E32 ratio {ratio:.3}");
    }

    #[test]
    fn calibrated_overhead_tracks_simulated_locality() {
        // the Fig 6 contrast through the calibration lens: OpenBLAS's
        // fixed x86 blocking spills the SG2042 L2 share, so its
        // trace-calibrated overhead must exceed BLIS's cache-derived one
        let reg = KernelRegistry::builtin();
        let s = &crate::arch::presets::sg2042().sockets[0];
        let blis = calibrated_host_overhead(&reg.get("blis-lmul4").unwrap(), s);
        let ob = calibrated_host_overhead(&reg.get("openblas-c920").unwrap(), s);
        assert!(blis < ob, "blis {blis:.3} !< openblas {ob:.3}");
        // every calibrated value is a valid host_overhead, and the
        // memoized path is deterministic bit for bit
        for k in reg.kernels() {
            let v = calibrated_host_overhead(k, s);
            assert!((0.0..1.0).contains(&v), "{}: {v}", k.id);
            assert!((0.10..=0.45).contains(&v), "{}: {v}", k.id);
            assert_eq!(v.to_bits(), calibrated_host_overhead(k, s).to_bits(), "{}", k.id);
        }
    }

    #[test]
    fn tuning_winner_flips_between_sg2042_and_sg2044() {
        // the blas-tuning premise, at the per-core level: on the SG2042
        // (0.7.1 retrofit era) the paper's LMUL=4 kernel is the best of
        // the registered kernels; on the C920v2's native RVV 1.0
        // pipeline a blis-rvv1-* kernel takes over (arXiv 2508.13840)
        let reg = KernelRegistry::builtin();
        let best = |core: &crate::arch::soc::CoreModel| {
            reg.kernels()
                .map(|k| {
                    let e = analyze(k, core).effective_gflops;
                    (k.id.clone(), e)
                })
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap()
        };
        let (old_winner, old_e) = best(&c920());
        assert_eq!(old_winner, "blis-lmul4", "SG2042 winner at {old_e:.2} GF/s");
        let (new_winner, new_e) = best(&c920v2());
        assert!(new_winner.starts_with("blis-rvv1"), "SG2044 winner {new_winner} {new_e:.2}");
        // and the native kernels pay the retrofit tax on the old core
        let native_old =
            analyze(&reg.get("blis-rvv1-lmul2").unwrap(), &c920()).effective_gflops;
        let native_new =
            analyze(&reg.get("blis-rvv1-lmul2").unwrap(), &c920v2()).effective_gflops;
        assert!(native_new > native_old, "{native_new:.2} !> {native_old:.2}");
    }

    #[test]
    fn vl256_kernel_needs_the_c930_datapath_to_win() {
        // the co-design punchline behind the full-codesign sweep: the
        // 16x4 VLEN-256 kernel only tops the table on the 4-lane C930
        // core it was shaped for — on the 2-lane C920v2 its doubled
        // per-inst latency and taller packing overhead lose to the
        // VLEN-128 native tuning points
        let reg = KernelRegistry::builtin();
        let best = |core: &crate::arch::soc::CoreModel| {
            reg.kernels()
                .map(|k| (k.id.clone(), analyze(k, core).effective_gflops))
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap()
        };
        let (wide_winner, wide_e) = best(&c930());
        assert_eq!(wide_winner, "blis-rvv1-vl256", "C930 winner at {wide_e:.2} GF/s");
        let narrow = analyze(&reg.get("blis-rvv1-vl256").unwrap(), &c920v2()).effective_gflops;
        let native = analyze(&reg.get("blis-rvv1-lmul2").unwrap(), &c920v2()).effective_gflops;
        assert!(narrow < native, "vl256 {narrow:.2} !< lmul2 {native:.2} on the C920v2");
    }
}
