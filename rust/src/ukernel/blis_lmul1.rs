//! BLIS's shipped rv64iv micro-kernel — the Fig 2a schedule.
//!
//! "The original implementation operates on single vector registers,
//! repeatedly invoking the vfmacc.vf instruction on contiguous data"
//! (Section 3.3.2). With VLEN=128 and FP64, each register holds 2 values, so
//! updating an 8-element column of AB takes FOUR `vfmacc.vf` calls and
//! FOUR loads per column of A.
//!
//! Register allocation (LMUL=1):
//! - v0..v15:  C accumulators (4 columns x 4 registers)
//! - v16..v19: current A column
//! - f0..f3:   B scalars
//!
//! Written in RVV 1.0 (the dialect BLIS ships); callers retrofit it to
//! theadvector via [`crate::isa::translate`] — exactly the paper's port.

use super::layout::PanelLayout;
use super::registry::{MicroKernel, UkernelId};
use crate::isa::inst::{Dialect, Inst, Program};
use crate::isa::rvv::{Lmul, Sew, VType};

pub struct BlisLmul1;

/// FP64 lanes per LMUL=1 register at VLEN=128.
const LANES: usize = 2;
pub const MR: usize = 8;
pub const NR: usize = 4;
/// Registers needed per 8-element column at LMUL=1.
const REGS_PER_COL: usize = MR / LANES;

impl MicroKernel for BlisLmul1 {
    fn id(&self) -> UkernelId {
        UkernelId::BlisLmul1
    }

    fn tile(&self) -> (usize, usize) {
        (MR, NR)
    }

    fn program(&self, l: PanelLayout) -> Program {
        assert_eq!((l.mr, l.nr), (MR, NR), "BlisLmul1 is an 8x4 kernel");
        let mut p = Program::new(Dialect::Rvv10);
        let mut vt = VType::new(Sew::E64, Lmul::M1);
        vt.tail_agnostic = true;
        vt.mask_agnostic = true;
        p.push(Inst::Vsetvli { avl: LANES, vtype: vt });

        // Load the C tile: 4 columns x 4 registers.
        for j in 0..NR {
            for r in 0..REGS_PER_COL {
                p.push(Inst::Vle {
                    sew: Sew::E64,
                    vd: (j * REGS_PER_COL + r) as u8,
                    addr: l.c_offset(j) + r * LANES,
                });
            }
        }

        // KC rank-1 update steps.
        for k in 0..l.kc {
            // four loads to populate four vector registers with a column of A
            for r in 0..REGS_PER_COL {
                p.push(Inst::Vle {
                    sew: Sew::E64,
                    vd: (16 + r) as u8,
                    addr: l.a_offset(k) + r * LANES,
                });
            }
            for j in 0..NR {
                p.push(Inst::Fld { fd: j as u8, addr: l.b_offset(k) + j });
                // four vfmacc.vf calls update one 8-element column of AB
                for r in 0..REGS_PER_COL {
                    p.push(Inst::VfmaccVf {
                        vd: (j * REGS_PER_COL + r) as u8,
                        fs: j as u8,
                        vs2: (16 + r) as u8,
                    });
                }
            }
            // pointer bumps for A and B, loop branch
            p.push(Inst::Addi);
            p.push(Inst::Addi);
            p.push(Inst::Bnez);
        }

        // Store C back.
        for j in 0..NR {
            for r in 0..REGS_PER_COL {
                p.push(Inst::Vse {
                    sew: Sew::E64,
                    vs: (j * REGS_PER_COL + r) as u8,
                    addr: l.c_offset(j) + r * LANES,
                });
            }
        }
        p
    }

    fn host_overhead(&self) -> f64 {
        // Calibrated: vanilla BLIS spends ~35% of DGEMM time outside the
        // micro-kernel (packing + framework) on the SG2042.
        0.35
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Matrix;

    #[test]
    fn computes_c_plus_ab() {
        let k = BlisLmul1;
        let a = Matrix::random_hpl(MR, 16, 1);
        let b = Matrix::random_hpl(16, NR, 2);
        let c = Matrix::random_hpl(MR, NR, 3);
        let out = k.run(&a, &b, &c, 128).unwrap();
        let mut want = c.clone();
        Matrix::gemm_acc(&mut want, &a, &b);
        assert!(out.allclose(&want, 1e-13, 1e-13));
    }

    #[test]
    fn kc_one_is_single_rank1() {
        let k = BlisLmul1;
        let a = Matrix::random_hpl(MR, 1, 4);
        let b = Matrix::random_hpl(1, NR, 5);
        let c = Matrix::zeros(MR, NR);
        let out = k.run(&a, &b, &c, 128).unwrap();
        for i in 0..MR {
            for j in 0..NR {
                assert!((out[(i, j)] - a[(i, 0)] * b[(0, j)]).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn instruction_count_matches_fig2a() {
        // per k-step: 4 A-loads + 4 x (1 fld + 4 vfmacc) + 3 bookkeeping = 27
        let k = BlisLmul1;
        let kc = 10;
        let p = k.program(PanelLayout::new(MR, NR, kc));
        let fixed = 1 + 16 + 16; // vsetvli + C loads + C stores
        assert_eq!(p.len(), fixed + kc * 27);
    }

    #[test]
    fn is_rvv10_and_translatable() {
        let k = BlisLmul1;
        let p = k.program(PanelLayout::new(MR, NR, 4));
        assert_eq!(p.dialect, Dialect::Rvv10);
        let t = crate::isa::translate::rvv10_to_thead(&p).unwrap();
        assert_eq!(t.len(), p.len());
    }
}
