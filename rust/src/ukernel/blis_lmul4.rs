//! The paper's optimized BLIS micro-kernel — the Fig 2b schedule.
//!
//! "We leveraged register grouping by increasing the RVV LMUL parameter
//! from one to four, with a subsequent remap of data across vector
//! registers. This adjustment allows a single load operation to populate
//! four vector registers with an entire column of A, and a single
//! vfmacc.vf instruction to update a column of AB" (Section 3.3.2).
//!
//! Register allocation (LMUL=4 groups):
//! - v0, v4, v8, v12: C accumulator columns (one group each)
//! - v16..v19:        current A column (one group)
//! - f0..f3:          B scalars
//!
//! Same data blocking and algorithm as [`super::blis_lmul1`] — only the
//! instruction schedule changes, which is the paper's point.

use super::layout::PanelLayout;
use super::registry::{MicroKernel, UkernelId};
use crate::isa::inst::{Dialect, Inst, Program};
use crate::isa::rvv::{Lmul, Sew, VType};

pub struct BlisLmul4;

pub const MR: usize = 8;
pub const NR: usize = 4;

impl MicroKernel for BlisLmul4 {
    fn id(&self) -> UkernelId {
        UkernelId::BlisLmul4
    }

    fn tile(&self) -> (usize, usize) {
        (MR, NR)
    }

    fn program(&self, l: PanelLayout) -> Program {
        assert_eq!((l.mr, l.nr), (MR, NR), "BlisLmul4 is an 8x4 kernel");
        let mut p = Program::new(Dialect::Rvv10);
        let mut vt = VType::new(Sew::E64, Lmul::M4);
        vt.tail_agnostic = true;
        vt.mask_agnostic = true;
        p.push(Inst::Vsetvli { avl: MR, vtype: vt });

        // Load C: one grouped load per column.
        for j in 0..NR {
            p.push(Inst::Vle { sew: Sew::E64, vd: (j * 4) as u8, addr: l.c_offset(j) });
        }

        for k in 0..l.kc {
            // ONE load populates four vector registers with a column of A
            p.push(Inst::Vle { sew: Sew::E64, vd: 16, addr: l.a_offset(k) });
            for j in 0..NR {
                p.push(Inst::Fld { fd: j as u8, addr: l.b_offset(k) + j });
                // ONE vfmacc.vf updates the whole column of AB
                p.push(Inst::VfmaccVf { vd: (j * 4) as u8, fs: j as u8, vs2: 16 });
            }
            p.push(Inst::Addi);
            p.push(Inst::Addi);
            p.push(Inst::Bnez);
        }

        for j in 0..NR {
            p.push(Inst::Vse { sew: Sew::E64, vs: (j * 4) as u8, addr: l.c_offset(j) });
        }
        p
    }

    fn host_overhead(&self) -> f64 {
        // Calibrated: the optimized kernel amortizes packing better (longer
        // effective inner loop), ~23% outside-kernel time.
        0.23
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ukernel::blis_lmul1::BlisLmul1;
    use crate::util::Matrix;

    #[test]
    fn computes_c_plus_ab() {
        let k = BlisLmul4;
        let a = Matrix::random_hpl(MR, 24, 11);
        let b = Matrix::random_hpl(24, NR, 12);
        let c = Matrix::random_hpl(MR, NR, 13);
        let out = k.run(&a, &b, &c, 128).unwrap();
        let mut want = c.clone();
        Matrix::gemm_acc(&mut want, &a, &b);
        assert!(out.allclose(&want, 1e-13, 1e-13));
    }

    #[test]
    fn bitwise_identical_to_lmul1() {
        // The optimization preserves the existing blocking and algorithm:
        // same rank-1 order, same FP rounding, bit-identical output.
        let a = Matrix::random_hpl(MR, 32, 21);
        let b = Matrix::random_hpl(32, NR, 22);
        let c = Matrix::random_hpl(MR, NR, 23);
        let o1 = BlisLmul1.run(&a, &b, &c, 128).unwrap();
        let o4 = BlisLmul4.run(&a, &b, &c, 128).unwrap();
        assert!(o1.allclose(&o4, 0.0, 0.0), "schedules must round identically");
    }

    #[test]
    fn instruction_count_matches_fig2b() {
        // per k-step: 1 A-load + 4 x (fld + vfmacc) + 3 bookkeeping = 12
        let kc = 10;
        let p = BlisLmul4.program(PanelLayout::new(MR, NR, kc));
        let fixed = 1 + 4 + 4; // vsetvli + C group loads + stores
        assert_eq!(p.len(), fixed + kc * 12);
    }

    #[test]
    fn reduces_instructions_vs_lmul1() {
        let l = PanelLayout::new(MR, NR, 64);
        let n1 = BlisLmul1.program(l).len();
        let n4 = BlisLmul4.program(l).len();
        // the paper's mechanism: >2x fewer fetched instructions
        assert!(n4 * 2 < n1, "{n4} vs {n1}");
    }

    #[test]
    fn group_alignment_valid() {
        let p = BlisLmul4.program(PanelLayout::new(MR, NR, 4));
        assert!(p.validate_register_groups(128).is_ok());
    }
}
