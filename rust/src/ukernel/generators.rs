//! The two micro-kernel *generator families* behind the registry: every
//! registered [`KernelDescriptor`](super::registry::KernelDescriptor)
//! names one of these plus its tunables (VLEN, LMUL, MRxNR tile,
//! K-unroll), and the generator emits the complete instruction schedule.
//!
//! - [`blis_rvv_program`] — BLIS's rank-1-update schedule (Fig 2): per
//!   k-step, load a column of A into one or more LMUL register groups,
//!   then for each of the NR columns of B load the scalar and issue the
//!   grouped `vfmacc.vf` burst. The B scalar is consumed immediately
//!   (the in-order stall the paper's Fig 2a kernel eats); deeper
//!   `k_unroll` amortizes the loop bookkeeping, nothing else — the
//!   schedule is what BLIS's `rv64iv` kernels actually compile to.
//! - [`openblas_asm_program`] — OpenBLAS's hand-scheduled asm: all NR B
//!   scalars are software-pipelined ahead of the A loads and the FMA
//!   burst, so the in-order core never stalls on a just-loaded `f`
//!   register. With `vlen_bits == 0` it degenerates to the pure-scalar
//!   `fmadd.d` register-blocked kernel OpenBLAS builds for generic RV64.
//!
//! The four paper kernels are fixed points of these generators: the
//! built-in descriptors reproduce the seed's hand-written programs
//! bit-for-bit (pinned by `rust/tests/integration_kernels.rs`), and the
//! same code paths generate every LMUL x K-unroll x VLEN sweep point of
//! [`super::ablation`].

use super::layout::PanelLayout;
use crate::isa::inst::{Dialect, Inst, Program};
use crate::isa::rvv::{Lmul, Sew, VType};

/// Register geometry of one vector micro-kernel configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VectorGeometry {
    /// Elements per architectural register (VLEN / SEW — at the default
    /// SEW=64 this is the FP64 lane count; SEW=32 doubles it).
    pub lanes: usize,
    /// Architectural registers per LMUL group.
    pub group: usize,
    /// Elements one full register group holds.
    pub elems_per_group: usize,
    /// Grouped loads/FMAs needed per MR-element column.
    pub ops_per_col: usize,
    /// Architectural registers one accumulator column occupies.
    pub regs_per_col: usize,
    /// First register of the A-column group(s).
    pub a_base: usize,
    /// One past the last architectural register the kernel touches.
    pub regs_used: usize,
}

/// The shared derivation both register maps build on; only the A-column
/// base rule differs per family, so it comes in as a function of the
/// shared quantities — one place for the `regs_used` accounting the
/// 32-register-file validation relies on.
fn geometry(
    vlen_bits: usize,
    lmul: Lmul,
    sew: Sew,
    mr: usize,
    nr: usize,
    a_base: impl Fn(usize, usize) -> usize,
) -> VectorGeometry {
    let lanes = vlen_bits / sew.bits();
    let group = lmul.multiplier();
    let elems_per_group = group * lanes;
    let ops_per_col = mr.div_ceil(elems_per_group);
    let regs_per_col = ops_per_col * group;
    let a_base = a_base(group, ops_per_col);
    VectorGeometry {
        lanes,
        group,
        elems_per_group,
        ops_per_col,
        regs_per_col,
        a_base,
        regs_used: a_base + ops_per_col * group,
    }
}

/// Derive the register map for a BLIS-style rank-1 kernel: C column `j`
/// occupies the group run starting at `j * regs_per_col`, the A column
/// lives at the first group boundary past the accumulators (v16 for
/// every paper configuration — kept so the built-ins stay bit-identical
/// to the seed's hand-written kernels).
pub fn blis_geometry(vlen_bits: usize, lmul: Lmul, mr: usize, nr: usize) -> VectorGeometry {
    blis_geometry_sew(vlen_bits, lmul, Sew::E64, mr, nr)
}

/// [`blis_geometry`] at an explicit element width: SEW=32 doubles the
/// elements per group, so the same MR tile needs half the grouped ops —
/// the register-map side of mixed-precision (HPL-MxP) kernels.
pub fn blis_geometry_sew(
    vlen_bits: usize,
    lmul: Lmul,
    sew: Sew,
    mr: usize,
    nr: usize,
) -> VectorGeometry {
    geometry(vlen_bits, lmul, sew, mr, nr, |group, ops_per_col| {
        ((nr * ops_per_col * group).div_ceil(group) * group).max(16)
    })
}

/// Register map for the OpenBLAS asm schedule: the accumulator groups
/// are *interleaved* — C column `j`, group `r` sits at
/// `r*nr*group + j*group` (the C920 kernel keeps the top halves of all
/// four columns in v0..v7 and the bottom halves in v8..v15), and the A
/// column follows the accumulators directly.
pub fn openblas_geometry(vlen_bits: usize, lmul: Lmul, mr: usize, nr: usize) -> VectorGeometry {
    openblas_geometry_sew(vlen_bits, lmul, Sew::E64, mr, nr)
}

/// [`openblas_geometry`] at an explicit element width (see
/// [`blis_geometry_sew`]).
pub fn openblas_geometry_sew(
    vlen_bits: usize,
    lmul: Lmul,
    sew: Sew,
    mr: usize,
    nr: usize,
) -> VectorGeometry {
    geometry(vlen_bits, lmul, sew, mr, nr, |group, ops_per_col| nr * group * ops_per_col)
}

/// BLIS rank-1-update schedule (the Fig 2 family), generalized over
/// VLEN, LMUL and K-unroll. `lmul=M1` / `lmul=M4` at VLEN=128 with
/// `k_unroll=1` reproduce the paper's Fig 2a / Fig 2b kernels
/// instruction for instruction. Written in RVV 1.0 (the dialect BLIS
/// ships); SG2042 callers retrofit it via [`crate::isa::translate`].
pub fn blis_rvv_program(
    vlen_bits: usize,
    lmul: Lmul,
    k_unroll: usize,
    l: PanelLayout,
) -> Program {
    blis_rvv_program_sew(vlen_bits, lmul, Sew::E64, k_unroll, l)
}

/// [`blis_rvv_program`] at an explicit element width. SEW=32 keeps the
/// exact schedule shape (same rank-1 update, same register map rules)
/// but every grouped op moves twice the elements — the kernel side of
/// the HPL-MxP mixed-precision workload.
pub fn blis_rvv_program_sew(
    vlen_bits: usize,
    lmul: Lmul,
    sew: Sew,
    k_unroll: usize,
    l: PanelLayout,
) -> Program {
    let g = blis_geometry_sew(vlen_bits, lmul, sew, l.mr, l.nr);
    let mut p = Program::new(Dialect::Rvv10);
    let mut vt = VType::new(sew, lmul);
    vt.tail_agnostic = true;
    vt.mask_agnostic = true;
    p.push(Inst::Vsetvli { avl: g.elems_per_group.min(l.mr), vtype: vt });

    // Load the C tile: `ops_per_col` grouped loads per column.
    for j in 0..l.nr {
        for r in 0..g.ops_per_col {
            p.push(Inst::Vle {
                sew,
                vd: (j * g.regs_per_col + r * g.group) as u8,
                addr: l.c_offset(j) + r * g.elems_per_group,
            });
        }
    }

    // KC rank-1 update steps, bookkeeping amortized per unrolled block.
    let mut k = 0;
    while k < l.kc {
        let block = k_unroll.min(l.kc - k);
        for kk in k..k + block {
            for r in 0..g.ops_per_col {
                p.push(Inst::Vle {
                    sew,
                    vd: (g.a_base + r * g.group) as u8,
                    addr: l.a_offset(kk) + r * g.elems_per_group,
                });
            }
            for j in 0..l.nr {
                p.push(Inst::Fld { fd: j as u8, addr: l.b_offset(kk) + j });
                for r in 0..g.ops_per_col {
                    p.push(Inst::VfmaccVf {
                        vd: (j * g.regs_per_col + r * g.group) as u8,
                        fs: j as u8,
                        vs2: (g.a_base + r * g.group) as u8,
                    });
                }
            }
        }
        // pointer bumps for A and B, loop branch
        p.push(Inst::Addi);
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
        k += block;
    }

    // Store C back.
    for j in 0..l.nr {
        for r in 0..g.ops_per_col {
            p.push(Inst::Vse {
                sew,
                vs: (j * g.regs_per_col + r * g.group) as u8,
                addr: l.c_offset(j) + r * g.elems_per_group,
            });
        }
    }
    p
}

/// OpenBLAS hand-scheduled asm (the `dgemm_kernel_*_c920.S` family),
/// generalized over VLEN, LMUL and K-unroll. `lmul=M2` at VLEN=128
/// reproduces the SG2042-optimized kernel bit for bit; `vlen_bits == 0`
/// reproduces the pure-scalar generic-RV64 kernel. Vector programs are
/// native theadvector (the Xuantie toolchain emits 0.7.1 directly).
pub fn openblas_asm_program(
    vlen_bits: usize,
    lmul: Lmul,
    k_unroll: usize,
    l: PanelLayout,
) -> Program {
    openblas_asm_program_sew(vlen_bits, lmul, Sew::E64, k_unroll, l)
}

/// [`openblas_asm_program`] at an explicit element width (see
/// [`blis_rvv_program_sew`]). The scalar (`vlen_bits == 0`) fallback is
/// FP64-only — descriptor validation rejects SEW=32 scalar kernels
/// before this generator runs.
pub fn openblas_asm_program_sew(
    vlen_bits: usize,
    lmul: Lmul,
    sew: Sew,
    k_unroll: usize,
    l: PanelLayout,
) -> Program {
    if vlen_bits == 0 {
        return openblas_scalar_program(k_unroll, l);
    }
    let g = openblas_geometry_sew(vlen_bits, lmul, sew, l.mr, l.nr);
    let mut p = Program::new(Dialect::Thead071);
    let vt = VType::new(sew, lmul);
    p.push(Inst::Vsetvli { avl: g.elems_per_group.min(l.mr), vtype: vt });

    // C tile: interleaved accumulator groups (see `openblas_geometry`).
    for j in 0..l.nr {
        for r in 0..g.ops_per_col {
            p.push(Inst::Vle {
                sew,
                vd: (r * l.nr * g.group + j * g.group) as u8,
                addr: l.c_offset(j) + r * g.elems_per_group,
            });
        }
    }

    let mut k = 0;
    while k < l.kc {
        let block = k_unroll.min(l.kc - k);
        for kk in k..k + block {
            // software pipeline: hoist ALL scalar loads first...
            for j in 0..l.nr {
                p.push(Inst::Fld { fd: j as u8, addr: l.b_offset(kk) + j });
            }
            // ...then the A column group(s)...
            for r in 0..g.ops_per_col {
                p.push(Inst::Vle {
                    sew,
                    vd: (g.a_base + r * g.group) as u8,
                    addr: l.a_offset(kk) + r * g.elems_per_group,
                });
            }
            // ...then the FMA burst.
            for j in 0..l.nr {
                for r in 0..g.ops_per_col {
                    p.push(Inst::VfmaccVf {
                        vd: (r * l.nr * g.group + j * g.group) as u8,
                        fs: j as u8,
                        vs2: (g.a_base + r * g.group) as u8,
                    });
                }
            }
        }
        p.push(Inst::Addi);
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
        k += block;
    }

    for j in 0..l.nr {
        for r in 0..g.ops_per_col {
            p.push(Inst::Vse {
                sew,
                vs: (r * l.nr * g.group + j * g.group) as u8,
                addr: l.c_offset(j) + r * g.elems_per_group,
            });
        }
    }
    p
}

/// The pure-scalar register-blocked kernel (what OpenBLAS's generic C
/// kernel compiles to): accumulators in f16..f31, the A column in
/// f0..f{MR-1}, the B row in f{MR}..f{MR+NR-1}, 2 FLOPs per `fmadd.d`.
fn openblas_scalar_program(k_unroll: usize, l: PanelLayout) -> Program {
    let mut p = Program::new(Dialect::Rvv10); // dialect irrelevant: no vector insts
    // Load C tile into accumulators f16.. (column-major).
    for j in 0..l.nr {
        for i in 0..l.mr {
            p.push(Inst::Fld { fd: (16 + j * l.mr + i) as u8, addr: l.c_offset(j) + i });
        }
    }
    let mut k = 0;
    while k < l.kc {
        let block = k_unroll.min(l.kc - k);
        for kk in k..k + block {
            // A column -> f0.., B row -> f{mr}..
            for i in 0..l.mr {
                p.push(Inst::Fld { fd: i as u8, addr: l.a_offset(kk) + i });
            }
            for j in 0..l.nr {
                p.push(Inst::Fld { fd: (l.mr + j) as u8, addr: l.b_offset(kk) + j });
            }
            for j in 0..l.nr {
                for i in 0..l.mr {
                    let acc = (16 + j * l.mr + i) as u8;
                    p.push(Inst::FmaddD {
                        fd: acc,
                        fs1: i as u8,
                        fs2: (l.mr + j) as u8,
                        fs3: acc,
                    });
                }
            }
        }
        p.push(Inst::Addi);
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
        k += block;
    }
    for j in 0..l.nr {
        for i in 0..l.mr {
            p.push(Inst::Fsd { fs: (16 + j * l.mr + i) as u8, addr: l.c_offset(j) + i });
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blis_geometry_reproduces_the_paper_register_maps() {
        // Fig 2a: LMUL=1 at VLEN=128 — 4 registers per 8-element column,
        // A at v16
        let g = blis_geometry(128, Lmul::M1, 8, 4);
        assert_eq!((g.elems_per_group, g.ops_per_col, g.regs_per_col), (2, 4, 4));
        assert_eq!(g.a_base, 16);
        assert_eq!(g.regs_used, 20);
        // Fig 2b: LMUL=4 — one group IS the column
        let g = blis_geometry(128, Lmul::M4, 8, 4);
        assert_eq!((g.elems_per_group, g.ops_per_col, g.regs_per_col), (8, 1, 4));
        assert_eq!(g.a_base, 16);
        // LMUL=8: the four accumulator groups alone fill the file
        let g = blis_geometry(128, Lmul::M8, 8, 4);
        assert_eq!(g.a_base, 32);
        assert!(g.regs_used > 32, "LMUL=8 must not be register-allocatable");
    }

    #[test]
    fn openblas_geometry_matches_the_c920_kernel() {
        let g = openblas_geometry(128, Lmul::M2, 8, 4);
        assert_eq!((g.elems_per_group, g.ops_per_col), (4, 2));
        assert_eq!(g.a_base, 16);
        assert_eq!(g.regs_used, 20);
    }

    #[test]
    fn k_unroll_amortizes_only_bookkeeping() {
        let l = PanelLayout::new(8, 4, 8);
        let u1 = blis_rvv_program(128, Lmul::M4, 1, l);
        let u4 = blis_rvv_program(128, Lmul::M4, 4, l);
        // 8 blocks of bookkeeping vs 2: 6 x 3 fewer instructions
        assert_eq!(u1.len() - u4.len(), 6 * 3);
        // the data-path instructions are identical and in order
        let data = |p: &Program| {
            p.insts
                .iter()
                .filter(|i| !matches!(i, Inst::Addi | Inst::Bnez))
                .copied()
                .collect::<Vec<_>>()
        };
        assert_eq!(data(&u1), data(&u4));
    }

    #[test]
    fn partial_tail_block_still_covers_every_kstep() {
        // kc=7 with unroll 4: blocks of 4 and 3
        let l = PanelLayout::new(8, 4, 7);
        let p = blis_rvv_program(128, Lmul::M4, 4, l);
        let fmas = p.insts.iter().filter(|i| matches!(i, Inst::VfmaccVf { .. })).count();
        assert_eq!(fmas, 7 * 4, "one grouped FMA per column per k-step");
        let branches = p.insts.iter().filter(|i| matches!(i, Inst::Bnez)).count();
        assert_eq!(branches, 2, "two unrolled blocks");
    }

    #[test]
    fn vlen256_halves_the_group_ops() {
        // at VLEN=256 an LMUL=2 group already holds 8 f64 lanes
        let l = PanelLayout::new(8, 4, 4);
        let narrow = blis_rvv_program(128, Lmul::M2, 1, l);
        let wide = blis_rvv_program(256, Lmul::M2, 1, l);
        assert!(wide.len() < narrow.len(), "{} vs {}", wide.len(), narrow.len());
        assert!(wide.validate_register_groups(256).is_ok());
    }

    #[test]
    fn scalar_program_has_no_vector_instructions() {
        let p = openblas_asm_program(0, Lmul::M1, 1, PanelLayout::new(4, 4, 5));
        assert!(p.insts.iter().all(|i| !i.is_vector()));
        // 8 fld + 16 fmadd per k-step + 3 bookkeeping, 16 C loads + stores
        assert_eq!(p.len(), 32 + 5 * 24 + 5 * 3);
    }

    #[test]
    fn openblas_vector_flds_are_hoisted() {
        let p = openblas_asm_program(128, Lmul::M2, 1, PanelLayout::new(8, 4, 1));
        let first_fma = p.insts.iter().position(|i| matches!(i, Inst::VfmaccVf { .. })).unwrap();
        let last_fld = p.insts.iter().rposition(|i| matches!(i, Inst::Fld { .. })).unwrap();
        assert!(last_fld < first_fma, "flds must precede the FMA burst");
    }

    #[test]
    fn e32_geometry_halves_the_grouped_ops() {
        // SEW=32 at VLEN=128: a register holds 4 elements, so the same
        // 8-row tile needs half the grouped ops of the E64 map
        let g64 = blis_geometry(128, Lmul::M1, 8, 4);
        let g32 = blis_geometry_sew(128, Lmul::M1, Sew::E32, 8, 4);
        assert_eq!(g32.lanes, 2 * g64.lanes);
        assert_eq!(g32.ops_per_col * 2, g64.ops_per_col);
        // the doubled-MR MxP tile lands on exactly the E64 register budget
        let g = blis_geometry_sew(128, Lmul::M4, Sew::E32, 16, 4);
        assert_eq!(g.regs_used, blis_geometry(128, Lmul::M4, 8, 4).regs_used);
    }

    #[test]
    fn e32_program_matches_e64_shape_with_doubled_mr() {
        // twice the rows at half the width: identical schedule shape
        let p64 = blis_rvv_program(128, Lmul::M4, 1, PanelLayout::new(8, 4, 3));
        let p32 =
            blis_rvv_program_sew(128, Lmul::M4, Sew::E32, 1, PanelLayout::new(16, 4, 3));
        assert_eq!(p64.len(), p32.len());
        assert!(p32.validate_register_groups(128).is_ok());
        // every vector memory op carries the 32-bit element width
        assert!(p32.insts.iter().all(|i| match i {
            Inst::Vle { sew, .. } | Inst::Vse { sew, .. } => *sew == Sew::E32,
            _ => true,
        }));
    }

    #[test]
    fn programs_validate_their_register_groups() {
        for lmul in [Lmul::M1, Lmul::M2, Lmul::M4] {
            let p = blis_rvv_program(128, lmul, 1, PanelLayout::new(8, 4, 3));
            assert!(p.validate_register_groups(128).is_ok(), "{lmul:?}");
        }
        let p = openblas_asm_program(128, Lmul::M2, 1, PanelLayout::new(8, 4, 3));
        assert!(p.validate_register_groups(128).is_ok());
    }
}
