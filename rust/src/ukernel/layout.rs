//! Packed-panel memory layout shared by all micro-kernels.
//!
//! BLIS packs A into column-major (MR x KC) panels and B into row-major
//! (KC x NR) panels before entering the micro-kernel; the C tile sits in
//! the output matrix. We reproduce that layout in the vector machine's
//! flat f64 memory:
//!
//! ```text
//! [0 .. mr*kc)                 A packed: column k at offset k*mr
//! [a_len .. a_len + kc*nr)     B packed: row    k at offset k*nr
//! [b_end .. b_end + mr*nr)     C tile, column-major
//! ```

use crate::util::Matrix;

/// Geometry + offsets of one micro-kernel invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PanelLayout {
    pub mr: usize,
    pub nr: usize,
    pub kc: usize,
}

impl PanelLayout {
    pub fn new(mr: usize, nr: usize, kc: usize) -> Self {
        assert!(mr > 0 && nr > 0 && kc > 0);
        PanelLayout { mr, nr, kc }
    }

    pub fn a_offset(&self, k: usize) -> usize {
        k * self.mr
    }

    pub fn b_offset(&self, k: usize) -> usize {
        self.mr * self.kc + k * self.nr
    }

    pub fn c_offset(&self, col: usize) -> usize {
        self.mr * self.kc + self.kc * self.nr + col * self.mr
    }

    /// Total f64 words the machine needs.
    pub fn mem_words(&self) -> usize {
        self.mr * self.kc + self.kc * self.nr + self.mr * self.nr
    }

    /// Pack (a, b, c) into a flat memory image.
    pub fn pack(&self, a: &Matrix, b: &Matrix, c: &Matrix) -> Vec<f64> {
        assert_eq!((a.rows(), a.cols()), (self.mr, self.kc), "A panel shape");
        assert_eq!((b.rows(), b.cols()), (self.kc, self.nr), "B panel shape");
        assert_eq!((c.rows(), c.cols()), (self.mr, self.nr), "C tile shape");
        let mut mem = vec![0.0; self.mem_words()];
        for k in 0..self.kc {
            for i in 0..self.mr {
                mem[self.a_offset(k) + i] = a[(i, k)];
            }
            for j in 0..self.nr {
                mem[self.b_offset(k) + j] = b[(k, j)];
            }
        }
        for j in 0..self.nr {
            for i in 0..self.mr {
                mem[self.c_offset(j) + i] = c[(i, j)];
            }
        }
        mem
    }

    /// Extract the C tile from a memory image.
    pub fn unpack_c(&self, mem: &[f64]) -> Matrix {
        let mut c = Matrix::zeros(self.mr, self.nr);
        for j in 0..self.nr {
            for i in 0..self.mr {
                c[(i, j)] = mem[self.c_offset(j) + i];
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offsets_are_disjoint_and_ordered() {
        let l = PanelLayout::new(8, 4, 16);
        assert_eq!(l.a_offset(0), 0);
        assert_eq!(l.a_offset(15) + 8, 128);
        assert_eq!(l.b_offset(0), 128);
        assert_eq!(l.b_offset(15) + 4, 128 + 64);
        assert_eq!(l.c_offset(0), 192);
        assert_eq!(l.mem_words(), 128 + 64 + 32);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let l = PanelLayout::new(8, 4, 3);
        let a = Matrix::random_hpl(8, 3, 1);
        let b = Matrix::random_hpl(3, 4, 2);
        let c = Matrix::random_hpl(8, 4, 3);
        let mem = l.pack(&a, &b, &c);
        let c2 = l.unpack_c(&mem);
        assert!(c2.allclose(&c, 0.0, 0.0));
        // spot-check A packing: column k contiguous
        assert_eq!(mem[l.a_offset(2) + 5], a[(5, 2)]);
        assert_eq!(mem[l.b_offset(1) + 3], b[(1, 3)]);
    }

    #[test]
    #[should_panic(expected = "A panel shape")]
    fn pack_validates_shapes() {
        let l = PanelLayout::new(8, 4, 3);
        let wrong = Matrix::zeros(4, 3);
        l.pack(&wrong, &Matrix::zeros(3, 4), &Matrix::zeros(8, 4));
    }
}
