//! The four GEMM micro-kernels of the paper's evaluation, as instruction
//! schedules over [`crate::isa`]:
//!
//! | name               | paper role                                  |
//! |--------------------|---------------------------------------------|
//! | `openblas_generic` | OpenBLAS built for generic RV64 (no RVV)     |
//! | `openblas_c920`    | OpenBLAS with SG2042-optimized asm kernels   |
//! | `blis_lmul1`       | BLIS's shipped rv64iv kernel (Fig 2a)        |
//! | `blis_lmul4`       | the paper's optimized kernel (Fig 2b)        |
//!
//! Each generator emits a complete micro-kernel [`Program`] (C-tile loads,
//! KC rank-1 update steps, C-tile stores) over the packed-panel memory
//! layout in [`layout`]. The programs EXECUTE for real on the functional
//! vector machine, and the cycle model turns them into per-core GFLOP/s.

pub mod ablation;
pub mod analysis;
pub mod blis_lmul1;
pub mod blis_lmul4;
pub mod layout;
pub mod openblas_c920;
pub mod openblas_generic;
pub mod registry;

pub use layout::PanelLayout;
pub use registry::{MicroKernel, UkernelId};
