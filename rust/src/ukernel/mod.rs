//! The GEMM micro-kernel layer, data-driven: kernels are
//! [`KernelDescriptor`]s in a [`KernelRegistry`] (the BLAS analogue of
//! the platform and fabric registries), each naming a generator family
//! plus its tunables (VLEN, LMUL, MRxNR tile, K-unroll, blocking
//! policy). The built-ins cover the paper's evaluation and its native
//! RVV 1.0 successors:
//!
//! | id                 | paper role                                   |
//! |--------------------|----------------------------------------------|
//! | `openblas-generic` | OpenBLAS built for generic RV64 (no RVV)     |
//! | `openblas-c920`    | OpenBLAS with SG2042-optimized asm kernels   |
//! | `blis-lmul1`       | BLIS's shipped rv64iv kernel (Fig 2a)        |
//! | `blis-lmul4`       | the paper's optimized kernel (Fig 2b)        |
//! | `blis-rvv1-lmul2`  | SG2044-native RVV 1.0 tuning point           |
//! | `blis-rvv1-lmul4`  | MCv3-native RVV 1.0 tuning point             |
//!
//! Each descriptor's generator ([`generators`]) emits a complete
//! micro-kernel [`Program`](crate::isa::inst::Program) (C-tile loads,
//! KC rank-1 update steps, C-tile stores) over the packed-panel memory
//! layout in [`layout`]. The programs EXECUTE for real on the
//! functional vector machine, and the cycle model ([`analysis`]) turns
//! them into per-core GFLOP/s. [`ablation`] sweeps the descriptor space
//! (LMUL x K-unroll x VLEN) that the seed hard-coded.

pub mod ablation;
pub mod analysis;
pub mod generators;
pub mod layout;
pub mod registry;

pub use layout::PanelLayout;
pub use registry::{BlockingPolicy, KernelDescriptor, KernelFamily, KernelRegistry};
