//! OpenBLAS's SG2042-optimized DGEMM micro-kernel model.
//!
//! The paper's baseline: "an optimized version of OpenBLAS, incorporating
//! assembly kernels specifically designed for the C920 core and its
//! vector unit ... compiled with the Xuantie GNU Toolchain" (Section 3.2).
//!
//! The real kernel (OpenBLAS `dgemm_kernel_8x4_c920.S`) is hand-scheduled:
//! LMUL=2 register groups and software-pipelined scalar loads (all four B
//! scalars are hoisted ahead of the FMA burst, so the in-order core never
//! stalls on a just-loaded `f` register). That scheduling quality — not a
//! different algorithm — is why it beats vanilla BLIS.
//!
//! Register allocation (LMUL=2 groups):
//! - v0,v2,v4,v6:  C accumulator columns (8 elements = one m2 group each)
//! - v16, v18:     current A column (two m2 groups)
//! - f0..f3:       B scalars (pre-loaded per k-step)
//!
//! Dialect: native theadvector (the Xuantie toolchain emits 0.7.1 directly).

use super::layout::PanelLayout;
use super::registry::{MicroKernel, UkernelId};
use crate::isa::inst::{Dialect, Inst, Program};
use crate::isa::rvv::{Lmul, Sew, VType};

pub struct OpenblasC920;

pub const MR: usize = 8;
pub const NR: usize = 4;
/// Elements per LMUL=2 group at VLEN=128.
const GROUP_ELEMS: usize = 4;

impl MicroKernel for OpenblasC920 {
    fn id(&self) -> UkernelId {
        UkernelId::OpenblasC920
    }

    fn tile(&self) -> (usize, usize) {
        (MR, NR)
    }

    fn program(&self, l: PanelLayout) -> Program {
        assert_eq!((l.mr, l.nr), (MR, NR), "OpenblasC920 is an 8x4 kernel");
        let mut p = Program::new(Dialect::Thead071);
        let vt = VType::new(Sew::E64, Lmul::M2);
        p.push(Inst::Vsetvli { avl: GROUP_ELEMS, vtype: vt });

        // C tile: each 8-element column needs two m2 groups; OpenBLAS keeps
        // only the top half resident and streams the bottom half — we model
        // the resident half in v0..v7 and reload the rest per store. For
        // numerics we simply load both halves (2 loads per column).
        for j in 0..NR {
            p.push(Inst::Vle { sew: Sew::E64, vd: (j * 2) as u8, addr: l.c_offset(j) });
            p.push(Inst::Vle {
                sew: Sew::E64,
                vd: (8 + j * 2) as u8,
                addr: l.c_offset(j) + GROUP_ELEMS,
            });
        }

        for k in 0..l.kc {
            // software pipeline: hoist ALL scalar loads first...
            for j in 0..NR {
                p.push(Inst::Fld { fd: j as u8, addr: l.b_offset(k) + j });
            }
            // ...then the A column (two m2 groups)...
            p.push(Inst::Vle { sew: Sew::E64, vd: 16, addr: l.a_offset(k) });
            p.push(Inst::Vle {
                sew: Sew::E64,
                vd: 18,
                addr: l.a_offset(k) + GROUP_ELEMS,
            });
            // ...then the FMA burst: two m2 vfmacc per column.
            for j in 0..NR {
                p.push(Inst::VfmaccVf { vd: (j * 2) as u8, fs: j as u8, vs2: 16 });
                p.push(Inst::VfmaccVf { vd: (8 + j * 2) as u8, fs: j as u8, vs2: 18 });
            }
            p.push(Inst::Addi);
            p.push(Inst::Addi);
            p.push(Inst::Bnez);
        }

        for j in 0..NR {
            p.push(Inst::Vse { sew: Sew::E64, vs: (j * 2) as u8, addr: l.c_offset(j) });
            p.push(Inst::Vse {
                sew: Sew::E64,
                vs: (8 + j * 2) as u8,
                addr: l.c_offset(j) + GROUP_ELEMS,
            });
        }
        p
    }

    fn host_overhead(&self) -> f64 {
        // Calibrated: OpenBLAS's level-3 framework + packing costs ~38% on
        // the SG2042 (its blocking is tuned for x86 cache ratios — exactly
        // the inefficiency Fig 6 exposes).
        0.38
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Matrix;

    #[test]
    fn computes_c_plus_ab() {
        let k = OpenblasC920;
        let a = Matrix::random_hpl(MR, 20, 31);
        let b = Matrix::random_hpl(20, NR, 32);
        let c = Matrix::random_hpl(MR, NR, 33);
        let out = k.run(&a, &b, &c, 128).unwrap();
        let mut want = c.clone();
        Matrix::gemm_acc(&mut want, &a, &b);
        assert!(out.allclose(&want, 1e-13, 1e-13));
    }

    #[test]
    fn is_native_thead() {
        let p = OpenblasC920.program(PanelLayout::new(MR, NR, 2));
        assert_eq!(p.dialect, Dialect::Thead071);
    }

    #[test]
    fn flds_are_hoisted_before_fmas() {
        // the software-pipelining property the cycle model rewards
        let p = OpenblasC920.program(PanelLayout::new(MR, NR, 1));
        let insts = &p.insts;
        let first_fma = insts.iter().position(|i| matches!(i, Inst::VfmaccVf { .. })).unwrap();
        let last_fld = insts.iter().rposition(|i| matches!(i, Inst::Fld { .. })).unwrap();
        assert!(last_fld < first_fma, "flds must precede the FMA burst");
    }

    #[test]
    fn per_kstep_instruction_count() {
        // 4 fld + 2 vle + 8 vfmacc + 3 bookkeeping = 17 per k-step
        let kc = 7;
        let p = OpenblasC920.program(PanelLayout::new(MR, NR, kc));
        let fixed = 1 + 8 + 8;
        assert_eq!(p.len(), fixed + kc * 17);
    }
}
