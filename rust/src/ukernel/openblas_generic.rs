//! OpenBLAS built for the generic RV64 target — the paper's no-vector
//! baseline: "serving as a baseline that does not leverage the processor's
//! vector unit" (Section 3.2).
//!
//! Pure scalar `fmadd.d` kernel with 4x4 register blocking (the shape the
//! generic C kernel compiles to): per k-step, 4 A loads + 4 B loads feed
//! 16 scalar FMAs held in f16..f31 accumulators.

use super::layout::PanelLayout;
use super::registry::{MicroKernel, UkernelId};
use crate::isa::inst::{Dialect, Inst, Program};

pub struct OpenblasGeneric;

pub const MR: usize = 4;
pub const NR: usize = 4;

impl MicroKernel for OpenblasGeneric {
    fn id(&self) -> UkernelId {
        UkernelId::OpenblasGeneric
    }

    fn tile(&self) -> (usize, usize) {
        (MR, NR)
    }

    fn program(&self, l: PanelLayout) -> Program {
        assert_eq!((l.mr, l.nr), (MR, NR), "OpenblasGeneric is a 4x4 kernel");
        let mut p = Program::new(Dialect::Rvv10); // dialect irrelevant: no vector insts
        // Load C tile into accumulators f16..f31 (column-major).
        for j in 0..NR {
            for i in 0..MR {
                p.push(Inst::Fld { fd: (16 + j * MR + i) as u8, addr: l.c_offset(j) + i });
            }
        }
        for k in 0..l.kc {
            // A column -> f0..f3, B row -> f4..f7
            for i in 0..MR {
                p.push(Inst::Fld { fd: i as u8, addr: l.a_offset(k) + i });
            }
            for j in 0..NR {
                p.push(Inst::Fld { fd: (4 + j) as u8, addr: l.b_offset(k) + j });
            }
            for j in 0..NR {
                for i in 0..MR {
                    let acc = (16 + j * MR + i) as u8;
                    p.push(Inst::FmaddD { fd: acc, fs1: i as u8, fs2: (4 + j) as u8, fs3: acc });
                }
            }
            p.push(Inst::Addi);
            p.push(Inst::Addi);
            p.push(Inst::Bnez);
        }
        for j in 0..NR {
            for i in 0..MR {
                p.push(Inst::Fsd { fs: (16 + j * MR + i) as u8, addr: l.c_offset(j) + i });
            }
        }
        p
    }

    fn host_overhead(&self) -> f64 {
        // Calibrated: the scalar kernel's slow inner loop makes framework
        // overhead relatively small (~16%).
        0.16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Matrix;

    #[test]
    fn computes_c_plus_ab() {
        let k = OpenblasGeneric;
        let a = Matrix::random_hpl(MR, 12, 41);
        let b = Matrix::random_hpl(12, NR, 42);
        let c = Matrix::random_hpl(MR, NR, 43);
        let out = k.run(&a, &b, &c, 128).unwrap();
        let mut want = c.clone();
        Matrix::gemm_acc(&mut want, &a, &b);
        assert!(out.allclose(&want, 1e-13, 1e-13));
    }

    #[test]
    fn uses_no_vector_instructions() {
        let p = OpenblasGeneric.program(PanelLayout::new(MR, NR, 8));
        assert!(p.insts.iter().all(|i| !i.is_vector()));
    }

    #[test]
    fn fma_matches_mul_add_semantics() {
        // fmadd.d uses fused rounding (mul_add); a 1-ulp check vs naive
        let k = OpenblasGeneric;
        let a = Matrix::random_hpl(MR, 3, 44);
        let b = Matrix::random_hpl(3, NR, 45);
        let c = Matrix::zeros(MR, NR);
        let out = k.run(&a, &b, &c, 128).unwrap();
        let mut want = Matrix::zeros(MR, NR);
        Matrix::gemm_acc(&mut want, &a, &b);
        assert!(out.allclose(&want, 1e-14, 1e-14));
    }

    #[test]
    fn per_kstep_instruction_count() {
        // 8 fld + 16 fmadd + 3 bookkeeping = 27 per k-step
        let kc = 5;
        let p = OpenblasGeneric.program(PanelLayout::new(MR, NR, kc));
        let fixed = 16 + 16; // C loads + stores
        assert_eq!(p.len(), fixed + kc * 27);
    }
}
